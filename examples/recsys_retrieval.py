"""Two-tower retrieval with MPAD-compressed candidates — the paper's native
integration (DESIGN.md §4): train a small two-tower model, embed the
catalog, fit MPAD on the candidate embeddings, and compare full-dim scoring
vs reduced-space scoring + exact re-rank.

Run: PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MPADConfig, fit_mpad
from repro.data.pipeline import twotower_batch
from repro.models.recsys import (TwoTowerConfig, twotower_init,
                                 twotower_item, twotower_loss,
                                 twotower_retrieve, twotower_user)
from repro.optim import AdamWConfig, init_opt_state, make_train_step


def main():
    cfg = TwoTowerConfig(name="tt-demo", n_users=2000, n_items=5000,
                         n_user_feats=8, field_dim=32, embed_dim=64,
                         tower_dims=(128, 64), n_negatives=256)
    params = twotower_init(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(
        lambda p, b: twotower_loss(p, cfg, b),
        AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=200)))
    opt = init_opt_state(params)
    for i in range(150):
        b = twotower_batch(jax.random.fold_in(jax.random.key(1), i), 512,
                           cfg.n_users, cfg.n_items, cfg.n_user_feats,
                           cfg.n_negatives)
        loss, params, opt = step(params, opt, b)
        if i % 20 == 0:
            print(f"step {i:3d} sampled-softmax loss {float(loss):.4f}")

    cand = twotower_item(params, cfg, jnp.arange(cfg.n_items))   # catalog
    red = fit_mpad(np.asarray(cand), MPADConfig(m=32, iters=80, alpha=25.0))
    print(f"\ncatalog embeddings {cand.shape} -> MPAD {red.matrix.shape[0]} dims")

    batch = {"user_ids": jnp.arange(1),
             "hist_ids": jnp.arange(8)[None, :], "cand_emb": cand}
    s_full, ids_full = twotower_retrieve(params, cfg, batch, k=20)
    s_red, ids_red = twotower_retrieve(
        params, cfg, batch, k=20, reducer=(red.matrix, red.mean), rerank=250)
    overlap = len(set(np.asarray(ids_full).tolist())
                  & set(np.asarray(ids_red).tolist()))
    print(f"top-20 overlap full-dim vs MPAD(64->32)+rerank250: {overlap}/20")
    print(f"scoring flops/query: full {2*cfg.n_items*cfg.embed_dim:,} -> "
          f"reduced {2*cfg.n_items*32 + 2*250*cfg.embed_dim:,} "
          f"({(2*cfg.n_items*cfg.embed_dim)/(2*cfg.n_items*32+2*250*cfg.embed_dim):.1f}x fewer)")


if __name__ == "__main__":
    main()
