"""Train a small LM with the full production substrate on CPU: deterministic
data pipeline, AdamW, checkpoint/restart with an injected failure.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 30]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.data.pipeline import lm_token_batches
from repro.models.transformer import LMConfig, lm_init_params, lm_train_forward
from repro.optim import AdamWConfig, init_opt_state, make_train_step
from repro.runtime import FailureInjector, run_with_restarts

CFG = LMConfig(name="lm-demo", n_layers=4, d_model=128, n_heads=8,
               n_kv_heads=4, d_head=16, d_ff=512, vocab=512,
               tie_embeddings=True, seq_chunk=64, q_chunk=64, kv_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    params = lm_init_params(jax.random.key(0), CFG)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        lambda p, b: lm_train_forward(p, CFG, b),
        AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps)))
    batches = list(lm_token_batches(0, args.batch, args.seq, CFG.vocab,
                                    n_steps=args.steps))
    losses = []

    def step_fn(state, i):
        loss, p, o = step(state["params"], state["opt"], batches[i])
        losses.append(float(loss))
        if i % 5 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
        return {"params": p, "opt": o}

    ckpt_dir = tempfile.mkdtemp(prefix="lm_ckpt_")
    try:
        # inject a failure mid-run: the loop resumes from the checkpoint and
        # replays the identical stream (deterministic pipeline)
        final = run_with_restarts(
            step_fn, {"params": params, "opt": opt}, args.steps, ckpt_dir,
            ckpt_every=10,
            injector=FailureInjector(fail_at=[args.steps // 2]))
        print(f"\nfirst loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
              f"(survived 1 injected failure, ckpts in {ckpt_dir})")
        assert losses[-1] < losses[0], "loss should decrease"
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
