"""Quickstart: fit MPAD on synthetic embeddings, compare k-NN preservation
against PCA and random projection in ~1 minute on CPU.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (MPADConfig, fit_mpad, fit_pca, fit_random_projection,
                        transform)
from repro.data.synthetic import make_fasttext_like
from repro.search import amk_accuracy

def main():
    key = jax.random.key(0)
    xtr, xte = make_fasttext_like(key, n_train=600, n_test=300)
    print(f"corpus: {xtr.shape}, queries: {xte.shape}")

    m, k = 30, 10                       # 300 -> 30 dims, top-10 neighbors
    mpad = fit_mpad(xtr, MPADConfig(m=m, alpha=50.0, b=80.0, iters=100))
    pca = fit_pca(xtr, m)
    rp = fit_random_projection(jax.random.key(1), xtr.shape[1], m)

    print(f"\nA_m(k={k}) — fraction of true neighbors kept after 10x "
          "compression:")
    for name, red in [("MPAD", mpad), ("PCA", pca), ("RandProj", rp)]:
        acc = float(amk_accuracy(red, xtr, xte, k))
        print(f"  {name:9s} {acc:.4f}")

    y = transform(mpad, xte)
    print(f"\nreduced queries: {y.shape}; projection rows unit-norm: "
          f"{float(abs(jax.numpy.linalg.norm(mpad.matrix, axis=1) - 1).max()):.2e}")


if __name__ == "__main__":
    main()
