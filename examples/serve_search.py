"""End-to-end serving driver (deliverable (b)): build a corpus, fit MPAD,
build IVF and IVF-PQ indexes over reduced vectors, serve batched queries
with exact re-rank, and report recall + latency vs the full-dimension exact
path. The IVF-PQ row is the full production memory hierarchy: reduce dims
-> coarse-quantize -> PQ-code the residuals -> ADC scan -> exact re-rank.

Run: PYTHONPATH=src python examples/serve_search.py [--corpus 20000]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import MPADConfig
from repro.data.synthetic import make_clustered
from repro.search import (SearchEngine, ServeConfig, build_engine,
                          knn_search, load_engine)
from repro.search.knn import recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--target-dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    key = jax.random.key(0)
    corpus, queries = make_clustered(
        key, args.corpus, args.queries, args.dim, n_clusters=64,
        spread=0.4, center_scale=1.5)
    print(f"corpus {corpus.shape}, queries {queries.shape}")

    _, truth = knn_search(queries, corpus, args.k)

    t0 = time.time()
    eng_full = SearchEngine(corpus, ServeConfig(target_dim=None))
    d, ids = eng_full.search(queries, args.k)
    jax.block_until_ready(ids)
    t_full_build = time.time() - t0
    t0 = time.time()
    d, ids_full = eng_full.search(queries, args.k)
    jax.block_until_ready(ids_full)
    t_full = time.time() - t0

    # pipelines are declared with index-spec strings: reduce -> coarse ->
    # [code ->] exact re-rank (repro.search.parse_spec for the grammar)
    t0 = time.time()
    eng = build_engine(
        corpus, f"qpad{args.target_dim}>ivf64x8>rr{4 * args.k}",
        mpad=MPADConfig(m=args.target_dim, iters=64, batch_size=2048),
        fit_sample=4096)
    print(f"build (fit MPAD + reduce + IVF): {time.time()-t0:.1f}s")
    d, ids = eng.search(queries, args.k)          # warm up / compile
    jax.block_until_ready(ids)
    t0 = time.time()
    d, ids = eng.search(queries, args.k)
    jax.block_until_ready(ids)
    t_mpad = time.time() - t0

    t0 = time.time()
    eng_pq = build_engine(
        corpus,
        f"qpad{args.target_dim}>ivf{max(args.corpus // 64, 16)}x4"
        f">pq{args.target_dim // 2}x256>rr{4 * args.k}",
        mpad=MPADConfig(m=args.target_dim, iters=64, batch_size=2048),
        fit_sample=4096)
    print(f"build (fit MPAD + reduce + IVF-PQ): {time.time()-t0:.1f}s")
    d, ids_pq = eng_pq.search(queries, args.k)    # warm up / compile
    jax.block_until_ready(ids_pq)
    t0 = time.time()
    d, ids_pq = eng_pq.search(queries, args.k)
    jax.block_until_ready(ids_pq)
    t_ivfpq = time.time() - t0

    # same index, int8-quantized ADC lookup tables (4x LUT memory cut);
    # lut_dtype is a query-time knob, so the engine is reused as-is
    import dataclasses
    eng_pq.config = dataclasses.replace(eng_pq.config, lut_dtype="int8")
    d, ids_pq8 = eng_pq.search(queries, args.k)   # warm up / compile
    jax.block_until_ready(ids_pq8)
    t0 = time.time()
    d, ids_pq8 = eng_pq.search(queries, args.k)
    jax.block_until_ready(ids_pq8)
    t_ivfpq8 = time.time() - t0

    # the reducer & index zoo: the same serving stack with the Reduce and
    # code stages swapped by spec string — PCA and a small nonlinear MLP
    # reducer ride everything the MPAD projection does, and OPQ's learned
    # rotation upgrades plain PQ at equal code bytes
    zoo = []
    for spec_s in (f"pca{args.target_dim}>flat",
                   f"mlp{args.target_dim}>flat",
                   f"opq{args.target_dim // 2}x256>rr{4 * args.k}"):
        eng_z = build_engine(corpus, spec_s, fit_sample=4096)
        _, ids_z = eng_z.search(queries, args.k)  # warm up / compile
        jax.block_until_ready(ids_z)
        t0 = time.time()
        _, ids_z = eng_z.search(queries, args.k)
        jax.block_until_ready(ids_z)
        zoo.append((spec_s, time.time() - t0,
                    float(recall_at_k(ids_z, truth))))

    # sharded serving: the same IVF-PQ engine partitioned over a data mesh
    # (every available device; on a plain CPU session that is a 1-device
    # mesh — run under XLA_FLAGS=--xla_force_host_platform_device_count=8
    # to see a real split). Results are identical to the unsharded path.
    from repro.launch.mesh import make_serving_mesh
    eng_pq.config = dataclasses.replace(eng_pq.config, lut_dtype="f32")
    mesh = make_serving_mesh()
    eng_pq.shard(mesh)
    d, ids_sh = eng_pq.search(queries, args.k)    # warm up / compile
    jax.block_until_ready(ids_sh)
    t0 = time.time()
    d, ids_sh = eng_pq.search(queries, args.k)
    jax.block_until_ready(ids_sh)
    t_shard = time.time() - t0
    n_shards = mesh.shape["data"]
    same = bool(jnp.all(ids_sh == ids_pq))

    # streaming: the same IVF-PQ layout with the write path enabled —
    # upsert fresh rows (served exactly from the delta segment), delete a
    # few, then compact them into the base (re-coded against the frozen
    # quantizers; no rebuild, no recompile)
    import numpy as np

    from repro.search import StreamConfig
    eng_s = SearchEngine(corpus, dataclasses.replace(
        eng_pq.config, stream=StreamConfig(delta_capacity=512)))
    nb = min(256, args.queries)
    fresh = queries[:nb] + 0.001 * jax.random.normal(
        jax.random.fold_in(key, 99), (nb, args.dim))
    t0 = time.time()
    eng_s.upsert(np.arange(args.corpus, args.corpus + nb), fresh)
    eng_s.delete(np.arange(0, 64))
    jax.block_until_ready(eng_s.store.delta_count)
    t_write = time.time() - t0
    _, ids_st = eng_s.search(queries[:nb], 1)
    hit_delta = float(np.mean(
        np.asarray(ids_st)[:, 0] == np.arange(args.corpus,
                                              args.corpus + nb)))
    t0 = time.time()
    eng_s.compact()
    t_compact = time.time() - t0
    _, ids_st = eng_s.search(queries[:nb], 1)
    hit_base = float(np.mean(
        np.asarray(ids_st)[:, 0] == np.arange(args.corpus,
                                              args.corpus + nb)))

    # snapshot persistence: spec + arrays round-trip through a directory
    # (covers the streaming store — tombstones and delta included)
    import tempfile
    with tempfile.TemporaryDirectory() as snap_dir:
        t0 = time.time()
        eng_s.save(snap_dir)
        eng_r = load_engine(snap_dir)
        t_snap = time.time() - t0
        _, ids_r = eng_r.search(queries[:nb], 1)
        snap_equal = bool(jnp.all(ids_r == ids_st))

    # durability: snapshot + write-ahead log. Mutations after durable()
    # hit the WAL before the store, so reopening the directory replays
    # them on top of the snapshot — a crash loses nothing acknowledged.
    from repro.search import DurabilityConfig
    with tempfile.TemporaryDirectory() as dur_dir:
        eng_s.durable(dur_dir, DurabilityConfig(fsync="batch"))
        eng_s.upsert(np.arange(args.corpus + nb, args.corpus + nb + 8),
                     fresh[:8])
        eng_s.delete(np.arange(args.corpus, args.corpus + 4))
        t0 = time.time()
        eng_d = load_engine(dur_dir)           # crash-recovery path
        t_recover = time.time() - t0
        _, ids_live = eng_s.search(queries[:nb], 1)
        _, ids_rec = eng_d.search(queries[:nb], 1)
        wal_equal = bool(jnp.all(ids_rec == ids_live))
        replayed = eng_d.metrics().wal.replayed

    rec = float(recall_at_k(ids, truth))
    rec_pq = float(recall_at_k(ids_pq, truth))
    rec_pq8 = float(recall_at_k(ids_pq8, truth))
    rec_sh = float(recall_at_k(ids_sh, truth))
    print(f"\nfull-dim exact : {t_full*1e3:7.1f} ms/batch  recall@{args.k}="
          f"{float(recall_at_k(ids_full, truth)):.4f}")
    print(f"MPAD {args.dim}->{args.target_dim} + IVF + rerank:"
          f" {t_mpad*1e3:7.1f} ms/batch  recall@{args.k}={rec:.4f}")
    print(f"MPAD {args.dim}->{args.target_dim} + IVF-PQ + rerank:"
          f" {t_ivfpq*1e3:7.1f} ms/batch  recall@{args.k}={rec_pq:.4f}")
    print(f"MPAD {args.dim}->{args.target_dim} + IVF-PQ int8 LUT + rerank:"
          f" {t_ivfpq8*1e3:7.1f} ms/batch  recall@{args.k}={rec_pq8:.4f}")
    print("reducer & index zoo (same stack, spec-swapped stages):")
    for spec_s, t_z, rec_z in zoo:
        print(f"  {spec_s:24s} {t_z*1e3:7.1f} ms/batch  "
              f"recall@{args.k}={rec_z:.4f}")
    print(f"MPAD {args.dim}->{args.target_dim} + IVF-PQ sharded x{n_shards}:"
          f" {t_shard*1e3:7.1f} ms/batch  recall@{args.k}={rec_sh:.4f}  "
          f"ids==unsharded: {same}")
    print(f"streaming IVF-PQ: {nb} upserts + 64 deletes in "
          f"{t_write*1e3:.1f} ms, fresh-top1 from delta {hit_delta:.3f}, "
          f"compact {t_compact*1e3:.0f} ms -> from base {hit_base:.3f}")
    print(f"snapshot save+load: {t_snap*1e3:.0f} ms, "
          f"restored ids == live engine: {snap_equal}")
    print(f"durable WAL: {replayed} records replayed on reopen in "
          f"{t_recover*1e3:.0f} ms, recovered ids == live engine: "
          f"{wal_equal}")
    m_sub = args.target_dim // 2
    print(f"bytes/vector: {args.dim*4} -> {args.target_dim*4} (reduced) -> "
          f"{m_sub} logical ivfpq code bytes "
          f"({args.dim*4/m_sub:.0f}x; stored as int32 in this repro, "
          f"{4*m_sub + 4}B incl. bias)")


if __name__ == "__main__":
    main()
