"""Paper Table 3: time complexity in practice — wall time of one MPAD
objective evaluation for the three backends as N grows, plus baseline fit
times. Verifies the beyond-paper O(N^2 log N) -> O(N log N) claim."""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.fast_objective import mu_b_fast_value_and_grad
from repro.core.objective import mu_b_exact_value_and_grad
from repro.kernels.mpad_pairwise import mu_kernel_value_and_grad


def _time(f, *args, reps=3, **kw):
    f(*args, **kw)                                   # compile
    t0 = time.time()
    for _ in range(reps):
        out = f(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(sizes, dim=64, b=80.0, out_dir="benchmarks/artifacts"):
    rows = []
    for n in sizes:
        x = jax.random.normal(jax.random.key(0), (n, dim))
        w = jax.random.normal(jax.random.key(1), (dim,))
        w = w / jnp.linalg.norm(w)
        t_fast = _time(mu_b_fast_value_and_grad, w, x, b=b)
        t_exact = (_time(mu_b_exact_value_and_grad, w, x, b=b)
                   if n <= 4096 else float("nan"))
        t_kernel = (_time(mu_kernel_value_and_grad, w, x, b=b)
                    if n <= 2048 else float("nan"))
        rows.append(dict(n=n, fast_ms=t_fast * 1e3, exact_ms=t_exact * 1e3,
                         kernel_interp_ms=t_kernel * 1e3))
        print(f"N={n:7d}  fast={t_fast*1e3:9.2f}ms  "
              f"exact(O(N^2))={t_exact*1e3:9.2f}ms  "
              f"kernel(interp)={t_kernel*1e3:9.2f}ms")
    # scaling exponents
    import math
    if len(rows) >= 3:
        r0, r1 = rows[0], rows[-1]
        exp_fast = math.log(r1["fast_ms"] / r0["fast_ms"]) / math.log(
            r1["n"] / r0["n"])
        print(f"\nfast-path empirical scaling exponent: {exp_fast:.2f} "
              "(1.0 = linear; paper's method is ~2.0)")
        fin = [r for r in rows if r["exact_ms"] == r["exact_ms"]]
        if len(fin) >= 2:
            e = math.log(fin[-1]["exact_ms"] / fin[0]["exact_ms"]) / math.log(
                fin[-1]["n"] / fin[0]["n"])
            print(f"exact-path empirical scaling exponent: {e:.2f}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table3_scaling.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="512,1024,2048,4096,16384,65536")
    args = ap.parse_args()
    run([int(s) for s in args.sizes.split(",")])


if __name__ == "__main__":
    main()
