"""Paper Fig. 2: robustness — how often each method ranks 1st/2nd in
A_m(k) across the MPAD (alpha, b) grid x global (ratio, k) combinations.

Default grid is a stratified subsample of the paper's 1000 settings per
dataset (the full grid is ~4x slower; pass --full for the exact 8x5 grid).
"""
from __future__ import annotations

import argparse
import json
import os
from collections import Counter

import jax

from repro.configs.mpad_paper import (ALPHA_GRID, B_GRID, K_VALUES,
                                      TARGET_RATIOS)
from repro.core import MPADConfig, fit_mpad
from repro.core.baselines import BASELINE_FITTERS
from repro.search import amk_accuracy

from .datasets import load


def run(datasets, alphas, bs, ratios, ks, iters=32, seed=0,
        out_dir="benchmarks/artifacts"):
    results = {}
    for ds in datasets:
        xtr, xte = load(ds, seed)
        n_dim = xtr.shape[1]
        first, second = Counter(), Counter()
        for ratio in ratios:
            m = max(1, int(round(ratio * n_dim)))
            base_reds = {name: fit(xtr, m, jax.random.key(seed + 7))
                         for name, fit in BASELINE_FITTERS.items()}
            base_acc = {}                      # (name, k) -> acc, computed once
            for k in ks:
                for name, red in base_reds.items():
                    base_acc[(name, k)] = float(amk_accuracy(red, xtr, xte, k))
            for alpha in alphas:
                for b in bs:
                    red = fit_mpad(xtr, MPADConfig(
                        m=m, alpha=alpha, b=b, iters=iters))
                    for k in ks:
                        acc = {"mpad": float(amk_accuracy(red, xtr, xte, k))}
                        for name in base_reds:
                            acc[name] = base_acc[(name, k)]
                        ranked = sorted(acc, key=acc.get, reverse=True)
                        first[ranked[0]] += 1
                        second[ranked[1]] += 1
        results[ds] = {"first": dict(first), "second": dict(second)}
        print(f"{ds}: first={dict(first)}")
        print(f"{ds}: second={dict(second)}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2_robustness.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="fasttext,isolet,arcene,pbmc3k")
    ap.add_argument("--full", action="store_true",
                    help="paper's full 8x5 (alpha, b) grid")
    args = ap.parse_args()
    if args.full:
        alphas, bs = ALPHA_GRID, B_GRID
    else:
        alphas, bs = [1.0, 25.0, 10000.0], [60.0, 80.0, 100.0]
    run(args.datasets.split(","), alphas, bs, TARGET_RATIOS, K_VALUES)


if __name__ == "__main__":
    main()
