"""CI bench regression gate: fail when serving perf or recall regresses.

Compares a freshly generated ``BENCH_serve.json`` (``benchmarks.run --fast
--json``) against the committed baseline and exits non-zero when, on the
gated row (batch-256 ivfpq, f32 LUT by default):

* QPS drops by more than ``--max-qps-drop`` (fractional, default 0.20), or
* recall@10 drops by more than ``--max-recall-drop`` (absolute, 0.02).

Once ``bench_stream`` rows are present, the streaming scenario is gated
too: update throughput (``upserts_per_sec``, fractional drop limit
``--max-ups-drop``, default 0.25) and the streaming recall@10 (same
absolute limit as the serving row). The ``durability`` section gates the
WAL write-path overhead within the fresh file (WAL-on upsert throughput
no more than ``--max-wal-overhead`` below WAL-off, default 0.25).

Two scan-path gates run within the fresh file (same machine, same run, so
no baseline needed): the quantized-LUT rows must hold ``qps >=
--min-lut-qps-ratio`` (default 0.95) of the f32 row, and the batch-64
fused-vs-staged speedup must stay >= ``--min-b64-speedup`` (default 1.0 —
the compact small-batch scan and re-rank pre-filter exist to keep it
there).

The ``observability`` section gates the tracing overhead within the
fresh file: an attached-but-inert tracer must cost <=
``--max-trace-off-overhead`` (default 1%) of batch-256 ivfpq p50, and
end-to-end histogram recording <= ``--max-hist-overhead`` (default 3%).

The ``zoo`` section gates the reducer/index-zoo recall pairs within the
fresh file: OPQ must hold recall@10 vs plain PQ at equal code bytes, and
the qpad (MPAD) reducer vs PCA at equal output dim — each within
``--zoo-recall-tol`` (default 0.005) of top-k tie noise.

A missing gated row in the FRESH file is itself a failure (the bench
silently lost coverage); a missing row in the BASELINE only warns, so the
gate can be introduced onto older baselines without a flag day.

The QPS compare is machine-absolute: refresh the committed baseline from a
CI artifact when runner hardware shifts, or widen ``--max-qps-drop`` if the
fleet is heterogeneous (recall@10 is hardware-independent either way).

  python benchmarks/check_regression.py BASELINE.json FRESH.json
"""
from __future__ import annotations

import argparse
import json
import sys

GATED = dict(index="ivfpq", lut_dtype="f32", batch=256)
STREAM_GATED = dict(scenario="stream_90_10", index="ivfpq")
# zoo recall pairs (challenger, reference): the challenger must hold
# recall@10 within --zoo-recall-tol of the reference, same file/run
ZOO_PAIRS = (
    ("opq-vs-pq@8B", "opq8x256", "pq8x256"),
    ("qpad-vs-pca@32d", "qpad32>flat", "pca32>flat"),
)


def find_row(doc: dict, key: str = "rows", **sel):
    for row in doc.get(key, []):
        if all(row.get(k) == v for k, v in sel.items()):
            return row
    return None


def check_stream(baseline: dict, fresh: dict, max_ups_drop: float = 0.25,
                 max_recall_drop: float = 0.02):
    """Gate the streaming scenario: update throughput + streaming recall.

    Active only once ``bench_stream`` rows exist: a baseline without a
    ``stream`` section skips the compare (pre-streaming baselines); a
    FRESH file without one while the baseline has it is a failure (the
    bench lost coverage).
    """
    failures, report = [], []
    base = find_row(baseline, key="stream", **STREAM_GATED)
    new = find_row(fresh, key="stream", **STREAM_GATED)
    sel = " ".join(f"{k}={v}" for k, v in STREAM_GATED.items())
    if base is None:
        report.append(f"baseline has no stream row ({sel}); skipping "
                      "stream compare")
        return failures, report
    if new is None:
        failures.append(f"fresh bench is missing the stream row ({sel})")
        return failures, report
    ups_drop = (1.0 - new["upserts_per_sec"] / base["upserts_per_sec"]
                if base["upserts_per_sec"] else 0.0)
    rec_drop = base["recall_at_10"] - new["recall_at_10"]
    report.append(f"upserts/s : {base['upserts_per_sec']} -> "
                  f"{new['upserts_per_sec']} (drop {ups_drop:+.1%}, "
                  f"limit {max_ups_drop:.0%})")
    report.append(f"stream rec: {base['recall_at_10']:.4f} -> "
                  f"{new['recall_at_10']:.4f} (drop {rec_drop:+.4f}, "
                  f"limit {max_recall_drop})")
    if ups_drop > max_ups_drop:
        failures.append(
            f"update-throughput regression on {sel}: "
            f"{base['upserts_per_sec']} -> {new['upserts_per_sec']} "
            f"({ups_drop:.1%} > {max_ups_drop:.0%})")
    if rec_drop > max_recall_drop:
        failures.append(
            f"streaming recall@10 regression on {sel}: "
            f"{base['recall_at_10']:.4f} -> {new['recall_at_10']:.4f} "
            f"(drop {rec_drop:.4f} > {max_recall_drop})")
    return failures, report


def check_durability(baseline: dict, fresh: dict,
                     max_wal_overhead: float = 0.25,
                     min_gc_speedup: float = 2.0,
                     max_inc_frac: float = 0.10):
    """Gate the durability/replication operations numbers.

    Unlike the throughput gates these are *within-file*: the fresh bench
    measures each pair on the same machine in the same run, so the ratios
    are hardware-independent and need no baseline:

    * WAL write-path overhead (``wal_overhead_frac`` <=
      ``--max-wal-overhead``),
    * group commit: the 8-thread fsync=always burst must run >=
      ``--min-group-commit-speedup`` faster grouped than ungrouped (the
      coalesced fsyncs are the whole point),
    * incremental snapshots: the delta-only link's bytes must stay <=
      ``--max-inc-snapshot-frac`` of the full checkpoint (delta-sized,
      not base-sized).

    A baseline without a ``durability`` section (or without the newer
    subsections) only means the gate predates it; a FRESH file missing
    something the baseline has is lost coverage.
    """
    failures, report = [], []
    new = fresh.get("durability")
    if new is None:
        if baseline.get("durability") is not None:
            failures.append("fresh bench is missing the durability section")
        else:
            report.append("no durability section; skipping WAL-overhead gate")
        return failures, report
    frac = new["wal_overhead_frac"]
    report.append(f"wal ovhd  : {new['upserts_per_sec_wal_off']} -> "
                  f"{new['upserts_per_sec_wal_on']} ups/s with WAL on "
                  f"({frac:+.1%}, limit {max_wal_overhead:.0%})")
    report.append(f"recovery  : {new['recovery_rows']} rows in "
                  f"{new['recovery_seconds']}s "
                  f"({new['recovery_rows_per_sec']} rows/s)")
    if frac > max_wal_overhead:
        failures.append(
            f"WAL write-path overhead too high: "
            f"{new['upserts_per_sec_wal_off']} -> "
            f"{new['upserts_per_sec_wal_on']} ups/s "
            f"({frac:.1%} > {max_wal_overhead:.0%})")
    base_dur = baseline.get("durability") or {}
    gc = new.get("group_commit")
    if gc is None:
        if base_dur.get("group_commit") is not None:
            failures.append("fresh bench is missing durability.group_commit")
    else:
        report.append(
            f"grp commit: {gc['appends_per_sec_ungrouped']} -> "
            f"{gc['appends_per_sec_grouped']} appends/s "
            f"({gc['speedup']:.2f}x, floor {min_gc_speedup}x; "
            f"fsyncs {gc['fsyncs_grouped']}/{gc['fsyncs_ungrouped']})")
        if gc["speedup"] < min_gc_speedup:
            failures.append(
                f"group-commit speedup too low: {gc['speedup']:.2f}x < "
                f"{min_gc_speedup}x on the fsync=always burst")
    inc = new.get("incremental_snapshot")
    if inc is None:
        if base_dur.get("incremental_snapshot") is not None:
            failures.append(
                "fresh bench is missing durability.incremental_snapshot")
    else:
        report.append(
            f"inc snap  : {inc['incremental_bytes']} of "
            f"{inc['full_bytes']} bytes "
            f"({inc['bytes_frac']:.1%}, limit {max_inc_frac:.0%}; "
            f"base_rows={inc['base_rows']} delta_rows={inc['delta_rows']})")
        if inc["bytes_frac"] > max_inc_frac:
            failures.append(
                f"incremental snapshot too large: "
                f"{inc['incremental_bytes']} bytes is "
                f"{inc['bytes_frac']:.1%} of the {inc['full_bytes']}-byte "
                f"full checkpoint (> {max_inc_frac:.0%} — the delta-only "
                "link is scaling with base rows)")
    return failures, report


def check_observability(baseline: dict, fresh: dict,
                        max_trace_off: float = 0.01,
                        max_hist: float = 0.03):
    """Gate the tracing overhead — within the fresh file.

    The three postures (no tracer / tracer attached but inert /
    histograms recording) run interleaved on the same ivfpq engine, so
    the paired median ratios are hardware-independent:

    * an inert tracer must cost <= ``--max-trace-off-overhead`` of p50
      (default 1% — the serve path takes no timestamp when every
      instrument is off),
    * end-to-end histogram recording must cost <= ``--max-hist-overhead``
      (default 3% — a block + bisect per search, nothing device-side).

    The ``latency_breakdown`` section (per-stage deep-trace shares) is
    lost-coverage-checked against the baseline like the other sections.
    """
    failures, report = [], []
    new = fresh.get("observability")
    if new is None:
        if baseline.get("observability") is not None:
            failures.append(
                "fresh bench is missing the observability section")
        else:
            report.append("no observability section; skipping tracing-"
                          "overhead gate")
        return failures, report
    report.append(
        f"trace ovhd: inert {new['trace_off_overhead']:+.2%} "
        f"(limit {max_trace_off:.0%}), histograms "
        f"{new['hist_overhead']:+.2%} (limit {max_hist:.0%}) on "
        f"base p50 {new['p50_us_base']}us")
    if new["trace_off_overhead"] > max_trace_off:
        failures.append(
            f"inert-tracer overhead too high: "
            f"{new['trace_off_overhead']:.2%} > {max_trace_off:.0%} "
            f"({new['p50_us_base']}us -> {new['p50_us_traced_off']}us "
            "p50 with an all-off tracer attached)")
    if new["hist_overhead"] > max_hist:
        failures.append(
            f"histogram-recording overhead too high: "
            f"{new['hist_overhead']:.2%} > {max_hist:.0%} "
            f"({new['p50_us_base']}us -> {new['p50_us_hist_on']}us "
            "p50 with e2e histograms on)")
    if baseline.get("latency_breakdown") and not fresh.get(
            "latency_breakdown"):
        failures.append("fresh bench is missing the latency_breakdown "
                        "section")
    return failures, report


def check_zoo(baseline: dict, fresh: dict, recall_tol: float = 0.005):
    """Gate the reducer/index-zoo recall pairs — within the fresh file.

    Both rows of each pair run on the same corpus in the same process, so
    the compare is hardware-independent and needs no baseline:

    * **opq vs pq at equal code bytes** — the learned rotation's whole
      point is better codes for the same budget; its fit keeps the best
      reconstruction among iterates *including* the un-rotated one, so
      falling below plain PQ's recall (beyond ``--zoo-recall-tol`` of
      top-k tie noise) means the rotation path is broken;
    * **qpad vs pca at equal output dim** — the paper's claim: the
      quantile-preserving projection beats variance-preserving PCA for
      neighbor retrieval at the same dimension budget.

    A baseline without a ``zoo`` section predates the zoo bench and only
    warns; a FRESH file missing it (or missing a pair row) is lost
    coverage and fails.
    """
    failures, report = [], []
    new = fresh.get("zoo")
    if new is None:
        if baseline.get("zoo") is not None:
            failures.append("fresh bench is missing the zoo section")
        else:
            report.append("no zoo section; skipping reducer/index-zoo gates")
        return failures, report
    for name, challenger, reference in ZOO_PAIRS:
        c = find_row(fresh, key="zoo", spec=challenger)
        r = find_row(fresh, key="zoo", spec=reference)
        missing = [s for s, row in ((challenger, c), (reference, r))
                   if row is None]
        if missing:
            failures.append(f"fresh bench is missing zoo row(s) "
                            f"{missing} ({name} gate)")
            continue
        gain = c["recall_at_10"] - r["recall_at_10"]
        report.append(f"zoo {name}: {challenger} {c['recall_at_10']:.4f} "
                      f"vs {reference} {r['recall_at_10']:.4f} "
                      f"(gain {gain:+.4f}, floor -{recall_tol})")
        if gain < -recall_tol:
            failures.append(
                f"zoo recall regression ({name}): {challenger} "
                f"recall@10 {c['recall_at_10']:.4f} fell "
                f"{-gain:.4f} below {reference} "
                f"{r['recall_at_10']:.4f} (> {recall_tol} tolerance)")
    return failures, report


def check_lut_parity(fresh: dict, min_ratio: float = 0.95):
    """Gate quantized-LUT throughput against f32 — within the fresh file.

    The narrow LUTs (bf16/int8) exist to make the ADC scan cheaper; a
    regression where they fall behind the f32 path (as the pre-uint8
    dequantize-then-gather refs did) defeats their purpose, so each
    quantized batch-256 ivfpq row must hold ``qps >= min_ratio * f32
    qps``. Same-machine, same-run rows: the ratio is hardware-independent
    and needs no baseline.
    """
    failures, report = [], []
    f32 = find_row(fresh, index="ivfpq", lut_dtype="f32", batch=256)
    if f32 is None:
        failures.append("fresh bench is missing the ivfpq f32 batch-256 "
                        "row (lut-parity gate)")
        return failures, report
    for lut in ("bf16", "int8"):
        row = find_row(fresh, index="ivfpq", lut_dtype=lut, batch=256)
        if row is None:
            failures.append(f"fresh bench is missing the ivfpq {lut} "
                            "batch-256 row (lut-parity gate)")
            continue
        ratio = row["qps"] / f32["qps"] if f32["qps"] else 1.0
        report.append(f"lut {lut:4s}: {row['qps']} qps vs f32 "
                      f"{f32['qps']} ({ratio:.2f}x, floor {min_ratio})")
        if ratio < min_ratio:
            failures.append(
                f"quantized-LUT slowdown: ivfpq {lut} runs {row['qps']} "
                f"qps vs f32 {f32['qps']} ({ratio:.2f}x < {min_ratio}x)")
    return failures, report


def check_small_batch(baseline: dict, fresh: dict,
                      min_b64_speedup: float = 1.0):
    """Gate the small-batch scan path — within the fresh file.

    The batch-64 fused-vs-staged speedup must stay >= ``min_b64_speedup``
    (the nprobe-proportional compact scan + re-rank pre-filter exist to
    fix the small-batch regression, so losing them must fail CI). The
    ``batch_sweep`` section is lost-coverage-checked against the baseline
    like the other sections.
    """
    failures, report = [], []
    if baseline.get("batch_sweep") and not fresh.get("batch_sweep"):
        failures.append("fresh bench is missing the batch_sweep section")
    row = find_row(fresh, key="staged_vs_fused", index="ivfpq", batch=64)
    if row is None:
        failures.append("fresh bench is missing the batch-64 "
                        "staged_vs_fused row (small-batch gate)")
        return failures, report
    report.append(f"b64 fused : {row['speedup']:.2f}x vs staged "
                  f"(floor {min_b64_speedup}x)")
    if row["speedup"] < min_b64_speedup:
        failures.append(
            f"small-batch regression: batch-64 fused-vs-staged speedup "
            f"{row['speedup']:.2f}x < {min_b64_speedup}x")
    return failures, report


def check(baseline: dict, fresh: dict, max_qps_drop: float = 0.20,
          max_recall_drop: float = 0.02, max_ups_drop: float = 0.25,
          max_wal_overhead: float = 0.25, min_lut_ratio: float = 0.95,
          min_b64_speedup: float = 1.0, min_gc_speedup: float = 2.0,
          max_inc_frac: float = 0.10, max_trace_off: float = 0.01,
          max_hist: float = 0.03, zoo_recall_tol: float = 0.005):
    """Returns (failures, report_lines); empty failures == gate passes."""
    failures, report = [], []
    zf, zr = check_zoo(baseline, fresh, zoo_recall_tol)
    failures += zf
    report += zr
    sf, sr = check_stream(baseline, fresh, max_ups_drop, max_recall_drop)
    failures += sf
    report += sr
    df, dr = check_durability(baseline, fresh, max_wal_overhead,
                              min_gc_speedup, max_inc_frac)
    failures += df
    report += dr
    of, orp = check_observability(baseline, fresh, max_trace_off, max_hist)
    failures += of
    report += orp
    lf, lr = check_lut_parity(fresh, min_lut_ratio)
    failures += lf
    report += lr
    bf, br = check_small_batch(baseline, fresh, min_b64_speedup)
    failures += bf
    report += br
    base = find_row(baseline, **GATED)
    new = find_row(fresh, **GATED)
    sel = " ".join(f"{k}={v}" for k, v in GATED.items())
    if new is None:
        failures.append(f"fresh bench is missing the gated row ({sel})")
        return failures, report
    if base is None:
        report.append(f"baseline has no gated row ({sel}); skipping compare")
        return failures, report
    qps_drop = 1.0 - new["qps"] / base["qps"] if base["qps"] else 0.0
    rec_drop = base["recall_at_10"] - new["recall_at_10"]
    report.append(f"qps    : {base['qps']} -> {new['qps']} "
                  f"(drop {qps_drop:+.1%}, limit {max_qps_drop:.0%})")
    report.append(f"recall : {base['recall_at_10']:.4f} -> "
                  f"{new['recall_at_10']:.4f} (drop {rec_drop:+.4f}, "
                  f"limit {max_recall_drop})")
    if qps_drop > max_qps_drop:
        failures.append(
            f"QPS regression on {sel}: {base['qps']} -> {new['qps']} "
            f"({qps_drop:.1%} > {max_qps_drop:.0%})")
    if rec_drop > max_recall_drop:
        failures.append(
            f"recall@10 regression on {sel}: {base['recall_at_10']:.4f} -> "
            f"{new['recall_at_10']:.4f} (drop {rec_drop:.4f} > "
            f"{max_recall_drop})")
    return failures, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_serve.json")
    ap.add_argument("fresh", help="freshly generated BENCH_serve.json")
    ap.add_argument("--max-qps-drop", type=float, default=0.20,
                    help="max fractional QPS drop (default 0.20)")
    ap.add_argument("--max-recall-drop", type=float, default=0.02,
                    help="max absolute recall@10 drop (default 0.02)")
    ap.add_argument("--max-ups-drop", type=float, default=0.25,
                    help="max fractional update-throughput drop on the "
                         "streaming scenario (default 0.25)")
    ap.add_argument("--max-wal-overhead", type=float, default=0.25,
                    help="max fractional upsert-throughput cost of the WAL "
                         "(WAL-on vs WAL-off, within the fresh file; "
                         "default 0.25)")
    ap.add_argument("--min-lut-qps-ratio", type=float, default=0.95,
                    help="min bf16/int8 QPS as a fraction of the f32 row "
                         "(within the fresh file; default 0.95)")
    ap.add_argument("--min-b64-speedup", type=float, default=1.0,
                    help="min batch-64 fused-vs-staged speedup (within the "
                         "fresh file; default 1.0)")
    ap.add_argument("--min-group-commit-speedup", type=float, default=2.0,
                    help="min grouped-vs-ungrouped speedup on the 8-thread "
                         "fsync=always burst (within the fresh file; "
                         "default 2.0)")
    ap.add_argument("--max-inc-snapshot-frac", type=float, default=0.10,
                    help="max incremental-snapshot bytes as a fraction of "
                         "the full checkpoint (within the fresh file; "
                         "default 0.10)")
    ap.add_argument("--max-trace-off-overhead", type=float, default=0.01,
                    help="max fractional p50 cost of an attached-but-inert "
                         "tracer (within the fresh file; default 0.01)")
    ap.add_argument("--max-hist-overhead", type=float, default=0.03,
                    help="max fractional p50 cost of e2e latency-histogram "
                         "recording (within the fresh file; default 0.03)")
    ap.add_argument("--zoo-recall-tol", type=float, default=0.005,
                    help="absolute recall@10 slack on the zoo pairs (opq "
                         "vs pq at equal bytes, qpad vs pca at equal dim; "
                         "within the fresh file; default 0.005)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures, report = check(baseline, fresh, args.max_qps_drop,
                             args.max_recall_drop, args.max_ups_drop,
                             args.max_wal_overhead, args.min_lut_qps_ratio,
                             args.min_b64_speedup,
                             args.min_group_commit_speedup,
                             args.max_inc_snapshot_frac,
                             args.max_trace_off_overhead,
                             args.max_hist_overhead,
                             args.zoo_recall_tol)
    for line in report:
        print(line)
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
