"""Paper Fig. 3: ablations — vary one parameter (k / target ratio / b /
alpha) with the others fixed; MPAD vs baselines."""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs.mpad_paper import (ALPHA_GRID, B_GRID, FIXED_PARAMS,
                                      K_VALUES, TARGET_RATIOS)
from repro.core import MPADConfig, fit_mpad
from repro.core.baselines import BASELINE_FITTERS
from repro.search import amk_accuracy

from .datasets import load

BASE = dict(ratio=0.2, k=10)


def run(dataset: str, iters=48, seed=0, out_dir="benchmarks/artifacts"):
    xtr, xte = load(dataset, seed)
    n_dim = xtr.shape[1]
    alpha0, b0 = FIXED_PARAMS[dataset]
    rows = []

    def eval_all(m, k, alpha, b, sweep, value):
        red = fit_mpad(xtr, MPADConfig(m=m, alpha=alpha, b=b, iters=iters))
        rows.append(dict(sweep=sweep, value=value, method="mpad",
                         acc=float(amk_accuracy(red, xtr, xte, k))))
        for name, fit in BASELINE_FITTERS.items():
            r = fit(xtr, m, jax.random.key(seed + 7))
            rows.append(dict(sweep=sweep, value=value, method=name,
                             acc=float(amk_accuracy(r, xtr, xte, k))))

    m0 = max(1, int(round(BASE["ratio"] * n_dim)))
    for k in K_VALUES:                                  # column 1: vary k
        eval_all(m0, k, alpha0, b0, "k", k)
    for ratio in TARGET_RATIOS:                         # column 2: vary ratio
        eval_all(max(1, int(round(ratio * n_dim))), BASE["k"], alpha0, b0,
                 "ratio", ratio)
    for b in B_GRID:                                    # column 3: vary b
        eval_all(m0, BASE["k"], alpha0, b, "b", b)
    for alpha in ALPHA_GRID:                            # column 4: vary alpha
        eval_all(m0, BASE["k"], alpha, b0, "alpha", alpha)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig3_ablation_{dataset}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    for sweep in ("k", "ratio", "b", "alpha"):
        print(f"\n--- {dataset}: vary {sweep} (base ratio={BASE['ratio']}, "
              f"k={BASE['k']}, alpha={alpha0}, b={b0}) ---")
        vals = sorted({r["value"] for r in rows if r["sweep"] == sweep})
        for v in vals:
            sub = {r["method"]: r["acc"] for r in rows
                   if r["sweep"] == sweep and r["value"] == v}
            best = max(sub, key=sub.get)
            print(f"  {sweep}={v:<8} " + " ".join(
                f"{m}={a:.3f}" for m, a in sub.items()) + f"  best={best}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="fasttext")
    args = ap.parse_args()
    run(args.dataset)


if __name__ == "__main__":
    main()
