"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. The dry-run records *per-device* quantities (XLA SPMD
compiles the per-device program), so:

  compute_term    = dot_flops_per_dev / 197e12        [s]
  memory_term     = bytes_per_dev     / 819e9         [s]  (op-level upper
                    bound: operands+outputs per fused op, fusion-internal
                    traffic excluded)
  collective_term = coll_bytes_per_dev / 50e9         [s]

All three use the trip-count-aware HLO analysis (scan bodies weighted by
known_trip_count — XLA's builtin cost_analysis counts them once).

MODEL_FLOPS is the analytic 6·N·D (dense) / 6·N_active·D (MoE) GLOBAL
count; utilization = MODEL_FLOPS / (dot_flops_per_dev * n_devices).

``--adc [BENCH_serve.json]`` prints the serving-side roofline term
instead: analytic bytes moved per query by the gathered ADC scan
(candidate codes + LUT + base/ids/scores), uint8 vs int32 stored codes and
padded vs compact gather width, from the bench's ``scan`` section.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
LINK_BW = 50e9              # bytes/s / ICI link

LUT_BYTES = {"f32": 4, "bf16": 2, "int8": 1}

__all__ = ["load_cells", "roofline_row", "adc_scan_bytes", "adc_report",
           "main"]


def adc_scan_bytes(width: int, m: int, kc: int, code_bytes: int,
                   lut_dtype: str = "f32") -> dict:
    """Analytic bytes moved per query by the gathered ivfpq ADC scan.

    ``width`` candidates each pull an M-byte-ish code row (``m *
    code_bytes`` — the term the uint8 end-to-end path shrank 4x by never
    materialising an int32 copy) plus a f32 base term and an id; the
    per-query LUT (``m * kc`` entries at the quantized width) is written
    once by the table build and read back by the gather; the scan emits
    one f32 score per candidate. Deliberately an operand-level model (like
    the HLO ``memory_term`` above): fusion-internal traffic excluded.
    """
    lut = m * kc * LUT_BYTES[lut_dtype] * 2     # build write + gather read
    codes = width * m * code_bytes
    base_ids = width * (4 + 4)
    scores = width * 4
    return {"lut_bytes": lut, "code_bytes": codes,
            "base_id_bytes": base_ids, "score_bytes": scores,
            "total_bytes": lut + codes + base_ids + scores}


def adc_report(bench_json: str = "BENCH_serve.json"):
    """Print per-query ADC-scan bytes for every (code width x gather
    width x lut_dtype) corner, anchored on the bench's measured ``scan``
    section. Returns the rows."""
    with open(bench_json) as f:
        doc = json.load(f)
    scan = doc.get("scan")
    cfg = doc.get("config", {})
    if scan is None:
        raise SystemExit(f"{bench_json} has no 'scan' section; regenerate "
                         "with: python -m benchmarks.run --fast --json")
    m, kc = cfg["pq_subspaces"], cfg["pq_centroids"]
    padded = scan["padded_scan_width"]
    compact = scan["compact_scan_cap"] or padded
    stored = scan["code_dtype"]
    rows = []
    hdr = (f"{'scan':8s} {'codes':6s} {'lut':5s} {'width':>6s} "
           f"{'code_B':>9s} {'lut_B':>8s} {'total_B':>9s} {'vs_worst':>8s}")
    print(f"ADC scan bytes/query (m={m} kc={kc}, stored codes {stored}, "
          f"nprobe={scan['nprobe']} max_cell={scan['max_cell']})")
    print(hdr)
    print("-" * len(hdr))
    worst = None
    for label, width in (("padded", padded), ("compact", compact)):
        for code_name, cb in (("int32", 4), ("uint8", 1)):
            for lut in ("f32", "bf16", "int8"):
                r = adc_scan_bytes(width, m, kc, cb, lut)
                r.update(scan=label, codes=code_name, lut_dtype=lut,
                         width=width)
                worst = worst or r["total_bytes"]
                r["frac_of_worst"] = r["total_bytes"] / worst
                rows.append(r)
                print(f"{label:8s} {code_name:6s} {lut:5s} {width:6d} "
                      f"{r['code_bytes']:9d} {r['lut_bytes']:8d} "
                      f"{r['total_bytes']:9d} {r['frac_of_worst']:8.3f}")
    return rows


def load_cells(art_dir: str, mesh: str = "pod_16x16"):
    cells = []
    for f in sorted(glob.glob(os.path.join(art_dir, f"{mesh}.*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def roofline_row(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec["status"], "reason": rec.get("reason", "")}
    n_dev = rec["n_devices"]
    flops = rec.get("hlo_dot_flops", rec.get("flops", 0.0))
    byts = rec.get("hlo_bytes_accessed", rec.get("bytes_accessed", 0.0))
    coll = rec.get("hlo_coll_bytes", rec.get("collectives", {}).get("total", 0))
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    model = rec.get("model_flops", 0.0)
    useful = model / (flops * n_dev) if flops else 0.0
    bound = max(t_c, t_m, t_x)
    # fraction of roofline: time the chip MUST spend on useful model flops
    # over the time the compiled program actually needs (bound by slowest term)
    frac = (model / n_dev / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {"arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "kind": rec["kind"], "n_devices": n_dev,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "model_flops": model,
            "useful_flops_ratio": useful, "roofline_frac": frac,
            "peak_mem_gb": rec.get("memory", {}).get(
                "peak_memory_in_bytes", 0) / 1e9}


def summarize(art_dir: str, mesh: str = "pod_16x16", out_json=None):
    rows = [roofline_row(r) for r in load_cells(art_dir, mesh)]
    ok = [r for r in rows if r.get("status") == "ok"]
    ok.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"{'arch':22s} {'shape':15s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} "
           f"{'roofl%':>7s} {'peakGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in ok:
        print(f"{r['arch']:22s} {r['shape']:15s} {r['compute_s']:10.2e} "
              f"{r['memory_s']:10.2e} {r['collective_s']:10.2e} "
              f"{r['dominant']:>10s} {r['useful_flops_ratio']:7.3f} "
              f"{100*r['roofline_frac']:6.1f}% {r['peak_mem_gb']:7.2f}")
    skipped = [r for r in rows if r.get("status") == "skipped"]
    for r in skipped:
        print(f"{r['arch']:22s} {r['shape']:15s} SKIPPED: {r['reason'][:60]}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--out", default="benchmarks/artifacts/roofline.json")
    ap.add_argument("--adc", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="BENCH_JSON",
                    help="report per-query ADC-scan bytes (uint8 vs int32 "
                         "codes, padded vs compact width) from the bench "
                         "JSON's scan section instead of the dry-run grid")
    args = ap.parse_args()
    if args.adc is not None:
        adc_report(args.adc)
        return
    summarize(args.artifacts, args.mesh, args.out)


if __name__ == "__main__":
    main()
