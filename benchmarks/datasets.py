"""Benchmark dataset loading following the paper's Table 4 protocol:
sample `dim` feature dimensions and `train`/`test` points from each
synthetic stand-in (seeded)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.mpad_paper import SAMPLING
from repro.data.synthetic import PAPER_DATASETS


def load(dataset: str, seed: int = 0):
    gen, _, _ = PAPER_DATASETS[dataset]
    prot = SAMPLING[dataset]
    key = jax.random.key(seed)
    xtr_full, xte_full = gen(jax.random.fold_in(key, 1))
    dim = prot["dim"]
    if xtr_full.shape[1] > dim:                     # paper: subsample dims
        cols = jax.random.choice(jax.random.fold_in(key, 2),
                                 xtr_full.shape[1], (dim,), replace=False)
        xtr_full, xte_full = xtr_full[:, cols], xte_full[:, cols]
    rtr = jax.random.choice(jax.random.fold_in(key, 3), xtr_full.shape[0],
                            (min(prot["train"], xtr_full.shape[0]),),
                            replace=False)
    rte = jax.random.choice(jax.random.fold_in(key, 4), xte_full.shape[0],
                            (min(prot["test"], xte_full.shape[0]),),
                            replace=False)
    return xtr_full[rtr], xte_full[rte]
