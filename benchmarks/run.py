"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Fast subset by default
(suitable for CI); the full paper grids live in the per-figure modules:

  fig1_accuracy.py   — Fig.1 average A_m(k), all methods x datasets
  fig2_robustness.py — Fig.2 first/second-place counts over param grid
  fig3_ablation.py   — Fig.3 single-parameter ablations
  table3_scaling.py  — Table 3 runtime scaling vs N
  roofline.py        — §Roofline terms per dry-run cell
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp


def _timeit(f, *args, reps=5, **kw):
    out = f(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = f(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6          # us


def bench_objective_backends(rows):
    """Table 3 (complexity): one objective eval, N=2048, n=64."""
    from repro.core.fast_objective import mu_b_fast_value_and_grad
    from repro.core.objective import mu_b_exact_value_and_grad
    from repro.kernels.mpad_pairwise import mu_kernel_value_and_grad
    n, d = 2048, 64
    x = jax.random.normal(jax.random.key(0), (n, d))
    w = jax.random.normal(jax.random.key(1), (d,))
    w = w / jnp.linalg.norm(w)
    us_fast = _timeit(mu_b_fast_value_and_grad, w, x, b=80.0)
    us_exact = _timeit(mu_b_exact_value_and_grad, w, x, b=80.0, reps=2)
    us_kern = _timeit(mu_kernel_value_and_grad, w, x, b=80.0, reps=2)
    rows.append(("mpad_objective_fast_N2048", us_fast,
                 f"speedup_vs_exact={us_exact / us_fast:.1f}x"))
    rows.append(("mpad_objective_exact_N2048", us_exact, "paper_faithful"))
    rows.append(("mpad_objective_kernel_N2048", us_kern,
                 "pallas_interpret_cpu"))


def bench_kernels(rows):
    from repro.kernels.knn_topk import knn_ref, knn_topk_pallas
    q = jax.random.normal(jax.random.key(0), (128, 64))
    x = jax.random.normal(jax.random.key(1), (4096, 64))
    us_k = _timeit(knn_topk_pallas, q, x, 10, reps=2)
    us_r = _timeit(knn_ref, q, x, 10)
    rows.append(("knn_topk_pallas_interp_4096", us_k, "interpret_mode"))
    rows.append(("knn_ref_jnp_4096", us_r, "oracle"))


def bench_fit(rows):
    from repro.core import MPADConfig, fit_mpad
    x = jax.random.normal(jax.random.key(0), (600, 128))
    t0 = time.time()
    res = fit_mpad(x, MPADConfig(m=16, iters=48))
    jax.block_until_ready(res.matrix)
    rows.append(("mpad_fit_600x128_m16", (time.time() - t0) * 1e6,
                 f"phi_final={float(res.objective_trace[-1, -1]):.3f}"))


def bench_accuracy(rows):
    """Fig.1 subset: fasttext stand-in, ratio 0.2, k=10, all methods."""
    from benchmarks.fig1_accuracy import run
    _, summary = run(["fasttext"], [0.2], [10], iters=32)
    for (ds, name), acc in summary.items():
        rows.append((f"amk_{ds}_{name}", 0.0, f"A_m(10)={acc:.4f}"))


def bench_serving(rows):
    from repro.core import MPADConfig
    from repro.search import SearchEngine, ServeConfig, knn_search
    from repro.search.knn import recall_at_k
    key = jax.random.key(0)
    centers = jax.random.normal(key, (32, 128)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (4096,), 0, 32)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (4096, 128))
    queries = corpus[:256] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (256, 128))
    eng_full = SearchEngine(corpus, ServeConfig(target_dim=None))
    eng_mpad = SearchEngine(corpus, ServeConfig(
        target_dim=16, rerank=64, mpad=MPADConfig(m=16, iters=32)))
    _, truth = knn_search(queries, corpus, 10)
    us_full = _timeit(eng_full.search, queries, 10, reps=3)
    us_mpad = _timeit(eng_mpad.search, queries, 10, reps=3)
    _, found = eng_mpad.search(queries, 10)
    rec = float(recall_at_k(found, truth))
    rows.append(("serve_full_dim128_4096x256q", us_full, "exact"))
    rows.append(("serve_mpad_dim16_rerank64", us_mpad,
                 f"recall@10={rec:.4f}"))


def bench_ivfpq(rows):
    """IVF-PQ recall/latency sweep (nprobe x pq_subspaces) vs the flat scan
    on a 16k x 128 clustered corpus — the acceptance grid for the residual
    index subsystem."""
    from repro.search import SearchEngine, ServeConfig, knn_search
    from repro.search.knn import recall_at_k
    key = jax.random.key(0)
    centers = jax.random.normal(key, (64, 128)) * 1.5
    lab = jax.random.randint(jax.random.fold_in(key, 1), (16384,), 0, 64)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (16384, 128))
    nq = 256
    queries = corpus[:nq] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (nq, 128))
    _, truth = knn_search(queries, corpus, 10)

    eng_flat = SearchEngine(corpus, ServeConfig(target_dim=None))
    us_flat = _timeit(eng_flat.search, queries, 10, reps=3)
    _, found = eng_flat.search(queries, 10)
    rec_flat = float(recall_at_k(found, truth))
    rows.append(("serve_flat_dim128_16384x256q", us_flat,
                 f"recall@10={rec_flat:.4f} us_per_q={us_flat / nq:.1f}"))

    import dataclasses
    for m in (8, 16):
        # one build per code budget; nprobe is a query-time knob
        eng = SearchEngine(corpus, ServeConfig(
            target_dim=None, rerank=64, index="ivfpq", nlist=256,
            pq_subspaces=m, pq_centroids=256))
        for nprobe in (2, 4, 8):
            eng.config = dataclasses.replace(eng.config, nprobe=nprobe)
            us = _timeit(eng.search, queries, 10, reps=3)
            _, found = eng.search(queries, 10)
            rec = float(recall_at_k(found, truth))
            rows.append((f"serve_ivfpq_m{m}_nprobe{nprobe}", us,
                         f"recall@10={rec:.4f} us_per_q={us / nq:.1f} "
                         f"speedup_vs_flat={us_flat / us:.1f}x"))


def roofline_summary(rows):
    art = "benchmarks/artifacts/dryrun"
    if not os.path.isdir(art):
        rows.append(("roofline", 0.0, "no_dryrun_artifacts_run_dryrun_first"))
        return
    from benchmarks.roofline import load_cells, roofline_row
    cells = [roofline_row(r) for r in load_cells(art)]
    ok = [r for r in cells if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"])
        best = max(ok, key=lambda r: r["roofline_frac"])
        rows.append(("roofline_cells_ok", float(len(ok)),
                     f"of_{len(cells)}"))
        rows.append((f"roofline_worst_{worst['arch']}.{worst['shape']}",
                     0.0, f"frac={worst['roofline_frac']:.3f}"))
        rows.append((f"roofline_best_{best['arch']}.{best['shape']}",
                     0.0, f"frac={best['roofline_frac']:.3f}"))


def main() -> None:
    rows = []
    for bench in (bench_objective_backends, bench_kernels, bench_fit,
                  bench_serving, bench_ivfpq, bench_accuracy,
                  roofline_summary):
        try:
            bench(rows)
        except Exception as e:                       # keep the harness going
            rows.append((bench.__name__, -1.0, f"ERROR:{type(e).__name__}"))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
