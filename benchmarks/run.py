"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Fast subset by default
(suitable for CI); the full paper grids live in the per-figure modules:

  fig1_accuracy.py   — Fig.1 average A_m(k), all methods x datasets
  fig2_robustness.py — Fig.2 first/second-place counts over param grid
  fig3_ablation.py   — Fig.3 single-parameter ablations
  table3_scaling.py  — Table 3 runtime scaling vs N
  roofline.py        — §Roofline terms per dry-run cell

``--json [PATH]`` additionally writes ``BENCH_serve.json`` — the serving
perf trajectory (p50/p95 per query batch, QPS, recall@10 per index kind x
lut_dtype, the fused-vs-staged pipeline speedup, plus the reducer/index
``zoo`` grid: recall@10 + QPS per registered reducer x index spec); the
CSV output is unchanged. ``--fast`` runs only the serving + kernel subset (CI budget).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp


def _timeit(f, *args, reps=5, **kw):
    out = f(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = f(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6          # us


def _timeit_dist(f, *args, reps=9, **kw):
    """Per-call wall times (us), warmed; for percentile reporting."""
    out = f(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = f(*args, **kw)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return sorted(ts)


def _pctl(ts, p):
    return ts[min(len(ts) - 1, int(round(p / 100 * (len(ts) - 1))))]


def _timeit_interleaved(fns, reps=9, calls=1):
    """Per-variant wall times (us) with the variants time-sliced
    round-robin: every round times each variant once, in one process, so
    machine-load drift hits all variants equally and the cross-variant
    RATIOS the regression gates check stay trustworthy even when absolute
    numbers wander (single-core CI boxes drift 20-30% between time
    slices). ``fns`` is an ordered dict name -> nullary callable; every
    variant is warmed once before any timing. Returns name -> us in ROUND
    ORDER (same-index entries across variants are temporally adjacent, the
    alignment paired-ratio estimators need; sort for percentiles).
    ``calls`` > 1 times that many back-to-back invocations per turn and
    records the per-call mean — the first call after a variant switch
    runs with the other variant's working set still in cache, so
    averaging a short burst keeps the interleaving fair to BOTH variants
    instead of charging each one its neighbor's evictions. Callables may
    be stateful (each is invoked exactly ``reps * calls + 1`` times)."""
    for f in fns.values():
        jax.block_until_ready(f())
    ts = {name: [] for name in fns}
    for _ in range(reps):
        for name, f in fns.items():
            t0 = time.perf_counter()
            for _ in range(calls):
                jax.block_until_ready(f())
            ts[name].append((time.perf_counter() - t0) * 1e6 / calls)
    return ts


def bench_objective_backends(rows):
    """Table 3 (complexity): one objective eval, N=2048, n=64."""
    from repro.core.fast_objective import mu_b_fast_value_and_grad
    from repro.core.objective import mu_b_exact_value_and_grad
    from repro.kernels.mpad_pairwise import mu_kernel_value_and_grad
    n, d = 2048, 64
    x = jax.random.normal(jax.random.key(0), (n, d))
    w = jax.random.normal(jax.random.key(1), (d,))
    w = w / jnp.linalg.norm(w)
    us_fast = _timeit(mu_b_fast_value_and_grad, w, x, b=80.0)
    us_exact = _timeit(mu_b_exact_value_and_grad, w, x, b=80.0, reps=2)
    us_kern = _timeit(mu_kernel_value_and_grad, w, x, b=80.0, reps=2)
    rows.append(("mpad_objective_fast_N2048", us_fast,
                 f"speedup_vs_exact={us_exact / us_fast:.1f}x"))
    rows.append(("mpad_objective_exact_N2048", us_exact, "paper_faithful"))
    rows.append(("mpad_objective_kernel_N2048", us_kern,
                 "pallas_interpret_cpu"))


def bench_kernels(rows):
    from repro.kernels.knn_topk import knn_ref, knn_topk_pallas
    q = jax.random.normal(jax.random.key(0), (128, 64))
    x = jax.random.normal(jax.random.key(1), (4096, 64))
    us_k = _timeit(knn_topk_pallas, q, x, 10, reps=2)
    us_r = _timeit(knn_ref, q, x, 10)
    rows.append(("knn_topk_pallas_interp_4096", us_k, "interpret_mode"))
    rows.append(("knn_ref_jnp_4096", us_r, "oracle"))


def bench_fit(rows):
    from repro.core import MPADConfig, fit_mpad
    x = jax.random.normal(jax.random.key(0), (600, 128))
    t0 = time.time()
    res = fit_mpad(x, MPADConfig(m=16, iters=48))
    jax.block_until_ready(res.matrix)
    rows.append(("mpad_fit_600x128_m16", (time.time() - t0) * 1e6,
                 f"phi_final={float(res.objective_trace[-1, -1]):.3f}"))


def bench_accuracy(rows):
    """Fig.1 subset: fasttext stand-in, ratio 0.2, k=10, all methods."""
    from benchmarks.fig1_accuracy import run
    _, summary = run(["fasttext"], [0.2], [10], iters=32)
    for (ds, name), acc in summary.items():
        rows.append((f"amk_{ds}_{name}", 0.0, f"A_m(10)={acc:.4f}"))


def bench_serving(rows):
    from repro.core import MPADConfig
    from repro.search import SearchEngine, ServeConfig, knn_search
    from repro.search.knn import recall_at_k
    key = jax.random.key(0)
    centers = jax.random.normal(key, (32, 128)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (4096,), 0, 32)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (4096, 128))
    queries = corpus[:256] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (256, 128))
    eng_full = SearchEngine(corpus, ServeConfig(target_dim=None))
    eng_mpad = SearchEngine(corpus, ServeConfig(
        target_dim=16, rerank=64, mpad=MPADConfig(m=16, iters=32)))
    _, truth = knn_search(queries, corpus, 10)
    us_full = _timeit(eng_full.search, queries, 10, reps=3)
    us_mpad = _timeit(eng_mpad.search, queries, 10, reps=3)
    _, found = eng_mpad.search(queries, 10)
    rec = float(recall_at_k(found, truth))
    rows.append(("serve_full_dim128_4096x256q", us_full, "exact"))
    rows.append(("serve_mpad_dim16_rerank64", us_mpad,
                 f"recall@10={rec:.4f}"))


def bench_ivfpq(rows):
    """IVF-PQ recall/latency sweep (nprobe x pq_subspaces) vs the flat scan
    on a 16k x 128 clustered corpus — the acceptance grid for the residual
    index subsystem."""
    from repro.search import SearchEngine, ServeConfig, knn_search
    from repro.search.knn import recall_at_k
    key = jax.random.key(0)
    centers = jax.random.normal(key, (64, 128)) * 1.5
    lab = jax.random.randint(jax.random.fold_in(key, 1), (16384,), 0, 64)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (16384, 128))
    nq = 256
    queries = corpus[:nq] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (nq, 128))
    _, truth = knn_search(queries, corpus, 10)

    eng_flat = SearchEngine(corpus, ServeConfig(target_dim=None))
    us_flat = _timeit(eng_flat.search, queries, 10, reps=3)
    _, found = eng_flat.search(queries, 10)
    rec_flat = float(recall_at_k(found, truth))
    rows.append(("serve_flat_dim128_16384x256q", us_flat,
                 f"recall@10={rec_flat:.4f} us_per_q={us_flat / nq:.1f}"))

    import dataclasses
    for m in (8, 16):
        # one build per code budget; nprobe is a query-time knob
        eng = SearchEngine(corpus, ServeConfig(
            target_dim=None, rerank=64, index="ivfpq", nlist=256,
            pq_subspaces=m, pq_centroids=256))
        for nprobe in (2, 4, 8):
            eng.config = dataclasses.replace(eng.config, nprobe=nprobe)
            us = _timeit(eng.search, queries, 10, reps=3)
            _, found = eng.search(queries, 10)
            rec = float(recall_at_k(found, truth))
            rows.append((f"serve_ivfpq_m{m}_nprobe{nprobe}", us,
                         f"recall@10={rec:.4f} us_per_q={us / nq:.1f} "
                         f"speedup_vs_flat={us_flat / us:.1f}x"))


# --- one-program serving trajectory (BENCH_serve.json) -----------------------

@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _prepr_ivfpq_search(cent, lists, cbs, codes, bias, q, k, nprobe):
    """The pre-PR-2 per-stage scan, pinned: einsum tables + scattered
    ``codes[cid]``/``bias[cid]`` gathers + per-subspace lookup loop. Kept
    verbatim so BENCH_serve.json's ``staged_vs_fused`` rows keep measuring
    against the same baseline as the repo evolves."""
    nq = q.shape[0]
    m, kc, dsub = cbs.shape
    cd2 = (jnp.sum(q * q, 1)[:, None] + jnp.sum(cent * cent, 1)[None, :]
           - 2.0 * q @ cent.T)
    _, probe = jax.lax.top_k(-cd2, nprobe)
    cd2p = jnp.take_along_axis(cd2, probe, axis=1)
    cand = lists[probe].reshape(nq, -1)
    valid = cand >= 0
    cid = jnp.maximum(cand, 0)
    qs = q.reshape(nq, m, dsub)
    tables = (jnp.sum(cbs ** 2, -1)[None]
              - 2.0 * jnp.einsum("qmd,mkd->qmk", qs, cbs))
    base = jnp.repeat(cd2p, lists.shape[1], axis=1)
    base = jnp.where(valid, base + bias[cid], jnp.inf)
    ccodes = codes[cid]
    d2 = base
    for j in range(m):
        d2 = d2 + jnp.take_along_axis(tables[:, j, :], ccodes[:, :, j],
                                      axis=1)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.where(sel >= 0,
                    jnp.take_along_axis(cand, jnp.maximum(sel, 0), axis=1),
                    -1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


@functools.partial(jax.jit, static_argnames=("k",))
def _prepr_rerank(queries, corpus, cand, k):
    cv = corpus[jnp.maximum(cand, 0)]
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(cand >= 0, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    return (jnp.sqrt(jnp.maximum(-neg, 0.0)),
            jnp.take_along_axis(cand, sel, axis=1))


def bench_serve_fused(rows, json_doc=None, fast=False):
    """The serving perf trajectory: p50/p95 us per query batch, QPS and
    recall@10 per index kind x lut_dtype on the 16k x 128 grid, plus the
    one-program engine vs the pre-PR per-stage pipeline (the PR-2
    acceptance row: >= 2x QPS at recall@10 >= 0.9)."""
    import dataclasses
    from repro.search import build_engine, knn_search
    from repro.search.knn import recall_at_k
    n, dim, nq, k = 16384, 128, 256, 10
    key = jax.random.key(0)
    centers = jax.random.normal(key, (64, dim)) * 1.5
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 64)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, dim))
    queries = corpus[:nq] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (nq, dim))
    _, truth = knn_search(queries, corpus, k)
    # staged-baseline knobs (shared with the pinned pre-PR pipeline below)
    base_cfg = dict(target_dim=None, rerank=64, nlist=256, nprobe=8,
                    pq_subspaces=16, pq_centroids=256)
    # engines are declared by pipeline-spec strings (the composable API);
    # each spec lowers onto the same knobs as the old flat configs
    grid = [("ivfpq", "ivf256x8>pq16x256", ("f32", "bf16", "int8"))]
    if not fast:
        grid = [("flat", "flat", ("f32",)),
                ("ivf", "ivf256x8", ("f32",)),
                ("pq", "pq16x256", ("f32", "bf16", "int8"))] + grid
    reps = 5 if fast else 9
    doc_rows, sweep_rows = [], []
    for index, spec, luts in grid:
        eng = build_engine(corpus, spec)
        # the bf16/int8-vs-f32 QPS ratio is a regression gate
        # (check_regression.py), so the three LUT widths are timed
        # interleaved — configs prebuilt so the timed call is search-only
        cfgs = {lut: dataclasses.replace(eng.config, lut_dtype=lut)
                for lut in luts}

        def _lut_call(lut):
            def go():
                eng.config = cfgs[lut]
                return eng.search(queries, k)
            return go

        ts_lut = _timeit_interleaved({lut: _lut_call(lut) for lut in luts},
                                     reps=max(reps, 9), calls=2)
        for lut in luts:
            eng.config = cfgs[lut]
            ts = sorted(ts_lut[lut])
            p50, p95 = _pctl(ts, 50), _pctl(ts, 95)
            _, found = eng.search(queries, k)
            rec = float(recall_at_k(found, truth))
            qps = nq / (p50 * 1e-6)
            rows.append((f"serve_fused_{index}_lut_{lut}", p50,
                         f"recall@10={rec:.4f} p95_us={p95:.0f} "
                         f"qps={qps:.0f}"))
            doc_rows.append(dict(index=index, lut_dtype=lut, batch=nq,
                                 p50_us=round(p50, 1), p95_us=round(p95, 1),
                                 us_per_query_p50=round(p50 / nq, 2),
                                 qps=round(qps), recall_at_10=round(rec, 4)))
        # batch sweep: p50 latency across the traffic range {1, 8, 64, 256}.
        # On the read-only ivfpq engine small buckets (<= compact_batch)
        # take the nprobe-proportional compact scan whenever the posting-
        # mass bound beats the padded width (bit-identical results, smaller
        # program); the opt-in re-rank pre-filter (prefilter_batch) stays
        # off here — on this corpus the PQ error bound is loose, so it
        # admits nearly all candidates and costs more than it saves.
        eng.config = dataclasses.replace(eng.config, lut_dtype=luts[0])
        for b in (1, 8, 64, 256):
            ts_b = _timeit_dist(eng.search, queries[:b], k, reps=reps)
            p50_b = _pctl(ts_b, 50)
            compact = (index == "ivfpq" and eng.last_bucket is not None
                       and eng.last_bucket <= eng.config.compact_batch
                       and eng._scan_cap(eng.config.nprobe) > 0)
            rows.append((f"serve_sweep_{index}_b{b}", p50_b,
                         f"us_per_q={p50_b / b:.1f} "
                         f"qps={b / (p50_b * 1e-6):.0f} "
                         f"compact={'Y' if compact else 'n'}"))
            sweep_rows.append(dict(
                index=index, lut_dtype=luts[0], batch=b,
                p50_us=round(p50_b, 1),
                us_per_query_p50=round(p50_b / b, 2),
                qps=round(b / (p50_b * 1e-6)),
                compact_scan=compact))
        if index == "ivfpq":
            if json_doc is not None:
                # scan-path metadata: what the compact scan + narrow codes
                # buy per query (roofline.py turns these into bytes moved)
                idxp = eng.state.index.payload
                json_doc["scan"] = dict(
                    index="ivfpq",
                    code_dtype=str(idxp.codes.dtype),
                    code_bytes_per_vector=(
                        idxp.codes.dtype.itemsize * idxp.codes.shape[1]),
                    nprobe=eng.config.nprobe,
                    max_cell=int(idxp.lists.shape[1]),
                    padded_scan_width=(eng.config.nprobe
                                       * int(idxp.lists.shape[1])),
                    compact_scan_cap=eng._scan_cap(eng.config.nprobe),
                    compact_batch=eng.config.compact_batch,
                    prefilter_batch=eng.config.prefilter_batch)
            # staged baseline: pre-PR pipeline = separate scan + re-rank
            # programs over the same index arrays
            idx = eng.state.index.payload        # the dense IVFPQIndex
            eng.config = dataclasses.replace(eng.config, lut_dtype="f32")

            def staged(q, k):
                _, cand = _prepr_ivfpq_search(
                    idx.centroids, idx.lists, idx.codebooks, idx.codes,
                    idx.bias, q, base_cfg["rerank"], base_cfg["nprobe"])
                return _prepr_rerank(q, eng.state.corpus, cand, k)

            staged_rows = []
            for b in (64, nq):
                # the b64 speedup is a regression gate: staged and fused
                # are timed back-to-back every round and the speedup is
                # the MEDIAN PER-ROUND RATIO — pairing cancels machine
                # drift that medians-of-separate-windows cannot; short
                # calls, so extra rounds are cheap insurance
                ts_sf = _timeit_interleaved(
                    {"staged": lambda: staged(queries[:b], k),
                     "fused": lambda: eng.search(queries[:b], k)},
                    reps=max(reps, 11), calls=2)
                p50_s = _pctl(sorted(ts_sf["staged"]), 50)
                p50_f = _pctl(sorted(ts_sf["fused"]), 50)
                _, f_s = staged(queries[:b], k)
                _, f_f = eng.search(queries[:b], k)
                rec_s = float(recall_at_k(f_s, truth[:b]))
                rec_f = float(recall_at_k(f_f, truth[:b]))
                speedup = _pctl(sorted(s / f for s, f in
                                       zip(ts_sf["staged"],
                                           ts_sf["fused"])), 50)
                rows.append((f"serve_staged_vs_fused_ivfpq_b{b}", p50_f,
                             f"staged_us={p50_s:.0f} speedup={speedup:.2f}x "
                             f"recall_fused={rec_f:.4f}"))
                staged_rows.append(dict(
                    index="ivfpq", batch=b, staged_p50_us=round(p50_s, 1),
                    fused_p50_us=round(p50_f, 1),
                    speedup=round(speedup, 2),
                    staged_recall_at_10=round(rec_s, 4),
                    fused_recall_at_10=round(rec_f, 4)))
            if json_doc is not None:
                json_doc["staged_vs_fused"] = staged_rows

            # --- observability overhead + per-stage breakdown ---------
            # the overhead numbers are regression gates (<=1% with a
            # tracer attached but inert, <=3% with histograms recording)
            # so the three postures run interleaved on the SAME engine
            # and the overhead is the median per-round base/variant time
            # ratio — pairing cancels machine drift
            from repro.search import TraceConfig, deep_trace
            from repro.search.tracing import Tracer

            def _posture(tracer):
                def go():
                    eng._tracer = tracer
                    return eng.search(queries, k)
                return go

            # the gated overheads are ~1%, far under this box class's
            # round-to-round noise, so the estimator needs many paired
            # rounds with short bursts to converge (25x3 per posture)
            ts_o = _timeit_interleaved(
                {"base": _posture(None),
                 "traced_off": _posture(Tracer(TraceConfig(
                     histograms=False))),
                 "hist_on": _posture(Tracer(TraceConfig()))},
                reps=max(reps, 25), calls=3)
            eng._tracer = None
            p50_o = {name: _pctl(sorted(ts), 50)
                     for name, ts in ts_o.items()}

            # upper-quartile paired ratio, not the median: the true
            # costs (an attribute check; a bisect + two adds) sit far
            # below this box class's noise floor, and load noise is
            # one-sided (spikes only slow calls down) — a REAL hot-path
            # regression (a stray sync/copy is >=1ms on this batch)
            # shifts the whole ratio distribution and still trips the
            # gate, while round-level spikes no longer do
            def _overhead(variant):
                return max(0.0, 1.0 - _pctl(sorted(
                    b / v for b, v in zip(ts_o["base"], ts_o[variant])),
                    75))

            ov_off = _overhead("traced_off")
            ov_hist = _overhead("hist_on")
            rows.append(("serve_observability_overhead", 0.0,
                         f"traced_off={ov_off:.2%} hist_on={ov_hist:.2%} "
                         f"base_p50_us={p50_o['base']:.0f}"))
            # per-stage attribution across the traffic range: the staged
            # re-run deep_trace samples in production, at bench precision
            kwd = dict(nprobe=eng.config.nprobe, rerank=eng.config.rerank,
                       backend=eng.config.pq_backend,
                       interpret=eng.config.pq_interpret,
                       lut_dtype="f32", scan_cap=0, prefilter=0)
            breakdown = []
            for b in (1, 64, nq):
                runs = [deep_trace(eng, queries[:b], k, kwd)
                        for _ in range(3)]
                names = [s for s, _ in runs[0]["stages"]]
                med = {s: sorted(r["stages"][i][1] for r in runs)[1]
                       for i, s in enumerate(names)}
                e2e = sorted(r["e2e_ms"] for r in runs)[1]
                total = sum(med.values()) or 1.0
                shares = {s: round(ms / total, 3) for s, ms in med.items()}
                rows.append((f"serve_latency_breakdown_b{b}", e2e * 1e3,
                             " ".join(f"{s}={shares[s]:.0%}"
                                      for s in names)))
                breakdown.append(dict(
                    index="ivfpq", batch=b, e2e_ms=round(e2e, 4),
                    stages_ms={s: round(ms, 4) for s, ms in med.items()},
                    shares=shares))
            if json_doc is not None:
                json_doc["observability"] = dict(
                    index="ivfpq", batch=nq,
                    p50_us_base=round(p50_o["base"], 1),
                    p50_us_traced_off=round(p50_o["traced_off"], 1),
                    p50_us_hist_on=round(p50_o["hist_on"], 1),
                    trace_off_overhead=round(ov_off, 4),
                    hist_overhead=round(ov_hist, 4))
                json_doc["latency_breakdown"] = breakdown
    if json_doc is not None:
        json_doc["rows"] = doc_rows
        json_doc["batch_sweep"] = sweep_rows
        json_doc["config"] = dict(corpus=n, dim=dim, batch=nq, k=k,
                                  **base_cfg)


def bench_stream(rows, json_doc=None, fast=False):
    """Streaming (mutable) serving: interleaved 90/10 read/write workload
    on the 16k x 128 ivfpq grid — update throughput, search latency under
    write load, and the staleness story (fresh rows served exactly from
    the delta vs re-coded through PQ after compaction)."""
    import numpy as np

    from repro.search import (SearchEngine, ServeConfig, StreamConfig,
                              knn_search)
    from repro.search.knn import recall_at_k
    n, dim, nq, k = 16384, 128, 256, 10
    key = jax.random.key(0)
    centers = jax.random.normal(key, (64, dim)) * 1.5
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 64)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, dim))
    queries = corpus[:nq] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (nq, dim))
    _, truth = knn_search(queries, corpus, k)
    wb = 256
    # cell_slack widens every probed cell, so it is a latency knob as much
    # as a capacity one: ~128 slots absorbs this workload's appends (~4k
    # rows over 256 cells) without inflating the probe-scan width
    eng = SearchEngine(corpus, ServeConfig(
        target_dim=None, rerank=64, index="ivfpq", nlist=256, nprobe=8,
        pq_subspaces=16, pq_centroids=256,
        stream=StreamConfig(delta_capacity=1024, write_bucket=wb,
                            row_capacity=n + 16384, cell_slack=128)))
    rng = np.random.RandomState(0)
    next_id = n

    def write_batch():
        nonlocal next_id
        ids = np.arange(next_id, next_id + wb)
        next_id += wb
        vecs = rng.randn(wb, dim).astype(np.float32)
        eng.upsert(ids, vecs)
        jax.block_until_ready(eng.store.delta_count)

    # warmup every program (search / upsert / delete / compact)
    eng.search(queries, k)
    write_batch()
    eng.delete(np.arange(n, n + 8))
    eng.compact()
    # pure write throughput
    reps_w = 3 if fast else 6
    t0 = time.perf_counter()
    for _ in range(reps_w):
        write_batch()
    ups_per_s = reps_w * wb / (time.perf_counter() - t0)
    # interleaved 90/10: 9 search batches per write batch
    rounds = 2 if fast else 4
    ts = []
    for _ in range(rounds):
        write_batch()
        for _ in range(9):
            t0 = time.perf_counter()
            out = eng.search(queries, k)
            jax.block_until_ready(out)
            ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    p50 = _pctl(ts, 50)
    _, found = eng.search(queries, k)
    rec = float(recall_at_k(found, truth))
    qps = nq / (p50 * 1e-6)
    # staleness: fresh rows served exactly from the delta, then re-coded
    # through the residual PQ by compaction
    fresh = queries[:128] + 0.001 * rng.randn(128, dim).astype(np.float32)
    fresh_ids = np.arange(next_id, next_id + 128)
    eng.upsert(fresh_ids, fresh)
    _, f1 = eng.search(queries[:128], 1)
    rec_delta = float((np.asarray(f1)[:, 0] == fresh_ids).mean())
    eng.compact()
    _, f2 = eng.search(queries[:128], 1)
    rec_compacted = float((np.asarray(f2)[:, 0] == fresh_ids).mean())
    rows.append(("stream_ivfpq_90_10", p50,
                 f"ups_per_s={ups_per_s:.0f} qps={qps:.0f} "
                 f"recall@10={rec:.4f} fresh_delta={rec_delta:.3f} "
                 f"fresh_compacted={rec_compacted:.3f} "
                 f"grow={eng.grow_count}"))
    if json_doc is not None:
        json_doc["stream"] = [dict(
            scenario="stream_90_10", index="ivfpq", write_batch=wb,
            upserts_per_sec=round(ups_per_s),
            search_p50_us=round(p50, 1), search_qps=round(qps),
            recall_at_10=round(rec, 4),
            fresh_top1_delta=round(rec_delta, 4),
            fresh_top1_compacted=round(rec_compacted, 4))]


def bench_zoo(rows, json_doc=None, fast=False):
    """Reducer & index zoo: recall@10 + QPS per registered reducer x index
    spec on one clustered grid (the ``zoo`` section of BENCH_serve.json).

    Two within-file pairs are regression gates (check_regression.py):
    OPQ's learned rotation must not lose recall vs plain PQ at equal code
    bytes (the OPQ fit's candidate set includes the un-rotated solution,
    so its reconstruction MSE is <= plain PQ by construction), and the
    MPAD reducer must hold recall vs PCA at equal output dim (the paper's
    claim, Fig.1)."""
    from repro.search import build_engine, knn_search, parse_spec
    from repro.search.knn import recall_at_k
    n, dim, nq, k = 8192, 128, 256, 10
    key = jax.random.key(0)

    # reducer grid: cluster structure in the first 96 dims plus 32
    # high-variance nuisance dims that carry no neighbor information —
    # the regime the quantile-preserving projection targets (PCA's
    # top-variance directions are exactly the nuisance dims)
    sig = dim - 32
    centers = jax.random.normal(key, (64, sig)) * 1.5
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 64)
    signal = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, sig))
    red_corpus = jnp.concatenate(
        [signal, 3.0 * jax.random.normal(jax.random.fold_in(key, 4),
                                         (n, 32))], axis=1)
    # code grid: anisotropic (decaying per-dim scales), so PQ's fixed
    # subspace split is variance-imbalanced and the learned rotation has
    # something to rebalance
    kc = jax.random.key(5)
    scales = 1.0 / jnp.sqrt(1.0 + jnp.arange(dim, dtype=jnp.float32))
    ccent = jax.random.normal(kc, (64, dim)) * 1.5
    clab = jax.random.randint(jax.random.fold_in(kc, 1), (n,), 0, 64)
    code_corpus = (ccent[clab] + 0.4 * jax.random.normal(
        jax.random.fold_in(kc, 2), (n, dim))) * scales

    grids = {}
    for gname, corpus, qkey, qscale in (
            ("reducer", red_corpus, jax.random.fold_in(key, 3), 1.0),
            ("code", code_corpus, jax.random.fold_in(kc, 3), scales)):
        queries = corpus[:nq] + 0.05 * jax.random.normal(
            qkey, (nq, dim)) * qscale
        _, truth = knn_search(queries, corpus, k)
        grids[gname] = (corpus, queries, truth)

    # each gate pair runs on one grid, so the within-file compare is
    # apples-to-apples: equal-dim reducers on the exact-scan pipeline,
    # equal-byte codes without a reducer
    specs = [("reducer", "qpad32>flat"), ("reducer", "pca32>flat"),
             ("reducer", "mlp32>flat"),
             ("code", "pq8x256"), ("code", "opq8x256")]
    if not fast:
        specs.append(("reducer", "qpad32>ivf64x8>pq8x256:i8"))
    reps = 5 if fast else 9
    zoo_rows = []
    for gname, spec_s in specs:
        corpus, queries, truth = grids[gname]
        sp = parse_spec(spec_s)
        eng = build_engine(corpus, spec_s, fit_sample=2048, seed=0)
        ts = _timeit_dist(eng.search, queries, k, reps=reps)
        p50 = _pctl(ts, 50)
        _, found = eng.search(queries, k)
        rec = float(recall_at_k(found, truth))
        qps = nq / (p50 * 1e-6)
        rows.append((f"zoo_{spec_s}", p50,
                     f"grid={gname} recall@10={rec:.4f} qps={qps:.0f}"))
        zoo_rows.append(dict(
            spec=spec_s, grid=gname,
            reducer=sp.reduce.kind if sp.reduce is not None else None,
            index=sp.kind,
            dim=sp.reduce.m if sp.reduce is not None else dim,
            code_bytes=(sp.code.subspaces if sp.code is not None else None),
            p50_us=round(p50, 1), qps=round(qps),
            recall_at_10=round(rec, 4)))
    if json_doc is not None:
        json_doc["zoo"] = zoo_rows


def bench_durability(rows, json_doc=None, fast=False):
    """Durability subsystem: what the WAL costs the write path, how fast
    crash recovery replays, and what background compaction buys search
    latency vs the blocking stall."""
    import shutil
    import tempfile

    import threading

    import numpy as np

    from repro.search import (DurabilityConfig, SearchEngine, ServeConfig,
                              StreamConfig, Wal, load_engine)
    from repro.search.durability.wal import RT_UPSERT, encode_upsert
    n, dim = (4096, 128) if fast else (16384, 128)
    wb = 256
    key = jax.random.key(0)
    corpus = jax.random.normal(key, (n, dim), jnp.float32)
    queries = corpus[:64] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (64, dim))
    rng = np.random.RandomState(0)

    def mk(**stream_kw):
        stream_kw.setdefault("delta_capacity", 2048)
        return SearchEngine(corpus, ServeConfig(
            rerank=64, index="ivfpq", nlist=64, nprobe=8,
            pq_subspaces=16, pq_centroids=256,
            stream=StreamConfig(write_bucket=wb, row_capacity=3 * n,
                                cell_slack=256, **stream_kw)))

    reps = 3 if fast else 6
    batches = [rng.randn(wb, dim).astype(np.float32)
               for _ in range(reps + 1)]

    def writer(eng, base_id):
        # per-batch stateful write thunk; the delta (cap 2048) holds every
        # batch, so no compaction inside any timed region
        step = [0]

        def go():
            r = step[0]
            step[0] += 1
            ids = np.arange(base_id + r * wb, base_id + (r + 1) * wb)
            eng.upsert(ids, batches[r % len(batches)])
            return eng.store.delta_count

        return go

    work = tempfile.mkdtemp(prefix="qpad-bench-dur-")
    try:
        # --- WAL overhead on the write path -------------------------------
        # the overhead is a regression gate: WAL-off and WAL-on engines
        # write alternately (interleaved) so the on/off ratio is immune to
        # machine drift between the two measurement windows
        eng_on = mk().durable(os.path.join(work, "wal_on"),
                              DurabilityConfig(fsync="batch"))
        ts_w = _timeit_interleaved(
            {"off": writer(mk(), n), "on": writer(eng_on, n)},
            reps=max(reps, 6))          # 7 batches/engine: under the delta cap
        off = wb / (_pctl(sorted(ts_w["off"]), 50) * 1e-6)
        on = wb / (_pctl(sorted(ts_w["on"]), 50) * 1e-6)
        # throughput-loss fraction from the median per-round off/on time
        # ratio (paired: each round's two writes are temporally adjacent)
        overhead = max(0.0, 1.0 - _pctl(sorted(
            t_off / t_on for t_off, t_on in
            zip(ts_w["off"], ts_w["on"])), 50))
        rows.append(("durability_wal_overhead", 0.0,
                     f"ups_off={off:.0f} ups_on={on:.0f} "
                     f"overhead={overhead:.1%}"))

        # --- crash-recovery replay speed ----------------------------------
        rec_dir = os.path.join(work, "recover")
        eng = mk().durable(rec_dir, DurabilityConfig(fsync="batch"))
        r_rows = 2048 if fast else 16384
        for b in range(r_rows // wb):
            ids = np.arange(2 * n + b * wb, 2 * n + (b + 1) * wb)
            eng.upsert(ids, rng.randn(wb, dim).astype(np.float32))
        jax.block_until_ready(eng.store.delta_count)
        t0 = time.perf_counter()
        rec = load_engine(rec_dir)
        jax.block_until_ready(rec.store.delta_count)
        rec_s = time.perf_counter() - t0
        assert rec._replayed > 0
        rows.append(("durability_recovery", rec_s * 1e6,
                     f"rows={r_rows} seconds={rec_s:.2f} "
                     f"rows_per_s={r_rows / rec_s:.0f}"))

        # --- background vs blocking compaction ----------------------------
        def fill(eng):
            for b in range(5):          # 1280 rows: under the 1536 point
                ids = np.arange(4 * n + b * wb, 4 * n + (b + 1) * wb)
                eng.upsert(ids, batches[b % (reps + 1)])
            jax.block_until_ready(eng.store.delta_count)

        eng = mk()
        eng.search(queries, 10)         # warmup the read program
        fill(eng)
        t0 = time.perf_counter()
        eng.compact()
        stall_ms = (time.perf_counter() - t0) * 1e3
        base_ts = []
        for _ in range(8):
            t0 = time.perf_counter()
            jax.block_until_ready(eng.search(queries, 10))
            base_ts.append((time.perf_counter() - t0) * 1e6)
        base_ts.sort()
        eng = mk(background_compact=True)
        eng.search(queries, 10)
        fill(eng)
        eng.begin_compact()
        bg_ts = []
        while eng._compact_future is not None:
            t0 = time.perf_counter()
            jax.block_until_ready(eng.search(queries, 10))
            bg_ts.append((time.perf_counter() - t0) * 1e6)
        bg_ts.sort()
        p50_bg, p50_base = _pctl(bg_ts, 50), _pctl(base_ts, 50)
        rows.append(("durability_bg_compact_search", p50_bg,
                     f"baseline_p50={p50_base:.0f}us "
                     f"blocking_stall={stall_ms:.0f}ms "
                     f"samples={len(bg_ts)}"))

        # --- group commit: concurrent fsync=always burst ------------------
        # 8 writer threads of durable appends, grouped vs one-fsync-per-
        # record: grouping coalesces the burst into shared commits (the
        # regression gate asks >=2x). WAL-layer only — the fsync is the
        # entire cost, so engine programs would just add noise.
        gc_threads, gc_per = 8, (12 if fast else 24)
        payload = encode_upsert(np.arange(32, dtype=np.int32),
                                rng.randn(32, dim).astype(np.float32))

        def burst(wal):
            def writer():
                for _ in range(gc_per):
                    wal.append(RT_UPSERT, payload)
            ths = [threading.Thread(target=writer)
                   for _ in range(gc_threads)]
            t0 = time.perf_counter()
            for t in ths:
                t.start()
            for t in ths:
                t.join()
            dt = time.perf_counter() - t0
            fsyncs = wal.stats()["fsyncs"]
            wal.close()
            return gc_threads * gc_per / dt, fsyncs

        aps_off, fs_off = burst(Wal(os.path.join(work, "gc_off"),
                                    DurabilityConfig(fsync="always")))
        aps_on, fs_on = burst(Wal(
            os.path.join(work, "gc_on"),
            DurabilityConfig(fsync="always", group_commit_ms=2.0)))
        gc_speedup = aps_on / aps_off
        rows.append(("durability_group_commit", 0.0,
                     f"grouped={aps_on:.0f}aps ungrouped={aps_off:.0f}aps "
                     f"speedup={gc_speedup:.2f}x fsyncs={fs_on}/{fs_off}"))

        # --- incremental vs full snapshot ---------------------------------
        # a small-delta engine (cap 512): the incremental link carries the
        # delta state only, so its bytes must not scale with base rows
        inc_dir = os.path.join(work, "inc")
        eng = mk(delta_capacity=512).durable(
            inc_dir, DurabilityConfig(fsync="batch"))
        t0 = time.perf_counter()
        full_bytes = os.path.getsize(eng.save(inc_dir))
        full_s = time.perf_counter() - t0
        d_rows = 256
        eng.upsert(np.arange(6 * n, 6 * n + d_rows),
                   rng.randn(d_rows, dim).astype(np.float32))
        jax.block_until_ready(eng.store.delta_count)
        t0 = time.perf_counter()
        inc_bytes = os.path.getsize(eng.save(inc_dir, incremental=True))
        inc_s = time.perf_counter() - t0
        inc_frac = inc_bytes / full_bytes
        rows.append(("durability_inc_snapshot", inc_s * 1e6,
                     f"base_rows={n} delta_rows={d_rows} "
                     f"bytes={inc_bytes} full_bytes={full_bytes} "
                     f"frac={inc_frac:.3f} full_s={full_s:.2f}"))
        if json_doc is not None:
            json_doc["durability"] = dict(
                upserts_per_sec_wal_off=round(off),
                upserts_per_sec_wal_on=round(on),
                wal_overhead_frac=round(overhead, 4),
                recovery_rows=r_rows,
                recovery_seconds=round(rec_s, 3),
                recovery_rows_per_sec=round(r_rows / rec_s),
                search_p50_us_during_bg_compact=round(p50_bg, 1),
                search_p50_us_baseline=round(p50_base, 1),
                blocking_compact_stall_ms=round(stall_ms, 1),
                group_commit=dict(
                    appends_per_sec_grouped=round(aps_on),
                    appends_per_sec_ungrouped=round(aps_off),
                    speedup=round(gc_speedup, 2),
                    fsyncs_grouped=fs_on, fsyncs_ungrouped=fs_off,
                    records=gc_threads * gc_per),
                incremental_snapshot=dict(
                    base_rows=n, delta_rows=d_rows,
                    full_bytes=full_bytes, incremental_bytes=inc_bytes,
                    bytes_frac=round(inc_frac, 4),
                    full_seconds=round(full_s, 3),
                    incremental_seconds=round(inc_s, 3)))
    finally:
        shutil.rmtree(work, ignore_errors=True)


def roofline_summary(rows):
    art = "benchmarks/artifacts/dryrun"
    if not os.path.isdir(art):
        rows.append(("roofline", 0.0, "no_dryrun_artifacts_run_dryrun_first"))
        return
    from benchmarks.roofline import load_cells, roofline_row
    cells = [roofline_row(r) for r in load_cells(art)]
    ok = [r for r in cells if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"])
        best = max(ok, key=lambda r: r["roofline_frac"])
        rows.append(("roofline_cells_ok", float(len(ok)),
                     f"of_{len(cells)}"))
        rows.append((f"roofline_worst_{worst['arch']}.{worst['shape']}",
                     0.0, f"frac={worst['roofline_frac']:.3f}"))
        rows.append((f"roofline_best_{best['arch']}.{best['shape']}",
                     0.0, f"frac={best['roofline_frac']:.3f}"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="also write the serving trajectory JSON "
                         "(default path: BENCH_serve.json)")
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: kernels + the fused serving bench only")
    args = ap.parse_args(argv)
    rows = []
    json_doc = {"schema": "qpad.bench_serve.v1",
                "created_unix": round(time.time())} if args.json else None
    benches = ((bench_kernels,) if args.fast
               else (bench_objective_backends, bench_kernels, bench_fit,
                     bench_serving, bench_ivfpq, bench_accuracy,
                     roofline_summary))
    for bench in benches:
        try:
            bench(rows)
        except Exception as e:                       # keep the harness going
            rows.append((bench.__name__, -1.0, f"ERROR:{type(e).__name__}"))
    serve_err = None
    try:
        bench_serve_fused(rows, json_doc=json_doc, fast=args.fast)
    except Exception as e:
        serve_err = e
        rows.append(("bench_serve_fused", -1.0, f"ERROR:{type(e).__name__}"))
    try:
        bench_stream(rows, json_doc=json_doc, fast=args.fast)
    except Exception as e:
        serve_err = serve_err or e
        rows.append(("bench_stream", -1.0, f"ERROR:{type(e).__name__}"))
    try:
        bench_durability(rows, json_doc=json_doc, fast=args.fast)
    except Exception as e:
        serve_err = serve_err or e
        rows.append(("bench_durability", -1.0, f"ERROR:{type(e).__name__}"))
    try:
        bench_zoo(rows, json_doc=json_doc, fast=args.fast)
    except Exception as e:
        serve_err = serve_err or e
        rows.append(("bench_zoo", -1.0, f"ERROR:{type(e).__name__}"))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_doc, f, indent=2)
        print(f"\nwrote {args.json}")
        if serve_err is not None:
            # the serving trajectory is the CI regression gate: a truncated
            # BENCH_serve.json must fail the job, not upload silently
            raise SystemExit(
                f"serving benches failed ({serve_err!r}); "
                f"{args.json} is incomplete")


if __name__ == "__main__":
    main()
