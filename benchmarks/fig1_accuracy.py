"""Paper Fig. 1: average k-NN accuracy A_m(k) across target ratios and
neighborhood sizes, per dataset, MPAD (fixed alpha,b) vs all baselines.

Usage: PYTHONPATH=src python -m benchmarks.fig1_accuracy
           [--datasets fasttext,isolet] [--ratios ...] [--out csv]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.mpad_paper import (FIXED_PARAMS, K_VALUES, TARGET_RATIOS)
from repro.core import MPADConfig, fit_mpad
from repro.core.baselines import BASELINE_FITTERS
from repro.search import amk_accuracy

from .datasets import load

METHODS = ["mpad", "pca", "rp", "mds", "kpca", "isomap", "umap"]


def run(datasets, ratios, ks, iters=48, seed=0, out_dir="benchmarks/artifacts"):
    rows = []
    for ds in datasets:
        xtr, xte = load(ds, seed)
        n_dim = xtr.shape[1]
        alpha, b = FIXED_PARAMS[ds]
        for ratio in ratios:
            m = max(1, int(round(ratio * n_dim)))
            reducers = {}
            t0 = time.time()
            reducers["mpad"] = fit_mpad(
                xtr, MPADConfig(m=m, alpha=alpha, b=b, iters=iters))
            fit_t = {"mpad": time.time() - t0}
            for name, fit in BASELINE_FITTERS.items():
                t0 = time.time()
                reducers[name] = fit(xtr, m, jax.random.key(seed + 7))
                fit_t[name] = time.time() - t0
            for k in ks:
                for name, red in reducers.items():
                    acc = float(amk_accuracy(red, xtr, xte, k))
                    rows.append(dict(dataset=ds, ratio=ratio, m=m, k=k,
                                     method=name, acc=acc,
                                     fit_s=round(fit_t[name], 2)))
                    print(f"{ds:9s} ratio={ratio:4.2f} k={k:2d} "
                          f"{name:7s} A_m(k)={acc:.4f}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig1_accuracy.json"), "w") as f:
        json.dump(rows, f, indent=1)
    # Fig.1 aggregate: mean over (ratio, k) per method per dataset
    print("\n=== Fig.1: average A_m(k) per dataset ===")
    summary = {}
    for ds in datasets:
        print(f"\n{ds}:")
        for name in METHODS:
            accs = [r["acc"] for r in rows
                    if r["dataset"] == ds and r["method"] == name]
            if accs:
                summary[(ds, name)] = sum(accs) / len(accs)
                print(f"  {name:7s} {summary[(ds, name)]:.4f}")
    return rows, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="fasttext,isolet,arcene,pbmc3k")
    ap.add_argument("--ratios", default=",".join(map(str, TARGET_RATIOS)))
    ap.add_argument("--ks", default=",".join(map(str, K_VALUES)))
    ap.add_argument("--iters", type=int, default=48)
    args = ap.parse_args()
    run(args.datasets.split(","),
        [float(r) for r in args.ratios.split(",")],
        [int(k) for k in args.ks.split(",")], iters=args.iters)


if __name__ == "__main__":
    main()
