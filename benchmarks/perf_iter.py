"""Perf-iteration probe: re-lower one (arch x shape) cell with the CURRENT
code and print the roofline terms + byte/collective breakdowns. This is the
measure step of the hypothesis -> change -> measure -> validate loop
(EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m benchmarks.perf_iter --arch olmoe-1b-7b \
      --shape train_4k [--tag baseline]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
import argparse
import json
import time

import jax

from repro.configs import get_arch
from repro.launch.hlo_analysis import analyze_hlo, bytes_breakdown
from repro.launch.mesh import make_production_mesh
from repro.parallel.context import mesh_context
from repro.parallel.sharding import tree_named

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def _arch_variant(arch_name, variant):
    """Build an ArchSpec with a config override (perf-iteration variants)."""
    if not variant:
        return get_arch(arch_name)
    import dataclasses
    import importlib
    from repro.configs.lm_family import make_lm_arch
    from repro.configs.registry import ARCH_MODULES
    mod = importlib.import_module(ARCH_MODULES[arch_name])
    cfg = mod.CONFIG
    if variant == "moe_ep":
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl="ep"))
    elif variant in ("tt_full", "tt_mpad", "tt_int8"):
        from repro.configs.recsys_family import make_twotower_arch
        from repro.configs.two_tower_retrieval import MPAD_DIM, RERANK
        return make_twotower_arch(cfg, mpad_dim=MPAD_DIM, rerank=RERANK,
                                  mode=variant.split("_")[1])
    else:
        raise ValueError(variant)
    return make_lm_arch(arch_name, cfg, mod.SMOKE, long_ok=False)


def probe(arch_name, shape, multi_pod=False, tag="probe", breakdown=True,
          variant=None):
    arch = _arch_variant(arch_name, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh_context(mesh):
        args = arch.abstract_args(shape)
        jitted = jax.jit(
            arch.step_fn(shape),
            in_shardings=tree_named(mesh, arch.arg_specs(shape, mesh)),
            out_shardings=tree_named(mesh, arch.out_specs(shape, mesh)))
        compiled = jitted.lower(*args).compile()
        hlo = compiled.as_text()
        tca = analyze_hlo(hlo)
        mem = compiled.memory_analysis()
    terms = {"compute_s": tca["dot_flops"] / PEAK_FLOPS,
             "memory_s": tca["bytes"] / HBM_BW,
             "collective_s": tca["coll_total"] / LINK_BW}
    dom = max(terms, key=terms.get)
    print(f"\n=== {tag}: {arch_name}.{shape} (compile {time.time()-t0:.0f}s) ===")
    print(f"dot_flops/dev {tca['dot_flops']:.3e}  bytes/dev {tca['bytes']:.3e}"
          f"  coll/dev {tca['coll_total']:.3e}")
    print(f"terms: compute {terms['compute_s']:.3e}s | memory "
          f"{terms['memory_s']:.3e}s | collective {terms['collective_s']:.3e}s"
          f"  -> dominant: {dom}")
    print(f"peak mem/dev: {mem.peak_memory_in_bytes/1e9:.2f} GB")
    print("collectives:", {k: f"{v:.2e}" for k, v in tca.items()
                           if k.startswith("coll_") and isinstance(v, float)
                           and v > 0})
    print("coll counts:", tca["coll_counts"])
    if breakdown:
        print("top byte movers (op:jax_op_name, trip-weighted):")
        for k, v in bytes_breakdown(hlo, top=12):
            print(f"  {v:12.3e}  {k}")
    return {"tag": tag, "arch": arch_name, "shape": shape, **tca,
            **terms, "dominant": dom,
            "peak_mem": mem.peak_memory_in_bytes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="probe")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()
    rec = probe(args.arch, args.shape, args.multi_pod, args.tag,
                variant=args.variant)
    if args.save:
        os.makedirs(os.path.dirname(args.save), exist_ok=True)
        with open(args.save, "w") as f:
            json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
