from .adamw import AdamWConfig, init_opt_state, adamw_update, make_train_step
from .compression import (compress_int8, decompress_int8,
                          ef_compress_update, CompressionState,
                          init_compression_state)

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "make_train_step",
           "compress_int8", "decompress_int8", "ef_compress_update",
           "CompressionState", "init_compression_state"]
