"""AdamW with warmup+cosine schedule and global-norm clipping (pure-JAX
pytrees; no optax offline). Optimizer moments are f32 regardless of param
dtype (mixed-precision training keeps a bf16 param copy + f32 moments)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(
        step < cfg.warmup_steps, 1.0, cos)


def init_opt_state(params):
    f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(f32zeros, params),
            "v": jax.tree.map(f32zeros, params)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step)
    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}


def make_train_step(loss_fn: Callable, cfg: AdamWConfig):
    """loss_fn(params, batch) -> scalar. Returns step(params, opt, batch)."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adamw_update(grads, opt_state, params, cfg)
        return loss, params, opt_state

    return step
