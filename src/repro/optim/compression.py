"""Int8 gradient compression with error feedback (1-bit-Adam lineage).

For the cross-pod data-parallel all-reduce: gradients are quantized to int8
with a per-tensor scale before the collective and the quantization residual
is fed back into the next step — unbiased in the long run, 4x fewer bytes on
the slowest (inter-pod) links. Used by the train driver when
``grad_compression=True``; correctness (convergence parity) covered in
tests/test_optim.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "CompressionState",
           "init_compression_state", "ef_compress_update"]


class CompressionState(NamedTuple):
    error: dict          # pytree of f32 residuals, same structure as grads


def init_compression_state(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_update(grads, state: CompressionState):
    """Returns (compressed-then-decompressed grads, new state).

    The returned grads are what the collective transports (int8 payload);
    the residual g - dec(q) is carried to the next step (error feedback).
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        dec = decompress_int8(q, s)
        return dec, corrected - dec

    out = jax.tree.map(one, grads, state.error)
    dec = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return dec, CompressionState(error=err)
