"""Pure-jnp oracle for the fused PQ ADC scan: full distance-table lookups
plus ``lax.top_k``. Used for kernel parity tests and as the semantic spec.

Two variants mirror the two kernel entry points:

* shared codes — one (N, M) code matrix scanned by every query (plain PQ);
* gathered codes — per-query (C, M) candidate codes plus a per-candidate
  additive ``base`` term (the IVF-PQ residual decomposition: coarse distance
  + centroid/codeword cross term; see ``repro.search.ivfpq``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["pq_adc_scores_ref", "pq_adc_topk_ref",
           "pq_adc_gather_scores_ref", "pq_adc_gather_topk_ref"]


def pq_adc_scores_ref(tables: jax.Array, codes: jax.Array) -> jax.Array:
    """ADC distances, shared codes: out[q, n] = sum_m tables[q, m, codes[n, m]].

    tables (Q, M, K) f32; codes (N, M) int. Returns (Q, N) f32.
    """
    m = tables.shape[1]
    d2 = jnp.zeros((tables.shape[0], codes.shape[0]), jnp.float32)
    for j in range(m):                       # M small (4-16): unrolled
        d2 = d2 + tables[:, j, :][:, codes[:, j]]
    return d2


@functools.partial(jax.jit, static_argnames=("k",))
def pq_adc_topk_ref(tables: jax.Array, codes: jax.Array, k: int):
    """Returns (d2 (Q, k) ascending, idx (Q, k)) over the shared code matrix."""
    d2 = pq_adc_scores_ref(tables, codes)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def pq_adc_gather_scores_ref(tables: jax.Array, codes: jax.Array,
                             base: jax.Array) -> jax.Array:
    """ADC distances, per-query candidate codes:

    out[q, c] = base[q, c] + sum_m tables[q, m, codes[q, c, m]].

    tables (Q, M, K) f32; codes (Q, C, M) int; base (Q, C) f32 (use +inf to
    mask padded candidates). Returns (Q, C) f32.
    """
    m = tables.shape[1]
    d2 = base.astype(jnp.float32)
    for j in range(m):
        d2 = d2 + jnp.take_along_axis(tables[:, j, :], codes[:, :, j], axis=1)
    return d2


@functools.partial(jax.jit, static_argnames=("k",))
def pq_adc_gather_topk_ref(tables: jax.Array, codes: jax.Array,
                           base: jax.Array, k: int):
    """Returns (d2 (Q, k) ascending, idx (Q, k)); idx is the candidate slot."""
    d2 = pq_adc_gather_scores_ref(tables, codes, base)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx
