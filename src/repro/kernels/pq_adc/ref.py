"""Pure-jnp oracle for the fused PQ ADC scan: full distance-table lookups
plus ``lax.top_k``. Used for kernel parity tests and as the semantic spec.

Two variants mirror the two kernel entry points:

* shared codes — one (N, M) code matrix scanned by every query (plain PQ);
* gathered codes — per-query (C, M) candidate codes plus a per-candidate
  additive ``base`` term (the IVF-PQ residual decomposition: coarse distance
  + centroid/codeword cross term; see ``repro.search.ivfpq``).

Every entry takes ``lut_dtype`` (see ``lut.py``): the oracle quantizes the
f32 tables exactly as the kernels do, then scores with the **dequantized**
f32 tables — so ref and kernel agree up to f32 summation order, and the
quantization error itself is part of the spec (bounded by
``lut_error_bound``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .lut import dequantize_lut, quantize_lut

__all__ = ["pq_adc_scores_ref", "pq_adc_topk_ref",
           "pq_adc_gather_scores_ref", "pq_adc_gather_topk_ref"]


def _lut_tables(tables: jax.Array, lut_dtype: str) -> jax.Array:
    if lut_dtype == "f32":
        return jnp.asarray(tables, jnp.float32)
    return dequantize_lut(*quantize_lut(tables, lut_dtype))


def pq_adc_scores_ref(tables: jax.Array, codes: jax.Array,
                      lut_dtype: str = "f32") -> jax.Array:
    """ADC distances, shared codes: out[q, n] = sum_m tables[q, m, codes[n, m]].

    tables (Q, M, K) f32; codes (N, M) int. Returns (Q, N) f32.
    """
    tables = _lut_tables(tables, lut_dtype)
    m = tables.shape[1]
    d2 = jnp.zeros((tables.shape[0], codes.shape[0]), jnp.float32)
    for j in range(m):                       # M small (4-16): unrolled
        d2 = d2 + tables[:, j, :][:, codes[:, j]]
    return d2


@functools.partial(jax.jit, static_argnames=("k", "lut_dtype"))
def pq_adc_topk_ref(tables: jax.Array, codes: jax.Array, k: int,
                    lut_dtype: str = "f32"):
    """Returns (d2 (Q, k) ascending, idx (Q, k)) over the shared code matrix."""
    d2 = pq_adc_scores_ref(tables, codes, lut_dtype)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def pq_adc_gather_scores_ref(tables: jax.Array, codes: jax.Array,
                             base: jax.Array,
                             lut_dtype: str = "f32") -> jax.Array:
    """ADC distances, per-query candidate codes:

    out[q, c] = base[q, c] + sum_m tables[q, m, codes[q, c, m]].

    tables (Q, M, K) f32; codes (Q, C, M) int; base (Q, C) f32 (use +inf to
    mask padded candidates; ``base`` is never quantized). Returns (Q, C) f32.

    The M per-subspace lookups are fused into ONE flattened gather over the
    (Q, M*K) tables (flat index ``m*K + code``) — identical semantics to the
    per-subspace loop, ~1.2x faster on CPU as the scoring backend.
    """
    tables = _lut_tables(tables, lut_dtype)
    nq, m, kc = tables.shape
    c = codes.shape[1]
    flat_idx = (codes + jnp.arange(m) * kc).reshape(nq, c * m)
    lut = jnp.take_along_axis(tables.reshape(nq, m * kc), flat_idx, axis=1)
    return base.astype(jnp.float32) + lut.reshape(nq, c, m).sum(-1)


@functools.partial(jax.jit, static_argnames=("k", "lut_dtype"))
def pq_adc_gather_topk_ref(tables: jax.Array, codes: jax.Array,
                           base: jax.Array, k: int, lut_dtype: str = "f32"):
    """Returns (d2 (Q, k) ascending, idx (Q, k)); idx is the candidate slot."""
    d2 = pq_adc_gather_scores_ref(tables, codes, base, lut_dtype)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx
