"""Pure-jnp oracle for the fused PQ ADC scan: full distance-table lookups
plus ``lax.top_k``. Used for kernel parity tests and as the semantic spec.

Two variants mirror the two kernel entry points:

* shared codes — one (N, M) code matrix scanned by every query (plain PQ);
* gathered codes — per-query (C, M) candidate codes plus a per-candidate
  additive ``base`` term (the IVF-PQ residual decomposition: coarse distance
  + centroid/codeword cross term; see ``repro.search.ivfpq``).

Every entry takes ``lut_dtype`` (see ``lut.py``): the oracle snaps the f32
tables onto exactly the kernel's bf16 / int8 grid but keeps the snapped
values in f32, so the scoring gather always runs the fast f32 path — on
CPU XLA a narrow-dtype gather is 2-3x SLOWER than the same gather in f32.
The snapped values are the narrow pipeline's values exactly: bf16 entries
are the bf16 roundings widened to f32, int8 entries are the integer codes
as f32 — per-candidate sums of <= M such integers are exact in f32, so
summing and applying the per-query ``scale`` once matches the kernel's
int32-accumulate path bit for bit. The quantization error itself is part
of the spec (bounded by ``lut_error_bound``).

The snap is wrapped in ``_pin`` (a ``lax.cond`` whose predicate is a
runtime value): without it XLA pulls the table-sized elementwise chain
INTO the kLoop fusion around the candidate gather and recomputes it per
*gathered* element — candidates outnumber table entries ~16x at serving
shapes, turning a ~0.2ms table pass into a ~2ms one. A conditional is a
separate XLA computation, so its result is materialized once
(``lax.optimization_barrier`` does NOT survive to the CPU fusion pass).

``scale`` (optional, int8 only) overrides the per-query quantization scale
with a caller-certified bound — it must be the same array the paired
kernel call gets, or the two backends land on different grids.

``center`` (optional, (Q, M) f32) subtracts a per-(query, subspace)
constant from the tables BEFORE the snap — the analytic row-mean centering
the IVF-PQ int8 scans use to halve the dynamic range the grid must cover.
The returned score then omits ``sum_m center[q, m]``; the caller adds it
back after top-k (a per-query constant never changes the ranking).

Codes may be uint8 (the stored width for K <= 256) or any int dtype; the
gather index is built at the narrowest width that spans ``M * K``, and the
gathers promise in-bounds indices (codes are < K by construction), which
drops take_along_axis's per-element wrap/oob-select chains.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .lut import _int8_scale, snap_values

__all__ = ["pq_adc_scores_ref", "pq_adc_topk_ref",
           "pq_adc_gather_scores_ref", "pq_adc_gather_topk_ref"]


def _resolve_scale(tables, lut_dtype, scale, center):
    """Per-query int8 scale: caller-certified, or max|t - center| / 127."""
    if lut_dtype != "int8":
        return None
    if scale is not None:
        return jnp.asarray(scale, jnp.float32)
    ct = tables if center is None else tables - center[:, :, None]
    return _int8_scale(ct, None)


def _snap_tables(tables, lut_dtype, scale, center):
    """Center + grid-snap the (Q, M, K) tables, materialized (see module
    docs). The cond predicate is true for any finite table — i.e. for any
    finite query; a non-finite query takes the identity branch and scores
    with unsnapped tables, which is as meaningless as its input."""
    if lut_dtype == "f32":
        return tables

    def snap(tb):
        tc = tb if center is None else tb - center[:, :, None]
        return snap_values(tc, lut_dtype,
                           None if scale is None else scale[:, None, None])

    return jax.lax.cond(jnp.isfinite(tables[0, 0, 0]), snap,
                        lambda tb: tb, tables)


def pq_adc_scores_ref(tables: jax.Array, codes: jax.Array,
                      lut_dtype: str = "f32", scale=None,
                      center=None) -> jax.Array:
    """ADC distances, shared codes: out[q, n] = sum_m tables[q, m, codes[n, m]].

    tables (Q, M, K) f32; codes (N, M) uint8/int. Returns (Q, N) f32
    (minus ``sum_m center`` when ``center`` is given — see module docs).
    """
    tables = jnp.asarray(tables, jnp.float32)
    nq, m, _ = tables.shape
    scale = _resolve_scale(tables, lut_dtype, scale, center)
    ft = _snap_tables(tables, lut_dtype, scale, center)
    n = codes.shape[0]
    d2 = jnp.zeros((nq, n), jnp.float32)
    for j in range(m):                       # M small (4-16): unrolled
        d2 = d2 + jnp.take(ft[:, j, :], codes[:, j], axis=1, mode="clip")
    if lut_dtype == "int8":
        d2 = d2 * scale[:, None]             # exact integer sums, one rescale
    return d2


@functools.partial(jax.jit, static_argnames=("k", "lut_dtype"))
def pq_adc_topk_ref(tables: jax.Array, codes: jax.Array, k: int,
                    lut_dtype: str = "f32", scale=None, center=None):
    """Returns (d2 (Q, k) ascending, idx (Q, k)) over the shared code matrix."""
    d2 = pq_adc_scores_ref(tables, codes, lut_dtype, scale, center)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def pq_adc_gather_scores_ref(tables: jax.Array, codes: jax.Array,
                             base: jax.Array, lut_dtype: str = "f32",
                             scale=None, center=None) -> jax.Array:
    """ADC distances, per-query candidate codes:

    out[q, c] = base[q, c] + sum_m tables[q, m, codes[q, c, m]].

    tables (Q, M, K) f32; codes (Q, C, M) uint8/int; base (Q, C) f32 (use
    +inf to mask padded candidates; ``base`` is never quantized). Returns
    (Q, C) f32 (minus ``sum_m center`` when ``center`` is given).

    The M per-subspace lookups are fused into ONE flattened gather over the
    (Q, M*K) grid-snapped f32 tables (flat index ``m*K + code``, int16 when
    the table fits) — identical semantics to the per-subspace loop, at the
    f32 gather speed regardless of ``lut_dtype``.
    """
    tables = jnp.asarray(tables, jnp.float32)
    nq, m, kc = tables.shape
    scale = _resolve_scale(tables, lut_dtype, scale, center)
    ft = _snap_tables(tables, lut_dtype, scale, center)
    c = codes.shape[1]
    idt = jnp.int16 if m * kc < 2 ** 15 else jnp.int32
    flat_idx = (codes.astype(idt)
                + jnp.arange(m, dtype=idt) * kc).reshape(nq, c * m)
    lut = jnp.take_along_axis(ft.reshape(nq, m * kc), flat_idx, axis=1,
                              mode="promise_in_bounds").reshape(nq, c, m)
    d2 = lut.sum(-1)
    if lut_dtype == "int8":
        d2 = d2 * scale[:, None]             # exact integer sums, one rescale
    return base.astype(jnp.float32) + d2


@functools.partial(jax.jit, static_argnames=("k", "lut_dtype"))
def pq_adc_gather_topk_ref(tables: jax.Array, codes: jax.Array,
                           base: jax.Array, k: int, lut_dtype: str = "f32",
                           scale=None, center=None):
    """Returns (d2 (Q, k) ascending, idx (Q, k)); idx is the candidate slot."""
    d2 = pq_adc_gather_scores_ref(tables, codes, base, lut_dtype, scale,
                                  center)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx
