"""Jit'd public wrappers for the fused ADC-scan Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import pq_adc_gather_topk_pallas, pq_adc_topk_pallas


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "lut_dtype"))
def pq_adc_topk(tables: jax.Array, codes: jax.Array, k: int, *,
                block_q: int = 128, block_n: int = 512,
                interpret: bool = True, lut_dtype: str = "f32"):
    """Top-k ADC over shared codes: (dists (Q,k), idx (Q,k)), sqrt'd."""
    d2, idx = pq_adc_topk_pallas(tables, codes, k, block_q=block_q,
                                 block_n=block_n, interpret=interpret,
                                 lut_dtype=lut_dtype)
    return jnp.sqrt(jnp.maximum(d2, 0.0)), idx


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "lut_dtype"))
def pq_adc_gather_topk(tables: jax.Array, codes: jax.Array, base: jax.Array,
                       k: int, *, block_q: int = 8, block_n: int = 256,
                       interpret: bool = True, lut_dtype: str = "f32"):
    """Top-k ADC over per-query candidates: (dists (Q,k), slot idx (Q,k))."""
    d2, idx = pq_adc_gather_topk_pallas(tables, codes, base, k,
                                        block_q=block_q, block_n=block_n,
                                        interpret=interpret,
                                        lut_dtype=lut_dtype)
    return jnp.sqrt(jnp.maximum(d2, 0.0)), idx
