"""Jit'd public wrappers for the fused ADC-scan Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import pq_adc_gather_topk_pallas, pq_adc_topk_pallas


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "lut_dtype"))
def pq_adc_topk(tables: jax.Array, codes: jax.Array, k: int, *,
                block_q: int = 128, block_n: int = 512,
                interpret: bool = True, lut_dtype: str = "f32"):
    """Top-k ADC over shared codes: (dists (Q,k), idx (Q,k)), sqrt'd."""
    with jax.named_scope("pq_adc.topk"):
        d2, idx = pq_adc_topk_pallas(tables, codes, k, block_q=block_q,
                                     block_n=block_n, interpret=interpret,
                                     lut_dtype=lut_dtype)
        return jnp.sqrt(jnp.maximum(d2, 0.0)), idx


@functools.partial(jax.jit, static_argnames=("k", "slack", "block_q",
                                             "block_n", "interpret",
                                             "lut_dtype"))
def pq_adc_topk_global(tables: jax.Array, codes: jax.Array, k: int, *,
                       row_offset: jax.Array, n_valid: jax.Array,
                       slack: int = 0, block_q: int = 128,
                       block_n: int = 512, interpret: bool = True,
                       lut_dtype: str = "f32"):
    """Shard-local fused ADC scan returning GLOBAL row ids (sharded serving).

    Runs the shared-codes kernel over one shard's (n_loc, M) row block and
    maps the local hits to global ids via ``row_offset`` (this shard's
    first global row). The kernel cannot see the shard-pad validity mask,
    so the scan over-fetches ``k + slack`` rows (``slack`` >= the possible
    pad-row count, i.e. shards - 1), drops hits with global id >=
    ``n_valid`` post-hoc, and re-top-ks to k — pad rows can then never
    displace a real candidate. Returns (d2 (Q, k), global ids (Q, k)) with
    (+inf, -1) on unfilled slots; d2 is NOT sqrt'd (merge key only).
    """
    n_loc = codes.shape[0]
    kk = min(k + slack, n_loc)
    with jax.named_scope("pq_adc.topk_global"):
        d2, idx = pq_adc_topk_pallas(tables, codes, kk, block_q=block_q,
                                     block_n=block_n, interpret=interpret,
                                     lut_dtype=lut_dtype)
        gid = row_offset + idx
        bad = (idx < 0) | (gid >= n_valid)
        # (+inf, -1) pad convention + masked re-top-k mirror
        # repro.search.knn.masked_topk (importing it here would cycle
        # kernels -> search -> kernels); keep the two in step
        d2 = jnp.where(bad, jnp.inf, d2)
        gid = jnp.where(bad, -1, gid)
        if kk > k:
            neg, sel = jax.lax.top_k(-d2, k)
            d2 = -neg
            gid = jnp.take_along_axis(gid, sel, axis=1)
        elif kk < k:
            d2 = jnp.pad(d2, ((0, 0), (0, k - kk)),
                         constant_values=jnp.inf)
            gid = jnp.pad(gid, ((0, 0), (0, k - kk)), constant_values=-1)
        return d2, gid


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "lut_dtype"))
def pq_adc_gather_topk(tables: jax.Array, codes: jax.Array, base: jax.Array,
                       k: int, *, block_q: int = 8, block_n: int = 256,
                       interpret: bool = True, lut_dtype: str = "f32"):
    """Top-k ADC over per-query candidates: (dists (Q,k), slot idx (Q,k))."""
    with jax.named_scope("pq_adc.gather_topk"):
        d2, idx = pq_adc_gather_topk_pallas(tables, codes, base, k,
                                            block_q=block_q,
                                            block_n=block_n,
                                            interpret=interpret,
                                            lut_dtype=lut_dtype)
        return jnp.sqrt(jnp.maximum(d2, 0.0)), idx
