"""Quantized ADC lookup tables: f32 -> bf16 / int8 per-query tables.

The per-query distance tables (Q, M, K) are the only f32 state the fused
ADC kernels keep resident in VMEM, so narrowing them cuts the kernel's
working set 2x (bf16) or 4x (int8) and moves the one-hot contraction onto
the low-precision MXU paths (bf16 x bf16 -> f32, int8 x int8 -> int32).

int8 uses **per-query symmetric** quantization: one scale per query over
its whole (M, K) table, ``scale = max|t| / 127`` by default, so the
integer partial sums accumulate exactly in int32 and a single f32 multiply
at the end restores the distance unit. Callers may instead pass their own
per-query ``scale`` (any certified upper bound on ``max|t| / 127`` keeps
the grid clip-free) — the IVF-PQ scans derive one analytically from the
codebook geometry so quantization costs no table-wide max reduction
(``repro.search.ivfpq.ivfpq_lut_stats``). The absolute error per table
entry is at most ``scale / 2``, hence at most ``M * scale / 2`` per ADC
distance — the bound asserted by the error tests in
``tests/test_pq_adc.py``.

bf16 needs no scale (it is a rounding of the same dynamic range); the
returned scale is 1 so both quantized formats share one calling convention.

``snap_lut`` is the grid-snap twin of ``quantize_lut`` for backends where
the narrow dtype only pays (jnp gathers on CPU): it rounds onto exactly
the same bf16 / int8 grid but returns the values in f32 — int8 entries as
exact small integers — so the scoring gather stays on the fast f32 path
while every produced value (and hence every downstream sum) is
bit-identical to the narrow-dtype pipeline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["LUT_DTYPES", "center_lut", "quantize_lut", "dequantize_lut",
           "snap_lut", "snap_values", "lut_error_bound"]

LUT_DTYPES = ("f32", "bf16", "int8")


def center_lut(tables: jax.Array):
    """Split tables into a zero-mean part plus a per-query constant.

    Returns (tables - rowmean, const (Q,)) with ``const = sum_m rowmean`` —
    the ADC sum of the centered tables plus ``const`` equals the original
    sum exactly, but per-query ranking ignores ``const``, so quantizing only
    the centered part roughly halves the dynamic range the int8/bf16 grid
    has to cover. Callers keep ``const`` in f32 and add it after top-k.
    """
    rowmean = jnp.mean(tables, axis=-1)                   # (Q, M)
    return tables - rowmean[..., None], jnp.sum(rowmean, axis=-1)

_JNP_DTYPE = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}


def _int8_scale(tables: jax.Array, scale):
    """Resolve the per-query int8 scale: caller-provided or max|t|/127."""
    if scale is not None:
        return jnp.asarray(scale, jnp.float32)
    amax = jnp.max(jnp.abs(tables), axis=(1, 2))          # (Q,)
    # floor well above the subnormal range: XLA flushes denormals to zero,
    # and a zero scale would NaN the dequantized 0/0 tables
    return jnp.maximum(amax, 1e-12) / 127.0


def quantize_lut(tables: jax.Array, lut_dtype: str, scale=None):
    """(Q, M, K) f32 tables -> (qtables, scale (Q,) f32).

    ``qtables`` dtype follows ``lut_dtype``; ``scale`` is all-ones except
    for int8 (per-query symmetric scale, strictly positive — defaults to
    ``max|t| / 127``, or the caller's certified bound when given).
    """
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(
            f"unknown lut_dtype {lut_dtype!r}; expected one of {LUT_DTYPES}")
    tables = jnp.asarray(tables, jnp.float32)
    ones = jnp.ones(tables.shape[:1], jnp.float32)
    if lut_dtype == "f32":
        return tables, ones
    if lut_dtype == "bf16":
        return tables.astype(jnp.bfloat16), ones
    s = _int8_scale(tables, scale)
    q = jnp.round(tables / s[:, None, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), s


def snap_values(x: jax.Array, lut_dtype: str, scale=None) -> jax.Array:
    """Elementwise grid snap of f32 table values (any shape).

    The snap commutes with gathers — ``snap(gather(t)) == gather(snap(t))``
    — so scoring paths that gather in f32 (2-3x faster than a narrow-dtype
    gather on CPU XLA) apply this to the *gathered* values instead, where
    it fuses into the already-memory-bound subspace-sum pass:

    * bf16: each value becomes its bf16 rounding widened back to f32 — the
      very values the narrow pipeline gathers and widens per tile;
    * int8: each value becomes the clipped integer code as an f32
      (|v| <= 127; ``scale`` is REQUIRED and must broadcast against ``x``).
      Sums of up to ``M`` such values stay exact in f32 (integers up to
      ``127 * M`` are far below 2^24), so summing and then applying
      ``scale`` once reproduces the int32-accumulate path bit for bit.

    f32 passes through untouched.
    """
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(
            f"unknown lut_dtype {lut_dtype!r}; expected one of {LUT_DTYPES}")
    if lut_dtype == "f32":
        return x
    if lut_dtype == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.clip(jnp.round(x / scale), -127.0, 127.0)


def snap_lut(tables: jax.Array, lut_dtype: str, scale=None):
    """Round whole tables onto the ``lut_dtype`` grid but keep them f32.

    Same (Q, M, K) -> (ftables, scale (Q,) f32) convention as
    ``quantize_lut`` and the exact same grid (same rounding expression,
    same ``scale`` resolution) — only the storage dtype differs (see
    ``snap_values`` for the value semantics).
    """
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(
            f"unknown lut_dtype {lut_dtype!r}; expected one of {LUT_DTYPES}")
    tables = jnp.asarray(tables, jnp.float32)
    ones = jnp.ones(tables.shape[:1], jnp.float32)
    if lut_dtype in ("f32", "bf16"):
        return snap_values(tables, lut_dtype), ones
    s = _int8_scale(tables, scale)
    return snap_values(tables, lut_dtype, s[:, None, None]), s


def dequantize_lut(qtables: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_lut`` up to rounding: (Q, M, K) f32."""
    return qtables.astype(jnp.float32) * scale[:, None, None]


def lut_error_bound(tables: jax.Array, lut_dtype: str, scale=None) -> jax.Array:
    """Per-query upper bound on |quantized ADC score - f32 ADC score|.

    int8: M * scale / 2 per summed table entry (pass the same ``scale`` the
    scan quantized with, else the default ``max|t| / 127`` is assumed).
    bf16: relative rounding of each entry (2^-8) summed over M. f32: zero.
    """
    tables = jnp.asarray(tables, jnp.float32)
    m = tables.shape[1]
    if lut_dtype == "f32":
        return jnp.zeros(tables.shape[:1], jnp.float32)
    if lut_dtype == "bf16":
        amax = jnp.max(jnp.abs(tables), axis=(1, 2))
        return m * amax * 2.0 ** -8
    return m * _int8_scale(tables, scale) / 2.0
