"""Quantized ADC lookup tables: f32 -> bf16 / int8 per-query tables.

The per-query distance tables (Q, M, K) are the only f32 state the fused
ADC kernels keep resident in VMEM, so narrowing them cuts the kernel's
working set 2x (bf16) or 4x (int8) and moves the one-hot contraction onto
the low-precision MXU paths (bf16 x bf16 -> f32, int8 x int8 -> int32).

int8 uses **per-query symmetric** quantization: one scale per query over
its whole (M, K) table, ``scale = max|t| / 127``, so the integer partial
sums accumulate exactly in int32 and a single f32 multiply at the end
restores the distance unit. The absolute error per table entry is at most
``scale / 2``, hence at most ``M * scale / 2`` per ADC distance — the bound
asserted by the error tests in ``tests/test_pq_adc.py``.

bf16 needs no scale (it is a rounding of the same dynamic range); the
returned scale is 1 so both quantized formats share one calling convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["LUT_DTYPES", "center_lut", "quantize_lut", "dequantize_lut",
           "lut_error_bound"]

LUT_DTYPES = ("f32", "bf16", "int8")


def center_lut(tables: jax.Array):
    """Split tables into a zero-mean part plus a per-query constant.

    Returns (tables - rowmean, const (Q,)) with ``const = sum_m rowmean`` —
    the ADC sum of the centered tables plus ``const`` equals the original
    sum exactly, but per-query ranking ignores ``const``, so quantizing only
    the centered part roughly halves the dynamic range the int8/bf16 grid
    has to cover. Callers keep ``const`` in f32 and add it after top-k.
    """
    rowmean = jnp.mean(tables, axis=-1)                   # (Q, M)
    return tables - rowmean[..., None], jnp.sum(rowmean, axis=-1)

_JNP_DTYPE = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}


def quantize_lut(tables: jax.Array, lut_dtype: str):
    """(Q, M, K) f32 tables -> (qtables, scale (Q,) f32).

    ``qtables`` dtype follows ``lut_dtype``; ``scale`` is all-ones except
    for int8 (per-query symmetric scale, strictly positive).
    """
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(
            f"unknown lut_dtype {lut_dtype!r}; expected one of {LUT_DTYPES}")
    tables = jnp.asarray(tables, jnp.float32)
    ones = jnp.ones(tables.shape[:1], jnp.float32)
    if lut_dtype == "f32":
        return tables, ones
    if lut_dtype == "bf16":
        return tables.astype(jnp.bfloat16), ones
    amax = jnp.max(jnp.abs(tables), axis=(1, 2))          # (Q,)
    # floor well above the subnormal range: XLA flushes denormals to zero,
    # and a zero scale would NaN the dequantized 0/0 tables
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.round(tables / scale[:, None, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_lut(qtables: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_lut`` up to rounding: (Q, M, K) f32."""
    return qtables.astype(jnp.float32) * scale[:, None, None]


def lut_error_bound(tables: jax.Array, lut_dtype: str) -> jax.Array:
    """Per-query upper bound on |quantized ADC score - f32 ADC score|.

    int8: M * scale / 2 per summed table entry. bf16: relative rounding of
    each entry (2^-8) summed over M. f32: zero.
    """
    tables = jnp.asarray(tables, jnp.float32)
    m = tables.shape[1]
    amax = jnp.max(jnp.abs(tables), axis=(1, 2))
    if lut_dtype == "f32":
        return jnp.zeros_like(amax)
    if lut_dtype == "bf16":
        return m * amax * 2.0 ** -8
    return m * (jnp.maximum(amax, 1e-12) / 127.0) / 2.0
