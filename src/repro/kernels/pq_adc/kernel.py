"""Pallas TPU kernel: tiled fused PQ ADC scan + running top-k.

The serving hot loop of a PQ / IVF-PQ index: per-query distance tables
T (Q x M x K) against the corpus code matrix C (N x M),

  d2[q, n] = sum_m T[q, m, C[n, m]]

The per-subspace table lookup is lane-hostile as a gather, so each subspace
is materialised as a one-hot matmul on the MXU: for a code tile (BN,) build
onehot (K x BN) with broadcasted_iota and contract T[:, m, :] (BQ x K)
against it — K is the codebook size (<=256), so the one-hot tile is small
and the MXU does BQ x K x BN useful work per subspace. Distances accumulate
in VMEM across the M unrolled subspaces; a running top-k buffer (BQ x K_top)
is merged across database tiles with the same K unrolled extract-min steps
as ``knn_topk`` (no in-kernel sort on Mosaic).

Grid (Q/BQ, N/BN), database axis fastest-varying; the top-k block for each
query tile is revisited and updated across database tiles.

Quantized LUTs (``lut_dtype``, see ``lut.py``): tables enter the kernel in
f32, bf16, or int8. bf16 contracts on the bf16 MXU path with f32
accumulation; int8 contracts int8 x int8 -> int32 and one per-query f32
``scale`` multiply (an extra (BQ, 1) input block) restores the distance
unit after the M subspaces accumulate — the integer partial sums are exact,
so the only error is the table rounding itself. VMEM for the tables drops
2x / 4x accordingly.

Two entry points share the merge:

* ``pq_adc_topk_pallas``       — shared (N, M) codes, plain-PQ scan;
* ``pq_adc_gather_topk_pallas``— per-query (C, M) candidate codes plus a
  per-candidate additive ``base`` (the IVF-PQ residual decomposition). The
  lookup here is per-query, so the one-hot contraction runs on the VPU
  ((BQ, BN, K) masked sum — int32 select/add for int8) — block defaults are
  smaller to bound VMEM.

Layout notes: codes enter the shared kernel transposed (M, N) so a subspace
row slice is a native (1, BN) lane vector; VMEM at defaults
(BQ=128, BN=512, M=16, K=256): tables 2 MiB f32 / 1 MiB bf16 / 0.5 MiB int8
+ onehot 0.5 MiB + d2 0.25 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lut import quantize_lut

_INF = float("inf")
_BIGI = 2**31 - 1


def _merge_topk(work, gj, bd, bi, k):
    """Merge a masked (BQ, BN) distance tile into running top-k buffers.

    K unrolled extract-min steps from vector min/compare/select +
    broadcasted_iota (first-occurrence argmin trick) — O(k·BQ·BN) VPU work.
    """
    pos = jax.lax.broadcasted_iota(jnp.int32, bd.shape, 1)   # (BQ, K_top)
    for _ in range(k):
        m = jnp.min(work, axis=1)                            # (BQ,)
        col = jnp.min(jnp.where(work == m[:, None], gj, _BIGI), axis=1)
        worst = jnp.max(bd, axis=1)                          # (BQ,)
        wpos = jnp.min(jnp.where(bd == worst[:, None], pos, _BIGI), axis=1)
        better = (m < worst)[:, None]                        # (BQ, 1)
        sel = (pos == wpos[:, None]) & better
        bd = jnp.where(sel, m[:, None], bd)
        bi = jnp.where(sel, col[:, None], bi)
        work = jnp.where(gj == col[:, None], _INF, work)
    return bd, bi


def _adc_kernel(n_total, k, lut_dtype, t_ref, *refs):
    if lut_dtype == "int8":
        s_ref, c_ref, best_d_ref, best_i_ref = refs
    else:
        (c_ref, best_d_ref, best_i_ref), s_ref = refs, None
    j = pl.program_id(1)
    tables = t_ref[...]                                      # (BQ, M, K)
    bq, m, kc = tables.shape
    bn = c_ref.shape[1]
    cent = jax.lax.broadcasted_iota(jnp.int32, (kc, bn), 0)
    if lut_dtype == "int8":
        acc = jnp.zeros((bq, bn), jnp.int32)
        for sub in range(m):                                 # M static: unroll
            # codes arrive at stored width (uint8); widen the (1, BN) slice
            # in-register — HBM traffic stays 1 byte/code
            row = c_ref[sub:sub + 1, :].astype(jnp.int32)
            onehot = (row == cent).astype(jnp.int8)
            acc = acc + jax.lax.dot_general(
                tables[:, sub, :], onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)            # int8 MXU path
        d2 = acc.astype(jnp.float32) * s_ref[...]            # (BQ,BN)*(BQ,1)
    else:
        d2 = jnp.zeros((bq, bn), jnp.float32)
        for sub in range(m):                                 # M static: unroll
            row = c_ref[sub:sub + 1, :].astype(jnp.int32)
            onehot = (row == cent).astype(tables.dtype)
            d2 = d2 + jax.lax.dot_general(
                tables[:, sub, :], onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # MXU (BQ,K)@(K,BN)
    gj = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    work = jnp.where(gj < n_total, d2, _INF)

    @pl.when(j == 0)
    def _init():
        best_d_ref[...] = jnp.full_like(best_d_ref, _INF)
        best_i_ref[...] = jnp.full_like(best_i_ref, -1)

    bd, bi = _merge_topk(work, gj, best_d_ref[...], best_i_ref[...], k)
    best_d_ref[...] = bd
    best_i_ref[...] = bi


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "lut_dtype"))
def pq_adc_topk_pallas(tables: jax.Array, codes: jax.Array, k: int,
                       block_q: int = 128, block_n: int = 512,
                       interpret: bool = True, lut_dtype: str = "f32",
                       scale=None):
    """Fused ADC scan over a shared code matrix.

    tables (Q, M, K) f32 (quantized internally per ``lut_dtype``; ``scale``
    optionally overrides the per-query int8 scale with a caller-certified
    bound — see ``lut.quantize_lut``);
    codes (N, M) int — kept at stored width (uint8 for K <= 256) through
    the HBM->VMEM pipeline and widened in-register per subspace. Returns
    (d2 (Q, k) ascending, idx (Q, k) int32 ids into the code matrix).
    """
    nq, m, kc = tables.shape
    n = codes.shape[0]
    qt, scale = quantize_lut(tables, lut_dtype, scale)
    pad_q = (-nq) % block_q
    pad_n = (-n) % block_n
    tp = jnp.pad(qt, ((0, pad_q), (0, 0), (0, 0))) if pad_q else qt
    cp = jnp.pad(codes, ((0, pad_n), (0, 0))) if pad_n else codes
    grid = (tp.shape[0] // block_q, cp.shape[0] // block_n)
    inputs = [tp, cp.T]                       # codes at stored width (uint8)
    in_specs = [
        pl.BlockSpec((block_q, m, kc), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((m, block_n), lambda i, j: (0, j)),
    ]
    if lut_dtype == "int8":
        sp = jnp.pad(scale, (0, pad_q)) if pad_q else scale
        inputs.insert(1, sp[:, None].astype(jnp.float32))
        in_specs.insert(1, pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)))
    bd, bi = pl.pallas_call(
        functools.partial(_adc_kernel, n, k, lut_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((tp.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    bd, bi = bd[:nq], bi[:nq]
    order = jnp.argsort(bd, axis=1)                          # ascending sort
    return (jnp.take_along_axis(bd, order, axis=1),
            jnp.take_along_axis(bi, order, axis=1))


def _adc_gather_kernel(c_total, k, lut_dtype, t_ref, *refs):
    if lut_dtype == "int8":
        s_ref, c_ref, base_ref, best_d_ref, best_i_ref = refs
    else:
        (c_ref, base_ref, best_d_ref, best_i_ref), s_ref = refs, None
    j = pl.program_id(1)
    tables = t_ref[...]                                      # (BQ, M, K)
    bq, m, kc = tables.shape
    bn = c_ref.shape[1]
    cent = jax.lax.broadcasted_iota(jnp.int32, (bq, bn, kc), 2)
    if lut_dtype == "int8":
        ti = tables.astype(jnp.int32)
        acc = jnp.zeros((bq, bn), jnp.int32)
        for sub in range(m):                                 # M static: unroll
            # uint8 codes widen in-register; gathered bytes stay narrow
            hit = c_ref[:, :, sub].astype(jnp.int32)[:, :, None] == cent
            acc = acc + jnp.sum(
                jnp.where(hit, ti[:, sub, :][:, None, :], 0), axis=2)
        lut = acc.astype(jnp.float32) * s_ref[...]           # (BQ,BN)*(BQ,1)
    else:
        tf = tables.astype(jnp.float32)
        lut = jnp.zeros((bq, bn), jnp.float32)
        for sub in range(m):                                 # M static: unroll
            onehot = (c_ref[:, :, sub].astype(jnp.int32)[:, :, None] == cent
                      ).astype(jnp.float32)
            lut = lut + jnp.sum(tf[:, sub, :][:, None, :] * onehot, axis=2)
    d2 = base_ref[...].astype(jnp.float32) + lut
    gj = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    work = jnp.where(gj < c_total, d2, _INF)

    @pl.when(j == 0)
    def _init():
        best_d_ref[...] = jnp.full_like(best_d_ref, _INF)
        best_i_ref[...] = jnp.full_like(best_i_ref, -1)

    bd, bi = _merge_topk(work, gj, best_d_ref[...], best_i_ref[...], k)
    best_d_ref[...] = bd
    best_i_ref[...] = bi


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "lut_dtype"))
def pq_adc_gather_topk_pallas(tables: jax.Array, codes: jax.Array,
                              base: jax.Array, k: int,
                              block_q: int = 8, block_n: int = 256,
                              interpret: bool = True, lut_dtype: str = "f32",
                              scale=None):
    """Fused ADC scan over per-query gathered candidate codes.

    tables (Q, M, K) f32 (quantized internally per ``lut_dtype``; ``scale``
    optionally overrides the per-query int8 scale with a caller-certified
    bound — see ``lut.quantize_lut``);
    codes (Q, C, M) int — kept at stored width (uint8 for K <= 256), so
    candidate-code HBM traffic is 1 byte/code; base (Q, C) f32 additive
    term (+inf masks padded candidates; never quantized). Returns
    (d2 (Q, k) ascending, idx (Q, k) int32 candidate-slot ids in [0, C)).
    """
    nq, m, kc = tables.shape
    c = codes.shape[1]
    qt, scale = quantize_lut(tables, lut_dtype, scale)
    pad_q = (-nq) % block_q
    pad_c = (-c) % block_n
    tp = jnp.pad(qt, ((0, pad_q), (0, 0), (0, 0))) if pad_q else qt
    cp = jnp.pad(codes, ((0, pad_q), (0, pad_c), (0, 0)))
    bp = jnp.pad(base, ((0, pad_q), (0, pad_c)), constant_values=_INF)
    grid = (tp.shape[0] // block_q, cp.shape[1] // block_n)
    inputs = [tp, cp, bp.astype(jnp.float32)]  # codes at stored width (uint8)
    in_specs = [
        pl.BlockSpec((block_q, m, kc), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((block_q, block_n, m), lambda i, j: (i, j, 0)),
        pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
    ]
    if lut_dtype == "int8":
        sp = jnp.pad(scale, (0, pad_q)) if pad_q else scale
        inputs.insert(1, sp[:, None].astype(jnp.float32))
        in_specs.insert(1, pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)))
    bd, bi = pl.pallas_call(
        functools.partial(_adc_gather_kernel, c, k, lut_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((tp.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    bd, bi = bd[:nq], bi[:nq]
    order = jnp.argsort(bd, axis=1)
    return (jnp.take_along_axis(bd, order, axis=1),
            jnp.take_along_axis(bi, order, axis=1))
