from .kernel import pq_adc_gather_topk_pallas, pq_adc_topk_pallas
from .lut import LUT_DTYPES, dequantize_lut, lut_error_bound, quantize_lut
from .ops import pq_adc_gather_topk, pq_adc_topk, pq_adc_topk_global
from .ref import (pq_adc_gather_scores_ref, pq_adc_gather_topk_ref,
                  pq_adc_scores_ref, pq_adc_topk_ref)

__all__ = [
    "pq_adc_topk_pallas", "pq_adc_gather_topk_pallas",
    "pq_adc_topk", "pq_adc_gather_topk", "pq_adc_topk_global",
    "pq_adc_scores_ref", "pq_adc_topk_ref",
    "pq_adc_gather_scores_ref", "pq_adc_gather_topk_ref",
    "LUT_DTYPES", "quantize_lut", "dequantize_lut", "lut_error_bound",
]
