"""Pure-jnp oracle: cross-entropy with materialized logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ce_ref(h, w, labels, vocab=None):
    """h (T, D), w (D, V), labels (T,) -> per-token loss (T,) f32.

    ``vocab``: logical vocab (<= V); padded tail masked out.
    """
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    if vocab is not None and vocab < w.shape[1]:
        col = jnp.arange(w.shape[1])[None, :]
        logits = jnp.where(col < vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return lse - gold
