from .kernel import fused_ce_fwd
from .ops import fused_ce
from .ref import ce_ref

__all__ = ["fused_ce_fwd", "fused_ce", "ce_ref"]
