"""Custom-VJP wrapper: Pallas fused-CE forward; backward recomputes the
softmax in vocab chunks (never materializing (T, V) either)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .kernel import fused_ce_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_ce(h, w, labels, vocab=None, interpret=True):
    return fused_ce_fwd(h, w, labels, vocab=vocab, interpret=interpret)


def _fwd(h, w, labels, vocab, interpret):
    loss = fused_ce_fwd(h, w, labels, vocab=vocab, interpret=interpret)
    return loss, (h, w, labels)


def _bwd(vocab, interpret, res, ct):
    """d h = (softmax - onehot) @ w^T, d w = h^T @ (softmax - onehot),
    computed per vocab chunk with a first lse pass (chunked, O(T) memory)."""
    h, w, labels = res
    t, d = h.shape
    v = w.shape[1]
    voc = v if vocab is None else vocab
    chunk = math.gcd(4096, v)
    n_chunks = v // chunk
    h32 = h.astype(jnp.float32)

    def lse_pass(carry, vi):
        m_p, s_p = carry
        wv = jax.lax.dynamic_slice_in_dim(w, vi * chunk, chunk, 1)
        lg = h32 @ wv.astype(jnp.float32)
        col = vi * chunk + jnp.arange(chunk)[None, :]
        lg = jnp.where(col < voc, lg, -1e30)
        m_n = jnp.maximum(m_p, lg.max(1))
        s_n = s_p * jnp.exp(m_p - m_n) + jnp.exp(
            lg - m_n[:, None]).sum(1)
        return (m_n, s_n), None

    (m, s), _ = jax.lax.scan(
        lse_pass, (jnp.full((t,), -1e30), jnp.zeros((t,))),
        jnp.arange(n_chunks))
    lse = m + jnp.log(jnp.maximum(s, 1e-30))

    def grad_pass(carry, vi):
        dh_acc = carry
        wv = jax.lax.dynamic_slice_in_dim(w, vi * chunk, chunk, 1)
        lg = h32 @ wv.astype(jnp.float32)
        col = vi * chunk + jnp.arange(chunk)[None, :]
        lg = jnp.where(col < voc, lg, -1e30)
        p = jnp.exp(lg - lse[:, None])
        p = p - (col == labels[:, None]).astype(jnp.float32)
        p = p * ct[:, None]
        dh_acc = dh_acc + p @ wv.astype(jnp.float32).T
        dwv = h32.T @ p
        return dh_acc, dwv

    dh, dws = jax.lax.scan(grad_pass, jnp.zeros((t, d)),
                           jnp.arange(n_chunks))
    dw = jnp.transpose(dws, (1, 0, 2)).reshape(d, v)   # chunks contiguous
    return dh.astype(h.dtype), dw.astype(w.dtype), None


fused_ce.defvjp(_fwd, _bwd)
