"""Pallas TPU fused cross-entropy: logits never reach HBM.

The chunked-CE scan still writes each (rows x vocab_chunk) f32 logits tile
to HBM around the fusion boundary (with vocab up to 262k this is the second
largest LM memory term after attention — §Perf). This kernel streams vocab
tiles through VMEM with an online logsumexp and picks out the gold logit on
the fly:

  grid (T/bt, V/bv), vocab axis fastest:
    logits_tile = h_tile @ w_tile              (bt x bv on the MXU)
    m, s        online max / exp-sum           (bt,) each, revisited outputs
    gold        sum of one-hot-selected logits (bt,)

loss = (m + log s) - gold, assembled in ops.py. HBM traffic: h read once
per vocab tile (bt x D), W read once, three (T,) vectors written — no
(T, V) tensor anywhere.

VMEM per step at bt=256, bv=512, D=2048: h 2 MB + w 4 MB + tile 0.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _ce_kernel(vocab, h_ref, w_ref, lab_ref, m_ref, s_ref, g_ref):
    vi = pl.program_id(1)
    bt = h_ref.shape[0]
    bv = w_ref.shape[1]
    h = h_ref[...].astype(jnp.float32)                   # (bt, D)
    w = w_ref[...].astype(jnp.float32)                   # (D, bv)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (bt, bv)
    col = vi * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    logits = jnp.where(col < vocab, logits, _NEG_INF)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    m_prev = m_ref[...][:, 0]
    s_prev = s_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    corr = jnp.exp(m_prev - m_new)
    s_new = s_prev * corr + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=1)
    m_ref[...] = m_new[:, None]
    s_ref[...] = s_new[:, None]
    lab = lab_ref[...][:, 0]                             # (bt,)
    hit = (col == lab[:, None])
    g_ref[...] += jnp.sum(jnp.where(hit, logits, 0.0), axis=1)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("vocab", "block_t", "block_v",
                                    "interpret"))
def fused_ce_fwd(h, w, labels, *, vocab=None, block_t: int = 256,
                 block_v: int = 512, interpret: bool = True):
    """h (T, D), w (D, V), labels (T,) -> per-token loss (T,) f32."""
    import math
    t, d = h.shape
    v = w.shape[1]
    vocab = v if vocab is None else vocab
    block_t = min(block_t, t)
    if t % block_t:
        block_t = math.gcd(block_t, t)
    block_v = min(block_v, v)
    if v % block_v:
        block_v = math.gcd(block_v, v)
    grid = (t // block_t, v // block_v)
    lab2 = labels.reshape(t, 1).astype(jnp.int32)
    m, s, g = pl.pallas_call(
        functools.partial(_ce_kernel, vocab),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((d, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((t, 1), jnp.float32)] * 3,
        interpret=interpret,
    )(h, w, lab2)
    return (m[:, 0] + jnp.log(jnp.maximum(s[:, 0], 1e-30))) - g[:, 0]
