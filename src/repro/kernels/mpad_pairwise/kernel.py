"""Pallas TPU kernel for MPAD pairwise-threshold statistics.

The paper's O(N^2) hot loop (Algorithm 1 steps 5-8) recast for the TPU memory
hierarchy (DESIGN.md §3): instead of materializing + sorting N^2/2 pairwise
distances in HBM, the kernel streams (BI x BJ) tiles of the implicit
difference matrix through VMEM and reduces them to O(N) outputs:

  out c     (N,1) f32 — signed within-threshold counts (gradient coefficients)
  out cnt   (1,1) i32 — #ordered pairs within tau (halve for unordered)
  out sum   (1,1) f32 — sum of |p_i-p_j| over ordered pairs within tau (halve)

Grid is (N/BI, N/BJ); the j axis is the fastest-varying (sequential) axis so
the c-block for row-tile i is revisited and accumulated across j — the
standard Pallas accumulate-over-grid pattern. Block sizes default to 256
(lane-aligned multiples of 128).

VMEM working set per step: BI + BJ scalars + one BI x BJ f32 tile
(256x256x4 = 256 KiB), far under the ~16 MiB VMEM budget; larger BJ (512/1024)
raises arithmetic intensity if needed — the kernel is compute-bound on the
VPU (no MXU work), which is what frees the MXU-bound matmuls elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _stats_kernel(n_total, pi_ref, pj_ref, tau_ref, c_ref, cnt_ref, s_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bi = pi_ref.shape[0]
    bj = pj_ref.shape[0]
    pi = pi_ref[:, 0]
    pj = pj_ref[:, 0]
    diff = pi[:, None] - pj[None, :]                       # (BI, BJ)
    ad = jnp.abs(diff)
    gi = i * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0)
    gj = j * bj + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1)
    valid = (gi != gj) & (gi < n_total) & (gj < n_total)
    mask = (ad <= tau_ref[0, 0]) & valid

    @pl.when(j == 0)
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[:, 0] += jnp.sum(jnp.where(mask, jnp.sign(diff), 0.0), axis=1)

    @pl.when((i == 0) & (j == 0))
    def _init_scalars():
        cnt_ref[0, 0] = 0
        s_ref[0, 0] = 0.0

    cnt_ref[0, 0] += jnp.sum(mask.astype(jnp.int32))
    s_ref[0, 0] += jnp.sum(jnp.where(mask, ad, 0.0))


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_j", "interpret"))
def pairwise_stats_pallas(p: jax.Array, tau: jax.Array,
                          block_i: int = DEFAULT_BLOCK,
                          block_j: int = DEFAULT_BLOCK,
                          interpret: bool = True):
    """Tiled threshold statistics. Returns (count i32, sum f32, coeff (N,))."""
    n = p.shape[0]
    pad = (-n) % max(block_i, block_j)
    p_padded = jnp.pad(p, (0, pad)) if pad else p
    np_ = p_padded.shape[0]
    p2 = p_padded.reshape(np_, 1).astype(jnp.float32)
    tau2 = jnp.reshape(tau, (1, 1)).astype(jnp.float32)
    grid = (np_ // block_i, np_ // block_j)
    c, cnt, s = pl.pallas_call(
        functools.partial(_stats_kernel, n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(p2, p2, tau2)
    coeff = c[:n, 0]
    return cnt[0, 0] // 2, s[0, 0] * 0.5, coeff
