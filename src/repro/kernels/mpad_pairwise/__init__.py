from .kernel import pairwise_stats_pallas
from .ref import pairwise_stats_ref
from .ops import mu_kernel_value_and_grad, phi_kernel_value_and_grad

__all__ = ["pairwise_stats_pallas", "pairwise_stats_ref",
           "mu_kernel_value_and_grad", "phi_kernel_value_and_grad"]
