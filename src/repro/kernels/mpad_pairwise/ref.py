"""Pure-jnp oracle for the MPAD pairwise-threshold statistics.

Given scalar projections ``p`` (N,) and a threshold ``tau``, over all
*unordered* pairs i<j with |p_i - p_j| <= tau:

  count — number of such pairs
  sum   — sum of |p_i - p_j|
  coeff — c_i = #{j : p_j < p_i within tau} - #{j : p_j > p_i within tau}
          (the exact subgradient coefficients: grad mu = X^T c / count)

O(N^2) dense; the ground truth for both the Pallas kernel and the sorted
fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_stats_ref(p: jax.Array, tau: jax.Array):
    n = p.shape[0]
    diff = p[:, None] - p[None, :]
    ad = jnp.abs(diff)
    neq = ~jnp.eye(n, dtype=bool)
    within = (ad <= tau) & neq
    count = jnp.sum(within, dtype=jnp.int32) // 2
    s = jnp.sum(jnp.where(within, ad, 0.0)) * 0.5
    coeff = jnp.sum(jnp.where(within, jnp.sign(diff), 0.0), axis=1)
    return count, s, coeff
