"""Jit'd wrapper: MPAD objective value-and-grad backed by the Pallas kernel.

Hybrid schedule (DESIGN.md §3.2): the b%-quantile threshold tau_b is found on
the *sorted scalar projections* (O(N log N) — sorting N scalars is trivial
next to the N^2 pair pass), then ONE kernel pass produces the exact count /
sum / gradient coefficients. This keeps the expensive O(N^2) work in a single
tiled VMEM-resident sweep instead of the ~60 sweeps a count-only bisection
would need.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.fast_objective import find_quantile_threshold
from repro.core.objective import num_selected_pairs
from .kernel import pairwise_stats_pallas


@functools.partial(jax.jit, static_argnames=("b", "interpret", "block"))
def mu_kernel_value_and_grad(w: jax.Array, x: jax.Array, *, b: float,
                             interpret: bool = True, block: int = 256):
    """Value and tangent gradient of mu_b at unit ``w`` via the Pallas kernel."""
    k_pairs = num_selected_pairs(x.shape[0], b)
    wn = w / jnp.linalg.norm(w)
    p = x @ wn
    tau = find_quantile_threshold(p, k_pairs)
    cnt, s, coeff = pairwise_stats_pallas(
        p, tau, block_i=block, block_j=block, interpret=interpret)
    cntf = jnp.maximum(cnt, 1).astype(p.dtype)
    excess = cntf - k_pairs
    value = (s - excess * tau) / k_pairs
    g_raw = (x.T @ coeff) / cntf
    g = g_raw - jnp.dot(g_raw, wn) * wn
    return value, g


@functools.partial(jax.jit, static_argnames=("b", "alpha", "interpret", "block"))
def phi_kernel_value_and_grad(w, x, prev, prev_mask, *, b: float, alpha: float,
                              interpret: bool = True, block: int = 256):
    """Trainer backend contract (see repro.core.mpad._get_backend)."""
    mu, g_mu = mu_kernel_value_and_grad(w, x, b=b, interpret=interpret,
                                        block=block)
    wn = w / jnp.linalg.norm(w)
    dots = (prev @ wn) * prev_mask
    pen = alpha * jnp.sum(dots * dots)
    g_pen_raw = 2.0 * alpha * (prev.T @ (dots * prev_mask))
    g_pen = g_pen_raw - jnp.dot(g_pen_raw, wn) * wn
    return mu - pen, g_mu - g_pen
