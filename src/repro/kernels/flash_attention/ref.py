"""Pure-jnp oracle: full-matrix causal GQA attention (optionally windowed)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, window=None):
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh); self-attention positions
    (q_pos = kv_pos = arange). Returns (B, Sq, H, dh) f32."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    ok = kp <= qp
    if window is not None:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh)
