"""Pallas TPU flash-attention (forward): online-softmax tiles in VMEM.

The §Perf measurement showed the chunked-jnp attention's score/probability
tensors dominate the LM cells' HBM traffic (every (q_chunk x kv_chunk) f32
tile is written + read back around each XLA fusion boundary). This kernel
keeps the running (m, l, acc) state and the score tile entirely in VMEM:
HBM traffic collapses to Q/K/V reads + one output write — the canonical
FlashAttention dataflow expressed for the TPU memory hierarchy.

Layout: grid (B*KV*G, nq, nk), kv axis fastest-varying. Q is viewed as
(B*KV*G, Sq, dh) — GQA folds query groups into the leading grid axis and the
K/V BlockSpec index maps divide it back (no KV head replication in HBM).
Running state lives in revisited output blocks (acc, m, l); pl.when skips
fully-masked (causal/window) kv tiles so the causal triangle costs ~half.

Causal self-attention (q_pos = kv_pos = arange) with optional sliding
window — the training/prefill hot path. Block sizes default to 128/256
(MXU-aligned); VMEM per step ~ (2*q_blk*dh + k_blk*dh + q_blk*k_blk)*4B
(128, 256, dh=128: ~0.4 MiB).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(sq, skv, g, window, scale, q_ref, k_ref, v_ref,
                  acc_ref, m_ref, l_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    qb = q_ref.shape[0]
    kb = k_ref.shape[0]
    # absolute positions: q rows are (g, Sq) folded -> position = row % Sq
    row = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    q_pos = row % sq
    kv_pos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)

    @pl.when((ki == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip tiles that are entirely in the causal future (or past the window)
    first_q = (qi * qb) % sq                 # positions are periodic in g
    last_q = jnp.minimum(first_q + qb - 1, sq - 1)
    tile_live = (ki * kb) <= last_q
    if window is not None:
        tile_live &= (ki * kb + kb - 1) >= 0   # window handled per-element

    @pl.when(tile_live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)             # (qb, dh)
        k = k_ref[...].astype(jnp.float32)             # (kb, dh)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (qb, kb)
        ok = (kv_pos <= q_pos) & (kv_pos < skv)
        if window is not None:
            ok &= (q_pos - kv_pos) < window
        s = jnp.where(ok, s, _NEG_INF)
        m_prev = m_ref[...][:, 0]                      # (qb,)
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (qb, dh)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, window=None, block_q: int = 128,
                        block_k: int = 256, interpret: bool = True):
    """Causal GQA self-attention. q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh).

    Returns (B, Sq, H, dh) in q's dtype.
    """
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    block_q = min(block_q, sq)
    if sq % block_q:
        block_q = math.gcd(block_q, sq)
    block_k = min(block_k, skv)
    if skv % block_k:
        block_k = math.gcd(block_k, skv)
    # fold GQA groups into the lead axis: row r of head (kv, g) = g*Sq + pos
    qv = (q.reshape(b, sq, kvh, g, dh).transpose(0, 2, 3, 1, 4)
          .reshape(b * kvh, g * sq, dh))
    kv_ = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, dh)
    vv = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, dh)
    grid = (b * kvh, g * sq // block_q, skv // block_k)
    acc, m, l = pl.pallas_call(
        functools.partial(_flash_kernel, sq, skv, g, window, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_q, 1), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_q, 1), lambda bh, qi, ki: (bh, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * kvh, g * sq, dh), jnp.float32),
            jax.ShapeDtypeStruct((b * kvh, g * sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * kvh, g * sq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qv, kv_, vv)
    out = acc / jnp.maximum(l, 1e-30)
    out = (out.reshape(b, kvh, g, sq, dh).transpose(0, 3, 1, 2, 4)
           .reshape(b, sq, h, dh))
    return out.astype(q.dtype)
