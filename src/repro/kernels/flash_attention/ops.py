"""Jit'd wrapper with custom VJP: Pallas flash forward, rematerialized
chunked-jnp backward (the standard serve-fast/train-correct split — the
backward recomputes through the memory-bounded chunked path)."""
from __future__ import annotations

import functools

import jax

from repro.models.layers import chunked_attention
from .kernel import flash_attention_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, window=None, interpret=True):
    return flash_attention_fwd(q, k, v, window=window, interpret=interpret)


def _chunked(q, k, v, window):
    sq = q.shape[1]
    pos = jax.numpy.arange(sq)
    return chunked_attention(q, k, v, pos, pos, window=window)


def _fwd(q, k, v, window, interpret):
    out = flash_attention_fwd(q, k, v, window=window, interpret=interpret)
    return out, (q, k, v)


def _bwd(window, interpret, res, ct):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _chunked(q_, k_, v_, window), q, k, v)
    return vjp(ct)


flash_attention.defvjp(_fwd, _bwd)
