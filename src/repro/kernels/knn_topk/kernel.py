"""Pallas TPU kernel: blocked L2 distances + running top-k.

The serving hot loop of the reduced-space scan (DESIGN.md §3.5). For a query
tile Q_blk (BQ x d) and database tile X_blk (BN x d):

  d2 = |q|^2 + |x|^2 - 2 q @ x^T      — the cross term is an MXU matmul
                                         (BQ x d) @ (d x BN)

and a running top-k buffer (BQ x K) is merged in-register. TPU Mosaic has no
general in-kernel sort/top_k, so the merge is K unrolled extract-min steps
built from vector min / compare / select + broadcasted_iota (first-occurrence
argmin trick) — O(K * BQ * BN) VPU work against O(BQ * BN * d) MXU work, i.e.
negligible for d >= K.

Grid (Q/BQ, N/BN), database axis fastest-varying; the top-k buffer block for
each query tile is revisited and updated across database tiles.

Layout notes: BQ, BN multiples of 128 keep the MXU fed and lanes full; the
distance tile (BQ x BN f32) plus both operand tiles bound VMEM:
128x512: 128*512*4 + (128+512)*d*4 ≈ 0.5 MiB at d=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")
_BIGI = 2**31 - 1


def _knn_kernel(n_total, k, q_ref, x_ref, best_d_ref, best_i_ref):
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                   # (BQ, d)
    xb = x_ref[...].astype(jnp.float32)                  # (BN, d)
    bq, bn = q.shape[0], xb.shape[0]
    qq = jnp.sum(q * q, axis=1, keepdims=True)
    xx = jnp.sum(xb * xb, axis=1)[None, :]
    cross = jax.lax.dot_general(
        q, xb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (BQ, BN) on the MXU
    d2 = jnp.maximum(qq + xx - 2.0 * cross, 0.0)
    gj = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    work = jnp.where(gj < n_total, d2, _INF)

    @pl.when(j == 0)
    def _init():
        best_d_ref[...] = jnp.full_like(best_d_ref, _INF)
        best_i_ref[...] = jnp.full_like(best_i_ref, -1)

    bd = best_d_ref[...]
    bi = best_i_ref[...]
    pos = jax.lax.broadcasted_iota(jnp.int32, bd.shape, 1)  # (BQ, K)
    for _ in range(k):                                   # unrolled extract-min
        m = jnp.min(work, axis=1)                        # (BQ,)
        col = jnp.min(jnp.where(work == m[:, None], gj, _BIGI), axis=1)
        worst = jnp.max(bd, axis=1)                      # (BQ,)
        wpos = jnp.min(jnp.where(bd == worst[:, None], pos, _BIGI), axis=1)
        better = (m < worst)[:, None]                    # (BQ, 1)
        sel = (pos == wpos[:, None]) & better
        bd = jnp.where(sel, m[:, None], bd)
        bi = jnp.where(sel, col[:, None], bi)
        work = jnp.where(gj == col[:, None], _INF, work)
    best_d_ref[...] = bd
    best_i_ref[...] = bi


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_n", "interpret"))
def knn_topk_pallas(q: jax.Array, x: jax.Array, k: int,
                    block_q: int = 128, block_n: int = 512,
                    interpret: bool = True):
    """Blocked exact k-NN. Returns (d2 (Q,k) ascending, idx (Q,k))."""
    nq, d = q.shape
    n = x.shape[0]
    pad_q = (-nq) % block_q
    pad_n = (-n) % block_n
    qp = jnp.pad(q, ((0, pad_q), (0, 0))) if pad_q else q
    xp = jnp.pad(x, ((0, pad_n), (0, 0))) if pad_n else x
    grid = (qp.shape[0] // block_q, xp.shape[0] // block_n)
    bd, bi = pl.pallas_call(
        functools.partial(_knn_kernel, n, k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(qp.astype(jnp.float32), xp.astype(jnp.float32))
    bd, bi = bd[:nq], bi[:nq]
    order = jnp.argsort(bd, axis=1)                      # ascending final sort
    return jnp.take_along_axis(bd, order, axis=1), jnp.take_along_axis(
        bi, order, axis=1)
