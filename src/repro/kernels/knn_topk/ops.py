"""Jit'd public wrapper for the blocked k-NN Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import knn_topk_pallas


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_n", "interpret"))
def knn_topk(q: jax.Array, x: jax.Array, k: int, *, block_q: int = 128,
             block_n: int = 512, interpret: bool = True):
    """Exact k-NN via the Pallas kernel: (dists (Q,k), idx (Q,k)), L2."""
    d2, idx = knn_topk_pallas(q, x, k, block_q=block_q, block_n=block_n,
                              interpret=interpret)
    return jnp.sqrt(jnp.maximum(d2, 0.0)), idx
