from .kernel import knn_topk_pallas
from .ops import knn_topk
from .ref import knn_ref

__all__ = ["knn_topk_pallas", "knn_topk", "knn_ref"]
