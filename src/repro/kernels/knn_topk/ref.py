"""Pure-jnp oracle for blocked k-NN: full L2 distance matrix + lax.top_k."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def knn_ref(q: jax.Array, x: jax.Array, k: int):
    """Returns (d2 (Q,k) ascending squared distances, idx (Q,k))."""
    qq = jnp.sum(q * q, axis=1)[:, None]
    xx = jnp.sum(x * x, axis=1)[None, :]
    d2 = jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx
