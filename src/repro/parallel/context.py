"""Trace-time mesh context: models call ``constrain(x, spec)`` freely; it is
a no-op unless a mesh is active (smoke tests run unsharded, the dry-run and
launchers activate the production mesh)."""
from __future__ import annotations

import contextlib
from typing import List, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["mesh_context", "active_mesh", "constrain", "require_mesh"]

_ACTIVE: List[Mesh] = []


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    _ACTIVE.append(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE.pop()


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE[-1] if _ACTIVE else None


def require_mesh(what: str = "this operation") -> Mesh:
    """The active mesh, or a clear error naming the caller.

    For APIs that need a mesh but accept ``mesh=None`` as "use the
    context's" (e.g. ``SearchEngine.shard()``, ``shard_engine``).
    """
    mesh = active_mesh()
    if mesh is None:
        raise RuntimeError(
            f"{what} needs a device mesh: pass mesh= explicitly or activate "
            "one with repro.parallel.context.mesh_context(...)")
    return mesh


def constrain(x, spec: P):
    """with_sharding_constraint iff a mesh is active and its axes exist."""
    mesh = active_mesh()
    if mesh is None:
        return x
    flat = []
    for entry in spec:
        if entry is None:
            flat.append(None)
        elif isinstance(entry, tuple):
            axes = tuple(a for a in entry if a in mesh.axis_names)
            flat.append(axes if axes else None)
        else:
            flat.append(entry if entry in mesh.axis_names else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*flat)))
