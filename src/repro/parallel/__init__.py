from .sharding import (dp_axes, lm_param_specs, opt_specs, tree_named,
                       lm_cache_specs, replicate_like)

__all__ = ["dp_axes", "lm_param_specs", "opt_specs", "tree_named",
           "lm_cache_specs", "replicate_like"]
