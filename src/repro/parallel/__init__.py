from .context import active_mesh, constrain, mesh_context, require_mesh
from .engine import shard_engine
from .sharding import (dp_axes, engine_state_specs, lm_param_specs,
                       opt_specs, tree_named, lm_cache_specs, replicate_like)

__all__ = ["active_mesh", "constrain", "mesh_context", "require_mesh",
           "shard_engine", "dp_axes", "engine_state_specs",
           "lm_param_specs", "opt_specs", "tree_named", "lm_cache_specs",
           "replicate_like"]
