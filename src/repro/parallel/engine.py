"""``shard_engine``: partition a serving ``EngineState`` across a mesh.

The data-parallel layout pass of sharded serving (DESIGN: one shard = one
slice of the database axis). The per-kind re-layout is a registry hook
(``repro.search.registry.IndexOps.shard_payload``):

* **row-major leaves** — corpus rows, flat scan vectors, plain-PQ code rows
  — are padded to a device-count multiple and split along dim 0 (pad rows
  carry global ids >= ``n_real`` and are masked out of every scan);
* **cell-major leaves** — IVF / IVF-PQ posting lists and the
  ``codes_cell``/``bias_cell`` mirrors, plus a ``cell_vectors`` mirror
  built for IVF-Flat — are padded to per-shard-equal cell counts and
  split along the cell axis (pad cells are all ``-1`` posting rows, never
  probed);
* everything else — MPAD projection, coarse centroids, codebook
  factorizations — replicates, so the coarse probe and per-query LUTs
  compute identically on every shard.

Placement is by ``NamedSharding`` from ``engine_state_specs``; the result
is a ``ShardedEngineState`` (corpus + projection + the tagged
``Index`` union carrying the kind's sharded payload) ready for
``sharded_search_fn`` / ``SearchEngine.shard()``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.search.registry import Index, get_ops
from repro.search.serve import EngineState, ShardedEngineState
from .context import require_mesh
from .sharding import engine_state_specs

__all__ = ["shard_engine", "shard_stream"]


def _pad_dim0(a: Optional[jax.Array], multiple: int, fill=0):
    """Right-pad dim 0 up to a multiple (per-shard-equal blocks)."""
    if a is None:
        return None
    n = a.shape[0]
    pad = (-n) % multiple
    if not pad:
        return a
    widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


def shard_engine(state: EngineState, mesh: Optional[Mesh] = None,
                 axis: str = "data", donate: bool = False,
                 keep=()) -> ShardedEngineState:
    """Re-lay-out and place ``state`` for serving over the ``axis`` of
    ``mesh`` (default: the context's active mesh).

    Pure layout — no index rebuild: the same corpus rows, posting lists,
    and codes end up distributed over the mesh devices, so
    ``sharded_search_fn`` returns exactly what ``search_fn`` returns on
    the unsharded state.

    ``donate=True`` releases the dense input buffers once the sharded
    copy is placed (build -> shard -> serve without 2x database memory):
    every leaf of ``state`` that did not pass through into the sharded
    pytree unchanged is deleted, except arrays listed in ``keep`` (by
    identity — e.g. a user-owned corpus the caller handed in). The caller
    must drop its own references to ``state`` — its arrays raise on use
    afterwards.
    """
    if mesh is None:
        mesh = require_mesh("shard_engine")
    shards = mesh.shape[axis]
    n = state.corpus.shape[0]
    payload = get_ops(state.index.kind).shard_payload(state, shards)
    sstate = ShardedEngineState(
        corpus=_pad_dim0(state.corpus, shards), proj=state.proj,
        n_real=jnp.asarray(n, jnp.int32),
        index=Index(state.index.kind, payload))
    specs = engine_state_specs(sstate, axis)
    if not donate:
        return jax.tree.map(
            lambda a, p: jax.device_put(a, NamedSharding(mesh, p)),
            sstate, specs)
    # donation-correct path: a donating jit identity re-lays the tree out,
    # letting XLA reuse or free the input buffers (plain device_put may
    # alias buffers invisibly, so deleting its inputs is unsafe). Backends
    # without donation (CPU) copy instead, so any input leaf the jit left
    # alive — and any dense leaf that never entered it, e.g. codebooks,
    # which the sharded layout replaces with their LUT factorization — is
    # freed explicitly below.
    if keep:
        # never donate a kept (user-owned) array: hand the jit a transient
        # copy instead (freed by the donation itself)
        keep_ids = {id(a) for a in keep}
        sstate = jax.tree.map(
            lambda a: jnp.array(a) if id(a) in keep_ids else a, sstate)
    shardings = jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                             is_leaf=lambda p: isinstance(p, P))
    reshard = jax.jit(lambda t: t, out_shardings=shardings,
                      donate_argnums=0)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        placed = reshard(sstate)
    hold = {id(leaf) for leaf in jax.tree.leaves(placed)}
    hold.update(id(a) for a in keep)
    dense = {id(a): a
             for a in jax.tree.leaves(state) + jax.tree.leaves(sstate)}
    for leaf in dense.values():
        if id(leaf) not in hold and not leaf.is_deleted():
            leaf.delete()
    return placed


def shard_stream(store, frozen, mesh: Optional[Mesh] = None,
                 axis: str = "data") -> ShardedEngineState:
    """Partition a streaming engine's **base** layer over ``mesh``.

    The mutable store's base arrays (capacity-padded row store, posting
    lists, codes) are re-laid out exactly like a read-only engine —
    ``n_real`` becomes the row *capacity*, since allocation/tombstone
    state lives in the replicated ``live`` mask the streaming search
    threads through the local scans. The delta segment, tombstone bitmap,
    and id maps are NOT placed here: they replicate per search call
    (``repro.search.stream.StreamReplica``), which is what lets
    upserts/deletes proceed without touching the sharded base. Never
    donates — the dense store backs the write path.
    """
    # the write programs DONATE the store's buffers, and device_put can
    # return a new Array that still SHARES the input buffer (zero-copy
    # re-placement, e.g. a 1-device mesh) — an upsert would then
    # invalidate the sharded base. The registry's ``stream_base_payload``
    # hands shard_engine genuine copies of every store-derived leaf;
    # frozen quantizers are never donated and may alias freely.
    kind = frozen.quant.kind
    corpus_owned = jnp.array(store.corpus)
    payload = get_ops(kind).stream_base_payload(store, frozen, corpus_owned)
    base = EngineState(corpus=corpus_owned, proj=frozen.proj,
                       index=Index(kind, payload))
    return shard_engine(base, mesh, axis=axis)
