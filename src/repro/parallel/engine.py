"""``shard_engine``: partition a serving ``EngineState`` across a mesh.

The data-parallel layout pass of sharded serving (DESIGN: one shard = one
slice of the database axis):

* **row-major leaves** — corpus rows, flat scan vectors, plain-PQ code rows
  — are padded to a device-count multiple and split along dim 0 (pad rows
  carry global ids >= ``n_real`` and are masked out of every scan);
* **cell-major leaves** — IVF / IVF-PQ posting lists and the
  ``codes_cell``/``bias_cell`` mirrors, plus a ``cell_vectors`` mirror
  built here for IVF-Flat — are padded to per-shard-equal cell counts and
  split along the cell axis (pad cells are all ``-1`` posting rows, never
  probed);
* everything else — MPAD projection, coarse centroids, codebook
  factorizations — replicates, so the coarse probe and per-query LUTs
  compute identically on every shard.

Placement is by ``NamedSharding`` from ``engine_state_specs``; the result
is a ``ShardedEngineState`` ready for ``sharded_search_fn`` /
``SearchEngine.shard()``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.search.ivf import cell_vectors
from repro.search.serve import EngineState, ShardedEngineState
from .context import require_mesh
from .sharding import engine_state_specs

__all__ = ["shard_engine"]


def _pad_dim0(a: Optional[jax.Array], multiple: int, fill=0):
    """Right-pad dim 0 up to a multiple (per-shard-equal blocks)."""
    if a is None:
        return None
    n = a.shape[0]
    pad = (-n) % multiple
    if not pad:
        return a
    widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


def shard_engine(state: EngineState, mesh: Optional[Mesh] = None,
                 axis: str = "data") -> ShardedEngineState:
    """Re-lay-out and place ``state`` for serving over the ``axis`` of
    ``mesh`` (default: the context's active mesh).

    Pure layout — no index rebuild: the same corpus rows, posting lists,
    and codes end up distributed over the mesh devices, so
    ``sharded_search_fn`` returns exactly what ``search_fn`` returns on
    the unsharded state.
    """
    if mesh is None:
        mesh = require_mesh("shard_engine")
    shards = mesh.shape[axis]
    n = state.corpus.shape[0]
    corpus = _pad_dim0(state.corpus, shards)
    # flat stores reduced = corpus when there is no projection; don't ship
    # the same rows twice
    reduced = (None if state.reduced is state.corpus
               else _pad_dim0(state.reduced, shards))
    codes = centroids = lists = cell_vecs = codes_cell = bias_cell = None
    lut_w = cbnorm = None
    if state.pq is not None:
        codes = _pad_dim0(jnp.asarray(state.pq.codes, jnp.int32), shards)
        lut_w, cbnorm = state.pq.lut_w, state.pq.cbnorm
    if state.ivf is not None:
        centroids = state.ivf.centroids
        lists = _pad_dim0(state.ivf.lists, shards, fill=-1)
        cell_vecs = cell_vectors(lists, state.ivf.vectors)
    if state.ivfpq is not None:
        ix = state.ivfpq
        centroids = ix.centroids
        lists = _pad_dim0(ix.lists, shards, fill=-1)
        codes_cell = _pad_dim0(ix.codes_cell, shards)
        bias_cell = _pad_dim0(ix.bias_cell, shards)
        lut_w, cbnorm = ix.lut_w, ix.cbnorm
    sstate = ShardedEngineState(
        corpus=corpus, proj=state.proj,
        n_real=jnp.asarray(n, jnp.int32), reduced=reduced, codes=codes,
        centroids=centroids, lists=lists, cell_vecs=cell_vecs,
        codes_cell=codes_cell, bias_cell=bias_cell,
        lut_w=lut_w, cbnorm=cbnorm)
    specs = engine_state_specs(sstate, axis)
    return jax.tree.map(
        lambda a, p: jax.device_put(a, NamedSharding(mesh, p)),
        sstate, specs)
