"""``shard_engine``: partition a serving ``EngineState`` across a mesh.

The data-parallel layout pass of sharded serving (DESIGN: one shard = one
slice of the database axis):

* **row-major leaves** — corpus rows, flat scan vectors, plain-PQ code rows
  — are padded to a device-count multiple and split along dim 0 (pad rows
  carry global ids >= ``n_real`` and are masked out of every scan);
* **cell-major leaves** — IVF / IVF-PQ posting lists and the
  ``codes_cell``/``bias_cell`` mirrors, plus a ``cell_vectors`` mirror
  built here for IVF-Flat — are padded to per-shard-equal cell counts and
  split along the cell axis (pad cells are all ``-1`` posting rows, never
  probed);
* everything else — MPAD projection, coarse centroids, codebook
  factorizations — replicates, so the coarse probe and per-query LUTs
  compute identically on every shard.

Placement is by ``NamedSharding`` from ``engine_state_specs``; the result
is a ``ShardedEngineState`` ready for ``sharded_search_fn`` /
``SearchEngine.shard()``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.search.ivf import cell_vectors
from repro.search.serve import EngineState, ShardedEngineState
from .context import require_mesh
from .sharding import engine_state_specs

__all__ = ["shard_engine", "shard_stream"]


def _pad_dim0(a: Optional[jax.Array], multiple: int, fill=0):
    """Right-pad dim 0 up to a multiple (per-shard-equal blocks)."""
    if a is None:
        return None
    n = a.shape[0]
    pad = (-n) % multiple
    if not pad:
        return a
    widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


def shard_engine(state: EngineState, mesh: Optional[Mesh] = None,
                 axis: str = "data", donate: bool = False,
                 keep=()) -> ShardedEngineState:
    """Re-lay-out and place ``state`` for serving over the ``axis`` of
    ``mesh`` (default: the context's active mesh).

    Pure layout — no index rebuild: the same corpus rows, posting lists,
    and codes end up distributed over the mesh devices, so
    ``sharded_search_fn`` returns exactly what ``search_fn`` returns on
    the unsharded state.

    ``donate=True`` releases the dense input buffers once the sharded
    copy is placed (build -> shard -> serve without 2x database memory):
    every leaf of ``state`` that did not pass through into the sharded
    pytree unchanged is deleted, except arrays listed in ``keep`` (by
    identity — e.g. a user-owned corpus the caller handed in). The caller
    must drop its own references to ``state`` — its arrays raise on use
    afterwards.
    """
    if mesh is None:
        mesh = require_mesh("shard_engine")
    shards = mesh.shape[axis]
    n = state.corpus.shape[0]
    corpus = _pad_dim0(state.corpus, shards)
    # flat stores reduced = corpus when there is no projection; don't ship
    # the same rows twice
    reduced = (None if state.reduced is state.corpus
               else _pad_dim0(state.reduced, shards))
    codes = centroids = lists = cell_vecs = codes_cell = bias_cell = None
    lut_w = cbnorm = None
    if state.pq is not None:
        codes = _pad_dim0(jnp.asarray(state.pq.codes, jnp.int32), shards)
        lut_w, cbnorm = state.pq.lut_w, state.pq.cbnorm
    if state.ivf is not None:
        centroids = state.ivf.centroids
        lists = _pad_dim0(state.ivf.lists, shards, fill=-1)
        cell_vecs = cell_vectors(lists, state.ivf.vectors)
    if state.ivfpq is not None:
        ix = state.ivfpq
        centroids = ix.centroids
        lists = _pad_dim0(ix.lists, shards, fill=-1)
        codes_cell = _pad_dim0(ix.codes_cell, shards)
        bias_cell = _pad_dim0(ix.bias_cell, shards)
        lut_w, cbnorm = ix.lut_w, ix.cbnorm
    sstate = ShardedEngineState(
        corpus=corpus, proj=state.proj,
        n_real=jnp.asarray(n, jnp.int32), reduced=reduced, codes=codes,
        centroids=centroids, lists=lists, cell_vecs=cell_vecs,
        codes_cell=codes_cell, bias_cell=bias_cell,
        lut_w=lut_w, cbnorm=cbnorm)
    specs = engine_state_specs(sstate, axis)
    if not donate:
        return jax.tree.map(
            lambda a, p: jax.device_put(a, NamedSharding(mesh, p)),
            sstate, specs)
    # donation-correct path: a donating jit identity re-lays the tree out,
    # letting XLA reuse or free the input buffers (plain device_put may
    # alias buffers invisibly, so deleting its inputs is unsafe). Backends
    # without donation (CPU) copy instead, so any input leaf the jit left
    # alive — and any dense leaf that never entered it, e.g. codebooks,
    # which the sharded layout replaces with their LUT factorization — is
    # freed explicitly below.
    if keep:
        # never donate a kept (user-owned) array: hand the jit a transient
        # copy instead (freed by the donation itself)
        keep_ids = {id(a) for a in keep}
        sstate = jax.tree.map(
            lambda a: jnp.array(a) if id(a) in keep_ids else a, sstate)
    shardings = jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                             is_leaf=lambda p: isinstance(p, P))
    reshard = jax.jit(lambda t: t, out_shardings=shardings,
                      donate_argnums=0)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        placed = reshard(sstate)
    hold = {id(leaf) for leaf in jax.tree.leaves(placed)}
    hold.update(id(a) for a in keep)
    dense = {id(a): a
             for a in jax.tree.leaves(state) + jax.tree.leaves(sstate)}
    for leaf in dense.values():
        if id(leaf) not in hold and not leaf.is_deleted():
            leaf.delete()
    return placed


def shard_stream(store, frozen, mesh: Optional[Mesh] = None,
                 axis: str = "data", index: str = "flat"
                 ) -> ShardedEngineState:
    """Partition a streaming engine's **base** layer over ``mesh``.

    The mutable store's base arrays (capacity-padded row store, posting
    lists, codes) are re-laid out exactly like a read-only engine —
    ``n_real`` becomes the row *capacity*, since allocation/tombstone
    state lives in the replicated ``live`` mask the streaming search
    threads through the local scans. The delta segment, tombstone bitmap,
    and id maps are NOT placed here: they replicate per search call
    (``repro.search.stream.StreamReplica``), which is what lets
    upserts/deletes proceed without touching the sharded base. Never
    donates — the dense store backs the write path.
    """
    # the write programs DONATE the store's buffers, and device_put can
    # return a new Array that still SHARES the input buffer (zero-copy
    # re-placement, e.g. a 1-device mesh) — an upsert would then
    # invalidate the sharded base. Hand shard_engine genuine copies of
    # every store-derived leaf; frozen quantizers are never donated and
    # may alias freely.
    def _own(a):
        return None if a is None else jnp.array(a)

    ivf = pq = ivfpq = None
    reduced = None
    if index == "flat":
        reduced = _own(store.reduced)
    elif index == "ivf":
        from repro.search.ivf import IVFIndex
        # vectors need no copy: shard_engine only reads them through
        # cell_vectors(), whose gather materializes fresh buffers
        scan_rows = (store.reduced if store.reduced is not None
                     else store.corpus)
        ivf = IVFIndex(centroids=frozen.centroids, lists=_own(store.lists),
                       vectors=scan_rows)
    elif index == "pq":
        from repro.search.pq import PQIndex
        pq = PQIndex(codebooks=frozen.codebooks, codes=_own(store.codes),
                     lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)
    elif index == "ivfpq":
        from repro.search.ivfpq import IVFPQIndex
        ivfpq = IVFPQIndex(
            centroids=frozen.centroids, lists=_own(store.lists),
            codebooks=frozen.codebooks, codes=_own(store.codes),
            bias=_own(store.bias), codes_cell=_own(store.codes_cell),
            bias_cell=_own(store.bias_cell),
            lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)
    else:
        raise ValueError(f"unknown index kind {index!r}")
    base = EngineState(corpus=_own(store.corpus), proj=frozen.proj,
                       reduced=reduced, ivf=ivf, pq=pq, ivfpq=ivfpq)
    return shard_engine(base, mesh, axis=axis)
