"""PartitionSpec rule sets per model family (DESIGN.md §5).

Axis conventions:
  pod   — outer data parallelism across pods (hierarchical gradient reduce)
  data  — data parallelism within a pod
  model — tensor / expert / vocab / sequence parallelism

Divisibility rules baked in:
  * attention projections are sharded on the FUSED (heads*dh) dim — always a
    multiple of the model-axis size even when head counts (e.g. gemma3's 8)
    are not;
  * vocab is padded to a multiple of 256 (LMConfig.vocab_padded);
  * long KV caches shard their sequence dim over every available axis,
    short (window) caches stay replicated;
  * edge lists / candidate sets are padded by configs to device-count
    multiples (masked in the models).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["dp_axes", "engine_state_specs", "lm_param_specs", "opt_specs",
           "tree_named", "lm_cache_specs", "replicate_like"]


def dp_axes(mesh: Mesh):
    """The data-parallel axis group: ('pod','data') multi-pod, ('data',) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tree_named(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def replicate_like(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


# ------------------------------------------------------------- serving

def engine_state_specs(state, axis: str = "data"):
    """``ShardedEngineState`` -> matching pytree of PartitionSpecs.

    The corpus rows shard along ``axis`` and the reducer params replicate
    (whatever their pytree shape — the reducer kind rides along as pytree
    metadata); the per-kind sharded index payload gets its spec tree from
    the ops registry (``IndexOps.payload_specs`` — row- or cell-sharded
    database leaves, replicated quantizers). Used both as ``shard_map``
    in_specs and for the ``device_put`` placement in ``shard_engine``.
    The registry import is deferred so this module stays importable
    without the search package.
    """
    from repro.search.registry import Index, get_ops
    payload_specs = get_ops(state.index.kind).payload_specs(
        state.index.payload, axis)
    return type(state)(
        corpus=P(axis),
        proj=(None if state.proj is None
              else jax.tree.map(lambda _: P(), state.proj)),
        n_real=P(),
        index=Index(state.index.kind, payload_specs))


# -------------------------------------------------------------------- LM

def _run_specs(moe: bool):
    base = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, None, "model"),
        "wk": P(None, None, "model"),
        "wv": P(None, None, "model"),
        "wo": P(None, "model", None),
    }
    if moe:
        base["moe"] = {
            "router": P(None, None, "model"),
            "w_gate": P(None, "model", None, None),
            "w_up": P(None, "model", None, None),
            "w_down": P(None, "model", None, None),
        }
    else:
        base.update({
            "w_gate": P(None, None, "model"),
            "w_up": P(None, None, "model"),
            "w_down": P(None, "model", None),
        })
    return base


def lm_param_specs(cfg) -> Any:
    """Matches the pytree of transformer.lm_init_params."""
    from repro.models.transformer import layer_runs
    specs = {
        "embed": P("model", None),
        "final_norm": P(None),
        "runs": [_run_specs(cfg.moe is not None) for _ in layer_runs(cfg)],
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model")
    return specs


def opt_specs(param_specs) -> Any:
    """Adam moments shard exactly like their parameters."""
    return {"step": P(),
            "m": jax.tree.map(lambda s: s, param_specs,
                              is_leaf=lambda s: isinstance(s, P)),
            "v": jax.tree.map(lambda s: s, param_specs,
                              is_leaf=lambda s: isinstance(s, P))}


def zero_opt_specs(params_abstract, param_specs, mesh) -> Any:
    """ZeRO-1-style optimizer-state sharding: each Adam moment additionally
    shards its first data-divisible unsharded dim over the DP axes. The
    update all-gathers fresh params over DP (exactly ZeRO-1 traffic) in
    exchange for an (dp_size)x cut of the f32 moment memory."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def moment_spec(leaf, spec):
        if dp_size == 1:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (dim, entry) in enumerate(zip(leaf.shape, entries)):
            if entry is None and dim % dp_size == 0 and dim >= dp_size:
                entries[i] = dp
                return P(*entries)
        return spec

    mom = jax.tree.map(moment_spec, params_abstract, param_specs,
                       is_leaf=lambda s: isinstance(s, P))
    return {"step": P(), "m": mom,
            "v": jax.tree.map(lambda s: s, mom,
                              is_leaf=lambda s: isinstance(s, P))}


def lm_cache_specs(cfg, mesh: Mesh, batch: int, max_len: int) -> Any:
    """Per-run cache specs: shard batch over dp when divisible; shard long
    sequences over 'model' (and over everything for single-stream decode)."""
    from repro.models.transformer import layer_runs
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    model_size = mesh.shape.get("model", 1)
    specs = []
    for kind, _ in layer_runs(cfg):
        s_run = (min(cfg.sliding_window, max_len)
                 if kind == "local" and cfg.sliding_window else max_len)
        if batch % dp_size == 0 and batch >= dp_size:
            b_ax, seq_candidates = dp, ("model",)
        else:
            b_ax, seq_candidates = None, dp + ("model",)
        seq_ax = None
        # shard long sequences; keep short/window caches replicated
        total = 1
        for a in seq_candidates:
            total *= mesh.shape[a]
        if s_run >= 8192 and s_run % total == 0:
            seq_ax = seq_candidates
        elif s_run >= 8192 and s_run % model_size == 0:
            seq_ax = "model"
        specs.append({
            "k": P(None, b_ax, seq_ax, None, None),
            "v": P(None, b_ax, seq_ax, None, None),
            "pos": P(None),
        })
    return specs


# ------------------------------------------------------------------- GNN

def gin_param_specs(params) -> Any:
    # GIN is tiny (64-d hidden): replicate everything.
    return replicate_like(params)


# ---------------------------------------------------------------- recsys

def sasrec_param_specs(params) -> Any:
    sp = replicate_like(params)
    sp["item_emb"] = P("model", None)
    return sp


def dien_param_specs(params) -> Any:
    sp = replicate_like(params)
    sp["item_emb"] = P("model", None)
    sp["cat_emb"] = P("model", None)
    return sp


def autoint_param_specs(params) -> Any:
    sp = replicate_like(params)
    sp["emb"] = P("model", None)
    return sp


def twotower_param_specs(params) -> Any:
    sp = replicate_like(params)
    sp["user_emb"] = P("model", None)
    sp["item_emb"] = P("model", None)
    return sp
