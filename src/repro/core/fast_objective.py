"""Sort-free-threshold / sorted-prefix fast path for the MPAD objective.

Beyond-paper optimization #2 (see DESIGN.md §6): the paper computes mu_b by
materializing all N(N-1)/2 pairwise differences and sorting them
(O(N^2 log N) time, O(N^2) space). For *scalar* projections the same exact
quantity is computable in O(N log N) time and O(N) space:

  1. sort the projections once:              p_sorted, O(N log N)
  2. exclusive prefix sums:                  O(N)
  3. pairs with |p_i - p_j| <= t counted by  searchsorted(p_sorted, p_sorted - t)
  4. the b%-quantile threshold tau_b found by monotone bisection on t
     (~60 iterations, each O(N log N))
  5. value  : sum of selected diffs from prefix sums
     gradient: per-point signed coefficients c_i; grad mu = X^T c / |D_b|

The selection->threshold duality: "smallest b% of pairs" == "pairs with
d_ij <= tau_b" (ties at tau_b handled by an exact correction term).

All functions expect a *unit-norm* ``w`` and return the *tangent-projected*
gradient (the gradient of mu_b(w/||w||) evaluated at ||w||=1), which matches
``jax.grad`` of the normalizing oracle in ``objective.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .objective import num_selected_pairs, orthogonality_penalty

__all__ = [
    "ThresholdStats",
    "threshold_stats",
    "find_quantile_threshold",
    "mu_b_fast_value_and_grad",
    "mu_b_fast",
    "phi_fast_value_and_grad",
]

_BISECT_ITERS = 60


class ThresholdStats(NamedTuple):
    """Statistics of the pair set {(i,j) : |p_i - p_j| <= tau}."""

    count: jax.Array      # int32 scalar: number of such pairs
    sum: jax.Array        # f32 scalar:   sum of |p_i - p_j| over the set
    coeff: jax.Array      # (N,) f32: c_i = #{j: p_j<p_i, within tau} - #{j: p_j>p_i, within tau}
    tau: jax.Array        # the threshold used


def _sorted_prefix(p: jax.Array):
    order = jnp.argsort(p)
    ps = p[order]
    prefix = jnp.concatenate([jnp.zeros((1,), ps.dtype), jnp.cumsum(ps)])
    return ps, prefix, order


_INT32_SAFE_N = 46_340          # n(n-1)/2 < 2^31


def _count_dtype(n: int):
    """Pair counts overflow int32 beyond n~46k; f32 accumulation is exact to
    ~6e-8 relative — far below the b% quantile granularity at that scale."""
    return jnp.int32 if n <= _INT32_SAFE_N else jnp.float32


def _count_below(ps: jax.Array, t: jax.Array) -> jax.Array:
    """#pairs (i<j in sorted order) with ps[j] - ps[i] <= t. O(N log N)."""
    n = ps.shape[0]
    lo = jnp.searchsorted(ps, ps - t, side="left")
    idx = jnp.arange(n)
    return jnp.sum((idx - lo).astype(_count_dtype(n)))


def threshold_stats(p: jax.Array, tau: jax.Array) -> ThresholdStats:
    """Exact count / sum / gradient-coefficients for pairs with d <= tau."""
    n = p.shape[0]
    ps, prefix, order = _sorted_prefix(p)
    idx = jnp.arange(n)
    lo = jnp.searchsorted(ps, ps - tau, side="left")
    hi = jnp.searchsorted(ps, ps + tau, side="right")
    below = idx - lo                  # j < i (sorted) within tau
    above = hi - idx - 1              # j > i (sorted) within tau
    count = jnp.sum(below.astype(_count_dtype(n)))
    # sum over {j<i} of (ps[i] - ps[j]) = below*ps[i] - (prefix[i]-prefix[lo])
    s = jnp.sum(below * ps - (prefix[idx] - prefix[lo]))
    c_sorted = (below - above).astype(p.dtype)
    coeff = jnp.zeros_like(p).at[order].set(c_sorted)
    return ThresholdStats(count=count, sum=s, coeff=coeff, tau=tau)


def find_quantile_threshold(p: jax.Array, k_pairs: int) -> jax.Array:
    """Smallest tau with count(tau) >= k_pairs, by monotone bisection."""
    ps = jnp.sort(p)
    lo0 = jnp.zeros((), p.dtype)
    hi0 = (ps[-1] - ps[0]) + jnp.asarray(1e-12, p.dtype)

    k_cmp = jnp.asarray(k_pairs, _count_dtype(p.shape[0]))

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = _count_below(ps, mid)
        take_hi = cnt >= k_cmp
        return (jnp.where(take_hi, lo, mid), jnp.where(take_hi, mid, hi))

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo0, hi0))
    return hi


@functools.partial(jax.jit, static_argnames=("b",))
def _mu_fast_impl(w: jax.Array, x: jax.Array, *, b: float):
    k_pairs = num_selected_pairs(x.shape[0], b)
    wn = w / jnp.linalg.norm(w)
    p = x @ wn
    tau = find_quantile_threshold(p, k_pairs)
    st = threshold_stats(p, tau)
    cnt = jnp.maximum(st.count, 1)
    # exact tie correction: drop the (count - k) excess pairs, all == tau
    kf = jnp.asarray(k_pairs, p.dtype)          # may exceed int32 range
    excess = cnt.astype(p.dtype) - kf
    value = (st.sum - excess * st.tau) / kf
    g_raw = (x.T @ st.coeff) / cnt.astype(p.dtype)
    g = g_raw - jnp.dot(g_raw, wn) * wn  # tangent projection (chain rule of w/||w||)
    return value, g, st


def mu_b_fast_value_and_grad(w: jax.Array, x: jax.Array, *, b: float):
    value, g, _ = _mu_fast_impl(w, x, b=b)
    return value, g


@jax.custom_vjp
def _mu_custom(w, x, b):
    value, _, _ = _mu_fast_impl(w, x, b=b)
    return value


def _mu_fwd(w, x, b):
    value, g, st = _mu_fast_impl(w, x, b=b)
    wn = w / jnp.linalg.norm(w)
    return value, (g, st.coeff, st.count, wn)


def _mu_bwd(res, ct):
    g, coeff, count, wn = res
    cnt = jnp.maximum(count, 1).astype(g.dtype)
    # d mu / d x_i = (c_i / count) * w_hat   (tangent part wrt x is exact)
    gx = (coeff[:, None] / cnt) * wn[None, :] * ct
    return (g * ct, gx, None)


_mu_custom.defvjp(_mu_fwd, _mu_bwd)


def mu_b_fast(w: jax.Array, x: jax.Array, *, b: float) -> jax.Array:
    """Differentiable fast mu_b (custom VJP; exact value, subgradient)."""
    return _mu_custom(w, x, b)


@functools.partial(jax.jit, static_argnames=("b",))
def phi_fast_value_and_grad(
    w: jax.Array,
    x: jax.Array,
    prev: jax.Array,
    prev_mask: jax.Array,
    *,
    b: float,
    alpha: float,
):
    """Value and tangent gradient of phi = mu_b(w) - alpha*sum_j mask_j (w_j.w)^2.

    ``prev`` is a fixed-size (m, n) buffer of previously selected directions
    with ``prev_mask`` marking valid rows — fixed shapes keep one XLA program
    for the whole greedy loop.
    """
    mu, g_mu, _ = _mu_fast_impl(w, x, b=b)
    wn = w / jnp.linalg.norm(w)
    dots = (prev @ wn) * prev_mask
    pen = alpha * jnp.sum(dots * dots)
    g_pen_raw = 2.0 * alpha * (prev.T @ (dots * prev_mask))
    g_pen = g_pen_raw - jnp.dot(g_pen_raw, wn) * wn
    return mu - pen, g_mu - g_pen
