"""Paper-faithful MPAD objective (Algorithm 1, Section 3.4).

This is the *oracle* implementation: it materializes all N(N-1)/2 pairwise
absolute differences of the scalar projections, selects the smallest b%, and
averages them — exactly as written in the paper. O(N^2) memory, O(N^2 log N)
time. Used as the correctness reference for the fast path
(`fast_objective.py`) and the Pallas kernel (`repro.kernels.mpad_pairwise`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "num_selected_pairs",
    "pairwise_abs_diff",
    "mu_b_exact",
    "mu_b_exact_value_and_grad",
    "orthogonality_penalty",
    "phi_exact",
]


def num_selected_pairs(n_points: int, b: float) -> int:
    """|D_b|: how many of the N(N-1)/2 pairs fall in the smallest b%."""
    total = n_points * (n_points - 1) // 2
    return max(1, int(total * (b / 100.0)))


def pairwise_abs_diff(p: jax.Array) -> jax.Array:
    """All N(N-1)/2 pairwise |p_i - p_j| as a flat vector (upper triangle)."""
    n = p.shape[0]
    diff = jnp.abs(p[:, None] - p[None, :])
    iu, ju = jnp.triu_indices(n, k=1)
    return diff[iu, ju]


@functools.partial(jax.jit, static_argnames=("b",))
def mu_b_exact(w: jax.Array, x: jax.Array, *, b: float) -> jax.Array:
    """mu_b(w): mean of the smallest b% of pairwise |<w, x_i - x_j>|.

    Differentiable through ``lax.top_k`` (gradient flows to the selected
    pairs only, matching the paper's subgradient).
    """
    w = w / jnp.linalg.norm(w)
    p = x @ w
    d = pairwise_abs_diff(p)
    k = num_selected_pairs(x.shape[0], b)
    # smallest-k == top_k of the negated distances
    neg_smallest, _ = jax.lax.top_k(-d, k)
    return -jnp.mean(neg_smallest)


def mu_b_exact_value_and_grad(w: jax.Array, x: jax.Array, *, b: float):
    return jax.value_and_grad(lambda w_: mu_b_exact(w_, x, b=b))(w)


def orthogonality_penalty(w: jax.Array, prev: jax.Array, alpha: float) -> jax.Array:
    """P_orth = alpha * sum_j (w_j . w)^2 over previously chosen rows ``prev``.

    ``prev`` is an (k-1, n) matrix; an empty (0, n) matrix gives zero.
    """
    if prev.shape[0] == 0:
        return jnp.zeros((), dtype=w.dtype)
    dots = prev @ w
    return alpha * jnp.sum(dots * dots)


@functools.partial(jax.jit, static_argnames=("b",))
def phi_exact(w: jax.Array, x: jax.Array, prev: jax.Array, *, b: float, alpha: float):
    """phi(w_k) = mu_b(w_k) - alpha * sum_{j<k} (w_j . w_k)^2 (paper eq., Sec 3.4)."""
    return mu_b_exact(w, x, b=b) - orthogonality_penalty(w, prev, alpha)
