"""Core MPAD (a.k.a. QPAD): quantile-preserving dimension reduction for
nearest-neighbor-preserving vector search. See DESIGN.md."""
from .mpad import MPADConfig, MPADResult, fit_mpad, transform
from .objective import (mu_b_exact, mu_b_exact_value_and_grad, phi_exact,
                        orthogonality_penalty, num_selected_pairs)
from .fast_objective import (mu_b_fast, mu_b_fast_value_and_grad,
                             phi_fast_value_and_grad, find_quantile_threshold,
                             threshold_stats)
from .baselines import (Reducer, fit_pca, fit_random_projection, fit_mds,
                        fit_kpca_rbf, fit_isomap, fit_umap_lite,
                        BASELINE_FITTERS)

__all__ = [
    "MPADConfig", "MPADResult", "fit_mpad", "transform",
    "mu_b_exact", "mu_b_exact_value_and_grad", "phi_exact",
    "orthogonality_penalty", "num_selected_pairs",
    "mu_b_fast", "mu_b_fast_value_and_grad", "phi_fast_value_and_grad",
    "find_quantile_threshold", "threshold_stats",
    "Reducer", "fit_pca", "fit_random_projection", "fit_mds", "fit_kpca_rbf",
    "fit_isomap", "fit_umap_lite", "BASELINE_FITTERS",
]
