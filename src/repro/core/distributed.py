"""Distributed MPAD via ``shard_map`` (DESIGN.md §3.4 / §6.3).

Data layout: ``x`` row-sharded over a 1-D device axis (in the production mesh
the rows axis is the flattened ``(pod, data, model)`` — MPAD has no model
parallelism, every device just owns N/P rows).

Per optimization iteration each device:

  1. computes its local projections       p_loc = X_loc w          (N/P · n FLOPs)
  2. all-gathers the *scalars*            p = all_gather(p_loc)    (4·N bytes)
  3. replicated threshold + statistics    (O(N log N), no comm)
  4. local partial gradient               g_loc = X_locᵀ c_loc     (N/P · n FLOPs)
  5. one psum of an n-vector              (4·n bytes)

Communication per iteration is O(N + n) bytes — all-gathering projections
instead of vectors is what makes the paper's "ideal parallel" model concrete:
a naive data-exchange of X itself would move O(N·n) bytes.

Scale note: at N ≥ 1e8 the replicated O(N) gather is the limit; combine with
``batch_size`` (stochastic MPAD) so each iteration gathers only the
subsample's projections.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import fast_objective
from .mpad import MPADConfig, MPADResult, greedy_fit_loop
from .objective import num_selected_pairs

__all__ = ["fit_mpad_sharded", "make_phi_dist"]


def make_phi_dist(axis_name: str, n_total: int):
    """Distributed phi value-and-grad: same contract as phi_fast_value_and_grad."""

    def phi_dist(w, x_loc, prev, prev_mask, *, b, alpha):
        k_pairs = num_selected_pairs(n_total, b)
        wn = w / jnp.linalg.norm(w)
        p_loc = x_loc @ wn
        p = jax.lax.all_gather(p_loc, axis_name, tiled=True)      # (N,) replicated
        tau = fast_objective.find_quantile_threshold(p, k_pairs)
        st = fast_objective.threshold_stats(p, tau)
        cnt = jnp.maximum(st.count, 1)
        kf = jnp.asarray(k_pairs, p.dtype)      # may exceed int32 range
        excess = cnt.astype(p.dtype) - kf
        mu = (st.sum - excess * st.tau) / kf
        # local slice of the coefficient vector -> local partial gradient
        n_loc = x_loc.shape[0]
        start = jax.lax.axis_index(axis_name) * n_loc
        c_loc = jax.lax.dynamic_slice(st.coeff, (start,), (n_loc,))
        g_raw = jax.lax.psum(x_loc.T @ c_loc, axis_name) / cnt.astype(p.dtype)
        g_mu = g_raw - jnp.dot(g_raw, wn) * wn
        dots = (prev @ wn) * prev_mask
        pen = alpha * jnp.sum(dots * dots)
        g_pen_raw = 2.0 * alpha * (prev.T @ (dots * prev_mask))
        g_pen = g_pen_raw - jnp.dot(g_pen_raw, wn) * wn
        return mu - pen, g_mu - g_pen

    return phi_dist


def fit_mpad_sharded(
    x: jax.Array,
    config: MPADConfig,
    mesh: Mesh,
    *,
    axis_names: Optional[tuple] = None,
    key: Optional[jax.Array] = None,
) -> MPADResult:
    """Fit MPAD with ``x`` row-sharded over all axes of ``mesh``.

    ``axis_names`` defaults to every mesh axis (rows sharded over the full
    device grid). N must divide the total device count evenly — pad upstream.
    """
    if axis_names is None:
        axis_names = tuple(mesh.axis_names)
    x = jnp.asarray(x, jnp.float32)
    n_total, n_dim = x.shape
    n_dev = 1
    for a in axis_names:
        n_dev *= mesh.shape[a]
    if n_total % n_dev:
        raise ValueError(f"N={n_total} must divide device count {n_dev}")
    if key is None:
        key = jax.random.key(config.seed)
    mean = x.mean(axis=0) if config.center else jnp.zeros(n_dim, x.dtype)
    xc = x - mean

    # collapse the (possibly multi-axis) row sharding into one logical axis
    row_spec = P(axis_names)
    phi_vg = make_phi_dist(axis_names, n_total)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(row_spec, P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    def sharded_fit(x_loc, k):
        return greedy_fit_loop(
            x_loc, k, phi_vg,
            m=config.m, b=config.b, alpha=config.alpha, iters=config.iters,
            lr=config.lr, batch_size=None,
            beta1=config.beta1, beta2=config.beta2, adam_eps=config.adam_eps)

    xs = jax.device_put(xc, NamedSharding(mesh, row_spec))
    matrix, traces = jax.jit(sharded_fit)(xs, key)
    return MPADResult(matrix=matrix, mean=mean, objective_trace=traces)
