"""MPAD trainer: greedy direction selection by Riemannian Adam on the sphere.

Implements Algorithm 1 of the paper as a single jitted ``lax.scan`` program:

  for k = 1..m:                      (outer scan, carry = direction buffer)
      w ~ random unit vector
      for t = 1..T:                  (inner scan, carry = (w, adam state))
          phi, g = mu_b(w) - alpha * sum_j (w_j . w)^2   (tangent gradient)
          w <- normalize(w + adam(g))
      append w

Backends:
  * ``fast``   — O(N log N) sorted-threshold path (default; beyond-paper)
  * ``exact``  — paper-faithful O(N^2) oracle via autodiff through top_k
  * ``kernel`` — Pallas tiled pairwise kernel (TPU target; interpret on CPU)

``batch_size`` enables *stochastic MPAD* (paper §6 future work): each inner
iteration evaluates the objective on a fresh uniform row-subsample.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import fast_objective, objective

__all__ = ["MPADConfig", "MPADResult", "fit_mpad", "transform"]


@dataclasses.dataclass(frozen=True)
class MPADConfig:
    m: int                      # target dimension (number of directions)
    b: float = 80.0             # quantile percentage in (0, 100]
    alpha: float = 25.0         # orthogonality penalty factor
    iters: int = 64             # optimization iterations per direction (T)
    lr: float = 0.05
    backend: str = "fast"       # fast | exact | kernel
    seed: int = 0
    center: bool = True
    batch_size: Optional[int] = None   # stochastic MPAD row-subsample
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8

    def __post_init__(self):
        if not (0.0 < self.b <= 100.0):
            raise ValueError(f"b must be in (0, 100], got {self.b}")
        if self.backend not in ("fast", "exact", "kernel"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.m < 1:
            raise ValueError("m must be >= 1")


class MPADResult(NamedTuple):
    matrix: jax.Array            # (m, n) projection matrix, rows unit-norm
    mean: jax.Array              # (n,) centering offset (zeros if center=False)
    objective_trace: jax.Array   # (m, iters) phi value per direction per iter

    def __call__(self, x: jax.Array) -> jax.Array:
        return transform(self, x)


def transform(result, x: jax.Array) -> jax.Array:
    """f(x) = M (x - mean): maps (..., n) -> (..., m)."""
    if isinstance(result, MPADResult):
        matrix, mean = result.matrix, result.mean
    else:
        matrix, mean = result, jnp.zeros(result.shape[1], result.dtype)
    return (x - mean) @ matrix.T


def _phi_exact_value_and_grad(w, x, prev, prev_mask, *, b, alpha):
    """Paper-faithful phi via autodiff (normalizing oracle + masked penalty)."""

    def phi(w_):
        mu = objective.mu_b_exact(w_, x, b=b)
        wn = w_ / jnp.linalg.norm(w_)
        dots = (prev @ wn) * prev_mask
        return mu - alpha * jnp.sum(dots * dots)

    return jax.value_and_grad(phi)(w)


def _get_backend(name: str):
    if name == "fast":
        return fast_objective.phi_fast_value_and_grad
    if name == "exact":
        return _phi_exact_value_and_grad
    if name == "kernel":
        from repro.kernels.mpad_pairwise import ops as kernel_ops

        return kernel_ops.phi_kernel_value_and_grad
    raise ValueError(name)


def greedy_fit_loop(x, key, phi_vg, *, m, b, alpha, iters, lr, batch_size,
                    beta1, beta2, adam_eps):
    """The greedy direction loop of Algorithm 1, parameterized on the
    objective backend ``phi_vg(w, x, prev, prev_mask, b=, alpha=)``.

    Pure function of its inputs — callers jit it (and may run it inside
    ``shard_map`` with a collective-aware ``phi_vg``; see ``distributed.py``).
    """
    n_points, n_dim = x.shape

    def direction_step(carry, k):
        mbuf, mask = carry
        wkey = jax.random.fold_in(key, k)
        w0 = jax.random.normal(wkey, (n_dim,), x.dtype)
        w0 = w0 / jnp.linalg.norm(w0)

        def adam_iter(state, t):
            w, mom, vel = state
            if batch_size is not None and batch_size < n_points:
                bkey = jax.random.fold_in(wkey, t + 1)
                rows = jax.random.choice(
                    bkey, n_points, (batch_size,), replace=False)
                xb = x[rows]
            else:
                xb = x
            phi, g = phi_vg(w, xb, mbuf, mask, b=b, alpha=alpha)
            mom = beta1 * mom + (1.0 - beta1) * g
            vel = beta2 * vel + (1.0 - beta2) * g * g
            t1 = (t + 1).astype(x.dtype)
            mhat = mom / (1.0 - beta1 ** t1)
            vhat = vel / (1.0 - beta2 ** t1)
            w = w + lr * mhat / (jnp.sqrt(vhat) + adam_eps)   # ascent
            w = w / jnp.linalg.norm(w)
            return (w, mom, vel), phi

        zeros = jnp.zeros((n_dim,), x.dtype)
        (w, _, _), trace = jax.lax.scan(
            adam_iter, (w0, zeros, zeros), jnp.arange(iters))
        mbuf = mbuf.at[k].set(w)
        mask = mask.at[k].set(1.0)
        return (mbuf, mask), trace

    mbuf0 = jnp.zeros((m, n_dim), x.dtype)
    mask0 = jnp.zeros((m,), x.dtype)
    (mbuf, _), traces = jax.lax.scan(
        direction_step, (mbuf0, mask0), jnp.arange(m))
    return mbuf, traces


@functools.partial(
    jax.jit,
    static_argnames=("m", "b", "alpha", "iters", "lr", "backend", "batch_size",
                     "beta1", "beta2", "adam_eps"),
)
def _fit(x, key, *, m, b, alpha, iters, lr, backend, batch_size, beta1, beta2,
         adam_eps):
    phi_vg = _get_backend(backend)
    return greedy_fit_loop(
        x, key, phi_vg, m=m, b=b, alpha=alpha, iters=iters, lr=lr,
        batch_size=batch_size, beta1=beta1, beta2=beta2, adam_eps=adam_eps)


def fit_mpad(x: jax.Array, config: MPADConfig,
             key: Optional[jax.Array] = None) -> MPADResult:
    """Fit the MPAD projection on data ``x`` of shape (N, n)."""
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"x must be (N, n), got {x.shape}")
    if config.m > x.shape[1]:
        raise ValueError(f"m={config.m} exceeds input dim {x.shape[1]}")
    if key is None:
        key = jax.random.key(config.seed)
    mean = x.mean(axis=0) if config.center else jnp.zeros(x.shape[1], x.dtype)
    xc = x - mean
    matrix, traces = _fit(
        xc, key,
        m=config.m, b=config.b, alpha=config.alpha, iters=config.iters,
        lr=config.lr, backend=config.backend, batch_size=config.batch_size,
        beta1=config.beta1, beta2=config.beta2, adam_eps=config.adam_eps)
    return MPADResult(matrix=matrix, mean=mean, objective_trace=traces)
