"""Baseline DR methods the paper compares against, re-implemented in JAX.

The paper evaluates MPAD against UMAP, Isomap, Kernel PCA and classical MDS
(with a linear-regression out-of-sample extension), plus PCA / random
projections as the classical references. No sklearn/umap-learn offline, so
each is built here from the primary sources:

  * PCA                 — Pearson 1901 / Jolliffe 2002 (SVD of centered X)
  * Random projection   — Achlioptas 2003 (gaussian + sparse ±1 variants)
  * Classical MDS       — Torgerson double-centering; out-of-sample via
                          ridge linear regression (paper refs [10, 45])
  * Kernel PCA (RBF)    — Schölkopf 1998; centered-kernel eigendecomposition;
                          optional Nyström landmark approximation for scale
  * Isomap              — Tenenbaum 2000: k-NN graph + min-plus geodesics +
                          MDS; landmark (de Silva–Tenenbaum) out-of-sample
  * UMAP-lite           — McInnes 2018: fuzzy k-NN graph + attraction /
                          negative-sampling repulsion SGD; OOS = fuzzy-
                          weighted average of neighbor embeddings

Every ``fit_*`` returns a :class:`Reducer` with a ``transform`` usable on
out-of-sample points — the paper's evaluation protocol (Table 2) requires it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Reducer", "fit_pca", "fit_random_projection", "fit_mds", "fit_kpca_rbf",
    "fit_isomap", "fit_umap_lite", "BASELINE_FITTERS",
]


@dataclasses.dataclass(frozen=True)
class Reducer:
    """A fitted DR method: callable transform + (for the affine methods)
    the raw fitted arrays.

    ``params`` carries the affine map in the engine's canonical
    ``(matrix (m, D), mean (D,))`` layout when the method is linear
    (PCA / random projection / MDS), which is what lets the serving
    registry (``repro.search.reducers``) wire these fits straight into
    the index pipeline instead of re-deriving them from the closure.
    Nonlinear methods leave it ``None``.
    """
    name: str
    transform: Callable[[jax.Array], jax.Array]
    params: Any = None

    def __call__(self, x):
        return self.transform(x)


# ---------------------------------------------------------------- PCA

def fit_pca(x: jax.Array, m: int) -> Reducer:
    x = jnp.asarray(x, jnp.float32)
    mean = x.mean(axis=0)
    xc = x - mean
    _, _, vt = jnp.linalg.svd(xc, full_matrices=False)
    comps = vt[:m]                                   # (m, n)
    return Reducer("pca",
                   lambda y: (jnp.asarray(y, jnp.float32) - mean) @ comps.T,
                   params=(comps, mean))


# ------------------------------------------------- Random projection

def fit_random_projection(key: jax.Array, n: int, m: int,
                          kind: str = "gaussian") -> Reducer:
    if kind == "gaussian":
        mat = jax.random.normal(key, (n, m)) / jnp.sqrt(m)
    elif kind == "achlioptas":                       # sparse ±sqrt(3), 2/3 zeros
        u = jax.random.uniform(key, (n, m))
        mat = jnp.where(u < 1 / 6, jnp.sqrt(3.0),
                        jnp.where(u < 1 / 3, -jnp.sqrt(3.0), 0.0)) / jnp.sqrt(m)
    else:
        raise ValueError(kind)
    return Reducer(f"rp_{kind}", lambda y: jnp.asarray(y, jnp.float32) @ mat,
                   params=(mat.T, jnp.zeros((n,), mat.dtype)))


# --------------------------------------------------- Classical MDS

def _sq_dists(a: jax.Array, b: jax.Array) -> jax.Array:
    aa = jnp.sum(a * a, axis=1)[:, None]
    bb = jnp.sum(b * b, axis=1)[None, :]
    return jnp.maximum(aa + bb - 2.0 * (a @ b.T), 0.0)


def _classical_mds_embed(d2: jax.Array, m: int):
    """Torgerson: B = -1/2 H D^2 H; coords = V sqrt(lambda). Returns (Y, V, lam)."""
    n = d2.shape[0]
    h = jnp.eye(n) - jnp.full((n, n), 1.0 / n)
    b = -0.5 * h @ d2 @ h
    lam, v = jnp.linalg.eigh(b)                      # ascending
    lam, v = lam[::-1][:m], v[:, ::-1][:, :m]
    lam = jnp.maximum(lam, 1e-9)
    return v * jnp.sqrt(lam)[None, :], v, lam


def fit_mds(x: jax.Array, m: int, ridge: float = 1e-4) -> Reducer:
    """Classical MDS + ridge-regression out-of-sample map (paper protocol)."""
    x = jnp.asarray(x, jnp.float32)
    mean = x.mean(axis=0)
    xc = x - mean
    y, _, _ = _classical_mds_embed(_sq_dists(x, x), m)
    # linear map W: argmin ||Xc W - Y||^2 + ridge||W||^2
    n_dim = xc.shape[1]
    w = jnp.linalg.solve(xc.T @ xc + ridge * jnp.eye(n_dim), xc.T @ y)
    return Reducer("mds", lambda q: (jnp.asarray(q, jnp.float32) - mean) @ w,
                   params=(w.T, mean))


# ------------------------------------------------- Kernel PCA (RBF)

def _median_heuristic_gamma(x: jax.Array) -> jax.Array:
    d2 = _sq_dists(x, x)
    n = x.shape[0]
    off = d2[jnp.triu_indices(n, k=1)]
    med = jnp.median(off)
    return 1.0 / jnp.maximum(med, 1e-9)


def fit_kpca_rbf(x: jax.Array, m: int, gamma: Optional[float] = None,
                 landmarks: Optional[int] = None,
                 key: Optional[jax.Array] = None) -> Reducer:
    """RBF Kernel PCA with centered-kernel OOS; Nyström if ``landmarks`` set."""
    x = jnp.asarray(x, jnp.float32)
    if landmarks is not None and landmarks < x.shape[0]:
        if key is None:
            key = jax.random.key(0)
        idx = jax.random.choice(key, x.shape[0], (landmarks,), replace=False)
        x = x[idx]                                   # Nyström: fit on landmark set
    g = _median_heuristic_gamma(x) if gamma is None else jnp.asarray(gamma)
    k = jnp.exp(-g * _sq_dists(x, x))
    n = x.shape[0]
    one = jnp.full((n, n), 1.0 / n)
    kc = k - one @ k - k @ one + one @ k @ one
    lam, v = jnp.linalg.eigh(kc)
    lam, v = lam[::-1][:m], v[:, ::-1][:, :m]
    lam = jnp.maximum(lam, 1e-9)
    alphas = v / jnp.sqrt(lam)[None, :]              # (n, m)
    k_row_mean = k.mean(axis=0)                      # (n,)
    k_all_mean = k.mean()

    def transform(q):
        q = jnp.asarray(q, jnp.float32)
        kq = jnp.exp(-g * _sq_dists(q, x))           # (d, n)
        kq_c = (kq - kq.mean(axis=1, keepdims=True)
                - k_row_mean[None, :] + k_all_mean)
        return kq_c @ alphas

    return Reducer("kpca", transform)


# ----------------------------------------------------------- Isomap

def _minplus_geodesics(d: jax.Array, iters: int) -> jax.Array:
    """All-pairs shortest paths by iterated min-plus squaring of (N,N) dists."""

    def body(g, _):
        # g2[i,j] = min_k g[i,k] + g[k,j] — one-hop relaxation doubling
        g2 = jnp.min(g[:, :, None] + g[None, :, :], axis=1)
        return jnp.minimum(g, g2), None

    g, _ = jax.lax.scan(body, d, None, length=iters)
    return g


def fit_isomap(x: jax.Array, m: int, k: int = 10) -> Reducer:
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    d = jnp.sqrt(_sq_dists(x, x))
    # symmetric k-NN graph: keep edge if either endpoint ranks it in top-k
    kth = jnp.sort(d, axis=1)[:, k]                  # k-th neighbor (excl. self at 0)
    adj = (d <= kth[:, None]) | (d <= kth[None, :])
    big = jnp.asarray(1e9, d.dtype)
    graph = jnp.where(adj, d, big)
    graph = jnp.where(jnp.eye(n, dtype=bool), 0.0, graph)
    iters = max(1, int(jnp.ceil(jnp.log2(n))))
    geo = _minplus_geodesics(graph, iters)
    # disconnected components: cap at 1.5 x max finite geodesic
    finite = geo < big / 2
    gmax = jnp.max(jnp.where(finite, geo, 0.0))
    geo = jnp.where(finite, geo, 1.5 * gmax)
    y, v, lam = _classical_mds_embed(geo * geo, m)
    col_mean = jnp.mean(geo * geo, axis=0)           # (n,)
    lhalf_pinv = v / jnp.sqrt(lam)[None, :]          # (n, m): 1/sqrt(l) * v

    def transform(q):
        q = jnp.asarray(q, jnp.float32)
        dq = jnp.sqrt(_sq_dists(q, x))               # (d, n)
        # approx geodesic from test point: hop through its k nearest anchors
        knn_d, knn_i = jax.lax.top_k(-dq, k)
        hop = (-knn_d)[:, :, None] + geo[knn_i]      # (d, k, n)
        geo_q = jnp.min(hop, axis=1)
        # landmark-MDS triangulation (de Silva & Tenenbaum)
        return 0.5 * (col_mean[None, :] - geo_q ** 2) @ lhalf_pinv

    return Reducer("isomap", transform)


# -------------------------------------------------------- UMAP-lite

_UMAP_A, _UMAP_B = 1.576943, 0.8950609   # min_dist=0.1 curve fit (umap-learn)


def fit_umap_lite(x: jax.Array, m: int, k: int = 15, epochs: int = 150,
                  key: Optional[jax.Array] = None, lr: float = 1.0,
                  n_neg: int = 5) -> Reducer:
    """Reduced-fidelity UMAP: fuzzy graph + SGD, vectorized over all edges."""
    x = jnp.asarray(x, jnp.float32)
    if key is None:
        key = jax.random.key(0)
    n = x.shape[0]
    d = jnp.sqrt(_sq_dists(x, x))
    d = d + jnp.eye(n) * 1e9
    knn_negd, knn_i = jax.lax.top_k(-d, k)           # (n, k)
    knn_d = -knn_negd
    rho = knn_d[:, 0:1]
    # binary search sigma_i: sum_j exp(-(d_ij - rho_i)/sigma_i) = log2(k)
    target = jnp.log2(jnp.asarray(float(k)))

    def sigma_body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        s = jnp.sum(jnp.exp(-jnp.maximum(knn_d - rho, 0.0) / mid[:, None]), axis=1)
        too_big = s > target
        return jnp.where(too_big, lo, mid), jnp.where(too_big, mid, hi)

    lo0 = jnp.full((n,), 1e-4)
    hi0 = jnp.full((n,), 1e3)
    _, sigma = jax.lax.fori_loop(0, 40, sigma_body, (lo0, hi0))
    w_knn = jnp.exp(-jnp.maximum(knn_d - rho, 0.0) / sigma[:, None])   # (n, k)
    # symmetrize into a dense fuzzy graph (N small in paper's protocol)
    wdense = jnp.zeros((n, n)).at[jnp.arange(n)[:, None], knn_i].set(w_knn)
    wsym = wdense + wdense.T - wdense * wdense.T
    src, dst = jnp.nonzero(wsym > 1e-3, size=n * k * 2, fill_value=0)
    ew = wsym[src, dst]
    # PCA init, small scale
    init = fit_pca(x, m).transform(x)
    emb0 = 1e-2 * init / (jnp.std(init) + 1e-9)
    a, b = _UMAP_A, _UMAP_B

    def epoch(emb, ek):
        alpha = ek[0]
        kk = ek[1].astype(jnp.uint32)
        e = emb[src] - emb[dst]
        d2 = jnp.maximum(jnp.sum(e * e, axis=1, keepdims=True), 1e-8)
        grad_coef = (-2.0 * a * b * d2 ** (b - 1.0)) / (1.0 + a * d2 ** b)
        att = jnp.clip(grad_coef * e, -4.0, 4.0) * ew[:, None]
        emb = emb.at[src].add(alpha * att)
        emb = emb.at[dst].add(-alpha * att)
        negk = jax.random.fold_in(key, kk)
        for t in range(n_neg):
            neg = jax.random.randint(jax.random.fold_in(negk, t), src.shape, 0, n)
            e = emb[src] - emb[neg]
            d2 = jnp.maximum(jnp.sum(e * e, axis=1, keepdims=True), 1e-8)
            rep = (2.0 * b) / ((1e-3 + d2) * (1.0 + a * d2 ** b))
            emb = emb.at[src].add(alpha * jnp.clip(rep * e, -4.0, 4.0) * ew[:, None])
        return emb, None

    alphas = lr * (1.0 - jnp.arange(epochs) / epochs)
    eks = jnp.stack([alphas, jnp.arange(epochs, dtype=jnp.float32)], axis=1)
    emb, _ = jax.lax.scan(epoch, emb0, eks)

    def transform(q):
        q = jnp.asarray(q, jnp.float32)
        dq = jnp.sqrt(_sq_dists(q, x))
        nb_negd, nb_i = jax.lax.top_k(-dq, k)
        wq = jnp.exp(-jnp.maximum(-nb_negd - (-nb_negd[:, 0:1]), 0.0))
        wq = wq / jnp.sum(wq, axis=1, keepdims=True)
        return jnp.einsum("dk,dkm->dm", wq, emb[nb_i])

    return Reducer("umap", transform)


# Registry used by the benchmark harness (name -> fit(x, m, key) -> Reducer)
BASELINE_FITTERS = {
    "pca": lambda x, m, key: fit_pca(x, m),
    "rp": lambda x, m, key: fit_random_projection(key, x.shape[1], m),
    "mds": lambda x, m, key: fit_mds(x, m),
    "kpca": lambda x, m, key: fit_kpca_rbf(x, m),
    "isomap": lambda x, m, key: fit_isomap(x, m),
    "umap": lambda x, m, key: fit_umap_lite(x, m, key=key),
}
