from .synthetic import (make_fasttext_like, make_isolet_like,
                        make_arcene_like, make_pbmc3k_like, PAPER_DATASETS,
                        make_clustered)
from .pipeline import lm_token_batches, deterministic_shard
from .graph import make_random_graph, sample_neighborhood_batch

__all__ = ["make_fasttext_like", "make_isolet_like", "make_arcene_like",
           "make_pbmc3k_like", "PAPER_DATASETS", "make_clustered",
           "lm_token_batches", "deterministic_shard",
           "make_random_graph", "sample_neighborhood_batch"]
