"""Synthetic stand-ins for the paper's four evaluation datasets (Table 4).

The container is offline, so each generator is shape-matched to the original
and reproduces the structural property that makes neighbor-preserving DR
non-trivial: **heavy-tailed nuisance dimensions**. Real embedding data has
high-variance directions that carry little neighborhood information —
frequency effects in word vectors (Mu & Viswanath 2018), rare large peaks in
mass-spectrometry, dropout + bursty expression in scRNA-seq. Variance-driven
DR (PCA/MDS) spends its budget there; the paper's quantile objective is
robust to them (a sparse-spike dimension has huge variance but near-zero
lower-quantile pairwise gaps). Every generator therefore produces:

  * an informative mixture subspace (moderate variance, carries the k-NN
    structure), plus
  * heavy-tailed nuisance dims (sparse spikes: higher per-dim variance, no
    neighbor information).

Every generator returns (train (N, n), test (d, n)) float32, seeded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_fasttext_like", "make_isolet_like", "make_arcene_like",
           "make_pbmc3k_like", "PAPER_DATASETS", "make_clustered",
           "make_informative_plus_spikes"]


def _split(key, n):
    return jax.random.split(key, n)


def make_clustered(key, n_train, n_test, dim, n_clusters=16, spread=0.35,
                   center_scale=1.0):
    """Generic gaussian-mixture workhorse used by tests and examples."""
    kc, kl, kn, kl2, kn2 = _split(key, 5)
    centers = jax.random.normal(kc, (n_clusters, dim)) * center_scale
    lab = jax.random.randint(kl, (n_train,), 0, n_clusters)
    xtr = centers[lab] + spread * jax.random.normal(kn, (n_train, dim))
    lab2 = jax.random.randint(kl2, (n_test,), 0, n_clusters)
    xte = centers[lab2] + spread * jax.random.normal(kn2, (n_test, dim))
    return xtr.astype(jnp.float32), xte.astype(jnp.float32)


def make_informative_plus_spikes(key, n, d_inf, d_spike, *, n_clusters=32,
                                 spread=0.35, spike_prob=0.03,
                                 spike_scale=8.0, floor=0.02,
                                 center_scale=1.0, nonneg=False):
    """Informative cluster subspace + heavy-tailed sparse-spike nuisance."""
    ks = _split(key, 6)
    centers = jax.random.normal(ks[0], (n_clusters, d_inf)) * center_scale
    lab = jax.random.randint(ks[1], (n,), 0, n_clusters)
    inf = centers[lab] + spread * jax.random.normal(ks[2], (n, d_inf))
    mask = jax.random.uniform(ks[3], (n, d_spike)) < spike_prob
    spikes = jnp.where(
        mask, jax.random.normal(ks[4], (n, d_spike)) * spike_scale,
        floor * jax.random.normal(ks[5], (n, d_spike)))
    if nonneg:
        inf, spikes = jax.nn.relu(inf), jnp.abs(spikes)
    return jnp.concatenate([inf, spikes], axis=1).astype(jnp.float32)


def make_fasttext_like(key, n_train=2000, n_test=600, dim=300):
    """300-d word-vector-ish: 64 semantic clusters in a 60-d informative
    subspace + 240 heavy-tailed 'frequency' dims."""
    k1, k2 = _split(key, 2)
    d_inf = 60
    mk = lambda kk, n: make_informative_plus_spikes(
        kk, n, d_inf, dim - d_inf, n_clusters=64, spread=0.35,
        spike_prob=0.03, spike_scale=8.0)
    return mk(k1, n_train), mk(k2, n_test)


def make_isolet_like(key, n_train=2000, n_test=600, dim=617):
    """617-d spoken-letter features: 26 classes, smooth correlated
    informative block + bursty noise bands."""
    k1, k2 = _split(key, 2)
    d_inf = 120

    def mk(kk, n):
        x = make_informative_plus_spikes(
            kk, n, d_inf, dim - d_inf, n_clusters=26, spread=0.45,
            spike_prob=0.05, spike_scale=6.0)
        kern = jnp.exp(-0.5 * (jnp.arange(-5, 6) / 2.0) ** 2)
        kern = kern / kern.sum()
        return jax.vmap(lambda r: jnp.convolve(r, kern, mode="same"))(x)

    return mk(k1, n_train).astype(jnp.float32), \
        mk(k2, n_test).astype(jnp.float32)


def make_arcene_like(key, n_train=700, n_test=297, dim=10000):
    """10000-d mass-spectrometry: 2 classes on a 400-d informative block of
    non-negative peaks; the rest are NIPS'03-style 'probe' dims with rare
    large peaks (sparse, heavy-tailed, non-negative)."""
    k1, k2 = _split(key, 2)
    d_inf = 2000        # informative peaks spread broadly (survives the
    # paper's 200-dim column subsampling protocol)
    mk = lambda kk, n: make_informative_plus_spikes(
        kk, n, d_inf, dim - d_inf, n_clusters=2, spread=0.5,
        spike_prob=0.01, spike_scale=10.0, nonneg=True, center_scale=1.5)
    return mk(k1, n_train), mk(k2, n_test)


def make_pbmc3k_like(key, n_train=2038, n_test=600, dim=1838):
    """1838-gene scRNA-seq: 8 cell types in a moderate informative block;
    nuisance genes are dropout-dominated with bursty expression (log1p of
    poisson bursts), i.e. naturally sparse-spiked."""
    k1, k2 = _split(key, 2)
    d_inf = 200

    def mk(kk, n):
        ks = _split(kk, 4)
        x = make_informative_plus_spikes(
            ks[0], n, d_inf, dim - d_inf, n_clusters=8, spread=0.4,
            spike_prob=0.02, spike_scale=7.0)
        # library-size multiplicative noise + per-gene standardization
        lib = jnp.exp(0.2 * jax.random.normal(ks[1], (n, 1)))
        x = x * lib
        return (x - x.mean(0)) / (x.std(0) + 1e-6)

    return mk(k1, n_train).astype(jnp.float32), \
        mk(k2, n_test).astype(jnp.float32)


# name -> (generator, paper sample dim, paper test size); the benchmark
# harness subsamples dims/points to the paper's Table 4 protocol.
PAPER_DATASETS = {
    "fasttext": (make_fasttext_like, 300, 600),
    "isolet": (make_isolet_like, 200, 600),
    "arcene": (make_arcene_like, 200, 297),
    "pbmc3k": (make_pbmc3k_like, 200, 600),
}
