"""Graph generation + the layered neighbor sampler for sampled GNN training.

The sampler produces *gathered feature* batches (feat_l0..feat_lD) — the
host-side sampler / device-side compute split used by real distributed GNN
systems: devices never hold the full graph, only fixed-shape fanout tensors.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["make_random_graph", "sample_neighborhood_batch"]


def make_random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                      n_classes: int = 8):
    """Power-law-ish random graph as (feats, src, dst, labels) numpy arrays."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavored endpoints: degree ~ power law
    w = 1.0 / np.arange(1, n_nodes + 1) ** 0.5
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w)
    dst = rng.integers(0, n_nodes, size=n_edges)
    # community-structured features: label-dependent mean
    labels = rng.integers(0, n_classes, size=n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = (centers[labels] +
             0.5 * rng.normal(size=(n_nodes, d_feat))).astype(np.float32)
    return feats, src.astype(np.int32), dst.astype(np.int32), \
        labels.astype(np.int32)


def _build_csr(src, dst, n_nodes):
    order = np.argsort(dst, kind="stable")
    s_sorted = src[order]
    counts = np.bincount(dst, minlength=n_nodes)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    return s_sorted, offsets


def sample_neighborhood_batch(seed: int, feats, src, dst, labels,
                              batch_nodes: int, fanout: Tuple[int, ...]):
    """Uniform fanout sampling -> {feat_l0..feat_lD, labels} fixed shapes.

    feat_ld has shape (batch, f_1, ..., f_d, F); missing neighbors are
    sampled with replacement (standard GraphSAGE practice).
    """
    rng = np.random.default_rng(seed)
    n_nodes = feats.shape[0]
    in_src, offsets = _build_csr(src, dst, n_nodes)
    seeds = rng.integers(0, n_nodes, size=batch_nodes).astype(np.int32)

    def sample_neighbors(nodes, fan):
        flat = nodes.reshape(-1)
        out = np.empty((flat.shape[0], fan), np.int32)
        for i, v in enumerate(flat):
            lo, hi = offsets[v], offsets[v + 1]
            if hi > lo:
                out[i] = in_src[rng.integers(lo, hi, size=fan)]
            else:
                out[i] = v                      # isolated: self-loop
        return out.reshape(nodes.shape + (fan,))

    levels = [seeds]
    for fan in fanout:
        levels.append(sample_neighbors(levels[-1], fan))
    batch = {f"feat_l{d}": feats[lvl] for d, lvl in enumerate(levels)}
    batch["labels"] = labels[seeds]
    return batch
