"""Deterministic, restart-safe data pipeline.

Every batch is a pure function of (seed, step, shard) — no dispatcher state.
This is the straggler/fault story (DESIGN.md §5): a replaced host recomputes
exactly its shard for any step without coordination, and resuming from a
checkpoint at step k replays the identical stream from k.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["lm_token_batches", "deterministic_shard", "recsys_ranking_batch",
           "twotower_batch"]


def deterministic_shard(seed: int, step: int, shard: int) -> jax.Array:
    """The per-(step, shard) PRNG key — the whole coordination protocol."""
    return jax.random.fold_in(jax.random.fold_in(
        jax.random.key(seed), step), shard)


def lm_token_batches(seed: int, batch: int, seq: int, vocab: int,
                     shard: int = 0, n_steps: int | None = None
                     ) -> Iterator[dict]:
    """Zipf-ish synthetic token stream; yields {tokens, labels} (B, S)."""
    ranks = np.arange(1, vocab + 1)
    probs = (1.0 / ranks ** 1.1)
    probs /= probs.sum()
    step = 0
    while n_steps is None or step < n_steps:
        key = deterministic_shard(seed, step, shard)
        toks = jax.random.choice(key, vocab, (batch, seq + 1),
                                 p=jnp.asarray(probs))
        yield {"tokens": toks[:, :-1].astype(jnp.int32),
               "labels": toks[:, 1:].astype(jnp.int32)}
        step += 1


def recsys_ranking_batch(key, batch: int, seq_len: int, n_items: int,
                         n_cats: int = 1000) -> dict:
    ks = jax.random.split(key, 7)
    return {
        "hist_items": jax.random.randint(ks[0], (batch, seq_len), 0, n_items),
        "hist_cats": jax.random.randint(ks[1], (batch, seq_len), 0, n_cats),
        "target_item": jax.random.randint(ks[2], (batch,), 0, n_items),
        "target_cat": jax.random.randint(ks[3], (batch,), 0, n_cats),
        "neg_items": jax.random.randint(ks[4], (batch, seq_len), 0, n_items),
        "neg_cats": jax.random.randint(ks[5], (batch, seq_len), 0, n_cats),
        "label": (jax.random.uniform(ks[6], (batch,)) > 0.5).astype(
            jnp.float32),
    }


def twotower_batch(key, batch: int, n_users: int, n_items: int,
                   n_hist: int, n_neg: int) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "user_ids": jax.random.randint(ks[0], (batch,), 0, n_users),
        "hist_ids": jax.random.randint(ks[1], (batch, n_hist), 0, n_items),
        "pos_items": jax.random.randint(ks[2], (batch,), 0, n_items),
        "neg_items": jax.random.randint(ks[3], (n_neg,), 0, n_items),
        "neg_logq": jnp.full((n_neg,), -float(np.log(n_items))),
    }
