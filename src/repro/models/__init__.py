"""Model zoo: decoder-only LMs (dense + MoE), GIN GNN, recsys rankers and
two-tower retrieval — the assigned architecture families (DESIGN.md §4)."""
