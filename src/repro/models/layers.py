"""Shared transformer layers: RMSNorm, RoPE, chunked (flash-style) attention
with GQA + sliding-window support, SwiGLU MLP.

Attention never materializes the full (Sq x Skv) score matrix: it runs an
online-softmax scan over KV chunks (and over Q chunks when Sq is long) — the
TPU-native equivalent of FlashAttention expressed in pure JAX so that XLA
keeps the working set at (q_chunk x kv_chunk) per step (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "chunked_attention", "swiglu", "he_init"]

_NEG_INF = -1e30


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, dh), positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP: down(silu(x@gate) * (x@up))."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def _attn_one_q_chunk(qc, k, v, q_pos_c, kv_pos, window, kv_chunk, scale):
    """Online-softmax over KV chunks for one query chunk.

    qc: (B, Tq, KV, G, dh); k, v: (B, Skv, KV, dh);
    q_pos_c: (Tq,), kv_pos: (Skv,) with -1 marking unwritten cache slots.
    """
    b, tq, kvh, g, dh = qc.shape
    skv = k.shape[1]
    n_kv_chunks = skv // kv_chunk
    kb = k.reshape(b, n_kv_chunks, kv_chunk, kvh, dh)
    vb = v.reshape(b, n_kv_chunks, kv_chunk, kvh, dh)
    kvpb = kv_pos.reshape(n_kv_chunks, kv_chunk)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, kpc = inp                                  # (B,C,KV,dh) etc.
        s = jnp.einsum("bqkgd,bckd->bqkgc", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale     # (B,Tq,KV,G,C)
        ok = (kpc[None, :] <= q_pos_c[:, None]) & (kpc[None, :] >= 0)
        if window is not None:
            ok &= (q_pos_c[:, None] - kpc[None, :]) < window
        s = jnp.where(ok[None, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vc.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, tq, kvh, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, tq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, tq, kvh, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kvpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out                                             # (B,Tq,KV,G,dh) f32


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_pos: jax.Array, kv_pos: jax.Array,
    *, window: Optional[int] = None,
    q_chunk: int = 512, kv_chunk: int = 1024,
) -> jax.Array:
    """Causal GQA attention with bounded working set.

    q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh); H = KV * G.
    q_pos (Sq,), kv_pos (Skv,): absolute token positions (-1 = invalid slot).
    Causality (kv_pos <= q_pos) and the optional sliding ``window`` are
    enforced via positions, which uniformly covers train / prefill / decode
    with ring-buffer caches.
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kvh, g, dh)
    kv_chunk = min(kv_chunk, k.shape[1])
    if k.shape[1] % kv_chunk:
        kv_chunk = math.gcd(kv_chunk, k.shape[1])
    if sq <= q_chunk:
        out = _attn_one_q_chunk(qg, k, v, q_pos, kv_pos, window, kv_chunk, scale)
        return out.reshape(b, sq, h, dh).astype(q.dtype)
    if sq % q_chunk:
        q_chunk = math.gcd(q_chunk, sq)
    nq = sq // q_chunk
    qb = qg.reshape(b, nq, q_chunk, kvh, g, dh).swapaxes(0, 1)
    qpb = q_pos.reshape(nq, q_chunk)

    def outer(_, inp):
        qc, qpc = inp
        o = _attn_one_q_chunk(qc, k, v, qpc, kv_pos, window, kv_chunk, scale)
        return None, o

    _, outs = jax.lax.scan(outer, None, (qb, qpb))         # (nq,B,qc,KV,G,dh)
    out = outs.swapaxes(0, 1).reshape(b, sq, h, dh)
    return out.astype(q.dtype)
