"""RecSys architectures: SASRec, DIEN, AutoInt, two-tower retrieval.

All four share the recsys substrate pattern: huge row-shardable embedding
tables -> feature interaction -> small MLP. Serving shapes return top-k only
(never a (B, vocab) score matrix). The two-tower `retrieval_cand` path is the
paper's native integration point: candidates are scored in MPAD-reduced
space and re-ranked exactly (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .embedding import embedding_bag, embedding_lookup
from .layers import he_init

__all__ = [
    "SASRecConfig", "sasrec_init", "sasrec_forward", "sasrec_loss",
    "sasrec_serve_topk",
    "DIENConfig", "dien_init", "dien_forward", "dien_loss", "dien_score",
    "AutoIntConfig", "autoint_init", "autoint_forward", "autoint_loss",
    "TwoTowerConfig", "twotower_init", "twotower_user", "twotower_item",
    "twotower_loss", "twotower_retrieve",
]


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": he_init(ks[i], (dims[i], dims[i + 1]), dims[i], dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp_apply(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ================================================================ SASRec

@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 100_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0
    dtype: object = jnp.float32


def sasrec_init(key, cfg: SASRecConfig):
    ks = jax.random.split(key, 3 + 6 * cfg.n_blocks)
    p = {
        "item_emb": he_init(ks[0], (cfg.n_items, cfg.embed_dim),
                            cfg.embed_dim, cfg.dtype),
        "pos_emb": he_init(ks[1], (cfg.seq_len, cfg.embed_dim),
                           cfg.embed_dim, cfg.dtype),
        "blocks": [],
    }
    d = cfg.embed_dim
    for i in range(cfg.n_blocks):
        base = 2 + 6 * i
        p["blocks"].append({
            "ln1": jnp.ones((d,), cfg.dtype), "ln2": jnp.ones((d,), cfg.dtype),
            "wq": he_init(ks[base], (d, d), d, cfg.dtype),
            "wk": he_init(ks[base + 1], (d, d), d, cfg.dtype),
            "wv": he_init(ks[base + 2], (d, d), d, cfg.dtype),
            "w1": he_init(ks[base + 3], (d, d), d, cfg.dtype),
            "b1": jnp.zeros((d,), cfg.dtype),
            "w2": he_init(ks[base + 4], (d, d), d, cfg.dtype),
            "b2": jnp.zeros((d,), cfg.dtype),
        })
    p["final_ln"] = jnp.ones((d,), cfg.dtype)
    return p


def _ln(x, g):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * g


def sasrec_forward(params, cfg: SASRecConfig, seq):
    """seq (B, L) item ids (-1 pad). Returns hidden states (B, L, D)."""
    b, l = seq.shape
    h = embedding_lookup(params["item_emb"], seq) * jnp.sqrt(
        jnp.asarray(cfg.embed_dim, cfg.dtype))
    h = h + params["pos_emb"][None, :l]
    causal = jnp.tril(jnp.ones((l, l), bool))
    valid = (seq >= 0)
    for blk in params["blocks"]:
        x = _ln(h, blk["ln1"])
        q, k, v = x @ blk["wq"], x @ blk["wk"], x @ blk["wv"]
        nh, dh = cfg.n_heads, cfg.embed_dim // cfg.n_heads
        q = q.reshape(b, l, nh, dh); k = k.reshape(b, l, nh, dh)
        v = v.reshape(b, l, nh, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
        mask = causal[None, None] & valid[:, None, None, :]
        s = jnp.where(mask, s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, l, cfg.embed_dim)
        h = h + o
        x2 = _ln(h, blk["ln2"])
        h = h + jax.nn.relu(x2 @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
    return _ln(h, params["final_ln"]) * valid[..., None]


def sasrec_loss(params, cfg: SASRecConfig, batch):
    """Paper objective: BCE(h_t . e_pos) vs BCE(h_t . e_neg)."""
    h = sasrec_forward(params, cfg, batch["seq"])          # (B, L, D)
    epos = embedding_lookup(params["item_emb"], batch["pos"])
    eneg = embedding_lookup(params["item_emb"], batch["neg"])
    sp = jnp.sum(h * epos, -1).astype(jnp.float32)
    sn = jnp.sum(h * eneg, -1).astype(jnp.float32)
    mask = (batch["pos"] >= 0).astype(jnp.float32)
    loss = (jax.nn.softplus(-sp) + jax.nn.softplus(sn)) * mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)


def sasrec_serve_topk(params, cfg: SASRecConfig, seq, k: int = 100,
                      item_chunk: int = 8192):
    """Score all items for the last position; blocked running top-k so the
    (B, V) score matrix is never materialized (serve_bulk: B=262144)."""
    h = sasrec_forward(params, cfg, seq)[:, -1]            # (B, D)
    v = params["item_emb"].shape[0]
    item_chunk = min(item_chunk, v)
    if v % item_chunk:
        import math as _m
        item_chunk = _m.gcd(item_chunk, v)
    n_chunks = v // item_chunk
    emb = params["item_emb"].reshape(n_chunks, item_chunk, cfg.embed_dim)

    def body(carry, xs):
        best_s, best_i, off = carry
        s = h @ xs.T                                       # (B, chunk)
        ids = off + jnp.arange(item_chunk)[None, :]
        cs = jnp.concatenate([best_s, s], axis=1)
        ci = jnp.concatenate([best_i, jnp.broadcast_to(ids, s.shape)], axis=1)
        top, sel = jax.lax.top_k(cs, k)
        return (top, jnp.take_along_axis(ci, sel, axis=1),
                off + item_chunk), None

    init = (jnp.full((h.shape[0], k), -jnp.inf, h.dtype),
            jnp.zeros((h.shape[0], k), jnp.int32), jnp.zeros((), jnp.int32))
    (s, i, _), _ = jax.lax.scan(body, init, emb)
    return s, i


# ================================================================== DIEN

@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    n_items: int = 1_000_000
    n_cats: int = 10_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: Tuple[int, ...] = (200, 80)
    aux_weight: float = 0.5
    dtype: object = jnp.float32


def _gru_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {"wx": he_init(k1, (d_in, 3 * d_h), d_in, dtype),
            "wh": he_init(k2, (d_h, 3 * d_h), d_h, dtype),
            "b": jnp.zeros((3 * d_h,), dtype)}


def _gru_cell_pre(p, h, xproj, d_h):
    """GRU step from a PRE-PROJECTED input (xproj = x @ wx + b, computed for
    all timesteps in one batched matmul before the scan — §Perf dien iter 2:
    hoists the time-invariant projection out of the sequential loop)."""
    zs = xproj[..., :2 * d_h] + h @ p["wh"][:, :2 * d_h]
    r = jax.nn.sigmoid(zs[..., :d_h])
    z = jax.nn.sigmoid(zs[..., d_h:])
    # candidate uses reset gate on the hidden contribution
    cand = jnp.tanh(xproj[..., 2 * d_h:] + (r * h) @ p["wh"][:, 2 * d_h:])
    return (1.0 - z) * cand + z * h


def _gru_cell(p, h, x, d_h):
    return _gru_cell_pre(p, h, x @ p["wx"] + p["b"], d_h)


def dien_init(key, cfg: DIENConfig):
    ks = jax.random.split(key, 8)
    e2 = cfg.embed_dim * 2                                 # item + category
    return {
        "item_emb": he_init(ks[0], (cfg.n_items, cfg.embed_dim),
                            cfg.embed_dim, cfg.dtype),
        "cat_emb": he_init(ks[1], (cfg.n_cats, cfg.embed_dim),
                           cfg.embed_dim, cfg.dtype),
        "gru1": _gru_init(ks[2], e2, cfg.gru_dim, cfg.dtype),
        "att_w": he_init(ks[3], (cfg.gru_dim + e2, 1), cfg.gru_dim, cfg.dtype),
        "att_proj": he_init(ks[4], (e2, cfg.gru_dim), e2, cfg.dtype),
        "gru2": _gru_init(ks[5], cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "mlp": _mlp_init(ks[6], (cfg.gru_dim + e2 + e2,) + cfg.mlp_dims + (1,),
                         cfg.dtype),
        "aux_w": he_init(ks[7], (cfg.gru_dim, e2), cfg.gru_dim, cfg.dtype),
    }


def _hist_embed(params, batch):
    hi = embedding_lookup(params["item_emb"], batch["hist_items"])
    hc = embedding_lookup(params["cat_emb"], batch["hist_cats"])
    return jnp.concatenate([hi, hc], axis=-1)              # (B, L, 2E)


def _target_embed(params, items, cats):
    ti = embedding_lookup(params["item_emb"], items)
    tc = embedding_lookup(params["cat_emb"], cats)
    return jnp.concatenate([ti, tc], axis=-1)              # (..., 2E)


def dien_interest(params, cfg: DIENConfig, hist):
    """GRU-1 over history -> interest states (B, L, H). Target-independent.

    The input projection (time-invariant) runs as ONE (B*L, 2E) x (2E, 3H)
    matmul before the scan; the sequential loop only carries h @ wh.
    """
    b = hist.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)

    def step(h, x):
        h = _gru_cell(params["gru1"], h, x, cfg.gru_dim)
        return h, h

    # NOTE (§Perf dien iters 2-3, both refuted and reverted): (a) hoisting
    # x@wx out of the scan INCREASES traffic — the projected stream
    # (B,L,3H=324) is 9x wider than the raw inputs (B,L,2E=36); (b) unroll=2
    # duplicates slice/update traffic. Plain scan + in-loop projection wins.
    _, states = jax.lax.scan(step, h0, hist.swapaxes(0, 1))
    return states.swapaxes(0, 1)                           # (B, L, H)


def dien_augru(params, cfg: DIENConfig, states, target, hist_mask):
    """Attention-gated GRU (AUGRU) over interest states for one target."""
    proj_t = target @ params["att_proj"]                   # (B, H)
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(target[:, None], states.shape[:2] + (target.shape[-1],))],
        axis=-1)
    scores = (att_in @ params["att_w"])[..., 0]            # (B, L)
    scores = scores + jnp.einsum("blh,bh->bl", states, proj_t)
    scores = jnp.where(hist_mask, scores, -1e30)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(states.dtype)
    b = states.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)

    def step(h, xs):
        s_t, a_t = xs
        h_new = _gru_cell(params["gru2"], h, s_t, cfg.gru_dim)
        h = (1.0 - a_t[:, None]) * h + a_t[:, None] * h_new   # attention gate
        return h, None

    hT, _ = jax.lax.scan(step, h0, (states.swapaxes(0, 1), att.T))
    return hT                                              # (B, H)


def dien_forward(params, cfg: DIENConfig, batch):
    """Returns (logit (B,), interest states) for target item/cat."""
    hist = _hist_embed(params, batch)
    mask = batch["hist_items"] >= 0
    states = dien_interest(params, cfg, hist)
    target = _target_embed(params, batch["target_item"], batch["target_cat"])
    hT = dien_augru(params, cfg, states, target, mask)
    feats = jnp.concatenate([hT, target, jnp.sum(hist * mask[..., None], 1)],
                            axis=-1)
    return _mlp_apply(params["mlp"], feats)[..., 0], states


def dien_loss(params, cfg: DIENConfig, batch):
    logit, states = dien_forward(params, cfg, batch)
    bce = jnp.mean(
        jax.nn.softplus(-logit) * batch["label"]
        + jax.nn.softplus(logit) * (1.0 - batch["label"]))
    # DIEN auxiliary loss: h_t should predict behavior e_{t+1} vs negatives
    hist = _hist_embed(params, batch)
    neg = _target_embed(params, batch["neg_items"], batch["neg_cats"])
    proj = states[:, :-1] @ params["aux_w"]                # (B, L-1, 2E)
    sp = jnp.sum(proj * hist[:, 1:], -1).astype(jnp.float32)
    sn = jnp.sum(proj * neg[:, 1:], -1).astype(jnp.float32)
    m = (batch["hist_items"][:, 1:] >= 0).astype(jnp.float32)
    aux = jnp.sum((jax.nn.softplus(-sp) + jax.nn.softplus(sn)) * m) / \
        jnp.maximum(jnp.sum(m), 1.0)
    return bce + cfg.aux_weight * aux


def dien_score(params, cfg: DIENConfig, batch):
    """Bulk scoring: one user history vs n_candidates targets.

    batch: hist_items/cats (1, L); cand_items/cats (C,). Shares the GRU-1
    pass across candidates; AUGRU is vmapped over candidates.
    """
    hist = _hist_embed(params, batch)
    mask = batch["hist_items"] >= 0
    states = dien_interest(params, cfg, hist)              # (1, L, H)
    targets = _target_embed(params, batch["cand_items"], batch["cand_cats"])

    def score_one(tgt):
        hT = dien_augru(params, cfg, states, tgt[None], mask)
        feats = jnp.concatenate(
            [hT, tgt[None], jnp.sum(hist * mask[..., None], 1)], axis=-1)
        return _mlp_apply(params["mlp"], feats)[0, 0]

    return jax.lax.map(score_one, targets, batch_size=4096)


# ================================================================ AutoInt

@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_fields: int = 39
    vocab_per_field: int = 100_000
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    dtype: object = jnp.float32


def autoint_init(key, cfg: AutoIntConfig):
    ks = jax.random.split(key, 2 + 4 * cfg.n_attn_layers)
    d_in = cfg.embed_dim
    p = {"emb": he_init(ks[0], (cfg.n_fields * cfg.vocab_per_field,
                                cfg.embed_dim), cfg.embed_dim, cfg.dtype),
         "layers": []}
    d_out = cfg.n_heads * cfg.d_attn
    d = d_in
    for i in range(cfg.n_attn_layers):
        base = 1 + 4 * i
        p["layers"].append({
            "wq": he_init(ks[base], (d, d_out), d, cfg.dtype),
            "wk": he_init(ks[base + 1], (d, d_out), d, cfg.dtype),
            "wv": he_init(ks[base + 2], (d, d_out), d, cfg.dtype),
            "wres": he_init(ks[base + 3], (d, d_out), d, cfg.dtype),
        })
        d = d_out
    p["head"] = he_init(ks[-1], (cfg.n_fields * d, 1), cfg.n_fields * d,
                        cfg.dtype)
    return p


def autoint_forward(params, cfg: AutoIntConfig, field_ids):
    """field_ids (B, n_fields) local-per-field ids -> logit (B,)."""
    offs = jnp.arange(cfg.n_fields) * cfg.vocab_per_field
    e = embedding_lookup(params["emb"], field_ids + offs[None, :])  # (B,F,E)
    h = e
    for lp in params["layers"]:
        b, f, d = h.shape
        nh, da = cfg.n_heads, cfg.d_attn
        q = (h @ lp["wq"]).reshape(b, f, nh, da)
        k = (h @ lp["wk"]).reshape(b, f, nh, da)
        v = (h @ lp["wv"]).reshape(b, f, nh, da)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(da))
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, f, nh * da)
        h = jax.nn.relu(o + h @ lp["wres"])
    return (h.reshape(h.shape[0], -1) @ params["head"])[..., 0]


def autoint_loss(params, cfg: AutoIntConfig, batch):
    logit = autoint_forward(params, cfg, batch["field_ids"]).astype(jnp.float32)
    y = batch["label"]
    return jnp.mean(jax.nn.softplus(-logit) * y + jax.nn.softplus(logit) * (1 - y))


def autoint_score_candidates(params, cfg: AutoIntConfig, user_fields,
                             cand_ids, chunk: int = 8192):
    """Retrieval scoring: fixed user context (n_fields-1,) x C candidate ids
    in field 0, evaluated in chunks (C up to 10^6)."""

    def score_chunk(ids):
        rows = jnp.concatenate(
            [ids[:, None], jnp.broadcast_to(user_fields[None, :],
                                            (ids.shape[0],
                                             cfg.n_fields - 1))], axis=1)
        return autoint_forward(params, cfg, rows)

    c = cand_ids.shape[0]
    chunk = min(chunk, c)
    return jax.lax.map(score_chunk, cand_ids.reshape(-1, chunk)).reshape(-1)


# ============================================================== Two-tower

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two_tower"
    n_users: int = 5_000_000
    n_items: int = 2_000_000
    n_user_feats: int = 8                  # multi-hot history bag width
    field_dim: int = 64
    embed_dim: int = 256
    tower_dims: Tuple[int, ...] = (1024, 512, 256)
    n_negatives: int = 8192
    temperature: float = 0.05
    dtype: object = jnp.float32


def twotower_init(key, cfg: TwoTowerConfig):
    ks = jax.random.split(key, 4)
    d_user_in = cfg.field_dim * 2                          # id + history bag
    d_item_in = cfg.field_dim
    return {
        "user_emb": he_init(ks[0], (cfg.n_users, cfg.field_dim),
                            cfg.field_dim, cfg.dtype),
        "item_emb": he_init(ks[1], (cfg.n_items, cfg.field_dim),
                            cfg.field_dim, cfg.dtype),
        "user_mlp": _mlp_init(ks[2], (d_user_in,) + cfg.tower_dims, cfg.dtype),
        "item_mlp": _mlp_init(ks[3], (d_item_in,) + cfg.tower_dims, cfg.dtype),
    }


def twotower_user(params, cfg: TwoTowerConfig, user_ids, hist_ids):
    """user_ids (B,), hist_ids (B, n_user_feats) -> normalized (B, D)."""
    uid = embedding_lookup(params["user_emb"], user_ids)
    bag = embedding_bag(params["item_emb"], hist_ids, mode="mean")
    # history bag uses item table projected into user field space: same dim
    u = jnp.concatenate([uid, bag], axis=-1)
    u = _mlp_apply(params["user_mlp"], u)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def twotower_item(params, cfg: TwoTowerConfig, item_ids):
    it = embedding_lookup(params["item_emb"], item_ids)
    v = _mlp_apply(params["item_mlp"], it)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params, cfg: TwoTowerConfig, batch):
    """Sampled softmax with logQ correction (Yi et al., RecSys'19).

    batch: user_ids (B,), hist_ids (B, F), pos_items (B,),
           neg_items (N_neg,), neg_logq (N_neg,) log sampling probabilities.
    """
    u = twotower_user(params, cfg, batch["user_ids"], batch["hist_ids"])
    vp = twotower_item(params, cfg, batch["pos_items"])    # (B, D)
    vn = twotower_item(params, cfg, batch["neg_items"])    # (N, D)
    sp = jnp.sum(u * vp, -1) / cfg.temperature             # (B,)
    sn = (u @ vn.T) / cfg.temperature - batch["neg_logq"][None, :]
    logits = jnp.concatenate([sp[:, None], sn], axis=1).astype(jnp.float32)
    return jnp.mean(jax.nn.logsumexp(logits, axis=1) - logits[:, 0])


def twotower_retrieve(params, cfg: TwoTowerConfig, batch, k: int = 100,
                      reducer=None, rerank: int = 0, quantized: bool = False):
    """Retrieval scoring: one query against (C, D) candidate embeddings.

    ``reducer``: optional (matrix (m,D), mean (D,)) MPAD projection — the
    paper's technique on the candidate cache: score in m dims, then exactly
    re-rank the top ``rerank`` in full dims.

    ``quantized``: beyond-paper — additionally store the REDUCED candidate
    cache as int8 (symmetric per-dim scales): 16x fewer candidate-cache
    bytes than f32 full-dim, scores on the int8 MXU path; the exact re-rank
    absorbs the quantization error.
    """
    u = twotower_user(params, cfg, batch["user_ids"], batch["hist_ids"])  # (1,D)
    cand = batch["cand_emb"]                               # (C, D) precomputed
    if reducer is not None:
        mat, mean = reducer
        ur = (u - mean) @ mat.T                            # (1, m)
        if quantized:
            # offline-quantized reduced cache: (C, m) int8 + per-dim scales
            cq, scale = batch["cand_red_q"], batch["cand_scale"]
            scores_r = (jnp.einsum(
                "qm,cm->qc", (ur * scale[None, :]).astype(jnp.bfloat16),
                cq.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32))[0]    # (C,)
        else:
            # offline-reduced cache if provided; else reduce in-step
            cr = batch.get("cand_red")
            if cr is None:
                cr = (cand - mean) @ mat.T
            scores_r = (ur @ cr.T)[0]                      # (C,) reduced-space
        n_cand = max(k, rerank)
        _, pre = jax.lax.top_k(scores_r, n_cand)
        full = (u @ cand[pre].T)[0]                        # exact re-rank
        s, loc = jax.lax.top_k(full, k)
        return s, pre[loc]
    scores = (u @ cand.T)[0]
    s, ids = jax.lax.top_k(scores, k)
    return s, ids


def quantize_candidates(cand_red: jax.Array):
    """Offline int8 quantization of the reduced candidate cache (symmetric,
    per-dim scales). Returns (int8 (C, m), scales (m,))."""
    scale = jnp.max(jnp.abs(cand_red), axis=0) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(cand_red / scale[None, :]), -127, 127)
    return q.astype(jnp.int8), scale
