"""GIN (Graph Isomorphism Network, Xu et al. 2019) in three data regimes.

Message passing is built on ``jax.ops.segment_sum`` over an edge index (JAX
is BCOO-only — the scatter-based formulation IS the system, per the kernel
taxonomy §GNN):

  h_i' = MLP_l( (1 + eps_l) * h_i + sum_{j in N(i)} h_j )

Regimes (one per assigned input shape):
  * full-graph     — (N, F) node feats + (2, E) edge index; edges shard over
                     the data axis, partial segment-sums all-reduce.
  * sampled        — layered fanout batches (GraphSAGE-style sampler in
                     ``repro.data.graph``); depth = len(fanout).
  * molecules      — batched dense small graphs: adjacency matmul aggregation
                     (n<=32 makes dense adj the MXU-friendly layout).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import he_init

__all__ = ["GINConfig", "gin_init_params", "gin_full_forward",
           "gin_sampled_forward", "gin_mol_forward", "gin_full_loss",
           "gin_sampled_loss", "gin_mol_loss"]


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    fanout: Tuple[int, ...] = (15, 10)     # sampled regime depth/fanouts
    dtype: object = jnp.float32


def _mlp_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {"w1": he_init(k1, (d_in, d_h), d_in, dtype),
            "b1": jnp.zeros((d_h,), dtype),
            "w2": he_init(k2, (d_h, d_h), d_h, dtype),
            "b2": jnp.zeros((d_h,), dtype)}


def _mlp(p, x):
    return jax.nn.relu(jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"])


def gin_init_params(key, cfg: GINConfig):
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_feat if i == 0 else cfg.d_hidden
        layers.append({"mlp": _mlp_init(ks[i], d_in, cfg.d_hidden, cfg.dtype),
                       "eps": jnp.zeros((), cfg.dtype)})
    return {"layers": layers,
            "head": he_init(ks[-1], (cfg.d_hidden, cfg.n_classes),
                            cfg.d_hidden, cfg.dtype)}


# ----------------------------------------------------------- full graph

def gin_full_forward(params, cfg: GINConfig, feats, edge_src, edge_dst,
                     edge_mask=None):
    """feats (N, F); edge_{src,dst} (E,). Returns logits (N, n_classes).

    ``edge_mask`` (E,) zeroes padding edges (edge lists are padded to a
    device-count multiple for even sharding)."""
    h = feats.astype(cfg.dtype)
    n = feats.shape[0]
    for lp in params["layers"]:
        msg = h[edge_src]
        if edge_mask is not None:
            msg = msg * edge_mask[:, None].astype(msg.dtype)
        agg = jax.ops.segment_sum(msg, edge_dst, num_segments=n)
        h = _mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
    return h @ params["head"]


def gin_full_loss(params, cfg: GINConfig, batch):
    logits = gin_full_forward(params, cfg, batch["feats"],
                              batch["edge_src"], batch["edge_dst"],
                              batch.get("edge_mask"))
    labels = batch["labels"]
    mask = batch.get("label_mask", jnp.ones_like(labels, jnp.float32))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------------------------------------- sampled

def gin_sampled_forward(params, cfg: GINConfig, feat_levels):
    """feat_levels[d]: (B, f_1, ..., f_d, F) gathered features at hop d.

    Depth = len(fanout); aggregates leaves up to the seed nodes. Uses the
    first ``depth`` GIN layers (bottom-up order matches full-graph layering).
    """
    depth = len(cfg.fanout)
    hs = [f.astype(cfg.dtype) for f in feat_levels]        # hop 0..depth
    for li in range(depth):
        lp = params["layers"][li]
        new_hs = []
        for lvl in range(depth - li):                      # update hops 0..D-li-1
            child = hs[lvl + 1]                            # (..., fan, F')
            agg = jnp.sum(child, axis=-2)
            new_hs.append(_mlp(lp["mlp"], (1.0 + lp["eps"]) * hs[lvl] + agg))
        hs = new_hs
    return hs[0] @ params["head"]                          # (B, n_classes)


def gin_sampled_loss(params, cfg: GINConfig, batch):
    depth = len(cfg.fanout)
    levels = [batch[f"feat_l{d}"] for d in range(depth + 1)]
    logits = gin_sampled_forward(params, cfg, levels)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    return jnp.mean(nll)


# ----------------------------------------------------------- molecules

def gin_mol_forward(params, cfg: GINConfig, feats, adj):
    """Batched dense graphs: feats (G, n, F), adj (G, n, n). Sum readout."""
    h = feats.astype(cfg.dtype)
    for lp in params["layers"]:
        agg = jnp.einsum("gij,gjf->gif", adj.astype(cfg.dtype), h)
        h = _mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
    return jnp.sum(h, axis=1) @ params["head"]             # (G, n_classes)


def gin_mol_loss(params, cfg: GINConfig, batch):
    logits = gin_mol_forward(params, cfg, batch["feats"], batch["adj"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    return jnp.mean(nll)
