"""Mixture-of-Experts FFN block (granite-moe, olmoe).

Two implementations with identical no-drop semantics:

* ``dense``    — every expert processes every token, combined by the gate
                 matrix. O(E) overcompute; the mathematical reference, used
                 for small configs / decode shapes (where token count is
                 tiny) and as the oracle in tests.
* ``dispatch`` — sort-by-expert + capacity buffers (GShard-style, but via
                 stable-sort instead of giant one-hot dispatch tensors):
                 tokens are argsorted by expert id, each expert receives a
                 fixed-capacity (C) slice, per-expert FFNs run as one
                 batched einsum over the (E, C, D) buffer, results are
                 scattered back weighted by the renormalized router gates.
                 The (E, ...) dims shard over the "model" mesh axis (EP);
                 XLA SPMD turns the gather/scatter into expert all-to-all
                 traffic. Capacity overflow drops tokens (residual passes
                 through), as in Switch/GShard.

Router: top-k softmax gating with renormalization (Mixtral/OLMoE style) and
the Switch load-balancing auxiliary loss.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .layers import he_init

__all__ = ["MoEConfig", "init_moe_params", "moe_block"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                        # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    impl: str = "dense"              # dense | dispatch


def init_moe_params(key, mcfg: MoEConfig, d_model: int, length: int, dtype):
    e, f = mcfg.n_experts, mcfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": he_init(ks[0], (length, d_model, e), d_model, jnp.float32),
        "w_gate": he_init(ks[1], (length, e, d_model, f), d_model, dtype),
        "w_up": he_init(ks[2], (length, e, d_model, f), d_model, dtype),
        "w_down": he_init(ks[3], (length, e, f, d_model), f, dtype),
    }


def _route(x2d, router, mcfg: MoEConfig):
    logits = (x2d.astype(jnp.float32) @ router)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, mcfg.top_k)        # (T, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    # Switch aux loss: E * sum_e f_e * P_e
    t = x2d.shape[0]
    f_e = jnp.zeros((mcfg.n_experts,), jnp.float32).at[topi.reshape(-1)].add(
        1.0) / (t * mcfg.top_k)
    p_e = probs.mean(axis=0)
    aux = mcfg.n_experts * jnp.sum(f_e * p_e)
    return topv, topi, aux


def _moe_dense(x2d, p, mcfg: MoEConfig, topv, topi):
    gates = jnp.sum(
        jax.nn.one_hot(topi, mcfg.n_experts, dtype=x2d.dtype)
        * topv[..., None].astype(x2d.dtype), axis=1)     # (T, E)
    hg = jnp.einsum("td,edf->tef", x2d, p["w_gate"])
    hu = jnp.einsum("td,edf->tef", x2d, p["w_up"])
    hd = jnp.einsum("tef,efd->ted", jax.nn.silu(hg) * hu, p["w_down"])
    return jnp.einsum("ted,te->td", hd, gates)


def _moe_dispatch(x2d, p, mcfg: MoEConfig, topv, topi):
    t, d = x2d.shape
    e, k = mcfg.n_experts, mcfg.top_k
    cap = int(math.ceil(t * k / e * mcfg.capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)
    flat_e = topi.reshape(-1)                            # (T*K,)
    flat_t = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    pos = jnp.arange(t * k) - jnp.searchsorted(se, se, side="left")
    valid = pos < cap
    slot = jnp.where(valid, se * cap + pos, e * cap)     # overflow -> scratch row
    buf = jnp.zeros((e * cap + 1, d), x2d.dtype).at[slot].set(x2d[st])
    from repro.parallel.context import constrain
    from jax.sharding import PartitionSpec as _P
    xe = buf[:-1].reshape(e, cap, d)                     # (E, C, D) shards on E
    xe = constrain(xe, _P("model", "data", None))        # EP x capacity-DP
    hg = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    hu = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hu, p["w_down"])
    out_rows = ye.reshape(e * cap, d)[jnp.minimum(slot, e * cap - 1)]
    contrib = out_rows * (sw * valid).astype(x2d.dtype)[:, None]
    return jnp.zeros((t, d), x2d.dtype).at[st].add(contrib)


def _dispatch_tables(x2d, mcfg: MoEConfig, topv, topi, cap):
    """Sort-by-expert dispatch bookkeeping shared by dispatch/EP paths."""
    t = x2d.shape[0]
    e, k = mcfg.n_experts, mcfg.top_k
    flat_e = topi.reshape(-1)
    flat_t = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    pos = jnp.arange(t * k) - jnp.searchsorted(se, se, side="left")
    valid = pos < cap
    slot = jnp.where(valid, se * cap + pos, e * cap)
    return st, sw, valid, slot


def _moe_ep_shardmap(x3d, p, mcfg: MoEConfig, mesh):
    """Expert parallelism via shard_map + all_to_all (DESIGN.md §5).

    Per device: slice this model-rank's share of the local tokens, route
    them, build per-(source-rank, expert) capacity buffers, all_to_all over
    the 'model' axis so each rank receives ONLY its experts' tokens, run the
    local expert FFNs as one batched einsum, all_to_all back, combine, and
    all_gather the outputs across model ranks. Collective payload per layer
    is O(tokens*D), vs the O(E*C*D)-sized all-reduces XLA SPMD emits for the
    plain sharded-scatter formulation (measured 7.75 TB/dev/step on
    olmoe train_4k -> see EXPERIMENTS.md §Perf).
    """
    import functools
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    all_axes = tuple(mesh.axis_names)
    mp = mesh.shape["model"]
    e, k = mcfg.n_experts, mcfg.top_k
    e_loc = e // mp

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False)
    def block(x_loc, router, wg, wu, wd):
        b_loc, s, d = x_loc.shape
        t_loc = b_loc * s
        t_mp = t_loc // mp
        me = jax.lax.axis_index("model")
        x2 = x_loc.reshape(t_loc, d)
        xs = jax.lax.dynamic_slice_in_dim(x2, me * t_mp, t_mp)
        topv, topi, aux = _route(xs, router, mcfg)
        cap = int(math.ceil(t_mp * k / e * mcfg.capacity_factor))
        cap = max(8, ((cap + 7) // 8) * 8)
        st, sw, valid, slot = _dispatch_tables(xs, mcfg, topv, topi, cap)
        buf = jnp.zeros((e * cap + 1, d), xs.dtype).at[slot].set(xs[st])
        send = buf[:-1].reshape(mp, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        xe = recv.reshape(mp, e_loc, cap, d).transpose(1, 0, 2, 3) \
            .reshape(e_loc, mp * cap, d)
        hg = jnp.einsum("ecd,edf->ecf", xe, wg)
        hu = jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hu, wd)
        back = ye.reshape(e_loc, mp, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back.reshape(mp, e_loc, cap, d), "model",
                                 split_axis=0, concat_axis=0, tiled=True)
        out_rows = ret.reshape(e * cap, d)[jnp.minimum(slot, e * cap - 1)]
        contrib = out_rows * (sw * valid).astype(xs.dtype)[:, None]
        y_mp = jnp.zeros((t_mp, d), xs.dtype).at[st].add(contrib)
        y2 = jax.lax.all_gather(y_mp, "model", tiled=True)   # (t_loc, D)
        aux_g = jax.lax.pmean(aux, all_axes)
        return y2.reshape(b_loc, s, d), aux_g

    return block(x3d, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _ep_applicable(x, mcfg: MoEConfig, mesh) -> bool:
    if mesh is None or "model" not in mesh.axis_names:
        return False
    mp = mesh.shape["model"]
    if mcfg.n_experts % mp:
        return False
    dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dp *= mesh.shape[a]
    b, s, _ = x.shape
    if b % dp:
        return False
    t_loc = (b // dp) * s
    return t_loc % mp == 0 and t_loc // mp >= 8


def moe_block(x, p, mcfg: MoEConfig):
    """x: (B, S, D) -> (B, S, D), plus scalar aux loss."""
    if mcfg.impl == "ep":
        from repro.parallel.context import active_mesh
        mesh = active_mesh()
        if _ep_applicable(x, mcfg, mesh):
            return _moe_ep_shardmap(x, p, mcfg, mesh)
        # fall through to the portable dispatch path
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    topv, topi, aux = _route(x2d, p["router"], mcfg)
    if mcfg.impl == "dense":
        y = _moe_dense(x2d, p, mcfg, topv, topi)
    elif mcfg.impl in ("dispatch", "ep"):
        y = _moe_dispatch(x2d, p, mcfg, topv, topi)
    else:
        raise ValueError(f"unknown moe impl {mcfg.impl!r}")
    return y.reshape(b, s, d), aux
