"""Decoder-only LM supporting the assigned dense + MoE architectures.

Key structural choices (DESIGN.md §5):

* **Run-structured layer stack.** Layers are grouped into contiguous runs of
  the same attention kind ("global" full-causal vs "local" sliding-window).
  Each run's parameters are stacked and executed with a rematerialized
  ``lax.scan`` — compact HLO (one scan body per distinct run shape instead of
  n_layers copies) and bounded live activations. Uniform archs degenerate to
  a single run; gemma3's 5:1 local:global pattern produces [5xlocal,
  1xglobal] blocks, letting local runs carry *window-sized ring-buffer KV
  caches* while global runs carry full-length caches — this is what makes
  the 512k-token decode cell fit.
* **Chunked attention** (``layers.chunked_attention``): flash-style online
  softmax, never materializes (Sq x Skv).
* **Chunked cross-entropy**: the (B, S, vocab) logits tensor is never
  materialized; a scan over sequence chunks computes logits + CE per chunk
  (vocab up to 262k makes this mandatory).
* **Position-based masking**: causality, sliding windows and ring-buffer
  cache validity are all expressed through absolute positions, so train /
  prefill / decode share one attention code path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import chunked_attention, he_init, rms_norm, rope, swiglu
from .moe import MoEConfig, init_moe_params, moe_block

__all__ = ["LMConfig", "lm_init_params", "lm_loss", "lm_train_forward",
           "lm_prefill", "lm_decode_step", "init_cache", "lm_embed",
           "layer_runs"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None   # gemma3: 10k local / 1M global
    sliding_window: Optional[int] = None   # window for "local" layers
    global_every: Optional[int] = None     # every k-th layer global (gemma 5:1 -> 6)
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = True
    dtype: Any = jnp.float32
    seq_chunk: int = 1024                  # chunked-CE sequence chunk
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    attn_impl: str = "chunked"             # chunked | flash (Pallas kernel)

    @property
    def vocab_padded(self) -> int:         # TPU-friendly vocab padding
        return ((self.vocab + 255) // 256) * 256


def layer_runs(cfg: LMConfig) -> List[Tuple[str, int]]:
    """[(kind, length), ...] contiguous runs of same-kind layers."""
    if cfg.global_every is None:
        kind = "local" if cfg.sliding_window is not None else "global"
        return [(kind, cfg.n_layers)]
    kinds = ["global" if (i % cfg.global_every) == cfg.global_every - 1
             else "local" for i in range(cfg.n_layers)]
    runs: List[Tuple[str, int]] = []
    for k in kinds:
        if runs and runs[-1][0] == k:
            runs[-1] = (k, runs[-1][1] + 1)
        else:
            runs.append((k, 1))
    return runs


def _init_run_params(key, cfg: LMConfig, length: int):
    d, h, kv, dh, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                       cfg.d_ff)
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.zeros((length, d), cfg.dtype),
        "ln2": jnp.zeros((length, d), cfg.dtype),
        "wq": he_init(ks[0], (length, d, h * dh), d, cfg.dtype),
        "wk": he_init(ks[1], (length, d, kv * dh), d, cfg.dtype),
        "wv": he_init(ks[2], (length, d, kv * dh), d, cfg.dtype),
        "wo": he_init(ks[3], (length, h * dh, d), h * dh, cfg.dtype),
    }
    if cfg.moe is None:
        p.update({
            "w_gate": he_init(ks[4], (length, d, f), d, cfg.dtype),
            "w_up": he_init(ks[5], (length, d, f), d, cfg.dtype),
            "w_down": he_init(ks[6], (length, f, d), f, cfg.dtype),
        })
    else:
        p["moe"] = init_moe_params(ks[7], cfg.moe, d, length, cfg.dtype)
    return p


def lm_init_params(key, cfg: LMConfig):
    ks = jax.random.split(key, len(layer_runs(cfg)) + 2)
    params = {
        "embed": he_init(ks[0], (cfg.vocab_padded, cfg.d_model),
                         cfg.d_model, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "runs": [_init_run_params(ks[2 + i], cfg, length)
                 for i, (_, length) in enumerate(layer_runs(cfg))],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(
            ks[1], (cfg.d_model, cfg.vocab_padded), cfg.d_model, cfg.dtype)
    return params


# ------------------------------------------------------------- layer bodies

def _qkv(cfg: LMConfig, x, lp, q_pos, window):
    b, sq, _ = x.shape
    theta = (cfg.rope_theta_local
             if (window is not None and cfg.rope_theta_local)
             else cfg.rope_theta)
    q = (x @ lp["wq"]).reshape(b, sq, cfg.n_heads, cfg.d_head)
    k = (x @ lp["wk"]).reshape(b, sq, cfg.n_kv_heads, cfg.d_head)
    v = (x @ lp["wv"]).reshape(b, sq, cfg.n_kv_heads, cfg.d_head)
    return (rope(q, q_pos, theta), rope(k, q_pos, theta), v)


def _mlp(cfg: LMConfig, h, lp):
    x2 = rms_norm(h, lp["ln2"])
    if cfg.moe is None:
        return h + swiglu(x2, lp["w_gate"], lp["w_up"], lp["w_down"]), \
            jnp.zeros((), jnp.float32)
    y, aux = moe_block(x2, lp["moe"], cfg.moe)
    return h + y, aux


def _layer_self(cfg: LMConfig, window, h, lp, q_pos):
    """Self-contained segment attention (training / prefill).

    Returns (h_out, k, v, aux)."""
    b, sq, _ = h.shape
    q, k, v = _qkv(cfg, rms_norm(h, lp["ln1"]), lp, q_pos, window)
    if cfg.attn_impl == "flash":
        # Pallas tile-resident kernel (TPU; interpret mode on CPU); the
        # custom VJP recomputes backward through the chunked path.
        from repro.kernels.flash_attention import flash_attention
        attn = flash_attention(q, k, v, window)
    else:
        attn = chunked_attention(q, k, v, q_pos, q_pos, window=window,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    h = h + attn.reshape(b, sq, -1) @ lp["wo"]
    h, aux = _mlp(cfg, h, lp)
    return h, k, v, aux


def _layer_cached(cfg: LMConfig, window, h, lp, q_pos, ck, cv, kv_pos, slots):
    """Decode: write this step's K/V into cache slots, attend over cache.

    Returns (h_out, ck, cv)."""
    b, sq, _ = h.shape
    q, k, v = _qkv(cfg, rms_norm(h, lp["ln1"]), lp, q_pos, window)
    ck = ck.at[:, slots].set(k.astype(ck.dtype))
    cv = cv.at[:, slots].set(v.astype(cv.dtype))
    attn = chunked_attention(
        q, ck.astype(q.dtype), cv.astype(q.dtype), q_pos, kv_pos,
        window=window, q_chunk=cfg.q_chunk, kv_chunk=ck.shape[1])
    h = h + attn.reshape(b, sq, -1) @ lp["wo"]
    h, _ = _mlp(cfg, h, lp)
    return h, ck, cv


def _forward_no_cache(cfg: LMConfig, params, h, q_pos):
    """Training/embedding forward over all runs; no cache."""
    total_aux = jnp.zeros((), jnp.float32)
    for ri, (kind, _) in enumerate(layer_runs(cfg)):
        window = cfg.sliding_window if kind == "local" else None

        def body(h, lp, _w=window):
            h, _, _, aux = _layer_self(cfg, _w, h, lp, q_pos)
            return h, aux

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, auxs = jax.lax.scan(body_fn, h, params["runs"][ri])
        total_aux = total_aux + jnp.sum(auxs)
    return h, total_aux


def _logits_head(cfg: LMConfig, params, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    if cfg.vocab_padded != cfg.vocab:       # mask padded vocab tail
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return logits


def lm_loss(params, cfg: LMConfig, tokens, labels):
    """Mean next-token CE with chunked (never-materialized) logits."""
    b, s = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    h, aux = _forward_no_cache(cfg, params, h, jnp.arange(s))
    h = rms_norm(h, params["final_norm"])
    ck = min(cfg.seq_chunk, s)
    if s % ck:
        ck = math.gcd(ck, s)
    nc = s // ck
    hc = h.reshape(b, nc, ck, cfg.d_model).swapaxes(0, 1)
    lc = labels.reshape(b, nc, ck).swapaxes(0, 1)

    def chunk_ce(carry, xs):
        hcb, lcb = xs
        logits = _logits_head(cfg, params, hcb).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lcb[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    chunk_fn = jax.checkpoint(chunk_ce) if cfg.remat else chunk_ce
    total, _ = jax.lax.scan(chunk_fn, jnp.zeros((), jnp.float32), (hc, lc))
    loss = total / (b * s)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.n_layers
    return loss


def lm_train_forward(params, cfg: LMConfig, batch):
    return lm_loss(params, cfg, batch["tokens"], batch["labels"])


# ------------------------------------------------------- serving path

def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Per-run KV caches: local runs allocate only the sliding window."""
    dtype = dtype if dtype is not None else cfg.dtype
    cache = []
    for kind, length in layer_runs(cfg):
        s_run = (min(cfg.sliding_window, max_len)
                 if kind == "local" and cfg.sliding_window else max_len)
        shape = (length, batch, s_run, cfg.n_kv_heads, cfg.d_head)
        cache.append({
            "k": jnp.zeros(shape, dtype),
            "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((s_run,), -1, jnp.int32),
        })
    return cache


def lm_prefill(params, cfg: LMConfig, tokens, cache):
    """Process a full prompt (B, S); returns (last-position logits, cache).

    Attention is self-contained within the prompt; caches are written as a
    side effect (local runs keep only the last ``window`` positions in their
    ring buffers)."""
    b, s = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    q_pos = jnp.arange(s)
    new_cache = []
    for ri, (kind, _) in enumerate(layer_runs(cfg)):
        rc = cache[ri]
        s_run = rc["k"].shape[2]
        window = cfg.sliding_window if kind == "local" else None
        n_write = min(s, s_run)
        src = jnp.arange(s - n_write, s)            # positions written
        dst = src % s_run                           # ring slots (identity if s<=s_run)

        def body(h, xs, _w=window, _src=src, _dst=dst):
            lp, (ck, cv) = xs
            h, k, v, _ = _layer_self(cfg, _w, h, lp, q_pos)
            ck = ck.at[:, _dst].set(k[:, _src].astype(ck.dtype))
            cv = cv.at[:, _dst].set(v[:, _src].astype(cv.dtype))
            return h, (ck, cv)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, kv_out = jax.lax.scan(body_fn, h, (params["runs"][ri],
                                              (rc["k"], rc["v"])))
        new_pos = rc["pos"].at[dst].set(src)
        new_cache.append({"k": kv_out[0], "v": kv_out[1], "pos": new_pos})
    h = rms_norm(h, params["final_norm"])
    logits = _logits_head(cfg, params, h[:, -1:, :])
    return logits[:, 0], new_cache


def lm_decode_step(params, cfg: LMConfig, token, cur_len, cache):
    """One decode step: token (B,) at absolute position ``cur_len`` (scalar).

    Returns (logits (B, vocab_padded), new_cache)."""
    h = params["embed"][token][:, None, :].astype(cfg.dtype)
    q_pos = jnp.reshape(cur_len, (1,)).astype(jnp.int32)
    new_cache = []
    for ri, (kind, _) in enumerate(layer_runs(cfg)):
        rc = cache[ri]
        s_run = rc["k"].shape[2]
        window = cfg.sliding_window if kind == "local" else None
        slots = (q_pos % s_run) if (kind == "local" and window
                                    and s_run == window) else q_pos
        kv_pos = rc["pos"].at[slots].set(q_pos)

        def body(h, xs, _w=window, _kvp=kv_pos, _slots=slots):
            lp, (ck, cv) = xs
            h, ck, cv = _layer_cached(cfg, _w, h, lp, q_pos, ck, cv,
                                      _kvp, _slots)
            return h, (ck, cv)

        h, kv_out = jax.lax.scan(body, h, (params["runs"][ri],
                                           (rc["k"], rc["v"])))
        new_cache.append({"k": kv_out[0], "v": kv_out[1], "pos": kv_pos})
    h = rms_norm(h, params["final_norm"])
    logits = _logits_head(cfg, params, h)
    return logits[:, 0], new_cache


def lm_embed(params, cfg: LMConfig, tokens):
    """Mean-pooled final hidden states — the vector-search integration hook
    (MPAD compresses these embeddings; DESIGN.md §4)."""
    h = params["embed"][tokens].astype(cfg.dtype)
    h, _ = _forward_no_cache(cfg, params, h, jnp.arange(tokens.shape[1]))
    h = rms_norm(h, params["final_norm"])
    return h.mean(axis=1)
