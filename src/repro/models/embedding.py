"""Sparse embedding ops: JAX has no native EmbeddingBag — built here from
``jnp.take`` + masking / ``segment_sum`` (kernel-taxonomy §RecSys note).

Tables are row-shardable over the "model" mesh axis (the tables ARE the
memory in recsys); lookups lower to gathers that XLA SPMD converts into
index-matched collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["embedding_lookup", "embedding_bag", "hash_bucket"]


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain gather: ids (...,) -> (..., D). Negative ids return zeros."""
    emb = table[jnp.maximum(ids, 0)]
    return emb * (ids >= 0)[..., None].astype(emb.dtype)


def embedding_bag(table: jax.Array, ids: jax.Array, mode: str = "sum"):
    """EmbeddingBag over fixed-width bags: ids (B, L) with -1 padding.

    mode: sum | mean | max. Returns (B, D).
    """
    mask = (ids >= 0)
    emb = table[jnp.maximum(ids, 0)]                       # (B, L, D)
    maskf = mask[..., None].astype(emb.dtype)
    if mode == "sum":
        return jnp.sum(emb * maskf, axis=1)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(maskf, axis=1), 1.0)
        return jnp.sum(emb * maskf, axis=1) / cnt
    if mode == "max":
        neg = jnp.where(mask[..., None], emb, -jnp.inf)
        out = jnp.max(neg, axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)


def hash_bucket(ids: jax.Array, n_buckets: int, salt: int = 0) -> jax.Array:
    """Multiplicative hashing for open-vocabulary id spaces."""
    h = (ids.astype(jnp.uint32) + jnp.uint32(salt)) * jnp.uint32(2654435761)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)
