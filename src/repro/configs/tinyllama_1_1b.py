"""tinyllama-1.1b [dense] 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-arch small [arXiv:2401.02385; hf]."""
import jax.numpy as jnp

from repro.configs.lm_family import make_lm_arch
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
    n_kv_heads=4, d_head=64, d_ff=5632, vocab=32000, rope_theta=10000.0,
    tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = LMConfig(
    name="tinyllama-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=256, tie_embeddings=False,
    seq_chunk=16, q_chunk=16, kv_chunk=16)


def get_arch():
    return make_lm_arch("tinyllama-1.1b", CONFIG, SMOKE, long_ok=False)
