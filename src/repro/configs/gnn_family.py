"""GNN-family ArchSpec builder (GIN): full_graph_sm / minibatch_lg /
ogb_products / molecule cells. All four shapes lower train_step."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import ArchSpec, ShapeDef
from repro.models import gnn
from repro.optim import AdamWConfig, init_opt_state, make_train_step
from repro.parallel import sharding as sh

__all__ = ["make_gin_arch", "GNN_SHAPES"]

_ADAM = AdamWConfig(lr=1e-3, total_steps=10_000)


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


GNN_SHAPES = {
    # name: (regime, params). Edge counts get padded to 512 multiples.
    "full_graph_sm": dict(regime="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg": dict(regime="sampled", batch_nodes=1024, fanout=(15, 10),
                         d_feat=602, n_classes=41),
    "ogb_products": dict(regime="full", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100, n_classes=47),
    "molecule": dict(regime="mol", n_graphs=128, n_nodes=30, d_feat=32,
                     n_classes=2),
}


def make_gin_arch(name: str, base_cfg: gnn.GINConfig) -> ArchSpec:
    shapes = {k: ShapeDef(name=k, kind="train", desc=str(v))
              for k, v in GNN_SHAPES.items()}

    def shape_cfg(sname):
        s = GNN_SHAPES[sname]
        return gnn.GINConfig(
            name=f"{base_cfg.name}:{sname}", n_layers=base_cfg.n_layers,
            d_hidden=base_cfg.d_hidden, d_feat=s["d_feat"],
            n_classes=s["n_classes"], fanout=s.get("fanout", (15, 10)))

    @functools.lru_cache(maxsize=None)
    def abstract_state(sname):
        c = shape_cfg(sname)
        params = jax.eval_shape(
            lambda: gnn.gin_init_params(jax.random.key(0), c))
        opt = jax.eval_shape(init_opt_state, params)
        return params, opt

    def batch_struct(sname):
        s = GNN_SHAPES[sname]
        f32, i32 = jnp.float32, jnp.int32
        sd = jax.ShapeDtypeStruct
        if s["regime"] == "full":
            ep = _pad_to(s["n_edges"], 512)
            return {"feats": sd((s["n_nodes"], s["d_feat"]), f32),
                    "edge_src": sd((ep,), i32), "edge_dst": sd((ep,), i32),
                    "edge_mask": sd((ep,), f32),
                    "labels": sd((s["n_nodes"],), i32),
                    "label_mask": sd((s["n_nodes"],), f32)}
        if s["regime"] == "sampled":
            b, (f1, f2), d = s["batch_nodes"], s["fanout"], s["d_feat"]
            return {"feat_l0": sd((b, d), f32),
                    "feat_l1": sd((b, f1, d), f32),
                    "feat_l2": sd((b, f1, f2, d), f32),
                    "labels": sd((b,), i32)}
        g, n, d = s["n_graphs"], s["n_nodes"], s["d_feat"]
        return {"feats": sd((g, n, d), f32), "adj": sd((g, n, n), f32),
                "labels": sd((g,), i32)}

    def abstract_args(sname):
        params, opt = abstract_state(sname)
        return (params, opt, batch_struct(sname))

    def step_fn(sname):
        s = GNN_SHAPES[sname]
        c = shape_cfg(sname)
        loss = {"full": gnn.gin_full_loss, "sampled": gnn.gin_sampled_loss,
                "mol": gnn.gin_mol_loss}[s["regime"]]
        return make_train_step(lambda p, b: loss(p, c, b), _ADAM)

    def _batch_specs(sname, mesh):
        s = GNN_SHAPES[sname]
        dp = sh.dp_axes(mesh)
        allax = tuple(mesh.axis_names)
        if s["regime"] == "full":
            return {"feats": P(None, None),
                    "edge_src": P(allax), "edge_dst": P(allax),
                    "edge_mask": P(allax),
                    "labels": P(None), "label_mask": P(None)}
        if s["regime"] == "sampled":
            return {"feat_l0": P(dp, None), "feat_l1": P(dp, None, None),
                    "feat_l2": P(dp, None, None, None), "labels": P(dp)}
        return {"feats": P(dp, None, None), "adj": P(dp, None, None),
                "labels": P(dp)}

    def arg_specs(sname, mesh):
        params, _ = abstract_state(sname)
        pspec = sh.replicate_like(params)
        return (pspec, sh.opt_specs(pspec), _batch_specs(sname, mesh))

    def out_specs(sname, mesh):
        params, _ = abstract_state(sname)
        pspec = sh.replicate_like(params)
        return (P(), pspec, sh.opt_specs(pspec))

    def model_flops(sname) -> float:
        s = GNN_SHAPES[sname]
        c = shape_cfg(sname)
        h = c.d_hidden
        if s["regime"] == "full":
            n, e = s["n_nodes"], s["n_edges"]
            per_layer = 2 * n * s["d_feat"] * h + 2 * n * h * h + e * h
            fwd = per_layer + (c.n_layers - 1) * (4 * n * h * h + e * h)
        elif s["regime"] == "sampled":
            b, (f1, f2) = s["batch_nodes"], s["fanout"]
            nodes = b * (1 + f1 + f1 * f2)
            fwd = 2 * nodes * s["d_feat"] * h + 4 * nodes * h * h
        else:
            g, n = s["n_graphs"], s["n_nodes"]
            fwd = c.n_layers * (g * (2 * n * s["d_feat"] * h
                                     + 4 * n * h * h + 2 * n * n * h))
        return 3.0 * fwd

    def smoke() -> dict:
        c = gnn.GINConfig(name="gin-smoke", n_layers=3, d_hidden=16,
                          d_feat=8, n_classes=3, fanout=(3, 2))
        params = gnn.gin_init_params(jax.random.key(0), c)
        opt = init_opt_state(params)
        n, e = 24, 64
        batch = {
            "feats": jax.random.normal(jax.random.key(1), (n, 8)),
            "edge_src": jax.random.randint(jax.random.key(2), (e,), 0, n),
            "edge_dst": jax.random.randint(jax.random.key(3), (e,), 0, n),
            "edge_mask": jnp.ones((e,)),
            "labels": jax.random.randint(jax.random.key(4), (n,), 0, 3),
            "label_mask": jnp.ones((n,)),
        }
        step = make_train_step(lambda p, b: gnn.gin_full_loss(p, c, b), _ADAM)
        loss, params2, _ = jax.jit(step)(params, opt, batch)
        sb = {"feat_l0": jax.random.normal(jax.random.key(5), (4, 8)),
              "feat_l1": jax.random.normal(jax.random.key(6), (4, 3, 8)),
              "feat_l2": jax.random.normal(jax.random.key(7), (4, 3, 2, 8)),
              "labels": jax.random.randint(jax.random.key(8), (4,), 0, 3)}
        l2 = gnn.gin_sampled_loss(params, c, sb)
        mb = {"feats": jax.random.normal(jax.random.key(9), (5, 6, 8)),
              "adj": jnp.ones((5, 6, 6)),
              "labels": jax.random.randint(jax.random.key(10), (5,), 0, 3)}
        l3 = gnn.gin_mol_loss(params, c, mb)
        ok = all(bool(jnp.isfinite(x)) for x in (loss, l2, l3))
        return {"ok": ok, "loss": float(loss), "sampled_loss": float(l2),
                "mol_loss": float(l3)}

    return ArchSpec(name=name, family="gnn", shapes=shapes,
                    abstract_args=abstract_args, arg_specs=arg_specs,
                    out_specs=out_specs, step_fn=step_fn, smoke=smoke,
                    model_flops=model_flops)
