"""sasrec [recsys] embed_dim=50 n_blocks=2 n_heads=1 seq_len=50
interaction=self-attn-seq [arXiv:1808.09781; paper].

Catalog sized 2^20 so the retrieval_cand cell scores the full catalog."""
from repro.configs.recsys_family import make_sasrec_arch
from repro.models.recsys import SASRecConfig

CONFIG = SASRecConfig(name="sasrec", n_items=1_048_576, embed_dim=50,
                      n_blocks=2, n_heads=1, seq_len=50)


def get_arch():
    return make_sasrec_arch(CONFIG)
