"""dien [recsys] embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80
interaction=augru [arXiv:1809.03672; unverified]."""
from repro.configs.recsys_family import make_dien_arch
from repro.models.recsys import DIENConfig

CONFIG = DIENConfig(name="dien", n_items=1_048_576, n_cats=10_000,
                    embed_dim=18, seq_len=100, gru_dim=108,
                    mlp_dims=(200, 80))


def get_arch():
    return make_dien_arch(CONFIG)
