"""olmoe-1b-7b [moe] 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 [arXiv:2409.02060; hf]."""
import jax.numpy as jnp

from repro.configs.lm_family import make_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_head=128, d_ff=0, vocab=50304, rope_theta=10000.0,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024, capacity_factor=1.25,
                  impl="ep"),
    tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = LMConfig(
    name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=0, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=2.0,
                  impl="dispatch"),
    tie_embeddings=False, seq_chunk=16, q_chunk=16, kv_chunk=16)


def get_arch():
    return make_lm_arch("olmoe-1b-7b", CONFIG, SMOKE, long_ok=False)
