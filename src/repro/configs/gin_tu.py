"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826; paper]."""
from repro.configs.gnn_family import make_gin_arch
from repro.models.gnn import GINConfig

CONFIG = GINConfig(name="gin-tu", n_layers=5, d_hidden=64)


def get_arch():
    return make_gin_arch("gin-tu", CONFIG)
