"""The paper's own experiment configuration (Section 4).

Parameter grids exactly as published:
  target ratios [0.05, 0.1, 0.2, 0.4, 0.6], k in [1, 3, 6, 10, 15],
  alpha in [1, 6, 12, 18, 25, 35, 50, 10000], b in [60, 70, 80, 90, 100]
  => 1000 settings per dataset (40 MPAD configs x 25 global combos).

Datasets are synthetic stand-ins matched to Table 4 (see
repro.data.synthetic); per-dataset fixed (alpha, b) for the Fig.1 protocol
follow the paper (alpha=50, b=80 for fasttext; defaults elsewhere).
"""
from repro.core import MPADConfig

TARGET_RATIOS = [0.05, 0.1, 0.2, 0.4, 0.6]
K_VALUES = [1, 3, 6, 10, 15]
ALPHA_GRID = [1.0, 6.0, 12.0, 18.0, 25.0, 35.0, 50.0, 10000.0]
B_GRID = [60.0, 70.0, 80.0, 90.0, 100.0]

# fixed per-dataset (alpha, b) used for the Fig.1 average-accuracy protocol
FIXED_PARAMS = {
    "fasttext": (50.0, 80.0),        # stated in the paper
    "isolet": (25.0, 80.0),
    "arcene": (25.0, 80.0),
    "pbmc3k": (25.0, 80.0),
}

# Table 4 sampling protocol: sample dim / train size / test size
SAMPLING = {
    "fasttext": dict(dim=300, train=600, test=600),
    "isolet": dict(dim=200, train=600, test=600),
    "arcene": dict(dim=200, train=600, test=297),
    "pbmc3k": dict(dim=200, train=600, test=600),
}


def mpad_config(dataset: str, m: int, iters: int = 48) -> MPADConfig:
    alpha, b = FIXED_PARAMS[dataset]
    return MPADConfig(m=m, alpha=alpha, b=b, iters=iters, backend="fast")
