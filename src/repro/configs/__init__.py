from .registry import get_arch, all_arch_names
from .common import ArchSpec, ShapeDef

__all__ = ["get_arch", "all_arch_names", "ArchSpec", "ShapeDef"]
