"""stablelm-1.6b [dense] 24L d_model=2048 32H (GQA kv=32, i.e. MHA)
d_ff=5632 vocab=100352 [hf:stabilityai/stablelm-2-1_6b; unverified]."""
import jax.numpy as jnp

from repro.configs.lm_family import make_lm_arch
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="stablelm-1.6b", n_layers=24, d_model=2048, n_heads=32,
    n_kv_heads=32, d_head=64, d_ff=5632, vocab=100352, rope_theta=10000.0,
    tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = LMConfig(
    name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=256, tie_embeddings=False,
    seq_chunk=16, q_chunk=16, kv_chunk=16)


def get_arch():
    return make_lm_arch("stablelm-1.6b", CONFIG, SMOKE, long_ok=False)
