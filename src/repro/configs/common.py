"""Shared config machinery: ShapeDef + ArchSpec.

Each assigned architecture module exposes ``get_arch() -> ArchSpec``; the
dry-run, smoke tests and benchmarks all consume this one interface:

  * ``abstract_args(shape)``  — ShapeDtypeStruct pytrees for every positional
                                argument of the step function (no allocation)
  * ``arg_specs(shape, mesh)``/``out_specs(shape, mesh)`` — PartitionSpec
                                pytrees for in_shardings / out_shardings
  * ``step_fn(shape)``        — the function to jit/lower for that cell
  * ``smoke()``               — reduced same-family config, one real step on
                                CPU, asserts finite outputs + shapes
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

__all__ = ["ShapeDef", "ArchSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str                       # train | prefill | decode | serve
    skip: Optional[str] = None      # reason this cell is skipped (documented)
    desc: str = ""


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str                     # lm | gnn | recsys
    shapes: Dict[str, ShapeDef]
    abstract_args: Callable[[str], tuple]
    arg_specs: Callable[[str, Any], tuple]
    out_specs: Callable[[str, Any], Any]
    step_fn: Callable[[str], Callable]
    smoke: Callable[[], dict]
    model_flops: Callable[[str], float] = lambda shape: 0.0   # 6ND-style

    def runnable_shapes(self):
        return {k: v for k, v in self.shapes.items() if v.skip is None}
