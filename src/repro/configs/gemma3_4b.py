"""gemma3-4b [dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
— 5:1 local:global, 128k context [hf:google/gemma-3-*-pt; unverified].

head_dim=256 (gemma3 family), sliding window 1024 for local layers, rope
theta 1M global / 10k local. The 5:1 pattern ((i % 6) == 5 is global) with
window-bounded local KV caches is why this is the one LM arch that runs the
long_500k decode cell (DESIGN.md §4)."""
import jax.numpy as jnp

from repro.configs.lm_family import make_lm_arch
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_head=256, d_ff=10240, vocab=262144, rope_theta=1_000_000.0,
    rope_theta_local=10_000.0, sliding_window=1024, global_every=6,
    tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = LMConfig(
    name="gemma3-smoke", n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=256, sliding_window=8, global_every=3,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0, tie_embeddings=True,
    seq_chunk=16, q_chunk=16, kv_chunk=16)


def get_arch():
    return make_lm_arch("gemma3-4b", CONFIG, SMOKE, long_ok=True)
