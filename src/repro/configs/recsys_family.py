"""RecSys-family ArchSpec builders: train_batch / serve_p99 / serve_bulk /
retrieval_cand cells for sasrec, dien, autoint, two-tower."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.common import ArchSpec, ShapeDef
from repro.models import recsys as rs
from repro.optim import AdamWConfig, init_opt_state, make_train_step
from repro.parallel import sharding as sh

__all__ = ["make_sasrec_arch", "make_dien_arch", "make_autoint_arch",
           "make_twotower_arch", "RECSYS_SHAPES"]

_ADAM = AdamWConfig(lr=1e-3, total_steps=100_000)

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    # spec says 1,000,000 candidates; padded to 2^20 for even sharding
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=1_048_576),
}

_SD = jax.ShapeDtypeStruct
_TOPK = 100


def _shape_defs():
    return {k: ShapeDef(name=k, kind=v["kind"], desc=str(v))
            for k, v in RECSYS_SHAPES.items()}


def _dp(mesh, b):
    dp = sh.dp_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return dp if (b % size == 0 and b >= size) else None


def _mk_arch(name, abstract_state, batch_struct, step_fn, batch_specs,
             out_specs_fn, smoke, model_flops):
    def abstract_args(sname):
        params, opt = abstract_state()
        if RECSYS_SHAPES[sname]["kind"] == "train":
            return (params, opt, batch_struct(sname))
        return (params, batch_struct(sname))

    def arg_specs(sname, mesh):
        params, _ = abstract_state()
        pspec = param_specs_holder[0](params)
        if RECSYS_SHAPES[sname]["kind"] == "train":
            return (pspec, sh.opt_specs(pspec), batch_specs(sname, mesh))
        return (pspec, batch_specs(sname, mesh))

    def out_specs(sname, mesh):
        params, _ = abstract_state()
        pspec = param_specs_holder[0](params)
        if RECSYS_SHAPES[sname]["kind"] == "train":
            return (P(), pspec, sh.opt_specs(pspec))
        return out_specs_fn(sname, mesh)

    param_specs_holder = [None]          # set by caller

    arch = ArchSpec(name=name, family="recsys", shapes=_shape_defs(),
                    abstract_args=abstract_args, arg_specs=arg_specs,
                    out_specs=out_specs, step_fn=step_fn, smoke=smoke,
                    model_flops=model_flops)
    return arch, param_specs_holder


# ================================================================ SASRec

def make_sasrec_arch(cfg: rs.SASRecConfig) -> ArchSpec:
    @functools.lru_cache(maxsize=None)
    def abstract_state():
        params = jax.eval_shape(lambda: rs.sasrec_init(jax.random.key(0), cfg))
        return params, jax.eval_shape(init_opt_state, params)

    def batch_struct(sname):
        s = RECSYS_SHAPES[sname]
        if s["kind"] == "train":
            b = s["batch"]
            return {k: _SD((b, cfg.seq_len), jnp.int32)
                    for k in ("seq", "pos", "neg")}
        b = s["batch"]
        return {"seq": _SD((b, cfg.seq_len), jnp.int32)}

    def step_fn(sname):
        if RECSYS_SHAPES[sname]["kind"] == "train":
            return make_train_step(lambda p, b: rs.sasrec_loss(p, cfg, b),
                                   _ADAM)
        return lambda p, batch: rs.sasrec_serve_topk(p, cfg, batch["seq"],
                                                     k=_TOPK)

    def batch_specs(sname, mesh):
        s = RECSYS_SHAPES[sname]
        b_ax = _dp(mesh, s["batch"])
        if s["kind"] == "train":
            return {k: P(b_ax, None) for k in ("seq", "pos", "neg")}
        return {"seq": P(b_ax, None)}

    def out_specs_fn(sname, mesh):
        b_ax = _dp(mesh, RECSYS_SHAPES[sname]["batch"])
        return (P(b_ax, None), P(b_ax, None))

    def model_flops(sname) -> float:
        s = RECSYS_SHAPES[sname]
        d, L = cfg.embed_dim, cfg.seq_len
        per_ex = cfg.n_blocks * (8 * L * d * d + 4 * L * L * d)
        if s["kind"] == "train":
            return 3.0 * s["batch"] * (per_ex + 4 * L * d)
        scan = 2.0 * cfg.n_items * d      # last-state x catalog
        return s["batch"] * (per_ex + scan)

    def smoke() -> dict:
        c = rs.SASRecConfig(name="sasrec-smoke", n_items=200, seq_len=12)
        p = rs.sasrec_init(jax.random.key(0), c)
        b = {k: jax.random.randint(jax.random.fold_in(jax.random.key(1), i),
                                   (4, 12), 0, 200)
             for i, k in enumerate(("seq", "pos", "neg"))}
        step = make_train_step(lambda pp, bb: rs.sasrec_loss(pp, c, bb), _ADAM)
        loss, _, _ = jax.jit(step)(p, init_opt_state(p), b)
        s, ids = rs.sasrec_serve_topk(p, c, b["seq"], k=7, item_chunk=64)
        ok = bool(jnp.isfinite(loss)) and s.shape == (4, 7)
        return {"ok": ok, "loss": float(loss), "topk_shape": tuple(s.shape)}

    arch, holder = _mk_arch("sasrec", abstract_state, batch_struct, step_fn,
                            batch_specs, out_specs_fn, smoke, model_flops)
    from repro.parallel.sharding import sasrec_param_specs
    holder[0] = sasrec_param_specs
    return arch


# ================================================================== DIEN

def make_dien_arch(cfg: rs.DIENConfig) -> ArchSpec:
    @functools.lru_cache(maxsize=None)
    def abstract_state():
        params = jax.eval_shape(lambda: rs.dien_init(jax.random.key(0), cfg))
        return params, jax.eval_shape(init_opt_state, params)

    def batch_struct(sname):
        s = RECSYS_SHAPES[sname]
        b, L = s["batch"], cfg.seq_len
        if s["kind"] == "train":
            return {"hist_items": _SD((b, L), jnp.int32),
                    "hist_cats": _SD((b, L), jnp.int32),
                    "target_item": _SD((b,), jnp.int32),
                    "target_cat": _SD((b,), jnp.int32),
                    "neg_items": _SD((b, L), jnp.int32),
                    "neg_cats": _SD((b, L), jnp.int32),
                    "label": _SD((b,), jnp.float32)}
        if sname == "retrieval_cand":
            c = s["n_candidates"]
            return {"hist_items": _SD((1, L), jnp.int32),
                    "hist_cats": _SD((1, L), jnp.int32),
                    "cand_items": _SD((c,), jnp.int32),
                    "cand_cats": _SD((c,), jnp.int32)}
        return {"hist_items": _SD((b, L), jnp.int32),
                "hist_cats": _SD((b, L), jnp.int32),
                "target_item": _SD((b,), jnp.int32),
                "target_cat": _SD((b,), jnp.int32)}

    def step_fn(sname):
        s = RECSYS_SHAPES[sname]
        if s["kind"] == "train":
            return make_train_step(lambda p, b: rs.dien_loss(p, cfg, b), _ADAM)
        if sname == "retrieval_cand":
            return lambda p, batch: rs.dien_score(p, cfg, batch)
        return lambda p, batch: rs.dien_forward(p, cfg, batch)[0]

    def batch_specs(sname, mesh):
        s = RECSYS_SHAPES[sname]
        if sname == "retrieval_cand":
            allax = tuple(mesh.axis_names)
            return {"hist_items": P(None, None), "hist_cats": P(None, None),
                    "cand_items": P(allax), "cand_cats": P(allax)}
        b_ax = _dp(mesh, s["batch"])
        spec = {"hist_items": P(b_ax, None), "hist_cats": P(b_ax, None),
                "target_item": P(b_ax), "target_cat": P(b_ax)}
        if s["kind"] == "train":
            spec.update({"neg_items": P(b_ax, None),
                         "neg_cats": P(b_ax, None), "label": P(b_ax)})
        return spec

    def out_specs_fn(sname, mesh):
        if sname == "retrieval_cand":
            return P(tuple(mesh.axis_names))
        return P(_dp(mesh, RECSYS_SHAPES[sname]["batch"]))

    def model_flops(sname) -> float:
        s = RECSYS_SHAPES[sname]
        e2, h, L = cfg.embed_dim * 2, cfg.gru_dim, cfg.seq_len
        gru = 6 * L * (e2 * h + h * h)
        augru = 6 * L * (h * h + h * h) + 2 * L * (h + e2)
        mlp = 2 * ((h + 2 * e2) * 200 + 200 * 80 + 80)
        if s["kind"] == "train":
            return 3.0 * s["batch"] * (gru + augru + mlp)
        n = s.get("n_candidates", s["batch"])
        shared = gru if sname == "retrieval_cand" else n * gru
        return shared + n * (augru + mlp)

    def smoke() -> dict:
        c = rs.DIENConfig(name="dien-smoke", n_items=300, n_cats=20,
                          seq_len=6)
        p = rs.dien_init(jax.random.key(0), c)
        ks = jax.random.split(jax.random.key(1), 7)
        b = {"hist_items": jax.random.randint(ks[0], (4, 6), 0, 300),
             "hist_cats": jax.random.randint(ks[1], (4, 6), 0, 20),
             "target_item": jax.random.randint(ks[2], (4,), 0, 300),
             "target_cat": jax.random.randint(ks[3], (4,), 0, 20),
             "neg_items": jax.random.randint(ks[4], (4, 6), 0, 300),
             "neg_cats": jax.random.randint(ks[5], (4, 6), 0, 20),
             "label": (jax.random.uniform(ks[6], (4,)) > 0.5).astype(
                 jnp.float32)}
        step = make_train_step(lambda pp, bb: rs.dien_loss(pp, c, bb), _ADAM)
        loss, _, _ = jax.jit(step)(p, init_opt_state(p), b)
        sc = rs.dien_score(p, c, {"hist_items": b["hist_items"][:1],
                                  "hist_cats": b["hist_cats"][:1],
                                  "cand_items": jnp.arange(32),
                                  "cand_cats": jnp.zeros(32, jnp.int32)})
        ok = bool(jnp.isfinite(loss)) and sc.shape == (32,)
        return {"ok": ok, "loss": float(loss), "scores": tuple(sc.shape)}

    arch, holder = _mk_arch("dien", abstract_state, batch_struct, step_fn,
                            batch_specs, out_specs_fn, smoke, model_flops)
    from repro.parallel.sharding import dien_param_specs
    holder[0] = dien_param_specs
    return arch


# ================================================================ AutoInt

def make_autoint_arch(cfg: rs.AutoIntConfig) -> ArchSpec:
    @functools.lru_cache(maxsize=None)
    def abstract_state():
        params = jax.eval_shape(
            lambda: rs.autoint_init(jax.random.key(0), cfg))
        return params, jax.eval_shape(init_opt_state, params)

    def batch_struct(sname):
        s = RECSYS_SHAPES[sname]
        if sname == "retrieval_cand":
            return {"user_fields": _SD((cfg.n_fields - 1,), jnp.int32),
                    "cand_ids": _SD((s["n_candidates"],), jnp.int32)}
        b = s["batch"]
        spec = {"field_ids": _SD((b, cfg.n_fields), jnp.int32)}
        if s["kind"] == "train":
            spec["label"] = _SD((b,), jnp.float32)
        return spec

    def step_fn(sname):
        s = RECSYS_SHAPES[sname]
        if s["kind"] == "train":
            return make_train_step(lambda p, b: rs.autoint_loss(p, cfg, b),
                                   _ADAM)
        if sname == "retrieval_cand":
            return lambda p, batch: rs.autoint_score_candidates(
                p, cfg, batch["user_fields"], batch["cand_ids"])
        return lambda p, batch: rs.autoint_forward(p, cfg, batch["field_ids"])

    def batch_specs(sname, mesh):
        s = RECSYS_SHAPES[sname]
        if sname == "retrieval_cand":
            return {"user_fields": P(None),
                    "cand_ids": P(tuple(mesh.axis_names))}
        b_ax = _dp(mesh, s["batch"])
        spec = {"field_ids": P(b_ax, None)}
        if s["kind"] == "train":
            spec["label"] = P(b_ax)
        return spec

    def out_specs_fn(sname, mesh):
        if sname == "retrieval_cand":
            return P(tuple(mesh.axis_names))
        return P(_dp(mesh, RECSYS_SHAPES[sname]["batch"]))

    def model_flops(sname) -> float:
        s = RECSYS_SHAPES[sname]
        f, d_out = cfg.n_fields, cfg.n_heads * cfg.d_attn
        per_ex = cfg.n_attn_layers * (8 * f * cfg.embed_dim * d_out
                                      + 4 * f * f * d_out) + 2 * f * d_out
        n = s.get("n_candidates", s["batch"])
        mult = 3.0 if s["kind"] == "train" else 1.0
        return mult * n * per_ex

    def smoke() -> dict:
        c = rs.AutoIntConfig(name="autoint-smoke", n_fields=6,
                             vocab_per_field=50)
        p = rs.autoint_init(jax.random.key(0), c)
        b = {"field_ids": jax.random.randint(jax.random.key(1), (8, 6), 0, 50),
             "label": (jax.random.uniform(jax.random.key(2), (8,)) > 0.5
                       ).astype(jnp.float32)}
        step = make_train_step(lambda pp, bb: rs.autoint_loss(pp, c, bb),
                               _ADAM)
        loss, _, _ = jax.jit(step)(p, init_opt_state(p), b)
        sc = rs.autoint_score_candidates(
            p, c, jnp.zeros((5,), jnp.int32), jnp.arange(32), chunk=16)
        ok = bool(jnp.isfinite(loss)) and sc.shape == (32,)
        return {"ok": ok, "loss": float(loss)}

    arch, holder = _mk_arch("autoint", abstract_state, batch_struct, step_fn,
                            batch_specs, out_specs_fn, smoke, model_flops)
    from repro.parallel.sharding import autoint_param_specs
    holder[0] = autoint_param_specs
    return arch


# ============================================================== Two-tower

def make_twotower_arch(cfg: rs.TwoTowerConfig, mpad_dim: int = 64,
                       rerank: int = 256, mode: str = "mpad") -> ArchSpec:
    """``mode`` selects the retrieval_cand serving path (§Perf hillclimb):
    full  — paper baseline: f32 full-dim scan of all candidates
    mpad  — the paper's technique: offline-reduced (C, m) cache + re-rank
    int8  — beyond-paper: int8-quantized reduced cache + re-rank
    """
    @functools.lru_cache(maxsize=None)
    def abstract_state():
        params = jax.eval_shape(
            lambda: rs.twotower_init(jax.random.key(0), cfg))
        return params, jax.eval_shape(init_opt_state, params)

    def batch_struct(sname):
        s = RECSYS_SHAPES[sname]
        if sname == "retrieval_cand":
            c = s["n_candidates"]
            base = {"user_ids": _SD((1,), jnp.int32),
                    "hist_ids": _SD((1, cfg.n_user_feats), jnp.int32),
                    "cand_emb": _SD((c, cfg.embed_dim), jnp.float32)}
            if mode == "full":
                return base
            base.update({
                "red_matrix": _SD((mpad_dim, cfg.embed_dim), jnp.float32),
                "red_mean": _SD((cfg.embed_dim,), jnp.float32)})
            if mode == "int8":
                base.update({
                    "cand_red_q": _SD((c, mpad_dim), jnp.int8),
                    "cand_scale": _SD((mpad_dim,), jnp.float32)})
            else:
                base["cand_red"] = _SD((c, mpad_dim), jnp.float32)
            return base
        b = s["batch"]
        spec = {"user_ids": _SD((b,), jnp.int32),
                "hist_ids": _SD((b, cfg.n_user_feats), jnp.int32)}
        if s["kind"] == "train":
            spec.update({"pos_items": _SD((b,), jnp.int32),
                         "neg_items": _SD((cfg.n_negatives,), jnp.int32),
                         "neg_logq": _SD((cfg.n_negatives,), jnp.float32)})
        else:
            spec["item_ids"] = _SD((b,), jnp.int32)
        return spec

    def step_fn(sname):
        s = RECSYS_SHAPES[sname]
        if s["kind"] == "train":
            return make_train_step(lambda p, b: rs.twotower_loss(p, cfg, b),
                                   _ADAM)
        if sname == "retrieval_cand":
            def retrieve(p, batch):
                if mode == "full":
                    return rs.twotower_retrieve(p, cfg, batch, k=_TOPK)
                return rs.twotower_retrieve(
                    p, cfg, batch, k=_TOPK,
                    reducer=(batch["red_matrix"], batch["red_mean"]),
                    rerank=rerank, quantized=(mode == "int8"))
            return retrieve

        def serve(p, batch):                       # pairwise scoring
            u = rs.twotower_user(p, cfg, batch["user_ids"], batch["hist_ids"])
            v = rs.twotower_item(p, cfg, batch["item_ids"])
            return jnp.sum(u * v, axis=-1)
        return serve

    def batch_specs(sname, mesh):
        s = RECSYS_SHAPES[sname]
        if sname == "retrieval_cand":
            allax = tuple(mesh.axis_names)
            spec = {"user_ids": P(None), "hist_ids": P(None, None),
                    "cand_emb": P(allax, None)}
            if mode == "full":
                return spec
            spec.update({"red_matrix": P(None, None), "red_mean": P(None)})
            if mode == "int8":
                spec.update({"cand_red_q": P(allax, None),
                             "cand_scale": P(None)})
            else:
                spec["cand_red"] = P(allax, None)
            return spec
        b_ax = _dp(mesh, s["batch"])
        spec = {"user_ids": P(b_ax), "hist_ids": P(b_ax, None)}
        if s["kind"] == "train":
            spec.update({"pos_items": P(b_ax), "neg_items": P(None),
                         "neg_logq": P(None)})
        else:
            spec["item_ids"] = P(b_ax)
        return spec

    def out_specs_fn(sname, mesh):
        if sname == "retrieval_cand":
            return (P(None), P(None))
        return P(_dp(mesh, RECSYS_SHAPES[sname]["batch"]))

    def model_flops(sname) -> float:
        s = RECSYS_SHAPES[sname]
        dims = (cfg.field_dim * 2,) + cfg.tower_dims
        tower = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        if s["kind"] == "train":
            return 3.0 * s["batch"] * (2 * tower) + \
                3.0 * 2 * s["batch"] * cfg.n_negatives * cfg.embed_dim
        if sname == "retrieval_cand":
            n = s["n_candidates"]
            return tower + 2.0 * n * mpad_dim + 2.0 * rerank * cfg.embed_dim
        return s["batch"] * 2 * tower

    def smoke() -> dict:
        c = rs.TwoTowerConfig(name="tt-smoke", n_users=200, n_items=100,
                              n_negatives=16)
        p = rs.twotower_init(jax.random.key(0), c)
        ks = jax.random.split(jax.random.key(1), 4)
        b = {"user_ids": jax.random.randint(ks[0], (8,), 0, 200),
             "hist_ids": jax.random.randint(ks[1], (8, c.n_user_feats), 0, 100),
             "pos_items": jax.random.randint(ks[2], (8,), 0, 100),
             "neg_items": jax.random.randint(ks[3], (16,), 0, 100),
             "neg_logq": jnp.full((16,), -float(np.log(100.0)))}
        step = make_train_step(lambda pp, bb: rs.twotower_loss(pp, c, bb),
                               _ADAM)
        loss, _, _ = jax.jit(step)(p, init_opt_state(p), b)
        cand = rs.twotower_item(p, c, jnp.arange(100))
        from repro.core import fit_mpad, MPADConfig
        red = fit_mpad(cand, MPADConfig(m=16, iters=8))
        s, ids = rs.twotower_retrieve(
            p, c, {"user_ids": b["user_ids"][:1],
                   "hist_ids": b["hist_ids"][:1], "cand_emb": cand},
            k=5, reducer=(red.matrix, red.mean), rerank=20)
        ok = bool(jnp.isfinite(loss)) and ids.shape == (5,)
        return {"ok": ok, "loss": float(loss)}

    arch, holder = _mk_arch("two-tower-retrieval", abstract_state,
                            batch_struct, step_fn, batch_specs, out_specs_fn,
                            smoke, model_flops)
    from repro.parallel.sharding import twotower_param_specs
    holder[0] = twotower_param_specs
    return arch
