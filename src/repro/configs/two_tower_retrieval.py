"""two-tower-retrieval [recsys] embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval [RecSys'19 (YouTube);
unverified].

The paper-native cell: retrieval_cand serves 1 query against ~10^6
candidates scored in MPAD-reduced space (256 -> 64) with exact re-rank of
the top 256 (DESIGN.md §4)."""
from repro.configs.recsys_family import make_twotower_arch
from repro.models.recsys import TwoTowerConfig

CONFIG = TwoTowerConfig(name="two-tower-retrieval", n_users=5_000_000,
                        n_items=2_000_000, n_user_feats=8, field_dim=64,
                        embed_dim=256, tower_dims=(1024, 512, 256),
                        n_negatives=8192)

MPAD_DIM = 64          # reduced serving dimension (the paper's technique)
RERANK = 256


def get_arch():
    return make_twotower_arch(CONFIG, mpad_dim=MPAD_DIM, rerank=RERANK)
