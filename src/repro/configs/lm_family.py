"""LM-family ArchSpec builder: train_4k / prefill_32k / decode_32k /
long_500k cells for the five assigned transformer architectures."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import ArchSpec, ShapeDef
from repro.models import transformer as tf
from repro.models.moe import MoEConfig
from repro.optim import AdamWConfig, init_opt_state, make_train_step
from repro.parallel import sharding as sh

__all__ = ["make_lm_arch", "lm_param_count", "LM_SHAPES"]

LM_SHAPES = {
    "train_4k": dict(kind="train", batch=256, seq=4096),
    "prefill_32k": dict(kind="prefill", batch=32, seq=32768),
    "decode_32k": dict(kind="decode", batch=128, seq=32768),
    "long_500k": dict(kind="decode", batch=1, seq=524288),
}

_ADAM = AdamWConfig(lr=3e-4, total_steps=100_000)


def lm_param_count(cfg: tf.LMConfig, active_only: bool = False) -> float:
    d, dh = cfg.d_model, cfg.d_head
    attn = d * cfg.n_heads * dh * 2 + d * cfg.n_kv_heads * dh * 2
    if cfg.moe is None:
        mlp = 3 * d * cfg.d_ff
    else:
        e = cfg.moe.top_k if active_only else cfg.moe.n_experts
        mlp = 3 * d * cfg.moe.d_ff * e + d * cfg.moe.n_experts
    emb = cfg.vocab_padded * d * (1 if cfg.tie_embeddings else 2)
    return float(cfg.n_layers * (attn + mlp + 2 * d) + emb + d)


def _with_moe_impl(cfg: tf.LMConfig, impl: str) -> tf.LMConfig:
    if cfg.moe is None or cfg.moe.impl == impl:
        return cfg
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl=impl))


def make_lm_arch(name: str, cfg: tf.LMConfig, smoke_cfg: tf.LMConfig,
                 long_ok: bool, long_skip_reason: str = "",
                 zero_opt: bool = True) -> ArchSpec:
    """``zero_opt``: shard Adam moments over the DP axes as well (ZeRO-1);
    validated as a §Perf iteration — cuts per-device optimizer memory by
    dp_size at the cost of a params all-gather in the update."""
    shapes = {}
    for sname, s in LM_SHAPES.items():
        skip = None
        if sname == "long_500k" and not long_ok:
            skip = long_skip_reason or (
                "pure full attention on every layer: no sub-quadratic "
                "structure for 512k decode (DESIGN.md §4)")
        shapes[sname] = ShapeDef(name=sname, kind=s["kind"], skip=skip,
                                 desc=f"B={s['batch']} S={s['seq']}")

    def shape_cfg(sname) -> tf.LMConfig:
        kind = LM_SHAPES[sname]["kind"]
        if kind == "decode":       # tiny token counts: dense combine
            return _with_moe_impl(cfg, "dense")
        return cfg                 # train/prefill: configured impl

    @functools.lru_cache(maxsize=None)
    def abstract_state():
        c = cfg
        params = jax.eval_shape(lambda: tf.lm_init_params(jax.random.key(0), c))
        opt = jax.eval_shape(init_opt_state, params)
        return params, opt

    def abstract_args(sname: str):
        s = LM_SHAPES[sname]
        params, opt = abstract_state()
        b, seq = s["batch"], s["seq"]
        if s["kind"] == "train":
            batch = {"tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((b, seq), jnp.int32)}
            return (params, opt, batch)
        cache = jax.eval_shape(
            lambda: tf.init_cache(cfg, b, seq))
        if s["kind"] == "prefill":
            tokens = jax.ShapeDtypeStruct((b, seq), jnp.int32)
            return (params, tokens, cache)
        token = jax.ShapeDtypeStruct((b,), jnp.int32)
        cur = jax.ShapeDtypeStruct((), jnp.int32)
        return (params, token, cur, cache)

    def step_fn(sname: str):
        s = LM_SHAPES[sname]
        c = shape_cfg(sname)
        if s["kind"] == "train":
            loss_fn = lambda p, batch: tf.lm_train_forward(p, c, batch)
            return make_train_step(loss_fn, _ADAM)
        if s["kind"] == "prefill":
            return lambda p, tokens, cache: tf.lm_prefill(p, c, tokens, cache)
        return lambda p, token, cur, cache: tf.lm_decode_step(
            p, c, token, cur, cache)

    def arg_specs(sname: str, mesh):
        s = LM_SHAPES[sname]
        dp = sh.dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        pspec = sh.lm_param_specs(cfg)
        b = s["batch"]
        b_ax = dp if (b % dp_size == 0 and b >= dp_size) else None
        if s["kind"] == "train":
            bspec = {"tokens": P(b_ax, None), "labels": P(b_ax, None)}
            params_abs, _ = abstract_state()
            ospec = (sh.zero_opt_specs(params_abs, pspec, mesh)
                     if zero_opt else sh.opt_specs(pspec))
            return (pspec, ospec, bspec)
        cspec = sh.lm_cache_specs(cfg, mesh, b, s["seq"])
        if s["kind"] == "prefill":
            return (pspec, P(b_ax, None), cspec)
        return (pspec, P(b_ax), P(), cspec)

    def out_specs(sname: str, mesh):
        s = LM_SHAPES[sname]
        dp = sh.dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        pspec = sh.lm_param_specs(cfg)
        b = s["batch"]
        b_ax = dp if (b % dp_size == 0 and b >= dp_size) else None
        if s["kind"] == "train":
            params_abs, _ = abstract_state()
            ospec = (sh.zero_opt_specs(params_abs, pspec, mesh)
                     if zero_opt else sh.opt_specs(pspec))
            return (P(), pspec, ospec)
        cspec = sh.lm_cache_specs(cfg, mesh, b, s["seq"])
        return (P(b_ax, "model"), cspec)     # logits vocab-sharded

    def model_flops(sname: str) -> float:
        s = LM_SHAPES[sname]
        n_active = lm_param_count(cfg, active_only=True)
        tokens = s["batch"] * (s["seq"] if s["kind"] in ("train", "prefill")
                               else 1)
        mult = 6.0 if s["kind"] == "train" else 2.0   # fwd+bwd vs fwd
        return mult * n_active * tokens

    def smoke() -> dict:
        c = smoke_cfg
        key = jax.random.key(0)
        params = tf.lm_init_params(key, c)
        b, s = 2, 32
        toks = jax.random.randint(jax.random.key(1), (b, s), 0, c.vocab)
        step = make_train_step(
            lambda p, batch: tf.lm_train_forward(p, _with_moe_impl(c, "dispatch"), batch),
            _ADAM)
        loss, params2, _ = jax.jit(step)(params, init_opt_state(params),
                                         {"tokens": toks, "labels": toks})
        cache = tf.init_cache(c, b, s + 4)
        logits, cache = jax.jit(
            lambda p, t, ca: tf.lm_prefill(p, c, t, ca))(params, toks, cache)
        nxt = jnp.argmax(logits[:, :c.vocab], axis=-1).astype(jnp.int32)
        logits2, _ = jax.jit(
            lambda p, t, n, ca: tf.lm_decode_step(p, c, t, n, ca))(
            params, nxt, jnp.int32(s), cache)
        ok = bool(jnp.isfinite(loss) and jnp.all(jnp.isfinite(logits2)))
        return {"ok": ok, "loss": float(loss),
                "logits_shape": tuple(logits2.shape),
                "expect_vocab": c.vocab_padded}

    return ArchSpec(
        name=name, family="lm", shapes=shapes,
        abstract_args=abstract_args, arg_specs=arg_specs,
        out_specs=out_specs, step_fn=step_fn, smoke=smoke,
        model_flops=model_flops)
