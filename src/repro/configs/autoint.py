"""autoint [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn [arXiv:1810.11921; paper].

Criteo-like: 39 sparse fields, 100k hash vocab per field."""
from repro.configs.recsys_family import make_autoint_arch
from repro.models.recsys import AutoIntConfig

CONFIG = AutoIntConfig(name="autoint", n_fields=39, vocab_per_field=100_000,
                       embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32)


def get_arch():
    return make_autoint_arch(CONFIG)
