"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base;
hf]. vocab padded 49155 -> 49408 (multiple of 256) for even vocab sharding."""
import jax.numpy as jnp

from repro.configs.lm_family import make_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=8, d_head=64, d_ff=0, vocab=49155, rope_theta=10000.0,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, capacity_factor=1.25,
                  impl="ep"),
    tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = LMConfig(
    name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=0, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=2.0,
                  impl="dispatch"),
    tie_embeddings=True, seq_chunk=16, q_chunk=16, kv_chunk=16)


def get_arch():
    return make_lm_arch("granite-moe-1b-a400m", CONFIG, SMOKE, long_ok=False)
