"""Architecture registry: ``--arch <id>`` resolution for launchers/dry-run."""
from __future__ import annotations

import importlib
from typing import Dict

from .common import ArchSpec

ARCH_MODULES = {
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "gin-tu": "repro.configs.gin_tu",
    "sasrec": "repro.configs.sasrec",
    "dien": "repro.configs.dien",
    "autoint": "repro.configs.autoint",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
}

_cache: Dict[str, ArchSpec] = {}


def get_arch(name: str) -> ArchSpec:
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(ARCH_MODULES)}")
    if name not in _cache:
        _cache[name] = importlib.import_module(ARCH_MODULES[name]).get_arch()
    return _cache[name]


def all_arch_names():
    return list(ARCH_MODULES)
