"""IVF-PQ: coarse k-means quantizer + product-quantized **residuals** —
the classic memory-hierarchy composition for production vector search
(reduce dims -> coarse-quantize -> PQ-code what the centroid missed).

Layout matches ``ivf.py``: padded-dense posting lists (nlist, max_cell)
with -1 pads, so probe-scan is gather + masked top-k (TPU-idiomatic, no
ragged structures on device). Codebooks are trained on residuals
``x - centroid[assign(x)]`` and shared across cells (standard IVF-ADC).

Scoring uses the exact residual decomposition so the per-query LUT is
cell-independent — the same (Q, M, K) shape as plain PQ, which is what lets
the fused ADC kernel serve both index types. With reconstruction
x̂ = c + r̂, r̂_m = cb[m, code_m]:

  ||q - x̂||² = ||q - c||²                                   (coarse term,
                                                 already computed to probe)
             + Σ_m ( ||cb[m,code_m]||² - 2⟨q_m, cb[m,code_m]⟩ )   (query LUT)
             + 2 Σ_m ⟨c_m, cb[m,code_m]⟩                 (per-id build-time
                                                          scalar: ``bias``)

No approximation beyond PQ itself: the cross terms are exact.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc.ref import pq_adc_gather_scores_ref
from .ivf import (_balanced_layout, kmeans, posting_lists, probe_cells,
                  sq_dists)
from .pq import _check_adc_args, adc_tables, build_pq

__all__ = ["IVFPQIndex", "build_ivfpq", "ivfpq_adc_scan",
           "ivfpq_compact_scan", "ivfpq_local_scan", "ivfpq_lut_stats",
           "ivfpq_scan", "ivfpq_search"]


class IVFPQIndex(NamedTuple):
    centroids: jax.Array    # (nlist, d) coarse quantizer
    lists: jax.Array        # (nlist, max_cell) int32 vector ids, -1 = pad
    codebooks: jax.Array    # (M, K, dsub) residual-space PQ codebooks
    codes: jax.Array        # (N, M) uint8/int32 residual codes, id-aligned
    bias: jax.Array         # (N,) f32: 2·Σ_m ⟨cent[assign]_m, cb[m, code_m]⟩
    rerr: jax.Array         # (N,) f32 per-row PQ reconstruction error
                            # ||x - x̂||, the exact-distance bound used by
                            # the re-rank candidate pre-filter
    # cell-major serving mirrors of codes/bias: probe-time access becomes
    # nprobe contiguous row-block gathers instead of |cand| scattered ones
    codes_cell: jax.Array   # (nlist, max_cell, M) uint8 (int32 if K > 256)
    bias_cell: jax.Array    # (nlist, max_cell) f32, 0 on pads
    lut_w: jax.Array        # (d, M*K) block-diagonal -2*codebook projection
    cbnorm: jax.Array       # (M, K) residual codeword squared norms


def build_ivfpq(key: jax.Array, vectors: jax.Array, nlist: int,
                m_subspaces: int = 8, n_centroids: int = 256,
                kmeans_iters: int = 12, pq_iters: int = 10,
                shards: int = 1, balance: bool = True) -> IVFPQIndex:
    """Coarse k-means, then per-subspace codebooks on the residuals.

    ``shards`` pads the cell axis of the cell-major serving mirrors
    (``lists``/``codes_cell``/``bias_cell``) to per-shard-equal shapes
    (see ``posting_lists``); ``balance`` additionally permutes the cell
    axis so the per-shard blocks carry near-equal posting **mass**
    (``repro.search.ivf.balance_cells`` — the load-aware placement for
    skewed corpora). Quantization and scan results are unchanged either
    way.
    """
    vectors = jnp.asarray(vectors, jnp.float32)
    n, d = vectors.shape
    cent = kmeans(key, vectors, nlist, kmeans_iters)
    assign = jnp.argmin(sq_dists(vectors, cent), axis=1)  # (N,)
    if balance and shards > 1:
        cent, assign = _balanced_layout(cent, assign, nlist, shards)
    lists = posting_lists(assign, nlist, shards)
    residuals = vectors - cent[assign]
    pq = build_pq(jax.random.fold_in(key, 7), residuals,
                  m_subspaces, n_centroids, pq_iters)
    # per-id centroid/codeword cross term (see module docstring)
    dsub = d // m_subspaces
    csub = cent[assign].reshape(n, m_subspaces, dsub)     # (N, M, dsub)
    recon = jnp.take_along_axis(
        pq.codebooks[None], pq.codes[:, :, None, None], axis=2
    )[:, :, 0, :]                                         # (N, M, dsub)
    bias = 2.0 * jnp.sum(csub * recon, axis=(1, 2))       # (N,)
    rerr = jnp.sqrt(jnp.sum(
        (residuals - recon.reshape(n, d)) ** 2, axis=1))  # (N,) ||x - x̂||
    lid = jnp.maximum(lists, 0)
    code_dt = jnp.uint8 if pq.codebooks.shape[1] <= 256 else jnp.int32
    return IVFPQIndex(centroids=cent, lists=lists, codebooks=pq.codebooks,
                      codes=pq.codes, bias=bias.astype(jnp.float32),
                      rerr=rerr.astype(jnp.float32),
                      codes_cell=pq.codes[lid].astype(code_dt),
                      bias_cell=jnp.where(lists >= 0, bias[lid], 0.0
                                          ).astype(jnp.float32),
                      lut_w=pq.lut_w, cbnorm=pq.cbnorm)


def ivfpq_lut_stats(codebooks: jax.Array, cbnorm: jax.Array, q: jax.Array,
                    lut_dtype: str):
    """Analytic centering + certified int8 scale for the quantized LUT.

    The old path centered the computed (Q, M, K) tables empirically
    (``center_lut``) and, for int8, took ``max|t|`` over the whole table —
    two full-table reductions per batch. Both follow analytically from the
    codebook geometry instead, at O(M * K * dsub) cost (the codebooks are
    ~100x smaller than a serving batch's tables):

      t[q, m, k]  = cbnorm[m, k] - 2 <q_m, cb[m, k]>
      rowmean[q, m] = mean_k t[q, m, :]
                    = mean_k cbnorm[m, :] - 2 <q_m, mean_k cb[m, :]>

    and with ``t_c = t - rowmean`` (the part the grid has to cover),

      |t_c[q, m, k]| <= max_k|cbnorm_c[m, :]| + ||q_m|| * max_k||-2 cb_c[m, k]||

    by Cauchy-Schwarz on the centered codewords — a certified bound, so the
    int8 grid built from it never clips. The tiny (1 + 1e-5) headroom
    absorbs the f32 rounding of ``t`` itself.

    [measured trade, don't "fix" either way without re-measuring both: the
    bound runs ~1.4-1.9x looser than the true ``max|t_c|``, which costs
    nothing on the bench corpus (recall gate) but ~0.05 recall@10 on a
    heavy-cluster corpus whose ADC gaps are comparable to the grid step;
    the exact scale (abs-max over the materialized tables, or min/max per
    row — both tried) re-reads the (Q, M, K) tables and costs ~13% of int8
    scan throughput on CPU, failing the int8 >= 0.95x-of-f32 QPS gate. A
    per-codeword Cauchy-Schwarz bound is no tighter on exactly the corpora
    that hurt and costs as much as the exact pass.]

    Returns (rowmean (Q, M) f32, scale (Q,) f32 or None when ``lut_dtype``
    needs no scale). Centering any fixed per-(q, m) constant is exact —
    the ADC sum restores ``sum_m rowmean`` through the f32 ``base`` term —
    so the analytic mean does not need to match the empirical one.
    """
    nq = q.shape[0]
    m, kc = cbnorm.shape
    dsub = codebooks.shape[2]
    qs = q.reshape(nq, m, dsub)
    wmean = -2.0 * jnp.mean(codebooks, axis=1)            # (M, dsub)
    cbmean = jnp.mean(cbnorm, axis=1)                     # (M,)
    rowmean = cbmean[None] + jnp.einsum("qmd,md->qm", qs, wmean)
    if lut_dtype != "int8":
        return rowmean, None
    w_c = -2.0 * codebooks - wmean[:, None, :]            # centered codewords
    wmax = jnp.max(jnp.sqrt(jnp.sum(w_c * w_c, axis=2)), axis=1)   # (M,)
    cbmax = jnp.max(jnp.abs(cbnorm - cbmean[:, None]), axis=1)     # (M,)
    qn = jnp.sqrt(jnp.sum(qs * qs, axis=2))               # (Q, M)
    bound = jnp.max(cbmax[None] + qn * wmax[None], axis=1) * (1.0 + 1e-5)
    return rowmean, jnp.maximum(bound, 1e-12) / 127.0


def ivfpq_adc_scan(centroids: jax.Array, lists: jax.Array,
                   codes_cell: jax.Array, bias_cell: jax.Array,
                   lut_w: jax.Array, cbnorm: jax.Array,
                   codebooks: jax.Array, q: jax.Array,
                   n_cand: int, nprobe: int = 8, backend: str = "jnp",
                   interpret: bool = True, lut_dtype: str = "f32",
                   live=None):
    """Probe + cell-major ADC scan over raw index arrays — the shared core
    of ``ivfpq_scan`` (read-only serving) and the streaming masked scan.

    ``live`` (optional (N,) bool keyed by row id) masks
    tombstoned/unallocated rows; like the posting-pad mask it rides the
    additive ``base`` term, so it works identically on both scoring
    backends. Returns (d2 (Q, n_cand) SQUARED approximate distances, ids
    (Q, n_cand)) with (+inf, -1) on masked/unfilled slots.
    """
    _check_adc_args(backend, lut_dtype)
    q = jnp.asarray(q, jnp.float32)
    # coarse probe: distances to every centroid, keep the nprobe nearest
    probe, cand, cd2p = probe_cells(centroids, lists, q,
                                    nprobe, n_cand)       # (Q,P),(Q,C),(Q,P)
    return ivfpq_scan_given_probe(probe, cand, cd2p, codes_cell, bias_cell,
                                  lut_w, cbnorm, codebooks, q, n_cand,
                                  backend=backend, interpret=interpret,
                                  lut_dtype=lut_dtype, live=live)


def ivfpq_scan_given_probe(probe: jax.Array, cand: jax.Array,
                           cd2p: jax.Array, codes_cell: jax.Array,
                           bias_cell: jax.Array, lut_w: jax.Array,
                           cbnorm: jax.Array, codebooks: jax.Array,
                           q: jax.Array, n_cand: int, backend: str = "jnp",
                           interpret: bool = True, lut_dtype: str = "f32",
                           live=None):
    """ADC scan given an already-computed coarse probe — the back half of
    ``ivfpq_adc_scan``, split out so the deep-trace staged pipeline can
    time probe and scan as separate programs with identical math.
    """
    q = jnp.asarray(q, jnp.float32)
    nq = q.shape[0]
    m, kc = cbnorm.shape
    # cell-independent query LUT over residual codebooks: (Q, M, K), ONE
    # dense matmul via the build-time block-diagonal factorization.
    # Only this LUT is quantized under lut_dtype; the coarse distance +
    # cross-term ``base`` stays f32 (it is O(1) memory, not a table).
    tables = adc_tables(lut_w, cbnorm, q)
    # candidate codes + bias through the cell-major mirrors: nprobe
    # contiguous (max_cell, M) row blocks per query, no scattered gather;
    # codes stay at stored width (uint8) — backends widen in-register
    max_cell = codes_cell.shape[1]
    ccodes = codes_cell[probe].reshape(nq, -1, m)
    base = (jnp.repeat(cd2p, max_cell, axis=1)
            + bias_cell[probe].reshape(nq, -1))           # (Q, P*max_cell)
    short = cand.shape[1] - base.shape[1]                 # degenerate budget
    if short:
        ccodes = jnp.pad(ccodes, ((0, 0), (0, short), (0, 0)))
        base = jnp.pad(base, ((0, 0), (0, short)))
    ok = cand >= 0                                        # mask posting pads
    if live is not None:
        ok &= live[jnp.clip(cand, 0, live.shape[0] - 1)]
    base = jnp.where(ok, base, jnp.inf)
    center = scale = None
    if lut_dtype == "int8":
        # analytic row-mean centering + certified int8 scale: the int8 grid
        # only has to cover the candidate-varying part of the table, with
        # no table-wide reduction. bf16 is NOT centered — its rounding
        # error is relative, so centering buys nothing and would cost the
        # stats einsum + an extra table pass. The omitted per-query
        # constant sum_m center is restored after top-k, where it touches
        # k values, not P*max_cell.
        center, scale = ivfpq_lut_stats(codebooks, cbnorm, q, lut_dtype)
    k_eff = min(n_cand, cand.shape[1])
    if backend == "kernel":
        from repro.kernels.pq_adc import pq_adc_gather_topk_pallas
        kt = tables if center is None else tables - center[:, :, None]
        d2, sel = pq_adc_gather_topk_pallas(kt, ccodes, base, k_eff,
                                            interpret=interpret,
                                            lut_dtype=lut_dtype, scale=scale)
    else:
        adc = pq_adc_gather_scores_ref(tables, ccodes, base, lut_dtype,
                                       scale, center)
        neg, sel = jax.lax.top_k(-adc, k_eff)
        d2 = -neg
    if center is not None:
        d2 = d2 + jnp.sum(center, axis=1)[:, None]        # inf pads stay inf
    # the kernel marks unfilled slots sel=-1; don't let them wrap the gather
    ids = jnp.where(sel >= 0,
                    jnp.take_along_axis(cand, jnp.maximum(sel, 0), axis=1),
                    -1)
    ids = jnp.where(jnp.isinf(d2), -1, ids)
    if k_eff < n_cand:
        d2 = jnp.pad(d2, ((0, 0), (0, n_cand - k_eff)),
                     constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, n_cand - k_eff)),
                      constant_values=-1)
    return d2, ids


def ivfpq_compact_scan(centroids: jax.Array, lists: jax.Array,
                       codes_cell: jax.Array, bias_cell: jax.Array,
                       lut_w: jax.Array, cbnorm: jax.Array,
                       codebooks: jax.Array, q: jax.Array,
                       n_cand: int, nprobe: int = 8, scan_cap: int = 128,
                       backend: str = "jnp", interpret: bool = True,
                       lut_dtype: str = "f32"):
    """nprobe-proportional ADC scan for small query buckets.

    The padded scan (``ivfpq_adc_scan``) gathers ``nprobe * max_cell``
    candidate slots per query regardless of how full the probed cells
    actually are; on skewed corpora most of those slots are -1 pads, and at
    small batch the wasted gather+score work dominates. This variant sizes
    work by actual posting mass instead: per-query prefix sums over the
    probed cell lengths map a flat slot ``j < scan_cap`` to (cell, in-cell
    slot), so only the first ``Σ len(probe_i)`` slots carry real candidates
    and the gather width is the **static** cap, not ``nprobe * max_cell``.

    Relies on the packed-prefix invariant of ``posting_lists`` /
    ``compact_fn``: every list row holds its real ids in slots
    ``[0, count)`` followed by -1 pads. Candidates are enumerated
    probe-major in in-cell slot order — exactly the padded scan's order
    minus the pads — so ``top_k`` tie-breaking (lowest index first) picks
    the same ids and the result is bit-identical to ``ivfpq_adc_scan``
    whenever ``scan_cap`` covers each query's probed mass (the engine
    guarantees this: cap = total mass of the ``nprobe`` largest cells).
    """
    _check_adc_args(backend, lut_dtype)
    if scan_cap <= 0:
        raise ValueError("ivfpq_compact_scan needs scan_cap > 0")
    q = jnp.asarray(q, jnp.float32)
    nq = q.shape[0]
    m, kc = cbnorm.shape
    cd2 = sq_dists(q, centroids)                          # (Q, nlist)
    _, probe = jax.lax.top_k(-cd2, nprobe)                # probe_cells order
    cd2p = jnp.take_along_axis(cd2, probe, axis=1)
    tables = adc_tables(lut_w, cbnorm, q)
    lens = jnp.sum(lists >= 0, axis=1).astype(jnp.int32)  # (nlist,) mass
    plens = lens[probe]                                   # (Q, P)
    cum = jnp.cumsum(plens, axis=1)                       # inclusive
    start = cum - plens
    total = cum[:, -1:]
    j = jnp.arange(scan_cap, dtype=jnp.int32)[None, :]    # flat slots (1, S)
    # flat slot -> probe slot: first prefix sum strictly above j, i.e. the
    # count of prefix sums <= j. nprobe is small, so the (Q, P, S) compare
    # + sum beats a vmapped searchsorted (same result element for element)
    p = jnp.sum((cum[:, :, None] <= j[0][None, None, :]).astype(jnp.int32),
                axis=1)
    pc = jnp.clip(p, 0, nprobe - 1)
    cell = jnp.take_along_axis(probe, pc, axis=1)         # (Q, S)
    r = j - jnp.take_along_axis(start, pc, axis=1)        # in-cell slot
    rc = jnp.clip(r, 0, lists.shape[1] - 1)
    ok = j < total                                        # real posting mass
    cand = jnp.where(ok, lists[cell, rc], -1)
    ccodes = codes_cell[cell, rc]                         # (Q, S, M) uint8
    base = jnp.take_along_axis(cd2p, pc, axis=1) + bias_cell[cell, rc]
    base = jnp.where(cand >= 0, base, jnp.inf)
    center = scale = None
    if lut_dtype == "int8":
        # see ivfpq_adc_scan: int8-only analytic centering + certified
        # scale; the per-query constant is restored after top-k
        center, scale = ivfpq_lut_stats(codebooks, cbnorm, q, lut_dtype)
    k_eff = min(n_cand, scan_cap)
    if backend == "kernel":
        from repro.kernels.pq_adc import pq_adc_gather_topk_pallas
        kt = tables if center is None else tables - center[:, :, None]
        d2, sel = pq_adc_gather_topk_pallas(kt, ccodes, base, k_eff,
                                            interpret=interpret,
                                            lut_dtype=lut_dtype, scale=scale)
    else:
        adc = pq_adc_gather_scores_ref(tables, ccodes, base, lut_dtype,
                                       scale, center)
        neg, sel = jax.lax.top_k(-adc, k_eff)
        d2 = -neg
    if center is not None:
        d2 = d2 + jnp.sum(center, axis=1)[:, None]        # inf pads stay inf
    ids = jnp.where(sel >= 0,
                    jnp.take_along_axis(cand, jnp.maximum(sel, 0), axis=1),
                    -1)
    ids = jnp.where(jnp.isinf(d2), -1, ids)
    if k_eff < n_cand:
        d2 = jnp.pad(d2, ((0, 0), (0, n_cand - k_eff)),
                     constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, n_cand - k_eff)),
                      constant_values=-1)
    return d2, ids


def ivfpq_scan(index: IVFPQIndex, q: jax.Array, k: int, nprobe: int = 8,
               backend: str = "jnp", interpret: bool = True,
               lut_dtype: str = "f32"):
    """Unjitted ``ivfpq_search`` core (inlineable into fused programs)."""
    d2, ids = ivfpq_adc_scan(index.centroids, index.lists, index.codes_cell,
                             index.bias_cell, index.lut_w, index.cbnorm,
                             index.codebooks, q, k, nprobe, backend,
                             interpret, lut_dtype)
    return jnp.sqrt(jnp.maximum(d2, 0.0)), ids


def ivfpq_local_scan(centroids: jax.Array, lists_loc: jax.Array,
                     codes_cell_loc: jax.Array, bias_cell_loc: jax.Array,
                     lut_w: jax.Array, cbnorm: jax.Array,
                     codebooks: jax.Array, q: jax.Array,
                     n_cand: int, nprobe: int, axis: str,
                     backend: str = "jnp", interpret: bool = True,
                     lut_dtype: str = "f32", live=None):
    """Shard-local IVF-PQ probe + ADC scan (a ``shard_map`` body of sharded
    serving).

    The coarse probe and the per-query residual LUT both run on replicated
    inputs (centroids, ``lut_w``/``cbnorm``) so they are identical on every
    shard; only the probed cells this shard owns (rows of the cell-major
    mirrors, offset by ``axis_index * nlist_local``) are ADC-scored — the
    ``base`` of non-local or padded slots is +inf, which masks them through
    either scoring backend. ``live`` (replicated (N,) bool, streaming
    serving) masks tombstoned/unallocated rows the same way — riding the
    additive ``base`` term, so it works on both backends. Returns (d2 (Q,
    n_cand), global ids (Q, n_cand)) with (+inf, -1) on masked slots.
    """
    _check_adc_args(backend, lut_dtype)
    q = jnp.asarray(q, jnp.float32)
    nq = q.shape[0]
    m, kc = cbnorm.shape
    cd2 = sq_dists(q, centroids)                          # (Q, nlist)
    _, probe = jax.lax.top_k(-cd2, nprobe)                # global cell ids
    cd2p = jnp.take_along_axis(cd2, probe, axis=1)
    tables = adc_tables(lut_w, cbnorm, q)
    nl_loc = lists_loc.shape[0]
    coff = jax.lax.axis_index(axis) * nl_loc
    lp = probe - coff
    own = (lp >= 0) & (lp < nl_loc)
    lpc = jnp.clip(lp, 0, nl_loc - 1)
    cand = jnp.where(own[:, :, None], lists_loc[lpc], -1).reshape(nq, -1)
    if live is not None:
        n_cap = live.shape[0]
        cand = jnp.where(live[jnp.clip(cand, 0, n_cap - 1)], cand, -1)
    ccodes = codes_cell_loc[lpc].reshape(nq, -1, m)
    base = (cd2p[:, :, None] + bias_cell_loc[lpc]).reshape(nq, -1)
    base = jnp.where(cand >= 0, base, jnp.inf)
    center = scale = None
    if lut_dtype == "int8":
        # replicated inputs -> identical centering/scale on every shard;
        # see ivfpq_adc_scan for the int8-only centering rationale
        center, scale = ivfpq_lut_stats(codebooks, cbnorm, q, lut_dtype)
    k_eff = min(n_cand, cand.shape[1])
    if backend == "kernel":
        from repro.kernels.pq_adc import pq_adc_gather_topk_pallas
        kt = tables if center is None else tables - center[:, :, None]
        d2, sel = pq_adc_gather_topk_pallas(kt, ccodes, base, k_eff,
                                            interpret=interpret,
                                            lut_dtype=lut_dtype, scale=scale)
    else:
        adc = pq_adc_gather_scores_ref(tables, ccodes, base, lut_dtype,
                                       scale, center)
        neg, sel = jax.lax.top_k(-adc, k_eff)
        d2 = -neg
    if center is not None:
        d2 = d2 + jnp.sum(center, axis=1)[:, None]        # inf stays inf
    ids = jnp.where(sel >= 0,
                    jnp.take_along_axis(cand, jnp.maximum(sel, 0), axis=1),
                    -1)
    ids = jnp.where(jnp.isinf(d2), -1, ids)
    if k_eff < n_cand:
        d2 = jnp.pad(d2, ((0, 0), (0, n_cand - k_eff)),
                     constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, n_cand - k_eff)),
                      constant_values=-1)
    return d2, ids


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "backend",
                                             "interpret", "lut_dtype"))
def ivfpq_search(index: IVFPQIndex, q: jax.Array, k: int, nprobe: int = 8,
                 backend: str = "jnp", interpret: bool = True,
                 lut_dtype: str = "f32"):
    """Probe ``nprobe`` cells, ADC-score their residual codes, top-k.

    Returns (approx dists (Q, k), ids (Q, k)). ``backend="kernel"`` routes
    the candidate scoring through the fused Pallas ADC-gather kernel;
    ``lut_dtype`` quantizes the per-query residual LUT on either backend.
    """
    return ivfpq_scan(index, q, k, nprobe, backend, interpret, lut_dtype)
