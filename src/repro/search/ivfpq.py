"""IVF-PQ: coarse k-means quantizer + product-quantized **residuals** —
the classic memory-hierarchy composition for production vector search
(reduce dims -> coarse-quantize -> PQ-code what the centroid missed).

Layout matches ``ivf.py``: padded-dense posting lists (nlist, max_cell)
with -1 pads, so probe-scan is gather + masked top-k (TPU-idiomatic, no
ragged structures on device). Codebooks are trained on residuals
``x - centroid[assign(x)]`` and shared across cells (standard IVF-ADC).

Scoring uses the exact residual decomposition so the per-query LUT is
cell-independent — the same (Q, M, K) shape as plain PQ, which is what lets
the fused ADC kernel serve both index types. With reconstruction
x̂ = c + r̂, r̂_m = cb[m, code_m]:

  ||q - x̂||² = ||q - c||²                                   (coarse term,
                                                 already computed to probe)
             + Σ_m ( ||cb[m,code_m]||² - 2⟨q_m, cb[m,code_m]⟩ )   (query LUT)
             + 2 Σ_m ⟨c_m, cb[m,code_m]⟩                 (per-id build-time
                                                          scalar: ``bias``)

No approximation beyond PQ itself: the cross terms are exact.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc.ref import pq_adc_gather_scores_ref
from .ivf import kmeans, posting_lists, sq_dists
from .pq import build_pq

__all__ = ["IVFPQIndex", "build_ivfpq", "ivfpq_search"]


class IVFPQIndex(NamedTuple):
    centroids: jax.Array    # (nlist, d) coarse quantizer
    lists: jax.Array        # (nlist, max_cell) int32 vector ids, -1 = pad
    codebooks: jax.Array    # (M, K, dsub) residual-space PQ codebooks
    codes: jax.Array        # (N, M) int32 residual codes, id-aligned
    bias: jax.Array         # (N,) f32: 2·Σ_m ⟨cent[assign]_m, cb[m, code_m]⟩


def build_ivfpq(key: jax.Array, vectors: jax.Array, nlist: int,
                m_subspaces: int = 8, n_centroids: int = 256,
                kmeans_iters: int = 12, pq_iters: int = 10) -> IVFPQIndex:
    """Coarse k-means, then per-subspace codebooks on the residuals."""
    vectors = jnp.asarray(vectors, jnp.float32)
    n, d = vectors.shape
    cent = kmeans(key, vectors, nlist, kmeans_iters)
    assign = jnp.argmin(sq_dists(vectors, cent), axis=1)  # (N,)
    lists = posting_lists(assign, nlist)
    residuals = vectors - cent[assign]
    pq = build_pq(jax.random.fold_in(key, 7), residuals,
                  m_subspaces, n_centroids, pq_iters)
    # per-id centroid/codeword cross term (see module docstring)
    dsub = d // m_subspaces
    csub = cent[assign].reshape(n, m_subspaces, dsub)     # (N, M, dsub)
    recon = jnp.take_along_axis(
        pq.codebooks[None], pq.codes[:, :, None, None], axis=2
    )[:, :, 0, :]                                         # (N, M, dsub)
    bias = 2.0 * jnp.sum(csub * recon, axis=(1, 2))       # (N,)
    return IVFPQIndex(centroids=cent, lists=lists, codebooks=pq.codebooks,
                      codes=pq.codes, bias=bias.astype(jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("k", "nprobe", "backend", "interpret"))
def ivfpq_search(index: IVFPQIndex, q: jax.Array, k: int, nprobe: int = 8,
                 backend: str = "jnp", interpret: bool = True):
    """Probe ``nprobe`` cells, ADC-score their residual codes, top-k.

    Returns (approx dists (Q, k), ids (Q, k)). ``backend="kernel"`` routes
    the candidate scoring through the fused Pallas ADC-gather kernel.
    """
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"unknown ADC backend {backend!r}")
    q = jnp.asarray(q, jnp.float32)
    cent, lists, cbs, codes, bias = index
    nq = q.shape[0]
    m, kc, dsub = cbs.shape
    # coarse probe: distances to every centroid, keep the nprobe nearest
    cd2 = sq_dists(q, cent)                               # (Q, nlist)
    _, probe = jax.lax.top_k(-cd2, nprobe)                # (Q, nprobe)
    cd2p = jnp.take_along_axis(cd2, probe, axis=1)        # (Q, nprobe)
    cand = lists[probe].reshape(nq, -1)                   # (Q, nprobe*max_cell)
    if cand.shape[1] < k:   # degenerate probe budget: pad so top_k is legal
        cand = jnp.pad(cand, ((0, 0), (0, k - cand.shape[1])),
                       constant_values=-1)
    valid = cand >= 0
    cid = jnp.maximum(cand, 0)
    # cell-independent query LUT over residual codebooks: (Q, M, K)
    qs = q.reshape(nq, m, dsub)
    tables = (jnp.sum(cbs ** 2, -1)[None]
              - 2.0 * jnp.einsum("qmd,mkd->qmk", qs, cbs))
    max_cell = lists.shape[1]
    base = jnp.repeat(cd2p, max_cell, axis=1)
    base = jnp.pad(base, ((0, 0), (0, cand.shape[1] - base.shape[1])))
    base = jnp.where(valid, base + bias[cid], jnp.inf)    # mask posting pads
    ccodes = codes[cid]                                   # (Q, C, M)
    if backend == "kernel":
        from repro.kernels.pq_adc import pq_adc_gather_topk_pallas
        d2, sel = pq_adc_gather_topk_pallas(tables, ccodes, base, k,
                                            interpret=interpret)
    else:
        adc = pq_adc_gather_scores_ref(tables, ccodes, base)
        neg, sel = jax.lax.top_k(-adc, k)
        d2 = -neg
    # the kernel marks unfilled slots sel=-1; don't let them wrap the gather
    ids = jnp.where(sel >= 0,
                    jnp.take_along_axis(cand, jnp.maximum(sel, 0), axis=1),
                    -1)
    return jnp.sqrt(jnp.maximum(d2, 0.0)), ids
