"""Pluggable Reduce stage: a ``ReducerOps`` registry mirroring ``IndexOps``.

QPAD's thesis makes the Reduce stage the retrieval-specific part of the
pipeline — yet until this module the engine hard-coded exactly one
reducer (the linear MPAD projection) while a new *index* kind was one
``register_index`` call. This is the same move for the projection: a
reducer kind is a :class:`ReducerOps` record (fit / transform /
snapshot-skeleton / output-dim hooks) keyed by the ``Reduce`` stage's
kind token in the spec grammar (``qpad32`` | ``pca32`` | ``mlp32``), and
every registered kind rides the full serving stack for free — fused
``search_fn``, sharded serving, streaming upsert/delete/compact,
snapshot save/load, WAL replay, tracing (pinned by
``tests/test_zoo.py``).

The fitted projection travels as a :class:`Reducer` **tagged union**
(static ``kind`` + params pytree), exactly like the index side's
``Index``: the kind lives in pytree metadata, so jitted search programs
dispatch on it at trace time and sharding/snapshot code treats the
params as an opaque pytree. The linear kinds (``qpad``, ``pca``) share
the legacy ``(matrix (m, D), mean (D,))`` params layout — snapshots of
``qpad`` engines keep byte-identical key paths to pre-zoo snapshots.

Registered kinds:

* ``qpad``  — the MPAD projection (Algorithm 1); bit-identical to the
  previously hard-coded path, and the default kind.
* ``pca``   — classical PCA via ``repro.core.baselines.fit_pca`` (the
  affine params the baseline ``Reducer`` closure now exposes).
* ``mlp``   — a GleanVec/RAE-style minimalist nonlinear reducer: a
  linear MPAD map plus a small zero-initialized tanh residual head,
  trained on an exact-NN triplet margin objective over the fit sample.
  The residual starts at the linear solution and is kept only when it
  reduces the triplet violation count, so ``mlp`` never ranks worse
  than its own linear init on the training sample.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.baselines import fit_pca
from repro.core.mpad import MPADConfig, fit_mpad

__all__ = ["Reducer", "ReducerOps", "register_reducer", "get_reducer_ops",
           "fit_reducer", "reduce_vectors", "reducer_dim", "REDUCER_KINDS"]


@dataclasses.dataclass(frozen=True)
class Reducer:
    """A fitted Reduce stage: ``kind`` names the registered ops, ``params``
    is the kind's pytree of fitted arrays. The kind is pytree *metadata*
    (static under jit), so traced programs specialize on it exactly like
    the index side's ``Index`` union."""
    kind: str
    params: Any

    def __call__(self, x: jax.Array) -> jax.Array:
        """Apply the fitted projection (``SearchEngine.reducer`` is one of
        these, so ``eng.reducer(q)`` reduces a query batch)."""
        return reduce_vectors(self, x)


jax.tree_util.register_dataclass(
    Reducer, data_fields=["params"], meta_fields=["kind"])


@dataclasses.dataclass(frozen=True)
class ReducerOps:
    """The per-kind hook table (the Reduce-stage counterpart of
    ``IndexOps``).

    * ``fit(key, x, m, mpad)`` -> params: fit on sample ``x`` (N, D) to
      ``m`` output dims. ``mpad`` is the engine's ``MPADConfig`` when the
      kind consumes one (only ``qpad`` does; others receive ``None``).
    * ``transform(params, x)`` -> (..., m): the projection itself; pure
      and jit-traceable (runs inside the fused search programs).
    * ``skeleton(leaf)`` -> params-shaped pytree of placeholder leaves
      (snapshot restore rebuilds params by key path from this).
    * ``out_dim(params)`` -> int: the reduced dimension ``m``.
    """
    kind: str
    fit: Callable[..., Any]
    transform: Callable[[Any, jax.Array], jax.Array]
    skeleton: Callable[[Any], Any]
    out_dim: Callable[[Any], int]


_REGISTRY: dict = {}


def register_reducer(ops: ReducerOps) -> ReducerOps:
    """Register a reducer kind. The spec grammar (``<kind><m>``), serving,
    sharding, snapshots, and the conformance suite pick it up from here."""
    _REGISTRY[ops.kind] = ops
    return ops


def get_reducer_ops(kind: str) -> ReducerOps:
    """Look up a registered reducer kind's hook table (actionable
    ``ValueError`` naming the registered kinds on a miss)."""
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown reducer kind {kind!r}; registered kinds: "
            f"{tuple(_REGISTRY)}") from None


def fit_reducer(kind: str, key: jax.Array, x: jax.Array, m: int,
                mpad: Optional[MPADConfig] = None) -> Reducer:
    """Fit a registered reducer kind on sample ``x`` -> tagged union."""
    ops = get_reducer_ops(kind)
    return Reducer(kind, ops.fit(key, x, m, mpad))


def reduce_vectors(proj: Optional[Reducer], x: jax.Array) -> jax.Array:
    """Apply a fitted reducer (identity when ``proj`` is None). The single
    projection entry point every scan/serve/stream path goes through."""
    if proj is None:
        return x
    return get_reducer_ops(proj.kind).transform(proj.params, x)


def reducer_dim(proj: Reducer) -> int:
    """The reduced dimension a fitted reducer maps into."""
    return get_reducer_ops(proj.kind).out_dim(proj.params)


# ------------------------------------------------------- linear kinds
# qpad and pca share the legacy affine params layout (matrix (m, D),
# mean (D,)) — the tuple the engine previously carried as its bare
# ``proj`` field, which is what keeps old snapshots' key paths valid.

def _affine_transform(params, x):
    matrix, mean = params
    return (jnp.asarray(x, jnp.float32) - mean) @ matrix.T


def _affine_skeleton(leaf):
    return (leaf, leaf)


def _affine_dim(params):
    return params[0].shape[0]


def _qpad_fit(key, x, m, mpad):
    del key        # fit_mpad derives its key from MPADConfig.seed — keeps
    #                qpad fits bit-identical to the pre-zoo serve path
    cfg = mpad if mpad is not None else MPADConfig(
        m=m, b=80.0, alpha=25.0, iters=48)
    if cfg.m != m:
        raise ValueError(
            f"MPADConfig.m={cfg.m} disagrees with the Reduce stage's "
            f"m={m}; the spec's reduce dim is authoritative")
    result = fit_mpad(x, cfg)
    return (result.matrix, result.mean)


def _pca_fit(key, x, m, mpad):
    del key, mpad                      # PCA is deterministic, config-free
    return fit_pca(x, m).params


register_reducer(ReducerOps(
    kind="qpad", fit=_qpad_fit, transform=_affine_transform,
    skeleton=_affine_skeleton, out_dim=_affine_dim))

register_reducer(ReducerOps(
    kind="pca", fit=_pca_fit, transform=_affine_transform,
    skeleton=_affine_skeleton, out_dim=_affine_dim))


# ------------------------------------------ mlp (nonlinear residual)
# f(x) = (x - mean) @ lin.T + tanh((x - mean) @ w1 + b1) @ w2
# with w2 zero-initialized: the map starts exactly at the linear MPAD
# solution and the residual head trains on a triplet margin objective
# (anchor / exact-NN positive / random negative over the fit sample).

_MLP_ANCHORS = 256       # triplet anchors subsampled from the fit set
_MLP_NEGATIVES = 4       # random negatives per anchor
_MLP_STEPS = 150
_MLP_LR = 3e-3
_MLP_INIT_ITERS = 24     # MPAD iterations for the linear init


def _mlp_transform(params, x):
    xc = jnp.asarray(x, jnp.float32) - params["mean"]
    h = jnp.tanh(xc @ params["w1"] + params["b1"])
    return xc @ params["lin"].T + h @ params["w2"]


def _mlp_skeleton(leaf):
    return {"mean": leaf, "lin": leaf, "w1": leaf, "b1": leaf, "w2": leaf}


def _mlp_dim(params):
    return params["lin"].shape[0]


def _mlp_fit(key, x, m, mpad):
    del mpad                 # the MPAD knobs configure the qpad kind only
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    k_lin, k_anchor, k_neg, k_w1 = jax.random.split(key, 4)
    lin = fit_mpad(x, MPADConfig(m=m, b=80.0, alpha=25.0,
                                 iters=_MLP_INIT_ITERS), k_lin)
    hidden = int(min(max(2 * m, 16), 128))
    params = {
        "mean": lin.mean,
        "lin": lin.matrix,
        "w1": jax.random.normal(k_w1, (d, hidden), jnp.float32)
              * (1.0 / jnp.sqrt(jnp.asarray(float(d)))),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jnp.zeros((hidden, m), jnp.float32),
    }
    # exact-NN triplets on the fit sample: anchor a, its true nearest
    # neighbor p in the ORIGINAL space, random negatives
    n_anchor = min(_MLP_ANCHORS, n)
    anchors = jax.random.choice(k_anchor, n, (n_anchor,), replace=False)
    xa = x[anchors]
    d2 = (jnp.sum(xa * xa, axis=1)[:, None] + jnp.sum(x * x, axis=1)[None, :]
          - 2.0 * xa @ x.T)
    d2 = d2.at[jnp.arange(n_anchor), anchors].set(jnp.inf)   # mask self
    pos = jnp.argmin(d2, axis=1)
    neg = jax.random.randint(k_neg, (n_anchor, _MLP_NEGATIVES), 0, n)
    neg_ok = (neg != anchors[:, None]) & (neg != pos[:, None])
    xp, xn = x[pos], x[neg]

    def triplet_stats(p):
        fa = _mlp_transform(p, xa)
        fp = _mlp_transform(p, xp)
        fn = _mlp_transform(p, xn.reshape(-1, d)).reshape(
            n_anchor, _MLP_NEGATIVES, m)
        dp = jnp.sum((fa - fp) ** 2, axis=1)
        dn = jnp.sum((fa[:, None, :] - fn) ** 2, axis=2)
        gap = (dp[:, None] - dn) * neg_ok            # >0 = NN order violated
        return gap, jnp.sum((gap > 0).astype(jnp.int32))

    gap0, _ = triplet_stats(params)
    margin = 0.05 * jnp.mean(jnp.abs(gap0))

    def loss_fn(p):
        gap, _ = triplet_stats(p)
        return jnp.mean(jax.nn.relu(gap + margin))

    def adam_step(carry, t):
        p, mom, vel = carry
        loss, g = jax.value_and_grad(loss_fn)(p)
        mom = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, mom, g)
        vel = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, vel, g)
        t1 = (t + 1).astype(jnp.float32)
        upd = jax.tree.map(
            lambda mo, ve: (mo / (1.0 - 0.9 ** t1))
            / (jnp.sqrt(ve / (1.0 - 0.999 ** t1)) + 1e-8), mom, vel)
        p = jax.tree.map(lambda a, u: a - _MLP_LR * u, p, upd)
        return (p, mom, vel), loss

    zeros = jax.tree.map(jnp.zeros_like, params)
    (trained, _, _), _ = jax.lax.scan(
        adam_step, (params, zeros, zeros), jnp.arange(_MLP_STEPS))
    # accept the residual only if it strictly improves NN-order
    # preservation on the sample; otherwise fall back to the linear init
    _, viol0 = triplet_stats(params)
    _, viol1 = triplet_stats(trained)
    keep = viol1 < viol0
    return jax.tree.map(lambda a, b: jnp.where(keep, a, b), trained, params)


register_reducer(ReducerOps(
    kind="mlp", fit=_mlp_fit, transform=_mlp_transform,
    skeleton=_mlp_skeleton, out_dim=_mlp_dim))


REDUCER_KINDS = tuple(_REGISTRY)
