"""Engine snapshot persistence: spec + arrays, restore anywhere.

``save_engine`` writes a serving engine into a directory as two pieces:

* ``engine.json`` — the pipeline **spec string** (the grammar of
  ``repro.search.spec``), the runtime knobs, and the streaming config;
  everything needed to rebuild the engine *shape* without the corpus.
* ``ckpt_*.npz`` — every array leaf, flattened by pytree key path through
  ``repro.runtime.checkpoint`` (atomic write + retention). Read-only
  engines persist their ``EngineState``; streaming engines persist the
  ``StreamStore`` + ``FrozenParams`` pair — the delta segment, tombstone
  bitmap, and id maps included, so a snapshot taken **mid-delta**
  restores mid-delta (un-compacted writes survive the round trip).

``load_engine`` rebuilds the ``SearchEngine`` around the restored arrays
— no MPAD refit, no index retrain, and (because shapes, dtypes, and the
index kind's pytree structure are reproduced exactly) **no new program
shapes**: the restored engine compiles the same one program per
(knobs, k, bucket) a fresh build would. Pass ``mesh=`` to restore onto a
device mesh: the dense leaves are placed through
``repro.runtime.checkpoint.restore_resharded`` (checkpoints are
shard-agnostic npz files — the elastic-scaling primitive) and the engine
is then partitioned with the usual layout pass (``shard``; read-only
restores donate the transient dense copy, so there is no standing 2x).

On a **durable** engine (``engine.durable(dir)``) the snapshot directory
also holds the write-ahead log: ``save_engine`` commits crash-consistently
(fresh checkpoint step -> fsync'd metadata replace -> WAL snapshot-mark +
truncate) and ``load_engine`` replays the log tail on top of the restored
store, so recovery lands on the exact pre-crash state (see
``repro.search.durability``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.checkpoint import (checkpoint_step, latest_checkpoint,
                                      restore_checkpoint, restore_resharded,
                                      save_arrays, save_checkpoint)
from .durability.policy import PolicyConfig
from .durability.wal import RT_SNAPSHOT, DurabilityConfig, Wal
from .reducers import Reducer, get_reducer_ops
from .registry import Index, get_ops
from .segments import FrozenParams, StreamConfig, StreamStore
from .serve import EngineState, SearchEngine, config_from_spec
from .spec import format_spec, parse_spec

__all__ = ["save_engine", "load_engine", "SNAPSHOT_META"]

SNAPSHOT_META = "engine.json"
_SCHEMA = "qpad.engine_snapshot.v1"
# engine knobs a pipeline spec does not carry; persisted verbatim
_RUNTIME_FIELDS = ("query_bucket", "small_batch", "compact_batch",
                   "prefilter_batch", "fit_sample", "seed", "pq_interpret")


class _Leaf:
    """Placeholder leaf in a shape-free skeleton pytree (filled from the
    checkpoint by key path)."""

    def __repr__(self):
        return "<leaf>"


_L = _Leaf()


# StreamStore fields that are optional per index kind / projection; which
# ones a snapshot carries is recorded in its meta at save time
_OPT_STORE_FIELDS = ("reduced", "codes", "bias", "lists", "codes_cell",
                     "bias_cell", "delta_reduced")

# StreamStore fields a pure delta write path mutates — everything an
# INCREMENTAL snapshot must carry. The base arrays (corpus, codes,
# lists, ... and the frozen quantizers) only change at compaction /
# vacuum / rebuild / grow, which dirties the base and forces the next
# snapshot to be full.
_INC_STORE_FIELDS = ("row_ids", "n_rows", "dead", "delta_vectors",
                     "delta_ids", "delta_count", "delta_reduced")


def _snapshot_skeleton(kind: str, reducer: Optional[str], streaming: bool,
                       flat_alias: bool, store_fields=()):
    """The snapshot pytree with placeholder leaves — the structure comes
    from the spec metadata (kind, reducer kind, streaming, the optional
    store fields present at save time) plus the ops registries' per-kind
    shapes (``ReducerOps.skeleton``,
    ``payload_skeleton``/``quant_skeleton``), so save and load flatten to
    the same key paths for any registered kind.

    ``reducer`` is the Reduce stage's kind (None = no projection). The
    proj travels as the kind's RAW params pytree — unwrapped from the
    ``Reducer`` union at save time — so qpad snapshots keep the exact
    ``proj[0]``/``proj[1]`` key paths of pre-zoo checkpoints; load
    rewraps."""
    ops = get_ops(kind)
    proj = (get_reducer_ops(reducer).skeleton(_L)
            if reducer is not None else None)
    if not streaming:
        # the flat-alias case (no Reduce stage: payload IS the corpus
        # array) is not re-saved; restore re-points it at the corpus
        payload = None if flat_alias else ops.payload_skeleton(_L)
        return {"state": EngineState(
            corpus=_L, proj=proj, index=Index(kind, payload))}
    opt = {f: (_L if f in store_fields else None) for f in _OPT_STORE_FIELDS}
    store = StreamStore(
        corpus=_L, row_ids=_L, n_rows=_L, dead=_L,
        delta_vectors=_L, delta_ids=_L, delta_count=_L, **opt)
    frozen = FrozenParams(proj=proj,
                          quant=Index(kind, ops.quant_skeleton(_L)))
    return {"store": store, "frozen": frozen}


def _host_template(skeleton, path: str, overlay: Optional[str] = None):
    """Fill a skeleton's placeholder leaves with the checkpoint's (host)
    arrays by pytree key path — shapes and dtypes come from the file.
    ``overlay`` (an incremental checkpoint) wins for the keys it holds."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(skeleton)
    over = {}
    if overlay is not None:
        with np.load(overlay) as d:
            over = {k: d[k] for k in d.files}
    with np.load(path) as data:
        leaves = []
        for kpath, _ in flat:
            key = jax.tree_util.keystr(kpath)
            if key in over:
                leaves.append(over[key])
                continue
            if key not in data:
                raise ValueError(
                    f"snapshot {path} is missing array {key!r} — was it "
                    "written by an incompatible version?")
            leaves.append(data[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _prior_chain(directory: str):
    """Checkpoint basenames the existing manifest (if any) still
    references — retention must not unlink them while the new snapshot
    is mid-commit (crash between array write and metadata replace must
    leave the old chain fully loadable)."""
    meta_path = os.path.join(directory, SNAPSHOT_META)
    if not os.path.isfile(meta_path):
        return set()
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError):
        return set()
    keep = set(meta.get("chain") or ())
    if meta.get("ckpt"):
        keep.add(meta["ckpt"])
    if meta.get("base_ckpt"):
        keep.add(meta["base_ckpt"])
    return keep


def _commit_meta(directory: str, meta: dict):
    tmp = os.path.join(directory, SNAPSHOT_META + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=2)
        f.flush()
        os.fsync(f.fileno())         # the commit point of the snapshot
    os.replace(tmp, os.path.join(directory, SNAPSHOT_META))


def save_engine(engine: SearchEngine, directory: str,
                incremental: bool = False) -> str:
    """Snapshot ``engine`` (spec + config + arrays) into ``directory``.

    Returns the checkpoint path. Raises if the dense arrays are gone
    (``shard(donate=True)``) — snapshot before donating, or snapshot the
    streaming store, which always stays dense.

    The write is **crash-consistent across the directory**: arrays land
    under a fresh (incremented) checkpoint step, the metadata commits via
    fsync'd temp-file + ``os.replace``, and only *after* that commit is
    the engine's WAL (when this is its durable directory) marked with a
    SNAPSHOT record and truncated up to the saved sequence — a crash at
    any point leaves either the old snapshot + full log or the new
    snapshot + tail, never a mix.

    ``incremental=True`` (streaming, durable, same-directory saves only)
    writes a **delta-only** checkpoint — the ``_INC_STORE_FIELDS``
    arrays plus the WAL position — whose manifest chains back to the
    newest full snapshot; see ``SearchEngine.save``. Each incremental
    carries the *complete current* delta/tombstone/id-map state, so the
    newest link supersedes the older ones: resolution always reads
    exactly two files (base + newest incremental). The chained base pins
    the WAL truncation floor: a follower seeded from the base artifact
    still needs every record past the base's ``wal_seq``.
    """
    if incremental:
        return _save_incremental(engine, directory)
    streaming = engine.store is not None
    if not streaming and engine.state is None:
        raise RuntimeError(
            "nothing to save: the dense EngineState was released by "
            "shard(donate=True) — call save() before donating the dense "
            "buffers")
    if streaming and engine._compact_future is not None:
        engine.finish_compact()      # snapshot the post-swap store
    wal = None
    wal_seq = -1
    if (engine._wal is not None
            and os.path.abspath(directory) == engine._durable_dir):
        wal = engine._wal
        wal.sync()                   # everything the snapshot covers is on
        wal_seq = wal.last_seq       # disk before the snapshot claims it
    elif engine._wal is not None:
        # foreign-directory snapshot of a durable primary: record the WAL
        # position anyway — it is the seed point a follower built from
        # this artifact catches up from
        engine._wal.sync()
        wal_seq = engine._wal.last_seq
    elif engine._role == "follower":
        wal_seq = engine._applied_seq    # a follower's position is its
        #                                  applied seq, not a local log
    cfg = engine.config
    spec = engine.spec
    flat_alias = False
    store_fields = []
    if streaming:
        proj = engine.frozen.proj
        # persist the RAW reducer params (not the tagged union): qpad key
        # paths stay identical to pre-zoo snapshots; load rewraps
        frozen = engine.frozen._replace(
            proj=proj.params if proj is not None else None)
        tree = {"store": engine.store, "frozen": frozen}
        store_fields = [f for f in _OPT_STORE_FIELDS
                        if getattr(engine.store, f) is not None]
    else:
        state = engine.state
        proj = state.proj
        state = state._replace(
            proj=proj.params if proj is not None else None)
        if state.index.kind == "flat" and state.index.payload is state.corpus:
            # don't write the same rows twice; restore re-aliases
            flat_alias = True
            state = state._replace(index=Index("flat", None))
        tree = {"state": state}
    has_proj = proj is not None
    red_kind = proj.kind if proj is not None else None
    # fresh step per save: the metadata names its checkpoint, so a crash
    # between the array write and the metadata commit leaves the previous
    # (still-named, still-retained) snapshot fully intact
    prev = latest_checkpoint(directory)
    step = checkpoint_step(prev) + 1 if prev else 0
    path = save_checkpoint(directory, step, tree,
                           protect=sorted(_prior_chain(directory)))
    engine._crash("snapshot_arrays")
    if wal is not None:
        # the mark is itself covered by wal_seq: a no-op on replay, so
        # writing it before the metadata commit is safe either way the
        # commit goes — and afterwards replay starts strictly past it
        wal_seq = wal.append(RT_SNAPSHOT, str(wal_seq).encode())
        wal.sync()
    meta = {
        "schema": _SCHEMA,
        "spec": format_spec(spec),
        "kind": spec.kind,
        "streaming": streaming,
        "has_proj": has_proj,
        "reducer": red_kind,
        "flat_alias": flat_alias,
        "store_fields": store_fields,
        "ckpt": os.path.basename(path),
        "runtime": {f: getattr(cfg, f) for f in _RUNTIME_FIELDS},
        "stream": (dataclasses.asdict(cfg.stream)
                   if cfg.stream is not None else None),
        "wal_seq": wal_seq,
        "durability": (dataclasses.asdict(engine._durability)
                       if wal is not None else None),
        "incremental": False,
        "chain": [os.path.basename(path)],
    }
    _commit_meta(directory, meta)
    engine._crash("snapshot_commit")
    if wal is not None:
        # snapshot durable: records at or before wal_seq are dead weight,
        # and this full snapshot is the new chain base — the floor moves
        wal.pin_floor(wal_seq)
        wal.truncate(wal_seq)
    if streaming:
        engine._base_ref = {"dir": os.path.abspath(directory),
                            "ckpt": os.path.basename(path),
                            "wal_seq": wal_seq,
                            "chain": [os.path.basename(path)]}
        engine._base_dirty = False
    engine._snap_counters["full"] += 1
    engine._snap_counters["last_bytes"] = os.path.getsize(path)
    engine._snap_counters["chain_depth"] = 0
    return path


def _save_incremental(engine: SearchEngine, directory: str) -> str:
    """The delta-only save path (``save_engine(..., incremental=True)``):
    validates the chain invariants, writes only the ``_INC_STORE_FIELDS``
    arrays, and commits a manifest chained to the existing base."""
    directory_abs = os.path.abspath(directory)
    if engine.store is None:
        raise ValueError(
            "incremental snapshots cover the streaming delta state; this "
            "engine is read-only — its one full snapshot already is "
            "minimal. Use engine.save(dir).")
    if engine._compact_future is not None:
        engine.finish_compact()      # lands base changes -> dirties base
    if engine._wal is None or engine._durable_dir != directory_abs:
        raise ValueError(
            "incremental save needs a durable base: the chain's WAL "
            "position only means something against the log in the same "
            "directory. Call engine.durable(dir) (which takes the full "
            "base snapshot) and then save(dir, incremental=True).")
    base = engine._base_ref
    if base is None or base["dir"] != directory_abs:
        raise ValueError(
            "incremental save without a base snapshot in this directory: "
            "call engine.save(dir) once (full) before chaining "
            "incrementals onto it.")
    if engine._base_dirty:
        raise ValueError(
            "the base arrays changed since the base snapshot (a "
            "compaction, vacuum, rebuild or grow rewrote them), so a "
            "delta-only snapshot can no longer restore this engine — "
            "take a full snapshot (engine.save(dir)) to start a new "
            "chain.")
    base_path = os.path.join(directory, base["ckpt"])
    if not os.path.isfile(base_path):
        raise FileNotFoundError(
            f"the chain's base checkpoint {base['ckpt']!r} is gone from "
            f"{directory!r}; take a full snapshot to start a new chain")
    wal = engine._wal
    wal.sync()
    wal_seq = wal.last_seq
    flat, _ = jax.tree_util.tree_flatten_with_path({"store": engine.store})
    arrays = {jax.tree_util.keystr(kpath): np.asarray(leaf)
              for kpath, leaf in flat
              if kpath[-1].name in _INC_STORE_FIELDS}
    prev = latest_checkpoint(directory)
    step = checkpoint_step(prev) + 1 if prev else 0
    protect = set(base["chain"]) | {base["ckpt"]}
    path = save_arrays(directory, step, arrays, protect=sorted(protect))
    engine._crash("snapshot_arrays")
    wal_seq = wal.append(RT_SNAPSHOT, str(wal_seq).encode())
    wal.sync()
    cfg = engine.config
    spec = engine.spec
    chain = list(base["chain"]) + [os.path.basename(path)]
    meta = {
        "schema": _SCHEMA,
        "spec": format_spec(spec),
        "kind": spec.kind,
        "streaming": True,
        "has_proj": engine.frozen.proj is not None,
        "reducer": (engine.frozen.proj.kind
                    if engine.frozen.proj is not None else None),
        "flat_alias": False,
        "store_fields": [f for f in _OPT_STORE_FIELDS
                         if getattr(engine.store, f) is not None],
        "ckpt": os.path.basename(path),
        "runtime": {f: getattr(cfg, f) for f in _RUNTIME_FIELDS},
        "stream": (dataclasses.asdict(cfg.stream)
                   if cfg.stream is not None else None),
        "wal_seq": wal_seq,
        "durability": dataclasses.asdict(engine._durability),
        "incremental": True,
        "base_ckpt": base["ckpt"],
        "base_wal_seq": base["wal_seq"],
        "chain": chain,
    }
    _commit_meta(directory, meta)
    engine._crash("snapshot_commit")
    # records past the BASE's position must survive truncation: they are
    # what re-seeds a follower built from the base artifact (and what a
    # re-resolved chain replays past the newest incremental)
    wal.pin_floor(base["wal_seq"])
    wal.truncate(wal_seq)
    engine._base_ref = dict(base, chain=chain)
    engine._snap_counters["incremental"] += 1
    engine._snap_counters["last_bytes"] = os.path.getsize(path)
    engine._snap_counters["chain_depth"] = len(chain) - 1
    return path


def load_engine(directory: str, mesh: Optional[Mesh] = None,
                axis: str = "data", role: str = "primary",
                **runtime_overrides) -> SearchEngine:
    """Restore a ``save_engine`` snapshot into a serving ``SearchEngine``.

    The spec string in ``engine.json`` rebuilds the config; the arrays are
    restored through ``repro.runtime.checkpoint`` into a pytree whose
    structure is derived from the spec — so the engine comes back with
    identical shapes, dtypes, and treedefs, and therefore compiles no new
    program shapes vs the engine that was saved. An incremental manifest
    resolves its chain: base arrays from the referenced full checkpoint,
    delta/tombstone/id-map arrays from the newest incremental.

    ``mesh`` restores straight onto a device mesh: every leaf is placed
    by ``restore_resharded`` and the engine is then partitioned along
    ``axis`` (read-only engines donate the transient dense copy; a
    streaming engine shards its base and keeps the replicated write
    path). ``runtime_overrides`` replace persisted runtime knobs
    (``query_bucket=...``, etc.).

    ``role="follower"`` builds a read replica: the snapshot's arrays and
    WAL *position* are restored, but the local log is neither replayed
    nor resumed (the directory may be a shipped copy; a follower's
    history comes from its primary via
    ``durability.replication.catch_up``, which also tracks the position
    from the snapshot's ``wal_seq``). Follower engines reject local
    writes.
    """
    if role not in ("primary", "follower"):
        raise ValueError(
            f"unknown role {role!r}; expected 'primary' or 'follower'")
    meta_path = os.path.join(directory, SNAPSHOT_META)
    if not os.path.isfile(meta_path):
        raise FileNotFoundError(
            f"no engine snapshot at {directory!r} (missing {SNAPSHOT_META})")
    with open(meta_path) as f:
        meta = json.load(f)
    if meta.get("schema") != _SCHEMA:
        raise ValueError(
            f"unknown snapshot schema {meta.get('schema')!r} in {meta_path}")
    if meta.get("ckpt"):
        # the metadata names its checkpoint: immune to a stray newer
        # array file whose metadata commit never happened (crash mid-save)
        path = os.path.join(directory, meta["ckpt"])
        if not os.path.isfile(path):
            raise FileNotFoundError(
                f"snapshot metadata names missing checkpoint {path!r}")
    else:
        path = latest_checkpoint(directory)
        if path is None:
            raise FileNotFoundError(f"no checkpoint file in {directory!r}")
    overlay = None
    if meta.get("incremental"):
        # chain resolution: the named ckpt is delta-only; the base holds
        # everything else. The newest incremental supersedes older links.
        overlay = path
        path = os.path.join(directory, meta["base_ckpt"])
        if not os.path.isfile(path):
            raise FileNotFoundError(
                f"incremental snapshot chain is broken: base checkpoint "
                f"{meta['base_ckpt']!r} is missing from {directory!r} "
                f"(chain {meta.get('chain')}); re-seed from a full "
                "snapshot")
    spec = parse_spec(meta["spec"])
    if "stream" in runtime_overrides:
        raise ValueError(
            "stream= cannot be overridden at load: the StreamConfig's "
            "capacities are baked into the saved store's array shapes — "
            "restore, then compact/rebuild to re-provision")
    runtime = dict(meta["runtime"])
    if meta["stream"] is not None:
        skw = dict(meta["stream"])
        if skw.get("policy") is not None:
            skw["policy"] = PolicyConfig(**skw["policy"])
        runtime["stream"] = StreamConfig(**skw)
    runtime.update(runtime_overrides)
    config = config_from_spec(spec, **runtime)
    # pre-zoo snapshots carry has_proj only: their one reducer was qpad
    red_kind = meta.get(
        "reducer", "qpad" if meta.get("has_proj") else None)
    skeleton = _snapshot_skeleton(meta["kind"], red_kind,
                                  meta["streaming"], meta["flat_alias"],
                                  store_fields=meta.get("store_fields", ()))
    template = _host_template(skeleton, path, overlay)
    if mesh is None:
        tree = restore_checkpoint(path, template, overlay=overlay)
    else:
        # checkpoints are shard-agnostic: place every leaf directly onto
        # the target mesh (replicated; the layout pass below partitions)
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), template)
        tree = restore_resharded(path, template, shardings, overlay=overlay)

    def _rewrap(raw):      # raw params from disk -> tagged Reducer union
        return Reducer(red_kind, raw) if red_kind is not None else None

    if meta["streaming"]:
        frozen = tree["frozen"]
        frozen = frozen._replace(proj=_rewrap(frozen.proj))
        engine = SearchEngine._restore(config, store=tree["store"],
                                       frozen=frozen)
    else:
        state = tree["state"]
        state = state._replace(proj=_rewrap(state.proj))
        if meta["flat_alias"]:
            state = state._replace(index=Index("flat", state.corpus))
        engine = SearchEngine._restore(config, state=state)
    wal_seq = meta.get("wal_seq", -1)
    engine._applied_seq = wal_seq
    if meta["streaming"]:
        # the loaded manifest's chain is the one this engine may extend
        # with save(dir, incremental=True)
        engine._base_ref = {
            "dir": os.path.abspath(directory),
            "ckpt": meta.get("base_ckpt") or meta["ckpt"],
            "wal_seq": (meta.get("base_wal_seq", wal_seq)
                        if meta.get("incremental") else wal_seq),
            "chain": list(meta.get("chain") or [meta["ckpt"]]),
        }
        engine._snap_counters["chain_depth"] = (
            len(engine._base_ref["chain"]) - 1)
    if role == "follower":
        # a replica: restore position only — no local replay (the
        # shipped history comes from the primary via catch_up), no
        # local WAL writer (followers never append)
        engine._role = "follower"
    elif meta.get("durability") is not None:
        # crash recovery: replay the WAL tail (records after the saved
        # sequence) through the engine's own write programs, then resume
        # appending to the same log — recovered == never-crashed
        from .durability.recovery import replay
        dcfg = DurabilityConfig(**meta["durability"])
        wal_dir = os.path.join(directory, "wal")
        stats = replay(engine, wal_dir, after_seq=wal_seq)
        engine._replayed = stats.records
        if stats.records:
            engine._applied_seq = stats.last_seq
        engine._wal = Wal(wal_dir, dcfg, resume=True)
        engine._durability = dcfg
        engine._durable_dir = os.path.abspath(directory)
        if meta["streaming"]:
            # the floor pin is engine state, not log state: re-pin from
            # the manifest so chained truncation keeps holding past a
            # process restart
            engine._wal.pin_floor(engine._base_ref["wal_seq"])
    if mesh is not None:
        engine.shard(mesh, axis=axis,
                     donate=not meta["streaming"])
    return engine
