"""Typed metrics: the engine's observability surface.

``SearchEngine.metrics()`` returns an ``EngineMetrics`` — frozen
dataclasses of named counters and gauges with *stable dotted names*
(``wal.records``, ``stream.fill``, ``compact.pending``,
``policy.drift_ema``, ``replication.follower_lag_seq``, ...). The dotted
names are the contract: dashboards, the ``--metrics-port`` endpoint and
``benchmarks/check_regression.py`` key off them, so they only ever gain
entries. (The legacy ``SearchEngine.stats()`` dict view completed its
deprecation cycle and is gone — ``metrics()`` is the only surface.)

When the engine has a ``Tracer`` attached (``engine.tracing()``, see
``repro.search.tracing``) two more sections appear: ``latency.*`` —
end-to-end and per-stage histograms (``HistogramSnapshot``) flattened to
``.p50/.p95/.p99/.count/.sum_ms`` plus slow-query counters — and
``recall.*`` — the shadow-exact online recall estimate.

Renderings:

- ``EngineMetrics.flatten()`` — ``{dotted_name: value}`` for JSON.
- ``render_prometheus(m)`` — Prometheus text exposition (dots become
  underscores under a ``qpad_`` prefix, names sanitized to the
  Prometheus grammar; counters and gauges get TYPE lines;
  ``HistogramSnapshot`` sections render as real ``histogram`` series
  in seconds with cumulative ``_bucket``/``_sum``/``_count``;
  string-valued entries ride on a ``qpad_engine_info`` label set with
  escaped values).
- ``MetricsServer`` — a stdlib ``http.server`` thread serving both
  (``/metrics`` Prometheus text, ``/metrics.json`` JSON); the
  launcher's ``--metrics-port`` flag.
"""
from __future__ import annotations

import dataclasses
import json
import re
import threading
import time
from typing import Mapping, Optional, Tuple

__all__ = ["EngineInfo", "StreamMetrics", "CompactMetrics", "PolicyMetrics",
           "WalMetrics", "SnapshotMetrics", "ReplicationMetrics",
           "HistogramSnapshot", "LatencyMetrics", "RecallMetrics",
           "EngineMetrics", "collect_metrics", "render_prometheus",
           "MetricsServer"]


@dataclasses.dataclass(frozen=True)
class HistogramSnapshot:
    """A frozen latency histogram: ``counts[i]`` observations at most
    ``bounds_ms[i]`` milliseconds (trailing overflow bucket), plus the
    exact sum/count. Percentiles interpolate linearly inside the winning
    bucket — the usual fixed-boundary estimate, so their resolution is
    the bucket width (log-spaced: ~a factor of 2)."""
    bounds_ms: Tuple[float, ...]
    counts: Tuple[int, ...]          # len(bounds_ms) + 1 (overflow)
    sum_ms: float
    count: int

    def percentile(self, p: float) -> float:
        """p in [0, 100] -> estimated latency in ms (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        target = (p / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds_ms[i - 1] if i > 0 else 0.0
                hi = (self.bounds_ms[i] if i < len(self.bounds_ms)
                      else self.bounds_ms[-1] * 2.0)
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.bounds_ms[-1] * 2.0


@dataclasses.dataclass(frozen=True)
class LatencyMetrics:
    """Request-latency section (present when a ``Tracer`` is attached)."""
    search: HistogramSnapshot        # latency.search.{p50,p95,p99,...}
    stages: Mapping[str, HistogramSnapshot]  # latency.stages.<stage>.*
    #                                  (deep-trace samples only)
    queries: int                     # latency.queries (traced searches)
    slow_queries: int                # latency.slow_queries
    slow_query_ms: Optional[float]   # latency.slow_query_ms (threshold)
    deep_traces: int                 # latency.deep_traces


@dataclasses.dataclass(frozen=True)
class RecallMetrics:
    """Online recall estimation (shadow-exact sampling)."""
    estimate_at_k: Optional[float]   # recall.estimate_at_k (EMA gauge)
    k: Optional[int]                 # recall.k (effective k of the checks)
    samples: int                     # recall.samples
    last: Optional[float]            # recall.last (newest raw sample)


@dataclasses.dataclass(frozen=True)
class EngineInfo:
    """Identity gauges: what this engine is."""
    index: str                       # engine.index
    spec: str                        # engine.spec
    streaming: bool                  # engine.streaming
    sharded: bool                    # engine.sharded
    role: str                        # engine.role ("primary" | "follower")
    compile_count: int               # engine.compile_count


@dataclasses.dataclass(frozen=True)
class StreamMetrics:
    """StreamStore occupancy gauges."""
    rows: int                        # stream.rows (allocated base rows;
    #                                  live = rows - tombstones)
    row_capacity: int                # stream.row_capacity
    delta_used: int                  # stream.delta_used
    delta_count: int                 # stream.delta_count
    delta_capacity: int              # stream.delta_capacity
    fill: float                      # stream.fill (delta_used / capacity)
    tombstones: int                  # stream.tombstones
    grow_count: int                  # stream.grow_count


@dataclasses.dataclass(frozen=True)
class CompactMetrics:
    """Compaction / maintenance counters + the in-flight gauge."""
    pending: bool                    # compact.pending (background fold live)
    compactions: int                 # compact.compactions
    swaps: int                       # compact.swaps
    vacuums: int                     # compact.vacuums
    rebuilds: int                    # compact.rebuilds
    policy_grows: int                # compact.policy_grows


@dataclasses.dataclass(frozen=True)
class PolicyMetrics:
    """MaintenancePolicy drift tracker + decision counters."""
    drift_ema: Optional[float]       # policy.drift_ema (recent build error)
    drift_base: Optional[float]      # policy.drift_base (error at build)
    drift_ratio: Optional[float]     # policy.drift_ratio (recent / base)
    observed_rows: int               # policy.observed_rows
    decisions: Mapping[str, int]     # policy.decisions.<kind>


@dataclasses.dataclass(frozen=True)
class WalMetrics:
    """Write-ahead-log counters and positions."""
    records: int                     # wal.records
    bytes: int                       # wal.bytes
    fsyncs: int                      # wal.fsyncs
    rotations: int                   # wal.rotations
    group_commits: int               # wal.group_commits
    segments: int                    # wal.segments
    last_seq: int                    # wal.last_seq
    durable_seq: int                 # wal.durable_seq
    floor_seq: int                   # wal.floor_seq (truncation pin; -1=none)
    replayed: int                    # wal.replayed (records at last recovery)
    fsync: str                       # wal.fsync (mode string)
    group_commit_ms: float           # wal.group_commit_ms


@dataclasses.dataclass(frozen=True)
class SnapshotMetrics:
    """Snapshot persistence counters (``engine.save``)."""
    full: int                        # snapshot.full
    incremental: int                 # snapshot.incremental
    last_bytes: int                  # snapshot.last_bytes (newest ckpt)
    chain_depth: int                 # snapshot.chain_depth (incrementals
    #                                  stacked on the current base)


@dataclasses.dataclass(frozen=True)
class ReplicationMetrics:
    """Follower position relative to its source (``catch_up``)."""
    applied_seq: int                 # replication.applied_seq
    source_tail_seq: int             # replication.source_tail_seq
    follower_lag_seq: int            # replication.follower_lag_seq
    catch_ups: int                   # replication.catch_ups
    records_applied: int             # replication.records_applied
    lag_seconds: Optional[float]     # replication.lag_seconds: wall time
    #                                  since the follower last drained its
    #                                  source (None until it first does)
    catch_up_age_seconds: Optional[float]  # replication.catch_up_age_seconds:
    #                                  wall time since the last catch_up
    #                                  pass of any kind (staleness alarm)


@dataclasses.dataclass(frozen=True)
class EngineMetrics:
    """One engine's full metrics snapshot. Sections that do not apply
    (a read-only engine has no ``stream``; a primary has no
    ``replication``) are ``None`` and drop out of ``flatten()``."""
    engine: EngineInfo
    stream: Optional[StreamMetrics] = None
    compact: Optional[CompactMetrics] = None
    policy: Optional[PolicyMetrics] = None
    wal: Optional[WalMetrics] = None
    snapshot: Optional[SnapshotMetrics] = None
    replication: Optional[ReplicationMetrics] = None
    latency: Optional[LatencyMetrics] = None
    recall: Optional[RecallMetrics] = None

    def flatten(self) -> dict:
        """``{dotted_name: value}`` — the stable wire form. Histogram
        fields flatten to derived ``.p50/.p95/.p99/.count/.sum_ms``
        entries (``latency.search.p50``, ``latency.stages.scan.p99``,
        ...); the full bucket vectors stay behind ``histograms()``."""
        out = {}
        for section in dataclasses.fields(self):
            val = getattr(self, section.name)
            if val is None:
                continue
            for f in dataclasses.fields(val):
                v = getattr(val, f.name)
                name = f"{section.name}.{f.name}"
                if isinstance(v, HistogramSnapshot):
                    out.update(_hist_entries(name, v))
                elif isinstance(v, Mapping):
                    for k in sorted(v):
                        if isinstance(v[k], HistogramSnapshot):
                            out.update(_hist_entries(f"{name}.{k}", v[k]))
                        else:
                            out[f"{name}.{k}"] = v[k]
                else:
                    out[name] = v
        return out

    def histograms(self) -> dict:
        """``{dotted_name: HistogramSnapshot}`` — the sections that
        render as Prometheus ``histogram`` series."""
        out = {}
        for section in dataclasses.fields(self):
            val = getattr(self, section.name)
            if val is None:
                continue
            for f in dataclasses.fields(val):
                v = getattr(val, f.name)
                name = f"{section.name}.{f.name}"
                if isinstance(v, HistogramSnapshot):
                    out[name] = v
                elif isinstance(v, Mapping):
                    for k in sorted(v):
                        if isinstance(v[k], HistogramSnapshot):
                            out[f"{name}.{k}"] = v[k]
        return out

    def to_json(self) -> str:
        return json.dumps(self.flatten(), sort_keys=True)


def _hist_entries(name: str, h: HistogramSnapshot) -> dict:
    return {f"{name}.p50": h.percentile(50.0),
            f"{name}.p95": h.percentile(95.0),
            f"{name}.p99": h.percentile(99.0),
            f"{name}.count": h.count,
            f"{name}.sum_ms": h.sum_ms}


# Dotted names that are monotonically increasing counters; everything
# else numeric is a gauge. Prefix-matched for the decision counters.
_COUNTER_NAMES = frozenset((
    "engine.compile_count", "stream.grow_count",
    "compact.compactions", "compact.swaps", "compact.vacuums",
    "compact.rebuilds", "compact.policy_grows",
    "wal.records", "wal.bytes", "wal.fsyncs", "wal.rotations",
    "wal.group_commits", "wal.replayed",
    "snapshot.full", "snapshot.incremental",
    "replication.catch_ups", "replication.records_applied",
    "latency.queries", "latency.slow_queries", "latency.deep_traces",
    "recall.samples",
))


def _is_counter(name: str) -> bool:
    return name in _COUNTER_NAMES or name.startswith("policy.decisions.")


def _sanitize_name(name: str) -> str:
    """Dotted metric name -> valid Prometheus identifier
    (``[a-zA-Z_:][a-zA-Z0-9_:]*``). Spec-derived map keys can carry
    digits/hyphens/arbitrary punctuation — every invalid byte becomes
    ``_`` and a leading digit gets a ``_`` prefix."""
    name = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _escape_label(value: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote and
    newline (spec strings contain ``>``/``:`` which are legal, but a
    quote or newline would tear the exposition)."""
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _render_histogram(lines: list, name: str, h: HistogramSnapshot):
    """One Prometheus ``histogram`` series (in seconds, the Prometheus
    base unit) with cumulative ``_bucket`` counts, ``_sum``, ``_count``."""
    pname = _sanitize_name("qpad_" + name.replace(".", "_") + "_seconds")
    lines.append(f"# TYPE {pname} histogram")
    cum = 0
    for bound_ms, count in zip(h.bounds_ms, h.counts):
        cum += count
        lines.append(f'{pname}_bucket{{le="{bound_ms / 1e3:.6g}"}} {cum}')
    cum += h.counts[-1]
    lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
    lines.append(f"{pname}_sum {h.sum_ms / 1e3:.9g}")
    lines.append(f"{pname}_count {h.count}")


def render_prometheus(m: EngineMetrics) -> str:
    """Prometheus text exposition of one metrics snapshot. Numeric
    entries become ``qpad_<dotted_with_underscores>`` samples with TYPE
    lines (names sanitized to the Prometheus grammar); histogram
    sections become real ``histogram`` series in seconds
    (``qpad_latency_search_seconds_bucket``/``_sum``/``_count``)
    alongside the derived percentile gauges; string entries (index kind,
    fsync mode, role, spec) become escaped labels on a single
    ``qpad_engine_info`` gauge."""
    lines, info_labels = [], []
    for name, value in sorted(m.flatten().items()):
        if value is None:
            continue
        if isinstance(value, str):
            key = _sanitize_name(name.replace(".", "_"))
            info_labels.append(f'{key}="{_escape_label(value)}"')
            continue
        pname = _sanitize_name("qpad_" + name.replace(".", "_"))
        kind = "counter" if _is_counter(name) else "gauge"
        lines.append(f"# TYPE {pname} {kind}")
        if isinstance(value, bool):
            value = int(value)
        lines.append(f"{pname} {value}")
    for name, h in sorted(m.histograms().items()):
        _render_histogram(lines, name, h)
    lines.append("# TYPE qpad_engine_info gauge")
    lines.append("qpad_engine_info{%s} 1" % ",".join(info_labels))
    return "\n".join(lines) + "\n"


def collect_metrics(engine) -> EngineMetrics:
    """Assemble ``EngineMetrics`` from a live ``SearchEngine``."""
    import jax.numpy as jnp

    from .spec import format_spec

    info = EngineInfo(
        index=engine.config.index, spec=format_spec(engine.spec),
        streaming=engine.store is not None,
        sharded=(engine.sharded_state is not None
                 or engine._stream_sharded_base is not None),
        role=engine._role, compile_count=engine.compile_count)
    stream = compact = policy = wal = snapshot = replication = None
    store = engine.store
    if store is not None:
        cap = int(store.delta_ids.shape[0])
        used = engine._delta_used
        tombstones = int(jnp.sum(store.dead))
        stream = StreamMetrics(
            rows=int(store.n_rows),
            row_capacity=int(store.corpus.shape[0]),
            delta_used=used, delta_count=int(store.delta_count),
            delta_capacity=cap, fill=used / cap if cap else 0.0,
            tombstones=tombstones,
            grow_count=engine.grow_count)
        c = engine._counters
        compact = CompactMetrics(
            pending=engine._compact_future is not None,
            compactions=c["compactions"], swaps=c["swaps"],
            vacuums=c["vacuums"], rebuilds=c["rebuilds"],
            policy_grows=c["policy_grows"])
        sc = engine._snap_counters
        snapshot = SnapshotMetrics(
            full=sc["full"], incremental=sc["incremental"],
            last_bytes=sc["last_bytes"], chain_depth=sc["chain_depth"])
    if engine._policy is not None:
        ps = engine._policy.stats()
        policy = PolicyMetrics(
            drift_ema=ps["recent_error"], drift_base=ps["base_error"],
            drift_ratio=ps["drift_ratio"], observed_rows=ps["recent_rows"],
            decisions=dict(ps["decisions"]))
    if engine._wal is not None:
        ws = engine._wal.stats()
        wal = WalMetrics(
            records=ws["records"], bytes=ws["bytes"], fsyncs=ws["fsyncs"],
            rotations=ws["rotations"], group_commits=ws["group_commits"],
            segments=ws["segments"], last_seq=ws["last_seq"],
            durable_seq=ws["durable_seq"], floor_seq=ws["floor_seq"],
            replayed=engine._replayed, fsync=ws["fsync"],
            group_commit_ms=ws["group_commit_ms"])
    if engine._role == "follower":
        now = time.time()
        last_ts = getattr(engine, "_repl_last_catch_up_ts", None)
        caught_ts = getattr(engine, "_repl_caught_up_ts", None)
        replication = ReplicationMetrics(
            applied_seq=engine._applied_seq,
            source_tail_seq=engine._repl_source_tail,
            follower_lag_seq=max(
                0, engine._repl_source_tail - engine._applied_seq),
            catch_ups=engine._repl_catch_ups,
            records_applied=engine._repl_records,
            lag_seconds=(None if caught_ts is None else now - caught_ts),
            catch_up_age_seconds=(None if last_ts is None
                                  else now - last_ts))
    latency = recall = None
    tracer = getattr(engine, "_tracer", None)
    if tracer is not None:
        latency, recall = tracer.metrics_sections()
    return EngineMetrics(engine=info, stream=stream, compact=compact,
                         policy=policy, wal=wal, snapshot=snapshot,
                         replication=replication, latency=latency,
                         recall=recall)


class MetricsServer:
    """Serve an engine's metrics from a background ``http.server``
    thread — the launcher's ``--metrics-port``.

    Routes: ``/metrics`` (Prometheus text), ``/metrics.json`` and ``/``
    (flattened JSON). Each request takes a fresh ``metrics()`` snapshot;
    a scrape that races an engine mutation gets a 503 and retries on the
    next interval. ``port=0`` binds an ephemeral port (``.port`` has the
    real one). Context-manager friendly; ``close()`` stops the thread.
    """

    def __init__(self, engine, port: int = 0, host: str = "127.0.0.1"):
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(handler):
                try:
                    m = engine.metrics()
                    if handler.path == "/metrics":
                        body = render_prometheus(m).encode()
                        ctype = "text/plain; version=0.0.4"
                    elif handler.path in ("/", "/metrics.json"):
                        body = m.to_json().encode()
                        ctype = "application/json"
                    else:
                        handler.send_error(404)
                        return
                except Exception as e:       # raced a donated-buffer write
                    handler.send_error(503, explain=str(e))
                    return
                handler.send_response(200)
                handler.send_header("Content-Type", ctype)
                handler.send_header("Content-Length", str(len(body)))
                handler.end_headers()
                handler.wfile.write(body)

            def log_message(handler, *a):    # quiet: no per-scrape stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="qpad-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
