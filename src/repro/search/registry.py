"""The tagged index union and the per-kind operation registry.

One ``Index`` pytree — a static ``kind`` tag plus the kind's stage
payload — replaces the old four-way ``Optional[IVFIndex] /
Optional[PQIndex] / Optional[IVFPQIndex] / Optional[reduced]`` fields
that ``EngineState``, ``ShardedEngineState``, and ``FrozenParams`` each
carried (and that every scan site re-dispatched on with if/elif chains).
The tag lives in the pytree's **aux data**, so it is static under
``jax.jit`` and keys compile caches through the treedef; the payload is
ordinary array state that shards, donates, and serialises.

Each kind registers one ``IndexOps`` entry holding every operation the
serving stack dispatches on:

    build                train the payload over the (reduced) corpus
    scan                 single-device probe/scan       (search_fn)
    local_scan           shard-local scan, global ids   (sharded serving,
                         also the streaming sharded base scan via live=)
    stream_scan          tombstone-masked base scan     (stream_search_fn)
    shard_payload        host-side sharded re-layout    (shard_engine)
    payload_specs        PartitionSpec tree for the sharded payload
    store_parts          StreamStore layout + frozen quantizer payload
    encode_delta         re-code delta rows on frozen quantizers (compact)
    rebuild              payload from frozen quantizers (rebuild_state)
    stream_base_payload  dense payload over a StreamStore (shard_stream)

Adding a future index kind (HNSW, additive quantizers, ...) is one
``register_index(IndexOps(...))`` call — no engine, stream, or sharding
edits. The ``opq`` kind below is the existence proof: a learned
orthogonal rotation (alternating Procrustes / assignment, OPQ-style)
fitted before PQ coding, registered as one entry that delegates every
scan to the plain-PQ ADC/LUT/kernel paths on the rotated query.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .ivf import (IVFIndex, build_ivf, cell_vectors, ivf_local_scan,
                  ivf_scan, probe_cells, sq_dists)
from .ivfpq import (IVFPQIndex, build_ivfpq, ivfpq_adc_scan,
                    ivfpq_compact_scan, ivfpq_local_scan, ivfpq_scan)
from .knn import _sq_dists, knn_scan, masked_topk
from .pq import PQIndex, adc_tables, build_pq, pq_local_scan, pq_scan

__all__ = ["Index", "IndexOps", "ScanParams", "INDEX_KINDS",
           "register_index", "get_ops",
           "ShardedIVF", "ShardedPQ", "ShardedIVFPQ", "ShardedOPQ",
           "PQQuant", "IVFPQQuant", "OPQIndex", "OPQQuant"]


@dataclasses.dataclass(frozen=True)
class Index:
    """The tagged union: ``kind`` (static aux data) + its array payload.

    Payload types by kind — dense / sharded / frozen-quantizer roles:

      "flat"   scan vectors (N, m)   / row-sharded copy or None / None
      "ivf"    IVFIndex              / ShardedIVF               / centroids
      "pq"     PQIndex               / ShardedPQ                / PQQuant
      "opq"    OPQIndex              / ShardedOPQ               / OPQQuant
      "ivfpq"  IVFPQIndex            / ShardedIVFPQ             / IVFPQQuant
    """
    kind: str
    payload: Any


jax.tree_util.register_dataclass(Index, data_fields=["payload"],
                                 meta_fields=["kind"])


@dataclasses.dataclass(frozen=True)
class ScanParams:
    """Query-time scan knobs (trace-time constants, one bundle).

    ``scan_cap > 0`` switches the ivfpq scan to the nprobe-proportional
    compact variant (``ivfpq_compact_scan``): the candidate gather width
    becomes ``scan_cap`` flat slots sized by actual posting mass instead
    of ``nprobe * max_cell`` padded slots. The engine computes a cap that
    covers any query's probed mass, so results stay bit-identical to the
    padded scan. 0 = padded scan (the default; other kinds ignore it).
    """
    nprobe: int = 8
    backend: str = "jnp"
    interpret: bool = True
    lut_dtype: str = "f32"
    scan_cap: int = 0


class ShardedIVF(NamedTuple):
    """IVF payload re-laid for a database-axis mesh (cell-sharded)."""
    centroids: jax.Array    # (nlist, d) replicated
    lists: jax.Array        # (nlist_pad, mc) cell-sharded
    cell_vecs: jax.Array    # (nlist_pad, mc, d) cell-sharded mirror


class ShardedPQ(NamedTuple):
    """Plain-PQ payload re-laid for a database-axis mesh (row-sharded)."""
    codes: jax.Array        # (N_pad, M) row-sharded
    lut_w: jax.Array        # (d, M*K) replicated
    cbnorm: jax.Array       # (M, K) replicated


class ShardedIVFPQ(NamedTuple):
    """IVF-PQ payload re-laid for a database-axis mesh (cell-sharded)."""
    centroids: jax.Array    # (nlist, d) replicated
    lists: jax.Array        # (nlist_pad, mc) cell-sharded
    codes_cell: jax.Array   # (nlist_pad, mc, M) cell-sharded
    bias_cell: jax.Array    # (nlist_pad, mc) cell-sharded
    lut_w: jax.Array        # (d, M*K) replicated
    cbnorm: jax.Array       # (M, K) replicated
    codebooks: jax.Array    # (M, K, dsub) replicated (analytic LUT stats)


class OPQIndex(NamedTuple):
    """OPQ payload: a learned orthogonal rotation of the scan space plus
    plain-PQ state over the rotated rows. Every scan delegates to the PQ
    ADC paths with the query rotated first (rotation is an isometry, so
    delta scans and re-ranks in the unrotated space stay consistent)."""
    rot: jax.Array          # (d, d) learned orthogonal rotation
    codebooks: jax.Array    # (M, K, dsub) over the rotated space
    codes: jax.Array        # (N, M) stored width (uint8 for K <= 256)
    lut_w: jax.Array        # (d, M*K)
    cbnorm: jax.Array       # (M, K)


class ShardedOPQ(NamedTuple):
    """OPQ payload re-laid for a database-axis mesh (row-sharded)."""
    rot: jax.Array          # (d, d) replicated
    codes: jax.Array        # (N_pad, M) row-sharded
    lut_w: jax.Array        # (d, M*K) replicated
    cbnorm: jax.Array       # (M, K) replicated


class PQQuant(NamedTuple):
    """Frozen PQ quantizers (streaming ``FrozenParams`` payload)."""
    codebooks: jax.Array    # (M, K, dsub)
    lut_w: jax.Array        # (d, M*K)
    cbnorm: jax.Array       # (M, K)


class OPQQuant(NamedTuple):
    """Frozen OPQ quantizers (streaming ``FrozenParams`` payload)."""
    rot: jax.Array          # (d, d)
    codebooks: jax.Array    # (M, K, dsub)
    lut_w: jax.Array        # (d, M*K)
    cbnorm: jax.Array       # (M, K)


class IVFPQQuant(NamedTuple):
    """Frozen IVF-PQ quantizers (streaming ``FrozenParams`` payload)."""
    centroids: jax.Array    # (nlist, d)
    codebooks: jax.Array    # (M, K, dsub)
    lut_w: jax.Array        # (d, M*K)
    cbnorm: jax.Array       # (M, K)


@dataclasses.dataclass(frozen=True)
class IndexOps:
    """Everything the serving stack needs to know about one index kind."""
    kind: str
    lossy: bool                       # scan scores approximate the metric
    #                                   (forces over-retrieve + re-rank)
    build: Callable                   # (key, reduced, spec) -> payload
    scan: Callable                    # (state, qr, n_cand, p) -> (d2, cand)
    local_scan: Callable              # (sstate, qr, n_cand, p, axis, slack,
    #                                    live=None) -> (d2, global cand)
    stream_scan: Callable             # (store, frozen, qr, n_cand, live, p)
    #                                    -> (d2, cand)
    shard_payload: Callable           # (state, shards) -> sharded payload
    payload_specs: Callable           # (payload, axis) -> PartitionSpec tree
    store_parts: Callable             # (state, n_cap, cell_slack) ->
    #                                    (store field overrides, quant payload)
    encode_delta: Callable            # (frozen, rows) -> (assign, codes, bias)
    rebuild: Callable                 # (frozen, reduced, shards) -> payload
    stream_base_payload: Callable     # (store, frozen, corpus_owned) ->
    #                                    dense payload over the store
    payload_skeleton: Callable        # (leaf) -> payload-shaped tree of leaf
    #                                    placeholders (snapshot restore)
    quant_skeleton: Callable          # (leaf) -> frozen-quant-shaped tree
    drift_stats: Optional[Callable] = None   # (frozen, rows) -> (B,) squared
    #                                    reconstruction error of scan-space
    #                                    rows under the frozen quantizers
    #                                    (MaintenancePolicy drift signal;
    #                                    None = kind is not quantized)


_REGISTRY: dict = {}


def register_index(ops: IndexOps) -> IndexOps:
    """Install (or replace) the ops entry for ``ops.kind``."""
    _REGISTRY[ops.kind] = ops
    return ops


def get_ops(kind: str) -> IndexOps:
    """Look up the registered ``IndexOps`` for an index kind (the single
    dispatch point of every scan/build/shard/stream site)."""
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown index kind {kind!r}; registered kinds: "
            f"{tuple(_REGISTRY)}") from None


def _pad_dim0(a: Optional[jax.Array], multiple: int, fill=0):
    """Right-pad dim 0 up to a multiple (per-shard-equal blocks)."""
    if a is None:
        return None
    pad = (-a.shape[0]) % multiple
    if not pad:
        return a
    widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


def _pad_rows(a: jax.Array, n_cap: int, fill=0) -> jax.Array:
    """Copy + right-pad dim 0 to the fixed row capacity (fresh buffer)."""
    pad = n_cap - a.shape[0]
    if pad <= 0:
        return jnp.array(a)                    # jnp.array copies
    widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


def _pad_cells(a: jax.Array, slack: int, fill=0) -> jax.Array:
    """Copy + grow the per-cell (dim-1) capacity of a cell-major array."""
    if slack <= 0:
        return jnp.array(a)
    widths = ((0, 0), (0, slack)) + ((0, 0),) * (a.ndim - 2)
    return jnp.pad(a, widths, constant_values=fill)


def _own(a: Optional[jax.Array]) -> Optional[jax.Array]:
    return None if a is None else jnp.array(a)


def _encode_pq(codebooks, x):
    from .segments import encode_pq
    return encode_pq(codebooks, x)


def _ivfpq_encode(centroids, codebooks, x):
    from .segments import ivfpq_encode
    return ivfpq_encode(centroids, codebooks, x)


def _pq_decode(codebooks, codes):
    """Reconstruct rows from PQ codes: (B, M) int32 -> (B, M*dsub) f32."""
    m, kc, dsub = codebooks.shape
    recon = jnp.take_along_axis(
        codebooks[None], codes[:, :, None, None], axis=2)[:, :, 0, :]
    return recon.reshape(codes.shape[0], m * dsub)


# per-kind drift statistics (MaintenancePolicy): squared reconstruction
# error of scan-space rows under the frozen quantizers — how much signal
# the coded scan loses on *today's* data vs the build-time baseline

def _ivf_drift_stats(frozen, rows):
    assign = jnp.argmin(sq_dists(rows, frozen.centroids), axis=1)
    return jnp.sum((rows - frozen.centroids[assign]) ** 2, axis=-1)


def _pq_drift_stats(frozen, rows):
    codes = _encode_pq(frozen.codebooks, rows)
    return jnp.sum((rows - _pq_decode(frozen.codebooks, codes)) ** 2,
                   axis=-1)


def _ivfpq_drift_stats(frozen, rows):
    assign, codes, _ = _ivfpq_encode(frozen.centroids, frozen.codebooks,
                                     rows)
    recon = frozen.centroids[assign] + _pq_decode(frozen.codebooks, codes)
    return jnp.sum((rows - recon) ** 2, axis=-1)


# --- flat: exact scan of the (reduced) vectors -------------------------------

def _flat_build(key, reduced, spec):
    # the payload IS the scan rows; with no Reduce stage this is the corpus
    # array itself (aliasing the sharding/persistence layers preserve)
    return reduced


def _flat_scan(state, qr, n_cand, p):
    return knn_scan(qr, state.index.payload, n_cand)


def _flat_local_scan(sstate, qr, n_cand, p, axis, slack, live=None):
    """Shard-local exact scan over this shard's row block; shard-pad rows
    (global id >= n_real) and — streaming — non-live rows mask to
    (+inf, -1). Distances come from the same ``_sq_dists`` as the
    single-device ``knn_scan`` so the two paths rank identically."""
    x_loc = (sstate.index.payload if sstate.index.payload is not None
             else sstate.corpus)
    n_loc = x_loc.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    gid = off + jnp.arange(n_loc)
    ok = gid < sstate.n_real
    if live is not None:
        n_cap = live.shape[0]
        ok = ok & live[jnp.clip(gid, 0, n_cap - 1)]
    d2 = jnp.where(ok[None, :], _sq_dists(qr, x_loc), jnp.inf)
    return masked_topk(d2, jnp.broadcast_to(gid[None, :], d2.shape), n_cand)


def _flat_stream_scan(store, frozen, qr, n_cand, live, p):
    scan_rows = store.reduced if store.reduced is not None else store.corpus
    d2 = _sq_dists(qr, scan_rows)
    d2 = jnp.where(live[None, :], d2, jnp.inf)
    n_cap = scan_rows.shape[0]
    ids = jnp.broadcast_to(jnp.arange(n_cap)[None, :], d2.shape)
    return masked_topk(d2, ids, n_cand)


def _flat_shard_payload(state, shards):
    # flat without a Reduce stage scans the corpus itself; don't ship the
    # same rows twice — None routes the local scan to the sharded corpus
    if state.index.payload is state.corpus:
        return None
    return _pad_dim0(state.index.payload, shards)


def _flat_payload_specs(payload, axis):
    return None if payload is None else P(axis)


def _flat_store_parts(state, n_cap, cell_slack):
    if state.proj is None:
        return {}, None            # scan falls back to the corpus row store
    return {"reduced": _pad_rows(state.index.payload, n_cap)}, None


def _flat_encode_delta(frozen, rows):
    return None, None, None


def _flat_rebuild(frozen, reduced, shards):
    return reduced


def _flat_stream_base_payload(store, frozen, corpus_owned):
    return _own(store.reduced) if store.reduced is not None else corpus_owned


register_index(IndexOps(
    kind="flat", lossy=False,
    build=_flat_build, scan=_flat_scan, local_scan=_flat_local_scan,
    stream_scan=_flat_stream_scan, shard_payload=_flat_shard_payload,
    payload_specs=_flat_payload_specs, store_parts=_flat_store_parts,
    encode_delta=_flat_encode_delta, rebuild=_flat_rebuild,
    stream_base_payload=_flat_stream_base_payload,
    payload_skeleton=lambda leaf: leaf,
    quant_skeleton=lambda leaf: None))


# --- ivf: coarse k-means quantizer + probed exact scan -----------------------

def _ivf_build(key, reduced, spec):
    return build_ivf(jax.random.fold_in(key, 1), reduced, spec.coarse.nlist)


def _ivf_scan(state, qr, n_cand, p):
    return ivf_scan(state.index.payload, qr, n_cand, p.nprobe)


def _ivf_local_scan(sstate, qr, n_cand, p, axis, slack, live=None):
    ix = sstate.index.payload
    return ivf_local_scan(ix.centroids, ix.lists, ix.cell_vecs, qr, n_cand,
                          p.nprobe, axis, live=live)


def _ivf_stream_scan(store, frozen, qr, n_cand, live, p):
    scan_rows = store.reduced if store.reduced is not None else store.corpus
    n_cap = scan_rows.shape[0]
    _, cand, _ = probe_cells(frozen.centroids, store.lists, qr, p.nprobe,
                             n_cand)
    ok = (cand >= 0) & live[jnp.clip(cand, 0, n_cap - 1)]
    cv = jnp.take(scan_rows, jnp.maximum(cand, 0), axis=0)
    d2 = jnp.sum((cv - qr[:, None, :]) ** 2, axis=-1)
    return masked_topk(jnp.where(ok, d2, jnp.inf), cand, n_cand)


def _ivf_shard_payload(state, shards):
    ix = state.index.payload
    lists = _pad_dim0(ix.lists, shards, fill=-1)
    return ShardedIVF(centroids=ix.centroids, lists=lists,
                      cell_vecs=cell_vectors(lists, ix.vectors))


def _ivf_payload_specs(payload, axis):
    return ShardedIVF(centroids=P(), lists=P(axis), cell_vecs=P(axis))


def _ivf_store_parts(state, n_cap, cell_slack):
    ix = state.index.payload
    parts = {"lists": _pad_cells(ix.lists, cell_slack, fill=-1)}
    if state.proj is not None:
        parts["reduced"] = _pad_rows(ix.vectors, n_cap)
    return parts, ix.centroids


def _ivf_encode_delta(frozen, rows):
    assign = jnp.argmin(sq_dists(rows, frozen.centroids), axis=1)
    return assign, None, None


def _ivf_rebuild(frozen, reduced, shards):
    from .ivf import posting_lists
    assign = jnp.argmin(sq_dists(reduced, frozen.centroids), axis=1)
    lists = posting_lists(assign, frozen.centroids.shape[0], shards)
    return IVFIndex(centroids=frozen.centroids, lists=lists, vectors=reduced)


def _ivf_stream_base_payload(store, frozen, corpus_owned):
    # vectors need no copy: shard_engine only reads them through
    # cell_vectors(), whose gather materializes fresh buffers
    scan_rows = store.reduced if store.reduced is not None else store.corpus
    return IVFIndex(centroids=frozen.centroids, lists=_own(store.lists),
                    vectors=scan_rows)


register_index(IndexOps(
    kind="ivf", lossy=False,
    build=_ivf_build, scan=_ivf_scan, local_scan=_ivf_local_scan,
    stream_scan=_ivf_stream_scan, shard_payload=_ivf_shard_payload,
    payload_specs=_ivf_payload_specs, store_parts=_ivf_store_parts,
    encode_delta=_ivf_encode_delta, rebuild=_ivf_rebuild,
    stream_base_payload=_ivf_stream_base_payload,
    payload_skeleton=lambda leaf: IVFIndex(
        centroids=leaf, lists=leaf, vectors=leaf),
    quant_skeleton=lambda leaf: leaf,
    drift_stats=_ivf_drift_stats))


# --- pq: product-quantized vectors, fused ADC scan ---------------------------

def _pq_build(key, reduced, spec):
    return build_pq(jax.random.fold_in(key, 2), reduced,
                    spec.code.subspaces, spec.code.centroids)


def _pq_scan(state, qr, n_cand, p):
    return pq_scan(state.index.payload, qr, n_cand, backend=p.backend,
                   interpret=p.interpret, lut_dtype=p.lut_dtype)


def _pq_local_scan(sstate, qr, n_cand, p, axis, slack, live=None):
    ix = sstate.index.payload
    return pq_local_scan(ix.lut_w, ix.cbnorm, ix.codes, qr, n_cand,
                         sstate.n_real, axis, backend=p.backend,
                         interpret=p.interpret, lut_dtype=p.lut_dtype,
                         slack=slack, live=live)


def _pq_stream_scan(store, frozen, qr, n_cand, live, p):
    from repro.kernels.pq_adc.lut import center_lut
    from repro.kernels.pq_adc.ref import pq_adc_scores_ref
    nq = qr.shape[0]
    m, kc = frozen.cbnorm.shape
    tables = adc_tables(frozen.lut_w, frozen.cbnorm, qr)
    const = jnp.sum(qr * qr, axis=1)
    if p.lut_dtype != "f32":
        tables, offs = center_lut(tables)
        const = const + offs
    scores = (pq_adc_scores_ref(tables, store.codes, p.lut_dtype)
              + const[:, None])
    scores = jnp.where(live[None, :], scores, jnp.inf)
    n_cap = store.codes.shape[0]
    ids = jnp.broadcast_to(jnp.arange(n_cap)[None, :], scores.shape)
    return masked_topk(scores, ids, n_cand)


def _pq_shard_payload(state, shards):
    ix = state.index.payload
    # codes ship at stored width (uint8 for K <= 256); both backends widen
    # in-register, so the sharded copy keeps the 4x memory saving
    return ShardedPQ(
        codes=_pad_dim0(ix.codes, shards),
        lut_w=ix.lut_w, cbnorm=ix.cbnorm)


def _pq_payload_specs(payload, axis):
    return ShardedPQ(codes=P(axis), lut_w=P(), cbnorm=P())


def _pq_store_parts(state, n_cap, cell_slack):
    # no ``reduced`` mirror: the coded base is scanned through its codes,
    # the delta through ``delta_reduced``, the re-rank through ``corpus``
    ix = state.index.payload
    parts = {"codes": _pad_rows(ix.codes, n_cap)}     # stored width (uint8)
    return parts, PQQuant(codebooks=ix.codebooks, lut_w=ix.lut_w,
                          cbnorm=ix.cbnorm)


def _pq_encode_delta(frozen, rows):
    return None, _encode_pq(frozen.codebooks, rows), None


def _pq_rebuild(frozen, reduced, shards):
    code_dt = jnp.uint8 if frozen.codebooks.shape[1] <= 256 else jnp.int32
    return PQIndex(codebooks=frozen.codebooks,
                   codes=_encode_pq(frozen.codebooks,
                                    reduced).astype(code_dt),
                   lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)


def _pq_stream_base_payload(store, frozen, corpus_owned):
    return PQIndex(codebooks=frozen.codebooks, codes=_own(store.codes),
                   lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)


register_index(IndexOps(
    kind="pq", lossy=True,
    build=_pq_build, scan=_pq_scan, local_scan=_pq_local_scan,
    stream_scan=_pq_stream_scan, shard_payload=_pq_shard_payload,
    payload_specs=_pq_payload_specs, store_parts=_pq_store_parts,
    encode_delta=_pq_encode_delta, rebuild=_pq_rebuild,
    stream_base_payload=_pq_stream_base_payload,
    payload_skeleton=lambda leaf: PQIndex(
        codebooks=leaf, codes=leaf, lut_w=leaf, cbnorm=leaf),
    quant_skeleton=lambda leaf: PQQuant(
        codebooks=leaf, lut_w=leaf, cbnorm=leaf),
    drift_stats=_pq_drift_stats))


# --- opq: learned orthogonal rotation + PQ codes -----------------------------
# "Quantization Meets Projection": alternate (1) k-means codebooks on the
# rotated rows with (2) the orthogonal Procrustes solution R = U V^T of
# X^T X_hat — each step can only help the rotated-space quantization, and
# the identity-rotation iterate IS the plain-pq build (same key fold), so
# keeping the lowest-MSE iterate guarantees opq reconstruction error
# <= plain pq at equal code bytes.

_OPQ_ITERS = 3          # Procrustes/assignment alternations after identity


def _opq_pq_view(ix) -> PQIndex:
    """The plain-PQ view of an OPQ payload (scan delegation)."""
    return PQIndex(codebooks=ix.codebooks, codes=ix.codes,
                   lut_w=ix.lut_w, cbnorm=ix.cbnorm)


def _opq_build(key, reduced, spec):
    x = jnp.asarray(reduced, jnp.float32)
    d = x.shape[1]
    rot = jnp.eye(d, dtype=jnp.float32)
    best = None
    best_err = jnp.inf
    # fold 2 on purpose: iterate 0 (rot = I) reproduces _pq_build exactly
    pq_key = jax.random.fold_in(key, 2)
    for _ in range(_OPQ_ITERS + 1):
        xr = x @ rot
        pq = build_pq(pq_key, xr, spec.code.subspaces, spec.code.centroids)
        recon = _pq_decode(pq.codebooks, pq.codes.astype(jnp.int32))
        err = jnp.mean(jnp.sum((xr - recon) ** 2, axis=1))
        if best is None or bool(err < best_err):
            best, best_err = OPQIndex(rot=rot, codebooks=pq.codebooks,
                                      codes=pq.codes, lut_w=pq.lut_w,
                                      cbnorm=pq.cbnorm), err
        u, _, vt = jnp.linalg.svd(x.T @ recon)
        rot = u @ vt
    return best


def _opq_scan(state, qr, n_cand, p):
    ix = state.index.payload
    return pq_scan(_opq_pq_view(ix), qr @ ix.rot, n_cand, backend=p.backend,
                   interpret=p.interpret, lut_dtype=p.lut_dtype)


def _opq_local_scan(sstate, qr, n_cand, p, axis, slack, live=None):
    ix = sstate.index.payload
    return pq_local_scan(ix.lut_w, ix.cbnorm, ix.codes, qr @ ix.rot, n_cand,
                         sstate.n_real, axis, backend=p.backend,
                         interpret=p.interpret, lut_dtype=p.lut_dtype,
                         slack=slack, live=live)


def _opq_stream_scan(store, frozen, qr, n_cand, live, p):
    # rotate, then the masked plain-PQ ADC scan serves the rotated space
    return _pq_stream_scan(store, frozen, qr @ frozen.quant.payload.rot,
                           n_cand, live, p)


def _opq_shard_payload(state, shards):
    ix = state.index.payload
    return ShardedOPQ(rot=ix.rot, codes=_pad_dim0(ix.codes, shards),
                      lut_w=ix.lut_w, cbnorm=ix.cbnorm)


def _opq_payload_specs(payload, axis):
    return ShardedOPQ(rot=P(), codes=P(axis), lut_w=P(), cbnorm=P())


def _opq_store_parts(state, n_cap, cell_slack):
    ix = state.index.payload
    parts = {"codes": _pad_rows(ix.codes, n_cap)}     # stored width (uint8)
    return parts, OPQQuant(rot=ix.rot, codebooks=ix.codebooks,
                           lut_w=ix.lut_w, cbnorm=ix.cbnorm)


def _opq_encode_delta(frozen, rows):
    rot = frozen.quant.payload.rot
    return None, _encode_pq(frozen.codebooks, rows @ rot), None


def _opq_rebuild(frozen, reduced, shards):
    rot = frozen.quant.payload.rot
    code_dt = jnp.uint8 if frozen.codebooks.shape[1] <= 256 else jnp.int32
    return OPQIndex(rot=rot, codebooks=frozen.codebooks,
                    codes=_encode_pq(frozen.codebooks,
                                     reduced @ rot).astype(code_dt),
                    lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)


def _opq_stream_base_payload(store, frozen, corpus_owned):
    q = frozen.quant.payload
    return OPQIndex(rot=q.rot, codebooks=q.codebooks,
                    codes=_own(store.codes), lut_w=q.lut_w, cbnorm=q.cbnorm)


def _opq_drift_stats(frozen, rows):
    xr = rows @ frozen.quant.payload.rot
    codes = _encode_pq(frozen.codebooks, xr)
    return jnp.sum((xr - _pq_decode(frozen.codebooks, codes)) ** 2, axis=-1)


register_index(IndexOps(
    kind="opq", lossy=True,
    build=_opq_build, scan=_opq_scan, local_scan=_opq_local_scan,
    stream_scan=_opq_stream_scan, shard_payload=_opq_shard_payload,
    payload_specs=_opq_payload_specs, store_parts=_opq_store_parts,
    encode_delta=_opq_encode_delta, rebuild=_opq_rebuild,
    stream_base_payload=_opq_stream_base_payload,
    payload_skeleton=lambda leaf: OPQIndex(
        rot=leaf, codebooks=leaf, codes=leaf, lut_w=leaf, cbnorm=leaf),
    quant_skeleton=lambda leaf: OPQQuant(
        rot=leaf, codebooks=leaf, lut_w=leaf, cbnorm=leaf),
    drift_stats=_opq_drift_stats))


# --- ivfpq: coarse quantizer + PQ-coded residuals ----------------------------

def _ivfpq_build(key, reduced, spec):
    return build_ivfpq(jax.random.fold_in(key, 3), reduced,
                       spec.coarse.nlist, spec.code.subspaces,
                       spec.code.centroids)


def _ivfpq_scan(state, qr, n_cand, p):
    ix = state.index.payload
    if p.scan_cap > 0:
        d2, ids = ivfpq_compact_scan(ix.centroids, ix.lists, ix.codes_cell,
                                     ix.bias_cell, ix.lut_w, ix.cbnorm,
                                     ix.codebooks, qr,
                                     n_cand, p.nprobe, p.scan_cap,
                                     backend=p.backend, interpret=p.interpret,
                                     lut_dtype=p.lut_dtype)
        return jnp.sqrt(jnp.maximum(d2, 0.0)), ids
    return ivfpq_scan(ix, qr, n_cand, p.nprobe,
                      backend=p.backend, interpret=p.interpret,
                      lut_dtype=p.lut_dtype)


def _ivfpq_local_scan(sstate, qr, n_cand, p, axis, slack, live=None):
    ix = sstate.index.payload
    return ivfpq_local_scan(ix.centroids, ix.lists, ix.codes_cell,
                            ix.bias_cell, ix.lut_w, ix.cbnorm, ix.codebooks,
                            qr, n_cand, p.nprobe, axis, backend=p.backend,
                            interpret=p.interpret, lut_dtype=p.lut_dtype,
                            live=live)


def _ivfpq_stream_scan(store, frozen, qr, n_cand, live, p):
    return ivfpq_adc_scan(frozen.centroids, store.lists, store.codes_cell,
                          store.bias_cell, frozen.lut_w, frozen.cbnorm,
                          frozen.codebooks, qr,
                          n_cand, p.nprobe, p.backend, p.interpret,
                          p.lut_dtype, live=live)


def _ivfpq_shard_payload(state, shards):
    ix = state.index.payload
    return ShardedIVFPQ(
        centroids=ix.centroids, lists=_pad_dim0(ix.lists, shards, fill=-1),
        codes_cell=_pad_dim0(ix.codes_cell, shards),
        bias_cell=_pad_dim0(ix.bias_cell, shards),
        lut_w=ix.lut_w, cbnorm=ix.cbnorm, codebooks=ix.codebooks)


def _ivfpq_payload_specs(payload, axis):
    return ShardedIVFPQ(centroids=P(), lists=P(axis), codes_cell=P(axis),
                        bias_cell=P(axis), lut_w=P(), cbnorm=P(),
                        codebooks=P())


def _ivfpq_store_parts(state, n_cap, cell_slack):
    ix = state.index.payload
    parts = {
        "codes": _pad_rows(ix.codes, n_cap),          # stored width (uint8)
        "bias": _pad_rows(ix.bias, n_cap),
        "lists": _pad_cells(ix.lists, cell_slack, fill=-1),
        "codes_cell": _pad_cells(ix.codes_cell, cell_slack),
        "bias_cell": _pad_cells(ix.bias_cell, cell_slack),
    }
    return parts, IVFPQQuant(centroids=ix.centroids, codebooks=ix.codebooks,
                             lut_w=ix.lut_w, cbnorm=ix.cbnorm)


def _ivfpq_encode_delta(frozen, rows):
    return _ivfpq_encode(frozen.centroids, frozen.codebooks, rows)


def _ivfpq_rebuild(frozen, reduced, shards):
    from .ivf import posting_lists
    assign, codes, bias = _ivfpq_encode(frozen.centroids, frozen.codebooks,
                                        reduced)
    lists = posting_lists(assign, frozen.centroids.shape[0], shards)
    lid = jnp.maximum(lists, 0)
    code_dt = jnp.uint8 if frozen.codebooks.shape[1] <= 256 else jnp.int32
    recon = frozen.centroids[assign] + _pq_decode(frozen.codebooks, codes)
    rerr = jnp.sqrt(jnp.sum((reduced - recon) ** 2, axis=1))
    return IVFPQIndex(
        centroids=frozen.centroids, lists=lists,
        codebooks=frozen.codebooks, codes=codes.astype(code_dt), bias=bias,
        rerr=rerr.astype(jnp.float32),
        codes_cell=codes[lid].astype(code_dt),
        bias_cell=jnp.where(lists >= 0, bias[lid], 0.0).astype(jnp.float32),
        lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)


def _ivfpq_stream_base_payload(store, frozen, corpus_owned):
    # rerr stays zero here: the re-rank pre-filter never engages on
    # streaming engines (the scan must stay zero-recompile under churn),
    # and a zero bound only ever *keeps* candidates — never unsafe
    return IVFPQIndex(
        centroids=frozen.centroids, lists=_own(store.lists),
        codebooks=frozen.codebooks, codes=_own(store.codes),
        bias=_own(store.bias), rerr=jnp.zeros_like(store.bias),
        codes_cell=_own(store.codes_cell),
        bias_cell=_own(store.bias_cell),
        lut_w=frozen.lut_w, cbnorm=frozen.cbnorm)


register_index(IndexOps(
    kind="ivfpq", lossy=True,
    build=_ivfpq_build, scan=_ivfpq_scan, local_scan=_ivfpq_local_scan,
    stream_scan=_ivfpq_stream_scan, shard_payload=_ivfpq_shard_payload,
    payload_specs=_ivfpq_payload_specs, store_parts=_ivfpq_store_parts,
    encode_delta=_ivfpq_encode_delta, rebuild=_ivfpq_rebuild,
    stream_base_payload=_ivfpq_stream_base_payload,
    payload_skeleton=lambda leaf: IVFPQIndex(
        centroids=leaf, lists=leaf, codebooks=leaf, codes=leaf, bias=leaf,
        rerr=leaf, codes_cell=leaf, bias_cell=leaf, lut_w=leaf, cbnorm=leaf),
    quant_skeleton=lambda leaf: IVFPQQuant(
        centroids=leaf, codebooks=leaf, lut_w=leaf, cbnorm=leaf),
    drift_stats=_ivfpq_drift_stats))


# derived from the registry: one register_index() call covers every scan /
# shard / stream / persistence dispatch site. (Exposing a new kind through
# the ServeConfig/spec-string front end additionally needs a stage mapping
# in repro.search.spec — the grammar can only express these stage
# combinations — but engines over a registered kind serve through
# search_fn/EngineState directly.)
INDEX_KINDS = tuple(_REGISTRY)
