"""Request-level tracing: per-query timing, deep per-stage attribution,
slow-query capture, and online recall estimation.

The fused serving path is ONE XLA program per (kind, knobs, bucket), so a
host-side timer around dispatch + block can only see end-to-end latency.
This module layers three opt-in instruments on top of that single number:

- **Latency histograms** (``TraceConfig(histograms=True)``): every search
  records its blocked end-to-end wall time into a fixed-boundary
  log-spaced histogram (``LatencyHistogram``); ``engine.metrics()`` then
  derives p50/p95/p99 under ``latency.search.*`` and the Prometheus
  endpoint renders a real ``histogram`` series. Measurably cheap — the
  ≤3% overhead is gated in ``benchmarks/check_regression.py``.
- **Sampled deep trace** (``deep_trace_every=N``): 1-in-N queries re-run
  through a *staged* pipeline — project / probe / scan / re-rank as
  separate jitted programs with a ``block_until_ready`` barrier between
  stages — for exact, non-overlapping per-stage attribution that sums to
  the staged run's own end-to-end time by construction. The stage
  programs are module-level jits (jax's global cache), so sampling never
  touches the engine's compile-count pins.
- **Slow-query log** (``slow_query_ms=T``): a ring buffer of the worst
  offenders — spec, batch shape, bucket, knob fan-out, stage timings
  when a deep trace rode the same query.
- **Shadow recall** (``recall_every=N``): 1-in-N queries are re-answered
  exactly (brute force against the live store — tombstone-aware on
  streaming engines) and the observed recall@k feeds a
  ``recall.estimate_at_k`` EMA gauge plus, when a maintenance policy is
  configured, ``MaintenancePolicy.observe_recall`` — the live signal the
  drift policy and the future spec auto-tuner act on.

Everything funnels through one ``Tracer`` attached by
``engine.tracing(...)``; with every feature off ``Tracer.active`` is
False and the serve path skips even the timestamp (the ≤1% gate).
Chrome-trace/Perfetto JSON export (``trace_dir=``) covers host-side
spans; for device-side TPU profiles use the ``jax_profile`` context
manager (``jax.profiler`` trace, viewable in Perfetto/TensorBoard).
"""
from __future__ import annotations

import bisect
import contextlib
import dataclasses
import functools
import json
import os
import threading
import time
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .ivf import probe_cells
from .ivfpq import ivfpq_scan_given_probe
from .knn import knn_search, recall_at_k
from .metrics import HistogramSnapshot, LatencyMetrics, RecallMetrics
from .registry import ScanParams, get_ops

__all__ = ["TraceConfig", "Tracer", "LatencyHistogram", "deep_trace",
           "jax_profile"]


# Log-spaced upper bounds in milliseconds: 0.05ms .. ~105s doubling, the
# range a single fused search on anything from CPU-interpret to TPU can
# land in. Fixed boundaries keep recording O(log n_buckets) (a bisect)
# and make snapshots mergeable across engines.
_BOUNDS_MS = tuple(0.05 * 2.0 ** i for i in range(22))


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs for one ``Tracer``. Everything defaults off except the
    histograms — ``SearchEngine.tracing()`` with no arguments gives the
    cheap always-on production posture (end-to-end histograms only)."""
    histograms: bool = True          # e2e latency histogram accumulation
    trace_dir: Optional[str] = None  # Chrome-trace JSON export directory
    slow_query_ms: Optional[float] = None   # ring-buffer capture threshold
    slow_query_capacity: int = 64
    deep_trace_every: int = 0        # 1-in-N staged re-runs (0 = off)
    recall_every: int = 0            # 1-in-N shadow-exact checks (0 = off)
    recall_alpha: float = 0.1        # EMA coefficient for the recall gauge
    max_events: int = 16384          # Chrome-trace event ring capacity

    def __post_init__(self):
        if self.deep_trace_every < 0 or self.recall_every < 0:
            raise ValueError("deep_trace_every/recall_every must be >= 0")
        if not 0.0 < self.recall_alpha <= 1.0:
            raise ValueError("recall_alpha must be in (0, 1]")
        if self.slow_query_ms is not None and self.slow_query_ms < 0:
            raise ValueError("slow_query_ms must be >= 0")


class LatencyHistogram:
    """Fixed-boundary log-spaced latency accumulator (milliseconds).

    ``record`` is a bisect + two adds — cheap enough for the per-search
    hot path; ``snapshot`` freezes to the stdlib-only
    ``metrics.HistogramSnapshot`` (bounds, per-bucket counts with a
    trailing overflow bucket, sum, count) that the metrics layer derives
    percentiles from and renders as a Prometheus histogram."""

    __slots__ = ("counts", "sum_ms", "count")

    def __init__(self):
        self.counts = [0] * (len(_BOUNDS_MS) + 1)
        self.sum_ms = 0.0
        self.count = 0

    def record(self, ms: float):
        self.counts[bisect.bisect_left(_BOUNDS_MS, ms)] += 1
        self.sum_ms += ms
        self.count += 1

    def snapshot(self) -> HistogramSnapshot:
        return HistogramSnapshot(bounds_ms=_BOUNDS_MS,
                                 counts=tuple(self.counts),
                                 sum_ms=self.sum_ms, count=self.count)


# --- staged pipeline (deep trace) --------------------------------------------
# Module-level jitted stages: jax's global jit cache keys them by (shapes,
# statics, treedef), so repeated deep traces reuse compilations and the
# engine-owned program caches (compile_count — pinned by tests) never see
# them. Each stage is blocked before the next starts, so the measured
# intervals are non-overlapping and sum to the staged run's e2e.

@jax.jit
def _project_stage(proj, queries):
    from .reducers import reduce_vectors
    queries = jnp.asarray(queries, jnp.float32)
    return reduce_vectors(proj, queries)


_probe_stage = jax.jit(probe_cells, static_argnames=("nprobe", "min_cand"))

_ivfpq_scan_stage = jax.jit(
    ivfpq_scan_given_probe,
    static_argnames=("n_cand", "backend", "interpret", "lut_dtype"))


@functools.partial(jax.jit, static_argnames=("n_cand", "p"))
def _scan_stage(state, qr, n_cand, p):
    ops = get_ops(state.index.kind)
    return ops.scan(state, qr, n_cand, p)


@functools.partial(jax.jit, static_argnames=("k",))
def _rerank_stage(queries, corpus, cand, k):
    from .serve import exact_rerank
    return exact_rerank(queries, corpus, cand, k)


def _block(x):
    return jax.block_until_ready(x)


def deep_trace(engine, queries, k: int, kw: Mapping) -> Optional[dict]:
    """Run one batch through the staged pipeline, timing each stage.

    ``queries`` is the engine's already-padded bucket batch and ``kw`` the
    normalized knob dict ``SearchEngine.search`` dispatched with, so the
    decomposition describes the same shapes the fused program ran (minus
    fusion, which is the point: the fused program is one opaque XLA
    computation). ivfpq decomposes as project/probe/scan/rerank (the scan
    given the probe is ``ivfpq_scan_given_probe`` — identical math to the
    fused path); other kinds as project/scan/rerank. Only read-only
    unsharded engines qualify (``engine.state``); returns None otherwise.

    Returns ``{"stages": [(name, ms), ...], "e2e_ms": float}`` where the
    stage list is ordered, non-overlapping, and sums to ``e2e_ms`` up to
    inter-stage host dispatch (the acceptance bound: within 10%).
    """
    state = engine.state
    if (state is None or engine.store is not None
            or engine.sharded_state is not None):
        return None
    ops = get_ops(state.index.kind)
    approximate = state.proj is not None or ops.lossy
    n_cand = kw["rerank"] if approximate else k
    statics = (kw["nprobe"], kw["backend"], kw["interpret"],
               kw["lut_dtype"], n_cand, k)

    def _run():
        stages = []
        t0 = time.perf_counter()
        qr = _block(_project_stage(state.proj, queries))
        t1 = time.perf_counter()
        stages.append(("project", (t1 - t0) * 1e3))
        if state.index.kind == "ivfpq":
            ix = state.index.payload
            probe, cand0, cd2p = _block(_probe_stage(
                ix.centroids, ix.lists, qr,
                nprobe=kw["nprobe"], min_cand=n_cand))
            t2 = time.perf_counter()
            stages.append(("probe", (t2 - t1) * 1e3))
            _, cand = _block(_ivfpq_scan_stage(
                probe, cand0, cd2p, ix.codes_cell, ix.bias_cell,
                ix.lut_w, ix.cbnorm, ix.codebooks, qr, n_cand=n_cand,
                backend=kw["backend"], interpret=kw["interpret"],
                lut_dtype=kw["lut_dtype"]))
            t3 = time.perf_counter()
            stages.append(("scan", (t3 - t2) * 1e3))
        else:
            p = ScanParams(nprobe=kw["nprobe"], backend=kw["backend"],
                           interpret=kw["interpret"],
                           lut_dtype=kw["lut_dtype"])
            _, cand = _block(_scan_stage(state, qr, n_cand=n_cand, p=p))
            t3 = time.perf_counter()
            stages.append(("scan", (t3 - t1) * 1e3))
        _block(_rerank_stage(queries, state.corpus, cand, k=k))
        t4 = time.perf_counter()
        stages.append(("rerank", (t4 - t3) * 1e3))
        return {"stages": stages, "e2e_ms": (t4 - t0) * 1e3}

    warm_key = (queries.shape, state.index.kind) + statics
    warmed = getattr(engine, "_deep_warm", None)
    if warmed is None:
        warmed = engine._deep_warm = set()
    if warm_key not in warmed:      # compile pass: never time a compile
        _run()
        warmed.add(warm_key)
    return _run()


# --- shadow-exact recall -----------------------------------------------------

def shadow_recall(engine, queries, nq: int, k: int, ids) -> Optional[tuple]:
    """Brute-force the same batch against the live store and score the
    served ids: returns (recall@k', k') or None when the engine has no
    dense store to check against (donated buffers). Streaming engines are
    checked tombstone-aware via ``_gather_live`` (base survivors + live
    delta rows, mapped to external ids); read-only engines against
    ``state.corpus`` (row index == external id). k' = min(k, live rows).
    """
    queries = queries[:nq]
    if engine.store is not None:
        vecs, ext = engine._gather_live()
        if len(ext) == 0:
            return None
        kk = min(k, len(ext))
        _, idx = knn_search(queries, jnp.asarray(vecs, jnp.float32), kk)
        truth = jnp.asarray(np.asarray(ext, np.int32))[idx]
    elif engine.state is not None:
        corpus = engine.state.corpus
        kk = min(k, corpus.shape[0])
        _, truth = knn_search(queries, corpus, kk)
    else:
        return None
    return float(recall_at_k(ids[:nq, :kk], truth)), kk


# --- the tracer --------------------------------------------------------------

class Tracer:
    """Per-engine trace state: histograms, slow-query ring, Chrome-trace
    events, recall EMA. Attached by ``SearchEngine.tracing()``; the serve
    path calls ``on_search`` after blocking the result. Thread-safe
    against concurrent ``MetricsServer`` scrapes (one lock around all
    mutation and snapshotting)."""

    def __init__(self, config: TraceConfig = TraceConfig()):
        self.config = config
        self._lock = threading.Lock()
        self._e2e = LatencyHistogram()
        self._stages: dict = {}          # stage name -> LatencyHistogram
        self._slow: list = []            # ring buffer of slow-query dicts
        self._events: list = []          # Chrome-trace events (capped)
        self._origin = time.perf_counter()
        self.queries = 0                 # search calls seen
        self.slow_queries = 0            # total over-threshold (>= ring)
        self.deep_traces = 0
        self.recall_ema: Optional[float] = None
        self.recall_last: Optional[float] = None
        self.recall_k: Optional[int] = None
        self.recall_samples = 0

    @property
    def active(self) -> bool:
        c = self.config
        return bool(c.histograms or c.trace_dir is not None
                    or c.slow_query_ms is not None
                    or c.deep_trace_every or c.recall_every)

    # -- recording ----------------------------------------------------------

    def on_search(self, engine, queries, nq: int, k: int, kw: Mapping,
                  t0: float, d, ids):
        """Finish one traced search: block, time, and run whichever
        instruments sampled this call. ``queries`` is the padded bucket
        batch; ``t0`` the host timestamp the engine took before dispatch;
        ``d``/``ids`` the (lazy) full-bucket result."""
        c = self.config
        _block((d, ids))
        t1 = time.perf_counter()
        e2e_ms = (t1 - t0) * 1e3
        with self._lock:
            n = self.queries
            self.queries += 1
        trace = (c.deep_trace_every
                 and n % c.deep_trace_every == 0) or None
        if trace:
            trace = deep_trace(engine, queries, k, kw)
        shadow = None
        if c.recall_every and n % c.recall_every == 0:
            shadow = shadow_recall(engine, queries, nq, k, ids)
        self._commit(engine, nq, k, kw, t0, e2e_ms, trace, shadow)

    def _commit(self, engine, nq, k, kw, t0, e2e_ms, trace, shadow):
        c = self.config
        with self._lock:
            if c.histograms:
                self._e2e.record(e2e_ms)
                if trace:
                    for name, ms in trace["stages"]:
                        h = self._stages.get(name)
                        if h is None:
                            h = self._stages[name] = LatencyHistogram()
                        h.record(ms)
            if trace:
                self.deep_traces += 1
            if shadow is not None:
                r, kk = shadow
                a = c.recall_alpha
                self.recall_ema = (r if self.recall_ema is None
                                   else a * r + (1.0 - a) * self.recall_ema)
                self.recall_last, self.recall_k = r, kk
                self.recall_samples += 1
            slow = (c.slow_query_ms is not None
                    and e2e_ms >= c.slow_query_ms)
            if slow:
                self.slow_queries += 1
                entry = {"e2e_ms": e2e_ms, "batch": nq,
                         "bucket": engine.last_bucket, "k": k,
                         "spec": self._spec(engine),
                         "nprobe": kw.get("nprobe"),
                         "rerank": kw.get("rerank"),
                         "lut_dtype": kw.get("lut_dtype"),
                         "scan_cap": kw.get("scan_cap"),
                         "prefilter": kw.get("prefilter"),
                         "seq": self.queries - 1}
                if trace:
                    entry["stages"] = {s: ms for s, ms in trace["stages"]}
                self._slow.append(entry)
                if len(self._slow) > c.slow_query_capacity:
                    del self._slow[0]
            if c.trace_dir is not None and len(self._events) < c.max_events:
                ts_us = (t0 - self._origin) * 1e6
                self._events.append({
                    "name": "search", "ph": "X", "ts": ts_us,
                    "dur": e2e_ms * 1e3, "pid": os.getpid(), "tid": 1,
                    "args": {"batch": nq, "k": k,
                             "nprobe": kw.get("nprobe"),
                             "spec": self._spec(engine)}})
                if trace:
                    cursor = ts_us
                    for name, ms in trace["stages"]:
                        self._events.append({
                            "name": f"deep.{name}", "ph": "X",
                            "ts": cursor, "dur": ms * 1e3,
                            "pid": os.getpid(), "tid": 2, "args": {}})
                        cursor += ms * 1e3
        if shadow is not None and engine._policy is not None:
            engine._policy.observe_recall(*shadow)

    @staticmethod
    def _spec(engine) -> str:
        from .spec import format_spec
        return format_spec(engine.spec)

    # -- export -------------------------------------------------------------

    def metrics_sections(self):
        """(LatencyMetrics, RecallMetrics) for ``collect_metrics`` — the
        ``latency.*`` / ``recall.*`` dotted sections."""
        with self._lock:
            latency = LatencyMetrics(
                search=self._e2e.snapshot(),
                stages={s: h.snapshot()
                        for s, h in sorted(self._stages.items())},
                queries=self.queries,
                slow_queries=self.slow_queries,
                slow_query_ms=self.config.slow_query_ms,
                deep_traces=self.deep_traces)
            recall = RecallMetrics(
                estimate_at_k=self.recall_ema, k=self.recall_k,
                samples=self.recall_samples, last=self.recall_last)
        return latency, recall

    def slow_query_log(self) -> list:
        """The current ring-buffer contents, oldest first (copies)."""
        with self._lock:
            return [dict(e) for e in self._slow]

    def flush(self, path: Optional[str] = None) -> Optional[str]:
        """Write the buffered events as Chrome-trace JSON (open in
        ``chrome://tracing`` or Perfetto). Default path is
        ``<trace_dir>/qpad_trace_<pid>.json``; returns the path, or None
        when event capture is off. The buffer is drained."""
        with self._lock:
            if path is None:
                if self.config.trace_dir is None:
                    return None
                os.makedirs(self.config.trace_dir, exist_ok=True)
                path = os.path.join(self.config.trace_dir,
                                    f"qpad_trace_{os.getpid()}.json")
            events, self._events = self._events, []
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


@contextlib.contextmanager
def jax_profile(logdir: str):
    """Device-side profile of the enclosed block via ``jax.profiler``
    (TensorBoard/Perfetto format — the TPU-grade complement to the
    host-side Chrome trace; on TPU this captures real per-kernel device
    timelines where host timers only see dispatch+block)."""
    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
