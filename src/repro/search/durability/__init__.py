"""Durability subsystem: write-ahead log, crash recovery, maintenance
policy.

Three pieces wire through the engine lifecycle
(``repro.search.serve.SearchEngine``):

* ``wal`` — the CRC-framed, fsync-configurable, segment-rotated record
  log every store mutation appends to *before* it runs
  (``engine.durable(dir)`` opens it; ``engine.save`` marks + truncates).
* ``recovery`` — ``load_engine`` replays the log tail on top of the
  newest durable snapshot through the engine's own write programs:
  recovered == never-crashed, record for record.
* ``policy`` — ``MaintenancePolicy`` watches tombstone density, delta
  fill, capacity headroom, and PQ encode-error drift, and decides
  between compact / vacuum / grow / quantizer rebuild; decisions are
  WAL records too, so recovery replays maintenance deterministically.
* ``replication`` — WAL shipping: a primary's log segments move through
  a ``WalSource`` transport; a follower seeded from any snapshot calls
  ``catch_up`` repeatedly to tail them (divergence — a seq gap or
  mid-stream CRC failure — raises ``DivergenceError``: re-seed).
"""
from .policy import Decision, MaintenancePolicy, PolicyConfig
from .recovery import ReplayStats, replay, replay_records
from .replication import (CatchUpStats, DivergenceError, LocalDirSource,
                          ReplicationError, WalSource, catch_up,
                          seed_follower)
from .wal import (DurabilityConfig, Wal, WalError, decode_delete,
                  decode_policy, decode_upsert, encode_delete, encode_policy,
                  encode_upsert, iter_frames, iter_records, wal_tail_seq)

__all__ = [
    "DurabilityConfig", "Wal", "WalError",
    "iter_frames", "iter_records", "wal_tail_seq",
    "encode_upsert", "decode_upsert", "encode_delete", "decode_delete",
    "encode_policy", "decode_policy",
    "PolicyConfig", "MaintenancePolicy", "Decision",
    "ReplayStats", "replay", "replay_records",
    "ReplicationError", "DivergenceError", "WalSource", "LocalDirSource",
    "CatchUpStats", "catch_up", "seed_follower",
]
