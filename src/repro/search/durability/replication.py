"""WAL shipping & follower catch-up: read replicas without rebuilds.

The replication contract extends the single-node durability contract
(``wal.py``): the primary's WAL is a deterministic replay script, so a
follower that (a) restores *any* snapshot of the primary and (b) applies
every shipped record past that snapshot's ``wal_seq`` through the same
``replay_records`` machinery is record-for-record identical to the
primary — including across compaction / vacuum / rebuild barriers,
which the follower re-folds from the logged RT_COMPACT / RT_POLICY
records with its own (deterministic, seeded) write programs. Folded
arrays are never copied over the wire; only cheap log records move.

Transports: a source is anything with the three-method ``WalSource``
shape — list segments, fetch one segment's bytes, report the tail seq.
``LocalDirSource`` (shared filesystem / rsync'd directory) is the
bundled implementation; a network transport implements the same
interface.

Divergence: ``catch_up`` demands strict seq contiguity from the shipped
stream. A gap (the primary truncated history past the follower's
position) or a CRC failure mid-stream (damaged shipment) raises
``DivergenceError`` — the follower cannot rejoin by tailing and must be
re-seeded from a fresh snapshot (``engine.save(dir, incremental=True)``
is the cheap re-seed artifact). A torn tail on the *last* shipped
segment is not divergence: it is the primary's in-flight append, and the
next ``catch_up`` picks it up.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Iterator, List, Protocol, Tuple

from .recovery import ReplayStats, replay_records
from .wal import WalError, _list_segments, _segment_first_seq, iter_frames

__all__ = ["ReplicationError", "DivergenceError", "WalSource",
           "LocalDirSource", "CatchUpStats", "catch_up", "seed_follower"]


class ReplicationError(RuntimeError):
    """Replication misuse or transport failure (not history damage)."""


class DivergenceError(ReplicationError):
    """The follower's position and the source's history no longer form
    one line: a seq gap (history truncated past the follower) or a CRC
    failure mid-stream. Tailing cannot recover this — re-seed the
    follower from a fresh primary snapshot."""


class WalSource(Protocol):
    """What a WAL-shipping transport must provide. ``LocalDirSource``
    reads a directory; a network transport implements the same calls."""

    def segments(self) -> List[Tuple[int, str]]:
        """Sorted (first_seq, name) of the available segments."""
        ...

    def fetch(self, name: str) -> bytes:
        """One segment's bytes, verbatim."""
        ...

    def tail_seq(self) -> int:
        """Seq of the source's last intact record (-1 = empty)."""
        ...


class LocalDirSource:
    """``WalSource`` over a local/shared filesystem directory — the
    primary's live ``<durable_dir>/wal`` or any rsync'd copy of it.
    Accepts either the WAL directory itself or the durable directory
    containing a ``wal/`` subdirectory."""

    def __init__(self, directory: str):
        wal_sub = os.path.join(directory, "wal")
        self.directory = wal_sub if os.path.isdir(wal_sub) else directory

    def segments(self) -> List[Tuple[int, str]]:
        return [(first, os.path.basename(path))
                for first, path in _list_segments(self.directory)]

    def fetch(self, name: str) -> bytes:
        if _segment_first_seq(name) is None:
            raise ReplicationError(f"not a WAL segment name: {name!r}")
        with open(os.path.join(self.directory, name), "rb") as f:
            return f.read()

    def tail_seq(self) -> int:
        last = -1
        for seq, _, _ in _iter_source_records(self, after=-1):
            last = seq
        return last


def _iter_source_records(source: WalSource, after: int
                         ) -> Iterator[Tuple[int, int, bytes]]:
    """Yield (seq, rtype, payload) with ``seq > after`` from a source's
    shipped segments — the transport-side mirror of ``iter_records``.
    Stops cleanly at a torn tail on the last segment; mid-stream damage
    raises ``WalError`` (wrapped into ``DivergenceError`` by
    ``catch_up``)."""
    segs = source.segments()
    for i, (first, name) in enumerate(segs):
        nxt = segs[i + 1][0] if i + 1 < len(segs) else None
        if nxt is not None and nxt - 1 <= after:
            continue                       # fully behind the follower
        data = source.fetch(name)
        for seq, rtype, payload, _ in iter_frames(
                data, is_last=(i == len(segs) - 1), name=name):
            if seq > after:
                yield seq, rtype, payload


@dataclasses.dataclass
class CatchUpStats:
    """What one ``catch_up`` pass shipped and applied."""
    records: int = 0
    upserts: int = 0
    deletes: int = 0
    compactions: int = 0
    policies: int = 0
    rows: int = 0
    applied_seq: int = -1            # follower position after the pass
    source_tail_seq: int = -1        # primary position when we looked
    lag_seq: int = 0                 # source_tail - applied (0 = caught up)


def _contiguous(records, start_after: int, available_floor):
    """Pass records through while enforcing seq == prev + 1; a gap means
    the source truncated history past the follower's position."""
    expected = start_after + 1
    for seq, rtype, payload in records:
        if seq != expected:
            raise DivergenceError(
                f"seq gap in shipped WAL: follower is at seq "
                f"{expected - 1} but the next available record is seq "
                f"{seq} (source history starts at segment seq "
                f"{available_floor}). The primary truncated past this "
                "follower; re-seed it from a fresh primary snapshot "
                "(engine.save(dir) or save(dir, incremental=True)) and "
                "catch_up again.")
        yield seq, rtype, payload
        expected = seq + 1


def catch_up(engine, source: WalSource, after_seq: int = None
             ) -> CatchUpStats:
    """Tail the primary's shipped WAL into a follower engine.

    ``engine`` is a streaming ``SearchEngine`` seeded from any primary
    snapshot (``seed_follower`` / ``load_engine(..., role="follower")``);
    ``source`` is the transport over the primary's log. Applies every
    record past ``after_seq`` (default: the follower's tracked
    ``applied_seq``) through the engine's own write programs, then
    advances the follower's position. Incremental and repeatable — call
    it on a schedule; a pass that finds nothing new is a cheap no-op.

    Raises ``DivergenceError`` on a seq gap or mid-stream CRC failure
    (re-seed the follower), ``ReplicationError`` on misuse (the engine
    owns a WAL, i.e. it is a primary — a node cannot be both).
    """
    if engine.store is None:
        raise ReplicationError(
            "catch_up needs a streaming engine (the follower applies "
            "shipped records through StreamStore write programs); build "
            "it from a streaming snapshot of the primary")
    if engine._wal is not None:
        raise ReplicationError(
            "this engine owns a local WAL (it is a primary); a node "
            "cannot both accept local writes and tail another primary. "
            "Seed a follower with load_engine(snapshot_dir, "
            "role='follower') instead.")
    engine._role = "follower"
    after = engine._applied_seq if after_seq is None else after_seq
    segs = source.segments()
    available_floor = segs[0][0] if segs else 0
    stats = ReplayStats()
    try:
        replay_records(
            engine,
            _contiguous(_iter_source_records(source, after), after,
                        available_floor),
            stats)
        if stats.records:
            engine._applied_seq = stats.last_seq
        tail = source.tail_seq()     # may scan damage replay skipped over
    except WalError as e:
        raise DivergenceError(
            f"CRC failure in shipped WAL mid-stream ({e}); the shipment "
            "is damaged or the histories diverged. Re-seed the follower "
            "from a fresh primary snapshot and catch_up again.") from e
    if tail < engine._applied_seq:
        raise DivergenceError(
            f"follower is at seq {engine._applied_seq} but the source's "
            f"tail is seq {tail} — the source lost or rewound history "
            "(not the same primary, or its directory was reset). "
            "Re-seed the follower from a fresh primary snapshot.")
    engine._repl_catch_ups += 1
    engine._repl_records += stats.records
    engine._repl_source_tail = tail
    # wall-clock stamps behind replication.lag_seconds /
    # .catch_up_age_seconds: every pass refreshes the staleness gauge,
    # and a pass that drains the source pins the "fully caught up" time
    engine._repl_last_catch_up_ts = time.time()
    if tail - engine._applied_seq <= 0:
        engine._repl_caught_up_ts = engine._repl_last_catch_up_ts
    return CatchUpStats(
        records=stats.records, upserts=stats.upserts, deletes=stats.deletes,
        compactions=stats.compactions, policies=stats.policies,
        rows=stats.rows, applied_seq=engine._applied_seq,
        source_tail_seq=tail, lag_seq=max(0, tail - engine._applied_seq))


def seed_follower(snapshot_dir: str, **runtime_overrides):
    """Build a follower from a primary snapshot directory: restores the
    arrays and the snapshot's WAL position, opens NO local WAL and
    replays NO local log (``catch_up`` ships the tail from the primary
    instead). Works off full and incremental (chained) snapshots alike.
    """
    from ..snapshot import load_engine          # lazy: avoid import cycle
    return load_engine(snapshot_dir, role="follower", **runtime_overrides)
