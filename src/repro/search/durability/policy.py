"""Maintenance policy: when to compact, vacuum, grow, or retrain.

A streaming engine degrades along three axes the write path itself never
fixes:

* **tombstone density** — deletes/overwrites mask base rows out of the
  scan but never reclaim them; the masked scan pays for dead rows
  forever. ``decide_delete`` routes dense-enough bitmaps into a
  **vacuum** (fold + rewrite the base over the survivors, frozen
  quantizers — no retraining).
* **capacity pressure** — compaction appends into pre-allocated slack;
  when the headroom left is less than a delta's worth, the next fold
  will overflow and pay the reactive grow+recompile mid-write.
  ``decide_post_compact`` can grow proactively instead (off by default:
  ``grow_headroom=0``).
* **quantizer drift** — the PQ codebooks are frozen at build time; as
  the live distribution drifts, the squared reconstruction error of
  newly folded rows rises above the build-time baseline and coded-scan
  ranking quality decays silently. ``MaintenancePolicy`` tracks both
  errors (per-kind ``IndexOps.drift_stats``), compares their ratio
  against ``drift_ratio``, and — only when the drift also clears the
  LUT quantization noise floor (``repro.kernels.pq_adc.lut
  .lut_error_bound``; drift below what the int8/bf16 LUT grid can even
  express is not actionable) — advises or (``auto_rebuild=True``)
  triggers a full quantizer rebuild through the ordinary build path.

Decisions are *data*, not actions: the engine executes them and logs
them to the WAL (``RT_POLICY``), so crash recovery replays maintenance
deterministically instead of re-deriving it from drifted statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["PolicyConfig", "MaintenancePolicy", "Decision"]


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Maintenance thresholds (``StreamConfig.policy``)."""
    tombstone_density: float = 0.25  # vacuum when dead/allocated exceeds
    tombstone_min_dead: int = 64     # ... and at least this many are dead
    delta_fill: Optional[float] = None   # auto-compact fill fraction;
    #                                      None = StreamConfig
    #                                      .compact_threshold
    grow_headroom: float = 0.0       # grow the base after compaction when
    #                                  free rows < headroom * delta
    #                                  capacity (0 disables)
    drift_ratio: float = 4.0         # rebuild when recent encode error
    #                                  exceeds this multiple of the
    #                                  build-time baseline
    drift_min_rows: int = 256        # ... measured over at least this
    #                                  many folded rows
    auto_rebuild: bool = False       # False: surface "advise_rebuild" in
    #                                  metrics; True: rebuild through
    #                                  build_engine automatically
    recall_floor: Optional[float] = None  # advise/trigger a rebuild when
    #                                  the online recall estimate
    #                                  (Tracer shadow-exact EMA, fed via
    #                                  observe_recall) drops below this
    #                                  (None disables; needs
    #                                  engine.tracing(recall_every=N))
    recall_min_samples: int = 8      # ... after at least this many
    #                                  shadow-exact samples (one noisy
    #                                  sample must not trigger retrains)

    def __post_init__(self):
        if not (0.0 < self.tombstone_density <= 1.0):
            raise ValueError("tombstone_density must be in (0, 1]")
        if self.tombstone_min_dead < 1:
            raise ValueError("tombstone_min_dead must be >= 1")
        if self.delta_fill is not None and not (0.0 < self.delta_fill <= 1.0):
            raise ValueError("delta_fill must be in (0, 1]")
        if self.grow_headroom < 0:
            raise ValueError("grow_headroom must be >= 0")
        if self.drift_ratio <= 1.0:
            raise ValueError("drift_ratio must be > 1")
        if self.drift_min_rows < 1:
            raise ValueError("drift_min_rows must be >= 1")
        if (self.recall_floor is not None
                and not 0.0 < self.recall_floor <= 1.0):
            raise ValueError("recall_floor must be in (0, 1]")
        if self.recall_min_samples < 1:
            raise ValueError("recall_min_samples must be >= 1")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One maintenance verdict: what to do, why, and with what params."""
    kind: str                        # "none" | "vacuum" | "grow" |
    #                                  "rebuild" | "advise_rebuild"
    reason: str = ""
    params: dict = dataclasses.field(default_factory=dict)


_NONE = Decision("none")


class MaintenancePolicy:
    """Stateful tracker + decider over one streaming engine's lifetime.

    The engine feeds it observations (build-time baseline encode error,
    per-compaction encode error of the folded delta rows, tombstone and
    capacity counts at decision points, and — when a ``Tracer`` runs
    shadow-exact sampling — the online recall estimate); it returns
    ``Decision``s and keeps per-kind counters for
    ``SearchEngine.metrics()``.
    """

    def __init__(self, config: Optional[PolicyConfig] = None):
        self.config = config or PolicyConfig()
        self.base_error: Optional[float] = None
        self.recent_error: Optional[float] = None
        self.recent_rows = 0
        self.recall_ema: Optional[float] = None
        self.recall_k: Optional[int] = None
        self.recall_samples = 0
        self.decisions: dict = {}

    # --- observations ----------------------------------------------------

    def observe_build_error(self, err: float):
        """(Re)base the drift reference: mean squared reconstruction
        error of the build-time rows under the (re)trained quantizers."""
        self.base_error = float(err)
        self.recent_error = None
        self.recent_rows = 0

    def observe_encode_error(self, err: float, n_rows: int):
        """Fold one compaction's mean encode error into the recent
        estimate (exponential blend so old batches age out)."""
        if n_rows <= 0:
            return
        err = float(err)
        if self.recent_error is None:
            self.recent_error = err
        else:
            self.recent_error = 0.5 * (self.recent_error + err)
        self.recent_rows += int(n_rows)

    def observe_recall(self, recall: float, k: int):
        """Fold one shadow-exact recall sample into the policy's view of
        serving quality (the ``Tracer`` calls this on every sampled
        query when a policy is configured). The EMA here intentionally
        mirrors the tracer's gauge: the policy must act on the same
        number the dashboards show."""
        a = 0.1
        recall = float(recall)
        self.recall_ema = (recall if self.recall_ema is None
                           else a * recall + (1.0 - a) * self.recall_ema)
        self.recall_k = int(k)
        self.recall_samples += 1

    def drift_ratio(self) -> Optional[float]:
        """recent/base encode-error ratio; None until both observed."""
        if (self.base_error is None or self.recent_error is None
                or self.base_error <= 0.0):
            return None
        return self.recent_error / self.base_error

    # --- decision points --------------------------------------------------

    def _emit(self, decision: Decision) -> Decision:
        if decision.kind != "none":
            self.decisions[decision.kind] = (
                self.decisions.get(decision.kind, 0) + 1)
        return decision

    def decide_delete(self, *, dead: int, allocated: int) -> Decision:
        """After a delete batch: vacuum when the tombstone bitmap is
        dense enough that the masked base scan is mostly waste."""
        c = self.config
        if (allocated > 0 and dead >= c.tombstone_min_dead
                and dead / allocated > c.tombstone_density):
            return self._emit(Decision(
                "vacuum",
                f"tombstones {dead}/{allocated} exceed density "
                f"{c.tombstone_density}"))
        return _NONE

    def decide_post_compact(self, *, free_rows: int, delta_capacity: int,
                            noise_floor: float = 0.0) -> Decision:
        """After a compaction: retrain on drift first (it re-provisions
        capacity anyway), else grow proactively if headroom ran out."""
        c = self.config
        ratio = self.drift_ratio()
        if (ratio is not None and self.recent_rows >= c.drift_min_rows
                and ratio > c.drift_ratio
                and (self.recent_error or 0.0) > float(noise_floor)):
            kind = "rebuild" if c.auto_rebuild else "advise_rebuild"
            return self._emit(Decision(
                kind, f"encode-error drift {ratio:.2f}x over "
                      f"{self.recent_rows} rows (threshold "
                      f"{c.drift_ratio}x)"))
        if (c.recall_floor is not None and self.recall_ema is not None
                and self.recall_samples >= c.recall_min_samples
                and self.recall_ema < c.recall_floor):
            kind = "rebuild" if c.auto_rebuild else "advise_rebuild"
            return self._emit(Decision(
                kind, f"online recall estimate {self.recall_ema:.3f}@"
                      f"{self.recall_k} below floor {c.recall_floor} "
                      f"({self.recall_samples} shadow samples)"))
        if c.grow_headroom > 0 and free_rows < c.grow_headroom * delta_capacity:
            return self._emit(Decision(
                "grow", f"free rows {free_rows} below headroom "
                        f"{c.grow_headroom} x {delta_capacity}",
                {"row_extra": 4 * delta_capacity,
                 "cell_extra": delta_capacity}))
        return _NONE

    def stats(self) -> dict:
        """Counters + drift/recall state for ``SearchEngine.metrics()``."""
        return {"decisions": dict(self.decisions),
                "base_error": self.base_error,
                "recent_error": self.recent_error,
                "recent_rows": self.recent_rows,
                "drift_ratio": self.drift_ratio(),
                "recall_ema": self.recall_ema,
                "recall_samples": self.recall_samples}
