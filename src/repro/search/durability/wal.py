"""Write-ahead log: CRC-framed, fsync-configurable, segment-rotated.

The durable-streaming contract (see ``repro.search.serve.SearchEngine
.durable``): every mutation of the ``StreamStore`` appends one record
here *before* it touches the store, in mutation order — so the byte
stream is a deterministic replay script. ``load_engine`` replays the
tail (records past the snapshot's ``wal_seq``) through the engine's own
write programs and arrives at a store record-for-record identical to the
one that never crashed.

Record framing (little-endian)::

    [crc32 u32][payload_len u32][seq u64][rtype u8][payload ...]

The CRC covers (payload_len, seq, rtype, payload). ``seq`` is a global
monotonically increasing record number — segment files are named
``wal-<firstseq>.log`` after the first record they hold, so truncating
history older than a durable snapshot is unlinking whole files.

Record types::

    RT_UPSERT    ids + vectors of one engine write chunk
    RT_DELETE    ids of one delete batch
    RT_COMPACT   compaction barrier (logged when compaction BEGINS;
                 replay redoes the fold, so a crash mid-compaction
                 recovers to the completed-compaction state)
    RT_SNAPSHOT  durable-snapshot mark (records at or before the seq in
                 ``engine.json`` are dead weight and get truncated)
    RT_POLICY    a MaintenancePolicy decision (JSON) — vacuum / grow /
                 rebuild are replayed deterministically from the log

Torn tails: a crash mid-append leaves a half frame (or a frame whose CRC
fails) at the end of the *last* segment — readers stop there; resuming a
writer truncates the torn bytes first. The same damage anywhere else is
real corruption and raises ``WalError``.

Fsync modes (``DurabilityConfig.fsync``): ``"always"`` fsyncs per
record (strict durability), ``"batch"`` flushes per record to the OS
and fsyncs at rotation/snapshot/close (crash-of-process safe, loses the
page cache on power loss), ``"never"`` leaves flushing to the runtime
(benchmark / bulk-load mode).

Group commit (``DurabilityConfig.group_commit_ms > 0``, requires
``fsync="always"``): appends enqueue onto a dedicated commit thread that
coalesces every record written while the previous fsync was in flight —
plus a bounded ``group_commit_ms`` gathering window — into ONE fsync.
``append`` still returns only after its covering sync (the strict
durability contract holds); concurrent writers just share the disk
flush instead of serializing one fsync per record.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import threading
import time
import zlib
from typing import Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["DurabilityConfig", "Wal", "WalError",
           "RT_UPSERT", "RT_DELETE", "RT_COMPACT", "RT_SNAPSHOT",
           "RT_POLICY",
           "encode_upsert", "decode_upsert", "encode_delete",
           "decode_delete", "encode_policy", "decode_policy",
           "iter_frames", "iter_records", "wal_tail_seq"]

RT_UPSERT = 1
RT_DELETE = 2
RT_COMPACT = 3
RT_SNAPSHOT = 4
RT_POLICY = 5

_MAGIC = b"QPADWAL1"
_HEAD = struct.Struct("<IQB")        # payload_len, seq, rtype (crc'd part)
_CRC = struct.Struct("<I")
_FRAME_MIN = _CRC.size + _HEAD.size
_UPS_HDR = struct.Struct("<II")      # batch, dim

_FSYNC_MODES = ("always", "batch", "never")
_ROLES = ("primary", "follower")


class WalError(RuntimeError):
    """Unrecoverable log damage: a bad frame *before* the tail of the
    last segment (torn tails are expected and handled; this is not)."""


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Write-ahead-log + replication-role knobs (``SearchEngine.durable``).

    ``role`` declares what this node is: a ``"primary"`` owns a local
    WAL and accepts writes; a ``"follower"`` tails a primary's shipped
    log (``repro.search.durability.replication``) and never opens a
    local WAL — ``SearchEngine.durable`` rejects the combination.
    ``group_commit_ms`` > 0 turns on group commit (see module docs);
    it bounds the extra latency one append may wait to share its fsync
    with neighbors, and only makes sense under ``fsync="always"`` —
    the other modes never fsync per record, so there is nothing to
    coalesce and the config is rejected as incoherent.
    """
    fsync: str = "batch"             # "always" | "batch" | "never"
    segment_bytes: int = 4 * 1024 * 1024   # rotate segments near this size
    role: str = "primary"            # "primary" | "follower"
    group_commit_ms: float = 0.0     # > 0: coalesce fsyncs (fsync="always")

    def __post_init__(self):
        if self.fsync not in _FSYNC_MODES:
            raise ValueError(
                f"unknown fsync mode {self.fsync!r}; expected one of "
                f"{_FSYNC_MODES}")
        if self.segment_bytes < len(_MAGIC) + _FRAME_MIN:
            raise ValueError("segment_bytes too small to hold one record")
        if self.role not in _ROLES:
            raise ValueError(
                f"unknown role {self.role!r}; expected one of {_ROLES}")
        if self.group_commit_ms < 0:
            raise ValueError("group_commit_ms must be >= 0")
        if self.group_commit_ms > 0 and self.fsync != "always":
            raise ValueError(
                f"group_commit_ms={self.group_commit_ms} is incoherent with "
                f"fsync={self.fsync!r}: group commit coalesces the per-record "
                "fsyncs of fsync='always'; the other modes never fsync per "
                "record. Use DurabilityConfig(fsync='always', "
                "group_commit_ms=...) or drop group_commit_ms.")


# --- record payload codecs ---------------------------------------------------

def encode_upsert(ids, vectors) -> bytes:
    """(B,) int32 ids + (B, D) f32 vectors -> one RT_UPSERT payload."""
    ids = np.ascontiguousarray(ids, np.int32)
    vectors = np.ascontiguousarray(vectors, np.float32)
    b, d = vectors.shape
    return (_UPS_HDR.pack(b, d) + ids.tobytes() + vectors.tobytes())


def decode_upsert(payload: bytes):
    """RT_UPSERT payload -> (ids (B,) int32, vectors (B, D) f32)."""
    b, d = _UPS_HDR.unpack_from(payload)
    off = _UPS_HDR.size
    ids = np.frombuffer(payload, np.int32, count=b, offset=off)
    vecs = np.frombuffer(payload, np.float32, count=b * d,
                         offset=off + 4 * b).reshape(b, d)
    return ids, vecs


def encode_delete(ids) -> bytes:
    """(B,) int32 ids -> one RT_DELETE payload."""
    return np.ascontiguousarray(ids, np.int32).tobytes()


def decode_delete(payload: bytes) -> np.ndarray:
    """RT_DELETE payload -> (B,) int32 ids."""
    return np.frombuffer(payload, np.int32)


def encode_policy(decision: dict) -> bytes:
    """A MaintenancePolicy decision -> one RT_POLICY payload (JSON)."""
    return json.dumps(decision, sort_keys=True).encode()


def decode_policy(payload: bytes) -> dict:
    """RT_POLICY payload -> the decision dict."""
    return json.loads(payload.decode())


# --- segment reading ---------------------------------------------------------

def _segment_first_seq(name: str) -> Optional[int]:
    if not (name.startswith("wal-") and name.endswith(".log")):
        return None
    try:
        return int(name[4:-4])
    except ValueError:
        return None


def _list_segments(directory: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    segs = []
    for name in os.listdir(directory):
        first = _segment_first_seq(name)
        if first is not None:
            segs.append((first, os.path.join(directory, name)))
    return sorted(segs)


def iter_frames(data: bytes, *, is_last: bool, name: str = "<bytes>"):
    """Yield (seq, rtype, payload, end_offset) frames of one segment's
    bytes — the shared parser under local recovery (``_read_segment``)
    and WAL shipping (a transport fetches segment *bytes*; the follower
    parses them with exactly the reader the primary would use).

    A bad/half frame ends iteration when ``is_last`` (torn tail, the
    expected crash artifact) and raises ``WalError`` otherwise.
    """
    if data[:len(_MAGIC)] != _MAGIC:
        raise WalError(f"bad segment magic in {name!r}")
    off = len(_MAGIC)
    while off < len(data):
        frame_ok = False
        if off + _FRAME_MIN <= len(data):
            (crc,) = _CRC.unpack_from(data, off)
            head = data[off + _CRC.size: off + _FRAME_MIN]
            plen, seq, rtype = _HEAD.unpack(head)
            end = off + _FRAME_MIN + plen
            if end <= len(data):
                payload = data[off + _FRAME_MIN: end]
                frame_ok = zlib.crc32(head + payload) == crc
        if not frame_ok:
            if is_last:
                return                      # torn tail: stop at last good
            raise WalError(
                f"corrupt WAL frame at {name!r}+{off} (not the log tail)")
        yield seq, rtype, payload, end
        off = end


def _read_segment(path: str, *, is_last: bool):
    """``iter_frames`` over one on-disk segment file."""
    with open(path, "rb") as f:
        data = f.read()
    yield from iter_frames(data, is_last=is_last, name=path)


def iter_records(directory: str, after: int = -1
                 ) -> Iterator[Tuple[int, int, bytes]]:
    """Yield (seq, rtype, payload) for every record with ``seq > after``,
    in order, across segments; stops cleanly at a torn tail."""
    segs = _list_segments(directory)
    for i, (first, path) in enumerate(segs):
        nxt = segs[i + 1][0] if i + 1 < len(segs) else None
        if nxt is not None and nxt - 1 <= after:
            continue                        # fully covered by the snapshot
        for seq, rtype, payload, _ in _read_segment(
                path, is_last=(i == len(segs) - 1)):
            if seq > after:
                yield seq, rtype, payload


def wal_tail_seq(directory: str) -> int:
    """Seq of the last intact record on disk (-1 = empty/absent log)."""
    last = -1
    for seq, _, _ in iter_records(directory):
        last = seq
    return last


# --- the writer --------------------------------------------------------------

class Wal:
    """Append-only writer over a directory of CRC-framed segments.

    ``resume=True`` scans the existing log, truncates a torn tail, and
    continues the sequence; the default refuses a non-empty directory
    (recover through ``load_engine`` instead of silently forking
    history). Counters (records/bytes/fsyncs/rotations/group_commits)
    surface through ``SearchEngine.metrics()``.

    The writer is thread-safe: concurrent ``append`` calls serialize on
    an internal lock, and with ``group_commit_ms`` > 0 they share fsyncs
    through the commit thread instead of each paying one.

    ``floor_seq``: chained incremental snapshots reference a *base*
    manifest whose WAL position pins how far history may be truncated —
    a follower re-seeded from the base artifact still needs every record
    past the base's ``wal_seq``. ``pin_floor`` records that bound and
    ``truncate`` clamps to it.
    """

    def __init__(self, directory: str, config: DurabilityConfig = None, *,
                 resume: bool = False):
        self.directory = directory
        self.config = config or DurabilityConfig()
        self.counters = {"records": 0, "bytes": 0, "fsyncs": 0,
                         "rotations": 0, "group_commits": 0}
        self.last_seq = -1
        self.floor_seq: Optional[int] = None
        self._f = None
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        self._durable_seq = -1          # group mode: last fsync-covered seq
        self._closing = False
        self._committer: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        segs = _list_segments(directory)
        if segs and not resume:
            raise RuntimeError(
                f"WAL directory {directory!r} already holds segments; "
                "re-open the engine with load_engine (which replays and "
                "resumes) instead of starting a second history")
        if segs:
            self._resume(segs)
        else:
            self._open_segment(0)
        self._durable_seq = self.last_seq
        if self.config.group_commit_ms > 0:
            self._committer = threading.Thread(
                target=self._commit_loop, name="wal-group-commit",
                daemon=True)
            self._committer.start()

    @property
    def _grouped(self) -> bool:
        return self._committer is not None

    def _resume(self, segs):
        first, path = segs[-1]
        end = len(_MAGIC)
        for seq, _, _, off in _read_segment(path, is_last=True):
            self.last_seq = seq
            end = off
        for f_seq, p in segs[:-1]:
            for seq, _, _, _ in _read_segment(p, is_last=False):
                self.last_seq = max(self.last_seq, seq)
        if self.last_seq < 0 and len(segs) > 1:
            self.last_seq = first - 1
        self._f = open(path, "r+b")
        self._f.truncate(end)               # drop the torn tail for good
        self._f.seek(end)
        self._path = path

    def _open_segment(self, first_seq: int):
        path = os.path.join(self.directory, f"wal-{first_seq:016d}.log")
        self._f = open(path, "wb")
        self._f.write(_MAGIC)
        self._path = path

    def _sync_file(self):
        self._f.flush()
        os.fsync(self._f.fileno())
        self.counters["fsyncs"] += 1

    def _commit_loop(self):
        """Group-commit thread: one fsync covers every record appended
        before it runs (records keep arriving while the previous fsync
        is in flight — that disk time IS the natural batching window;
        ``group_commit_ms`` adds a bounded extra gather)."""
        window_s = self.config.group_commit_ms / 1e3
        while True:
            with self._cv:
                while self.last_seq <= self._durable_seq and not self._closing:
                    self._cv.wait()
                if self._f is None or (self._closing
                                       and self.last_seq <= self._durable_seq):
                    self._cv.notify_all()
                    return
            if window_s > 0 and not self._closing:
                time.sleep(window_s)        # bounded coalescing wait
            with self._cv:
                if self._f is None:
                    self._cv.notify_all()
                    return
                target = self.last_seq
                if target > self._durable_seq:
                    self._sync_file()
                    self.counters["group_commits"] += 1
                    self._durable_seq = target
                self._cv.notify_all()

    def append(self, rtype: int, payload: bytes = b"", *,
               wait: bool = True) -> int:
        """Append one record; returns its seq. Durability per the
        configured fsync mode; under group commit the call returns after
        the fsync covering this record (``wait=False`` defers that to a
        later ``wait_durable`` — for multi-record batches that only need
        one durability point at the end)."""
        with self._cv:
            if self._f is None:
                raise RuntimeError("WAL is closed")
            seq = self.last_seq + 1
            head = _HEAD.pack(len(payload), seq, rtype)
            frame = _CRC.pack(zlib.crc32(head + payload)) + head + payload
            if (self._f.tell() + len(frame) > self.config.segment_bytes
                    and self._f.tell() > len(_MAGIC)):
                self._sync_file()
                self._f.close()
                self._open_segment(seq)
                self.counters["rotations"] += 1
                self._durable_seq = seq - 1   # rotation synced everything
            self._f.write(frame)
            if self.config.fsync == "always":
                if self._grouped:
                    # Make the bytes visible to same-host readers now;
                    # the commit thread owns the (expensive) fsync.
                    self._f.flush()
                    self._cv.notify_all()
                else:
                    self._sync_file()
                    self._durable_seq = seq
            elif self.config.fsync == "batch":
                self._f.flush()
            self.last_seq = seq
            self.counters["records"] += 1
            self.counters["bytes"] += len(frame)
        if wait:
            self.wait_durable(seq)
        return seq

    def wait_durable(self, seq: Optional[int] = None):
        """Block until record ``seq`` (default: the last appended) is
        covered by an fsync. No-op outside group-commit mode — the other
        fsync modes resolve durability inside ``append`` itself."""
        if not self._grouped:
            return
        with self._cv:
            target = self.last_seq if seq is None else seq
            while self._durable_seq < target and self._f is not None:
                self._cv.wait(timeout=1.0)

    def sync(self):
        """Force the appended records to disk (snapshot barrier)."""
        with self._cv:
            if self._f is not None:
                self._sync_file()
                self._durable_seq = self.last_seq
                self._cv.notify_all()

    def pin_floor(self, seq: Optional[int]):
        """Pin the truncation floor: records with ``seq > floor`` must
        stay on disk (the newest *base* snapshot manifest still
        references them). ``None`` lifts the pin."""
        self.floor_seq = seq

    def truncate(self, upto_seq: int):
        """Unlink segments whose every record has ``seq <= upto_seq``
        (history covered by a durable snapshot), clamped to the pinned
        ``floor_seq``. The open segment always survives."""
        if self.floor_seq is not None:
            upto_seq = min(upto_seq, self.floor_seq)
        with self._mu:
            segs = _list_segments(self.directory)
            for i, (first, path) in enumerate(segs):
                nxt = segs[i + 1][0] if i + 1 < len(segs) else None
                if (path != self._path and nxt is not None
                        and nxt - 1 <= upto_seq):
                    os.unlink(path)

    def close(self):
        if self._committer is not None:
            with self._cv:
                self._closing = True
                self._cv.notify_all()
            self._committer.join()
            self._committer = None
        with self._cv:
            if self._f is not None:
                if self.config.fsync != "never":
                    self._sync_file()
                    self._durable_seq = self.last_seq
                self._f.close()
                self._f = None
            self._cv.notify_all()

    def stats(self) -> dict:
        """Counters + positions for ``SearchEngine.metrics()``."""
        return dict(self.counters, last_seq=self.last_seq,
                    durable_seq=self._durable_seq,
                    floor_seq=-1 if self.floor_seq is None else self.floor_seq,
                    segments=len(_list_segments(self.directory)),
                    fsync=self.config.fsync,
                    group_commit_ms=self.config.group_commit_ms)
