"""Crash recovery: replay the WAL tail through the engine's write path.

``load_engine`` restores the newest durable snapshot, then calls
``replay`` to drive every record past the snapshot's ``wal_seq`` back
through ``SearchEngine.upsert/delete/compact`` — the *same* donated-jit
programs live traffic uses, so the recovered store is record-for-record
identical to the uncrashed engine (the property
``tests/test_durability.py`` pins at every kill point, against both the
uncrashed oracle and ``rebuild_state``).

Replay runs with the engine's ``_replaying`` flag up: WAL appends and
policy auto-decisions are disabled (the log already contains both the
writes and the maintenance decisions; re-deriving either would
double-apply), and a ``RT_COMPACT`` barrier — logged when compaction
*begins* — is redone to completion, so a crash mid-compaction recovers
to the committed (post-swap) state.
"""
from __future__ import annotations

import dataclasses

from .wal import (RT_COMPACT, RT_DELETE, RT_POLICY, RT_SNAPSHOT, RT_UPSERT,
                  decode_delete, decode_policy, decode_upsert, iter_records)

__all__ = ["ReplayStats", "replay", "replay_records"]


@dataclasses.dataclass
class ReplayStats:
    """What one recovery pass applied (``SearchEngine.metrics()`` keeps
    the record count as ``wal.replayed``)."""
    records: int = 0
    upserts: int = 0
    deletes: int = 0
    compactions: int = 0
    policies: int = 0
    rows: int = 0                    # upserted rows applied
    last_seq: int = -1


def replay_records(engine, records, stats: ReplayStats = None) -> ReplayStats:
    """Apply an ordered iterable of ``(seq, rtype, payload)`` records to
    ``engine`` — the shared apply loop under local crash recovery
    (records read from the engine's own WAL directory) and follower
    catch-up (records shipped from a primary through a transport).

    Runs with the engine's ``_replaying`` flag up: WAL appends and
    policy auto-decisions stay off, and RT_COMPACT / RT_POLICY barriers
    are re-folded through the engine's own write programs — a follower
    never copies folded arrays, it re-derives them deterministically.
    """
    stats = stats or ReplayStats()
    engine._replaying = True
    try:
        for seq, rtype, payload in records:
            if rtype == RT_UPSERT:
                ids, vectors = decode_upsert(payload)
                engine.upsert(ids, vectors)
                stats.upserts += 1
                stats.rows += int(ids.shape[0])
            elif rtype == RT_DELETE:
                engine.delete(decode_delete(payload))
                stats.deletes += 1
            elif rtype == RT_COMPACT:
                engine.compact()
                stats.compactions += 1
            elif rtype == RT_POLICY:
                engine._apply_policy_record(decode_policy(payload))
                stats.policies += 1
            elif rtype == RT_SNAPSHOT:
                pass                 # marker only; truncation bookkeeping
            else:
                raise ValueError(f"unknown WAL record type {rtype}")
            stats.records += 1
            stats.last_seq = seq
    finally:
        engine._replaying = False
    return stats


def replay(engine, wal_dir: str, after_seq: int = -1) -> ReplayStats:
    """Apply every WAL record with ``seq > after_seq`` to ``engine``.

    ``engine`` is a streaming ``SearchEngine`` restored from the
    snapshot the log tail extends. Stops cleanly at a torn tail (the
    crash artifact); raises ``WalError`` on mid-log corruption.
    """
    stats = ReplayStats(last_seq=after_seq)
    return replay_records(engine, iter_records(wal_dir, after=after_seq),
                          stats)
