"""Batched vector-search serving engine: a functional one-program core.

Pipeline (DESIGN.md §2): corpus -> [fit MPAD on a sample] -> reduce corpus ->
[build an index over reduced vectors] -> serve batched queries:
reduce query -> index probe/scan in reduced space -> exact re-rank of the C
candidates in the original space -> top-k.

The reduced-space scan is where the paper's win lands: score FLOPs and corpus
bytes scale with m instead of n, and the re-rank restores exactness on the
short candidate list.

The composable API
------------------

The pipeline is declared by an ``IndexSpec`` (``repro.search.spec``) —
``Reduce -> Coarse -> Code -> Rerank`` stages with a string grammar
(``"qpad32>ivf64x8>pq8x256:i8"``) — and lowered onto a **tagged index
union** (``repro.search.registry.Index``): one ``kind`` tag + stage
payload instead of four mutually-exclusive Optional fields. Every scan
site dispatches through the per-kind ``IndexOps`` registry, so adding an
index kind is one registry entry. The legacy flat ``ServeConfig`` keeps
working (it lowers onto a spec via ``spec_from_config``, which also
rejects dead knobs).

Lifecycle::

    eng = build_engine(corpus, "qpad32>ivf256x8>pq16x256:i8")   # build
    eng.shard(mesh)                   # optional: partition over a mesh
    eng.streaming(StreamConfig(...))  # optional: enable the write path
    eng.save(dir)                     # snapshot: spec + arrays
    eng = load_engine(dir)            # restore (optionally onto a mesh)

Serving architecture
--------------------

The engine is split into a **pytree of arrays** and a **pure function**:

* ``EngineState`` — an immutable pytree holding the re-rank corpus, the
  (optional) MPAD projection, and the built index as the tagged union.
  Being a pytree, it shards, donates, and serialises like any other jax
  state; the ``kind`` tag is pytree aux data, so it is static under jit
  and keys compile caches through the treedef.
* ``search_fn(state, queries, k, *, nprobe, rerank, backend, interpret,
  lut_dtype)`` — the whole query pipeline (project -> probe ->
  ADC/flat scan -> dedup'd masked re-rank gather -> final top-k) as one
  traceable function. Jitted, it compiles to a **single XLA program**: no
  Python dispatch or host syncs between stages.

``SearchEngine`` is a thin stateful wrapper: it builds ``EngineState`` once,
owns a per-engine ``jax.jit(search_fn)`` whose cache is keyed by
``(index kind + knobs, k, query bucket)``, and pads incoming query batches
up to power-of-two buckets (floored at ``ServeConfig.query_bucket``) so
ragged traffic reuses compilations — batch sizes {9, 33, 64} all run the
one program compiled for bucket 64. Batches of at most
``ServeConfig.small_batch`` (default 8) take their own power-of-two bucket
instead of the floor, so a single query runs a compute-proportional scan
rather than a 64-wide one (the small-batch latency cliff).
``SearchEngine.compile_count`` exposes the cache size for regression tests.

Sharded serving
---------------

``shard_engine(state, mesh, axis="data")`` (``repro.parallel.engine``)
partitions the state pytree along the **database axis** of a device mesh:
corpus rows and the per-kind sharded payload (row-sharded flat
vectors/PQ codes, cell-sharded IVF/IVF-PQ posting structures; projection,
centroids, and codebook factorizations replicated — see
``IndexOps.shard_payload``). ``sharded_search_fn`` then runs the same
fused pipeline under ``shard_map``: each shard probes (replicated math —
identical on every shard), scans only the rows/cells it owns, keeps a
local top-n_cand with **global** row ids via its shard offset, and the
shards finish with an ``all_gather`` + global top-k merge and a masked
exact re-rank in which each shard gathers only the winning candidates it
owns (``psum``-free: a ``pmin`` combines the per-shard masked distances).
The merge keeps the exact candidate set of the single-device program, so
sharded and single-device serving return identical neighbors; the
single-device path itself is untouched. The jit cache keys on the mesh
(shape + devices), so resizing the fleet recompiles exactly once per
shape.

Streaming (mutable) serving
---------------------------

``engine.streaming(StreamConfig(...))`` (or the declarative
``ServeConfig(stream=...)``) enables the write path: the built index
becomes the frozen **base** layer of a ``repro.search.segments.StreamStore``
(fixed row capacity + posting-list pad slack + tombstone bitmap) with a
fixed-capacity exact-scan **delta segment** on top.
``SearchEngine.upsert/delete/compact`` are pure donated-jit programs over
that store — no recompiles per write — and ``search`` routes through
``repro.search.stream.stream_search_fn`` (or its sharded twin: base
sharded, delta/tombstones replicated).

Index kinds (``IndexSpec.kind`` / ``ServeConfig.index``):

  "flat"   exact scan of the (reduced) vectors
  "ivf"    k-means coarse quantizer, probe nprobe cells, exact cell scan
  "pq"     product-quantized vectors, fused ADC scan
  "ivfpq"  coarse quantizer + PQ-coded residuals, probed ADC scan — the
           production memory-hierarchy composition

The ``Code`` stage's ``lut_dtype`` ("f32" | "bf16" | "int8") quantizes the
per-query ADC lookup tables of the pq/ivfpq scans (see
``repro.kernels.pq_adc.lut``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import MPADConfig, MPADResult, fit_mpad
from repro.kernels.pq_adc.lut import LUT_DTYPES
from .registry import INDEX_KINDS, Index, ScanParams, get_ops
from .segments import StreamConfig
from .spec import IndexSpec, parse_spec, spec_from_config

__all__ = ["ServeConfig", "SearchEngine", "EngineState",
           "ShardedEngineState", "StreamConfig", "search_fn",
           "sharded_search_fn", "exact_rerank", "INDEX_KINDS",
           "build_engine", "config_from_spec"]

_ADC_BACKENDS = ("jnp", "kernel")
_SEARCH_STATICS = ("k", "nprobe", "rerank", "backend", "interpret",
                   "lut_dtype")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The flat (legacy) engine config: pipeline knobs + runtime knobs.

    The pipeline part lowers onto an ``IndexSpec`` (``spec_from_config``)
    — which is also where cross-knob validation happens: ``nprobe`` may
    not exceed ``nlist``, and knobs whose stage is absent from the
    selected pipeline (e.g. ``nlist`` under ``index="pq"``) are rejected
    instead of silently ignored. Prefer building engines from a spec
    (``build_engine(corpus, "qpad32>ivf64x8>pq8x256:i8")``); construct a
    ``ServeConfig`` directly when you need the runtime knobs too.
    """
    target_dim: Optional[int] = None     # None = no reduction (full-dim exact)
    rerank: int = 64                     # candidates re-ranked in original space
    index: str = "flat"                  # one of INDEX_KINDS
    nlist: int = 64                      # ivf/ivfpq: coarse cells
    nprobe: int = 8                      # ivf/ivfpq: cells probed per query
    pq_subspaces: int = 8                # pq/ivfpq: code bytes per vector
    pq_centroids: int = 256              # pq/ivfpq: codebook size per subspace
    pq_backend: str = "jnp"              # ADC scoring: "jnp" | "kernel"
    pq_interpret: bool = True            # kernel backend: Pallas interpret
    #                                      mode (set False on real TPU)
    lut_dtype: str = "f32"               # ADC LUT precision: f32 | bf16 | int8
    query_bucket: int = 64               # min padded query-batch size; ragged
    #                                      batches round up to powers of two
    small_batch: int = 8                 # batches <= this take their own
    #                                      power-of-two bucket instead of the
    #                                      query_bucket floor (0 disables)
    mpad: Optional[MPADConfig] = None    # defaults derived from target_dim
    fit_sample: int = 2048               # rows used to fit the projection
    seed: int = 0
    stream: Optional[StreamConfig] = None  # enable the mutable write path
    #                                        (delta segment + tombstones +
    #                                        compaction; see search/stream.py)
    # removed boolean index spec (PR-1 deprecation cycle complete): any
    # value raises with a pointer to the spec grammar
    use_ivf: Optional[bool] = None
    use_pq: Optional[bool] = None

    def __post_init__(self):
        if self.use_ivf is not None or self.use_pq is not None:
            raise ValueError(
                "ServeConfig(use_ivf=/use_pq=) was removed after its "
                "deprecation cycle; select the pipeline with "
                "ServeConfig(index='ivf'|'pq'|'ivfpq') or an index-spec "
                "string such as 'qpad32>ivf64x8>pq8x256:i8' "
                "(repro.search.parse_spec)")
        if self.index not in INDEX_KINDS:
            raise ValueError(
                f"unknown index kind {self.index!r}; expected one of "
                f"{INDEX_KINDS}")
        if self.pq_backend not in _ADC_BACKENDS:
            raise ValueError(
                f"unknown pq_backend {self.pq_backend!r}; expected one of "
                f"{_ADC_BACKENDS}")
        if self.lut_dtype not in LUT_DTYPES:
            raise ValueError(
                f"unknown lut_dtype {self.lut_dtype!r}; expected one of "
                f"{LUT_DTYPES}")
        if self.query_bucket < 1:
            raise ValueError("query_bucket must be >= 1")
        if self.small_batch < 0:
            raise ValueError("small_batch must be >= 0 (0 disables the "
                             "small-batch bucket floor path)")
        if (self.stream is not None and self.index == "pq"
                and self.pq_backend == "kernel"):
            raise ValueError(
                "streaming index='pq' needs pq_backend='jnp': the "
                "shared-codes Pallas kernel has no masked entry point for "
                "an arbitrary tombstone bitmap (use index='ivfpq' for a "
                "kernel-backed streaming ADC scan)")
        # stage-level validation: lower onto the pipeline spec (rejects
        # nprobe > nlist, dead knobs, bad stage values)
        self.to_spec()

    def to_spec(self) -> IndexSpec:
        """Lower this config onto its pipeline spec (validating)."""
        return spec_from_config(self)


def config_from_spec(spec, **runtime) -> ServeConfig:
    """Lower an ``IndexSpec`` (or spec string) onto a ``ServeConfig``.

    ``runtime`` forwards the engine knobs a pipeline spec does not carry
    (``query_bucket``, ``small_batch``, ``mpad``, ``fit_sample``,
    ``seed``, ``pq_interpret``, ``stream``). Round-trips with
    ``ServeConfig.to_spec``.
    """
    if isinstance(spec, str):
        spec = parse_spec(spec)
    if not isinstance(spec, IndexSpec):
        raise TypeError(f"IndexSpec or spec string expected, got "
                        f"{type(spec).__name__}")
    kw = dict(index=spec.kind, rerank=spec.rerank.n)
    if spec.reduce is not None:
        kw["target_dim"] = spec.reduce.m
    if spec.coarse is not None:
        kw.update(nlist=spec.coarse.nlist, nprobe=spec.coarse.nprobe)
    if spec.code is not None:
        kw.update(pq_subspaces=spec.code.subspaces,
                  pq_centroids=spec.code.centroids,
                  lut_dtype=spec.code.lut_dtype,
                  pq_backend=spec.code.backend)
    kw.update(runtime)
    return ServeConfig(**kw)


def as_serve_config(config) -> ServeConfig:
    """Accept a ServeConfig, an IndexSpec, or a spec string everywhere a
    config is expected."""
    if isinstance(config, ServeConfig):
        return config
    if isinstance(config, (str, IndexSpec)):
        return config_from_spec(config)
    raise TypeError(
        "expected a ServeConfig, an IndexSpec, or a spec string like "
        f"'qpad32>ivf64x8>pq8x256:i8'; got {type(config).__name__}")


class EngineState(NamedTuple):
    """Everything ``search_fn`` needs, as one immutable pytree.

    ``index`` is the tagged union: ``index.kind`` selects the registered
    ``IndexOps`` (static under jit — it rides the treedef), ``index.payload``
    is that kind's built arrays. ``corpus`` is the original-space row store
    for the exact re-rank; ``proj`` the (optional) MPAD projection.
    """
    corpus: jax.Array                              # (N, D) re-rank space
    proj: Optional[Tuple[jax.Array, jax.Array]]    # (matrix (m,D), mean (D,))
    index: Index                                   # tagged union payload


class ShardedEngineState(NamedTuple):
    """``EngineState`` re-laid-out for data-parallel serving on a mesh.

    ``corpus`` is padded to a per-shard-equal shape and sharded along dim
    0; ``index`` holds the kind's **sharded** payload (see
    ``IndexOps.shard_payload`` — row- or cell-sharded database leaves,
    replicated quantizers); the MPAD projection replicates. Built by
    ``repro.parallel.engine.shard_engine``; consumed by
    ``sharded_search_fn``. ``n_real`` is the unpadded corpus size — rows
    at or beyond it are shard padding, masked out of every scan.
    """
    corpus: jax.Array                              # (N_pad, D) row-sharded
    proj: Optional[Tuple[jax.Array, jax.Array]]    # replicated (matrix, mean)
    n_real: jax.Array                              # () int32 replicated
    index: Index                                   # kind + sharded payload


def _dedupe_candidates(cand: jax.Array):
    """Collapse duplicate candidate ids to -1: sort (pads sort first) +
    neighbor compare. Returns (cand sorted/deduped, valid mask). Shared by
    the single-device and sharded re-ranks — their parity depends on running
    the identical prologue."""
    cand = jnp.sort(cand, axis=1)                        # pads (-1) sort first
    dup = jnp.concatenate(
        [jnp.zeros_like(cand[:, :1], bool), cand[:, 1:] == cand[:, :-1]],
        axis=1)
    cand = jnp.where(dup, -1, cand)
    return cand, cand >= 0


def exact_rerank(queries: jax.Array, corpus: jax.Array, cand: jax.Array,
                 k: int):
    """Re-score candidate ids in the original space; top-k of the survivors.

    ``cand`` (Q, C) may contain -1 pads and duplicate ids (over-retrieval
    across probes): duplicates are collapsed to -1 first (sort + neighbor
    compare), then a single masked gather pulls each surviving row once and
    pads/dups are held out of the top-k with +inf.
    """
    cand, valid = _dedupe_candidates(cand)
    cv = jnp.take(corpus, jnp.where(valid, cand, 0), axis=0)   # (Q, C, D)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def _check_rerank_budget(approximate: bool, rerank: int, k: int):
    if approximate and rerank < k:
        raise ValueError(
            f"k={k} exceeds the re-rank budget rerank={rerank} on an "
            "approximate pipeline (reduction and/or PQ codes): the exact "
            "re-rank could only return rerank candidates. Raise the "
            f"Rerank stage (e.g. spec '...>rr{k}') or lower k.")


def search_fn(state: EngineState, queries: jax.Array, k: int, *,
              nprobe: int = 8, rerank: int = 64, backend: str = "jnp",
              interpret: bool = True, lut_dtype: str = "f32"):
    """The entire query pipeline as one pure traceable function.

    project -> probe/scan (dispatched on ``state.index.kind`` through the
    ops registry) -> exact re-rank -> top-k. Jitted
    (``jax.jit(search_fn, static_argnames=_SEARCH_STATICS)``) this is
    a single XLA program; the index kind is pytree aux data, so it keys
    the compile cache without being an argument. Every per-query op is
    row-independent, so padded query rows never perturb real results.
    Returns (dists (Q,k), ids (Q,k)); distances in the original space when
    re-ranking is active, else in the serving (reduced) space.
    """
    ops = get_ops(state.index.kind)
    queries = jnp.asarray(queries, jnp.float32)
    if state.proj is not None:
        matrix, mean = state.proj
        qr = (queries - mean) @ matrix.T
    else:
        qr = queries
    # lossy scoring (reduction and/or PQ codes) -> over-retrieve + re-rank
    approximate = state.proj is not None or ops.lossy
    _check_rerank_budget(approximate, rerank, k)
    n_cand = rerank if approximate else k
    p = ScanParams(nprobe=nprobe, backend=backend, interpret=interpret,
                   lut_dtype=lut_dtype)
    _, cand = ops.scan(state, qr, n_cand, p)
    return exact_rerank(queries, state.corpus, cand, k)


# --- sharded serving (shard_map over a database-axis mesh) -------------------

def _sharded_rerank(queries: jax.Array, corpus_loc: jax.Array,
                    cand: jax.Array, k: int, axis: str):
    """``exact_rerank`` with the corpus row-sharded: the same sort + dedupe
    runs replicated, then each shard gathers and scores only the candidates
    it owns and a ``pmin`` over the mesh axis assembles the full exact
    distance row (every candidate is owned by exactly one shard) — only the
    k winners' rows are ever touched on any device."""
    cand, valid = _dedupe_candidates(cand)
    n_loc = corpus_loc.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    local = cand - off
    own = valid & (local >= 0) & (local < n_loc)
    cv = jnp.take(corpus_loc, jnp.clip(local, 0, n_loc - 1), axis=0)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(own, d2, jnp.inf)
    d2 = jax.lax.pmin(d2, axis)                          # (Q, C) replicated
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def _sharded_core(sstate: ShardedEngineState, queries: jax.Array, *, k: int,
                  nprobe: int, rerank: int, backend: str,
                  interpret: bool, lut_dtype: str, axis: str, slack: int):
    """The shard_map body: the full per-shard pipeline + distributed merge."""
    ops = get_ops(sstate.index.kind)
    queries = jnp.asarray(queries, jnp.float32)
    if sstate.proj is not None:
        matrix, mean = sstate.proj
        qr = (queries - mean) @ matrix.T
    else:
        qr = queries
    approximate = sstate.proj is not None or ops.lossy
    _check_rerank_budget(approximate, rerank, k)
    n_cand = rerank if approximate else k
    p = ScanParams(nprobe=nprobe, backend=backend, interpret=interpret,
                   lut_dtype=lut_dtype)
    d2, cand = ops.local_scan(sstate, qr, n_cand, p, axis, slack)
    # distributed merge: every shard's local top-n_cand is a superset of the
    # global top-n_cand members it owns, so the merged set equals the
    # single-device candidate set exactly
    d2g = jax.lax.all_gather(d2, axis, axis=1, tiled=True)   # (Q, S*n_cand)
    idg = jax.lax.all_gather(cand, axis, axis=1, tiled=True)
    neg, sel = jax.lax.top_k(-d2g, n_cand)
    merged = jnp.take_along_axis(idg, sel, axis=1)
    merged = jnp.where(jnp.isneginf(neg), -1, merged)
    return _sharded_rerank(queries, sstate.corpus, merged, k, axis)


def sharded_search_fn(sstate: ShardedEngineState, queries: jax.Array, k: int,
                      *, mesh: Mesh, axis: str = "data",
                      nprobe: int = 8, rerank: int = 64, backend: str = "jnp",
                      interpret: bool = True, lut_dtype: str = "f32"):
    """``search_fn`` partitioned over the ``axis`` of ``mesh``.

    Same contract and — by construction of the distributed merge — the same
    results as the single-device ``search_fn`` on the unsharded state.
    Jit with ``mesh``/``axis`` static (``Mesh`` hashes by shape + devices,
    which is exactly what the compile cache must key on).
    """
    from repro.parallel.sharding import engine_state_specs
    specs = engine_state_specs(sstate, axis)
    core = functools.partial(
        _sharded_core, k=k, nprobe=nprobe, rerank=rerank,
        backend=backend, interpret=interpret, lut_dtype=lut_dtype, axis=axis,
        slack=mesh.shape[axis] - 1)
    f = shard_map(core, mesh=mesh, in_specs=(specs, P()),
                  out_specs=(P(), P()), check_rep=False)
    return f(sstate, queries)


def _bucket(nq: int, floor: int, small: int = 0) -> int:
    """Smallest power-of-two >= nq, floored at ``floor`` — except batches of
    at most ``small``, which take their own power-of-two bucket so tiny
    batches run a compute-proportional program instead of padding to the
    floor (the small-batch latency cliff; ``small=0`` disables)."""
    pow2 = 1 << max(nq - 1, 0).bit_length()
    if 0 < nq <= small:
        return pow2
    return max(floor, pow2)


class SearchEngine:
    """Build once over a corpus; serve batched k-NN queries.

    Thin wrapper over the functional core: construction builds
    ``self.state`` (an ``EngineState``), ``search`` pads the batch to its
    bucket and calls the engine-owned jitted ``search_fn``. The config may
    be a ``ServeConfig``, an ``IndexSpec``, or a spec string. Mutating
    ``self.config`` (e.g. ``dataclasses.replace(..., nprobe=16)``) is
    supported — knob changes re-key the jit cache, not the state.

    Lifecycle methods: ``shard(mesh)`` partitions the state over a device
    mesh, ``streaming(StreamConfig(...))`` enables the mutable write path
    (``upsert``/``delete``/``compact``), ``save(dir)`` snapshots spec +
    arrays (restore with ``repro.search.load_engine``).
    """

    def __init__(self, corpus: jax.Array, config=ServeConfig()):
        config = as_serve_config(config)
        spec = config.to_spec()
        corpus_in = corpus
        corpus = jnp.asarray(corpus, jnp.float32)
        # when the caller's array passes through unconverted, it stays
        # caller-owned: shard(donate=True) must not delete it
        self._user_corpus = corpus if corpus is corpus_in else None
        n, dim = corpus.shape
        key = jax.random.key(config.seed)
        if spec.reduce is not None:
            mcfg = config.mpad or MPADConfig(
                m=spec.reduce.m, b=80.0, alpha=25.0, iters=48,
                seed=config.seed)
            sample = corpus
            if config.fit_sample < n:
                rows = jax.random.choice(
                    key, n, (config.fit_sample,), replace=False)
                sample = corpus[rows]
            reducer: Optional[MPADResult] = fit_mpad(sample, mcfg)
            reduced = reducer(corpus)
            proj = (reducer.matrix, reducer.mean)
        else:
            reducer = None
            reduced = corpus
            proj = None
        payload = get_ops(config.index).build(key, reduced, spec)
        state = EngineState(corpus=corpus, proj=proj,
                            index=Index(config.index, payload))
        self._attach(config, state, reducer)

    # --- lifecycle --------------------------------------------------------

    def _attach(self, config: ServeConfig, state: Optional[EngineState],
                reducer: Optional[MPADResult], store=None, frozen=None):
        """Wire a built (or restored) state into a serving engine: jit
        programs, compile caches, counters. The shared tail of ``__init__``
        and the snapshot-restore constructors."""
        self._user_corpus = getattr(self, "_user_corpus", None)
        self.config = config
        self.reducer = reducer
        self.state: Optional[EngineState] = state
        self.last_bucket: Optional[int] = None   # padded size of the last
        #                                          served batch (shape pin
        #                                          for latency tests)
        self.sharded_state: Optional[ShardedEngineState] = None
        self._mesh: Optional[Mesh] = None
        self._shard_axis = "data"
        self._sharded_program = None
        # engine-owned jit: a fresh closure gives this engine its own
        # compilation cache (jax shares caches for identical function
        # objects), keyed by (statics, query bucket)
        def _engine_search_fn(state, queries, k, **kw):
            return search_fn(state, queries, k, **kw)
        self._program = jax.jit(_engine_search_fn,
                                static_argnames=_SEARCH_STATICS)
        self.store, self.frozen = store, frozen  # streaming (write) state
        self._stream_sharded_base = None
        self._stream_program = self._stream_sharded_program = None
        self._upsert_program = self._delete_program = None
        self._compact_program = None
        self.grow_count = 0          # compaction-overflow regrowths (rare;
        #                              each one is a recompile point)
        self._delta_used = 0         # conservative host mirror of the delta
        #                              fill (overwrites counted as appends)
        if store is not None:        # restored mid-delta snapshot
            self._delta_used = int(store.delta_count)
            self._stream_programs()
        elif config.stream is not None:
            self._init_stream()

    @classmethod
    def _restore(cls, config: ServeConfig, *, state=None, store=None,
                 frozen=None) -> "SearchEngine":
        """Construct an engine around already-built arrays (snapshot
        restore): no MPAD refit, no index retrain. Exactly one of
        ``state`` (read-only) or ``store``+``frozen`` (streaming) is
        given; see ``repro.search.snapshot``."""
        eng = object.__new__(cls)
        eng._user_corpus = None
        proj = state.proj if state is not None else frozen.proj
        reducer = None
        if proj is not None:
            matrix, mean = proj
            reducer = MPADResult(matrix=matrix, mean=mean,
                                 objective_trace=jnp.zeros((0, 0)))
        eng._attach(config, state, reducer, store=store, frozen=frozen)
        return eng

    @property
    def spec(self) -> IndexSpec:
        """The pipeline spec this engine serves (lowered from the current
        config, so query-time knob mutations are reflected)."""
        return self.config.to_spec()

    def save(self, directory: str) -> str:
        """Snapshot the engine (spec + config + arrays) into ``directory``;
        restore with ``repro.search.load_engine``. Covers read-only and
        streaming engines (the delta segment and tombstones are saved
        as-is, so a mid-delta snapshot restores mid-delta). Returns the
        checkpoint path."""
        from .snapshot import save_engine
        return save_engine(self, directory)

    @property
    def compile_count(self) -> int:
        """Number of compiled (statics, bucket) variants this engine holds
        (single-device + sharded + streaming read/write programs)."""
        progs = [self._program, self._sharded_program,
                 self._stream_program, self._stream_sharded_program,
                 self._upsert_program, self._delete_program,
                 self._compact_program]
        try:
            return sum(int(p._cache_size()) for p in progs if p is not None)
        except AttributeError as e:     # private jax hook; fail loudly if
            raise RuntimeError(          # an unpinned jax drops it
                "jax no longer exposes PjitFunction._cache_size(); "
                "SearchEngine.compile_count needs a replacement hook"
            ) from e

    # --- streaming (mutable) serving -------------------------------------

    def streaming(self, config: Optional[StreamConfig] = None
                  ) -> "SearchEngine":
        """Enable the mutable write path on a built engine: the dense
        index becomes the frozen base of a ``StreamStore`` with a delta
        segment + tombstones on top, and ``upsert``/``delete``/``compact``
        come alive. One-way and idempotent-hostile by design: call once,
        after build and before ``shard``. Returns ``self`` for chaining.
        (The declarative ``ServeConfig(stream=...)`` route does this at
        construction.)
        """
        if self.store is not None:
            raise RuntimeError(
                "this engine is already streaming; re-configure by "
                "rebuilding or load_engine from a snapshot")
        if self.sharded_state is not None:
            raise RuntimeError(
                "enable streaming BEFORE shard(): the store takes over "
                "the dense arrays, which would leave the placed sharded "
                "state stale (or, on a zero-copy placement, deleted) — "
                "rebuild, call streaming(...), then shard(mesh)")
        if self.state is None:
            raise RuntimeError(
                "the dense EngineState is gone (shard(donate=True)); "
                "streaming needs the dense arrays — rebuild the engine "
                "or load_engine from a snapshot")
        # replace() re-runs config validation (e.g. pq+kernel streaming)
        self.config = dataclasses.replace(
            self.config, stream=config or StreamConfig())
        self._init_stream()
        return self

    def _require_stream(self):
        if self.store is None:
            raise RuntimeError(
                "this engine is read-only; enable the write path with "
                "engine.streaming(StreamConfig(...)) or "
                "ServeConfig(stream=StreamConfig(...))")

    def _init_stream(self):
        from .segments import make_mutable
        self.store, self.frozen = make_mutable(self.state,
                                               self.config.stream)
        # the store owns fresh (capacity-padded) copies of every database
        # leaf, so the dense EngineState duplicates them — release the
        # duplicated buffers (the frozen quantizers and any caller-owned
        # corpus stay shared/alive) instead of holding 2x forever
        hold = {id(leaf) for leaf in jax.tree_util.tree_leaves(self.frozen)}
        if self._user_corpus is not None:
            hold.add(id(self._user_corpus))
        dense = {id(a): a for a in jax.tree_util.tree_leaves(self.state)}
        for leaf in dense.values():
            if id(leaf) not in hold and not leaf.is_deleted():
                leaf.delete()
        self.state = None
        self._stream_programs()

    def _stream_programs(self):
        """Jit the streaming read/write programs (fresh closures: per-engine
        compile caches, same as ``_program``). The write programs donate
        the store, so the ``.at[]`` updates alias the input buffers
        instead of copying the row store per write."""
        from .segments import compact_fn, delete_fn, upsert_fn
        from .stream import sharded_stream_search_fn, stream_search_fn

        def _engine_stream_fn(store, frozen, queries, k, **kw):
            return stream_search_fn(store, frozen, queries, k, **kw)
        self._stream_program = jax.jit(_engine_stream_fn,
                                       static_argnames=_SEARCH_STATICS)

        def _engine_upsert(store, frozen, ids, vectors):
            return upsert_fn(store, frozen, ids, vectors)
        self._upsert_program = jax.jit(_engine_upsert, donate_argnums=(0,))

        def _engine_delete(store, ids):
            return delete_fn(store, ids)
        self._delete_program = jax.jit(_engine_delete, donate_argnums=(0,))

        def _engine_compact(store, frozen):
            return compact_fn(store, frozen)
        self._compact_program = jax.jit(_engine_compact, donate_argnums=(0,))

        def _engine_stream_sharded(sbase, repl, queries, k, **kw):
            return sharded_stream_search_fn(sbase, repl, queries, k, **kw)
        self._stream_sharded_program = jax.jit(
            _engine_stream_sharded,
            static_argnames=_SEARCH_STATICS + ("mesh", "axis"))

    def upsert(self, ids: jax.Array, vectors: jax.Array):
        """Insert or overwrite rows by external id (ids (B,), vectors
        (B, D)). Pure in-place delta appends — no recompilation (batches
        pad to ``StreamConfig.write_bucket``-floored power-of-two buckets)
        and no index rebuild; the delta auto-compacts into the base at
        ``compact_threshold``. Returns ``self``.
        """
        self._require_stream()
        scfg = self.config.stream
        ids = jnp.asarray(ids, jnp.int32).reshape(-1)
        vectors = jnp.asarray(vectors, jnp.float32).reshape(ids.shape[0], -1)
        cap = scfg.delta_capacity
        point = max(1, min(cap, int(scfg.compact_threshold * cap)))
        b = 0
        while b < ids.shape[0]:
            chunk = min(ids.shape[0] - b, point)
            if self._delta_used + chunk > point:
                self.compact()
            cid, cv = ids[b:b + chunk], vectors[b:b + chunk]
            bucket = _bucket(chunk, scfg.write_bucket)
            if bucket != chunk:
                cid = jnp.pad(cid, (0, bucket - chunk), constant_values=-1)
                cv = jnp.pad(cv, ((0, bucket - chunk), (0, 0)))
            # dropped stays 0 by construction (the chunking above never
            # exceeds the compact point), so it is not synced to host here
            self.store, _ = self._upsert_program(self.store, self.frozen,
                                                 cid, cv)
            self._delta_used += chunk
            b += chunk
        return self

    def delete(self, ids: jax.Array):
        """Delete rows by external id: tombstone base copies, punch delta
        holes. Absent ids are no-ops. Returns ``self``."""
        self._require_stream()
        ids = jnp.asarray(ids, jnp.int32).reshape(-1)
        bucket = _bucket(ids.shape[0], self.config.stream.write_bucket)
        if bucket != ids.shape[0]:
            ids = jnp.pad(ids, (0, bucket - ids.shape[0]),
                          constant_values=-1)
        self.store = self._delete_program(self.store, ids)
        return self

    def compact(self):
        """Fold the delta segment into the base index (re-coding against
        the frozen quantizers — shapes and compiled programs survive).

        If the append would overflow the pre-allocated row capacity or a
        posting cell's slack, the store grows host-side and the compaction
        retries: correct, but a recompile point (``grow_count`` ticks) —
        size ``StreamConfig.row_capacity``/``cell_slack`` to avoid it.
        Returns ``self``.
        """
        self._require_stream()
        from .segments import grow_store
        scfg = self.config.stream
        store, dropped = self._compact_program(self.store, self.frozen)
        while int(dropped):
            # one delta's worth of cell slack covers the worst case (every
            # delta row landing in one cell), so a single grow suffices
            store = grow_store(store,
                               row_extra=4 * scfg.delta_capacity,
                               cell_extra=scfg.delta_capacity)
            self.grow_count += 1
            store, dropped = self._compact_program(store, self.frozen)
        self.store = store
        self._delta_used = 0
        if self._stream_sharded_base is not None:
            self._shard_stream_base()        # re-lay the (grown) base out
        return self

    def _shard_stream_base(self):
        from repro.parallel.engine import shard_stream
        self._stream_sharded_base = shard_stream(
            self.store, self.frozen, self._mesh, axis=self._shard_axis)

    # --- sharding ---------------------------------------------------------

    def shard(self, mesh: Optional[Mesh] = None, axis: str = "data",
              donate: bool = False):
        """Partition the engine over the ``axis`` of ``mesh`` (default: the
        mesh activated by ``repro.parallel.context.mesh_context``).

        Subsequent ``search`` calls route through ``sharded_search_fn`` —
        same results, database split across the mesh devices. Returns
        ``self`` for chaining. Re-call with a different mesh to re-shard.

        ``donate=True`` releases the dense single-device buffers once the
        sharded copy is placed (no 2x database memory): re-sharding then
        raises, and switching back via ``sharded_state = None`` is no
        longer possible. With the default ``donate=False`` both copies
        stay live — fine for dry-runs, 2x memory at real scale.

        On a streaming engine the **base** shards and the delta segment /
        tombstones stay replicated (writes keep working; ``compact()``
        re-lays the base out). Donation is refused there: the dense store
        is the write path.
        """
        if mesh is None:
            from repro.parallel.context import require_mesh
            mesh = require_mesh("SearchEngine.shard()")
        self._mesh, self._shard_axis = mesh, axis
        if self.store is not None:
            if donate:
                raise ValueError(
                    "donate=True is not supported on a streaming engine: "
                    "the dense StreamStore backs upsert/delete/compact")
            self._shard_stream_base()
            return self
        if self.state is None:
            raise RuntimeError(
                "the dense EngineState is gone: its buffers were released "
                "by shard(donate=True) — rebuild the engine (or "
                "load_engine from a snapshot) to re-shard")
        from repro.parallel.engine import shard_engine
        keep = (self._user_corpus,) if self._user_corpus is not None else ()
        self.sharded_state = shard_engine(self.state, mesh,
                                          axis=axis, donate=donate,
                                          keep=keep)
        if donate:
            self.state = None
            if self.reducer is not None:
                # the dense projection arrays were donated; point the
                # public reducer at the replicated sharded copies so
                # eng.reducer(x) keeps working
                matrix, mean = self.sharded_state.proj
                self.reducer = self.reducer._replace(matrix=matrix,
                                                     mean=mean)
        if self._sharded_program is None:
            def _engine_sharded_fn(sstate, queries, k, **kw):
                return sharded_search_fn(sstate, queries, k, **kw)
            self._sharded_program = jax.jit(
                _engine_sharded_fn,
                static_argnames=_SEARCH_STATICS + ("mesh", "axis"))
        return self

    def search(self, queries: jax.Array, k: int):
        """Returns (dists (Q,k), ids (Q,k)); distances in the original space
        when re-ranking is active, else in the serving (reduced) space.

        One device program per call: the batch is zero-padded up to its
        power-of-two bucket (>= ``config.query_bucket``) so every batch size
        in a bucket reuses the same compilation, then sliced back to Q rows.
        """
        cfg = self.config
        ops = get_ops(cfg.index)
        # reject an unservable k eagerly (host-side, before any tracing)
        # instead of silently truncating the candidate list inside the scan
        _check_rerank_budget(cfg.target_dim is not None or ops.lossy,
                             cfg.rerank, k)
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        bucket = _bucket(nq, cfg.query_bucket, cfg.small_batch)
        self.last_bucket = bucket
        if bucket != nq:
            queries = jnp.pad(queries, ((0, bucket - nq), (0, 0)))
        # normalize knobs the index kind can't observe so flipping them
        # (e.g. a stray nprobe on a flat engine) never re-keys the jit cache
        probed = cfg.index in ("ivf", "ivfpq")
        coded = cfg.index in ("pq", "ivfpq")
        kw = dict(nprobe=cfg.nprobe if probed else 0,
                  rerank=cfg.rerank,
                  backend=cfg.pq_backend if coded else "jnp",
                  interpret=cfg.pq_interpret if coded else True,
                  lut_dtype=cfg.lut_dtype if coded else "f32")
        if self.store is not None:
            if self._stream_sharded_base is not None:
                from .stream import StreamReplica
                repl = StreamReplica(
                    row_ids=self.store.row_ids, dead=self.store.dead,
                    delta_vectors=self.store.delta_vectors,
                    delta_reduced=self.store.delta_reduced,
                    delta_ids=self.store.delta_ids,
                    delta_count=self.store.delta_count)
                d, ids = self._stream_sharded_program(
                    self._stream_sharded_base, repl, queries, k,
                    mesh=self._mesh, axis=self._shard_axis, **kw)
            else:
                d, ids = self._stream_program(self.store, self.frozen,
                                              queries, k, **kw)
        elif self.sharded_state is not None:
            d, ids = self._sharded_program(
                self.sharded_state, queries, k, mesh=self._mesh,
                axis=self._shard_axis, **kw)
        else:
            d, ids = self._program(self.state, queries, k, **kw)
        return d[:nq], ids[:nq]


def build_engine(corpus: jax.Array, spec, **runtime) -> SearchEngine:
    """Build a serving engine from a pipeline spec — the canonical
    constructor of the composable API.

    ``spec`` is an ``IndexSpec``, a spec string
    (``"qpad32>ivf64x8>pq8x256:i8"``), or a full ``ServeConfig``;
    ``runtime`` forwards engine knobs the pipeline does not carry
    (``query_bucket``, ``mpad``, ``fit_sample``, ``seed``, ``stream``,
    ...). Continue with the lifecycle methods: ``.shard(mesh)``,
    ``.streaming(StreamConfig(...))``, ``.save(dir)``.
    """
    if isinstance(spec, ServeConfig):
        if runtime:
            spec = dataclasses.replace(spec, **runtime)
        return SearchEngine(corpus, spec)
    return SearchEngine(corpus, config_from_spec(spec, **runtime))
