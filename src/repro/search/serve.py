"""Batched vector-search serving engine with MPAD as a first-class feature.

Pipeline (DESIGN.md §2): corpus -> [fit MPAD on a sample] -> reduce corpus ->
[build IVF over reduced vectors] -> serve batched queries:
reduce query -> (IVF probe | brute top-C) in reduced space -> exact re-rank of
the C candidates in the original space -> top-k.

The reduced-space scan is where the paper's win lands: score FLOPs and corpus
bytes scale with m instead of n, and the re-rank restores exactness on the
short candidate list.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import MPADConfig, MPADResult, fit_mpad
from .ivf import IVFIndex, build_ivf, ivf_search
from .knn import knn_search
from .pq import build_pq, pq_search

__all__ = ["ServeConfig", "SearchEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    target_dim: Optional[int] = None     # None = no reduction (full-dim exact)
    rerank: int = 64                     # candidates re-ranked in original space
    use_ivf: bool = False
    nlist: int = 64
    nprobe: int = 8
    use_pq: bool = False                 # PQ-code the (reduced) vectors
    pq_subspaces: int = 8
    pq_centroids: int = 256
    mpad: Optional[MPADConfig] = None    # defaults derived from target_dim
    fit_sample: int = 2048               # rows used to fit the projection
    seed: int = 0


class SearchEngine:
    """Build once over a corpus; serve batched k-NN queries."""

    def __init__(self, corpus: jax.Array, config: ServeConfig):
        self.config = config
        self.corpus = jnp.asarray(corpus, jnp.float32)
        n, dim = self.corpus.shape
        key = jax.random.key(config.seed)
        if config.target_dim is not None:
            mcfg = config.mpad or MPADConfig(
                m=config.target_dim, b=80.0, alpha=25.0, iters=48,
                seed=config.seed)
            sample = self.corpus
            if config.fit_sample < n:
                rows = jax.random.choice(
                    key, n, (config.fit_sample,), replace=False)
                sample = self.corpus[rows]
            self.reducer: Optional[MPADResult] = fit_mpad(sample, mcfg)
            self.reduced = self.reducer(self.corpus)
        else:
            self.reducer = None
            self.reduced = self.corpus
        self.index: Optional[IVFIndex] = None
        self.pq = None
        if config.use_ivf:
            self.index = build_ivf(
                jax.random.fold_in(key, 1), self.reduced, config.nlist)
        elif config.use_pq:
            self.pq = build_pq(jax.random.fold_in(key, 2), self.reduced,
                               config.pq_subspaces, config.pq_centroids)

    def search(self, queries: jax.Array, k: int):
        """Returns (dists (Q,k), ids (Q,k)); distances in the original space
        when re-ranking is active, else in the serving (reduced) space."""
        cfg = self.config
        queries = jnp.asarray(queries, jnp.float32)
        qr = self.reducer(queries) if self.reducer is not None else queries
        approximate = self.reducer is not None or self.pq is not None
        n_cand = max(k, cfg.rerank if approximate else k)
        if self.index is not None:
            _, cand = ivf_search(self.index, qr, n_cand, cfg.nprobe)
        elif self.pq is not None:
            _, cand = pq_search(self.pq, qr, n_cand)
        else:
            _, cand = knn_search(qr, self.reduced, n_cand)
        return _exact_rerank(queries, self.corpus, cand, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _exact_rerank(queries, corpus, cand, k):
    cv = corpus[cand]                                    # (Q, C, n)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids
