"""Batched vector-search serving engine: a functional one-program core.

Pipeline (DESIGN.md §2): corpus -> [fit MPAD on a sample] -> reduce corpus ->
[build an index over reduced vectors] -> serve batched queries:
reduce query -> index probe/scan in reduced space -> exact re-rank of the C
candidates in the original space -> top-k.

The reduced-space scan is where the paper's win lands: score FLOPs and corpus
bytes scale with m instead of n, and the re-rank restores exactness on the
short candidate list.

The composable API
------------------

The pipeline is declared by an ``IndexSpec`` (``repro.search.spec``) —
``Reduce -> Coarse -> Code -> Rerank`` stages with a string grammar
(``"qpad32>ivf64x8>pq8x256:i8"``) — and lowered onto a **tagged index
union** (``repro.search.registry.Index``): one ``kind`` tag + stage
payload instead of four mutually-exclusive Optional fields. Every scan
site dispatches through the per-kind ``IndexOps`` registry, so adding an
index kind is one registry entry. The legacy flat ``ServeConfig`` keeps
working (it lowers onto a spec via ``spec_from_config``, which also
rejects dead knobs).

Lifecycle::

    eng = build_engine(corpus, "qpad32>ivf256x8>pq16x256:i8")   # build
    eng.shard(mesh)                   # optional: partition over a mesh
    eng.streaming(StreamConfig(...))  # optional: enable the write path
    eng.save(dir)                     # snapshot: spec + arrays
    eng = load_engine(dir)            # restore (optionally onto a mesh)

Serving architecture
--------------------

The engine is split into a **pytree of arrays** and a **pure function**:

* ``EngineState`` — an immutable pytree holding the re-rank corpus, the
  (optional) MPAD projection, and the built index as the tagged union.
  Being a pytree, it shards, donates, and serialises like any other jax
  state; the ``kind`` tag is pytree aux data, so it is static under jit
  and keys compile caches through the treedef.
* ``search_fn(state, queries, k, *, nprobe, rerank, backend, interpret,
  lut_dtype)`` — the whole query pipeline (project -> probe ->
  ADC/flat scan -> dedup'd masked re-rank gather -> final top-k) as one
  traceable function. Jitted, it compiles to a **single XLA program**: no
  Python dispatch or host syncs between stages.

``SearchEngine`` is a thin stateful wrapper: it builds ``EngineState`` once,
owns a per-engine ``jax.jit(search_fn)`` whose cache is keyed by
``(index kind + knobs, k, query bucket)``, and pads incoming query batches
up to power-of-two buckets (floored at ``ServeConfig.query_bucket``) so
ragged traffic reuses compilations — batch sizes {9, 33, 64} all run the
one program compiled for bucket 64. Batches of at most
``ServeConfig.small_batch`` (default 8) take their own power-of-two bucket
instead of the floor, so a single query runs a compute-proportional scan
rather than a 64-wide one (the small-batch latency cliff).
``SearchEngine.compile_count`` exposes the cache size for regression tests.

Sharded serving
---------------

``shard_engine(state, mesh, axis="data")`` (``repro.parallel.engine``)
partitions the state pytree along the **database axis** of a device mesh:
corpus rows and the per-kind sharded payload (row-sharded flat
vectors/PQ codes, cell-sharded IVF/IVF-PQ posting structures; projection,
centroids, and codebook factorizations replicated — see
``IndexOps.shard_payload``). ``sharded_search_fn`` then runs the same
fused pipeline under ``shard_map``: each shard probes (replicated math —
identical on every shard), scans only the rows/cells it owns, keeps a
local top-n_cand with **global** row ids via its shard offset, and the
shards finish with an ``all_gather`` + global top-k merge and a masked
exact re-rank in which each shard gathers only the winning candidates it
owns (``psum``-free: a ``pmin`` combines the per-shard masked distances).
The merge keeps the exact candidate set of the single-device program, so
sharded and single-device serving return identical neighbors; the
single-device path itself is untouched. The jit cache keys on the mesh
(shape + devices), so resizing the fleet recompiles exactly once per
shape.

Streaming (mutable) serving
---------------------------

``engine.streaming(StreamConfig(...))`` (or the declarative
``ServeConfig(stream=...)``) enables the write path: the built index
becomes the frozen **base** layer of a ``repro.search.segments.StreamStore``
(fixed row capacity + posting-list pad slack + tombstone bitmap) with a
fixed-capacity exact-scan **delta segment** on top.
``SearchEngine.upsert/delete/compact`` are pure donated-jit programs over
that store — no recompiles per write — and ``search`` routes through
``repro.search.stream.stream_search_fn`` (or its sharded twin: base
sharded, delta/tombstones replicated).

Durability & maintenance (``repro.search.durability``):
``engine.durable(dir)`` opens a write-ahead log that every mutation
appends to before it runs, so ``load_engine(dir)`` replays the tail on
top of the newest snapshot and recovers the exact pre-crash store;
``StreamConfig(background_compact=True)`` double-buffers compaction
(searches keep serving the old store until the atomic swap); a
``MaintenancePolicy`` (``StreamConfig(policy=PolicyConfig(...))``)
watches tombstone density, capacity headroom, and quantizer drift and
triggers ``vacuum``/grow/``rebuild_quantizers`` — every decision logged
to the WAL for deterministic replay. ``engine.metrics()`` surfaces the
counters; ``engine.tracing()`` (``repro.search.tracing``) adds latency
histograms, sampled deep traces, slow-query capture, and online recall
estimation on top.

Index kinds (``IndexSpec.kind`` / ``ServeConfig.index``):

  "flat"   exact scan of the (reduced) vectors
  "ivf"    k-means coarse quantizer, probe nprobe cells, exact cell scan
  "pq"     product-quantized vectors, fused ADC scan
  "ivfpq"  coarse quantizer + PQ-coded residuals, probed ADC scan — the
           production memory-hierarchy composition

The ``Code`` stage's ``lut_dtype`` ("f32" | "bf16" | "int8") quantizes the
per-query ADC lookup tables of the pq/ivfpq scans (see
``repro.kernels.pq_adc.lut``).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import MPADConfig
from repro.kernels.pq_adc.lut import LUT_DTYPES, lut_error_bound
from .durability.wal import (RT_COMPACT, RT_DELETE, RT_POLICY, RT_UPSERT,
                             encode_delete, encode_policy, encode_upsert)
from .reducers import Reducer, fit_reducer, reduce_vectors
from .registry import INDEX_KINDS, Index, ScanParams, get_ops
from .segments import StreamConfig
from .spec import IndexSpec, parse_spec, spec_from_config

__all__ = ["ServeConfig", "SearchEngine", "EngineState",
           "ShardedEngineState", "StreamConfig", "search_fn",
           "sharded_search_fn", "exact_rerank", "INDEX_KINDS",
           "build_engine", "config_from_spec"]

_ADC_BACKENDS = ("jnp", "kernel")
_SEARCH_STATICS = ("k", "nprobe", "rerank", "backend", "interpret",
                   "lut_dtype", "scan_cap", "prefilter")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The flat (legacy) engine config: pipeline knobs + runtime knobs.

    The pipeline part lowers onto an ``IndexSpec`` (``spec_from_config``)
    — which is also where cross-knob validation happens: ``nprobe`` may
    not exceed ``nlist``, and knobs whose stage is absent from the
    selected pipeline (e.g. ``nlist`` under ``index="pq"``) are rejected
    instead of silently ignored. Prefer building engines from a spec
    (``build_engine(corpus, "qpad32>ivf64x8>pq8x256:i8")``); construct a
    ``ServeConfig`` directly when you need the runtime knobs too.
    """
    target_dim: Optional[int] = None     # None = no reduction (full-dim exact)
    reducer: str = "qpad"                # Reduce-stage kind (REDUCER_KINDS):
    #                                      "qpad" | "pca" | "mlp" | registered
    rerank: int = 64                     # candidates re-ranked in original space
    index: str = "flat"                  # one of INDEX_KINDS
    nlist: int = 64                      # ivf/ivfpq: coarse cells
    nprobe: int = 8                      # ivf/ivfpq: cells probed per query
    pq_subspaces: int = 8                # pq/ivfpq: code bytes per vector
    pq_centroids: int = 256              # pq/ivfpq: codebook size per subspace
    pq_backend: str = "jnp"              # ADC scoring: "jnp" | "kernel"
    pq_interpret: bool = True            # kernel backend: Pallas interpret
    #                                      mode (set False on real TPU)
    lut_dtype: str = "f32"               # ADC LUT precision: f32 | bf16 | int8
    query_bucket: int = 64               # min padded query-batch size; ragged
    #                                      batches round up to powers of two
    small_batch: int = 8                 # batches <= this take their own
    #                                      power-of-two bucket instead of the
    #                                      query_bucket floor (0 disables)
    compact_batch: int = 64              # ivfpq read-only engines: buckets
    #                                      <= this take the nprobe-
    #                                      proportional compact scan when the
    #                                      posting-mass bound beats the padded
    #                                      gather; returned ids stay
    #                                      bit-identical (0 disables)
    prefilter_batch: int = 0             # ivfpq read-only engines without a
    #                                      projection: buckets <= this shrink
    #                                      the exact re-rank to certified ADC
    #                                      survivors. Ids stay bit-identical,
    #                                      but it only pays when the PQ
    #                                      reconstruction error is small next
    #                                      to neighbor gaps (else the bound
    #                                      admits everyone and the full-width
    #                                      fallback runs anyway), so it is
    #                                      opt-in (0 disables, the default)
    mpad: Optional[MPADConfig] = None    # defaults derived from target_dim
    fit_sample: int = 2048               # rows used to fit the projection
    seed: int = 0
    stream: Optional[StreamConfig] = None  # enable the mutable write path
    #                                        (delta segment + tombstones +
    #                                        compaction; see search/stream.py)
    # removed boolean index spec (PR-1 deprecation cycle complete): any
    # value raises with a pointer to the spec grammar
    use_ivf: Optional[bool] = None
    use_pq: Optional[bool] = None

    def __post_init__(self):
        if self.use_ivf is not None or self.use_pq is not None:
            raise ValueError(
                "ServeConfig(use_ivf=/use_pq=) was removed after its "
                "deprecation cycle; select the pipeline with "
                "ServeConfig(index='ivf'|'pq'|'ivfpq') or an index-spec "
                "string such as 'qpad32>ivf64x8>pq8x256:i8' "
                "(repro.search.parse_spec)")
        if self.index not in INDEX_KINDS:
            raise ValueError(
                f"unknown index kind {self.index!r}; expected one of "
                f"{INDEX_KINDS}")
        if self.pq_backend not in _ADC_BACKENDS:
            raise ValueError(
                f"unknown pq_backend {self.pq_backend!r}; expected one of "
                f"{_ADC_BACKENDS}")
        if self.lut_dtype not in LUT_DTYPES:
            raise ValueError(
                f"unknown lut_dtype {self.lut_dtype!r}; expected one of "
                f"{LUT_DTYPES}")
        if self.query_bucket < 1:
            raise ValueError("query_bucket must be >= 1")
        if self.small_batch < 0:
            raise ValueError("small_batch must be >= 0 (0 disables the "
                             "small-batch bucket floor path)")
        if self.compact_batch < 0:
            raise ValueError("compact_batch must be >= 0 (0 disables the "
                             "compact small-batch scan)")
        if self.prefilter_batch < 0:
            raise ValueError("prefilter_batch must be >= 0 (0 disables the "
                             "re-rank candidate pre-filter)")
        if (self.stream is not None and self.index in ("pq", "opq")
                and self.pq_backend == "kernel"):
            raise ValueError(
                f"streaming index={self.index!r} needs pq_backend='jnp': "
                "the shared-codes Pallas kernel has no masked entry point "
                "for an arbitrary tombstone bitmap (use index='ivfpq' for "
                "a kernel-backed streaming ADC scan)")
        # stage-level validation: lower onto the pipeline spec (rejects
        # nprobe > nlist, dead knobs, bad stage values)
        self.to_spec()

    def to_spec(self) -> IndexSpec:
        """Lower this config onto its pipeline spec (validating)."""
        return spec_from_config(self)


def config_from_spec(spec, **runtime) -> ServeConfig:
    """Lower an ``IndexSpec`` (or spec string) onto a ``ServeConfig``.

    ``runtime`` forwards the engine knobs a pipeline spec does not carry
    (``query_bucket``, ``small_batch``, ``mpad``, ``fit_sample``,
    ``seed``, ``pq_interpret``, ``stream``). Round-trips with
    ``ServeConfig.to_spec``.
    """
    if isinstance(spec, str):
        spec = parse_spec(spec)
    if not isinstance(spec, IndexSpec):
        raise TypeError(f"IndexSpec or spec string expected, got "
                        f"{type(spec).__name__}")
    kw = dict(index=spec.kind, rerank=spec.rerank.n)
    if spec.reduce is not None:
        kw["target_dim"] = spec.reduce.m
        kw["reducer"] = spec.reduce.kind
    if spec.coarse is not None:
        kw.update(nlist=spec.coarse.nlist, nprobe=spec.coarse.nprobe)
    if spec.code is not None:
        kw.update(pq_subspaces=spec.code.subspaces,
                  pq_centroids=spec.code.centroids,
                  lut_dtype=spec.code.lut_dtype,
                  pq_backend=spec.code.backend)
    kw.update(runtime)
    return ServeConfig(**kw)


def as_serve_config(config) -> ServeConfig:
    """Accept a ServeConfig, an IndexSpec, or a spec string everywhere a
    config is expected."""
    if isinstance(config, ServeConfig):
        return config
    if isinstance(config, (str, IndexSpec)):
        return config_from_spec(config)
    raise TypeError(
        "expected a ServeConfig, an IndexSpec, or a spec string like "
        f"'qpad32>ivf64x8>pq8x256:i8'; got {type(config).__name__}")


class EngineState(NamedTuple):
    """Everything ``search_fn`` needs, as one immutable pytree.

    ``index`` is the tagged union: ``index.kind`` selects the registered
    ``IndexOps`` (static under jit — it rides the treedef), ``index.payload``
    is that kind's built arrays. ``corpus`` is the original-space row store
    for the exact re-rank; ``proj`` the (optional) fitted Reduce stage —
    a ``repro.search.reducers.Reducer`` tagged union whose ``kind`` is
    pytree metadata, exactly like ``index.kind``.
    """
    corpus: jax.Array                              # (N, D) re-rank space
    proj: Optional[Reducer]                        # fitted Reduce stage
    index: Index                                   # tagged union payload


class ShardedEngineState(NamedTuple):
    """``EngineState`` re-laid-out for data-parallel serving on a mesh.

    ``corpus`` is padded to a per-shard-equal shape and sharded along dim
    0; ``index`` holds the kind's **sharded** payload (see
    ``IndexOps.shard_payload`` — row- or cell-sharded database leaves,
    replicated quantizers); the reducer params replicate. Built by
    ``repro.parallel.engine.shard_engine``; consumed by
    ``sharded_search_fn``. ``n_real`` is the unpadded corpus size — rows
    at or beyond it are shard padding, masked out of every scan.
    """
    corpus: jax.Array                              # (N_pad, D) row-sharded
    proj: Optional[Reducer]                        # replicated reducer params
    n_real: jax.Array                              # () int32 replicated
    index: Index                                   # kind + sharded payload


def _dedupe_candidates(cand: jax.Array):
    """Collapse duplicate candidate ids to -1: sort (pads sort first) +
    neighbor compare. Returns (cand sorted/deduped, valid mask). Shared by
    the single-device and sharded re-ranks — their parity depends on running
    the identical prologue."""
    cand = jnp.sort(cand, axis=1)                        # pads (-1) sort first
    dup = jnp.concatenate(
        [jnp.zeros_like(cand[:, :1], bool), cand[:, 1:] == cand[:, :-1]],
        axis=1)
    cand = jnp.where(dup, -1, cand)
    return cand, cand >= 0


def exact_rerank(queries: jax.Array, corpus: jax.Array, cand: jax.Array,
                 k: int):
    """Re-score candidate ids in the original space; top-k of the survivors.

    ``cand`` (Q, C) may contain -1 pads and duplicate ids (over-retrieval
    across probes): duplicates are collapsed to -1 first (sort + neighbor
    compare), then a single masked gather pulls each surviving row once and
    pads/dups are held out of the top-k with +inf.
    """
    cand, valid = _dedupe_candidates(cand)
    cv = jnp.take(corpus, jnp.where(valid, cand, 0), axis=0)   # (Q, C, D)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def _prefiltered_rerank(state: EngineState, queries: jax.Array,
                        qr: jax.Array, d_scan: jax.Array, cand: jax.Array,
                        k: int, r_s: int, lut_dtype: str):
    """Exact re-rank behind the in-scan candidate pre-filter.

    The ivfpq ADC scan already scored every candidate; with no projection
    the scan space IS the re-rank space, so per-candidate bounds on the
    true distance d = ||q - x|| follow from the stored per-row PQ
    reconstruction error ``rerr = ||x - x̂||`` (triangle inequality) plus
    the LUT quantization bound b (``lut_error_bound``; 0 for f32):

        LB = max(0, sqrt(max(d2 - b, 0)) - rerr) <= d
        UB = sqrt(d2 + b) + rerr                 >= d

    The k-th smallest UB is a certified threshold W >= d_(k): any
    candidate with LB > W has d > d_(k) strictly and cannot be a true
    top-k member (ties at d_(k) always satisfy LB <= d = d_(k) <= W, so
    the tie-break pool is preserved and the returned IDS are
    bit-identical; distances can wiggle by reduction-order ULPs since the
    narrower gather vectorizes the feature sum differently).
    When every query's survivor count fits the static width ``r_s``, the
    survivors are stably compacted left and the exact gather runs r_s
    wide instead of rerank wide — the stage that dominates small batches.
    Otherwise (rare: W is loose only when rerr is large) the full-width
    re-rank runs unchanged.
    """
    ix = state.index.payload
    n = state.corpus.shape[0]
    valid = cand >= 0
    rerr = ix.rerr[jnp.clip(cand, 0, n - 1)]                # (Q, C)
    if lut_dtype != "f32":
        # same matmul + (int8) scale the scan ran on the same operands
        # (``ivfpq_lut_stats``) — XLA CSEs the repeats, and the bound is
        # computed on exactly the grid the scan quantized onto: the raw
        # tables for bf16 (relative rounding, no centering), the analytic
        # centered scale for int8
        from .ivfpq import ivfpq_lut_stats
        from .pq import adc_tables
        tables = adc_tables(ix.lut_w, ix.cbnorm, qr)
        scale = None
        if lut_dtype == "int8":
            _, scale = ivfpq_lut_stats(ix.codebooks, ix.cbnorm, qr,
                                       lut_dtype)
        b = lut_error_bound(tables, lut_dtype, scale)[:, None]    # (Q, 1)
    else:
        b = jnp.zeros((1, 1), jnp.float32)
    d2 = jnp.square(d_scan)
    ub = jnp.sqrt(jnp.maximum(d2 + b, 0.0)) + rerr
    lb = jnp.maximum(jnp.sqrt(jnp.maximum(d2 - b, 0.0)) - rerr, 0.0)
    ub = jnp.where(valid, ub, jnp.inf)
    negk, _ = jax.lax.top_k(-ub, k)
    w = -negk[:, -1:]                                       # (Q, 1) = W
    # relative slack absorbs the sqrt/square round-trips; slack only KEEPS
    # extra candidates, never drops more — safety is one-sided
    keep = valid & (lb <= w + 1e-3 * (1.0 + jnp.abs(w)))

    def _tight(_):
        order = jnp.argsort(~keep, axis=1, stable=True)[:, :r_s]
        cc = jnp.take_along_axis(cand, order, axis=1)
        kk = jnp.take_along_axis(keep, order, axis=1)
        return exact_rerank(queries, state.corpus,
                            jnp.where(kk, cc, -1), k)

    def _full(_):
        return exact_rerank(queries, state.corpus, cand, k)

    fits = jnp.max(jnp.sum(keep.astype(jnp.int32), axis=1)) <= r_s
    return jax.lax.cond(fits, _tight, _full, None)


def _check_rerank_budget(approximate: bool, rerank: int, k: int):
    if approximate and rerank < k:
        raise ValueError(
            f"k={k} exceeds the re-rank budget rerank={rerank} on an "
            "approximate pipeline (reduction and/or PQ codes): the exact "
            "re-rank could only return rerank candidates. Raise the "
            f"Rerank stage (e.g. spec '...>rr{k}') or lower k.")


def search_fn(state: EngineState, queries: jax.Array, k: int, *,
              nprobe: int = 8, rerank: int = 64, backend: str = "jnp",
              interpret: bool = True, lut_dtype: str = "f32",
              scan_cap: int = 0, prefilter: int = 0):
    """The entire query pipeline as one pure traceable function.

    project -> probe/scan (dispatched on ``state.index.kind`` through the
    ops registry) -> exact re-rank -> top-k. Jitted
    (``jax.jit(search_fn, static_argnames=_SEARCH_STATICS)``) this is
    a single XLA program; the index kind is pytree aux data, so it keys
    the compile cache without being an argument. Every per-query op is
    row-independent, so padded query rows never perturb real results.
    Returns (dists (Q,k), ids (Q,k)); distances in the original space when
    re-ranking is active, else in the serving (reduced) space.

    ``scan_cap > 0`` (ivfpq) sizes the candidate gather by actual posting
    mass instead of ``nprobe * max_cell`` (``ivfpq_compact_scan``);
    ``prefilter > 0`` (ivfpq, no projection) shrinks the exact re-rank to
    that many certified survivors (``_prefiltered_rerank``). Both are
    engaged by ``SearchEngine`` for small buckets and keep the returned
    ids bit-identical to the defaults (the compact scan keeps distances
    bit-identical too; the pre-filter's narrower re-rank gather can move
    distances by reduction-order ULPs).
    """
    ops = get_ops(state.index.kind)
    queries = jnp.asarray(queries, jnp.float32)
    # named_scope annotations label the stage boundaries inside the fused
    # program for jax.profiler / Perfetto timelines (see
    # repro.search.tracing); they are free at run time
    with jax.named_scope("qpad.project"):
        qr = reduce_vectors(state.proj, queries)
    # lossy scoring (reduction and/or PQ codes) -> over-retrieve + re-rank
    approximate = state.proj is not None or ops.lossy
    _check_rerank_budget(approximate, rerank, k)
    n_cand = rerank if approximate else k
    p = ScanParams(nprobe=nprobe, backend=backend, interpret=interpret,
                   lut_dtype=lut_dtype, scan_cap=scan_cap)
    with jax.named_scope("qpad.scan"):
        d_scan, cand = ops.scan(state, qr, n_cand, p)
    if prefilter > 0:
        if state.index.kind != "ivfpq" or state.proj is not None:
            raise ValueError(
                "prefilter needs an ivfpq index with no Reduce stage: the "
                "certified distance bounds require the scan space to be "
                "the re-rank space")
        if prefilter < n_cand:
            with jax.named_scope("qpad.rerank"):
                return _prefiltered_rerank(state, queries, qr, d_scan,
                                           cand, k, prefilter, lut_dtype)
    with jax.named_scope("qpad.rerank"):
        return exact_rerank(queries, state.corpus, cand, k)


# --- sharded serving (shard_map over a database-axis mesh) -------------------

def _sharded_rerank(queries: jax.Array, corpus_loc: jax.Array,
                    cand: jax.Array, k: int, axis: str):
    """``exact_rerank`` with the corpus row-sharded: the same sort + dedupe
    runs replicated, then each shard gathers and scores only the candidates
    it owns and a ``pmin`` over the mesh axis assembles the full exact
    distance row (every candidate is owned by exactly one shard) — only the
    k winners' rows are ever touched on any device."""
    cand, valid = _dedupe_candidates(cand)
    n_loc = corpus_loc.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    local = cand - off
    own = valid & (local >= 0) & (local < n_loc)
    cv = jnp.take(corpus_loc, jnp.clip(local, 0, n_loc - 1), axis=0)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(own, d2, jnp.inf)
    d2 = jax.lax.pmin(d2, axis)                          # (Q, C) replicated
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def _sharded_core(sstate: ShardedEngineState, queries: jax.Array, *, k: int,
                  nprobe: int, rerank: int, backend: str,
                  interpret: bool, lut_dtype: str, axis: str, slack: int,
                  scan_cap: int = 0, prefilter: int = 0):
    """The shard_map body: the full per-shard pipeline + distributed merge."""
    if scan_cap or prefilter:
        raise ValueError(
            "scan_cap/prefilter are single-device read-only fast paths: "
            "the compact scan sizes on the unsharded posting mass and the "
            "pre-filter bounds assume the full candidate row — leave both "
            "0 on the sharded path")
    ops = get_ops(sstate.index.kind)
    queries = jnp.asarray(queries, jnp.float32)
    with jax.named_scope("qpad.project"):
        qr = reduce_vectors(sstate.proj, queries)
    approximate = sstate.proj is not None or ops.lossy
    _check_rerank_budget(approximate, rerank, k)
    n_cand = rerank if approximate else k
    p = ScanParams(nprobe=nprobe, backend=backend, interpret=interpret,
                   lut_dtype=lut_dtype)
    with jax.named_scope("qpad.scan"):
        d2, cand = ops.local_scan(sstate, qr, n_cand, p, axis, slack)
    # distributed merge: every shard's local top-n_cand is a superset of the
    # global top-n_cand members it owns, so the merged set equals the
    # single-device candidate set exactly
    with jax.named_scope("qpad.merge"):
        d2g = jax.lax.all_gather(d2, axis, axis=1, tiled=True)  # (Q, S*n_cand)
        idg = jax.lax.all_gather(cand, axis, axis=1, tiled=True)
        neg, sel = jax.lax.top_k(-d2g, n_cand)
        merged = jnp.take_along_axis(idg, sel, axis=1)
        merged = jnp.where(jnp.isneginf(neg), -1, merged)
    with jax.named_scope("qpad.rerank"):
        return _sharded_rerank(queries, sstate.corpus, merged, k, axis)


def sharded_search_fn(sstate: ShardedEngineState, queries: jax.Array, k: int,
                      *, mesh: Mesh, axis: str = "data",
                      nprobe: int = 8, rerank: int = 64, backend: str = "jnp",
                      interpret: bool = True, lut_dtype: str = "f32",
                      scan_cap: int = 0, prefilter: int = 0):
    """``search_fn`` partitioned over the ``axis`` of ``mesh``.

    Same contract and — by construction of the distributed merge — the same
    results as the single-device ``search_fn`` on the unsharded state.
    Jit with ``mesh``/``axis`` static (``Mesh`` hashes by shape + devices,
    which is exactly what the compile cache must key on).
    """
    from repro.parallel.sharding import engine_state_specs
    specs = engine_state_specs(sstate, axis)
    core = functools.partial(
        _sharded_core, k=k, nprobe=nprobe, rerank=rerank,
        backend=backend, interpret=interpret, lut_dtype=lut_dtype, axis=axis,
        slack=mesh.shape[axis] - 1, scan_cap=scan_cap, prefilter=prefilter)
    f = shard_map(core, mesh=mesh, in_specs=(specs, P()),
                  out_specs=(P(), P()), check_rep=False)
    return f(sstate, queries)


def _bucket(nq: int, floor: int, small: int = 0) -> int:
    """Smallest power-of-two >= nq, floored at ``floor`` — except batches of
    at most ``small``, which take their own power-of-two bucket so tiny
    batches run a compute-proportional program instead of padding to the
    floor (the small-batch latency cliff; ``small=0`` disables)."""
    pow2 = 1 << max(nq - 1, 0).bit_length()
    if 0 < nq <= small:
        return pow2
    return max(floor, pow2)


class SearchEngine:
    """Build once over a corpus; serve batched k-NN queries.

    Thin wrapper over the functional core: construction builds
    ``self.state`` (an ``EngineState``), ``search`` pads the batch to its
    bucket and calls the engine-owned jitted ``search_fn``. The config may
    be a ``ServeConfig``, an ``IndexSpec``, or a spec string. Mutating
    ``self.config`` (e.g. ``dataclasses.replace(..., nprobe=16)``) is
    supported — knob changes re-key the jit cache, not the state.

    Lifecycle methods: ``shard(mesh)`` partitions the state over a device
    mesh, ``streaming(StreamConfig(...))`` enables the mutable write path
    (``upsert``/``delete``/``compact``), ``save(dir)`` snapshots spec +
    arrays (restore with ``repro.search.load_engine``).
    """

    def __init__(self, corpus: jax.Array, config=ServeConfig()):
        config = as_serve_config(config)
        spec = config.to_spec()
        corpus_in = corpus
        corpus = jnp.asarray(corpus, jnp.float32)
        # when the caller's array passes through unconverted, it stays
        # caller-owned: shard(donate=True) must not delete it
        self._user_corpus = corpus if corpus is corpus_in else None
        n, dim = corpus.shape
        key = jax.random.key(config.seed)
        if spec.reduce is not None:
            mcfg = config.mpad
            if mcfg is None and spec.reduce.kind == "qpad":
                mcfg = MPADConfig(
                    m=spec.reduce.m, b=80.0, alpha=25.0, iters=48,
                    seed=config.seed)
            sample = corpus
            if config.fit_sample < n:
                rows = jax.random.choice(
                    key, n, (config.fit_sample,), replace=False)
                sample = corpus[rows]
            proj: Optional[Reducer] = fit_reducer(
                spec.reduce.kind, key, sample, spec.reduce.m, mcfg)
            reduced = reduce_vectors(proj, corpus)
        else:
            proj = None
            reduced = corpus
        payload = get_ops(config.index).build(key, reduced, spec)
        state = EngineState(corpus=corpus, proj=proj,
                            index=Index(config.index, payload))
        self._attach(config, state, proj)

    # --- lifecycle --------------------------------------------------------

    def _attach(self, config: ServeConfig, state: Optional[EngineState],
                reducer: Optional[Reducer], store=None, frozen=None):
        """Wire a built (or restored) state into a serving engine: jit
        programs, compile caches, counters. The shared tail of ``__init__``
        and the snapshot-restore constructors."""
        self._user_corpus = getattr(self, "_user_corpus", None)
        self.config = config
        self.reducer = reducer
        self.state: Optional[EngineState] = state
        self.last_bucket: Optional[int] = None   # padded size of the last
        #                                          served batch (shape pin
        #                                          for latency tests)
        self._scan_caps: dict = {}   # nprobe -> compact-scan gather width
        #                              (host-side, cached: one posting-mass
        #                              sync per nprobe per engine)
        self.sharded_state: Optional[ShardedEngineState] = None
        self._mesh: Optional[Mesh] = None
        self._shard_axis = "data"
        self._sharded_program = None
        # engine-owned jit: a fresh closure gives this engine its own
        # compilation cache (jax shares caches for identical function
        # objects), keyed by (statics, query bucket)
        def _engine_search_fn(state, queries, k, **kw):
            return search_fn(state, queries, k, **kw)
        self._program = jax.jit(_engine_search_fn,
                                static_argnames=_SEARCH_STATICS)
        self.store, self.frozen = store, frozen  # streaming (write) state
        self._stream_sharded_base = None
        self._stream_program = self._stream_sharded_program = None
        self._upsert_program = self._delete_program = None
        self._compact_program = None
        self.grow_count = 0          # compaction-overflow regrowths (rare;
        #                              each one is a recompile point)
        self._delta_used = 0         # conservative host mirror of the delta
        #                              fill (overwrites counted as appends)
        # durability + maintenance (repro.search.durability)
        self.crash_hook = None       # optional callable(point_name) fired at
        #                              named lifecycle points ("wal_appended",
        #                              "compact_begin", "compact_task",
        #                              "compact_swap", "compact_done",
        #                              "vacuum", "rebuild") — plug
        #                              FailureInjector.maybe_fail in for
        #                              crash drills, or block in it to
        #                              schedule background compaction
        self._replaying = False      # WAL replay in flight: appends and
        #                              policy auto-decisions disabled
        self._wal = None             # durability.wal.Wal once durable()
        self._durability = None      # its DurabilityConfig
        self._durable_dir = None     # snapshot+wal directory
        self._replayed = 0           # records applied by recovery
        # replication (repro.search.durability.replication)
        self._role = "primary"       # "follower" engines tail a shipped
        #                              WAL and reject local writes
        self._applied_seq = -1       # last WAL seq reflected in the store
        #                              (snapshot position + replay/catch-up)
        self._repl_catch_ups = 0     # catch_up passes completed
        self._repl_records = 0       # shipped records applied
        self._repl_source_tail = -1  # source tail at the last catch_up
        self._repl_last_catch_up_ts = None   # wall clock of the last
        #                              catch_up pass (staleness gauge)
        self._repl_caught_up_ts = None       # wall clock of the last
        #                              catch_up that drained the source
        #                              (replication.lag_seconds)
        # observability (repro.search.tracing): None until tracing() —
        # the serve path takes zero extra work without a tracer
        self._tracer = None
        self._deep_warm: set = set() # deep-trace stage shapes already
        #                              compiled (never time a compile)
        # incremental snapshots (repro.search.snapshot)
        self._base_ref = None        # the chain this engine can extend:
        #                              {dir, ckpt, wal_seq, chain} of the
        #                              newest full snapshot + incrementals
        self._base_dirty = False     # base arrays rewritten since the base
        #                              snapshot (compact/vacuum/rebuild/
        #                              grow): the next save must be full
        self._snap_counters = {"full": 0, "incremental": 0,
                               "last_bytes": 0, "chain_depth": 0}
        self._policy = None          # MaintenancePolicy (streaming engines)
        self._policy_active = False  # auto-decisions only when the user
        #                              configured StreamConfig.policy
        self._compact_future = None  # pending background compaction
        self._compact_executor = None
        self._compact_tail = []      # writes logged during the pending
        #                              compaction, re-applied at the swap
        self._tail_rows = 0
        self._counters = {"compactions": 0, "swaps": 0, "vacuums": 0,
                          "rebuilds": 0, "policy_grows": 0}
        if store is not None:        # restored mid-delta snapshot
            self._delta_used = int(store.delta_count)
            self._stream_programs()
            self._stream_policy_init()
        elif config.stream is not None:
            self._init_stream()

    @classmethod
    def _restore(cls, config: ServeConfig, *, state=None, store=None,
                 frozen=None) -> "SearchEngine":
        """Construct an engine around already-built arrays (snapshot
        restore): no MPAD refit, no index retrain. Exactly one of
        ``state`` (read-only) or ``store``+``frozen`` (streaming) is
        given; see ``repro.search.snapshot``."""
        eng = object.__new__(cls)
        eng._user_corpus = None
        proj = state.proj if state is not None else frozen.proj
        eng._attach(config, state, proj, store=store, frozen=frozen)
        return eng

    @property
    def spec(self) -> IndexSpec:
        """The pipeline spec this engine serves (lowered from the current
        config, so query-time knob mutations are reflected)."""
        return self.config.to_spec()

    def save(self, directory: str, incremental: bool = False) -> str:
        """Snapshot the engine (spec + config + arrays) into ``directory``;
        restore with ``repro.search.load_engine``. Covers read-only and
        streaming engines (the delta segment and tombstones are saved
        as-is, so a mid-delta snapshot restores mid-delta). Returns the
        checkpoint path.

        ``incremental=True`` persists only what changes between
        snapshots of a streaming engine — the delta segment, tombstone
        bitmap, id maps and WAL position — against the newest *full*
        snapshot already in ``directory`` (chained manifests;
        ``load_engine`` resolves the chain). Checkpoint cost stops
        scaling with base size, and the result doubles as the cheap
        re-seed artifact for followers. Requires a prior full ``save``
        to the same directory and a base untouched since (after a
        compaction / vacuum / rebuild / grow the next save must be
        full); incoherent calls raise with the fix spelled out."""
        from .snapshot import save_engine
        return save_engine(self, directory, incremental=incremental)

    @property
    def compile_count(self) -> int:
        """Number of compiled (statics, bucket) variants this engine holds
        (single-device + sharded + streaming read/write programs)."""
        progs = [self._program, self._sharded_program,
                 self._stream_program, self._stream_sharded_program,
                 self._upsert_program, self._delete_program,
                 self._compact_program]
        try:
            return sum(int(p._cache_size()) for p in progs if p is not None)
        except AttributeError as e:     # private jax hook; fail loudly if
            raise RuntimeError(          # an unpinned jax drops it
                "jax no longer exposes PjitFunction._cache_size(); "
                "SearchEngine.compile_count needs a replacement hook"
            ) from e

    # --- streaming (mutable) serving -------------------------------------

    def streaming(self, config: Optional[StreamConfig] = None
                  ) -> "SearchEngine":
        """Enable the mutable write path on a built engine: the dense
        index becomes the frozen base of a ``StreamStore`` with a delta
        segment + tombstones on top, and ``upsert``/``delete``/``compact``
        come alive. One-way and idempotent-hostile by design: call once,
        after build and before ``shard``. Returns ``self`` for chaining.
        (The declarative ``ServeConfig(stream=...)`` route does this at
        construction.)
        """
        if self.store is not None:
            raise RuntimeError(
                "this engine is already streaming; re-configure by "
                "rebuilding or load_engine from a snapshot")
        if self.sharded_state is not None:
            raise RuntimeError(
                "enable streaming BEFORE shard(): the store takes over "
                "the dense arrays, which would leave the placed sharded "
                "state stale (or, on a zero-copy placement, deleted) — "
                "rebuild, call streaming(...), then shard(mesh)")
        if self.state is None:
            raise RuntimeError(
                "the dense EngineState is gone (shard(donate=True)); "
                "streaming needs the dense arrays — rebuild the engine "
                "or load_engine from a snapshot")
        # replace() re-runs config validation (e.g. pq+kernel streaming)
        self.config = dataclasses.replace(
            self.config, stream=config or StreamConfig())
        self._init_stream()
        return self

    def _require_stream(self):
        if self.store is None:
            raise RuntimeError(
                "this engine is read-only; enable the write path with "
                "engine.streaming(StreamConfig(...)) or "
                "ServeConfig(stream=StreamConfig(...))")
        if self._role == "follower" and not self._replaying:
            from .durability.replication import ReplicationError
            raise ReplicationError(
                "this engine is a follower: its store is a replica of a "
                "primary's WAL and local writes would fork the history. "
                "Write to the primary and catch_up, or re-open the "
                "snapshot without role='follower' to promote it.")

    def _init_stream(self):
        from .segments import make_mutable
        self.store, self.frozen = make_mutable(self.state,
                                               self.config.stream)
        # the store owns fresh (capacity-padded) copies of every database
        # leaf, so the dense EngineState duplicates them — release the
        # duplicated buffers (the frozen quantizers and any caller-owned
        # corpus stay shared/alive) instead of holding 2x forever
        hold = {id(leaf) for leaf in jax.tree_util.tree_leaves(self.frozen)}
        if self._user_corpus is not None:
            hold.add(id(self._user_corpus))
        dense = {id(a): a for a in jax.tree_util.tree_leaves(self.state)}
        for leaf in dense.values():
            if id(leaf) not in hold and not leaf.is_deleted():
                leaf.delete()
        self.state = None
        self._stream_programs()
        self._stream_policy_init()

    def _stream_policy_init(self):
        """Create the MaintenancePolicy and (when the user configured one)
        seed its drift baseline: mean encode error of a sample of the base
        rows under the freshly trained frozen quantizers."""
        from .durability.policy import MaintenancePolicy
        scfg = self.config.stream
        self._policy = MaintenancePolicy(scfg.policy)
        self._policy_active = scfg.policy is not None
        if not self._policy_active:
            return
        ops = get_ops(self.config.index)
        n = int(self.store.n_rows)
        if ops.drift_stats is None or n == 0:
            return
        from .segments import _project
        rows = self.store.corpus[:min(n, 1024)]
        err = ops.drift_stats(self.frozen,
                              _project(self.frozen.proj, rows))
        self._policy.observe_build_error(float(jnp.mean(err)))

    def _stream_programs(self):
        """Jit the streaming read/write programs (fresh closures: per-engine
        compile caches, same as ``_program``). The write programs donate
        the store, so the ``.at[]`` updates alias the input buffers
        instead of copying the row store per write."""
        from .segments import compact_fn, delete_fn, upsert_fn
        from .stream import sharded_stream_search_fn, stream_search_fn

        def _engine_stream_fn(store, frozen, queries, k, **kw):
            return stream_search_fn(store, frozen, queries, k, **kw)
        self._stream_program = jax.jit(_engine_stream_fn,
                                       static_argnames=_SEARCH_STATICS)

        def _engine_upsert(store, frozen, ids, vectors):
            return upsert_fn(store, frozen, ids, vectors)
        self._upsert_program = jax.jit(_engine_upsert, donate_argnums=(0,))

        def _engine_delete(store, ids):
            return delete_fn(store, ids)
        self._delete_program = jax.jit(_engine_delete, donate_argnums=(0,))

        def _engine_compact(store, frozen):
            return compact_fn(store, frozen)
        self._compact_program = jax.jit(_engine_compact, donate_argnums=(0,))

        def _engine_stream_sharded(sbase, repl, queries, k, **kw):
            return sharded_stream_search_fn(sbase, repl, queries, k, **kw)
        self._stream_sharded_program = jax.jit(
            _engine_stream_sharded,
            static_argnames=_SEARCH_STATICS + ("mesh", "axis"))

    def _crash(self, point: str):
        if self.crash_hook is not None:
            self.crash_hook(point)

    def _wal_append(self, rtype: int, payload: bytes = b"", *,
                    wait: bool = True):
        """Log one record *before* the mutation it describes (no-op when
        the engine is not durable or is replaying its own log).
        ``wait=False`` defers the group-commit durability wait — a
        multi-chunk write batch waits once at the end
        (``_wal_wait_durable``) instead of once per chunk."""
        if self._wal is None or self._replaying:
            return
        self._wal.append(rtype, payload, wait=wait)
        self._crash("wal_appended")

    def _wal_wait_durable(self):
        """Batch-end durability point for ``wait=False`` appends (no-op
        outside group-commit mode)."""
        if self._wal is not None and not self._replaying:
            self._wal.wait_durable()

    def _pad_write(self, ids, vectors=None):
        """Pad a write batch up to its ``write_bucket`` bucket (-1 id
        pads are no-ops in the write programs)."""
        ids = jnp.asarray(ids, jnp.int32).reshape(-1)
        n = ids.shape[0]
        bucket = _bucket(n, self.config.stream.write_bucket)
        if bucket != n:
            ids = jnp.pad(ids, (0, bucket - n), constant_values=-1)
        if vectors is None:
            return ids, None
        vectors = jnp.asarray(vectors, jnp.float32).reshape(n, -1)
        if bucket != n:
            vectors = jnp.pad(vectors, ((0, bucket - n), (0, 0)))
        return ids, vectors

    def _compact_point(self) -> int:
        """Delta fill (rows) that triggers auto-compaction."""
        scfg = self.config.stream
        fill = scfg.compact_threshold
        if self._policy is not None and self._policy.config.delta_fill:
            fill = self._policy.config.delta_fill
        return max(1, min(scfg.delta_capacity,
                          int(fill * scfg.delta_capacity)))

    def _ensure_delta_room(self, chunk: int, cap: int, point: int):
        """Pre-write maintenance: compact (blocking or double-buffered)
        so the next ``chunk`` delta rows fit."""
        if self._compact_future is not None:
            if (self._delta_used + chunk > cap
                    or self._tail_rows + chunk > point):
                self.finish_compact()
            else:
                return      # the pending fold reclaims the delta at the swap
        if self._delta_used + chunk > point:
            if (self._compact_future is None
                    and self.config.stream.background_compact
                    and self._delta_used + chunk <= cap):
                self.begin_compact()
            else:
                self.compact()

    def upsert(self, ids: jax.Array, vectors: jax.Array):
        """Insert or overwrite rows by external id (ids (B,), vectors
        (B, D)). Pure in-place delta appends — no recompilation (batches
        pad to ``StreamConfig.write_bucket``-floored power-of-two buckets)
        and no index rebuild; the delta auto-compacts into the base at
        ``compact_threshold`` (double-buffered off-thread under
        ``StreamConfig(background_compact=True)``). On a durable engine
        each chunk is WAL-logged before it lands. Returns ``self``.
        """
        self._require_stream()
        self._poll_compaction()
        ids = np.asarray(ids, np.int32).reshape(-1)
        vectors = np.asarray(vectors, np.float32).reshape(ids.shape[0], -1)
        cap = self.config.stream.delta_capacity
        point = self._compact_point()
        b = 0
        while b < ids.shape[0]:
            chunk = min(ids.shape[0] - b, point)
            if not self._replaying:
                self._ensure_delta_room(chunk, cap, point)
            cid, cv = ids[b:b + chunk], vectors[b:b + chunk]
            self._wal_append(RT_UPSERT, encode_upsert(cid, cv), wait=False)
            if self._compact_future is not None:
                # the pending fold donated a pre-begin copy; replay this
                # write onto the folded store at the swap
                self._compact_tail.append(("upsert", cid.copy(), cv.copy()))
                self._tail_rows += chunk
            pid, pv = self._pad_write(cid, cv)
            # dropped stays 0 by construction (the chunking above never
            # exceeds the compact point), so it is not synced to host here
            self.store, _ = self._upsert_program(self.store, self.frozen,
                                                 pid, pv)
            self._delta_used += chunk
            b += chunk
        self._wal_wait_durable()     # one group-commit wait per batch
        return self

    def delete(self, ids: jax.Array):
        """Delete rows by external id: tombstone base copies, punch delta
        holes. Absent ids are no-ops. WAL-logged on a durable engine;
        with a configured ``StreamConfig.policy``, a dense-enough
        tombstone bitmap triggers ``vacuum`` (the reclaim path deletes
        alone never had). Returns ``self``."""
        self._require_stream()
        self._poll_compaction()
        ids = np.asarray(ids, np.int32).reshape(-1)
        self._wal_append(RT_DELETE, encode_delete(ids))
        if self._compact_future is not None:
            self._compact_tail.append(("delete", ids.copy(), None))
        pid, _ = self._pad_write(ids)
        self.store = self._delete_program(self.store, pid)
        if not self._replaying and self._policy_active:
            dead = int(jnp.sum(self.store.dead))
            decision = self._policy.decide_delete(
                dead=dead, allocated=int(self.store.n_rows))
            if decision.kind == "vacuum":
                self.vacuum()
        return self

    # --- compaction (blocking and double-buffered) ------------------------

    def _run_compact(self, store):
        """The fold + grow-retry loop over ``store`` (donated). Returns
        (folded store, grows)."""
        from .segments import grow_store
        scfg = self.config.stream
        store, dropped = self._compact_program(store, self.frozen)
        grows = 0
        while int(dropped):
            # one delta's worth of cell slack covers the worst case (every
            # delta row landing in one cell), so a single grow suffices
            store = grow_store(store,
                               row_extra=4 * scfg.delta_capacity,
                               cell_extra=scfg.delta_capacity)
            grows += 1
            store, dropped = self._compact_program(store, self.frozen)
        return store, grows

    def _compact_task(self, store):
        self._crash("compact_task")
        return self._run_compact(store)

    def _install_compacted(self, store, grows, tail, tail_rows):
        """Re-apply the tail writes recorded during the fold, then swap
        the folded store in atomically (a single reference assignment —
        searches observe the old store or the new one, never a mix)."""
        for kind, tids, tvecs in tail:
            pid, pv = self._pad_write(tids, tvecs)
            if kind == "upsert":
                store, _ = self._upsert_program(store, self.frozen, pid, pv)
            else:
                store = self._delete_program(store, pid)
        self._crash("compact_swap")
        self.store = store
        self._delta_used = tail_rows
        self._base_dirty = True      # the fold rewrote the base arrays
        self.grow_count += grows
        self._counters["compactions"] += 1
        self._counters["swaps"] += 1
        if self._stream_sharded_base is not None:
            self._shard_stream_base()        # re-lay the (grown) base out
        self._crash("compact_done")
        if not self._replaying:
            self._post_compact_maintenance()

    def compact(self):
        """Fold the delta segment into the base index (re-coding against
        the frozen quantizers — shapes and compiled programs survive),
        blocking until the swap. A pending ``begin_compact`` is finished
        first. On a durable engine the COMPACT barrier is logged before
        the fold, so recovery redoes an interrupted compaction.

        If the append would overflow the pre-allocated row capacity or a
        posting cell's slack, the store grows host-side and the compaction
        retries: correct, but a recompile point (``grow_count`` ticks) —
        size ``StreamConfig.row_capacity``/``cell_slack`` to avoid it.
        Returns ``self``.
        """
        self._require_stream()
        if self._compact_future is not None:
            self.finish_compact()
        self._observe_drift()
        self._wal_append(RT_COMPACT)
        self._crash("compact_begin")
        store, grows = self._run_compact(self.store)
        self._install_compacted(store, grows, (), 0)
        return self

    def begin_compact(self):
        """Start a double-buffered compaction: fold a *copy* of the store
        on a worker thread while searches (and further writes) keep
        serving the live store; ``finish_compact`` (or the automatic poll
        at the next search/write once the fold is done) re-applies the
        writes that landed meanwhile and swaps atomically. No-op if a
        compaction is already pending. Returns ``self``."""
        self._require_stream()
        if self._compact_future is not None:
            return self
        self._observe_drift()
        self._wal_append(RT_COMPACT)
        self._crash("compact_begin")
        snapshot = jax.tree.map(jnp.array, self.store)   # the double buffer
        self._compact_tail = []
        self._tail_rows = 0
        if self._compact_executor is None:
            from concurrent.futures import ThreadPoolExecutor
            self._compact_executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="qpad-compact")
        self._compact_future = self._compact_executor.submit(
            self._compact_task, snapshot)
        return self

    def finish_compact(self):
        """Complete a pending ``begin_compact``: wait for the fold,
        re-apply the tail writes, swap. No-op without one. Returns
        ``self``."""
        self._require_stream()
        fut = self._compact_future
        if fut is None:
            return self
        try:
            store, grows = fut.result()
        finally:
            self._compact_future = None
        tail, self._compact_tail = self._compact_tail, []
        rows, self._tail_rows = self._tail_rows, 0
        self._install_compacted(store, grows, tail, rows)
        return self

    def _poll_compaction(self):
        """Swap in a background compaction that has finished folding —
        called at every search/write entry, so the swap needs no timer."""
        fut = self._compact_future
        if fut is not None and fut.done():
            self.finish_compact()

    # --- maintenance policy ----------------------------------------------

    def _lut_noise_floor(self) -> float:
        """The smallest drift worth acting on: below the LUT
        quantization error bound the coded scan could not express the
        difference anyway."""
        cb = self.frozen.cbnorm if self.frozen is not None else None
        if cb is None or self.config.index not in ("pq", "opq", "ivfpq"):
            return 0.0
        from repro.kernels.pq_adc.lut import lut_error_bound
        return float(lut_error_bound(cb[None], self.config.lut_dtype)[0])

    def _observe_drift(self):
        """Feed the encode error of the delta rows about to be folded
        into the policy's drift estimate."""
        if not self._policy_active or self._replaying:
            return
        ops = get_ops(self.config.index)
        if ops.drift_stats is None:
            return
        store = self.store
        rows = (store.delta_reduced if store.delta_reduced is not None
                else store.delta_vectors)
        cap = store.delta_ids.shape[0]
        alive = (jnp.arange(cap) < store.delta_count) & (store.delta_ids >= 0)
        n = int(jnp.sum(alive))
        if n == 0:
            return
        err = ops.drift_stats(self.frozen, rows)
        self._policy.observe_encode_error(
            float(jnp.sum(jnp.where(alive, err, 0.0))) / n, n)

    def _post_compact_maintenance(self):
        """Run the post-compaction policy decision (grow / rebuild)."""
        if not self._policy_active:
            return
        scfg = self.config.stream
        free = int(self.store.corpus.shape[0]) - int(self.store.n_rows)
        decision = self._policy.decide_post_compact(
            free_rows=free, delta_capacity=scfg.delta_capacity,
            noise_floor=self._lut_noise_floor())
        if decision.kind == "grow":
            from .segments import grow_store
            self._wal_append(RT_POLICY, encode_policy(
                {"decision": "grow", **decision.params}))
            self.store = grow_store(self.store, **decision.params)
            self._base_dirty = True
            self._counters["policy_grows"] += 1
            if self._stream_sharded_base is not None:
                self._shard_stream_base()
        elif decision.kind == "rebuild":
            self.rebuild_quantizers()

    def _gather_live(self):
        """Host-side gather of every live row (base survivors in row
        order, then live delta rows in slot order — a deterministic
        order, so WAL replay of vacuum/rebuild reproduces the store
        exactly). Returns (vectors (L, D) f32, external ids (L,) i32)."""
        store = self.store
        row_ids = np.asarray(store.row_ids)
        live = (row_ids >= 0) & ~np.asarray(store.dead)
        cap = store.delta_ids.shape[0]
        dids = np.asarray(store.delta_ids)
        alive = (np.arange(cap) < int(store.delta_count)) & (dids >= 0)
        vectors = np.concatenate([np.asarray(store.corpus)[live],
                                  np.asarray(store.delta_vectors)[alive]])
        ext = np.concatenate([row_ids[live], dids[alive]]).astype(np.int32)
        return vectors, ext

    def vacuum(self):
        """Reclaim tombstoned rows: rewrite the base over the live rows
        (delta folded in) against the FROZEN quantizers — no retraining.
        The masked scan stops paying for dead rows; shapes shrink back to
        ``StreamConfig`` capacities, so the write programs recompile once
        (rare by construction: the tombstone-density policy gates it).
        WAL-logged as a policy decision. Returns ``self``."""
        self._require_stream()
        if self._compact_future is not None:
            self.finish_compact()
        self._wal_append(RT_POLICY, encode_policy({"decision": "vacuum"}))
        self._crash("vacuum")
        self._do_vacuum()
        return self

    def _do_vacuum(self):
        from .segments import make_mutable, rebuild_state
        vectors, ext = self._gather_live()
        state = rebuild_state(self.frozen, vectors)
        store, frozen = make_mutable(state, self.config.stream)
        store = store._replace(row_ids=store.row_ids.at[:len(ext)].set(
            jnp.asarray(ext)))
        self.store, self.frozen = store, frozen
        self._delta_used = 0
        self._base_dirty = True
        self._counters["vacuums"] += 1
        if self._stream_sharded_base is not None:
            self._shard_stream_base()

    def rebuild_quantizers(self, seed: Optional[int] = None):
        """Full quantizer retrain over the live rows through the ordinary
        build path (new MPAD fit + index train, fresh drift baseline),
        keeping external ids. The drift-policy escape hatch for when the
        frozen quantizers no longer fit the data; every compiled program
        re-keys (new constants), so this is the expensive, rare op the
        whole streaming design exists to avoid needing often. WAL-logged
        with its seed for deterministic replay. Returns ``self``."""
        self._require_stream()
        if self._compact_future is not None:
            self.finish_compact()
        if seed is None:
            seed = self.config.seed + 1 + self._counters["rebuilds"]
        self._wal_append(RT_POLICY, encode_policy(
            {"decision": "rebuild", "seed": int(seed)}))
        self._crash("rebuild")
        self._do_rebuild(int(seed))
        return self

    def _do_rebuild(self, seed: int):
        vectors, ext = self._gather_live()
        cfg = dataclasses.replace(self.config, seed=seed)
        fresh = SearchEngine(vectors, cfg)
        store = fresh.store._replace(
            row_ids=fresh.store.row_ids.at[:len(ext)].set(jnp.asarray(ext)))
        decisions = self._policy.decisions if self._policy else {}
        self.config = cfg
        self.store, self.frozen = store, fresh.frozen
        self.reducer = fresh.reducer
        self._policy = fresh._policy         # fresh drift baseline
        if self._policy is not None:
            self._policy.decisions = decisions
        self._delta_used = 0
        self._base_dirty = True
        self._counters["rebuilds"] += 1
        self._stream_programs()              # new constants: re-key caches
        if self._stream_sharded_base is not None:
            self._shard_stream_base()

    def _apply_policy_record(self, decision: dict):
        """Replay one RT_POLICY record (recovery path)."""
        kind = decision.get("decision")
        if kind == "vacuum":
            self._do_vacuum()
        elif kind == "grow":
            from .segments import grow_store
            self.store = grow_store(
                self.store, row_extra=int(decision["row_extra"]),
                cell_extra=int(decision["cell_extra"]))
            self._base_dirty = True
            self._counters["policy_grows"] += 1
        elif kind == "rebuild":
            self._do_rebuild(int(decision["seed"]))
        else:
            raise ValueError(f"unknown policy decision {decision!r}")

    # --- durability -------------------------------------------------------

    def durable(self, directory: str, config=None):
        """Make this streaming engine durable: open a write-ahead log
        under ``directory`` and take the initial durable snapshot there.
        From here on every ``upsert``/``delete``/``compact``/policy
        decision is logged *before* it mutates the store, ``save()`` to
        the same directory marks + truncates the log, and
        ``load_engine(directory)`` recovers the exact live store after a
        crash (snapshot + WAL-tail replay). ``config`` is a
        ``repro.search.durability.DurabilityConfig`` (fsync mode, segment
        size). Returns ``self``."""
        from .durability.wal import DurabilityConfig, Wal
        self._require_stream()
        if self._wal is not None:
            raise RuntimeError(
                "this engine is already durable; one WAL per engine "
                f"(directory {self._durable_dir!r})")
        config = config or DurabilityConfig()
        if config.role == "follower" or self._role == "follower":
            raise ValueError(
                "durable(role='follower') is incoherent: a follower "
                "tails a primary's shipped WAL and never owns a local "
                "one (local writes on a follower would fork the "
                "history). Seed a follower with load_engine(snapshot, "
                "role='follower') + durability.replication.catch_up; "
                "use role='primary' (the default) for a writable node.")
        os.makedirs(directory, exist_ok=True)
        self._wal = Wal(os.path.join(directory, "wal"), config)
        self._durability = config
        self._durable_dir = os.path.abspath(directory)
        self.save(directory)                 # the initial durable snapshot
        return self

    def metrics(self):
        """The engine's typed metrics snapshot: an
        ``repro.search.metrics.EngineMetrics`` of frozen dataclasses
        with stable dotted names (``wal.records``, ``stream.fill``,
        ``compact.pending``, ``policy.drift_ema``,
        ``replication.follower_lag_seq``, ...). This is the
        observability surface — benches, regression gates and the
        launcher's ``--metrics-port`` endpoint consume it; sections that
        do not apply to this engine are ``None``."""
        from .metrics import collect_metrics
        return collect_metrics(self)

    def tracing(self, config=None, **knobs) -> "SearchEngine":
        """Attach request-level observability (``repro.search.tracing``):
        latency histograms into ``metrics().latency``, optional sampled
        deep traces (``deep_trace_every=N``), slow-query capture
        (``slow_query_ms=T``), shadow-exact recall estimation
        (``recall_every=N``) and Chrome-trace export (``trace_dir=``).

        Pass a ``TraceConfig`` or its fields as keyword knobs; calling
        with no arguments attaches the cheap production default
        (end-to-end histograms only). ``tracing(None)`` with an explicit
        ``config=None`` and no knobs re-attaches defaults too; detach
        with ``engine.tracer = None`` via the attribute. Returns ``self``
        for chaining."""
        from .tracing import TraceConfig, Tracer
        if config is None:
            config = TraceConfig(**knobs)
        elif knobs:
            config = dataclasses.replace(config, **knobs)
        self._tracer = Tracer(config)
        return self

    @property
    def tracer(self):
        """The attached ``Tracer`` (None when tracing is off)."""
        return self._tracer

    @tracer.setter
    def tracer(self, value):
        self._tracer = value

    @property
    def trace_dir(self) -> Optional[str]:
        """Chrome-trace export directory (None = event capture off).
        Setting it attaches/updates the tracer in place."""
        return (self._tracer.config.trace_dir
                if self._tracer is not None else None)

    @trace_dir.setter
    def trace_dir(self, directory: Optional[str]):
        from .tracing import TraceConfig, Tracer
        if self._tracer is None:
            self._tracer = Tracer(TraceConfig(trace_dir=directory))
        else:
            self._tracer.config = dataclasses.replace(
                self._tracer.config, trace_dir=directory)

    def flush_trace(self, path: Optional[str] = None) -> Optional[str]:
        """Write buffered trace events as Chrome-trace JSON; returns the
        path (None when no tracer / event capture is attached)."""
        if self._tracer is None:
            return None
        return self._tracer.flush(path)

    def _shard_stream_base(self):
        from repro.parallel.engine import shard_stream
        self._stream_sharded_base = shard_stream(
            self.store, self.frozen, self._mesh, axis=self._shard_axis)

    # --- sharding ---------------------------------------------------------

    def shard(self, mesh: Optional[Mesh] = None, axis: str = "data",
              donate: bool = False):
        """Partition the engine over the ``axis`` of ``mesh`` (default: the
        mesh activated by ``repro.parallel.context.mesh_context``).

        Subsequent ``search`` calls route through ``sharded_search_fn`` —
        same results, database split across the mesh devices. Returns
        ``self`` for chaining. Re-call with a different mesh to re-shard.

        ``donate=True`` releases the dense single-device buffers once the
        sharded copy is placed (no 2x database memory): re-sharding then
        raises, and switching back via ``sharded_state = None`` is no
        longer possible. With the default ``donate=False`` both copies
        stay live — fine for dry-runs, 2x memory at real scale.

        On a streaming engine the **base** shards and the delta segment /
        tombstones stay replicated (writes keep working; ``compact()``
        re-lays the base out). Donation is refused there: the dense store
        is the write path.
        """
        if mesh is None:
            from repro.parallel.context import require_mesh
            mesh = require_mesh("SearchEngine.shard()")
        self._mesh, self._shard_axis = mesh, axis
        if self.store is not None:
            if donate:
                raise ValueError(
                    "donate=True is not supported on a streaming engine: "
                    "the dense StreamStore backs upsert/delete/compact")
            if self._compact_future is not None:
                self.finish_compact()    # lay out the post-fold base, once
            self._shard_stream_base()
            return self
        if self.state is None:
            raise RuntimeError(
                "the dense EngineState is gone: its buffers were released "
                "by shard(donate=True) — rebuild the engine (or "
                "load_engine from a snapshot) to re-shard")
        from repro.parallel.engine import shard_engine
        keep = (self._user_corpus,) if self._user_corpus is not None else ()
        self.sharded_state = shard_engine(self.state, mesh,
                                          axis=axis, donate=donate,
                                          keep=keep)
        if donate:
            self.state = None
            if self.reducer is not None:
                # the dense reducer params were donated; point the public
                # reducer at the replicated sharded copies so
                # eng.reducer(x) keeps working
                self.reducer = self.sharded_state.proj
        if self._sharded_program is None:
            def _engine_sharded_fn(sstate, queries, k, **kw):
                return sharded_search_fn(sstate, queries, k, **kw)
            self._sharded_program = jax.jit(
                _engine_sharded_fn,
                static_argnames=_SEARCH_STATICS + ("mesh", "axis"))
        return self

    def _scan_cap(self, nprobe: int) -> int:
        """Compact-scan gather width for this engine at ``nprobe``: the
        worst-case probed posting mass (sum of the ``nprobe`` largest cell
        fills), rounded up to a lane multiple — so the capped gather can
        NEVER truncate a query's candidates and results stay bit-identical
        to the padded scan. Returns 0 (disabled) unless the bound beats the
        padded ``nprobe * max_cell`` gather by a wide margin: each compact
        slot costs ~1.5x a padded slot (the prefix-sum slot mapping and the
        2D cell/slot gathers), so a cap must remove well over a third of
        the slots to win — in practice that means a few outlier-huge cells,
        the regime the cap exists for, not mild skew. Host-side and cached:
        the posting-mass sync runs once per (engine, nprobe)."""
        cap = self._scan_caps.get(nprobe)
        if cap is None:
            lists = self.state.index.payload.lists
            lens = np.asarray(jnp.sum(lists >= 0, axis=1))
            top = np.sort(lens)[-nprobe:]
            cap = -(-int(top.sum()) // 128) * 128
            if cap * 8 >= nprobe * lists.shape[1] * 5:
                cap = 0
            self._scan_caps[nprobe] = cap
        return cap

    def search(self, queries: jax.Array, k: int):
        """Returns (dists (Q,k), ids (Q,k)); distances in the original space
        when re-ranking is active, else in the serving (reduced) space.

        One device program per call: the batch is zero-padded up to its
        power-of-two bucket (>= ``config.query_bucket``) so every batch size
        in a bucket reuses the same compilation, then sliced back to Q rows.
        """
        cfg = self.config
        ops = get_ops(cfg.index)
        # reject an unservable k eagerly (host-side, before any tracing)
        # instead of silently truncating the candidate list inside the scan
        _check_rerank_budget(cfg.target_dim is not None or ops.lossy,
                             cfg.rerank, k)
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        bucket = _bucket(nq, cfg.query_bucket, cfg.small_batch)
        self.last_bucket = bucket
        if bucket != nq:
            queries = jnp.pad(queries, ((0, bucket - nq), (0, 0)))
        # normalize knobs the index kind can't observe so flipping them
        # (e.g. a stray nprobe on a flat engine) never re-keys the jit cache
        probed = cfg.index in ("ivf", "ivfpq")
        coded = cfg.index in ("pq", "opq", "ivfpq")
        kw = dict(nprobe=cfg.nprobe if probed else 0,
                  rerank=cfg.rerank,
                  backend=cfg.pq_backend if coded else "jnp",
                  interpret=cfg.pq_interpret if coded else True,
                  lut_dtype=cfg.lut_dtype if coded else "f32",
                  scan_cap=0, prefilter=0)
        # small read-only ivfpq buckets: size the candidate gather by the
        # actual probed posting mass (compact scan) and — when the scan
        # space is the re-rank space — shrink the exact re-rank to the
        # certified survivors. Both are bit-identical to the defaults, so
        # engaging them per bucket only re-keys the cache, never results.
        # They engage independently: the compact scan wins whenever the
        # posting-mass bound clears _scan_cap's margin, but the pre-filter
        # pays only when the quantization/PQ error bound is tight enough to
        # actually cut survivors — on loose-bound corpora everyone survives,
        # the full-width fallback runs anyway, and the bound + partition
        # work is pure loss (~0.4-1.0ms per batch-64 call measured), so it
        # rides its own opt-in knob.
        if (cfg.index == "ivfpq" and self.store is None
                and self.sharded_state is None):
            if 0 < bucket <= cfg.compact_batch:
                kw["scan_cap"] = self._scan_cap(cfg.nprobe)
            if (0 < bucket <= cfg.prefilter_batch
                    and cfg.target_dim is None):
                r_s = max(2 * k, cfg.rerank // 2)
                if r_s < cfg.rerank:
                    kw["prefilter"] = r_s
        # tracing: one perf_counter read when a tracer is attached and
        # active; with no tracer the serve path is exactly the old one
        tracer = self._tracer
        t0 = (time.perf_counter()
              if tracer is not None and tracer.active else None)
        if self.store is not None:
            self._poll_compaction()     # swap in a finished background fold
            if self._stream_sharded_base is not None:
                from .stream import replica_from_store
                repl = replica_from_store(self.store)
                d, ids = self._stream_sharded_program(
                    self._stream_sharded_base, repl, queries, k,
                    mesh=self._mesh, axis=self._shard_axis, **kw)
            else:
                d, ids = self._stream_program(self.store, self.frozen,
                                              queries, k, **kw)
        elif self.sharded_state is not None:
            d, ids = self._sharded_program(
                self.sharded_state, queries, k, mesh=self._mesh,
                axis=self._shard_axis, **kw)
        else:
            d, ids = self._program(self.state, queries, k, **kw)
        if t0 is not None:
            # blocks the result (an honest end-to-end number — the
            # caller's own block becomes a no-op), then records/samples
            tracer.on_search(self, queries, nq, k, kw, t0, d, ids)
        return d[:nq], ids[:nq]


def build_engine(corpus: jax.Array, spec, **runtime) -> SearchEngine:
    """Build a serving engine from a pipeline spec — the canonical
    constructor of the composable API.

    ``spec`` is an ``IndexSpec``, a spec string
    (``"qpad32>ivf64x8>pq8x256:i8"``), or a full ``ServeConfig``;
    ``runtime`` forwards engine knobs the pipeline does not carry
    (``query_bucket``, ``mpad``, ``fit_sample``, ``seed``, ``stream``,
    ...). Continue with the lifecycle methods: ``.shard(mesh)``,
    ``.streaming(StreamConfig(...))``, ``.save(dir)``.
    """
    if isinstance(spec, ServeConfig):
        if runtime:
            spec = dataclasses.replace(spec, **runtime)
        return SearchEngine(corpus, spec)
    return SearchEngine(corpus, config_from_spec(spec, **runtime))
