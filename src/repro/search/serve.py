"""Batched vector-search serving engine: a functional one-program core.

Pipeline (DESIGN.md §2): corpus -> [fit MPAD on a sample] -> reduce corpus ->
[build an index over reduced vectors] -> serve batched queries:
reduce query -> index probe/scan in reduced space -> exact re-rank of the C
candidates in the original space -> top-k.

The reduced-space scan is where the paper's win lands: score FLOPs and corpus
bytes scale with m instead of n, and the re-rank restores exactness on the
short candidate list.

Serving architecture
--------------------

The engine is split into a **pytree of arrays** and a **pure function**:

* ``EngineState`` — an immutable pytree holding the re-rank corpus, the
  (optional) MPAD projection, and exactly one built index (flat / IVF / PQ /
  IVF-PQ). Being a pytree, it shards, donates, and serialises like any other
  jax state.
* ``search_fn(state, queries, k, *, index, nprobe, rerank, backend,
  interpret, lut_dtype)`` — the whole query pipeline (project -> probe ->
  ADC/flat scan -> dedup'd masked re-rank gather -> final top-k) as one
  traceable function. Jitted, it compiles to a **single XLA program**: no
  Python dispatch or host syncs between stages.

``SearchEngine`` is a thin stateful wrapper: it builds ``EngineState`` once,
owns a per-engine ``jax.jit(search_fn)`` whose cache is keyed by
``(index kind + knobs, k, query bucket)``, and pads incoming query batches
up to power-of-two buckets (floored at ``ServeConfig.query_bucket``) so
ragged traffic reuses compilations — batch sizes {1, 7, 64} all run the one
program compiled for bucket 64. ``SearchEngine.compile_count`` exposes the
cache size for regression tests.

Index layouts (``ServeConfig.index``):

  "flat"   exact scan of the (reduced) vectors
  "ivf"    k-means coarse quantizer, probe nprobe cells, exact cell scan
  "pq"     product-quantized vectors, fused ADC scan
  "ivfpq"  coarse quantizer + PQ-coded residuals, probed ADC scan — the
           production memory-hierarchy composition

``ServeConfig.lut_dtype`` ("f32" | "bf16" | "int8") quantizes the per-query
ADC lookup tables of the pq/ivfpq scans (see ``repro.kernels.pq_adc.lut``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import MPADConfig, MPADResult, fit_mpad
from repro.kernels.pq_adc.lut import LUT_DTYPES
from .ivf import IVFIndex, build_ivf, ivf_scan
from .ivfpq import IVFPQIndex, build_ivfpq, ivfpq_scan
from .knn import knn_scan
from .pq import PQIndex, build_pq, pq_scan

__all__ = ["ServeConfig", "SearchEngine", "EngineState", "search_fn",
           "exact_rerank", "INDEX_KINDS"]

INDEX_KINDS = ("flat", "ivf", "pq", "ivfpq")
_ADC_BACKENDS = ("jnp", "kernel")
_SEARCH_STATICS = ("k", "index", "nprobe", "rerank", "backend", "interpret",
                   "lut_dtype")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    target_dim: Optional[int] = None     # None = no reduction (full-dim exact)
    rerank: int = 64                     # candidates re-ranked in original space
    index: str = "flat"                  # one of INDEX_KINDS
    nlist: int = 64                      # ivf/ivfpq: coarse cells
    nprobe: int = 8                      # ivf/ivfpq: cells probed per query
    pq_subspaces: int = 8                # pq/ivfpq: code bytes per vector
    pq_centroids: int = 256              # pq/ivfpq: codebook size per subspace
    pq_backend: str = "jnp"              # ADC scoring: "jnp" | "kernel"
    pq_interpret: bool = True            # kernel backend: Pallas interpret
    #                                      mode (set False on real TPU)
    lut_dtype: str = "f32"               # ADC LUT precision: f32 | bf16 | int8
    query_bucket: int = 64               # min padded query-batch size; ragged
    #                                      batches round up to powers of two
    mpad: Optional[MPADConfig] = None    # defaults derived from target_dim
    fit_sample: int = 2048               # rows used to fit the projection
    seed: int = 0
    # deprecated boolean index spec (pre-``index=``); shimmed in __post_init__
    use_ivf: Optional[bool] = None
    use_pq: Optional[bool] = None

    def __post_init__(self):
        if self.use_ivf and self.use_pq:
            raise ValueError(
                "use_ivf=True with use_pq=True is ambiguous (the old engine "
                "silently built IVF only); request the composition explicitly "
                "with ServeConfig(index='ivfpq').")
        if self.use_ivf or self.use_pq:
            if self.index != "flat":
                raise ValueError(
                    "pass either index= or the deprecated use_ivf/use_pq "
                    "booleans, not both")
            warnings.warn(
                "ServeConfig(use_ivf=/use_pq=) is deprecated; use "
                "ServeConfig(index='ivf'|'pq'|'ivfpq')", DeprecationWarning,
                stacklevel=3)
            object.__setattr__(
                self, "index", "ivf" if self.use_ivf else "pq")
            # clear the booleans so dataclasses.replace() on a shimmed
            # config doesn't re-trip the either/or check above
            object.__setattr__(self, "use_ivf", None)
            object.__setattr__(self, "use_pq", None)
        if self.index not in INDEX_KINDS:
            raise ValueError(
                f"unknown index kind {self.index!r}; expected one of "
                f"{INDEX_KINDS}")
        if self.pq_backend not in _ADC_BACKENDS:
            raise ValueError(
                f"unknown pq_backend {self.pq_backend!r}; expected one of "
                f"{_ADC_BACKENDS}")
        if self.lut_dtype not in LUT_DTYPES:
            raise ValueError(
                f"unknown lut_dtype {self.lut_dtype!r}; expected one of "
                f"{LUT_DTYPES}")
        if self.query_bucket < 1:
            raise ValueError("query_bucket must be >= 1")


class EngineState(NamedTuple):
    """Everything ``search_fn`` needs, as one immutable pytree.

    Exactly one of (``reduced``, ``ivf``, ``pq``, ``ivfpq``) is non-None —
    the built index — plus the original-space corpus for the exact re-rank
    and the (optional) MPAD projection as raw arrays.
    """
    corpus: jax.Array                              # (N, D) re-rank space
    proj: Optional[Tuple[jax.Array, jax.Array]]    # (matrix (m,D), mean (D,))
    reduced: Optional[jax.Array]                   # flat: (N, m) scan vectors
    ivf: Optional[IVFIndex]
    pq: Optional[PQIndex]
    ivfpq: Optional[IVFPQIndex]


def exact_rerank(queries: jax.Array, corpus: jax.Array, cand: jax.Array,
                 k: int):
    """Re-score candidate ids in the original space; top-k of the survivors.

    ``cand`` (Q, C) may contain -1 pads and duplicate ids (over-retrieval
    across probes): duplicates are collapsed to -1 first (sort + neighbor
    compare), then a single masked gather pulls each surviving row once and
    pads/dups are held out of the top-k with +inf.
    """
    cand = jnp.sort(cand, axis=1)                        # pads (-1) sort first
    dup = jnp.concatenate(
        [jnp.zeros_like(cand[:, :1], bool), cand[:, 1:] == cand[:, :-1]],
        axis=1)
    cand = jnp.where(dup, -1, cand)
    valid = cand >= 0
    cv = jnp.take(corpus, jnp.where(valid, cand, 0), axis=0)   # (Q, C, D)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def search_fn(state: EngineState, queries: jax.Array, k: int, *,
              index: str = "flat", nprobe: int = 8, rerank: int = 64,
              backend: str = "jnp", interpret: bool = True,
              lut_dtype: str = "f32"):
    """The entire query pipeline as one pure traceable function.

    project -> probe/scan (per ``index``) -> exact re-rank -> top-k.
    Jitted (``jax.jit(search_fn, static_argnames=_SEARCH_STATICS)``) this is
    a single XLA program; every per-query op is row-independent, so padded
    query rows never perturb real results. Returns (dists (Q,k), ids (Q,k));
    distances in the original space when re-ranking is active, else in the
    serving (reduced) space.
    """
    queries = jnp.asarray(queries, jnp.float32)
    if state.proj is not None:
        matrix, mean = state.proj
        qr = (queries - mean) @ matrix.T
    else:
        qr = queries
    # lossy scoring (reduction and/or PQ codes) -> over-retrieve + re-rank
    approximate = state.proj is not None or index in ("pq", "ivfpq")
    n_cand = max(k, rerank) if approximate else k
    if index == "ivf":
        _, cand = ivf_scan(state.ivf, qr, n_cand, nprobe)
    elif index == "pq":
        _, cand = pq_scan(state.pq, qr, n_cand, backend=backend,
                          interpret=interpret, lut_dtype=lut_dtype)
    elif index == "ivfpq":
        _, cand = ivfpq_scan(state.ivfpq, qr, n_cand, nprobe,
                             backend=backend, interpret=interpret,
                             lut_dtype=lut_dtype)
    else:
        base = state.reduced if state.reduced is not None else state.corpus
        _, cand = knn_scan(qr, base, n_cand)
    return exact_rerank(queries, state.corpus, cand, k)


def _bucket(nq: int, floor: int) -> int:
    """Smallest power-of-two >= nq, floored at ``floor``."""
    return max(floor, 1 << max(nq - 1, 0).bit_length())


class SearchEngine:
    """Build once over a corpus; serve batched k-NN queries.

    Thin wrapper over the functional core: ``__init__`` builds
    ``self.state`` (an ``EngineState``), ``search`` pads the batch to its
    bucket and calls the engine-owned jitted ``search_fn``. Mutating
    ``self.config`` (e.g. ``dataclasses.replace(..., nprobe=16)``) is
    supported — knob changes re-key the jit cache, not the state.
    """

    def __init__(self, corpus: jax.Array, config: ServeConfig):
        self.config = config
        corpus = jnp.asarray(corpus, jnp.float32)
        n, dim = corpus.shape
        key = jax.random.key(config.seed)
        if config.target_dim is not None:
            mcfg = config.mpad or MPADConfig(
                m=config.target_dim, b=80.0, alpha=25.0, iters=48,
                seed=config.seed)
            sample = corpus
            if config.fit_sample < n:
                rows = jax.random.choice(
                    key, n, (config.fit_sample,), replace=False)
                sample = corpus[rows]
            self.reducer: Optional[MPADResult] = fit_mpad(sample, mcfg)
            reduced = self.reducer(corpus)
            proj = (self.reducer.matrix, self.reducer.mean)
        else:
            self.reducer = None
            reduced = corpus
            proj = None
        ivf = pq = ivfpq = None
        if config.index == "ivf":
            ivf = build_ivf(
                jax.random.fold_in(key, 1), reduced, config.nlist)
        elif config.index == "pq":
            pq = build_pq(jax.random.fold_in(key, 2), reduced,
                          config.pq_subspaces, config.pq_centroids)
        elif config.index == "ivfpq":
            ivfpq = build_ivfpq(
                jax.random.fold_in(key, 3), reduced, config.nlist,
                config.pq_subspaces, config.pq_centroids)
        self.state = EngineState(
            corpus=corpus, proj=proj,
            reduced=reduced if config.index == "flat" else None,
            ivf=ivf, pq=pq, ivfpq=ivfpq)
        self._reduced = reduced      # back-compat view for every index kind
        # engine-owned jit: a fresh closure gives this engine its own
        # compilation cache (jax shares caches for identical function
        # objects), keyed by (statics, query bucket)
        def _engine_search_fn(state, queries, k, **kw):
            return search_fn(state, queries, k, **kw)
        self._program = jax.jit(_engine_search_fn,
                                static_argnames=_SEARCH_STATICS)

    # back-compat array views into the state pytree
    @property
    def corpus(self) -> jax.Array:
        return self.state.corpus

    @property
    def reduced(self) -> jax.Array:
        return self._reduced

    @property
    def ivf(self) -> Optional[IVFIndex]:
        return self.state.ivf

    @property
    def pq(self) -> Optional[PQIndex]:
        return self.state.pq

    @property
    def ivfpq(self) -> Optional[IVFPQIndex]:
        return self.state.ivfpq

    @property
    def compile_count(self) -> int:
        """Number of compiled (statics, bucket) variants this engine holds."""
        try:
            return int(self._program._cache_size())
        except AttributeError as e:     # private jax hook; fail loudly if
            raise RuntimeError(          # an unpinned jax drops it
                "jax no longer exposes PjitFunction._cache_size(); "
                "SearchEngine.compile_count needs a replacement hook"
            ) from e

    def search(self, queries: jax.Array, k: int):
        """Returns (dists (Q,k), ids (Q,k)); distances in the original space
        when re-ranking is active, else in the serving (reduced) space.

        One device program per call: the batch is zero-padded up to its
        power-of-two bucket (>= ``config.query_bucket``) so every batch size
        in a bucket reuses the same compilation, then sliced back to Q rows.
        """
        cfg = self.config
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        bucket = _bucket(nq, cfg.query_bucket)
        if bucket != nq:
            queries = jnp.pad(queries, ((0, bucket - nq), (0, 0)))
        # normalize knobs the index kind can't observe so flipping them
        # (e.g. lut_dtype on a flat engine) never re-keys the jit cache
        probed = cfg.index in ("ivf", "ivfpq")
        coded = cfg.index in ("pq", "ivfpq")
        d, ids = self._program(
            self.state, queries, k, index=cfg.index,
            nprobe=cfg.nprobe if probed else 0,
            rerank=cfg.rerank,
            backend=cfg.pq_backend if coded else "jnp",
            interpret=cfg.pq_interpret if coded else True,
            lut_dtype=cfg.lut_dtype if coded else "f32")
        return d[:nq], ids[:nq]
