"""Batched vector-search serving engine with MPAD as a first-class feature.

Pipeline (DESIGN.md §2): corpus -> [fit MPAD on a sample] -> reduce corpus ->
[build an index over reduced vectors] -> serve batched queries:
reduce query -> index probe/scan in reduced space -> exact re-rank of the C
candidates in the original space -> top-k.

The reduced-space scan is where the paper's win lands: score FLOPs and corpus
bytes scale with m instead of n, and the re-rank restores exactness on the
short candidate list.

Index layouts (``ServeConfig.index``):

  "flat"   exact scan of the (reduced) vectors
  "ivf"    k-means coarse quantizer, probe nprobe cells, exact cell scan
  "pq"     product-quantized vectors, fused ADC scan
  "ivfpq"  coarse quantizer + PQ-coded residuals, probed ADC scan — the
           production memory-hierarchy composition
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import MPADConfig, MPADResult, fit_mpad
from .ivf import IVFIndex, build_ivf, ivf_search
from .ivfpq import IVFPQIndex, build_ivfpq, ivfpq_search
from .knn import knn_search
from .pq import PQIndex, build_pq, pq_search

__all__ = ["ServeConfig", "SearchEngine", "INDEX_KINDS"]

INDEX_KINDS = ("flat", "ivf", "pq", "ivfpq")
_ADC_BACKENDS = ("jnp", "kernel")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    target_dim: Optional[int] = None     # None = no reduction (full-dim exact)
    rerank: int = 64                     # candidates re-ranked in original space
    index: str = "flat"                  # one of INDEX_KINDS
    nlist: int = 64                      # ivf/ivfpq: coarse cells
    nprobe: int = 8                      # ivf/ivfpq: cells probed per query
    pq_subspaces: int = 8                # pq/ivfpq: code bytes per vector
    pq_centroids: int = 256              # pq/ivfpq: codebook size per subspace
    pq_backend: str = "jnp"              # ADC scoring: "jnp" | "kernel"
    pq_interpret: bool = True            # kernel backend: Pallas interpret
    #                                      mode (set False on real TPU)
    mpad: Optional[MPADConfig] = None    # defaults derived from target_dim
    fit_sample: int = 2048               # rows used to fit the projection
    seed: int = 0
    # deprecated boolean index spec (pre-``index=``); shimmed in __post_init__
    use_ivf: Optional[bool] = None
    use_pq: Optional[bool] = None

    def __post_init__(self):
        if self.use_ivf and self.use_pq:
            raise ValueError(
                "use_ivf=True with use_pq=True is ambiguous (the old engine "
                "silently built IVF only); request the composition explicitly "
                "with ServeConfig(index='ivfpq').")
        if self.use_ivf or self.use_pq:
            if self.index != "flat":
                raise ValueError(
                    "pass either index= or the deprecated use_ivf/use_pq "
                    "booleans, not both")
            warnings.warn(
                "ServeConfig(use_ivf=/use_pq=) is deprecated; use "
                "ServeConfig(index='ivf'|'pq'|'ivfpq')", DeprecationWarning,
                stacklevel=3)
            object.__setattr__(
                self, "index", "ivf" if self.use_ivf else "pq")
            # clear the booleans so dataclasses.replace() on a shimmed
            # config doesn't re-trip the either/or check above
            object.__setattr__(self, "use_ivf", None)
            object.__setattr__(self, "use_pq", None)
        if self.index not in INDEX_KINDS:
            raise ValueError(
                f"unknown index kind {self.index!r}; expected one of "
                f"{INDEX_KINDS}")
        if self.pq_backend not in _ADC_BACKENDS:
            raise ValueError(
                f"unknown pq_backend {self.pq_backend!r}; expected one of "
                f"{_ADC_BACKENDS}")


class SearchEngine:
    """Build once over a corpus; serve batched k-NN queries."""

    def __init__(self, corpus: jax.Array, config: ServeConfig):
        self.config = config
        self.corpus = jnp.asarray(corpus, jnp.float32)
        n, dim = self.corpus.shape
        key = jax.random.key(config.seed)
        if config.target_dim is not None:
            mcfg = config.mpad or MPADConfig(
                m=config.target_dim, b=80.0, alpha=25.0, iters=48,
                seed=config.seed)
            sample = self.corpus
            if config.fit_sample < n:
                rows = jax.random.choice(
                    key, n, (config.fit_sample,), replace=False)
                sample = self.corpus[rows]
            self.reducer: Optional[MPADResult] = fit_mpad(sample, mcfg)
            self.reduced = self.reducer(self.corpus)
        else:
            self.reducer = None
            self.reduced = self.corpus
        self.ivf: Optional[IVFIndex] = None
        self.pq: Optional[PQIndex] = None
        self.ivfpq: Optional[IVFPQIndex] = None
        if config.index == "ivf":
            self.ivf = build_ivf(
                jax.random.fold_in(key, 1), self.reduced, config.nlist)
        elif config.index == "pq":
            self.pq = build_pq(jax.random.fold_in(key, 2), self.reduced,
                               config.pq_subspaces, config.pq_centroids)
        elif config.index == "ivfpq":
            self.ivfpq = build_ivfpq(
                jax.random.fold_in(key, 3), self.reduced, config.nlist,
                config.pq_subspaces, config.pq_centroids)

    def search(self, queries: jax.Array, k: int):
        """Returns (dists (Q,k), ids (Q,k)); distances in the original space
        when re-ranking is active, else in the serving (reduced) space."""
        cfg = self.config
        queries = jnp.asarray(queries, jnp.float32)
        qr = self.reducer(queries) if self.reducer is not None else queries
        # lossy scoring (reduction and/or PQ codes) -> over-retrieve + re-rank
        approximate = (self.reducer is not None
                       or cfg.index in ("pq", "ivfpq"))
        n_cand = max(k, cfg.rerank if approximate else k)
        if cfg.index == "ivf":
            _, cand = ivf_search(self.ivf, qr, n_cand, cfg.nprobe)
        elif cfg.index == "pq":
            _, cand = pq_search(self.pq, qr, n_cand,
                                backend=cfg.pq_backend,
                                interpret=cfg.pq_interpret)
        elif cfg.index == "ivfpq":
            _, cand = ivfpq_search(self.ivfpq, qr, n_cand, cfg.nprobe,
                                   backend=cfg.pq_backend,
                                   interpret=cfg.pq_interpret)
        else:
            _, cand = knn_search(qr, self.reduced, n_cand)
        return _exact_rerank(queries, self.corpus, cand, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _exact_rerank(queries, corpus, cand, k):
    cv = corpus[jnp.maximum(cand, 0)]                    # (Q, C, n)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    # -1 pads (under-filled probes) must never displace real candidates
    d2 = jnp.where(cand >= 0, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids
