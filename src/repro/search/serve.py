"""Batched vector-search serving engine: a functional one-program core.

Pipeline (DESIGN.md §2): corpus -> [fit MPAD on a sample] -> reduce corpus ->
[build an index over reduced vectors] -> serve batched queries:
reduce query -> index probe/scan in reduced space -> exact re-rank of the C
candidates in the original space -> top-k.

The reduced-space scan is where the paper's win lands: score FLOPs and corpus
bytes scale with m instead of n, and the re-rank restores exactness on the
short candidate list.

Serving architecture
--------------------

The engine is split into a **pytree of arrays** and a **pure function**:

* ``EngineState`` — an immutable pytree holding the re-rank corpus, the
  (optional) MPAD projection, and exactly one built index (flat / IVF / PQ /
  IVF-PQ). Being a pytree, it shards, donates, and serialises like any other
  jax state.
* ``search_fn(state, queries, k, *, index, nprobe, rerank, backend,
  interpret, lut_dtype)`` — the whole query pipeline (project -> probe ->
  ADC/flat scan -> dedup'd masked re-rank gather -> final top-k) as one
  traceable function. Jitted, it compiles to a **single XLA program**: no
  Python dispatch or host syncs between stages.

``SearchEngine`` is a thin stateful wrapper: it builds ``EngineState`` once,
owns a per-engine ``jax.jit(search_fn)`` whose cache is keyed by
``(index kind + knobs, k, query bucket)``, and pads incoming query batches
up to power-of-two buckets (floored at ``ServeConfig.query_bucket``) so
ragged traffic reuses compilations — batch sizes {9, 33, 64} all run the
one program compiled for bucket 64. Batches of at most
``ServeConfig.small_batch`` (default 8) take their own power-of-two bucket
instead of the floor, so a single query runs a compute-proportional scan
rather than a 64-wide one (the small-batch latency cliff).
``SearchEngine.compile_count`` exposes the cache size for regression tests.

Sharded serving
---------------

``shard_engine(state, mesh, axis="data")`` (``repro.parallel.engine``)
partitions the state pytree along the **database axis** of a device mesh:
corpus rows, flat scan vectors, and plain-PQ codes split by row; IVF /
IVF-PQ posting structures (``lists`` plus the cell-major
``codes_cell``/``bias_cell``/``cell_vectors`` mirrors) split by cell; the
MPAD projection, coarse centroids, and PQ codebooks replicate. Database
leaves are padded to per-shard-equal shapes (pad rows/cells are masked out
of every scan). ``sharded_search_fn`` then runs the same fused pipeline
under ``shard_map``: each shard probes (replicated math — identical on
every shard), scans only the rows/cells it owns, keeps a local top-n_cand
with **global** row ids via its shard offset, and the shards finish with an
``all_gather`` + global top-k merge and a masked exact re-rank in which
each shard gathers only the winning candidates it owns (``psum``-free: a
``pmin`` combines the per-shard masked distances). The merge keeps the
exact candidate set of the single-device program, so sharded and
single-device serving return identical neighbors; the single-device path
itself is untouched. The jit cache keys on the mesh (shape + devices), so
resizing the fleet recompiles exactly once per shape.

Streaming (mutable) serving
---------------------------

``ServeConfig(stream=StreamConfig(...))`` enables the write path: the
built index becomes the frozen **base** layer of a
``repro.search.segments.StreamStore`` (fixed row capacity + posting-list
pad slack + tombstone bitmap) with a fixed-capacity exact-scan **delta
segment** on top. ``SearchEngine.upsert/delete/compact`` are pure
donated-jit programs over that store — no recompiles per write — and
``search`` routes through ``repro.search.stream.stream_search_fn`` (or
its sharded twin: base sharded, delta/tombstones replicated).

Index layouts (``ServeConfig.index``):

  "flat"   exact scan of the (reduced) vectors
  "ivf"    k-means coarse quantizer, probe nprobe cells, exact cell scan
  "pq"     product-quantized vectors, fused ADC scan
  "ivfpq"  coarse quantizer + PQ-coded residuals, probed ADC scan — the
           production memory-hierarchy composition

``ServeConfig.lut_dtype`` ("f32" | "bf16" | "int8") quantizes the per-query
ADC lookup tables of the pq/ivfpq scans (see ``repro.kernels.pq_adc.lut``).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import MPADConfig, MPADResult, fit_mpad
from repro.kernels.pq_adc.lut import LUT_DTYPES
from .ivf import IVFIndex, build_ivf, ivf_local_scan, ivf_scan
from .ivfpq import IVFPQIndex, build_ivfpq, ivfpq_local_scan, ivfpq_scan
from .knn import _sq_dists, knn_scan, masked_topk
from .pq import PQIndex, build_pq, pq_local_scan, pq_scan
from .segments import StreamConfig

__all__ = ["ServeConfig", "SearchEngine", "EngineState",
           "ShardedEngineState", "StreamConfig", "search_fn",
           "sharded_search_fn", "exact_rerank", "INDEX_KINDS"]

INDEX_KINDS = ("flat", "ivf", "pq", "ivfpq")
_ADC_BACKENDS = ("jnp", "kernel")
_SEARCH_STATICS = ("k", "index", "nprobe", "rerank", "backend", "interpret",
                   "lut_dtype")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    target_dim: Optional[int] = None     # None = no reduction (full-dim exact)
    rerank: int = 64                     # candidates re-ranked in original space
    index: str = "flat"                  # one of INDEX_KINDS
    nlist: int = 64                      # ivf/ivfpq: coarse cells
    nprobe: int = 8                      # ivf/ivfpq: cells probed per query
    pq_subspaces: int = 8                # pq/ivfpq: code bytes per vector
    pq_centroids: int = 256              # pq/ivfpq: codebook size per subspace
    pq_backend: str = "jnp"              # ADC scoring: "jnp" | "kernel"
    pq_interpret: bool = True            # kernel backend: Pallas interpret
    #                                      mode (set False on real TPU)
    lut_dtype: str = "f32"               # ADC LUT precision: f32 | bf16 | int8
    query_bucket: int = 64               # min padded query-batch size; ragged
    #                                      batches round up to powers of two
    small_batch: int = 8                 # batches <= this take their own
    #                                      power-of-two bucket instead of the
    #                                      query_bucket floor (0 disables)
    mpad: Optional[MPADConfig] = None    # defaults derived from target_dim
    fit_sample: int = 2048               # rows used to fit the projection
    seed: int = 0
    stream: Optional[StreamConfig] = None  # enable the mutable write path
    #                                        (delta segment + tombstones +
    #                                        compaction; see search/stream.py)
    # deprecated boolean index spec (pre-``index=``); shimmed in __post_init__
    use_ivf: Optional[bool] = None
    use_pq: Optional[bool] = None

    def __post_init__(self):
        if self.use_ivf and self.use_pq:
            raise ValueError(
                "use_ivf=True with use_pq=True is ambiguous (the old engine "
                "silently built IVF only); request the composition explicitly "
                "with ServeConfig(index='ivfpq').")
        if self.use_ivf or self.use_pq:
            if self.index != "flat":
                raise ValueError(
                    "pass either index= or the deprecated use_ivf/use_pq "
                    "booleans, not both")
            warnings.warn(
                "ServeConfig(use_ivf=/use_pq=) is deprecated; use "
                "ServeConfig(index='ivf'|'pq'|'ivfpq')", DeprecationWarning,
                stacklevel=3)
            object.__setattr__(
                self, "index", "ivf" if self.use_ivf else "pq")
            # clear the booleans so dataclasses.replace() on a shimmed
            # config doesn't re-trip the either/or check above
            object.__setattr__(self, "use_ivf", None)
            object.__setattr__(self, "use_pq", None)
        if self.index not in INDEX_KINDS:
            raise ValueError(
                f"unknown index kind {self.index!r}; expected one of "
                f"{INDEX_KINDS}")
        if self.pq_backend not in _ADC_BACKENDS:
            raise ValueError(
                f"unknown pq_backend {self.pq_backend!r}; expected one of "
                f"{_ADC_BACKENDS}")
        if self.lut_dtype not in LUT_DTYPES:
            raise ValueError(
                f"unknown lut_dtype {self.lut_dtype!r}; expected one of "
                f"{LUT_DTYPES}")
        if self.query_bucket < 1:
            raise ValueError("query_bucket must be >= 1")
        if self.small_batch < 0:
            raise ValueError("small_batch must be >= 0 (0 disables the "
                             "small-batch bucket floor path)")
        if (self.stream is not None and self.index == "pq"
                and self.pq_backend == "kernel"):
            raise ValueError(
                "streaming index='pq' needs pq_backend='jnp': the "
                "shared-codes Pallas kernel has no masked entry point for "
                "an arbitrary tombstone bitmap (use index='ivfpq' for a "
                "kernel-backed streaming ADC scan)")


class EngineState(NamedTuple):
    """Everything ``search_fn`` needs, as one immutable pytree.

    Exactly one of (``reduced``, ``ivf``, ``pq``, ``ivfpq``) is non-None —
    the built index — plus the original-space corpus for the exact re-rank
    and the (optional) MPAD projection as raw arrays.
    """
    corpus: jax.Array                              # (N, D) re-rank space
    proj: Optional[Tuple[jax.Array, jax.Array]]    # (matrix (m,D), mean (D,))
    reduced: Optional[jax.Array]                   # flat: (N, m) scan vectors
    ivf: Optional[IVFIndex]
    pq: Optional[PQIndex]
    ivfpq: Optional[IVFPQIndex]


class ShardedEngineState(NamedTuple):
    """``EngineState`` re-laid-out for data-parallel serving on a mesh.

    Database-axis leaves (corpus rows, flat vectors, PQ code rows, and the
    cell-major IVF / IVF-PQ posting structures) are padded to
    per-shard-equal shapes and sharded along dim 0; the MPAD projection,
    coarse centroids, and codebook factorizations replicate. Built by
    ``repro.parallel.engine.shard_engine``; consumed by
    ``sharded_search_fn``. ``n_real`` is the unpadded corpus size — rows
    at or beyond it are shard padding, masked out of every scan.
    """
    corpus: jax.Array                              # (N_pad, D) row-sharded
    proj: Optional[Tuple[jax.Array, jax.Array]]    # replicated (matrix, mean)
    n_real: jax.Array                              # () int32 replicated
    reduced: Optional[jax.Array]                   # (N_pad, m) row-sharded
    codes: Optional[jax.Array]                     # (N_pad, M) row-sharded
    centroids: Optional[jax.Array]                 # (nlist, d) replicated
    lists: Optional[jax.Array]                     # (nlist_pad, mc) cell-shd
    cell_vecs: Optional[jax.Array]                 # (nlist_pad, mc, d) "
    codes_cell: Optional[jax.Array]                # (nlist_pad, mc, M) "
    bias_cell: Optional[jax.Array]                 # (nlist_pad, mc) "
    lut_w: Optional[jax.Array]                     # (d, M*K) replicated
    cbnorm: Optional[jax.Array]                    # (M, K) replicated


def _dedupe_candidates(cand: jax.Array):
    """Collapse duplicate candidate ids to -1: sort (pads sort first) +
    neighbor compare. Returns (cand sorted/deduped, valid mask). Shared by
    the single-device and sharded re-ranks — their parity depends on running
    the identical prologue."""
    cand = jnp.sort(cand, axis=1)                        # pads (-1) sort first
    dup = jnp.concatenate(
        [jnp.zeros_like(cand[:, :1], bool), cand[:, 1:] == cand[:, :-1]],
        axis=1)
    cand = jnp.where(dup, -1, cand)
    return cand, cand >= 0


def exact_rerank(queries: jax.Array, corpus: jax.Array, cand: jax.Array,
                 k: int):
    """Re-score candidate ids in the original space; top-k of the survivors.

    ``cand`` (Q, C) may contain -1 pads and duplicate ids (over-retrieval
    across probes): duplicates are collapsed to -1 first (sort + neighbor
    compare), then a single masked gather pulls each surviving row once and
    pads/dups are held out of the top-k with +inf.
    """
    cand, valid = _dedupe_candidates(cand)
    cv = jnp.take(corpus, jnp.where(valid, cand, 0), axis=0)   # (Q, C, D)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def search_fn(state: EngineState, queries: jax.Array, k: int, *,
              index: str = "flat", nprobe: int = 8, rerank: int = 64,
              backend: str = "jnp", interpret: bool = True,
              lut_dtype: str = "f32"):
    """The entire query pipeline as one pure traceable function.

    project -> probe/scan (per ``index``) -> exact re-rank -> top-k.
    Jitted (``jax.jit(search_fn, static_argnames=_SEARCH_STATICS)``) this is
    a single XLA program; every per-query op is row-independent, so padded
    query rows never perturb real results. Returns (dists (Q,k), ids (Q,k));
    distances in the original space when re-ranking is active, else in the
    serving (reduced) space.
    """
    queries = jnp.asarray(queries, jnp.float32)
    if state.proj is not None:
        matrix, mean = state.proj
        qr = (queries - mean) @ matrix.T
    else:
        qr = queries
    # lossy scoring (reduction and/or PQ codes) -> over-retrieve + re-rank
    approximate = state.proj is not None or index in ("pq", "ivfpq")
    n_cand = max(k, rerank) if approximate else k
    if index == "ivf":
        _, cand = ivf_scan(state.ivf, qr, n_cand, nprobe)
    elif index == "pq":
        _, cand = pq_scan(state.pq, qr, n_cand, backend=backend,
                          interpret=interpret, lut_dtype=lut_dtype)
    elif index == "ivfpq":
        _, cand = ivfpq_scan(state.ivfpq, qr, n_cand, nprobe,
                             backend=backend, interpret=interpret,
                             lut_dtype=lut_dtype)
    else:
        base = state.reduced if state.reduced is not None else state.corpus
        _, cand = knn_scan(qr, base, n_cand)
    return exact_rerank(queries, state.corpus, cand, k)


# --- sharded serving (shard_map over a database-axis mesh) -------------------

def _flat_local_topk(qr: jax.Array, x_loc: jax.Array, n_real: jax.Array,
                     n_cand: int, axis: str):
    """Shard-local exact scan over this shard's row block of the (reduced)
    corpus; shard-pad rows (global id >= n_real) mask to (+inf, -1).
    Distances come from the same ``_sq_dists`` as the single-device
    ``knn_scan`` so the two paths rank identically."""
    n_loc = x_loc.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    d2 = _sq_dists(qr, x_loc)
    gid = off + jnp.arange(n_loc)
    d2 = jnp.where(gid[None, :] < n_real, d2, jnp.inf)
    return masked_topk(d2, jnp.broadcast_to(gid[None, :], d2.shape), n_cand)


def _sharded_rerank(queries: jax.Array, corpus_loc: jax.Array,
                    cand: jax.Array, k: int, axis: str):
    """``exact_rerank`` with the corpus row-sharded: the same sort + dedupe
    runs replicated, then each shard gathers and scores only the candidates
    it owns and a ``pmin`` over the mesh axis assembles the full exact
    distance row (every candidate is owned by exactly one shard) — only the
    k winners' rows are ever touched on any device."""
    cand, valid = _dedupe_candidates(cand)
    n_loc = corpus_loc.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    local = cand - off
    own = valid & (local >= 0) & (local < n_loc)
    cv = jnp.take(corpus_loc, jnp.clip(local, 0, n_loc - 1), axis=0)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(own, d2, jnp.inf)
    d2 = jax.lax.pmin(d2, axis)                          # (Q, C) replicated
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def _sharded_core(sstate: ShardedEngineState, queries: jax.Array, *, k: int,
                  index: str, nprobe: int, rerank: int, backend: str,
                  interpret: bool, lut_dtype: str, axis: str, slack: int):
    """The shard_map body: the full per-shard pipeline + distributed merge."""
    queries = jnp.asarray(queries, jnp.float32)
    if sstate.proj is not None:
        matrix, mean = sstate.proj
        qr = (queries - mean) @ matrix.T
    else:
        qr = queries
    approximate = sstate.proj is not None or index in ("pq", "ivfpq")
    n_cand = max(k, rerank) if approximate else k
    if index == "ivf":
        d2, cand = ivf_local_scan(sstate.centroids, sstate.lists,
                                  sstate.cell_vecs, qr, n_cand, nprobe, axis)
    elif index == "pq":
        d2, cand = pq_local_scan(sstate.lut_w, sstate.cbnorm, sstate.codes,
                                 qr, n_cand, sstate.n_real, axis,
                                 backend=backend, interpret=interpret,
                                 lut_dtype=lut_dtype, slack=slack)
    elif index == "ivfpq":
        d2, cand = ivfpq_local_scan(sstate.centroids, sstate.lists,
                                    sstate.codes_cell, sstate.bias_cell,
                                    sstate.lut_w, sstate.cbnorm, qr, n_cand,
                                    nprobe, axis, backend=backend,
                                    interpret=interpret, lut_dtype=lut_dtype)
    else:
        x_loc = sstate.reduced if sstate.reduced is not None else sstate.corpus
        d2, cand = _flat_local_topk(qr, x_loc, sstate.n_real, n_cand, axis)
    # distributed merge: every shard's local top-n_cand is a superset of the
    # global top-n_cand members it owns, so the merged set equals the
    # single-device candidate set exactly
    d2g = jax.lax.all_gather(d2, axis, axis=1, tiled=True)   # (Q, S*n_cand)
    idg = jax.lax.all_gather(cand, axis, axis=1, tiled=True)
    neg, sel = jax.lax.top_k(-d2g, n_cand)
    merged = jnp.take_along_axis(idg, sel, axis=1)
    merged = jnp.where(jnp.isneginf(neg), -1, merged)
    return _sharded_rerank(queries, sstate.corpus, merged, k, axis)


def sharded_search_fn(sstate: ShardedEngineState, queries: jax.Array, k: int,
                      *, mesh: Mesh, axis: str = "data", index: str = "flat",
                      nprobe: int = 8, rerank: int = 64, backend: str = "jnp",
                      interpret: bool = True, lut_dtype: str = "f32"):
    """``search_fn`` partitioned over the ``axis`` of ``mesh``.

    Same contract and — by construction of the distributed merge — the same
    results as the single-device ``search_fn`` on the unsharded state.
    Jit with ``mesh``/``axis`` static (``Mesh`` hashes by shape + devices,
    which is exactly what the compile cache must key on).
    """
    from repro.parallel.sharding import engine_state_specs
    specs = engine_state_specs(sstate, axis)
    core = functools.partial(
        _sharded_core, k=k, index=index, nprobe=nprobe, rerank=rerank,
        backend=backend, interpret=interpret, lut_dtype=lut_dtype, axis=axis,
        slack=mesh.shape[axis] - 1)
    f = shard_map(core, mesh=mesh, in_specs=(specs, P()),
                  out_specs=(P(), P()), check_rep=False)
    return f(sstate, queries)


def _bucket(nq: int, floor: int, small: int = 0) -> int:
    """Smallest power-of-two >= nq, floored at ``floor`` — except batches of
    at most ``small``, which take their own power-of-two bucket so tiny
    batches run a compute-proportional program instead of padding to the
    floor (the small-batch latency cliff; ``small=0`` disables)."""
    pow2 = 1 << max(nq - 1, 0).bit_length()
    if 0 < nq <= small:
        return pow2
    return max(floor, pow2)


class SearchEngine:
    """Build once over a corpus; serve batched k-NN queries.

    Thin wrapper over the functional core: ``__init__`` builds
    ``self.state`` (an ``EngineState``), ``search`` pads the batch to its
    bucket and calls the engine-owned jitted ``search_fn``. Mutating
    ``self.config`` (e.g. ``dataclasses.replace(..., nprobe=16)``) is
    supported — knob changes re-key the jit cache, not the state.
    """

    def __init__(self, corpus: jax.Array, config: ServeConfig):
        self.config = config
        corpus_in = corpus
        corpus = jnp.asarray(corpus, jnp.float32)
        # when the caller's array passes through unconverted, it stays
        # caller-owned: shard(donate=True) must not delete it
        self._user_corpus = corpus if corpus is corpus_in else None
        n, dim = corpus.shape
        key = jax.random.key(config.seed)
        if config.target_dim is not None:
            mcfg = config.mpad or MPADConfig(
                m=config.target_dim, b=80.0, alpha=25.0, iters=48,
                seed=config.seed)
            sample = corpus
            if config.fit_sample < n:
                rows = jax.random.choice(
                    key, n, (config.fit_sample,), replace=False)
                sample = corpus[rows]
            self.reducer: Optional[MPADResult] = fit_mpad(sample, mcfg)
            reduced = self.reducer(corpus)
            proj = (self.reducer.matrix, self.reducer.mean)
        else:
            self.reducer = None
            reduced = corpus
            proj = None
        ivf = pq = ivfpq = None
        if config.index == "ivf":
            ivf = build_ivf(
                jax.random.fold_in(key, 1), reduced, config.nlist)
        elif config.index == "pq":
            pq = build_pq(jax.random.fold_in(key, 2), reduced,
                          config.pq_subspaces, config.pq_centroids)
        elif config.index == "ivfpq":
            ivfpq = build_ivfpq(
                jax.random.fold_in(key, 3), reduced, config.nlist,
                config.pq_subspaces, config.pq_centroids)
        self.state: Optional[EngineState] = EngineState(
            corpus=corpus, proj=proj,
            reduced=reduced if config.index == "flat" else None,
            ivf=ivf, pq=pq, ivfpq=ivfpq)
        self._reduced = reduced      # back-compat view for every index kind
        self.last_bucket: Optional[int] = None   # padded size of the last
        #                                          served batch (shape pin
        #                                          for latency tests)
        self.sharded_state: Optional[ShardedEngineState] = None
        self._mesh: Optional[Mesh] = None
        self._shard_axis = "data"
        self._sharded_program = None
        # engine-owned jit: a fresh closure gives this engine its own
        # compilation cache (jax shares caches for identical function
        # objects), keyed by (statics, query bucket)
        def _engine_search_fn(state, queries, k, **kw):
            return search_fn(state, queries, k, **kw)
        self._program = jax.jit(_engine_search_fn,
                                static_argnames=_SEARCH_STATICS)
        self.store = self.frozen = None          # streaming (write-path) state
        self._stream_sharded_base = None
        self._stream_program = self._stream_sharded_program = None
        self._upsert_program = self._delete_program = None
        self._compact_program = None
        self.grow_count = 0          # compaction-overflow regrowths (rare;
        #                              each one is a recompile point)
        self._delta_used = 0         # conservative host mirror of the delta
        #                              fill (overwrites counted as appends)
        if config.stream is not None:
            self._init_stream()

    def _require_dense(self) -> EngineState:
        if self.state is None:
            raise RuntimeError(
                "the dense EngineState is gone: its buffers were released "
                "by shard(donate=True) or superseded by the streaming "
                "StreamStore (use engine.store / engine.frozen there) — "
                "rebuild the engine to get the read-only views back")
        return self.state

    # back-compat array views into the state pytree
    @property
    def corpus(self) -> jax.Array:
        return self._require_dense().corpus

    @property
    def reduced(self) -> jax.Array:
        if self._reduced is None:
            self._require_dense()
        return self._reduced

    @property
    def ivf(self) -> Optional[IVFIndex]:
        return self._require_dense().ivf

    @property
    def pq(self) -> Optional[PQIndex]:
        return self._require_dense().pq

    @property
    def ivfpq(self) -> Optional[IVFPQIndex]:
        return self._require_dense().ivfpq

    @property
    def compile_count(self) -> int:
        """Number of compiled (statics, bucket) variants this engine holds
        (single-device + sharded + streaming read/write programs)."""
        progs = [self._program, self._sharded_program,
                 self._stream_program, self._stream_sharded_program,
                 self._upsert_program, self._delete_program,
                 self._compact_program]
        try:
            return sum(int(p._cache_size()) for p in progs if p is not None)
        except AttributeError as e:     # private jax hook; fail loudly if
            raise RuntimeError(          # an unpinned jax drops it
                "jax no longer exposes PjitFunction._cache_size(); "
                "SearchEngine.compile_count needs a replacement hook"
            ) from e

    # --- streaming (mutable) serving -------------------------------------

    @property
    def streaming(self) -> bool:
        return self.config.stream is not None

    def _require_stream(self):
        if self.store is None:
            raise RuntimeError(
                "this engine is read-only; enable the write path with "
                "ServeConfig(stream=StreamConfig(...))")

    def _init_stream(self):
        from .segments import compact_fn, delete_fn, make_mutable, upsert_fn
        from .stream import sharded_stream_search_fn, stream_search_fn
        self.store, self.frozen = make_mutable(
            self.state, self.config.stream, self.config.index)
        # the store owns fresh (capacity-padded) copies of every database
        # leaf, so the dense EngineState duplicates them — release the
        # duplicated buffers (the frozen quantizers and any caller-owned
        # corpus stay shared/alive) instead of holding 2x forever
        hold = {id(leaf) for leaf in jax.tree_util.tree_leaves(self.frozen)}
        if self._user_corpus is not None:
            hold.add(id(self._user_corpus))
        dense = {id(a): a for a in jax.tree_util.tree_leaves(self.state)}
        for leaf in dense.values():
            if id(leaf) not in hold and not leaf.is_deleted():
                leaf.delete()
        self.state = None
        self._reduced = None
        # fresh closures: per-engine compile caches, same as _program. The
        # write programs donate the store, so the .at[] updates alias the
        # input buffers instead of copying the row store per write.
        def _engine_stream_fn(store, frozen, queries, k, **kw):
            return stream_search_fn(store, frozen, queries, k, **kw)
        self._stream_program = jax.jit(_engine_stream_fn,
                                       static_argnames=_SEARCH_STATICS)

        def _engine_upsert(store, frozen, ids, vectors):
            return upsert_fn(store, frozen, ids, vectors)
        self._upsert_program = jax.jit(_engine_upsert, donate_argnums=(0,))

        def _engine_delete(store, ids):
            return delete_fn(store, ids)
        self._delete_program = jax.jit(_engine_delete, donate_argnums=(0,))

        def _engine_compact(store, frozen, *, index):
            return compact_fn(store, frozen, index=index)
        self._compact_program = jax.jit(
            _engine_compact, static_argnames=("index",), donate_argnums=(0,))

        def _engine_stream_sharded(sbase, repl, queries, k, **kw):
            return sharded_stream_search_fn(sbase, repl, queries, k, **kw)
        self._stream_sharded_program = jax.jit(
            _engine_stream_sharded,
            static_argnames=_SEARCH_STATICS + ("mesh", "axis"))

    def upsert(self, ids: jax.Array, vectors: jax.Array):
        """Insert or overwrite rows by external id (ids (B,), vectors
        (B, D)). Pure in-place delta appends — no recompilation (batches
        pad to ``StreamConfig.write_bucket``-floored power-of-two buckets)
        and no index rebuild; the delta auto-compacts into the base at
        ``compact_threshold``. Returns ``self``.
        """
        self._require_stream()
        scfg = self.config.stream
        ids = jnp.asarray(ids, jnp.int32).reshape(-1)
        vectors = jnp.asarray(vectors, jnp.float32).reshape(ids.shape[0], -1)
        cap = scfg.delta_capacity
        point = max(1, min(cap, int(scfg.compact_threshold * cap)))
        b = 0
        while b < ids.shape[0]:
            chunk = min(ids.shape[0] - b, point)
            if self._delta_used + chunk > point:
                self.compact()
            cid, cv = ids[b:b + chunk], vectors[b:b + chunk]
            bucket = _bucket(chunk, scfg.write_bucket)
            if bucket != chunk:
                cid = jnp.pad(cid, (0, bucket - chunk), constant_values=-1)
                cv = jnp.pad(cv, ((0, bucket - chunk), (0, 0)))
            # dropped stays 0 by construction (the chunking above never
            # exceeds the compact point), so it is not synced to host here
            self.store, _ = self._upsert_program(self.store, self.frozen,
                                                 cid, cv)
            self._delta_used += chunk
            b += chunk
        return self

    def delete(self, ids: jax.Array):
        """Delete rows by external id: tombstone base copies, punch delta
        holes. Absent ids are no-ops. Returns ``self``."""
        self._require_stream()
        ids = jnp.asarray(ids, jnp.int32).reshape(-1)
        bucket = _bucket(ids.shape[0], self.config.stream.write_bucket)
        if bucket != ids.shape[0]:
            ids = jnp.pad(ids, (0, bucket - ids.shape[0]),
                          constant_values=-1)
        self.store = self._delete_program(self.store, ids)
        return self

    def compact(self):
        """Fold the delta segment into the base index (re-coding against
        the frozen quantizers — shapes and compiled programs survive).

        If the append would overflow the pre-allocated row capacity or a
        posting cell's slack, the store grows host-side and the compaction
        retries: correct, but a recompile point (``grow_count`` ticks) —
        size ``StreamConfig.row_capacity``/``cell_slack`` to avoid it.
        Returns ``self``.
        """
        self._require_stream()
        from .segments import grow_store
        scfg = self.config.stream
        store, dropped = self._compact_program(self.store, self.frozen,
                                               index=self.config.index)
        while int(dropped):
            # one delta's worth of cell slack covers the worst case (every
            # delta row landing in one cell), so a single grow suffices
            store = grow_store(store,
                               row_extra=4 * scfg.delta_capacity,
                               cell_extra=scfg.delta_capacity)
            self.grow_count += 1
            store, dropped = self._compact_program(store, self.frozen,
                                                   index=self.config.index)
        self.store = store
        self._delta_used = 0
        if self._stream_sharded_base is not None:
            self._shard_stream_base()        # re-lay the (grown) base out
        return self

    def _shard_stream_base(self):
        from repro.parallel.engine import shard_stream
        self._stream_sharded_base = shard_stream(
            self.store, self.frozen, self._mesh, axis=self._shard_axis,
            index=self.config.index)

    # --- sharding ---------------------------------------------------------

    def shard(self, mesh: Optional[Mesh] = None, axis: str = "data",
              donate: bool = False):
        """Partition the engine over the ``axis`` of ``mesh`` (default: the
        mesh activated by ``repro.parallel.context.mesh_context``).

        Subsequent ``search`` calls route through ``sharded_search_fn`` —
        same results, database split across the mesh devices. Returns
        ``self`` for chaining. Re-call with a different mesh to re-shard.

        ``donate=True`` releases the dense single-device buffers once the
        sharded copy is placed (no 2x database memory): the back-compat
        views and re-sharding then raise, and switching back via
        ``sharded_state = None`` is no longer possible. With the default
        ``donate=False`` both copies stay live — fine for dry-runs, 2x
        memory at real scale.

        On a streaming engine the **base** shards and the delta segment /
        tombstones stay replicated (writes keep working; ``compact()``
        re-lays the base out). Donation is refused there: the dense store
        is the write path.
        """
        if mesh is None:
            from repro.parallel.context import require_mesh
            mesh = require_mesh("SearchEngine.shard()")
        self._mesh, self._shard_axis = mesh, axis
        if self.streaming:
            if donate:
                raise ValueError(
                    "donate=True is not supported on a streaming engine: "
                    "the dense StreamStore backs upsert/delete/compact")
            self._shard_stream_base()
            return self
        from repro.parallel.engine import shard_engine
        keep = (self._user_corpus,) if self._user_corpus is not None else ()
        self.sharded_state = shard_engine(self._require_dense(), mesh,
                                          axis=axis, donate=donate,
                                          keep=keep)
        if donate:
            self.state = None
            self._reduced = None
            if self.reducer is not None:
                # the dense projection arrays were donated; point the
                # public reducer at the replicated sharded copies so
                # eng.reducer(x) keeps working
                matrix, mean = self.sharded_state.proj
                self.reducer = self.reducer._replace(matrix=matrix,
                                                     mean=mean)
        if self._sharded_program is None:
            def _engine_sharded_fn(sstate, queries, k, **kw):
                return sharded_search_fn(sstate, queries, k, **kw)
            self._sharded_program = jax.jit(
                _engine_sharded_fn,
                static_argnames=_SEARCH_STATICS + ("mesh", "axis"))
        return self

    def search(self, queries: jax.Array, k: int):
        """Returns (dists (Q,k), ids (Q,k)); distances in the original space
        when re-ranking is active, else in the serving (reduced) space.

        One device program per call: the batch is zero-padded up to its
        power-of-two bucket (>= ``config.query_bucket``) so every batch size
        in a bucket reuses the same compilation, then sliced back to Q rows.
        """
        cfg = self.config
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        bucket = _bucket(nq, cfg.query_bucket, cfg.small_batch)
        self.last_bucket = bucket
        if bucket != nq:
            queries = jnp.pad(queries, ((0, bucket - nq), (0, 0)))
        # normalize knobs the index kind can't observe so flipping them
        # (e.g. lut_dtype on a flat engine) never re-keys the jit cache
        probed = cfg.index in ("ivf", "ivfpq")
        coded = cfg.index in ("pq", "ivfpq")
        kw = dict(index=cfg.index,
                  nprobe=cfg.nprobe if probed else 0,
                  rerank=cfg.rerank,
                  backend=cfg.pq_backend if coded else "jnp",
                  interpret=cfg.pq_interpret if coded else True,
                  lut_dtype=cfg.lut_dtype if coded else "f32")
        if self.streaming:
            if self._stream_sharded_base is not None:
                from .stream import StreamReplica
                repl = StreamReplica(
                    row_ids=self.store.row_ids, dead=self.store.dead,
                    delta_vectors=self.store.delta_vectors,
                    delta_reduced=self.store.delta_reduced,
                    delta_ids=self.store.delta_ids,
                    delta_count=self.store.delta_count)
                d, ids = self._stream_sharded_program(
                    self._stream_sharded_base, repl, queries, k,
                    mesh=self._mesh, axis=self._shard_axis, **kw)
            else:
                d, ids = self._stream_program(self.store, self.frozen,
                                              queries, k, **kw)
        elif self.sharded_state is not None:
            d, ids = self._sharded_program(
                self.sharded_state, queries, k, mesh=self._mesh,
                axis=self._shard_axis, **kw)
        else:
            d, ids = self._program(self.state, queries, k, **kw)
        return d[:nq], ids[:nq]
