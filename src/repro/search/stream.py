"""Streaming search: one XLA program over base index + delta segment +
tombstones.

``stream_search_fn`` is the mutable-engine counterpart of
``repro.search.serve.search_fn``: the same project -> probe/scan ->
re-rank pipeline, extended with

* a **tombstone mask** (``live = row_ids >= 0 & ~dead``) applied *before*
  every base top-k, so dead rows can never crowd live candidates out of
  the budget (for the coded indexes the mask rides the additive ``base``
  term, which is what lets the fused Pallas ADC-gather kernel serve the
  masked scan unchanged);
* an **exact delta scan** — recently upserted rows are scored with true
  squared distances in the scan space, so fresh writes are served at full
  fidelity before they are ever quantized;
* a **tombstone-masked merge** of the two layers in one internal id space
  (base row r | delta slot ``n_cap + s``), followed by the shared
  dedup'd exact re-rank (two-source gather) and a final map from internal
  ids to **external** ids.

The base scan dispatches on the frozen quantizers' kind
(``frozen.quant.kind``) through the ops registry
(``IndexOps.stream_scan``), so the streaming read path needs no per-kind
code here. Everything is shape-static in (n_cap, delta capacity, query
bucket), so a serving process upserting/deleting/compacting at full tilt
reuses one compiled program per (index kind, knobs, k, bucket) — pinned
by ``tests/test_stream.py``.

``sharded_stream_search_fn`` runs the same pipeline under ``shard_map``:
the base is partitioned exactly like read-only sharded serving
(``repro.parallel.engine.shard_stream``), while the delta segment,
tombstone bitmap, and id maps **replicate** — writes touch only
replicated leaves, so the sharded base stays valid between compactions
and every shard scans the delta identically (per-shard scan =
``IndexOps.local_scan`` with the replicated ``live`` mask).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .knn import _sq_dists, masked_topk
from .pq import _check_adc_args
from .reducers import reduce_vectors
from .registry import ScanParams, get_ops
from .segments import FrozenParams, StreamStore, live_mask
from .serve import (ShardedEngineState, _check_rerank_budget,
                    _dedupe_candidates)

__all__ = ["stream_search_fn", "sharded_stream_search_fn", "StreamReplica",
           "replica_from_store"]


class StreamReplica(NamedTuple):
    """The replicated (small, write-hot) leaves a sharded streaming search
    needs next to the sharded base: id maps, tombstones, and the delta
    segment. Rebuilt from the ``StreamStore`` per call — upserts and
    deletes never touch the sharded base."""
    row_ids: jax.Array                   # (n_cap,)
    dead: jax.Array                      # (n_cap,) bool
    delta_vectors: jax.Array             # (cap, D)
    delta_reduced: Optional[jax.Array]   # (cap, m)
    delta_ids: jax.Array                 # (cap,)
    delta_count: jax.Array               # ()


def replica_from_store(store: StreamStore) -> StreamReplica:
    """Project the write-hot replicated leaves out of a ``StreamStore``
    (free: a view of the same buffers, fresh every call so the sharded
    read path always serves the latest writes)."""
    return StreamReplica(
        row_ids=store.row_ids, dead=store.dead,
        delta_vectors=store.delta_vectors,
        delta_reduced=store.delta_reduced,
        delta_ids=store.delta_ids, delta_count=store.delta_count)


def _check_stream_backend(kind: str, backend: str):
    if kind in ("pq", "opq") and backend == "kernel":
        raise ValueError(
            f"streaming index={kind!r} needs backend='jnp': the "
            "shared-codes Pallas kernel has no masked entry point for an "
            "arbitrary tombstone bitmap (ivfpq folds the mask into the "
            "base term)")


def _delta_scan(qr, delta_scan_rows, delta_ids, delta_count, n_cap, n_cand):
    """Exact scan of the delta segment in the scan space; internal ids are
    offset by ``n_cap``. Empty/hole slots mask to (+inf, -1)."""
    cap = delta_ids.shape[0]
    alive = (jnp.arange(cap) < delta_count) & (delta_ids >= 0)
    d2 = _sq_dists(qr, delta_scan_rows)
    d2 = jnp.where(alive[None, :], d2, jnp.inf)
    ids = jnp.broadcast_to((n_cap + jnp.arange(cap))[None, :], d2.shape)
    return masked_topk(d2, ids, min(n_cand, cap))


def _stream_rerank(queries, corpus, delta_vectors, cand, k):
    """``exact_rerank`` with the two-source gather: internal ids below
    ``n_cap`` pull base corpus rows, the rest pull delta rows. Returns
    (dists (Q, k), INTERNAL ids (Q, k)); pads are (+inf, -1)."""
    cand, valid = _dedupe_candidates(cand)
    n_cap = corpus.shape[0]
    cap = delta_vectors.shape[0]
    isd = cand >= n_cap
    bv = jnp.take(corpus, jnp.clip(cand, 0, n_cap - 1), axis=0)
    dv = jnp.take(delta_vectors, jnp.clip(cand - n_cap, 0, cap - 1), axis=0)
    cv = jnp.where(isd[..., None], dv, bv)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def _to_external(ids, row_ids, delta_ids):
    """Internal (base row | n_cap + slot) -> external ids; -1 pads kept."""
    n_cap = row_ids.shape[0]
    cap = delta_ids.shape[0]
    ext_b = jnp.take(row_ids, jnp.clip(ids, 0, n_cap - 1))
    ext_d = jnp.take(delta_ids, jnp.clip(ids - n_cap, 0, cap - 1))
    ext = jnp.where(ids >= n_cap, ext_d, ext_b)
    return jnp.where(ids >= 0, ext, -1)


def stream_search_fn(store: StreamStore, frozen: FrozenParams,
                     queries: jax.Array, k: int, *,
                     nprobe: int = 8, rerank: int = 64, backend: str = "jnp",
                     interpret: bool = True, lut_dtype: str = "f32",
                     scan_cap: int = 0, prefilter: int = 0):
    """The mutable-engine query pipeline as one pure traceable function.

    project -> tombstone-masked base probe/scan (``IndexOps.stream_scan``
    on the frozen kind) -> exact delta scan -> merged top-C -> two-source
    exact re-rank -> external-id top-k.
    Returns (dists (Q, k), external ids (Q, k)); -1 ids pad short rows.
    """
    if scan_cap or prefilter:
        raise ValueError(
            "scan_cap/prefilter are read-only fast paths: the compact "
            "scan's posting-mass cap goes stale under writes and the "
            "pre-filter bounds ignore tombstones — leave both 0 on the "
            "streaming path")
    kind = frozen.quant.kind
    ops = get_ops(kind)
    _check_adc_args(backend, lut_dtype)
    _check_stream_backend(kind, backend)
    queries = jnp.asarray(queries, jnp.float32)
    with jax.named_scope("qpad.project"):
        qr = reduce_vectors(frozen.proj, queries)
    approximate = frozen.proj is not None or ops.lossy
    _check_rerank_budget(approximate, rerank, k)
    n_cand = rerank if approximate else k
    live = live_mask(store)
    n_cap = store.corpus.shape[0]
    p = ScanParams(nprobe=nprobe, backend=backend, interpret=interpret,
                   lut_dtype=lut_dtype)
    with jax.named_scope("qpad.base_scan"):
        bd2, bids = ops.stream_scan(store, frozen, qr, n_cand, live, p)
    delta_scan_rows = (store.delta_reduced
                       if store.delta_reduced is not None
                       else store.delta_vectors)
    with jax.named_scope("qpad.delta_scan"):
        dd2, dids = _delta_scan(qr, delta_scan_rows, store.delta_ids,
                                store.delta_count, n_cap, n_cand)
    with jax.named_scope("qpad.merge"):
        md2, mids = masked_topk(jnp.concatenate([bd2, dd2], axis=1),
                                jnp.concatenate([bids, dids], axis=1),
                                n_cand)
    with jax.named_scope("qpad.rerank"):
        dists, internal = _stream_rerank(queries, store.corpus,
                                         store.delta_vectors, mids, k)
    return dists, _to_external(internal, store.row_ids, store.delta_ids)


# --- sharded streaming (base sharded, delta + tombstones replicated) ---------

def _stream_sharded_core(sbase: ShardedEngineState, repl: StreamReplica,
                         queries: jax.Array, *, k: int,
                         nprobe: int, rerank: int, backend: str,
                         interpret: bool, lut_dtype: str, axis: str):
    """The shard_map body: masked per-shard base scan + replicated delta
    scan + distributed merge + two-source re-rank."""
    ops = get_ops(sbase.index.kind)
    queries = jnp.asarray(queries, jnp.float32)
    with jax.named_scope("qpad.project"):
        qr = reduce_vectors(sbase.proj, queries)
    approximate = sbase.proj is not None or ops.lossy
    _check_rerank_budget(approximate, rerank, k)
    n_cand = rerank if approximate else k
    live = (repl.row_ids >= 0) & ~repl.dead
    n_cap = repl.row_ids.shape[0]
    p = ScanParams(nprobe=nprobe, backend=backend, interpret=interpret,
                   lut_dtype=lut_dtype)
    with jax.named_scope("qpad.base_scan"):
        d2, cand = ops.local_scan(sbase, qr, n_cand, p, axis, 0, live=live)
        d2g = jax.lax.all_gather(d2, axis, axis=1, tiled=True)
        idg = jax.lax.all_gather(cand, axis, axis=1, tiled=True)
        bd2, bids = masked_topk(d2g, idg, n_cand)
    delta_scan_rows = (repl.delta_reduced if repl.delta_reduced is not None
                       else repl.delta_vectors)
    with jax.named_scope("qpad.delta_scan"):
        dd2, dids = _delta_scan(qr, delta_scan_rows, repl.delta_ids,
                                repl.delta_count, n_cap, n_cand)
    md2, mids = masked_topk(jnp.concatenate([bd2, dd2], axis=1),
                            jnp.concatenate([bids, dids], axis=1), n_cand)
    # two-source re-rank: base rows scored by their owner shard, delta rows
    # scored identically on every shard; pmin assembles the full row
    cand2, valid = _dedupe_candidates(mids)
    n_loc = sbase.corpus.shape[0]
    cap = repl.delta_vectors.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    isd = cand2 >= n_cap
    local = cand2 - off
    own_base = valid & ~isd & (local >= 0) & (local < n_loc)
    bv = jnp.take(sbase.corpus, jnp.clip(local, 0, n_loc - 1), axis=0)
    dv = jnp.take(repl.delta_vectors,
                  jnp.clip(cand2 - n_cap, 0, cap - 1), axis=0)
    cv = jnp.where(isd[..., None], dv, bv)
    d2 = jnp.sum((cv - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(own_base | (valid & isd), d2, jnp.inf)
    d2 = jax.lax.pmin(d2, axis)
    neg, sel = jax.lax.top_k(-d2, k)
    internal = jnp.take_along_axis(cand2, sel, axis=1)
    internal = jnp.where(jnp.isneginf(neg), -1, internal)
    dists = jnp.sqrt(jnp.maximum(-neg, 0.0))
    return dists, _to_external(internal, repl.row_ids, repl.delta_ids)


def sharded_stream_search_fn(sbase: ShardedEngineState, repl: StreamReplica,
                             queries: jax.Array, k: int, *, mesh: Mesh,
                             axis: str = "data",
                             nprobe: int = 8, rerank: int = 64,
                             backend: str = "jnp", interpret: bool = True,
                             lut_dtype: str = "f32",
                             scan_cap: int = 0, prefilter: int = 0):
    """``stream_search_fn`` with the base partitioned over ``mesh``.

    Same results as the single-device streaming search on the unsharded
    store: the per-shard masked scans keep a full local top-C (so the
    merged base candidate set is exact), and the delta scan is replicated
    math. Jit with ``mesh``/``axis`` static.
    """
    from repro.parallel.sharding import engine_state_specs
    if scan_cap or prefilter:
        raise ValueError(
            "scan_cap/prefilter are single-device read-only fast paths — "
            "leave both 0 on the sharded streaming path")
    _check_stream_backend(sbase.index.kind, backend)
    base_specs = engine_state_specs(sbase, axis)
    repl_specs = StreamReplica(*[None if getattr(repl, f) is None else P()
                                 for f in StreamReplica._fields])
    core = functools.partial(
        _stream_sharded_core, k=k, nprobe=nprobe, rerank=rerank,
        backend=backend, interpret=interpret, lut_dtype=lut_dtype, axis=axis)
    f = shard_map(core, mesh=mesh, in_specs=(base_specs, repl_specs, P()),
                  out_specs=(P(), P()), check_rep=False)
    return f(sbase, repl, queries)
