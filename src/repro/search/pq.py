"""Product quantization (Jégou et al., TPAMI'11): the classic complement to
DR in vector-search memory hierarchies. MPAD reduces dimensionality; PQ
compresses the residual precision — together: f32 n-dim -> uint8 codes.

Asymmetric distance computation (ADC): per-query distance tables
(M x n_centroids) against subspace codebooks, then code lookups — no
decompression of the corpus.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc.ref import pq_adc_scores_ref
from .ivf import kmeans, sq_dists

__all__ = ["PQIndex", "build_pq", "pq_search", "pq_reconstruct"]


class PQIndex(NamedTuple):
    codebooks: jax.Array    # (M, K, dsub)
    codes: jax.Array        # (N, M) uint8/int32 centroid ids


def build_pq(key: jax.Array, x: jax.Array, m_subspaces: int = 8,
             n_centroids: int = 256, iters: int = 10) -> PQIndex:
    """Train per-subspace codebooks and encode the corpus."""
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    if d % m_subspaces:
        raise ValueError(f"dim {d} not divisible by M={m_subspaces}")
    dsub = d // m_subspaces
    xs = x.reshape(n, m_subspaces, dsub)
    cbs, codes = [], []
    for m in range(m_subspaces):
        sub = xs[:, m]
        cb = kmeans(jax.random.fold_in(key, m), sub,
                    min(n_centroids, n), iters)
        cbs.append(cb)
        codes.append(jnp.argmin(sq_dists(sub, cb), axis=1))
    return PQIndex(codebooks=jnp.stack(cbs),
                   codes=jnp.stack(codes, axis=1).astype(jnp.int32))


def pq_reconstruct(index: PQIndex) -> jax.Array:
    """Decode the corpus (for error analysis): (N, D)."""
    m = index.codebooks.shape[0]
    parts = [index.codebooks[j][index.codes[:, j]] for j in range(m)]
    return jnp.concatenate(parts, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "backend", "interpret"))
def pq_search(index: PQIndex, q: jax.Array, k: int, backend: str = "jnp",
              interpret: bool = True):
    """ADC top-k: returns (approx dists (Q,k), ids (Q,k)).

    ``backend="jnp"`` scores with vectorized table lookups; ``"kernel"``
    dispatches the fused Pallas ADC scan (``repro.kernels.pq_adc``),
    identical semantics, tiled + running top-k on device.
    """
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"unknown ADC backend {backend!r}")
    q = jnp.asarray(q, jnp.float32)
    nq, d = q.shape
    m, kc, dsub = index.codebooks.shape
    qs = q.reshape(nq, m, dsub)
    # distance tables: (Q, M, K)
    tables = (jnp.sum(qs * qs, -1)[:, :, None]
              + jnp.sum(index.codebooks ** 2, -1)[None]
              - 2.0 * jnp.einsum("qmd,mkd->qmk", qs, index.codebooks))
    if backend == "kernel":
        from repro.kernels.pq_adc import pq_adc_topk_pallas
        d2, ids = pq_adc_topk_pallas(tables, index.codes, k,
                                     interpret=interpret)
        return jnp.sqrt(jnp.maximum(d2, 0.0)), ids
    neg, ids = jax.lax.top_k(-pq_adc_scores_ref(tables, index.codes), k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids
