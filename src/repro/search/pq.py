"""Product quantization (Jégou et al., TPAMI'11): the classic complement to
DR in vector-search memory hierarchies. MPAD reduces dimensionality; PQ
compresses the residual precision — together: f32 n-dim -> uint8 codes.

Asymmetric distance computation (ADC): per-query distance tables
(M x n_centroids) against subspace codebooks, then code lookups — no
decompression of the corpus. ``lut_dtype`` quantizes the tables themselves
(f32 -> bf16/int8, see ``repro.kernels.pq_adc.lut``) for a 2-4x LUT memory
cut on both scoring backends.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc.lut import LUT_DTYPES, center_lut
from repro.kernels.pq_adc.ref import pq_adc_scores_ref
from .ivf import kmeans, sq_dists
from .knn import masked_topk

__all__ = ["PQIndex", "adc_tables", "build_pq", "lut_projection",
           "pq_local_scan", "pq_scan", "pq_search", "pq_reconstruct"]


class PQIndex(NamedTuple):
    codebooks: jax.Array    # (M, K, dsub)
    codes: jax.Array        # (N, M) uint8/int32 centroid ids
    lut_w: jax.Array        # (d, M*K) block-diagonal -2*codebook projection
    cbnorm: jax.Array       # (M, K) per-codeword squared norms


def lut_projection(codebooks: jax.Array):
    """Build-time table factorization: (lut_w (d, M*K), cbnorm (M, K)).

    The candidate-varying part of the per-query ADC tables is
    ``||cb||^2 - 2<q_m, cb[m,k]>``; ``lut_w`` stores the whole projection
    as one block-diagonal (d, M*K) matrix (block m = -2 * cb[m].T) — a
    single array any consumer can contract however its backend likes.
    ``adc_tables`` is the scan-path contraction of it.
    """
    m, kc, dsub = codebooks.shape
    w = jnp.zeros((m * dsub, m * kc), jnp.float32)
    for j in range(m):                                    # M small: unrolled
        w = w.at[j * dsub:(j + 1) * dsub, j * kc:(j + 1) * kc].set(
            -2.0 * codebooks[j].T)
    return w, jnp.sum(codebooks ** 2, -1)


def adc_tables(lut_w: jax.Array, cbnorm: jax.Array, q: jax.Array) -> jax.Array:
    """Per-query ADC tables (Q, M, K): ``cbnorm + (q @ lut_w).reshape``,
    contracted subspace-by-subspace.

    The dense (Q, d) @ (d, M*K) form spends M x the necessary FLOPs on the
    block-diagonal zeros; extracting the M diagonal (dsub, K) blocks (a
    32k-element gather) and running ONE batched ``dot_general`` over the
    subspace axis is ~3x faster at serving batches on CPU — and exact: the
    dropped products are exact zeros, so the result is bit-identical to
    the dense matmul. (The per-subspace einsum lowering XLA picks for
    ``qmd,mkd->qmk`` is far slower at batch >= 256; don't "simplify" back
    to it.)
    """
    m, kc = cbnorm.shape
    nq, d = q.shape
    dsub = d // m
    blocks = lut_w.reshape(m, dsub, m, kc)[
        jnp.arange(m), :, jnp.arange(m), :]               # (M, dsub, K)
    qs = q.reshape(nq, m, dsub).transpose(1, 0, 2)        # (M, Q, dsub)
    t = jax.lax.dot_general(qs, blocks, (((2,), (1,)), ((0,), (0,))))
    return cbnorm[None] + t.transpose(1, 0, 2)


def build_pq(key: jax.Array, x: jax.Array, m_subspaces: int = 8,
             n_centroids: int = 256, iters: int = 10) -> PQIndex:
    """Train per-subspace codebooks and encode the corpus."""
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    if d % m_subspaces:
        raise ValueError(f"dim {d} not divisible by M={m_subspaces}")
    dsub = d // m_subspaces
    xs = x.reshape(n, m_subspaces, dsub)
    cbs, codes = [], []
    for m in range(m_subspaces):
        sub = xs[:, m]
        cb = kmeans(jax.random.fold_in(key, m), sub,
                    min(n_centroids, n), iters)
        cbs.append(cb)
        codes.append(jnp.argmin(sq_dists(sub, cb), axis=1))
    cbs = jnp.stack(cbs)
    lut_w, cbnorm = lut_projection(cbs)
    # uint8 code storage end-to-end: both scoring backends gather the
    # narrow codes and widen in-register, so 4x fewer candidate bytes move
    code_dt = jnp.uint8 if n_centroids <= 256 else jnp.int32
    return PQIndex(codebooks=cbs,
                   codes=jnp.stack(codes, axis=1).astype(code_dt),
                   lut_w=lut_w, cbnorm=cbnorm)


def pq_reconstruct(index: PQIndex) -> jax.Array:
    """Decode the corpus (for error analysis): (N, D)."""
    m = index.codebooks.shape[0]
    parts = [index.codebooks[j][index.codes[:, j]] for j in range(m)]
    return jnp.concatenate(parts, axis=1)


def _check_adc_args(backend: str, lut_dtype: str):
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"unknown ADC backend {backend!r}")
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(
            f"unknown lut_dtype {lut_dtype!r}; expected one of {LUT_DTYPES}")


def pq_scan(index: PQIndex, q: jax.Array, k: int, backend: str = "jnp",
            interpret: bool = True, lut_dtype: str = "f32"):
    """Unjitted ``pq_search`` core (inlineable into fused programs).

    Only the candidate-varying table part (||cb||^2 - 2<q, cb>) goes through
    the (possibly quantized) scan; the per-query constants — ||q||^2 and,
    when quantizing, the table row means (``center_lut``) — stay in f32 and
    are added back after top-k, so they cost no quantization range and
    cannot perturb the ranking.
    """
    _check_adc_args(backend, lut_dtype)
    q = jnp.asarray(q, jnp.float32)
    m, kc, dsub = index.codebooks.shape
    tables = adc_tables(index.lut_w, index.cbnorm, q)
    const = jnp.sum(q * q, axis=1)                        # (Q,) ||q||^2
    if lut_dtype != "f32":
        tables, offs = center_lut(tables)
        const = const + offs
    if backend == "kernel":
        from repro.kernels.pq_adc import pq_adc_topk_pallas
        d2, ids = pq_adc_topk_pallas(tables, index.codes, k,
                                     interpret=interpret,
                                     lut_dtype=lut_dtype)
    else:
        scores = pq_adc_scores_ref(tables, index.codes, lut_dtype)
        neg, ids = jax.lax.top_k(-scores, k)
        d2 = -neg
    return jnp.sqrt(jnp.maximum(d2 + const[:, None], 0.0)), ids


def pq_local_scan(lut_w: jax.Array, cbnorm: jax.Array, codes_loc: jax.Array,
                  q: jax.Array, n_cand: int, n_real: jax.Array, axis: str,
                  backend: str = "jnp", interpret: bool = True,
                  lut_dtype: str = "f32", slack: int = 0,
                  live=None):
    """Shard-local plain-PQ ADC scan (a ``shard_map`` body of sharded
    serving): score this shard's row block of the code matrix and return
    **global** row ids via the shard offset.

    ``codes_loc`` is a (n_loc, M) block of the row-padded code matrix; rows
    whose global id (``axis_index * n_loc + row``) lands at or beyond
    ``n_real`` are shard padding and masked to (+inf, -1). On the kernel
    backend the fused scan cannot see the validity mask, so it over-fetches
    ``slack`` extra rows (>= the pad-row count, i.e. shards - 1) and drops
    pads post-hoc — see ``pq_adc_topk_global``. The per-query table is
    quantized exactly as on the single-device path; the centered constant
    is per-query and therefore ranking-invariant, so it is dropped here
    (final distances come from the exact re-rank).

    ``live`` (replicated (N,) bool, streaming serving) masks
    tombstoned/unallocated global rows before the local top-k. The fused
    kernel's validity handling is a prefix bound (``n_valid``), so an
    arbitrary tombstone bitmap needs ``backend="jnp"``.
    """
    _check_adc_args(backend, lut_dtype)
    q = jnp.asarray(q, jnp.float32)
    nq = q.shape[0]
    m, kc = cbnorm.shape
    tables = adc_tables(lut_w, cbnorm, q)
    if lut_dtype != "f32":
        tables, _ = center_lut(tables)
    n_loc = codes_loc.shape[0]
    off = jax.lax.axis_index(axis) * n_loc
    if backend == "kernel":
        if live is not None:
            raise ValueError(
                "pq_local_scan(live=...) needs backend='jnp': the "
                "shared-codes kernel only masks a row-count prefix")
        from repro.kernels.pq_adc.ops import pq_adc_topk_global
        return pq_adc_topk_global(tables, codes_loc, n_cand, row_offset=off,
                                  n_valid=n_real, slack=slack,
                                  interpret=interpret, lut_dtype=lut_dtype)
    scores = pq_adc_scores_ref(tables, codes_loc, lut_dtype)
    gid = off + jnp.arange(n_loc)
    ok = gid[None, :] < n_real
    if live is not None:
        n_cap = live.shape[0]
        ok = ok & live[jnp.clip(gid, 0, n_cap - 1)][None, :]
    scores = jnp.where(ok, scores, jnp.inf)
    return masked_topk(scores, jnp.broadcast_to(gid[None, :], scores.shape),
                       n_cand)


@functools.partial(jax.jit,
                   static_argnames=("k", "backend", "interpret", "lut_dtype"))
def pq_search(index: PQIndex, q: jax.Array, k: int, backend: str = "jnp",
              interpret: bool = True, lut_dtype: str = "f32"):
    """ADC top-k: returns (approx dists (Q,k), ids (Q,k)).

    ``backend="jnp"`` scores with vectorized table lookups; ``"kernel"``
    dispatches the fused Pallas ADC scan (``repro.kernels.pq_adc``),
    identical semantics, tiled + running top-k on device. ``lut_dtype``
    quantizes the per-query tables (both backends score through the same
    quantization, so they stay parity oracles of each other).
    """
    return pq_scan(index, q, k, backend, interpret, lut_dtype)
