"""Vector-search substrate: brute-force k-NN, recall metrics, IVF-Flat /
PQ / IVF-PQ ANN indexes, and the batched serving engine that integrates
MPAD reduction."""
from .knn import (knn_search, knn_search_blocked, masked_topk, recall_at_k,
                  amk_accuracy)
from .ivf import (IVFIndex, build_ivf, cell_vectors, ivf_search,
                  posting_lists, probe_cells)
from .ivfpq import IVFPQIndex, build_ivfpq, ivfpq_search
from .pq import PQIndex, build_pq, pq_search, pq_reconstruct
from .serve import (EngineState, INDEX_KINDS, SearchEngine, ServeConfig,
                    ShardedEngineState, exact_rerank, search_fn,
                    sharded_search_fn)

__all__ = [
    "knn_search", "knn_search_blocked", "masked_topk", "recall_at_k",
    "amk_accuracy",
    "IVFIndex", "build_ivf", "cell_vectors", "ivf_search", "posting_lists",
    "probe_cells",
    "IVFPQIndex", "build_ivfpq", "ivfpq_search",
    "PQIndex", "build_pq", "pq_search", "pq_reconstruct",
    "SearchEngine", "ServeConfig", "EngineState", "ShardedEngineState",
    "search_fn", "sharded_search_fn", "exact_rerank", "INDEX_KINDS",
]
