"""Vector-search substrate: brute-force k-NN, recall metrics, IVF-Flat /
PQ / IVF-PQ ANN indexes, the batched serving engine that integrates MPAD
reduction, and the streaming (mutable) layer on top of it."""
from .knn import (knn_search, knn_search_blocked, masked_topk, recall_at_k,
                  amk_accuracy)
from .ivf import (IVFIndex, balance_cells, build_ivf, cell_vectors,
                  ivf_search, posting_lists, probe_cells)
from .ivfpq import IVFPQIndex, build_ivfpq, ivfpq_search
from .pq import PQIndex, build_pq, pq_search, pq_reconstruct
from .segments import (FrozenParams, MutableEngineState, StreamStore,
                       compact_fn, delete_fn, make_mutable, rebuild_state,
                       upsert_fn)
from .serve import (EngineState, INDEX_KINDS, SearchEngine, ServeConfig,
                    ShardedEngineState, StreamConfig, exact_rerank,
                    search_fn, sharded_search_fn)
from .stream import StreamReplica, sharded_stream_search_fn, stream_search_fn

__all__ = [
    "knn_search", "knn_search_blocked", "masked_topk", "recall_at_k",
    "amk_accuracy",
    "IVFIndex", "balance_cells", "build_ivf", "cell_vectors", "ivf_search",
    "posting_lists", "probe_cells",
    "IVFPQIndex", "build_ivfpq", "ivfpq_search",
    "PQIndex", "build_pq", "pq_search", "pq_reconstruct",
    "SearchEngine", "ServeConfig", "EngineState", "ShardedEngineState",
    "search_fn", "sharded_search_fn", "exact_rerank", "INDEX_KINDS",
    "StreamConfig", "StreamStore", "MutableEngineState", "FrozenParams",
    "make_mutable", "upsert_fn", "delete_fn", "compact_fn", "rebuild_state",
    "StreamReplica", "stream_search_fn", "sharded_stream_search_fn",
]
