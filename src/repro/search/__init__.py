"""Vector-search substrate: brute-force k-NN, recall metrics, IVF-Flat ANN
index, and the batched serving engine that integrates MPAD reduction."""
from .knn import knn_search, knn_search_blocked, recall_at_k, amk_accuracy
from .ivf import IVFIndex, build_ivf, ivf_search
from .pq import PQIndex, build_pq, pq_search, pq_reconstruct
from .serve import SearchEngine, ServeConfig

__all__ = [
    "knn_search", "knn_search_blocked", "recall_at_k", "amk_accuracy",
    "IVFIndex", "build_ivf", "ivf_search",
    "PQIndex", "build_pq", "pq_search", "pq_reconstruct",
    "SearchEngine", "ServeConfig",
]
