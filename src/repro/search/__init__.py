"""Vector-search substrate: brute-force k-NN, recall metrics, IVF-Flat /
PQ / IVF-PQ ANN indexes, the composable index-spec API (pipeline specs +
the tagged index union + ops registry), the batched serving engine that
integrates MPAD reduction, the streaming (mutable) layer on top of it,
snapshot persistence, the durability subsystem (write-ahead log, crash
recovery, maintenance policy), the replication layer (WAL shipping +
follower catch-up, incremental snapshot chains, group commit), the
typed metrics surface, and request-level tracing (latency histograms,
sampled deep traces, slow-query capture, online recall estimation)."""
from .knn import (knn_search, knn_search_blocked, masked_topk, recall_at_k,
                  amk_accuracy)
from .ivf import (IVFIndex, balance_cells, build_ivf, cell_vectors,
                  ivf_search, posting_lists, probe_cells)
from .ivfpq import IVFPQIndex, build_ivfpq, ivfpq_search
from .pq import PQIndex, build_pq, pq_search, pq_reconstruct
from .spec import (Coarse, Code, IndexSpec, Reduce, Rerank, format_spec,
                   parse_spec, spec_from_config)
from .reducers import (REDUCER_KINDS, Reducer, ReducerOps, fit_reducer,
                       get_reducer_ops, reduce_vectors, reducer_dim,
                       register_reducer)
from .registry import Index, IndexOps, ScanParams, get_ops, register_index
from .segments import (FrozenParams, MutableEngineState, StreamStore,
                       compact_fn, delete_fn, make_mutable, rebuild_state,
                       upsert_fn)
from .serve import (EngineState, INDEX_KINDS, SearchEngine, ServeConfig,
                    ShardedEngineState, StreamConfig, build_engine,
                    config_from_spec, exact_rerank, search_fn,
                    sharded_search_fn)
from .snapshot import load_engine, save_engine
from .stream import (StreamReplica, replica_from_store,
                     sharded_stream_search_fn, stream_search_fn)
from .durability import (CatchUpStats, Decision, DivergenceError,
                         DurabilityConfig, LocalDirSource, MaintenancePolicy,
                         PolicyConfig, ReplayStats, ReplicationError, Wal,
                         WalError, WalSource, catch_up, replay,
                         replay_records, seed_follower)
from .metrics import (CompactMetrics, EngineInfo, EngineMetrics,
                      HistogramSnapshot, LatencyMetrics, MetricsServer,
                      PolicyMetrics, RecallMetrics, ReplicationMetrics,
                      SnapshotMetrics, StreamMetrics, WalMetrics,
                      collect_metrics, render_prometheus)
from .tracing import TraceConfig, Tracer, deep_trace, jax_profile

__all__ = [
    "knn_search", "knn_search_blocked", "masked_topk", "recall_at_k",
    "amk_accuracy",
    "IVFIndex", "balance_cells", "build_ivf", "cell_vectors", "ivf_search",
    "posting_lists", "probe_cells",
    "IVFPQIndex", "build_ivfpq", "ivfpq_search",
    "PQIndex", "build_pq", "pq_search", "pq_reconstruct",
    # the composable index-spec API
    "IndexSpec", "Reduce", "Coarse", "Code", "Rerank",
    "parse_spec", "format_spec", "spec_from_config", "config_from_spec",
    "Index", "IndexOps", "ScanParams", "get_ops", "register_index",
    # the reducer zoo (pluggable Reduce stage)
    "Reducer", "ReducerOps", "register_reducer", "get_reducer_ops",
    "fit_reducer", "reduce_vectors", "reducer_dim", "REDUCER_KINDS",
    # engine + lifecycle
    "SearchEngine", "ServeConfig", "EngineState", "ShardedEngineState",
    "build_engine", "save_engine", "load_engine",
    "search_fn", "sharded_search_fn", "exact_rerank", "INDEX_KINDS",
    # streaming
    "StreamConfig", "StreamStore", "MutableEngineState", "FrozenParams",
    "make_mutable", "upsert_fn", "delete_fn", "compact_fn", "rebuild_state",
    "StreamReplica", "replica_from_store", "stream_search_fn",
    "sharded_stream_search_fn",
    # durability: WAL + crash recovery + maintenance policy
    "DurabilityConfig", "Wal", "WalError", "replay", "ReplayStats",
    "replay_records",
    "PolicyConfig", "MaintenancePolicy", "Decision",
    # replication: WAL shipping + follower catch-up
    "ReplicationError", "DivergenceError", "WalSource", "LocalDirSource",
    "CatchUpStats", "catch_up", "seed_follower",
    # typed metrics / observability
    "EngineMetrics", "EngineInfo", "StreamMetrics", "CompactMetrics",
    "PolicyMetrics", "WalMetrics", "SnapshotMetrics", "ReplicationMetrics",
    "HistogramSnapshot", "LatencyMetrics", "RecallMetrics",
    "collect_metrics", "render_prometheus", "MetricsServer",
    # request-level tracing
    "TraceConfig", "Tracer", "deep_trace", "jax_profile",
]
