"""Mutable serving state: delta segments, tombstones, and jit-compiled
compaction over a frozen base index.

The read-only engine (``repro.search.serve``) freezes the corpus at build
time; real deployments continuously upsert and delete vectors. This module
is the **write path**: an LSM-flavored two-layer layout whose every
operation is a pure jit-stable function over fixed-shape arrays, so a
serving process never recompiles per write.

Layers
------

* **base** — the built index arrays, re-padded to a fixed *row capacity*
  ``n_cap >= N`` (and, for IVF layouts, per-cell *pad slack* on the posting
  lists) so compaction can append without changing any array shape.
  ``row_ids (n_cap,)`` maps base row -> external id (-1 = unallocated
  slot); ``dead (n_cap,) bool`` is the **tombstone bitmap** masking
  deleted/overwritten rows out of every scan.
* **delta** — a fixed-capacity segment of recently upserted vectors,
  scanned *exactly* in the reduced space (no quantization staleness for
  fresh rows). ``delta_ids (cap,)`` holds external ids, -1 = empty slot or
  deletion hole; ``delta_count`` is the append pointer.

Quantizers (MPAD projection + the index kind's frozen payload — coarse
centroids, PQ codebooks and their LUT factorization — carried as the
tagged ``Index`` union in ``FrozenParams.quant``) are **frozen** at build
time; compaction re-codes delta rows against them, never retrains — which
is exactly what keeps the compiled serve programs cache-valid across the
whole write lifecycle.

Operations (all pure; the engine jits them with the store donated, so XLA
aliases the buffers and the ``.at[]`` writes happen in place):

* ``upsert_fn(store, frozen, ids, vectors)`` — tombstone any base copy of
  each id, overwrite an existing delta slot for the id or append a new
  one. Later rows of a batch win over earlier ones (sequential
  semantics); ``id == -1`` rows are no-ops, so batches can be padded to
  fixed bucket shapes.
* ``delete_fn(store, ids)`` — tombstone base copies, punch holes in the
  delta. Deleting an absent id is a no-op.
* ``compact_fn(store, frozen)`` — fold the delta into the base:
  re-encode against the frozen quantizers (``IndexOps.encode_delta``),
  append rows into the row store and the cell-major
  ``codes_cell``/``bias_cell`` mirrors, extend posting lists into their
  pad slack, clear the delta. All-or-nothing: if the append would
  overflow the row capacity or any cell's slack, the state is returned
  unchanged with a nonzero dropped-count and the caller grows the store
  host-side (``grow_store`` — a rare, amortized reshape that is the only
  recompile point in the subsystem).

``rebuild_state`` builds a fresh read-only ``EngineState`` over any row
set with the same frozen quantizers — the from-scratch oracle the
streaming equivalence tests (and offline full rebuilds) compare against.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .durability.policy import PolicyConfig
from .ivf import sq_dists
from .reducers import Reducer, reduce_vectors, reducer_dim
from .registry import Index, _pad_cells, _pad_rows, get_ops

__all__ = ["StreamConfig", "StreamStore", "MutableEngineState",
           "FrozenParams", "make_mutable", "upsert_fn", "delete_fn",
           "compact_fn", "grow_store", "live_mask", "rebuild_state",
           "encode_pq", "ivfpq_encode"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Write-path knobs (``SearchEngine.streaming`` / ``ServeConfig.stream``
    enable streaming)."""
    delta_capacity: int = 256        # fixed delta segment size (rows)
    compact_threshold: float = 0.75  # auto-compact when the delta holds
    #                                  this fraction of its capacity
    row_capacity: Optional[int] = None   # total base row slots; None =
    #                                      N + 4 * delta_capacity
    cell_slack: Optional[int] = None     # extra posting slots per cell for
    #                                      compaction appends; None =
    #                                      delta_capacity
    write_bucket: int = 64           # min padded write-batch size; ragged
    #                                  batches round up to powers of two
    background_compact: bool = False     # double-buffered compaction: fold
    #                                      a copy off-thread while searches
    #                                      keep serving the old store, then
    #                                      swap atomically
    policy: Optional[PolicyConfig] = None    # maintenance thresholds
    #                                          (tombstone density, drift,
    #                                          headroom); None = defaults

    def __post_init__(self):
        if self.delta_capacity < 1:
            raise ValueError("delta_capacity must be >= 1")
        if not (0.0 < self.compact_threshold <= 1.0):
            raise ValueError("compact_threshold must be in (0, 1]")
        if self.cell_slack is not None and self.cell_slack < 1:
            raise ValueError("cell_slack must be >= 1")
        if self.write_bucket < 1:
            raise ValueError("write_bucket must be >= 1")
        if self.policy is not None and not isinstance(self.policy,
                                                      PolicyConfig):
            raise TypeError(
                "StreamConfig.policy must be a "
                "repro.search.durability.PolicyConfig (or None)")


class FrozenParams(NamedTuple):
    """Build-time quantizers shared by base and delta; never mutated (and
    never donated), so they can alias the original ``EngineState``.

    ``quant`` is the tagged union: the index kind plus its frozen payload
    (None for flat, coarse centroids for ivf, ``PQQuant`` /
    ``IVFPQQuant`` for the coded kinds). The accessor properties give the
    per-array views the scan/encode code reads.
    """
    proj: Optional[Reducer]                       # fitted Reduce stage
    quant: Index                                  # kind + frozen quantizers

    @property
    def kind(self) -> str:
        return self.quant.kind

    @property
    def centroids(self) -> Optional[jax.Array]:
        q = self.quant.payload
        if self.quant.kind == "ivf":
            return q
        return getattr(q, "centroids", None)

    @property
    def codebooks(self) -> Optional[jax.Array]:
        return getattr(self.quant.payload, "codebooks", None)

    @property
    def lut_w(self) -> Optional[jax.Array]:
        return getattr(self.quant.payload, "lut_w", None)

    @property
    def cbnorm(self) -> Optional[jax.Array]:
        return getattr(self.quant.payload, "cbnorm", None)


class StreamStore(NamedTuple):
    """Every mutable leaf of the streaming engine, one fixed-shape pytree.

    Internal id space: base row r in [0, n_cap) | delta slot s as
    ``n_cap + s``. External ids live in ``row_ids``/``delta_ids``.
    """
    corpus: jax.Array               # (n_cap, D) original-space row store
    row_ids: jax.Array              # (n_cap,) int32 row -> external id, -1
    n_rows: jax.Array               # () int32 allocated base rows
    dead: jax.Array                 # (n_cap,) bool tombstone bitmap
    reduced: Optional[jax.Array]    # (n_cap, m) scan-space rows (None = no
    #                                 projection; scan from ``corpus``)
    codes: Optional[jax.Array]      # (n_cap, M) uint8/int32 pq/ivfpq codes
    bias: Optional[jax.Array]       # (n_cap,) f32 ivfpq cross term
    lists: Optional[jax.Array]      # (nlist, mc_cap) posting lists, -1 pad
    codes_cell: Optional[jax.Array]  # (nlist, mc_cap, M) cell-major codes
    bias_cell: Optional[jax.Array]   # (nlist, mc_cap) cell-major bias
    delta_vectors: jax.Array        # (cap, D) original-space delta rows
    delta_reduced: Optional[jax.Array]  # (cap, m) scan-space (None = no proj)
    delta_ids: jax.Array            # (cap,) int32 external ids, -1 = empty
    delta_count: jax.Array          # () int32 append pointer


# the store IS the mutable engine state (base + delta + tombstones); the
# serving-layer name for the same pytree
MutableEngineState = StreamStore


def live_mask(store: StreamStore) -> jax.Array:
    """(n_cap,) bool: base rows that are allocated and not tombstoned."""
    return (store.row_ids >= 0) & ~store.dead


def _project(proj, vectors: jax.Array) -> jax.Array:
    return reduce_vectors(proj, vectors)


def encode_pq(codebooks: jax.Array, x: jax.Array) -> jax.Array:
    """Nearest-codeword PQ codes for rows ``x``: (B, M) int32.

    The same argmin as ``build_pq``'s final assignment, so a vector encodes
    to identical codes whether it arrived at build time or at compaction.
    """
    m, kc, dsub = codebooks.shape
    xs = x.reshape(x.shape[0], m, dsub)
    codes = [jnp.argmin(sq_dists(xs[:, j], codebooks[j]), axis=1)
             for j in range(m)]                         # M small: unrolled
    return jnp.stack(codes, axis=1).astype(jnp.int32)


def ivfpq_encode(centroids: jax.Array, codebooks: jax.Array, x: jax.Array):
    """Coarse-assign + residual-PQ-encode rows ``x`` against frozen
    quantizers. Returns (assign (B,), codes (B, M) int32, bias (B,) f32) —
    the exact per-row payload ``build_ivfpq`` computes at build time.
    """
    m, kc, dsub = codebooks.shape
    assign = jnp.argmin(sq_dists(x, centroids), axis=1)
    cent = centroids[assign]
    codes = encode_pq(codebooks, x - cent)
    csub = cent.reshape(x.shape[0], m, dsub)
    recon = jnp.take_along_axis(
        codebooks[None], codes[:, :, None, None], axis=2)[:, :, 0, :]
    bias = 2.0 * jnp.sum(csub * recon, axis=(1, 2))
    return assign, codes, bias.astype(jnp.float32)


def make_mutable(state, config: StreamConfig
                 ) -> Tuple[StreamStore, FrozenParams]:
    """Re-lay an immutable ``EngineState`` into (StreamStore, FrozenParams).

    Every store leaf is a fresh buffer (padded or copied —
    ``IndexOps.store_parts`` lays out the kind-specific base arrays), so
    the engine can donate the store to the write programs without
    invalidating the original state or the frozen quantizers.
    """
    kind = state.index.kind
    ops = get_ops(kind)
    n, d = state.corpus.shape
    cap = config.delta_capacity
    n_cap = config.row_capacity or n + 4 * cap
    if n_cap <= n:
        raise ValueError(
            f"row_capacity {n_cap} must exceed the corpus size {n} "
            "(compaction needs append slack)")
    proj = state.proj
    cell_slack = config.cell_slack if config.cell_slack is not None else cap
    parts, quant = ops.store_parts(state, n_cap, cell_slack)
    m_dim = reducer_dim(proj) if proj is not None else d
    store = StreamStore(
        corpus=_pad_rows(state.corpus, n_cap),
        row_ids=_pad_rows(jnp.arange(n, dtype=jnp.int32), n_cap, fill=-1),
        n_rows=jnp.asarray(n, jnp.int32),
        dead=jnp.zeros((n_cap,), bool),
        reduced=parts.get("reduced"), codes=parts.get("codes"),
        bias=parts.get("bias"), lists=parts.get("lists"),
        codes_cell=parts.get("codes_cell"),
        bias_cell=parts.get("bias_cell"),
        delta_vectors=jnp.zeros((cap, d), jnp.float32),
        delta_reduced=(jnp.zeros((cap, m_dim), jnp.float32)
                       if proj is not None else None),
        delta_ids=jnp.full((cap,), -1, jnp.int32),
        delta_count=jnp.zeros((), jnp.int32))
    return store, FrozenParams(proj=proj, quant=Index(kind, quant))


# --- the write path (pure; engine jits with the store donated) ---------------

def upsert_fn(store: StreamStore, frozen: FrozenParams, ids: jax.Array,
              vectors: jax.Array) -> Tuple[StreamStore, jax.Array]:
    """Apply a padded upsert batch: (ids (B,) int32 with -1 = no-op pad,
    vectors (B, D) f32). Sequential batch semantics (later rows win).

    Returns (store, dropped): ``dropped`` counts valid rows that found the
    delta segment full (the engine pre-compacts so this stays 0; direct
    callers must check it and compact + retry the remainder).
    """
    ids = jnp.asarray(ids, jnp.int32)
    vectors = jnp.asarray(vectors, jnp.float32)
    valid = ids >= 0
    # tombstone any base copy of each upserted id (vectorized over batch)
    hit = (store.row_ids[:, None] == ids[None, :]) & valid[None, :]
    dead = store.dead | hit.any(axis=1)
    cap = store.delta_ids.shape[0]
    slots = jnp.arange(cap)
    has_red = store.delta_reduced is not None
    red = _project(frozen.proj, vectors) if has_red else vectors

    def body(carry, x):
        d_ids, d_vec, d_red, count, dropped = carry
        i, v, vr, val = x
        match = (d_ids == i) & (slots < count) & val
        exists = match.any()
        slot = jnp.where(exists, jnp.argmax(match), count)
        slot = jnp.where(val, slot, cap)          # pads scatter out of range
        d_ids = d_ids.at[slot].set(i, mode="drop")
        d_vec = d_vec.at[slot].set(v, mode="drop")
        if d_red is not None:
            d_red = d_red.at[slot].set(vr, mode="drop")
        appended = val & ~exists & (slot < cap)
        lost = val & ~exists & (slot >= cap)      # delta full
        return (d_ids, d_vec, d_red, count + appended.astype(count.dtype),
                dropped + lost.astype(dropped.dtype)), None

    init = (store.delta_ids, store.delta_vectors,
            store.delta_reduced if has_red else None, store.delta_count,
            jnp.zeros((), jnp.int32))
    (d_ids, d_vec, d_red, count, dropped), _ = jax.lax.scan(
        body, init, (ids, vectors, red, valid))
    out = store._replace(dead=dead, delta_ids=d_ids, delta_vectors=d_vec,
                         delta_reduced=d_red, delta_count=count)
    return out, dropped


def delete_fn(store: StreamStore, ids: jax.Array) -> StreamStore:
    """Apply a padded delete batch (ids (B,) int32, -1 = no-op pad):
    tombstone base rows, punch delta holes. Absent ids are no-ops."""
    ids = jnp.asarray(ids, jnp.int32)
    valid = ids >= 0
    hit = (store.row_ids[:, None] == ids[None, :]) & valid[None, :]
    dead = store.dead | hit.any(axis=1)
    kill = ((store.delta_ids[:, None] == ids[None, :])
            & valid[None, :]).any(axis=1)
    return store._replace(
        dead=dead, delta_ids=jnp.where(kill, -1, store.delta_ids))


def compact_fn(store: StreamStore, frozen: FrozenParams
               ) -> Tuple[StreamStore, jax.Array]:
    """Fold the delta segment into the base; returns (store, dropped).

    All-or-nothing: when the append would overflow the row capacity or any
    posting cell's pad slack, the state comes back unchanged and
    ``dropped`` (the number of rows that could not be folded) is nonzero —
    the caller grows the store host-side and retries. Quantizers are
    frozen: delta rows are re-coded against them
    (``IndexOps.encode_delta`` on ``frozen.quant.kind``), so no
    serve-program shape or constant changes.
    """
    ops = get_ops(frozen.quant.kind)
    cap = store.delta_ids.shape[0]
    n_cap = store.corpus.shape[0]
    slots = jnp.arange(cap)
    alive = (slots < store.delta_count) & (store.delta_ids >= 0)
    n_alive = jnp.sum(alive.astype(jnp.int32))
    pos = jnp.cumsum(alive.astype(jnp.int32)) - 1       # packed ordinal
    dest = store.n_rows + pos                           # target base row
    ok = store.n_rows + n_alive <= n_cap                # row-capacity check

    scan_rows = (store.delta_reduced if store.delta_reduced is not None
                 else store.delta_vectors)
    assign, codes, bias = ops.encode_delta(frozen, scan_rows)
    slot_pos = None
    if store.lists is not None:
        nlist, mc_cap = store.lists.shape
        counts = jnp.sum((store.lists >= 0).astype(jnp.int32), axis=1)
        onehot = (jax.nn.one_hot(assign, nlist, dtype=jnp.int32)
                  * alive[:, None].astype(jnp.int32))
        rank = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, assign[:, None], axis=1)[:, 0]
        slot_pos = counts[assign] + rank
        ok = ok & ~jnp.any(alive & (slot_pos >= mc_cap))  # cell-slack check

    write = ok & alive
    dest = jnp.where(write, dest, n_cap)                # OOB => dropped
    corpus = store.corpus.at[dest].set(store.delta_vectors, mode="drop")
    row_ids = store.row_ids.at[dest].set(store.delta_ids, mode="drop")
    reduced = (store.reduced.at[dest].set(store.delta_reduced, mode="drop")
               if store.reduced is not None else None)
    new_codes = (store.codes.at[dest].set(
        codes.astype(store.codes.dtype), mode="drop")
                 if store.codes is not None else None)
    new_bias = (store.bias.at[dest].set(bias, mode="drop")
                if store.bias is not None else None)
    lists = codes_cell = bias_cell = None
    if store.lists is not None:
        nlist = store.lists.shape[0]
        cell = jnp.where(write, assign, nlist)          # OOB => dropped
        lists = store.lists.at[cell, slot_pos].set(
            dest.astype(jnp.int32), mode="drop")
        if store.codes_cell is not None:
            codes_cell = store.codes_cell.at[cell, slot_pos].set(
                codes.astype(store.codes_cell.dtype), mode="drop")
            bias_cell = store.bias_cell.at[cell, slot_pos].set(
                bias, mode="drop")
    okw = ok.astype(jnp.int32)
    out = store._replace(
        corpus=corpus, row_ids=row_ids,
        n_rows=store.n_rows + okw * n_alive,
        reduced=reduced, codes=new_codes, bias=new_bias, lists=lists,
        codes_cell=codes_cell, bias_cell=bias_cell,
        delta_ids=jnp.where(ok, -1, store.delta_ids),
        delta_count=store.delta_count * (1 - okw))
    return out, (1 - okw) * n_alive


def grow_store(store: StreamStore, *, row_extra: int = 0,
               cell_extra: int = 0) -> StreamStore:
    """Host-side capacity growth (the compaction-overflow escape hatch).

    Pads the row store by ``row_extra`` rows and every posting cell by
    ``cell_extra`` slots. Shapes change, so downstream programs recompile
    once — size ``StreamConfig.row_capacity``/``cell_slack`` to make this
    rare.
    """
    n_cap = store.corpus.shape[0] + row_extra
    return store._replace(
        corpus=_pad_rows(store.corpus, n_cap),
        row_ids=_pad_rows(store.row_ids, n_cap, fill=-1),
        dead=_pad_rows(store.dead, n_cap, fill=False),
        reduced=(_pad_rows(store.reduced, n_cap)
                 if store.reduced is not None else None),
        codes=(_pad_rows(store.codes, n_cap)
               if store.codes is not None else None),
        bias=(_pad_rows(store.bias, n_cap)
              if store.bias is not None else None),
        lists=(_pad_cells(store.lists, cell_extra, fill=-1)
               if store.lists is not None else None),
        codes_cell=(_pad_cells(store.codes_cell, cell_extra)
                    if store.codes_cell is not None else None),
        bias_cell=(_pad_cells(store.bias_cell, cell_extra)
                   if store.bias_cell is not None else None))


def rebuild_state(frozen: FrozenParams, vectors: jax.Array, *,
                  index: Optional[str] = None, shards: int = 1):
    """Build a read-only ``EngineState`` over ``vectors`` with the FROZEN
    quantizers (no retraining) — the offline full-rebuild path and the
    from-scratch oracle of the streaming equivalence tests: after
    ``compact()``, streaming search over the survivors must return exactly
    what this state returns. ``index`` defaults to the frozen kind.
    """
    from .serve import EngineState

    kind = index if index is not None else frozen.quant.kind
    if kind != frozen.quant.kind:
        raise ValueError(
            f"index={kind!r} does not match the frozen quantizers "
            f"({frozen.quant.kind!r})")
    vectors = jnp.asarray(vectors, jnp.float32)
    reduced = _project(frozen.proj, vectors)
    payload = get_ops(kind).rebuild(frozen, reduced, shards)
    return EngineState(corpus=vectors, proj=frozen.proj,
                       index=Index(kind, payload))
