"""IVF-Flat approximate nearest-neighbor index (k-means coarse quantizer).

The paper's future-work item "integrating MPAD into existing ANN pipelines":
vectors (optionally MPAD-reduced) are clustered into ``nlist`` cells; a query
probes the ``nprobe`` nearest cells and scans only those posting lists.

Implementation is padded-dense for jit-ability: each cell's posting list is a
fixed-size row of a (nlist, max_cell) index matrix (padded with -1), so the
probe-scan is a gather + masked top-k — the TPU-idiomatic layout (no ragged
structures on device).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .knn import masked_topk

__all__ = ["IVFIndex", "balance_cells", "build_ivf", "cell_vectors",
           "ivf_local_scan", "ivf_scan", "ivf_search", "kmeans",
           "posting_lists", "probe_cells", "sq_dists"]


def sq_dists(a: jax.Array, b: jax.Array) -> jax.Array:
    """Unclamped pairwise squared L2: |a|^2 + |b|^2 - 2 a@b^T, shape (A, B)."""
    return (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
            - 2.0 * a @ b.T)


class IVFIndex(NamedTuple):
    centroids: jax.Array    # (nlist, d)
    lists: jax.Array        # (nlist, max_cell) int32 vector ids, -1 = pad
    vectors: jax.Array      # (N, d) the stored (possibly reduced) vectors


@functools.partial(jax.jit, static_argnames=("nlist", "iters"))
def kmeans(key: jax.Array, x: jax.Array, nlist: int, iters: int = 12):
    """Lloyd k-means with k-means++-ish random restarts on empty clusters."""
    n = x.shape[0]
    init = jax.random.choice(key, n, (nlist,), replace=False)
    cent = x[init]

    def step(cent, _):
        assign = jnp.argmin(sq_dists(x, cent), axis=1)
        one_hot = jax.nn.one_hot(assign, nlist, dtype=x.dtype)
        counts = one_hot.sum(0)
        sums = one_hot.T @ x
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep old centroid for empty clusters
        new = jnp.where((counts > 0)[:, None], new, cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


def posting_lists(assign: jax.Array, nlist: int, shards: int = 1) -> jax.Array:
    """Padded-dense posting lists from a cell assignment.

    Returns (nlist_pad, max_cell) int32 vector ids, -1 = pad; rows are
    cells. ``shards`` pads the cell axis up to a multiple with empty
    (all -1) cells so the layout splits into per-shard-equal blocks along
    the database axis (sharded serving); shards=1 leaves it unchanged.
    Padded cells are unreachable: the coarse probe only ever emits real
    cell ids (< nlist).
    """
    counts = jnp.bincount(assign, length=nlist)
    max_cell = int(counts.max())
    nlist_pad = -(-nlist // shards) * shards
    # stable bucket layout: sort ids by (cell, id); row-major fill
    order = jnp.argsort(assign, stable=True)
    sorted_cells = assign[order]
    # position of each sorted element within its cell
    pos = jnp.arange(order.shape[0]) - jnp.searchsorted(
        sorted_cells, sorted_cells, side="left")
    lists = jnp.full((nlist_pad, max_cell), -1, jnp.int32)
    return lists.at[sorted_cells, pos].set(order.astype(jnp.int32))


def balance_cells(counts, shards: int) -> np.ndarray:
    """Load-aware cell placement: a permutation of the cell axis such that
    the per-shard contiguous blocks carry near-equal posting-list **mass**
    (row count), not just equal cell count.

    Greedy LPT bin-pack: cells sorted heaviest-first, each placed on the
    lightest shard that still has cell slots. Shard s's slot budget is the
    block size ``ceil(nlist / shards)``, except the tail blocks that the
    ``posting_lists`` padding turns into all-pad cells (pads stay at the
    end of the cell axis, which the sharded layout relies on). Host-side
    (build time, numpy); apply the permutation to centroids AND the
    assignment so cell ids stay consistent end to end.
    """
    counts = np.asarray(counts)
    nlist = counts.shape[0]
    per = -(-nlist // shards)
    caps = np.full(shards, per)
    deficit = per * shards - nlist
    s = shards - 1
    while deficit > 0:                     # pad cells live in the tail blocks
        take = min(per, deficit)
        caps[s] -= take
        deficit -= take
        s -= 1
    order = np.argsort(-counts, kind="stable")
    load = np.zeros(shards, dtype=np.int64)
    members: list = [[] for _ in range(shards)]
    for c in order:
        elig = [i for i in range(shards) if len(members[i]) < caps[i]]
        tgt = min(elig, key=lambda i: (load[i], i))
        members[tgt].append(int(c))
        load[tgt] += int(counts[c])
    return np.concatenate(
        [np.asarray(m, dtype=np.int64) for m in members if m])


def _balanced_layout(cent: jax.Array, assign: jax.Array, nlist: int,
                     shards: int):
    """Permute the cell axis by ``balance_cells`` (centroid order is
    arbitrary, so this changes layout only, never scan results)."""
    counts = np.asarray(jnp.bincount(assign, length=nlist))
    perm = balance_cells(counts, shards)
    inv = np.empty(nlist, np.int32)
    inv[perm] = np.arange(nlist, dtype=np.int32)
    return cent[jnp.asarray(perm)], jnp.asarray(inv)[assign]


def build_ivf(key: jax.Array, vectors: jax.Array, nlist: int,
              kmeans_iters: int = 12, shards: int = 1,
              balance: bool = True) -> IVFIndex:
    """``balance`` (with ``shards > 1``) permutes cells so shard blocks
    carry near-equal posting mass — see ``balance_cells``."""
    vectors = jnp.asarray(vectors, jnp.float32)
    cent = kmeans(key, vectors, nlist, kmeans_iters)
    assign = jnp.argmin(sq_dists(vectors, cent), axis=1)  # (N,)
    if balance and shards > 1:
        cent, assign = _balanced_layout(cent, assign, nlist, shards)
    lists = posting_lists(assign, nlist, shards)
    return IVFIndex(centroids=cent, lists=lists, vectors=vectors)


def cell_vectors(lists: jax.Array, vectors: jax.Array) -> jax.Array:
    """Cell-major mirror of the stored vectors: (nlist, max_cell, d).

    Posting pads (-1) become zero rows. Probe-time access turns into nprobe
    contiguous row-block gathers, and — like ``codes_cell`` in IVF-PQ — the
    cell axis is the database axis sharded serving partitions.
    """
    cv = vectors[jnp.maximum(lists, 0)]
    return jnp.where((lists >= 0)[..., None], cv, 0.0)


def probe_cells(centroids: jax.Array, lists: jax.Array, q: jax.Array,
                nprobe: int, min_cand: int):
    """Shared coarse-probe: nearest ``nprobe`` cells' posting lists.

    Returns (probe (Q, nprobe) int32 cell ids, cand (Q, C) int32 vector ids
    with -1 pads, coarse_d2 (Q, nprobe) squared distances to the probed
    centroids, in probe order). ``cand`` is right-padded with -1 up to
    ``min_cand`` so a downstream top-k of that size is always legal
    (degenerate probe budgets). Pure/unjitted so callers can inline it into
    larger fused programs; ``probe`` lets them gather any cell-major
    per-vector payload (codes, bias, vectors) with contiguous row gathers.
    """
    cd2 = sq_dists(q, centroids)                          # (Q, nlist)
    _, probe = jax.lax.top_k(-cd2, nprobe)                # (Q, nprobe)
    cd2p = jnp.take_along_axis(cd2, probe, axis=1)        # (Q, nprobe)
    cand = lists[probe].reshape(q.shape[0], -1)           # (Q, nprobe*max_cell)
    if cand.shape[1] < min_cand:
        cand = jnp.pad(cand, ((0, 0), (0, min_cand - cand.shape[1])),
                       constant_values=-1)
    return probe, cand, cd2p


def ivf_scan(index: IVFIndex, q: jax.Array, k: int, nprobe: int = 8):
    """Unjitted ``ivf_search`` core (inlineable into fused programs)."""
    q = jnp.asarray(q, jnp.float32)
    cent, lists, vecs = index
    _, cand, _ = probe_cells(cent, lists, q, nprobe, k)
    valid = cand >= 0
    cv = vecs[jnp.maximum(cand, 0)]                       # (Q, C, d)
    d2 = jnp.sum((cv - q[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids


def ivf_local_scan(centroids: jax.Array, lists_loc: jax.Array,
                   cell_vecs_loc: jax.Array, q: jax.Array, n_cand: int,
                   nprobe: int, axis: str,
                   live: Optional[jax.Array] = None):
    """Shard-local IVF probe + scan (a ``shard_map`` body of sharded serving).

    The coarse probe runs on the replicated ``centroids`` — identical on
    every shard, so it equals the single-device probe exactly — then only
    the probed cells this shard owns (rows of ``lists_loc``/
    ``cell_vecs_loc``, offset by ``axis_index * nlist_local`` along the
    cell axis) are scanned. Returns (d2 (Q, n_cand), global ids (Q,
    n_cand)); non-local or padded slots are (+inf, -1) and are supplied by
    the shard that owns them. ``live`` (replicated (N,) bool, streaming
    serving) additionally masks tombstoned/unallocated global rows before
    the local top-k, so dead rows never crowd out live candidates.
    """
    q = jnp.asarray(q, jnp.float32)
    cd2 = sq_dists(q, centroids)                          # (Q, nlist)
    _, probe = jax.lax.top_k(-cd2, nprobe)                # global cell ids
    nl_loc = lists_loc.shape[0]
    coff = jax.lax.axis_index(axis) * nl_loc
    lp = probe - coff
    own = (lp >= 0) & (lp < nl_loc)                       # (Q, nprobe)
    lpc = jnp.clip(lp, 0, nl_loc - 1)
    cand = jnp.where(own[:, :, None], lists_loc[lpc], -1)
    if live is not None:
        n_cap = live.shape[0]
        cand = jnp.where(live[jnp.clip(cand, 0, n_cap - 1)], cand, -1)
    cv = cell_vecs_loc[lpc]                               # (Q, P, mc, d)
    d2 = jnp.sum((cv - q[:, None, None, :]) ** 2, axis=-1)
    nq = q.shape[0]
    cand = cand.reshape(nq, -1)
    d2 = jnp.where(cand >= 0, d2.reshape(nq, -1), jnp.inf)
    return masked_topk(d2, cand, n_cand)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_search(index: IVFIndex, q: jax.Array, k: int, nprobe: int = 8):
    """Probe the nprobe nearest cells; returns (dists (Q,k), ids (Q,k))."""
    return ivf_scan(index, q, k, nprobe)
