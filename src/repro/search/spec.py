"""Declarative index-pipeline specs: the composable serving API.

QPAD's thesis is that dimension reduction *composes* with the downstream
ANN machinery — reduce, then coarse-quantize, then code, then exact
re-rank — and this module makes that composition the first-class object
(the shape GleanVec's DR-then-scan pipelines and "Quantization Meets
Projection"'s DR+PQ marriage treat as primary). An ``IndexSpec`` is a
typed pipeline of stages:

    Reduce(m, kind)  ->  Coarse(nlist, nprobe)  ->  Code(subspaces,
                                                        centroids,
                                                        lut_dtype,
                                                        backend, kind)
                                                ->  Rerank(n)

Every stage except ``Rerank`` is optional; the stage combination
determines the index kind (``IndexSpec.kind``):

    no Coarse, no Code   ->  "flat"    exact scan
    Coarse only          ->  "ivf"     probed exact scan
    Code(kind="pq")      ->  "pq"      fused ADC scan
    Code(kind="opq")     ->  "opq"     learned rotation + fused ADC scan
    Coarse + Code        ->  "ivfpq"   probed ADC scan over residual codes

The ``Reduce`` stage is itself pluggable: its ``kind`` names an entry in
the reducer registry (``repro.search.reducers`` — ``qpad`` | ``pca`` |
``mlp``), mirroring how the stage combination names an entry in the
index registry.

Specs also have a FAISS-factory-style **string grammar** (parser and
printer round-trip)::

    spec   := "flat" | stage (">" stage)*        stages in pipeline order
    stage  := RED M                              Reduce(m=M, kind=RED)
            | "flat"                             exact scan (no ivf/code)
            | "ivf" NLIST "x" NPROBE             Coarse(nlist, nprobe)
            | CODE M "x" K [":" LUT] ["@" BACK]  Code(subspaces=M,
                                                      centroids=K,
                                                      kind=CODE, ...)
            | "rr" N                             Rerank(n=N)
    RED    := "qpad" | "pca" | "mlp"             registered reducer kinds
    CODE   := "pq" | "opq"                       plain / OPQ-rotated PQ
    LUT    := "f32" | "bf16" | "i8" | "int8"     ADC table precision
    BACK   := "jnp" | "kernel"                   ADC scoring backend

e.g. ``"qpad32>ivf64x8>pq8x256:i8"`` = MPAD to 32 dims, 64 coarse cells
probing 8, 8x256 residual PQ codes scored through int8 LUTs, default
64-candidate exact re-rank; ``"pca32>opq8x256"`` = PCA to 32 dims then
OPQ-rotated 8x256 codes. ``parse_spec``/``format_spec`` round-trip:
``parse_spec(format_spec(s)) == s`` for every spec value.

Validation is **stage-level**: each stage checks its own knobs in
``__post_init__`` (e.g. ``Coarse`` rejects ``nprobe > nlist`` — probing
more cells than exist was previously clamped inside the jitted scan), and
the spec cannot *express* dead knobs — there is no ``nlist`` without a
``Coarse`` stage. The legacy flat ``ServeConfig`` keeps working through
``spec_from_config``, which lowers it onto a spec and rejects knobs the
selected pipeline has no stage for.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.kernels.pq_adc.lut import LUT_DTYPES

from .reducers import REDUCER_KINDS

__all__ = ["Reduce", "Coarse", "Code", "Rerank", "IndexSpec",
           "parse_spec", "format_spec", "spec_from_config"]

ADC_BACKENDS = ("jnp", "kernel")
CODE_KINDS = ("pq", "opq")
DEFAULT_RERANK = 64

# grammar aliases: token in a spec string -> canonical lut_dtype
_LUT_TOKENS = {"f32": "f32", "bf16": "bf16", "i8": "int8", "int8": "int8"}
_LUT_PRINT = {"f32": "f32", "bf16": "bf16", "int8": "i8"}


@dataclasses.dataclass(frozen=True)
class Reduce:
    """Dimension reduction: project the corpus D -> ``m`` dims with the
    registered reducer ``kind`` (``qpad`` — the MPAD projection — by
    default; see ``repro.search.reducers``)."""
    m: int
    kind: str = "qpad"

    def __post_init__(self):
        if self.m < 1:
            raise ValueError(f"Reduce(m={self.m}): m must be >= 1")
        if self.kind not in REDUCER_KINDS:
            raise ValueError(
                f"Reduce(kind={self.kind!r}): unknown reducer kind; "
                f"registered kinds: {REDUCER_KINDS} "
                "(register new ones via repro.search.reducers."
                "register_reducer)")


@dataclasses.dataclass(frozen=True)
class Coarse:
    """Coarse k-means quantizer: ``nlist`` cells, probe ``nprobe``/query."""
    nlist: int
    nprobe: int = 8

    def __post_init__(self):
        if self.nlist < 1:
            raise ValueError(f"Coarse(nlist={self.nlist}): nlist must "
                             "be >= 1")
        if self.nprobe < 1:
            raise ValueError(f"Coarse(nprobe={self.nprobe}): nprobe must "
                             "be >= 1")
        if self.nprobe > self.nlist:
            raise ValueError(
                f"Coarse(nlist={self.nlist}, nprobe={self.nprobe}): "
                f"nprobe exceeds nlist — cannot probe more cells than "
                f"exist; lower nprobe or raise nlist (nprobe == nlist "
                "already scans every cell)")


@dataclasses.dataclass(frozen=True)
class Code:
    """PQ coding: ``subspaces`` x ``centroids`` codebooks + ADC scan knobs.

    ``kind="opq"`` prepends a learned orthogonal rotation (alternating
    Procrustes / assignment, OPQ-style) to the coder — the codes cover
    the rotated scan space, and every ADC scan path rotates the query
    first. Distances are rotation-invariant, so the delta/re-rank
    machinery is shared with plain ``pq`` unchanged.
    """
    subspaces: int = 8
    centroids: int = 256
    lut_dtype: str = "f32"
    backend: str = "jnp"
    kind: str = "pq"

    def __post_init__(self):
        if self.kind not in CODE_KINDS:
            raise ValueError(
                f"Code(kind={self.kind!r}): expected one of {CODE_KINDS}")
        if self.subspaces < 1:
            raise ValueError(f"Code(subspaces={self.subspaces}): must "
                             "be >= 1")
        if self.centroids < 2:
            raise ValueError(f"Code(centroids={self.centroids}): a "
                             "codebook needs >= 2 codewords")
        if self.lut_dtype not in LUT_DTYPES:
            raise ValueError(
                f"Code(lut_dtype={self.lut_dtype!r}): expected one of "
                f"{LUT_DTYPES}")
        if self.backend not in ADC_BACKENDS:
            raise ValueError(
                f"Code(backend={self.backend!r}): expected one of "
                f"{ADC_BACKENDS} (pq_backend)")


@dataclasses.dataclass(frozen=True)
class Rerank:
    """Exact re-rank of the top ``n`` candidates in the original space."""
    n: int = DEFAULT_RERANK

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"Rerank(n={self.n}): n must be >= 1")


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """A serving pipeline: optional Reduce/Coarse/Code stages + Rerank.

    The stage combination is the index kind (``.kind``); validation is
    per-stage plus the composition checks here. Hashable and immutable,
    so a spec can key compile caches directly.
    """
    reduce: Optional[Reduce] = None
    coarse: Optional[Coarse] = None
    code: Optional[Code] = None
    rerank: Rerank = Rerank()

    def __post_init__(self):
        for field, cls in (("reduce", Reduce), ("coarse", Coarse),
                           ("code", Code)):
            val = getattr(self, field)
            if val is not None and not isinstance(val, cls):
                raise TypeError(f"IndexSpec.{field} must be a {cls.__name__}"
                                f" (or None), got {type(val).__name__}")
        if not isinstance(self.rerank, Rerank):
            raise TypeError("IndexSpec.rerank must be a Rerank stage, got "
                            f"{type(self.rerank).__name__}")
        if (self.coarse is not None and self.code is not None
                and self.code.kind == "opq"):
            raise ValueError(
                "Coarse + Code(kind='opq') is not a registered pipeline: "
                "the OPQ rotation is fitted on the whole scan space, which "
                "residual coding under a coarse quantizer would invalidate "
                "per cell. Use 'opq<M>x<K>' without an ivf stage, or "
                "'ivf<nlist>x<nprobe>>pq<M>x<K>' for coarse + codes.")

    @property
    def kind(self) -> str:
        """The index layout this pipeline lowers to (registry key)."""
        if self.coarse is not None and self.code is not None:
            return "ivfpq"
        if self.coarse is not None:
            return "ivf"
        if self.code is not None:
            return self.code.kind       # "pq" | "opq"
        return "flat"

    @property
    def approximate(self) -> bool:
        """True when scan-space scores are lossy (reduction or PQ codes),
        i.e. the over-retrieve + exact re-rank stage is load-bearing."""
        return self.reduce is not None or self.code is not None

    def stages(self):
        """The present stages, in pipeline order."""
        return tuple(s for s in (self.reduce, self.coarse, self.code,
                                 self.rerank) if s is not None)

    def __str__(self) -> str:
        return format_spec(self)


# the generic reduce token (<kind><m>) is tried LAST so every
# fixed-prefix stage token (ivf.., pq.., opq.., rr..) wins first; the
# matched kind is then validated against the reducer registry
_STAGE_RES = (
    ("coarse", re.compile(r"ivf(\d+)x(\d+)$")),
    ("code", re.compile(
        r"(pq|opq)(\d+)x(\d+)(?::(f32|bf16|i8|int8))?(?:@(jnp|kernel))?$")),
    ("rerank", re.compile(r"rr(\d+)$")),
    ("reduce", re.compile(r"([a-z]+)(\d+)$")),
)
_ORDER = {"reduce": 0, "coarse": 1, "code": 2, "rerank": 3}

_GRAMMAR_HINT = (
    "expected 'flat' or '>'-joined stages in pipeline order: "
    f"<reducer><m> (reducer in {'|'.join(REDUCER_KINDS)}) | flat | "
    "ivf<nlist>x<nprobe> | pq<M>x<K>[:f32|bf16|i8][@jnp|kernel] | "
    "opq<M>x<K>[:...] | rr<n> (e.g. 'qpad32>ivf64x8>pq8x256:i8')")


def parse_spec(s: str) -> IndexSpec:
    """Parse the string grammar into an ``IndexSpec`` (see module doc).

    Inverse of ``format_spec``. Raises ``ValueError`` with the grammar on
    unknown tokens, out-of-order stages, or repeated stages.
    """
    if not isinstance(s, str):
        raise TypeError(f"spec string expected, got {type(s).__name__}")
    text = s.strip().lower()
    if not text:
        raise ValueError(f"empty index spec; {_GRAMMAR_HINT}")
    if text == "flat":
        return IndexSpec()
    stages: dict = {}
    last = -1
    flat = False
    for token in text.split(">"):
        token = token.strip()
        if token == "flat":
            # explicit exact-scan marker: the pipeline has no Coarse/Code
            # stage (e.g. 'mlp16>flat' = reduce, then exact scan)
            if flat:
                raise ValueError(
                    f"duplicate 'flat' token in spec {s!r}")
            if _ORDER["coarse"] < last:
                raise ValueError(
                    f"stage 'flat' out of pipeline order in spec {s!r}; "
                    "order is <reducer> > flat > rr")
            flat = True
            last = _ORDER["coarse"]
            continue
        for name, rx in _STAGE_RES:
            m = rx.match(token)
            if m:
                break
        else:
            raise ValueError(
                f"unknown stage token {token!r} in spec {s!r}; "
                f"{_GRAMMAR_HINT}")
        if name in stages:
            raise ValueError(
                f"duplicate {name} stage ({token!r}) in spec {s!r}")
        if _ORDER[name] < last:
            raise ValueError(
                f"stage {token!r} out of pipeline order in spec {s!r}; "
                "order is <reducer> > ivf > pq|opq > rr")
        last = _ORDER[name]
        if name == "reduce":
            kind = m.group(1)
            if kind in ("ivf", "pq", "opq", "rr"):
                # a fixed-prefix stage with malformed decorations (e.g.
                # 'ivf64' without xNPROBE), not a reducer named 'ivf'
                raise ValueError(
                    f"malformed {kind} stage token {token!r} in spec "
                    f"{s!r}; {_GRAMMAR_HINT}")
            if kind not in REDUCER_KINDS:
                raise ValueError(
                    f"unknown reducer kind {kind!r} in stage {token!r} of "
                    f"spec {s!r}; registered reducer kinds: "
                    f"{REDUCER_KINDS}. {_GRAMMAR_HINT}")
            stages[name] = Reduce(m=int(m.group(2)), kind=kind)
        elif name == "coarse":
            stages[name] = Coarse(nlist=int(m.group(1)),
                                  nprobe=int(m.group(2)))
        elif name == "code":
            stages[name] = Code(
                kind=m.group(1),
                subspaces=int(m.group(2)), centroids=int(m.group(3)),
                lut_dtype=_LUT_TOKENS[m.group(4) or "f32"],
                backend=m.group(5) or "jnp")
        else:
            stages[name] = Rerank(n=int(m.group(1)))
    if flat and ("coarse" in stages or "code" in stages):
        extra = stages.get("coarse") or stages.get("code")
        raise ValueError(
            f"spec {s!r} mixes 'flat' (exact scan) with a "
            f"{type(extra).__name__} stage; drop one of them")
    return IndexSpec(**stages)


def format_spec(spec: IndexSpec) -> str:
    """Print a spec in the canonical string grammar.

    Inverse of ``parse_spec``: default-valued decorations (f32 LUTs, jnp
    backend, default rerank) are omitted, so
    ``parse_spec(format_spec(spec)) == spec`` and
    ``format_spec(parse_spec(s))`` is the canonical form of ``s``.
    """
    parts = []
    if spec.reduce is not None:
        parts.append(f"{spec.reduce.kind}{spec.reduce.m}")
    if spec.coarse is not None:
        parts.append(f"ivf{spec.coarse.nlist}x{spec.coarse.nprobe}")
    if spec.code is not None:
        tok = f"{spec.code.kind}{spec.code.subspaces}x{spec.code.centroids}"
        if spec.code.lut_dtype != "f32":
            tok += f":{_LUT_PRINT[spec.code.lut_dtype]}"
        if spec.code.backend != "jnp":
            tok += f"@{spec.code.backend}"
        parts.append(tok)
    if spec.rerank.n != DEFAULT_RERANK:
        parts.append(f"rr{spec.rerank.n}")
    return ">".join(parts) if parts else "flat"


def spec_from_config(cfg) -> IndexSpec:
    """Lower a legacy flat ``ServeConfig`` onto a pipeline spec.

    The adapter that keeps ``ServeConfig(index=...)`` working: the
    index-pipeline knobs map onto stages, and knobs the selected pipeline
    has **no stage for** are rejected when set away from their defaults
    (previously e.g. ``nlist`` silently meant nothing under
    ``index="pq"``). Duck-typed over the config's dataclass fields so this
    module stays import-light.
    """
    kind = cfg.index
    if kind not in ("flat", "ivf", "pq", "opq", "ivfpq"):
        raise ValueError(
            f"unknown index kind {kind!r}; expected one of "
            "('flat', 'ivf', 'pq', 'opq', 'ivfpq')")
    defaults = {f.name: f.default for f in dataclasses.fields(cfg)}
    coarse_knobs = ("nlist", "nprobe")
    code_knobs = ("pq_subspaces", "pq_centroids", "lut_dtype", "pq_backend")
    dead = []
    if kind in ("ivf", "ivfpq"):
        coarse = Coarse(nlist=cfg.nlist, nprobe=cfg.nprobe)
    else:
        coarse = None
        dead += [(k, "Coarse") for k in coarse_knobs
                 if getattr(cfg, k) != defaults[k]]
    if kind in ("pq", "opq", "ivfpq"):
        code = Code(subspaces=cfg.pq_subspaces, centroids=cfg.pq_centroids,
                    lut_dtype=cfg.lut_dtype, backend=cfg.pq_backend,
                    kind="opq" if kind == "opq" else "pq")
    else:
        code = None
        dead += [(k, "Code") for k in code_knobs
                 if getattr(cfg, k) != defaults[k]]
    reducer = getattr(cfg, "reducer", "qpad")
    if cfg.target_dim is None:
        dead += [("reducer", "Reduce")] if reducer != "qpad" else []
    elif reducer != "qpad" and getattr(cfg, "mpad", None) is not None:
        raise ValueError(
            f"mpad= configures the 'qpad' reducer fit, but reducer="
            f"{reducer!r} is selected — drop mpad, or use reducer='qpad'")
    if dead:
        knobs = ", ".join(f"{k}={getattr(cfg, k)!r} (needs a {s} stage)"
                          for k, s in dead)
        raise ValueError(
            f"dead knob(s) for index={kind!r}: {knobs}. The {kind!r} "
            "pipeline has no stage that reads them — drop them, or select "
            "a pipeline that has the stage (e.g. spec "
            "'qpad32>ivf64x8>pq8x256').")
    reduce = (Reduce(m=cfg.target_dim, kind=reducer)
              if cfg.target_dim is not None else None)
    return IndexSpec(reduce=reduce, coarse=coarse, code=code,
                     rerank=Rerank(n=cfg.rerank))
