"""Brute-force k-NN and the paper's A_m(k) neighbor-preservation metric.

``knn_search`` is the single-shot exact search (full distance matrix);
``knn_search_blocked`` streams the database in blocks with a running top-k so
memory stays O(Q·(k+block)) — the jnp mirror of the Pallas kernel in
``repro.kernels.knn_topk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["knn_scan", "knn_search", "knn_search_blocked", "masked_topk",
           "recall_at_k", "amk_accuracy"]


def _sq_dists(q: jax.Array, x: jax.Array) -> jax.Array:
    qq = jnp.sum(q * q, axis=-1)[:, None]
    xx = jnp.sum(x * x, axis=-1)[None, :]
    return jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)


def knn_scan(q: jax.Array, x: jax.Array, k: int):
    """Unjitted ``knn_search`` core (inlineable into fused programs).

    Tolerates k > N (a candidate budget above the corpus size): the short
    rows are right-padded with (-1, inf), matching the IVF pad convention.
    """
    d2 = _sq_dists(q, x)
    k_eff = min(k, x.shape[0])
    neg, idx = jax.lax.top_k(-d2, k_eff)
    if k_eff < k:
        neg = jnp.pad(neg, ((0, 0), (0, k - k_eff)),
                      constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, k - k_eff)), constant_values=-1)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


def masked_topk(d2: jax.Array, ids: jax.Array, k: int):
    """Row-wise top-k of masked distances carrying payload ids.

    ``d2`` (Q, C) with +inf marking invalid entries; ``ids`` (Q, C) the
    payload (e.g. global row ids) returned for the surviving slots. Invalid
    or missing slots come back as (+inf, -1); tolerates k > C by
    right-padding — the shared pad convention of every scan in this package.
    The building block of the per-shard local scans in sharded serving.
    """
    k_eff = min(k, d2.shape[1])
    neg, sel = jax.lax.top_k(-d2, k_eff)
    out_i = jnp.where(jnp.isneginf(neg), -1,
                      jnp.take_along_axis(ids, sel, axis=1))
    out_d = -neg
    if k_eff < k:
        out_d = jnp.pad(out_d, ((0, 0), (0, k - k_eff)),
                        constant_values=jnp.inf)
        out_i = jnp.pad(out_i, ((0, 0), (0, k - k_eff)),
                        constant_values=-1)
    return out_d, out_i


@functools.partial(jax.jit, static_argnames=("k",))
def knn_search(q: jax.Array, x: jax.Array, k: int):
    """Exact k-NN: returns (dists (Q,k), indices (Q,k)) by L2 distance."""
    return knn_scan(q, x, k)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def knn_search_blocked(q: jax.Array, x: jax.Array, k: int, block: int = 1024):
    """Streaming exact k-NN with a running top-k over database blocks."""
    nq = q.shape[0]
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.concatenate(
            [x, jnp.full((pad, x.shape[1]), jnp.inf, x.dtype)], axis=0)
    n_blocks = x.shape[0] // block
    xb = x.reshape(n_blocks, block, x.shape[1])
    qq = jnp.sum(q * q, axis=-1)[:, None]

    def scan_block(carry, xblk):
        best_d, best_i, offset = carry
        xx = jnp.sum(xblk * xblk, axis=-1)[None, :]
        d2 = qq + xx - 2.0 * (q @ xblk.T)                     # (Q, block)
        d2 = jnp.where(jnp.isfinite(xx), jnp.maximum(d2, 0.0), jnp.inf)
        idx = offset + jnp.arange(block)[None, :]
        cand_d = jnp.concatenate([best_d, d2], axis=1)
        cand_i = jnp.concatenate([best_i, jnp.broadcast_to(idx, d2.shape)], axis=1)
        neg, sel = jax.lax.top_k(-cand_d, k)
        return (-neg, jnp.take_along_axis(cand_i, sel, axis=1), offset + block), None

    init = (jnp.full((nq, k), jnp.inf), jnp.zeros((nq, k), jnp.int32),
            jnp.zeros((), jnp.int32))
    (best_d, best_i, _), _ = jax.lax.scan(scan_block, init, xb)
    return jnp.sqrt(jnp.maximum(best_d, 0.0)), best_i


def recall_at_k(found: jax.Array, truth: jax.Array) -> jax.Array:
    """|found ∩ truth| / k per query, averaged. Shapes (Q, k) int."""
    inter = (found[:, :, None] == truth[:, None, :]).any(axis=2)
    return jnp.mean(jnp.sum(inter, axis=1) / truth.shape[1])


def amk_accuracy(reducer, x_train: jax.Array, y_test: jax.Array, k: int,
                 block: int | None = None) -> jax.Array:
    """The paper's A_m(k) (Section 3.2).

    For each test vector y_i: k-NN in the *original* space X vs k-NN of f(y_i)
    in the *reduced* set f(X); A_m(k) = mean fraction retained.
    """
    if block is None:
        _, truth = knn_search(y_test, x_train, k)
    else:
        _, truth = knn_search_blocked(y_test, x_train, k, block=block)
    xr = reducer(x_train) if callable(reducer) else reducer.transform(x_train)
    yr = reducer(y_test) if callable(reducer) else reducer.transform(y_test)
    xr = jnp.asarray(xr, jnp.float32)
    yr = jnp.asarray(yr, jnp.float32)
    if block is None:
        _, found = knn_search(yr, xr, k)
    else:
        _, found = knn_search_blocked(yr, xr, k, block=block)
    return recall_at_k(found, truth)
