from .checkpoint import (save_checkpoint, restore_checkpoint,
                         latest_checkpoint, restore_resharded)
from .fault import run_with_restarts, FailureInjector

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_checkpoint",
           "restore_resharded", "run_with_restarts", "FailureInjector"]
