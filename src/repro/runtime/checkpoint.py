"""Checkpointing: atomic, retention-managed, mesh-agnostic.

Checkpoints are stored as flat ``{path: np.ndarray}`` npz files — fully
shard-agnostic, so a checkpoint written on one mesh restores onto any other
(``restore_resharded``): the elastic-scaling primitive. Writes go to a temp
file + fsync + atomic rename; a crash mid-write (or a power loss right
after) never corrupts the latest good step.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_checkpoint",
           "restore_resharded", "checkpoint_step"]

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())                 # bytes down before the name
        final = os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")
        os.replace(tmp, final)                   # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: int):
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if _STEP_RE.search(f))
    for f in ckpts[:-keep] if keep else []:
        os.unlink(os.path.join(ckpt_dir, f))


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(f for f in os.listdir(ckpt_dir) if _STEP_RE.search(f))
    return os.path.join(ckpt_dir, ckpts[-1]) if ckpts else None


def checkpoint_step(path: str) -> int:
    m = _STEP_RE.search(path)
    return int(m.group(1)) if m else -1


def restore_checkpoint(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for kpath, leaf in flat:
            arr = data[jax.tree_util.keystr(kpath)]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch at {jax.tree_util.keystr(kpath)}: "
                    f"ckpt {arr.shape} vs template {leaf.shape}")
            leaves.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_resharded(path: str, template: Any, shardings: Any) -> Any:
    """Restore onto a (possibly different) mesh: elastic scaling.

    ``shardings`` is a pytree of NamedSharding congruent with ``template``;
    each leaf is device_put directly to its target sharding, so restore on
    2x fewer/more hosts needs no conversion step.
    """
    state = restore_checkpoint(path, template)
    return jax.tree.map(jax.device_put, state, shardings)
