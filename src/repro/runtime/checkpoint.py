"""Checkpointing: atomic, retention-managed, mesh-agnostic.

Checkpoints are stored as flat ``{path: np.ndarray}`` npz files — fully
shard-agnostic, so a checkpoint written on one mesh restores onto any other
(``restore_resharded``): the elastic-scaling primitive. Writes go to a temp
file + fsync + atomic rename; a crash mid-write (or a power loss right
after) never corrupts the latest good step.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "save_arrays", "restore_checkpoint",
           "latest_checkpoint", "restore_resharded", "checkpoint_step"]

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def save_arrays(ckpt_dir: str, step: int, arrays: dict,
                keep: int = 3, protect=()) -> str:
    """Write an already-flattened ``{keypath: array}`` mapping as one
    checkpoint file (same atomic commit + retention as
    ``save_checkpoint``). ``protect`` names checkpoint basenames
    retention must never unlink — e.g. the full base an incremental
    snapshot chain still references."""
    os.makedirs(ckpt_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())                 # bytes down before the name
        final = os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")
        os.replace(tmp, final)                   # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _apply_retention(ckpt_dir, keep, protect=protect)
    return final


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    keep: int = 3, protect=()) -> str:
    return save_arrays(ckpt_dir, step, _flatten(state), keep=keep,
                       protect=protect)


def _apply_retention(ckpt_dir: str, keep: int, protect=()):
    protect = frozenset(protect)
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if _STEP_RE.search(f))
    for f in ckpts[:-keep] if keep else []:
        if f not in protect:
            os.unlink(os.path.join(ckpt_dir, f))


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(f for f in os.listdir(ckpt_dir) if _STEP_RE.search(f))
    return os.path.join(ckpt_dir, ckpts[-1]) if ckpts else None


def checkpoint_step(path: str) -> int:
    m = _STEP_RE.search(path)
    return int(m.group(1)) if m else -1


def restore_checkpoint(path: str, template: Any,
                       overlay: Optional[str] = None) -> Any:
    """Restore into the structure of ``template`` (shapes must match).

    ``overlay`` names a second (delta) checkpoint whose keys win over
    ``path`` — how an incremental snapshot chain resolves: base arrays
    from the full checkpoint, the delta/tombstone/id-map arrays from the
    newest incremental."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    over = {}
    if overlay is not None:
        with np.load(overlay) as d:
            over = {k: d[k] for k in d.files}
    with np.load(path) as data:
        leaves = []
        for kpath, leaf in flat:
            key = jax.tree_util.keystr(kpath)
            arr = over[key] if key in over else data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch at {key}: "
                    f"ckpt {arr.shape} vs template {leaf.shape}")
            leaves.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_resharded(path: str, template: Any, shardings: Any,
                      overlay: Optional[str] = None) -> Any:
    """Restore onto a (possibly different) mesh: elastic scaling.

    ``shardings`` is a pytree of NamedSharding congruent with ``template``;
    each leaf is device_put directly to its target sharding, so restore on
    2x fewer/more hosts needs no conversion step.
    """
    state = restore_checkpoint(path, template, overlay=overlay)
    return jax.tree.map(jax.device_put, state, shardings)
