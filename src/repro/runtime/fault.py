"""Fault tolerance: checkpoint/restart driver + failure injection.

``run_with_restarts`` is the production step-loop contract:

  * checkpoint every ``ckpt_every`` steps (atomic, retention-managed)
  * on any step failure, resume from the newest valid checkpoint and replay
    — the deterministic data pipeline (``repro.data.pipeline``) guarantees
    the replayed stream is identical, so a restart is bitwise-reproducible
  * stragglers: because each host's shard is a pure function of
    (seed, step, shard), a slow/replaced host never blocks data
    redistribution; the step barrier is the only sync point.

``FailureInjector`` raises at configured steps to exercise the path in tests
and examples (this container is single-process; multi-host failures are
simulated at the step-function boundary, which is where they surface to JAX
anyway — a failed collective raises from the step call).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from .checkpoint import (latest_checkpoint, restore_checkpoint,
                         save_checkpoint, checkpoint_step)

__all__ = ["FailureInjector", "run_with_restarts"]


class FailureInjector:
    """Raises RuntimeError at the given fail points (once each).

    Points are global step numbers in the ``run_with_restarts`` loop, or
    string labels for the named engine lifecycle points
    (``SearchEngine.crash_hook`` fires ``maybe_fail("wal_appended")``,
    ``"compact_swap"``, ... — the crash drills of
    ``tests/test_durability.py``)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)

    def maybe_fail(self, step):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_with_restarts(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    ckpt_dir: str,
    *,
    ckpt_every: int = 10,
    keep: int = 3,
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 10,
) -> Any:
    """Run ``state = step_fn(state, step)`` for n_steps with checkpoint/
    restart. Returns the final state. Restart resumes from the newest valid
    checkpoint (or from scratch if none)."""
    restarts = 0
    while True:
        path = latest_checkpoint(ckpt_dir)
        if path is not None:
            state = restore_checkpoint(path, init_state)
            start = checkpoint_step(path) + 1
        else:
            state, start = init_state, 0
        try:
            for step in range(start, n_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                state = step_fn(state, step)
                if (step + 1) % ckpt_every == 0 or step == n_steps - 1:
                    save_checkpoint(ckpt_dir, step, state, keep=keep)
            return state
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
