"""Production train launcher: ``--arch <id>`` resolves a registry config;
reduced sizes run end-to-end on CPU, full sizes target the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 10 --ckpt-dir /tmp/ck

Features exercised: deterministic sharded data, AdamW, checkpoint/restart
(resumes from the newest checkpoint in --ckpt-dir), optional int8
error-feedback gradient compression.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import lm_token_batches
from repro.models.transformer import lm_init_params, lm_train_forward
from repro.optim import (AdamWConfig, adamw_update, ef_compress_update,
                         init_compression_state, init_opt_state)
from repro.runtime import run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    # reduced config of the requested arch family (full configs are
    # exercised via the dry-run; real-hardware launches swap in CONFIG)
    import importlib
    from repro.configs.registry import ARCH_MODULES
    mod = importlib.import_module(ARCH_MODULES[args.arch])
    if not hasattr(mod, "SMOKE"):
        # non-LM archs: delegate to their smoke step loop
        arch = mod.get_arch()
        out = arch.smoke()
        print(f"{args.arch}: non-LM arch; smoke train step ran: {out}")
        return
    cfg = mod.SMOKE
    print(f"training reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}")

    params = lm_init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params)
    cstate = init_compression_state(params) if args.grad_compression else None
    adam = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)

    @jax.jit
    def grad_step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_train_forward(p, cfg, batch))(params)
        return loss, grads

    batches = list(lm_token_batches(0, args.batch, args.seq, cfg.vocab,
                                    n_steps=args.steps))

    def step_fn(state, i):
        nonlocal cstate
        loss, grads = grad_step(state["params"], state["opt"], batches[i])
        if cstate is not None:
            grads, cstate = ef_compress_update(grads, cstate)
        p, o = adamw_update(grads, state["opt"], state["params"], adam)
        print(f"step {i:4d} loss {float(loss):.4f}")
        return {"params": p, "opt": o}

    final = run_with_restarts(step_fn, {"params": params, "opt": opt},
                              args.steps, args.ckpt_dir,
                              ckpt_every=args.ckpt_every)
    print("done; final step:", int(final["opt"]["step"]))


if __name__ == "__main__":
    main()
