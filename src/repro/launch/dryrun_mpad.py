import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Dry-run of the PAPER CORE at production scale: one distributed-MPAD
optimization iteration (shard_map over the full 512-chip multi-pod mesh),
N=2^20 corpus rows x 1024 dims, rows sharded over every axis.

Proves the comm-optimal design of DESIGN.md §3.4: per iteration each chip
moves O(N) scalar bytes (all-gather of projections) + O(n) gradient psum —
vs O(N·n) for a naive data exchange.

  PYTHONPATH=src python -m repro.launch.dryrun_mpad [--n 1048576 --dim 1024]
"""
import argparse
import functools
import json

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import make_phi_dist
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_048_576)
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun/mpad_core.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=True)
    axes = tuple(mesh.axis_names)
    phi = make_phi_dist(axes, args.n)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axes, None), P(None, None), P(None)),
        out_specs=(P(), P()), check_rep=False)
    def one_iter(w, x_loc, prev, mask):
        return phi(w, x_loc, prev, mask, b=80.0, alpha=25.0)

    sd = jax.ShapeDtypeStruct
    argspecs = (sd((args.dim,), jnp.float32),
                sd((args.n, args.dim), jnp.float32),
                sd((args.m, args.dim), jnp.float32),
                sd((args.m,), jnp.float32))
    jitted = jax.jit(one_iter,
                     in_shardings=(NamedSharding(mesh, P()),
                                   NamedSharding(mesh, P(axes, None)),
                                   NamedSharding(mesh, P(None, None)),
                                   NamedSharding(mesh, P(None))))
    compiled = jitted.lower(*argspecs).compile()
    hlo = compiled.as_text()
    tca = analyze_hlo(hlo)
    mem = compiled.memory_analysis()
    naive = args.n * args.dim * 4          # naive data-exchange bytes
    rec = {
        "cell": "multipod_2x16x16.mpad-core.fit_iteration",
        "n": args.n, "dim": args.dim,
        "dot_flops_dev": tca["dot_flops"],
        "bytes_dev": tca["bytes"],
        "coll_bytes_dev": tca["coll_total"],
        "coll_counts": tca["coll_counts"],
        "peak_mem_dev": mem.peak_memory_in_bytes,
        "naive_exchange_bytes": naive,
        "comm_reduction_vs_naive": naive / max(tca["coll_total"], 1),
    }
    print(json.dumps(rec, indent=1))
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"\nper-chip collective bytes/iteration: {tca['coll_total']:.3e} "
          f"(all-gather of N scalars + psum of the n-gradient)\n"
          f"naive X-exchange would be {naive:.3e} B "
          f"({rec['comm_reduction_vs_naive']:.0f}x more)")


if __name__ == "__main__":
    main()
