import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. jits the cell's step function with the arch's in/out shardings,
  3. ``.lower(*ShapeDtypeStructs).compile()`` — no real allocation,
  4. records ``memory_analysis()`` (proves fit), ``cost_analysis()``
     (FLOPs/bytes for the roofline), and the collective-op byte volume
     parsed from the partitioned HLO,
  5. writes one JSON artifact per cell to --out (incremental: finished
     cells are skipped on re-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both \
      [--arch NAME] [--shape NAME] [--out benchmarks/artifacts/dryrun]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import get_arch, all_arch_names
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.parallel.context import mesh_context
from repro.parallel.sharding import tree_named

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in partitioned HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # match "= <shape> <coll>(" and "-start(" variants; skip -done
            if (f" {coll}(" in stripped or f" {coll}-start(" in stripped):
                lhs, _, rhs = stripped.partition("(")
                operands = rhs.rsplit(")", 1)[0]
                n = sum(_tensor_bytes(m.group(1), m.group(2))
                        for m in _SHAPE_RE.finditer(operands))
                if n == 0:  # operands listed by name only: use result shape
                    n = sum(_tensor_bytes(m.group(1), m.group(2))
                            for m in _SHAPE_RE.finditer(lhs))
                out[coll] += n
                counts[coll] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def _memory_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:                             # backend-specific
        return {"error": str(e)}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "peak_memory_in_bytes", "generated_code_size_in_bytes")
    d = {k: getattr(m, k) for k in keys if hasattr(m, k)}
    if not d:
        d = {"repr": str(m)}
    return d


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, verbose: bool = True) -> dict:
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell_id = f"{mesh_tag}.{arch_name}.{shape_name}"
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skipped"):
            if verbose:
                print(f"[cached] {cell_id}: {rec['status']}")
            return rec
    arch = get_arch(arch_name)
    sdef = arch.shapes[shape_name]
    rec = {"cell": cell_id, "arch": arch_name, "shape": shape_name,
           "mesh": mesh_tag, "kind": sdef.kind,
           "n_devices": 512 if multi_pod else 256,
           "model_flops": arch.model_flops(shape_name)}
    if sdef.skip is not None:
        rec.update(status="skipped", reason=sdef.skip)
        _write(path, rec)
        if verbose:
            print(f"[skip]   {cell_id}: {sdef.skip}")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh_context(mesh):
            args = arch.abstract_args(shape_name)
            in_sh = tree_named(mesh, arch.arg_specs(shape_name, mesh))
            out_sh = tree_named(mesh, arch.out_specs(shape_name, mesh))
            step = arch.step_fn(shape_name)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            cost = dict(compiled.cost_analysis() or {})
            mem = _memory_dict(compiled)
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            # trip-count-aware analysis (XLA cost_analysis counts scan
            # bodies once; this multiplies through known_trip_count)
            tca = analyze_hlo(hlo)
        rec.update(
            status="ok", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            cost_raw={k: v for k, v in cost.items()
                      if isinstance(v, (int, float)) and not k.startswith("utilization")},
            memory=mem, collectives=coll,
            hlo_dot_flops=tca["dot_flops"], hlo_bytes_accessed=tca["bytes"],
            hlo_coll_bytes=tca["coll_total"],
            hlo_coll_detail={k: v for k, v in tca.items()
                             if k.startswith("coll_")},
            hlo_coll_counts=tca["coll_counts"],
            hlo_bytes=len(hlo))
        if verbose:
            print(f"[ok]     {cell_id}: compile {t_compile:.0f}s "
                  f"dotflops={tca['dot_flops']:.3e} "
                  f"coll={tca['coll_total']:.3e}B")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[FAIL]   {cell_id}: {type(e).__name__}: {e}")
    _write(path, rec)
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else all_arch_names()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failed = []
    for multi in meshes:
        for a in archs:
            arch = get_arch(a)
            shapes = [args.shape] if args.shape else list(arch.shapes)
            for s in shapes:
                rec = run_cell(a, s, multi, args.out)
                if rec["status"] == "error":
                    failed.append(rec["cell"])
    print(f"\ndone. {'FAILURES: ' + ', '.join(failed) if failed else 'all cells ok.'}")
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
