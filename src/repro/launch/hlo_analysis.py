"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE —
scan-stacked layer models (all our LMs) under-count FLOPs/bytes by ~n_layers
and miss collectives inside scan bodies entirely. This parser walks the
post-optimization HLO text instead:

  * builds a symbol table of every instruction's result shape,
  * computes dot FLOPs exactly (2 * prod(out) * prod(contracted)),
  * computes bytes accessed per top-level op (operands + outputs; fusion
    internals collapse into the fusion op),
  * sums collective operand bytes per collective kind,
  * weights everything by ``known_trip_count`` through nested while loops
    (scan bodies multiply correctly even when nested).

This is the measurement backbone of EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HLO_COLLECTIVES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "s4": 1, "u4": 1,
}

HLO_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^\(?\s*([a-z0-9]+)\[([0-9,]*)\]")
_TUPLE_SHAPES = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPNAME = re.compile(r"^(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([\w\-]+)\(")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
    "domain",
}


def _shape_bytes(dtype: str, dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * DTYPE_BYTES.get(dtype, 4)


def _parse_result_shapes(rhs: str) -> List[Tuple[str, List[int]]]:
    """Result type(s) from the rhs of '='; tuples give several entries."""
    if rhs.startswith("("):
        end = rhs.index(")")
        return [(m.group(1), [int(x) for x in m.group(2).split(",") if x])
                for m in _TUPLE_SHAPES.finditer(rhs[:end])]
    m = _SHAPE.match(rhs)
    if not m:
        return []
    return [(m.group(1), [int(x) for x in m.group(2).split(",") if x])]


class _Instr:
    __slots__ = ("name", "op", "rhs", "shapes", "operands")

    def __init__(self, name, op, rhs, shapes, operands):
        self.name, self.op, self.rhs = name, op, rhs
        self.shapes, self.operands = shapes, operands


def _parse_module(hlo: str):
    comps: Dict[str, List[_Instr]] = {}
    roots: Dict[str, str] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        is_hdr = ((line.startswith("%") or line.startswith("ENTRY"))
                  and stripped.endswith("{") and "->" in stripped)
        if is_hdr:
            tok = (stripped.split()[1] if stripped.startswith("ENTRY")
                   else stripped.split()[0])
            cur = tok.lstrip("%").split("(")[0]
            comps[cur] = []
            if stripped.startswith("ENTRY"):
                entry = cur
            continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(stripped)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shapes = _parse_result_shapes(rhs)
        opm = _OPNAME.match(rhs)
        op = opm.group(1) if opm else ""
        paren = rhs.find("(", rhs.find(op) if op else 0)
        operands = []
        if paren >= 0:
            depth, j = 0, paren
            while j < len(rhs):
                if rhs[j] == "(":
                    depth += 1
                elif rhs[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
                j += 1
            operands = _OPERANDS.findall(rhs[paren:j + 1])
        comps[cur].append(_Instr(name, op, rhs, shapes, operands))
        if stripped.startswith("ROOT"):
            roots[cur] = op
    return comps, entry, roots


def _dot_flops(instr: _Instr, table) -> float:
    out_elems = 1
    for _, dims in instr.shapes:
        for d in dims:
            out_elems *= d
    lhs_shape = None
    for o in instr.operands:
        if o in table:
            lhs_shape = table[o]
            break
    if lhs_shape is None:
        return 0.0
    cdims = _LHS_C.search(instr.rhs)
    contracted = 1
    if cdims and cdims.group(1):
        for ax in cdims.group(1).split(","):
            ax = int(ax)
            if ax < len(lhs_shape[1]):
                contracted *= lhs_shape[1][ax]
    return 2.0 * out_elems * contracted


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _make_operand_charger(comps, roots, table):
    """Returns charge(ins) -> bytes for one op, with fusion introspection:
    a fusion operand consumed ONLY by dynamic-slice ops inside the callee is
    charged at the slice size (what the kernel actually reads), not the full
    buffer — otherwise scan bodies slicing stacked buffers look like they
    re-read the whole stack every trip."""
    param_charge_cache: Dict[str, Dict[int, float]] = {}

    def callee_param_charges(callee: str) -> Dict[int, float]:
        if callee in param_charge_cache:
            return param_charge_cache[callee]
        charges: Dict[int, float] = {}
        instrs = comps.get(callee, [])
        by_name = {i.name: i for i in instrs}
        params = {}
        for i in instrs:
            if i.op == "parameter":
                m = _PARAM_IDX.search(i.rhs)
                if m:
                    params[i.name] = int(m.group(1))
        for pname, idx in params.items():
            consumers = [i for i in instrs if pname in i.operands]
            if consumers and all(c.op == "dynamic-slice" for c in consumers):
                charges[idx] = sum(
                    2.0 * sum(_shape_bytes(*s) for s in c.shapes)
                    for c in consumers)
        param_charge_cache[callee] = charges
        return charges

    def charge(ins: _Instr) -> float:
        opb = [_shape_bytes(*table[o]) if o in table else 0
               for o in ins.operands]
        outb = sum(_shape_bytes(*s) for s in ins.shapes)
        callee = None
        if ins.op == "fusion":
            cm = _CALLS.search(ins.rhs)
            callee = cm.group(1) if cm else None
        root_op = roots.get(callee, "") if callee else ""
        if ins.op == "dynamic-update-slice" or root_op == "dynamic-update-slice":
            big = max(opb) if opb else 0
            return 2.0 * max(sum(opb) - big, 0)
        if ins.op == "dynamic-slice" or root_op == "dynamic-slice":
            return 2.0 * outb
        if callee:
            charges = callee_param_charges(callee)
            total = outb
            for i, b in enumerate(opb):
                total += charges.get(i, b)
            return total
        return outb + sum(opb)

    return charge


def analyze_hlo(hlo: str) -> dict:
    comps, entry, roots = _parse_module(hlo)
    # global symbol table name -> (dtype, dims); per-computation conflicts are
    # rare post-opt (names suffixed); last writer wins is acceptable.
    table: Dict[str, Tuple[str, List[int]]] = {}
    for instrs in comps.values():
        for ins in instrs:
            if ins.shapes:
                table[ins.name] = ins.shapes[0]
    charge = _make_operand_charger(comps, roots, table)

    memo: Dict[str, dict] = {}

    def comp_cost(cname: str) -> dict:
        if cname in memo:
            return dict(memo[cname])
        total = {"dot_flops": 0.0, "bytes": 0.0,
                 **{f"coll_{c}": 0.0 for c in HLO_COLLECTIVES},
                 **{f"count_{c}": 0.0 for c in HLO_COLLECTIVES}}
        memo[cname] = total            # cycle guard
        for ins in comps.get(cname, []):
            op = ins.op
            if op in _ZERO_COST_OPS or not op:
                continue
            if op == "while":
                trip = 1
                tm = _TRIP.search(ins.rhs)
                if tm:
                    trip = int(tm.group(1))
                bm, cm = _BODY.search(ins.rhs), _COND.search(ins.rhs)
                for sub, mult in ((bm, trip), (cm, trip + 1)):
                    if sub:
                        sc = comp_cost(sub.group(1))
                        for k in total:
                            total[k] += mult * sc[k]
                continue
            if op in ("fusion", "call", "conditional", "custom-call",
                      "reduce", "map", "sort", "scatter", "select-and-scatter"):
                cm = _CALLS.search(ins.rhs)
                if cm and cm.group(1) in comps:
                    sc = comp_cost(cm.group(1))
                    # fusion internals collapse into one kernel: take FLOPs
                    # and collectives, NOT the internal bytes
                    for k in total:
                        if k != "bytes":
                            total[k] += sc[k]
            if op == "dot":
                total["dot_flops"] += _dot_flops(ins, table)
            # bytes with in-place-update + fusion slice-introspection
            # semantics (see _make_operand_charger) — without them, scan
            # bodies look like they move the whole stacked buffers per trip.
            total["bytes"] += charge(ins)
            base = op.replace("-start", "")
            if base in HLO_COLLECTIVES and not op.endswith("-done"):
                ob = sum(_shape_bytes(*table[o])
                         for o in ins.operands if o in table)
                if ob == 0:
                    ob = sum(_shape_bytes(*s) for s in ins.shapes)
                total[f"coll_{base}"] += ob
                total[f"count_{base}"] += 1
        memo[cname] = total
        return dict(total)

    if entry is None:
        return {"dot_flops": 0.0, "bytes": 0.0, "coll_total": 0.0}
    out = comp_cost(entry)
    out["coll_total"] = sum(out[f"coll_{c}"] for c in HLO_COLLECTIVES)
    out["coll_counts"] = {c: out.pop(f"count_{c}") for c in HLO_COLLECTIVES}
    return out


def bytes_breakdown(hlo: str, top: int = 12):
    """Trip-weighted bytes per (op, metadata op_name prefix) — the perf-loop
    profiling view: which ops move the memory term."""
    comps, entry, roots = _parse_module(hlo)
    table: Dict[str, Tuple[str, List[int]]] = {}
    for instrs in comps.values():
        for ins in instrs:
            if ins.shapes:
                table[ins.name] = ins.shapes[0]
    agg: Dict[str, float] = {}
    _META = re.compile(r'op_name="([^"]*)"')
    charge = _make_operand_charger(comps, roots, table)

    def visit(cname: str, weight: float):
        for ins in comps.get(cname, []):
            op = ins.op
            if op in _ZERO_COST_OPS or not op:
                continue
            if op == "while":
                trip = 1
                tm = _TRIP.search(ins.rhs)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY.search(ins.rhs)
                if bm:
                    visit(bm.group(1), weight * trip)
                continue
            b = charge(ins)
            mm = _META.search(ins.rhs)
            tag = "/".join(mm.group(1).split("/")[-3:])[-64:] if mm else ""
            key = f"{op}:{tag}"
            agg[key] = agg.get(key, 0.0) + weight * b

    if entry:
        visit(entry, 1.0)
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]
