"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; real deployments get the same shapes from the TPU topology.

  single-pod: (16, 16)    -> ("data", "model")   = 256 chips (v5e pod)
  multi-pod:  (2, 16, 16) -> ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(jax.devices())} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(axes))
