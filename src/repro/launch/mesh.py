"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; real deployments get the same shapes from the TPU topology.

  single-pod: (16, 16)    -> ("data", "model")   = 256 chips (v5e pod)
  multi-pod:  (2, 16, 16) -> ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_serving_mesh"]


def make_serving_mesh(shards: int | None = None, axis: str = "data"):
    """1-D data-parallel mesh for sharded serving: the first ``shards``
    devices (default: all) under a single ``axis`` name.

    CPU dry-runs / CI simulate the fleet with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before the
    first jax device query; real deployments get the shape from the
    accelerator topology.
    """
    devices = jax.devices()
    if shards is None:
        shards = len(devices)
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if len(devices) < shards:
        raise RuntimeError(
            f"need {shards} devices for a serving mesh, have "
            f"{len(devices)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} "
            "before the first jax device use")
    return jax.make_mesh((shards,), (axis,), devices=devices[:shards])


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(jax.devices())} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (see launch/dryrun.py)")
    try:                              # AxisType landed after jax 0.4.x;
        from jax.sharding import AxisType   # Auto matches its old default
        kw = {"axis_types": (AxisType.Auto,) * len(axes)}
    except ImportError:
        kw = {}
    return jax.make_mesh(shape, axes, devices=devices, **kw)
