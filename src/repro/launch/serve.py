"""Serving launcher: build an MPAD-reduced vector index over a corpus and
serve batched k-NN queries (the paper's deployment shape).

  PYTHONPATH=src python -m repro.launch.serve --corpus 20000 --dim 256 \
      --spec "qpad32>ivf64x8>rr40" --batches 5

The pipeline is declared either with ``--spec`` (the index-spec grammar:
``qpad<m> > ivf<nlist>x<nprobe> > pq<M>x<K>[:f32|bf16|i8][@jnp|kernel] >
rr<n>``) or with the individual legacy flags (``--index``/``--nlist``/...),
which are lowered onto the same spec. ``--snapshot-dir`` exercises the
persistence lifecycle: the built engine is saved and re-loaded before
serving.

Durable streaming: ``--stream --durable DIR`` snapshots the engine to DIR
and write-ahead-logs every mutation (``--fsync`` picks the durability/
throughput trade-off; ``--group-commit-ms`` coalesces ``--fsync always``
bursts into shared fsyncs), then serves from the crash-recovered engine;
``--background-compact`` folds the delta on a worker thread instead of
blocking searches.

Observability: ``--metrics-port N`` serves the engine's typed metrics
snapshot (``SearchEngine.metrics()``) from a stdlib http.server thread —
``GET /metrics`` is Prometheus text, ``GET /metrics.json`` the flattened
JSON (port 0 binds an ephemeral port and prints it). Request-level
tracing rides the same engine: ``--trace-dir DIR`` exports a
Chrome-trace JSON of the served batches, ``--slow-query-ms T`` captures
over-threshold queries into a ring buffer, ``--deep-trace-every N``
re-runs 1-in-N batches through the staged pipeline for per-stage
latency attribution, and ``--recall-every N`` shadow-checks 1-in-N
batches against the exact scan to estimate live recall — any of these
turns on the ``latency.*`` histograms in the scrape.

Sharded serving: ``--shards N`` partitions the engine state over an N-way
data mesh (``--mesh host`` simulates the N devices on CPU — useful for
dry-runs; it must run before jax touches its backend, which this launcher
guarantees by setting XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import argparse
import os
import time


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--spec", default=None,
                    help="index pipeline spec string, e.g. "
                         "'qpad32>ivf64x8>pq8x256:i8' — overrides "
                         "--target-dim/--index/--nlist/--nprobe/"
                         "--pq-subspaces/--lut-dtype/--pq-backend")
    ap.add_argument("--target-dim", type=int, default=32,
                    help="MPAD reduction target (0 = no reduction)")
    ap.add_argument("--reducer", choices=["qpad", "pca", "mlp"],
                    default="qpad",
                    help="Reduce-stage kind (the reducer zoo; ignored "
                         "when --target-dim is 0)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--index", choices=["flat", "ivf", "pq", "opq",
                                        "ivfpq"],
                    default="flat")
    ap.add_argument("--nlist", type=int, default=64)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--pq-subspaces", type=int, default=8)
    ap.add_argument("--lut-dtype", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="ADC lookup-table precision (pq/ivfpq)")
    ap.add_argument("--pq-backend", choices=["jnp", "kernel"], default="jnp",
                    help="ADC scoring backend (kernel = fused Pallas scan)")
    ap.add_argument("--interpret", dest="interpret", action="store_true",
                    default=None,
                    help="run the Pallas ADC kernel in interpret mode "
                         "(CPU-safe smoke of --pq-backend kernel; the "
                         "engine default)")
    ap.add_argument("--no-interpret", dest="interpret", action="store_false",
                    help="compile the Pallas ADC kernel for the real "
                         "accelerator")
    ap.add_argument("--query-bucket", type=int, default=64,
                    help="min padded query-batch size; ragged batches round "
                         "up to powers of two and share compilations")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="save the built engine to DIR and serve from the "
                         "re-loaded snapshot (persistence smoke)")
    ap.add_argument("--shards", type=int, default=0,
                    help="partition EngineState over this many devices "
                         "(data-parallel sharded serving; 0 = single-device)")
    ap.add_argument("--mesh", choices=["device", "host"], default="device",
                    help="mesh device source: 'device' = the real jax "
                         "devices; 'host' = simulate --shards CPU devices "
                         "via --xla_force_host_platform_device_count")
    ap.add_argument("--donate", action="store_true",
                    help="with --shards: release the dense EngineState "
                         "once the sharded copy is placed (no 2x memory)")
    ap.add_argument("--stream", action="store_true",
                    help="mutable serving: interleave a 90/10 read/write "
                         "workload (upserts into the delta segment, "
                         "tombstoned deletes, auto-compaction)")
    ap.add_argument("--delta-capacity", type=int, default=512,
                    help="--stream: delta segment size (rows)")
    ap.add_argument("--write-batch", type=int, default=64,
                    help="--stream: rows per upsert batch")
    ap.add_argument("--durable", default=None, metavar="DIR",
                    help="--stream: make the engine durable — snapshot to "
                         "DIR, write-ahead log every mutation, and reopen "
                         "via crash recovery (load_engine) before serving")
    ap.add_argument("--fsync", choices=["always", "batch", "never"],
                    default="batch",
                    help="--durable: WAL fsync mode (default batch)")
    ap.add_argument("--background-compact", action="store_true",
                    help="--stream: fold the delta on a worker thread and "
                         "swap atomically instead of blocking searches")
    ap.add_argument("--group-commit-ms", type=float, default=0.0,
                    help="--durable --fsync always: coalesce concurrent "
                         "WAL appends into shared fsyncs, waiting at most "
                         "this long to gather a batch (0 = one fsync per "
                         "record)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve SearchEngine.metrics() over HTTP from a "
                         "background thread: /metrics (Prometheus text), "
                         "/metrics.json (JSON); 0 = ephemeral port")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export a Chrome-trace JSON of the served "
                         "batches into DIR (open in chrome://tracing or "
                         "Perfetto); implies latency histograms")
    ap.add_argument("--slow-query-ms", type=float, default=None, metavar="T",
                    help="capture searches slower than T ms into the "
                         "tracer's slow-query ring buffer (printed at "
                         "the end of the run)")
    ap.add_argument("--deep-trace-every", type=int, default=0, metavar="N",
                    help="re-run 1-in-N batches through the staged "
                         "pipeline for exact per-stage latency "
                         "attribution (0 = off; read-only unsharded "
                         "engines only)")
    ap.add_argument("--recall-every", type=int, default=0, metavar="N",
                    help="shadow-check 1-in-N batches against an exact "
                         "brute-force scan and maintain the "
                         "recall.estimate_at_k gauge (0 = off)")
    return ap.parse_args()


def _spec_from_flags(args):
    """Lower the legacy flags onto a pipeline spec (one build path; the
    stages are constructed directly so the grammar lives only in
    ``repro.search.spec``). Import deferred: must run after the XLA_FLAGS
    setup in ``main``."""
    from repro.search import Coarse, Code, IndexSpec, Reduce, Rerank
    return IndexSpec(
        reduce=(Reduce(args.target_dim, kind=args.reducer)
                if args.target_dim else None),
        coarse=(Coarse(nlist=args.nlist, nprobe=args.nprobe)
                if args.index in ("ivf", "ivfpq") else None),
        code=(Code(kind="opq" if args.index == "opq" else "pq",
                   subspaces=args.pq_subspaces, centroids=256,
                   lut_dtype=args.lut_dtype, backend=args.pq_backend)
              if args.index in ("pq", "opq", "ivfpq") else None),
        rerank=Rerank(4 * args.k))


def main():
    args = _parse_args()
    if args.shards and args.mesh == "host":
        # must land before jax initializes its backend (first device use)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}")

    import jax

    from repro.core import MPADConfig
    from repro.data.synthetic import make_clustered
    from repro.launch.mesh import make_serving_mesh
    from repro.search import (StreamConfig, build_engine, format_spec,
                              knn_search, load_engine, parse_spec)
    from repro.search.knn import recall_at_k

    spec = parse_spec(args.spec) if args.spec else _spec_from_flags(args)
    key = jax.random.key(0)
    corpus, _ = make_clustered(key, args.corpus, 1, args.dim, n_clusters=64,
                               spread=0.4, center_scale=1.5)
    t0 = time.time()
    runtime = dict(query_bucket=args.query_bucket, fit_sample=4096)
    if args.interpret is not None:
        runtime["pq_interpret"] = args.interpret
    if args.stream:
        runtime["stream"] = StreamConfig(
            delta_capacity=args.delta_capacity,
            background_compact=args.background_compact)
    if spec.reduce is not None and spec.reduce.kind == "qpad":
        # the MPAD knobs configure the qpad kind only; other reducers
        # own their training hyperparameters
        runtime["mpad"] = MPADConfig(m=spec.reduce.m, iters=64,
                                     batch_size=2048)
    engine = build_engine(corpus, spec, **runtime)
    print(f"index built in {time.time()-t0:.1f}s "
          f"(spec={format_spec(spec)}, kind={spec.kind}"
          + (f", streaming delta={args.delta_capacity}" if args.stream
             else "") + ")")
    if args.durable:
        from repro.search import DurabilityConfig
        t0 = time.time()
        engine.durable(args.durable, DurabilityConfig(
            fsync=args.fsync, group_commit_ms=args.group_commit_ms))
        # reopen through the recovery path so the launcher exercises the
        # same snapshot+replay an operator would see after a crash
        engine = load_engine(args.durable)
        print(f"durable via {args.durable} in {time.time()-t0:.1f}s "
              f"(fsync={args.fsync}"
              + (f", group_commit_ms={args.group_commit_ms}"
                 if args.group_commit_ms else "")
              + "; every write WAL-logged, served from the recovered "
              "engine)")
    if args.snapshot_dir:
        t0 = time.time()
        engine.save(args.snapshot_dir)
        engine = load_engine(args.snapshot_dir)
        print(f"snapshot round-trip via {args.snapshot_dir} in "
              f"{time.time()-t0:.1f}s (serving from the restored engine)")
    if args.shards:
        mesh = make_serving_mesh(args.shards)
        engine.shard(mesh, donate=args.donate)
        print(f"engine sharded over mesh {dict(mesh.shape)} "
              f"({args.corpus} rows -> ~{-(-args.corpus // args.shards)} "
              "per shard"
              + (", dense state donated" if args.donate else "") + ")")
    tracing_on = (args.trace_dir is not None
                  or args.slow_query_ms is not None
                  or args.deep_trace_every or args.recall_every
                  or args.metrics_port is not None)
    if tracing_on:
        # attach to the FINAL engine object (post durable/snapshot/shard
        # swap-outs) so the tracer sees the served programs
        engine.tracing(trace_dir=args.trace_dir,
                       slow_query_ms=args.slow_query_ms,
                       deep_trace_every=args.deep_trace_every,
                       recall_every=args.recall_every)
        knobs = ["histograms"]
        if args.trace_dir is not None:
            knobs.append(f"trace_dir={args.trace_dir}")
        if args.slow_query_ms is not None:
            knobs.append(f"slow_query_ms={args.slow_query_ms}")
        if args.deep_trace_every:
            knobs.append(f"deep_trace_every={args.deep_trace_every}")
        if args.recall_every:
            knobs.append(f"recall_every={args.recall_every}")
        print(f"tracing on ({', '.join(knobs)})")
    metrics_srv = None
    if args.metrics_port is not None:
        from repro.search import MetricsServer
        metrics_srv = MetricsServer(engine, port=args.metrics_port)
        print(f"metrics at {metrics_srv.url} (Prometheus text; "
              f"/metrics.json for JSON)")

    total, rec_sum = 0.0, 0.0
    write_s, rows_written = 0.0, 0
    next_id = args.corpus
    import numpy as np
    for i in range(args.batches):
        queries = corpus[jax.random.randint(
            jax.random.fold_in(key, i), (args.batch,), 0, args.corpus)]
        if args.stream:
            # the 10% write leg: upsert a batch of perturbed rows under
            # fresh ids, plus a few deletes — all served from the delta /
            # tombstones, auto-compacting at the threshold
            wb = args.write_batch
            vecs = corpus[:wb] + 0.01 * jax.random.normal(
                jax.random.fold_in(key, 1000 + i), (wb, args.dim))
            t0 = time.time()
            engine.upsert(np.arange(next_id, next_id + wb), vecs)
            if next_id > args.corpus:         # only delete rows WE streamed
                engine.delete(np.arange(next_id - wb,
                                        next_id - wb + wb // 8))
            jax.block_until_ready(engine.store.delta_count)
            write_s += time.time() - t0
            rows_written += wb
            next_id += wb
        t0 = time.time()
        _, ids = engine.search(queries, args.k)
        jax.block_until_ready(ids)
        dt = time.time() - t0
        _, truth = knn_search(queries, corpus, args.k)
        rec = float(recall_at_k(ids, truth))
        total += dt
        rec_sum += rec
        print(f"batch {i}: {dt*1e3:7.1f} ms  recall@{args.k}={rec:.4f}")
        if i == 0 and metrics_srv is not None and tracing_on:
            # mid-traffic scrape: the histogram series must already be
            # live after the first batch (the CI smoke greps for it)
            import urllib.request
            with urllib.request.urlopen(metrics_srv.url, timeout=5) as r:
                mid = r.read().decode().splitlines()
            hist = [ln for ln in mid
                    if ln.startswith("qpad_latency_search_seconds")]
            print(f"mid-traffic scrape: {len(mid)} lines, "
                  f"{len(hist)} latency-histogram samples")
            for line in hist[:3]:
                print(f"  {line}")
    print(f"\nmean: {total/args.batches*1e3:.1f} ms/batch "
          f"({args.batch/(total/args.batches):.0f} qps), "
          f"recall={rec_sum/args.batches:.4f}")
    if args.stream and write_s:
        print(f"writes: {rows_written} rows in {write_s:.2f}s "
              f"({rows_written/write_s:.0f} rows/s), "
              f"grow_count={engine.grow_count}")
        t0 = time.time()
        engine.compact()
        print(f"final compact: {time.time()-t0:.2f}s "
              f"(base rows={int(engine.store.n_rows)})")
        m = engine.metrics()
        if m.wal is not None:
            print(f"wal: {m.wal.records} records / {m.wal.bytes} bytes / "
                  f"{m.wal.fsyncs} fsyncs"
                  + (f" ({m.wal.group_commits} group commits)"
                     if m.wal.group_commits else "")
                  + f", {m.wal.replayed} replayed; "
                  f"compactions={m.compact.compactions} "
                  f"vacuums={m.compact.vacuums} "
                  f"rebuilds={m.compact.rebuilds}")
    if tracing_on:
        flat = engine.metrics().flatten()
        print(f"latency: p50={flat['latency.search.p50']:.2f}ms "
              f"p95={flat['latency.search.p95']:.2f}ms "
              f"p99={flat['latency.search.p99']:.2f}ms over "
              f"{flat['latency.queries']} traced searches")
        if args.recall_every:
            est = flat.get("recall.estimate_at_k")
            if est is not None:
                print(f"recall estimate: {est:.4f}@{flat['recall.k']} "
                      f"({flat['recall.samples']} shadow samples)")
        if args.deep_trace_every:
            stages = sorted(
                (name.split(".")[2], flat[name])
                for name in flat
                if name.startswith("latency.stages.")
                and name.endswith(".p50"))
            if stages:
                share = ", ".join(f"{s}={ms:.2f}ms" for s, ms in stages)
                print(f"deep-trace stage p50: {share} "
                      f"({flat['latency.deep_traces']} samples)")
        if args.slow_query_ms is not None:
            log = engine.tracer.slow_query_log()
            print(f"slow queries (>{args.slow_query_ms}ms): "
                  f"{flat['latency.slow_queries']} captured, "
                  f"{len(log)} in the ring")
            for entry in log[-3:]:
                print(f"  seq={entry['seq']} {entry['e2e_ms']:.2f}ms "
                      f"batch={entry['batch']} bucket={entry['bucket']} "
                      f"nprobe={entry['nprobe']} spec={entry['spec']}")
        if args.trace_dir is not None:
            path = engine.flush_trace()
            print(f"trace written: {path}")
    if metrics_srv is not None:
        import urllib.request
        with urllib.request.urlopen(metrics_srv.url, timeout=5) as r:
            sample = r.read().decode().splitlines()
        print("sample scrape (/metrics):")
        for line in sample[:8]:
            print(f"  {line}")
        metrics_srv.close()


if __name__ == "__main__":
    main()
