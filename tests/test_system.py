"""End-to-end behaviour tests for the paper's system (deliverable (c)):
the full pipeline — data -> MPAD -> index -> serve — and the paper's
headline claims on the benchmark protocol (reduced sizes)."""
import jax
import jax.numpy as jnp

from repro.core import MPADConfig, fit_mpad, fit_pca, fit_random_projection
from repro.data.synthetic import make_fasttext_like
from repro.search import SearchEngine, ServeConfig, amk_accuracy, knn_search
from repro.search.knn import recall_at_k


def _bench_data():
    return make_fasttext_like(jax.random.key(0), n_train=400, n_test=120)


def test_mpad_beats_variance_methods_on_heavy_tailed_data():
    """The paper's core claim (Fig.1 regime): on embedding-like data with
    heavy-tailed nuisance dimensions, MPAD preserves k-NN better than
    variance-driven projections."""
    xtr, xte = _bench_data()
    m, k = 30, 10
    acc_mpad = float(amk_accuracy(
        fit_mpad(xtr, MPADConfig(m=m, alpha=50.0, b=80.0, iters=80)),
        xtr, xte, k))
    acc_pca = float(amk_accuracy(fit_pca(xtr, m), xtr, xte, k))
    acc_rp = float(amk_accuracy(
        fit_random_projection(jax.random.key(1), xtr.shape[1], m),
        xtr, xte, k))
    assert acc_mpad > acc_pca, (acc_mpad, acc_pca)
    assert acc_mpad > acc_rp, (acc_mpad, acc_rp)


def test_accuracy_increases_with_target_dim():
    """Paper Fig.3 column 2: A_m(k) grows monotonically-ish with m."""
    xtr, xte = _bench_data()
    accs = [float(amk_accuracy(
        fit_mpad(xtr, MPADConfig(m=m, iters=48)), xtr, xte, 10))
        for m in (5, 30, 120)]
    assert accs[0] < accs[-1] + 0.02
    assert accs[1] <= accs[2] + 0.05


def test_end_to_end_serving_pipeline():
    """corpus -> MPAD fit -> IVF -> batched queries -> rerank -> recall."""
    key = jax.random.key(0)
    centers = jax.random.normal(key, (32, 128)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (2000,), 0, 32)
    corpus = centers[lab] + 0.4 * jax.random.normal(
        jax.random.fold_in(key, 2), (2000, 128))
    queries = corpus[:64] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 3), (64, 128))
    engine = SearchEngine(corpus, ServeConfig(
        target_dim=16, rerank=40, index="ivf", nlist=32, nprobe=8,
        mpad=MPADConfig(m=16, iters=32), fit_sample=1024))
    _, ids = engine.search(queries, 10)
    _, truth = knn_search(queries, corpus, 10)
    rec = float(recall_at_k(ids, truth))
    assert rec > 0.8, rec


def test_stochastic_mpad_matches_full_quality():
    """Beyond-paper stochastic MPAD stays within a few points of full-batch
    accuracy while touching a fraction of rows per iteration."""
    xtr, xte = _bench_data()
    full = float(amk_accuracy(
        fit_mpad(xtr, MPADConfig(m=20, iters=60)), xtr, xte, 10))
    stoch = float(amk_accuracy(
        fit_mpad(xtr, MPADConfig(m=20, iters=60, batch_size=128)),
        xtr, xte, 10))
    assert stoch > full - 0.08, (stoch, full)
