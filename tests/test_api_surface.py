"""Public-API surface: everything ``repro.search.__all__`` exports must
import, be documented, and cover the composable-API entry points."""
import inspect

import repro.search as search


def test_all_names_resolve():
    assert search.__all__, "repro.search must declare __all__"
    for name in search.__all__:
        assert hasattr(search, name), f"__all__ exports missing {name!r}"


def test_all_public_objects_are_documented():
    """Every exported class/function carries a docstring — the API is the
    documentation surface."""
    undocumented = []
    for name in search.__all__:
        obj = getattr(search, name)
        if inspect.isclass(obj) or inspect.isfunction(obj):
            if not (inspect.getdoc(obj) or "").strip():
                undocumented.append(name)
    assert not undocumented, f"undocumented public API: {undocumented}"


def test_composable_api_entry_points_exported():
    """The spec / registry / lifecycle / persistence layers are public."""
    for name in ("IndexSpec", "Reduce", "Coarse", "Code", "Rerank",
                 "parse_spec", "format_spec", "spec_from_config",
                 "config_from_spec", "Index", "IndexOps", "ScanParams",
                 "get_ops", "register_index", "build_engine", "save_engine",
                 "load_engine", "SearchEngine", "ServeConfig",
                 "StreamConfig", "Reducer", "ReducerOps", "register_reducer",
                 "get_reducer_ops", "fit_reducer", "reduce_vectors",
                 "reducer_dim", "REDUCER_KINDS"):
        assert name in search.__all__, f"{name} missing from __all__"


def test_reducer_registry_covers_kinds():
    """Every registered reducer kind exposes the full ReducerOps hook
    table (the Reduce-stage counterpart of the index registry pin)."""
    assert set(search.REDUCER_KINDS) >= {"qpad", "pca", "mlp"}
    for kind in search.REDUCER_KINDS:
        ops = search.get_reducer_ops(kind)
        assert ops.kind == kind
        for hook in ("fit", "transform", "skeleton", "out_dim"):
            assert callable(getattr(ops, hook)), (kind, hook)


def test_registry_covers_index_kinds():
    for kind in search.INDEX_KINDS:
        ops = search.get_ops(kind)
        assert ops.kind == kind
        for hook in ("build", "scan", "local_scan", "stream_scan",
                     "shard_payload", "payload_specs", "store_parts",
                     "encode_delta", "rebuild", "stream_base_payload"):
            assert callable(getattr(ops, hook)), (kind, hook)


def test_exports_match_module_all():
    """Names re-exported from the submodules stay in sync with their
    source __all__ (no silently-dropped public symbols)."""
    from repro.search import registry, spec
    for name in spec.__all__:
        assert name in search.__all__, f"spec.{name} not re-exported"
    for name in ("Index", "IndexOps", "ScanParams", "get_ops",
                 "register_index"):
        assert name in registry.__all__
