"""Typed metrics / observability surface.

The contracts pinned here:

* **stable dotted names** — ``EngineMetrics.flatten()`` exposes the
  documented names (``wal.records``, ``wal.fsyncs``, ``stream.fill``,
  ``compact.pending``, ``policy.drift_ema``,
  ``replication.follower_lag_seq``, ``latency.search.p50``,
  ``recall.estimate_at_k``, ...); sections that do not apply drop out
  instead of renaming.
* **stats() is gone** — the PR-8 ``DeprecationWarning`` dict view
  completed its cycle; ``metrics()`` is the only counters window.
* **renderings** — ``render_prometheus`` emits ``qpad_``-prefixed
  samples with counter/gauge/histogram TYPE lines, sanitized metric
  names, escaped label values, and an ``qpad_engine_info`` label set;
  ``MetricsServer`` serves both forms over HTTP from a background
  thread (the launcher's ``--metrics-port``) and stays correct under
  concurrent scrapes mid-traffic.
* **exposition hygiene** — a pure-python lint accepts the ``/metrics``
  text of every index kind: well-formed sample lines, TYPE-before-
  sample ordering, cumulative histogram buckets ending in ``+Inf``
  whose count equals ``_count``.
"""
import json
import re
import threading
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.search import (DurabilityConfig, MetricsServer, PolicyConfig,
                          SearchEngine, ServeConfig, StreamConfig,
                          build_engine, render_prometheus, seed_follower)
from repro.search.metrics import _escape_label, _sanitize_name

pytestmark = pytest.mark.durability

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _stream_cfg(**stream_kw):
    stream_kw.setdefault("delta_capacity", 64)
    return ServeConfig(index="flat", rerank=128, fit_sample=512,
                       stream=StreamConfig(**stream_kw))


def _rows(seed, n):
    return np.asarray(_data(seed=seed, n=n), np.float32)


def test_typed_surface_dotted_names():
    """The documented dotted names are present with live values; the
    sections that do not apply are None and absent from flatten()."""
    eng = SearchEngine(_data(), _stream_cfg())
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    m = eng.metrics()
    flat = m.flatten()
    assert flat["engine.index"] == "flat"
    assert flat["engine.streaming"] is True
    assert flat["engine.role"] == "primary"
    assert flat["engine.compile_count"] == eng.compile_count
    assert flat["stream.delta_used"] == 20
    assert flat["stream.fill"] == pytest.approx(20 / 64)
    assert flat["compact.pending"] is False
    assert m.wal is None and m.replication is None
    assert m.latency is None and m.recall is None  # no tracer attached
    assert not any(k.startswith(("wal.", "replication.", "latency.",
                                 "recall.")) for k in flat)
    # read-only engines have no stream/compact/snapshot sections at all
    ro = SearchEngine(_data(), ServeConfig(index="flat")).metrics()
    assert ro.stream is None and ro.compact is None and ro.snapshot is None
    assert ro.engine.streaming is False


def test_typed_surface_wal_policy_and_follower_sections(tmp_path):
    """Durable engines expose wal.* (fsyncs, floor), policy engines
    policy.* (drift + decision counters), followers replication.*."""
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _stream_cfg(
        policy=PolicyConfig())).durable(
        live, DurabilityConfig(fsync="batch"))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    flat = eng.metrics().flatten()
    assert flat["wal.records"] >= 2            # snapshot mark + upsert
    assert flat["wal.fsyncs"] >= 1
    assert flat["wal.durable_seq"] <= flat["wal.last_seq"]
    assert flat["wal.floor_seq"] == 0          # pinned by the base snapshot
    assert flat["wal.fsync"] == "batch"
    assert flat["policy.observed_rows"] == 0
    assert "policy.drift_ema" in flat
    assert flat["snapshot.full"] == 1
    eng._wal.sync()
    fol = seed_follower(live)
    ff = fol.metrics().flatten()
    assert ff["engine.role"] == "follower"
    assert ff["replication.follower_lag_seq"] >= 0
    assert "wal.records" not in ff             # followers own no log


def test_stats_removed():
    """The deprecation cycle is closed: the dict view is gone and the
    typed surface is the only counters window."""
    eng = SearchEngine(_data(), _stream_cfg())
    assert not hasattr(eng, "stats")
    assert not hasattr(SearchEngine, "stats")
    assert eng.metrics().engine.streaming is True


def test_latency_section_and_histogram_rendering():
    """A traced engine grows latency.* names in flatten() and a proper
    Prometheus histogram (_bucket/_sum/_count) in the text form."""
    eng = SearchEngine(_data(), ServeConfig(index="flat")).tracing()
    q = _rows(3, 8)
    for _ in range(5):
        eng.search(q, K)
    flat = eng.metrics().flatten()
    assert flat["latency.queries"] == 5
    for p in ("p50", "p95", "p99"):
        assert flat[f"latency.search.{p}"] > 0.0
    assert flat["latency.search.p50"] <= flat["latency.search.p99"]
    assert flat["latency.search.count"] == 5
    assert flat["latency.search.sum_ms"] > 0.0
    text = render_prometheus(eng.metrics())
    assert "# TYPE qpad_latency_search_seconds histogram" in text
    buckets = [int(m.group(1)) for m in re.finditer(
        r'qpad_latency_search_seconds_bucket\{le="[^"]+"\} (\d+)', text)]
    assert buckets == sorted(buckets)          # cumulative
    assert buckets[-1] == 5                    # +Inf holds every sample
    assert "qpad_latency_search_seconds_count 5" in text
    assert "qpad_latency_search_seconds_sum " in text


def test_recall_section_and_slow_query_capture():
    """Shadow-exact sampling feeds recall.estimate_at_k; a zero slow
    threshold captures every query into the ring with its knobs."""
    eng = build_engine(_data(), "ivf12x4>pq8x64>rr40").tracing(
        recall_every=1, slow_query_ms=0.0, deep_trace_every=2)
    q = _rows(3, 8)
    for _ in range(4):
        eng.search(q, K)
    m = eng.metrics()
    assert m.recall.samples == 4
    assert 0.0 < m.recall.estimate_at_k <= 1.0
    assert m.recall.k == K
    assert m.latency.slow_queries == 4
    assert m.latency.deep_traces == 2          # sampled 1-in-2
    assert set(m.latency.stages) >= {"project", "probe", "scan", "rerank"}
    ring = eng.tracer.slow_query_log()
    assert len(ring) == 4
    assert ring[-1]["k"] == K and ring[-1]["batch"] == 8
    assert ring[-1]["e2e_ms"] > 0.0
    text = render_prometheus(m)
    assert "qpad_recall_estimate_at_k" in text
    assert "# TYPE qpad_recall_estimate_at_k gauge" in text


def test_render_prometheus_text():
    eng = SearchEngine(_data(), _stream_cfg())
    eng.upsert(np.arange(600, 610, dtype=np.int32), _rows(1, 10))
    text = render_prometheus(eng.metrics())
    assert "# TYPE qpad_engine_compile_count counter" in text
    assert "# TYPE qpad_stream_fill gauge" in text
    assert "qpad_stream_delta_used 10" in text
    assert "qpad_compact_pending 0" in text    # bools render as 0/1
    assert 'engine_index="flat"' in text
    assert text.rstrip().splitlines()[-1].startswith("qpad_engine_info{")


def test_name_sanitization_and_label_escaping():
    """Dotted names with hostile characters become valid Prometheus
    names; label values with quotes/backslashes/newlines stay one
    well-formed line."""
    assert _sanitize_name("latency.search.p50") == "latency_search_p50"
    assert _sanitize_name("qpad.per-stage/scan") == "qpad_per_stage_scan"
    assert _sanitize_name("0weird") == "_0weird"
    assert _sanitize_name("ok_name:sub") == "ok_name:sub"
    assert _escape_label('a"b') == 'a\\"b'
    assert _escape_label("a\\b") == "a\\\\b"
    assert _escape_label("a\nb") == "a\\nb"
    # end-to-end: a spec string with every hostile character survives
    # the info line as one parseable sample
    text = render_prometheus(
        SearchEngine(_data(), ServeConfig(index="flat")).metrics())
    info = [ln for ln in text.splitlines()
            if ln.startswith("qpad_engine_info{")]
    assert len(info) == 1 and "\n" not in info[0]


# --- exposition lint ---------------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})? '
    r'-?(\d+\.?\d*([eE][+-]?\d+)?|[+-]?Inf|NaN)$')


def _lint_exposition(text):
    """Minimal pure-python Prometheus text-format checker: every line is
    a comment or a well-formed sample; TYPE precedes its samples; each
    histogram's buckets are cumulative, end at +Inf, and agree with
    _count; no duplicate sample names outside histogram series."""
    typed, seen = {}, set()
    hist = {}
    for ln in text.splitlines():
        if not ln:
            continue
        if ln.startswith("# TYPE "):
            _, _, name, kind = ln.split(" ")
            assert name not in typed, f"duplicate TYPE for {name}"
            assert kind in ("counter", "gauge", "histogram"), ln
            typed[name] = kind
            continue
        if ln.startswith("#"):
            continue
        assert _SAMPLE_RE.match(ln), f"malformed sample line: {ln!r}"
        name = re.split(r"[{ ]", ln, maxsplit=1)[0]
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if typed.get(base) == "histogram":
            series = hist.setdefault(base, {"buckets": [], "count": None})
            val = float(ln.rsplit(" ", 1)[1])
            if name.endswith("_bucket"):
                le = re.search(r'le="([^"]+)"', ln).group(1)
                series["buckets"].append((le, val))
            elif name.endswith("_count"):
                series["count"] = val
        else:
            assert typed.get(name), f"sample before TYPE: {ln!r}"
            key = ln.rsplit(" ", 1)[0]
            assert key not in seen, f"duplicate sample: {key!r}"
            seen.add(key)
    for base, series in hist.items():
        counts = [v for _, v in series["buckets"]]
        assert counts == sorted(counts), f"{base} buckets not cumulative"
        assert series["buckets"][-1][0] == "+Inf", f"{base} missing +Inf"
        assert counts[-1] == series["count"], f"{base} +Inf != _count"
    return typed


@pytest.mark.parametrize("spec", ("flat", "ivf12x4", "pq8x64",
                                  "ivf12x4>pq8x64>rr40"))
def test_exposition_lint_every_index_kind(spec):
    """The /metrics text of every index kind — traced, so the histogram
    series render too — passes the exposition lint."""
    eng = build_engine(_data(), spec).tracing(recall_every=2)
    q = _rows(3, 8)
    for _ in range(3):
        eng.search(q, K)
    typed = _lint_exposition(render_prometheus(eng.metrics()))
    assert typed.get("qpad_latency_search_seconds") == "histogram"
    assert typed.get("qpad_engine_compile_count") == "counter"


def test_metrics_server_serves_both_forms(tmp_path):
    """The --metrics-port endpoint: Prometheus text at /metrics, the
    flattened JSON at /metrics.json, 404 elsewhere — all consuming only
    the typed surface."""
    eng = SearchEngine(_data(), _stream_cfg()).durable(
        str(tmp_path / "live"), DurabilityConfig(fsync="batch"))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    with MetricsServer(eng, port=0) as srv:
        assert srv.port > 0
        with urllib.request.urlopen(srv.url, timeout=10) as r:
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/plain")
            body = r.read().decode()
        assert "qpad_wal_records" in body
        assert "# TYPE qpad_wal_fsyncs counter" in body
        base = f"http://{srv.host}:{srv.port}"
        with urllib.request.urlopen(base + "/metrics.json",
                                    timeout=10) as r:
            doc = json.loads(r.read().decode())
        assert doc["stream.delta_used"] == 20
        assert doc["wal.records"] >= 2
        assert doc["engine.role"] == "primary"
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(base + "/nope", timeout=10)
        assert exc.value.code == 404


def test_metrics_server_concurrent_scrapes_mid_traffic(tmp_path):
    """Scrapes racing live writes + traced searches: every response is a
    200 that passes the exposition lint — collect_metrics reads a
    consistent engine view and the Tracer's lock keeps the histogram
    internally consistent."""
    eng = SearchEngine(_data(), _stream_cfg(delta_capacity=256)).tracing(
        slow_query_ms=0.0)
    q = _rows(3, 8)
    eng.search(q, K)                           # warm the read program
    errors = []

    def scraper(url, n):
        try:
            for _ in range(n):
                with urllib.request.urlopen(url, timeout=10) as r:
                    assert r.status == 200
                    _lint_exposition(r.read().decode())
        except Exception as e:                 # pragma: no cover - surfaced
            errors.append(e)

    with MetricsServer(eng, port=0) as srv:
        ths = [threading.Thread(target=scraper, args=(srv.url, 8))
               for _ in range(4)]
        for t in ths:
            t.start()
        for i in range(6):                     # traffic while they scrape
            eng.upsert(np.arange(600 + 8 * i, 608 + 8 * i, dtype=np.int32),
                       _rows(4 + i, 8))
            eng.search(q, K)
        for t in ths:
            t.join()
    assert not errors
    m = eng.metrics()
    assert m.latency.queries == 7              # warmup + 6 in-loop
    assert m.stream.delta_used == 48
