"""Typed metrics / observability surface.

The contracts pinned here:

* **stable dotted names** — ``EngineMetrics.flatten()`` exposes the
  documented names (``wal.records``, ``wal.fsyncs``, ``stream.fill``,
  ``compact.pending``, ``policy.drift_ema``,
  ``replication.follower_lag_seq``, ...); sections that do not apply
  drop out instead of renaming.
* **deprecation** — ``SearchEngine.stats()`` still returns the exact
  legacy dict shape but warns ``DeprecationWarning``; callers migrate to
  ``metrics()``.
* **renderings** — ``render_prometheus`` emits ``qpad_``-prefixed
  samples with counter/gauge TYPE lines and an ``qpad_engine_info``
  label set; ``MetricsServer`` serves both forms over HTTP from a
  background thread (the launcher's ``--metrics-port``).
"""
import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.search import (DurabilityConfig, MetricsServer, PolicyConfig,
                          SearchEngine, ServeConfig, StreamConfig,
                          render_prometheus, seed_follower)

pytestmark = pytest.mark.durability

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _stream_cfg(**stream_kw):
    stream_kw.setdefault("delta_capacity", 64)
    return ServeConfig(index="flat", rerank=128, fit_sample=512,
                       stream=StreamConfig(**stream_kw))


def _rows(seed, n):
    return np.asarray(_data(seed=seed, n=n), np.float32)


def test_typed_surface_dotted_names():
    """The documented dotted names are present with live values; the
    sections that do not apply are None and absent from flatten()."""
    eng = SearchEngine(_data(), _stream_cfg())
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    m = eng.metrics()
    flat = m.flatten()
    assert flat["engine.index"] == "flat"
    assert flat["engine.streaming"] is True
    assert flat["engine.role"] == "primary"
    assert flat["engine.compile_count"] == eng.compile_count
    assert flat["stream.delta_used"] == 20
    assert flat["stream.fill"] == pytest.approx(20 / 64)
    assert flat["compact.pending"] is False
    assert m.wal is None and m.replication is None
    assert not any(k.startswith(("wal.", "replication.")) for k in flat)
    # read-only engines have no stream/compact/snapshot sections at all
    ro = SearchEngine(_data(), ServeConfig(index="flat")).metrics()
    assert ro.stream is None and ro.compact is None and ro.snapshot is None
    assert ro.engine.streaming is False


def test_typed_surface_wal_policy_and_follower_sections(tmp_path):
    """Durable engines expose wal.* (fsyncs, floor), policy engines
    policy.* (drift + decision counters), followers replication.*."""
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _stream_cfg(
        policy=PolicyConfig())).durable(
        live, DurabilityConfig(fsync="batch"))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    flat = eng.metrics().flatten()
    assert flat["wal.records"] >= 2            # snapshot mark + upsert
    assert flat["wal.fsyncs"] >= 1
    assert flat["wal.durable_seq"] <= flat["wal.last_seq"]
    assert flat["wal.floor_seq"] == 0          # pinned by the base snapshot
    assert flat["wal.fsync"] == "batch"
    assert flat["policy.observed_rows"] == 0
    assert "policy.drift_ema" in flat
    assert flat["snapshot.full"] == 1
    eng._wal.sync()
    fol = seed_follower(live)
    ff = fol.metrics().flatten()
    assert ff["engine.role"] == "follower"
    assert ff["replication.follower_lag_seq"] >= 0
    assert "wal.records" not in ff             # followers own no log


def test_stats_is_a_deprecated_view():
    """stats() warns but keeps the exact legacy shape for one cycle."""
    eng = SearchEngine(_data(), _stream_cfg())
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    with pytest.warns(DeprecationWarning, match="metrics"):
        st = eng.stats()
    assert st["streaming"] and not st["sharded"]
    assert st["stream"]["delta_used"] == 20
    assert set(st["maintenance"]) == {"compactions", "swaps", "vacuums",
                                      "rebuilds", "policy_grows"}
    assert "wal" not in st


def test_render_prometheus_text():
    eng = SearchEngine(_data(), _stream_cfg())
    eng.upsert(np.arange(600, 610, dtype=np.int32), _rows(1, 10))
    text = render_prometheus(eng.metrics())
    assert "# TYPE qpad_engine_compile_count counter" in text
    assert "# TYPE qpad_stream_fill gauge" in text
    assert "qpad_stream_delta_used 10" in text
    assert "qpad_compact_pending 0" in text    # bools render as 0/1
    assert 'engine_index="flat"' in text
    assert text.rstrip().splitlines()[-1].startswith("qpad_engine_info{")


def test_metrics_server_serves_both_forms(tmp_path):
    """The --metrics-port endpoint: Prometheus text at /metrics, the
    flattened JSON at /metrics.json, 404 elsewhere — all consuming only
    the typed surface."""
    eng = SearchEngine(_data(), _stream_cfg()).durable(
        str(tmp_path / "live"), DurabilityConfig(fsync="batch"))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    with MetricsServer(eng, port=0) as srv:
        assert srv.port > 0
        with urllib.request.urlopen(srv.url, timeout=10) as r:
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/plain")
            body = r.read().decode()
        assert "qpad_wal_records" in body
        assert "# TYPE qpad_wal_fsyncs counter" in body
        base = f"http://{srv.host}:{srv.port}"
        with urllib.request.urlopen(base + "/metrics.json",
                                    timeout=10) as r:
            doc = json.loads(r.read().decode())
        assert doc["stream.delta_used"] == 20
        assert doc["wal.records"] >= 2
        assert doc["engine.role"] == "primary"
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(base + "/nope", timeout=10)
        assert exc.value.code == 404
