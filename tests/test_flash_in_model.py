"""attn_impl='flash' through the full LM forward == chunked path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (LMConfig, lm_init_params, lm_loss)

CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
               d_head=8, d_ff=64, vocab=64, seq_chunk=16, q_chunk=16,
               kv_chunk=16)


def test_flash_impl_matches_chunked_loss_and_grads():
    params = lm_init_params(jax.random.key(0), CFG)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, CFG.vocab)
    cfg_flash = dataclasses.replace(CFG, attn_impl="flash")
    l1 = lm_loss(params, CFG, toks, toks)
    l2 = lm_loss(params, cfg_flash, toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: lm_loss(p, CFG, toks, toks))(params)
    g2 = jax.grad(lambda p: lm_loss(p, cfg_flash, toks, toks))(params)
    np.testing.assert_allclose(g1["embed"], g2["embed"], atol=1e-5)


def test_flash_impl_local_global():
    cfg = LMConfig(name="g", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
                   d_head=8, d_ff=64, vocab=64, sliding_window=8,
                   global_every=2, seq_chunk=16, q_chunk=16, kv_chunk=16)
    params = lm_init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab)
    l1 = lm_loss(params, cfg, toks, toks)
    l2 = lm_loss(params, dataclasses.replace(cfg, attn_impl="flash"),
                 toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
