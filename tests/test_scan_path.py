"""Scan-path acceptance tests: uint8 codes end-to-end, the
nprobe-proportional compact scan, and the re-rank candidate pre-filter.

The speed paths this PR adds are all gated on BIT-IDENTICAL results — the
narrow code dtype, the posting-mass-capped gather, and the certified
pre-filter may change what the program reads and how wide it runs, never
what it returns. Every test here asserts ``array_equal`` (not allclose) on
ids AND distances against the reference path: jnp vs kernel backends,
1/2/8 devices, the streaming live-mask path, and a property sweep for the
pre-filter.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.search import SearchEngine, ServeConfig
from repro.search.ivfpq import ivfpq_adc_scan, ivfpq_compact_scan
from repro.search.registry import Index
from repro.search.serve import search_fn, sharded_search_fn

N, DIM, K = 601, 32, 10


def _data(seed=0, n=N, d=DIM):
    """Outlier-skewed corpus: ~40% of rows pile into one cluster, the kind
    of cell-size skew the compact scan exists for (the engine only engages
    it when the capped gather is well under the padded width)."""
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    heavy = jax.random.uniform(jax.random.fold_in(key, 3), (n,)) < 0.4
    lab = jnp.where(heavy, 0, lab)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=24, seed=9):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(seed), (nq, DIM))


_ENGINES = {}


def _engine(**kw):
    """One ivfpq build per knob set (k-means train is the slow part)."""
    key = tuple(sorted(kw.items()))
    if key not in _ENGINES:
        cfg = ServeConfig(index="ivfpq", rerank=64, nlist=16, nprobe=8,
                          pq_subspaces=8, pq_centroids=64, **kw)
        _ENGINES[key] = SearchEngine(_data(), cfg)
    return _ENGINES[key]


def _as_int32_state(state):
    """The same built index with the stored codes widened to int32 — the
    pre-PR storage. Both widths must flow through every scan unchanged."""
    ix = state.index.payload
    wide = ix._replace(codes=ix.codes.astype(jnp.int32),
                       codes_cell=ix.codes_cell.astype(jnp.int32))
    return state._replace(index=Index("ivfpq", wide))


def _assert_bit_identical(a, b):
    (da, ia), (db, ib) = a, b
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


def _assert_same_ids(a, b):
    """Ids bit-identical; distances to float ULPs. The pre-filtered
    re-rank gathers a NARROWER candidate tensor, so XLA may vectorize the
    per-row feature reduction differently — same candidates, same math,
    reduction-order ULP wiggle on the returned distance."""
    (da, ia), (db, ib) = a, b
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_allclose(np.asarray(da), np.asarray(db),
                               rtol=1e-6, atol=1e-6)


# --- uint8 end-to-end ---------------------------------------------------------

def test_codes_stored_uint8():
    """K <= 256 builds store byte codes (row-major and cell-major mirrors)
    and the per-row reconstruction-error bound the pre-filter consumes."""
    ix = _engine().state.index.payload
    assert ix.codes.dtype == jnp.uint8
    assert ix.codes_cell.dtype == jnp.uint8
    assert ix.rerr.dtype == jnp.float32
    assert bool(jnp.all(ix.rerr >= 0))


@pytest.mark.kernels
@pytest.mark.parametrize("lut", ("f32", "bf16", "int8"))
@pytest.mark.parametrize("backend", ("jnp", "kernel"))
def test_uint8_vs_int32_parity(backend, lut):
    eng = _engine()
    q = _queries()
    kw = dict(nprobe=8, rerank=64, backend=backend, interpret=True,
              lut_dtype=lut)
    _assert_bit_identical(search_fn(eng.state, q, K, **kw),
                          search_fn(_as_int32_state(eng.state), q, K, **kw))


@pytest.mark.multidevice
@pytest.mark.parametrize("shards", (1, 2, 8))
def test_uint8_vs_int32_sharded_parity(shards):
    if jax.device_count() < shards:
        pytest.skip(f"needs {shards} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={shards})")
    from repro.parallel.engine import shard_engine
    mesh = jax.make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])
    eng = _engine()
    q = _queries()
    kw = dict(nprobe=8, rerank=64, backend="jnp", interpret=True,
              lut_dtype="f32")
    s8 = shard_engine(eng.state, mesh)
    s32 = shard_engine(_as_int32_state(eng.state), mesh)
    _assert_bit_identical(
        sharded_search_fn(s8, q, K, mesh=mesh, axis="data", **kw),
        sharded_search_fn(s32, q, K, mesh=mesh, axis="data", **kw))


@pytest.mark.stream
def test_uint8_vs_int32_streaming_parity():
    """The tombstone-masked base scan consumes stored-width codes too:
    upserts + deletes (a live mask with real holes), then search the same
    store with codes widened to int32."""
    from repro.search.segments import StreamConfig
    from repro.search.stream import stream_search_fn
    cfg = ServeConfig(index="ivfpq", rerank=64, nlist=16, nprobe=8,
                      pq_subspaces=8, pq_centroids=64,
                      stream=StreamConfig(delta_capacity=64))
    eng = SearchEngine(_data(), cfg)
    eng.upsert(np.arange(N, N + 16), _data(seed=3, n=16))
    eng.delete(np.arange(0, 40, 3))
    assert eng.store.codes_cell.dtype == jnp.uint8
    wide = eng.store._replace(
        codes=eng.store.codes.astype(jnp.int32),
        codes_cell=eng.store.codes_cell.astype(jnp.int32))
    q = _queries()
    kw = dict(nprobe=8, rerank=64, backend="jnp", interpret=True,
              lut_dtype="f32")
    _assert_bit_identical(
        stream_search_fn(eng.store, eng.frozen, q, K, **kw),
        stream_search_fn(wide, eng.frozen, q, K, **kw))


# --- nprobe-proportional compact scan ----------------------------------------

@pytest.mark.parametrize("lut", ("f32", "bf16", "int8"))
@pytest.mark.parametrize("backend", ("jnp", "kernel"))
def test_compact_scan_bit_identical(backend, lut):
    """The capped, prefix-sum-indexed gather must reproduce the padded
    scan exactly: same candidates in the same enumeration order, so even
    top-k tie-breaks agree."""
    ix = _engine().state.index.payload
    q = _queries()
    cap = _engine()._scan_cap(8)
    assert cap > 0, "test corpus should have skewed cells"
    d1, i1 = ivfpq_adc_scan(ix.centroids, ix.lists, ix.codes_cell,
                            ix.bias_cell, ix.lut_w, ix.cbnorm, ix.codebooks,
                            q, 64, 8, backend, True, lut)
    d2, i2 = ivfpq_compact_scan(ix.centroids, ix.lists, ix.codes_cell,
                                ix.bias_cell, ix.lut_w, ix.cbnorm,
                                ix.codebooks, q, 64,
                                nprobe=8, scan_cap=cap, backend=backend,
                                interpret=True, lut_dtype=lut)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_engine_compact_path_matches_defaults():
    """End to end: small buckets route through the compact scan
    (``compact_batch``) plus the opt-in pre-filter (``prefilter_batch``)
    and must return exactly what the default wide program returns —
    across the whole small-batch range."""
    eng = _engine()
    assert eng._scan_cap(8) > 0
    for nq in (1, 3, 8, 24, 64):
        q = _queries(nq=nq, seed=100 + nq)
        eng.config = dataclasses.replace(eng.config, compact_batch=64,
                                         prefilter_batch=64)
        fast = eng.search(q, K)
        eng.config = dataclasses.replace(eng.config, compact_batch=0,
                                         prefilter_batch=0)
        slow = eng.search(q, K)
        _assert_same_ids(fast, slow)


def test_scan_cap_covers_worst_case():
    """The cached cap is a certified upper bound on any query's probed
    posting mass (sum of the nprobe largest cells), so the capped gather
    can never truncate."""
    eng = _engine()
    ix = eng.state.index.payload
    lens = np.asarray(jnp.sum(ix.lists >= 0, axis=1))
    for nprobe in (1, 4, 8, 16):
        cap = eng._scan_cap(nprobe)
        if cap:
            assert cap >= np.sort(lens)[-nprobe:].sum()
            assert cap % 128 == 0


# --- re-rank candidate pre-filter --------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 10), st.sampled_from(
    ["f32", "bf16", "int8"]))
def test_prefilter_never_drops_a_true_topk_id(seed, k, lut):
    """Property: for any queries, k, and LUT width, the pre-filtered
    re-rank returns exactly the ids and distances of the full-width
    re-rank — i.e. the certified threshold never discards a true top-k
    member (ties included)."""
    eng = _engine()
    q = _data()[:8] + 0.1 * jax.random.normal(jax.random.key(seed), (8, DIM))
    kw = dict(nprobe=8, rerank=64, backend="jnp", interpret=True,
              lut_dtype=lut)
    r_s = max(2 * k, 32)
    _assert_same_ids(
        search_fn(eng.state, q, k, prefilter=r_s, **kw),
        search_fn(eng.state, q, k, prefilter=0, **kw))


def test_prefilter_requires_scan_space_eq_rerank_space():
    """With a Reduce stage the scan distance bounds live in the reduced
    space and certify nothing about the re-rank space: search_fn must
    refuse, and the engine must not engage the pre-filter."""
    from repro.core import MPADConfig
    eng = _engine(target_dim=8, mpad=MPADConfig(m=8, iters=16),
                  fit_sample=512, prefilter_batch=64)
    with pytest.raises(ValueError, match="prefilter"):
        search_fn(eng.state, _queries(), K, nprobe=8, rerank=64,
                  prefilter=32)
    # prefilter_batch is set but target_dim forces it off: compact only
    d, ids = eng.search(_queries(), K)
    eng.config = dataclasses.replace(eng.config, compact_batch=0)
    _assert_same_ids((d, ids), eng.search(_queries(), K))


def test_stream_and_sharded_reject_fast_paths():
    """The fast paths are single-device read-only by contract."""
    from repro.search.segments import StreamConfig
    from repro.search.stream import stream_search_fn
    cfg = ServeConfig(index="ivfpq", rerank=64, nlist=16, nprobe=8,
                      pq_subspaces=8, pq_centroids=64,
                      stream=StreamConfig(delta_capacity=64))
    eng = SearchEngine(_data(), cfg)
    with pytest.raises(ValueError, match="scan_cap/prefilter"):
        stream_search_fn(eng.store, eng.frozen, _queries(), K, scan_cap=128)
    with pytest.raises(ValueError, match="scan_cap/prefilter"):
        stream_search_fn(eng.store, eng.frozen, _queries(), K, prefilter=32)
