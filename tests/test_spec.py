"""Index-spec API: grammar round-trip, stage validation, config adapters,
and spec-built engines matching config-built engines."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.search import (Coarse, Code, IndexSpec, Reduce, Rerank,
                          SearchEngine, ServeConfig, build_engine,
                          config_from_spec, format_spec, parse_spec,
                          spec_from_config)


# --- grammar: every production round-trips -----------------------------------

@pytest.mark.parametrize("s,kind", [
    ("flat", "flat"),
    ("qpad32", "flat"),
    ("rr128", "flat"),
    ("ivf64x8", "ivf"),
    ("qpad32>ivf64x8", "ivf"),
    ("pq8x256", "pq"),
    ("pq8x256:f32", "pq"),
    ("pq8x256:bf16", "pq"),
    ("pq8x256:i8", "pq"),
    ("pq8x256:int8", "pq"),
    ("pq8x256@kernel", "pq"),
    ("pq8x256:i8@kernel", "pq"),
    ("qpad16>pq4x64:bf16@jnp", "pq"),
    ("ivf64x8>pq8x256", "ivfpq"),
    ("qpad32>ivf64x8>pq8x256:i8", "ivfpq"),
    ("qpad32>ivf64x8>pq8x256:i8>rr96", "ivfpq"),
    ("pca32>ivf64x8>pq8x256:i8", "ivfpq"),
    ("mlp16>flat", "flat"),
    ("flat>rr64", "flat"),
    ("opq8x256", "opq"),
    ("qpad32>opq8x256:i8", "opq"),
])
def test_parse_print_round_trip(s, kind):
    spec = parse_spec(s)
    assert spec.kind == kind
    # value round-trip: parse(print(spec)) == spec
    assert parse_spec(format_spec(spec)) == spec
    # canonical form is a fixed point
    canon = format_spec(spec)
    assert format_spec(parse_spec(canon)) == canon


def test_printer_canonicalizes():
    assert format_spec(parse_spec("pq8x256:int8")) == "pq8x256:i8"
    assert format_spec(parse_spec("pq8x256:f32@jnp")) == "pq8x256"
    assert format_spec(parse_spec("qpad32>rr64")) == "qpad32"  # default rr
    assert format_spec(IndexSpec()) == "flat"
    assert str(parse_spec("QPAD32 > IVF64x8 ")) == "qpad32>ivf64x8"


@pytest.mark.parametrize("bad,match", [
    ("", "empty"),
    ("hnsw32", "unknown reducer kind"),
    ("qpad", "unknown stage token"),
    ("ivf64", "malformed ivf stage"),          # missing xNPROBE
    ("pq8x256:fp8", "unknown stage token"),
    ("pq8x256@triton", "unknown stage token"),
    ("qpad32>qpad16", "duplicate"),
    ("ivf64x8>qpad32", "out of pipeline order"),
    ("rr64>pq8x256", "out of pipeline order"),
    ("flat>flat", "duplicate 'flat'"),
    ("rr64>flat", "out of pipeline order"),
    ("ivf64x8>flat", "mixes 'flat'"),
    ("flat>pq8x256", "mixes 'flat'"),
    ("ivf8x16", "nprobe exceeds nlist"),
    ("qpad0", "m must be >= 1"),
    ("rr0", "n must be >= 1"),
    ("pq8x1", "codewords"),
])
def test_bad_spec_strings_raise(bad, match):
    with pytest.raises(ValueError, match=match):
        parse_spec(bad)


def test_stage_validation():
    with pytest.raises(ValueError, match="nprobe exceeds nlist"):
        Coarse(nlist=4, nprobe=5)
    with pytest.raises(ValueError, match="lut_dtype"):
        Code(lut_dtype="fp8")
    with pytest.raises(ValueError, match="backend"):
        Code(backend="triton")
    with pytest.raises(TypeError, match="Coarse"):
        IndexSpec(coarse=Code())
    with pytest.raises(TypeError, match="Rerank"):
        IndexSpec(rerank=64)


def test_kind_and_approximate():
    assert IndexSpec().kind == "flat"
    assert not IndexSpec().approximate
    assert IndexSpec(reduce=Reduce(8)).approximate
    assert IndexSpec(coarse=Coarse(16)).kind == "ivf"
    assert not IndexSpec(coarse=Coarse(16)).approximate
    assert IndexSpec(code=Code()).kind == "pq"
    assert IndexSpec(code=Code()).approximate
    assert IndexSpec(coarse=Coarse(16), code=Code()).kind == "ivfpq"
    assert IndexSpec(reduce=Reduce(8), rerank=Rerank(32)).stages() == (
        Reduce(8), Rerank(32))


# --- adapters: ServeConfig <-> IndexSpec -------------------------------------

def test_config_spec_round_trip():
    for s in ("flat", "qpad16", "ivf32x4", "pq8x64:i8@kernel",
              "qpad16>ivf32x4>pq8x64:bf16>rr96"):
        spec = parse_spec(s)
        cfg = config_from_spec(spec, query_bucket=32, seed=3)
        assert cfg.query_bucket == 32 and cfg.seed == 3
        assert cfg.to_spec() == spec
        assert spec_from_config(cfg) == spec


def test_config_from_spec_accepts_strings_and_rejects_junk():
    assert config_from_spec("ivf32x4").index == "ivf"
    with pytest.raises(TypeError, match="IndexSpec or spec string"):
        config_from_spec(42)


def test_serveconfig_validates_through_spec():
    # composition rules surface at config construction, not inside a scan
    with pytest.raises(ValueError, match="nprobe exceeds nlist"):
        ServeConfig(index="ivfpq", nlist=4, nprobe=8)
    with pytest.raises(ValueError, match="n must be >= 1"):
        ServeConfig(rerank=0, target_dim=8)


# --- acceptance: spec-built engine == config-built engine --------------------

def _data(seed=0, n=900, d=64):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def test_build_engine_spec_matches_serveconfig_engine():
    """The acceptance pin: build_engine(corpus,
    parse_spec("qpad32>ivf64x8>pq8x256:i8")) returns ids identical to the
    equivalent ServeConfig engine (same seeds, same build path)."""
    x = _data()
    q = _data(seed=9, n=32)
    mpad = MPADConfig(m=32, iters=8)           # tiny fit: parity, not recall
    eng_spec = build_engine(x, parse_spec("qpad32>ivf64x8>pq8x256:i8"),
                            mpad=mpad, fit_sample=512)
    eng_cfg = SearchEngine(x, ServeConfig(
        target_dim=32, index="ivfpq", nlist=64, nprobe=8,
        pq_subspaces=8, pq_centroids=256, lut_dtype="int8", rerank=64,
        mpad=mpad, fit_sample=512))
    d1, i1 = eng_spec.search(q, 10)
    d2, i2 = eng_cfg.search(q, 10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)


def test_search_engine_accepts_spec_everywhere():
    """SearchEngine takes a spec string / IndexSpec directly, and the
    engine exposes the lowered spec (reflecting knob mutations)."""
    x = _data(n=400, d=32)
    eng = SearchEngine(x, "ivf16x4>rr32")
    assert eng.spec == parse_spec("ivf16x4>rr32")
    d, ids = eng.search(x[:8], 5)
    assert ids.shape == (8, 5)
    eng.config = dataclasses.replace(eng.config, nprobe=8)
    assert eng.spec.coarse.nprobe == 8         # spec tracks the live config
    with pytest.raises(TypeError, match="spec string"):
        SearchEngine(x, config=42)


def test_rerank_budget_validated_at_search_time():
    """k > rerank on an approximate pipeline raises an actionable error
    host-side instead of silently truncating inside the jitted scan."""
    x = _data(n=400, d=32)
    eng = SearchEngine(x, "pq4x16>rr8")
    with pytest.raises(ValueError, match="re-rank budget"):
        eng.search(x[:4], 16)
    # exact pipelines have no re-rank budget to exceed
    flat = SearchEngine(x, "flat")
    d, ids = flat.search(x[:4], 16)
    assert ids.shape == (4, 16)
