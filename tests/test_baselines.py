"""Baseline DR methods: correctness properties + OOS transforms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (fit_isomap, fit_kpca_rbf, fit_mds, fit_pca,
                        fit_random_projection, fit_umap_lite)


@pytest.fixture(scope="module")
def data():
    key = jax.random.key(0)
    centers = jax.random.normal(key, (6, 32)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (200,), 0, 6)
    x = centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (200, 32))
    return x[:160], x[160:]


def test_pca(data):
    xtr, xte = data
    red = fit_pca(xtr, 5)
    y = red.transform(xte)
    assert y.shape == (40, 5)
    # projecting train data reproduces the top singular subspace: variance
    ytr = red.transform(xtr)
    v_kept = float(jnp.var(ytr, axis=0).sum())
    v_tot = float(jnp.var(xtr - xtr.mean(0), axis=0).sum())
    assert v_kept / v_tot > 0.5


def test_random_projection_jl(data):
    xtr, _ = data
    red = fit_random_projection(jax.random.key(1), 32, 24)
    y = red.transform(xtr)
    d_orig = jnp.linalg.norm(xtr[:20, None] - xtr[None, :20], axis=-1)
    d_proj = jnp.linalg.norm(y[:20, None] - y[None, :20], axis=-1)
    iu = jnp.triu_indices(20, 1)
    ratio = d_proj[iu] / jnp.maximum(d_orig[iu], 1e-6)
    assert 0.5 < float(jnp.median(ratio)) < 1.5       # JL distortion sanity


def test_rp_achlioptas_sparsity():
    red = fit_random_projection(jax.random.key(2), 100, 10,
                                kind="achlioptas")
    x = jnp.eye(100)
    m = red.transform(x)                               # the matrix itself
    frac_zero = float(jnp.mean(m == 0.0))
    assert 0.5 < frac_zero < 0.8                       # 2/3 expected


def test_mds_oos(data):
    xtr, xte = data
    red = fit_mds(xtr, 4)
    assert red.transform(xte).shape == (40, 4)
    assert bool(jnp.all(jnp.isfinite(red.transform(xte))))


def test_kpca_and_nystrom(data):
    xtr, xte = data
    full = fit_kpca_rbf(xtr, 4)
    nys = fit_kpca_rbf(xtr, 4, landmarks=80, key=jax.random.key(3))
    for red in (full, nys):
        y = red.transform(xte)
        assert y.shape == (40, 4) and bool(jnp.all(jnp.isfinite(y)))


def test_isomap(data):
    xtr, xte = data
    red = fit_isomap(xtr, 3, k=8)
    y = red.transform(xte)
    assert y.shape == (40, 3) and bool(jnp.all(jnp.isfinite(y)))


def test_umap_lite(data):
    xtr, xte = data
    red = fit_umap_lite(xtr, 3, epochs=50, key=jax.random.key(4))
    y = red.transform(xte)
    assert y.shape == (40, 3) and bool(jnp.all(jnp.isfinite(y)))
