"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see 1 device; only launch/dryrun.py forces 512 (and the
distributed tests spawn subprocesses with their own flags).

Also installs a fallback ``hypothesis`` stub when the real package is not
available, so the property-test modules still collect and run: ``@given``
degrades to a seeded deterministic sweep over a handful of examples drawn
from the declared strategies (no shrinking, no database — just coverage).
"""
import random
import sys
import types

import jax
import pytest


def _install_hypothesis_stub():
    try:
        import hypothesis  # noqa: F401  (the real thing wins if present)
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd):
            return self._draw(rnd)

    def integers(lo, hi):
        return _Strategy(lambda r: r.randint(lo, hi))

    def floats(lo, hi, **_kw):
        return _Strategy(lambda r: r.uniform(lo, hi))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda r: r.choice(items))

    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    _DEFAULT_EXAMPLES = 5

    def given(*strats):
        def deco(fn):
            def wrapper():
                rnd = random.Random(0)
                for _ in range(min(wrapper._max_examples, 10)):
                    fn(*(s.example(rnd) for s in strats))
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = _DEFAULT_EXAMPLES
            wrapper.pytestmark = list(getattr(fn, "pytestmark", []))
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.sampled_from = sampled_from
    strategies.booleans = booleans
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
