"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see 1 device; only launch/dryrun.py forces 512 (and the
distributed tests spawn subprocesses with their own flags)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
