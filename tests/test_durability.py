"""Durability subsystem: WAL, crash recovery, background compaction, policy.

The contracts pinned here:

* **WAL framing** — records round-trip byte-exact across segment
  rotation; a torn tail (the crash artifact) is skipped by readers and
  truncated by a resuming writer; damage anywhere *else* raises
  ``WalError``; truncation after a durable snapshot unlinks only fully
  covered segments.
* **crash recovery** — killing the engine at EVERY WAL record boundary
  (and mid-compaction-swap, via ``runtime.fault.FailureInjector`` on the
  named ``crash_hook`` points) then ``load_engine`` lands on search ids
  identical to an uncrashed oracle that ran the same op prefix — for
  flat / ivf / pq / ivfpq — and the fully recovered store matches the
  from-scratch ``rebuild_state`` oracle.
* **non-blocking compaction** — searches concurrent with a background
  fold return ids identical to the pre- OR post-compaction store, never
  a mix, on 1/2/8 (simulated) devices; writes during the fold survive
  the swap.
* **maintenance policy** — tombstone density triggers vacuum from
  ``delete``, headroom pressure triggers proactive grow, encode-error
  drift above the LUT noise floor advises (or runs) a quantizer rebuild;
  decisions are WAL records and replay deterministically.
"""
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.runtime.fault import FailureInjector
from repro.search import (DurabilityConfig, PolicyConfig, SearchEngine,
                          ServeConfig, StreamConfig, Wal, WalError,
                          load_engine, rebuild_state, search_fn)
from repro.search.durability.wal import (RT_COMPACT, RT_DELETE, RT_POLICY,
                                         RT_UPSERT, decode_delete,
                                         decode_upsert, encode_delete,
                                         encode_policy, encode_upsert,
                                         decode_policy, iter_records,
                                         wal_tail_seq)

pytestmark = pytest.mark.durability

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=16):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, DIM))


def _cfg(index, target_dim=None, **stream_kw):
    stream_kw.setdefault("delta_capacity", 64)
    kw = dict(target_dim=target_dim, rerank=128, index=index,
              mpad=MPADConfig(m=8, iters=16) if target_dim else None,
              fit_sample=512, stream=StreamConfig(**stream_kw))
    if index in ("ivf", "ivfpq"):
        kw.update(nlist=12, nprobe=12)
    if index in ("pq", "ivfpq"):
        kw.update(pq_subspaces=8, pq_centroids=64)
    return ServeConfig(**kw)


def _rows(seed, n):
    return np.asarray(_data(seed=seed, n=n), np.float32)


# --- WAL unit layer ----------------------------------------------------------

def test_wal_roundtrip_and_rotation(tmp_path):
    """Records come back in order, byte-exact, across forced segment
    rotation; truncation after a snapshot unlinks only covered segments."""
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="never", segment_bytes=256))
    payloads = []
    for i in range(30):
        p = encode_upsert(np.arange(i + 1, dtype=np.int32),
                          np.full((i + 1, 4), float(i), np.float32))
        payloads.append((RT_UPSERT, p))
        wal.append(RT_UPSERT, p)
    wal.append(RT_COMPACT, b"")
    payloads.append((RT_COMPACT, b""))
    wal.close()
    got = list(iter_records(d))
    assert [seq for seq, _, _ in got] == list(range(31))
    assert [(rt, pl) for _, rt, pl in got] == payloads
    segs = [f for f in os.listdir(d) if f.endswith(".log")]
    assert len(segs) > 1, "256-byte segments must have rotated"
    assert wal_tail_seq(d) == 30
    # truncate: re-open resuming, drop everything before seq 20
    wal = Wal(d, DurabilityConfig(fsync="never", segment_bytes=256),
              resume=True)
    wal.truncate(20)
    remaining = list(iter_records(d))
    assert remaining[-1][0] == 30
    assert remaining[0][0] <= 21          # nothing past the snapshot lost
    assert len(os.listdir(d)) < len(segs) + 1
    wal.close()


def test_wal_truncate_respects_pinned_floor(tmp_path):
    """A pinned floor (the newest base snapshot's position) clamps
    truncation: records past it survive even when the caller asks for
    more — they are what re-seeds a base-seeded follower."""
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="never", segment_bytes=128))
    for i in range(20):
        wal.append(RT_DELETE, encode_delete(np.arange(8)))
    assert wal.stats()["floor_seq"] == -1            # unpinned
    wal.pin_floor(5)
    wal.truncate(15)                                 # clamped to 5
    assert wal.stats()["floor_seq"] == 5
    wal.close()                                      # flush buffered tail
    remaining = [seq for seq, _, _ in iter_records(d)]
    assert set(range(6, 20)).issubset(remaining)     # floor tail intact


def test_wal_torn_tail_skipped_and_truncated_on_resume(tmp_path):
    """A half-written final frame (the crash artifact) is invisible to
    readers and removed by a resuming writer, which then continues the
    sequence."""
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="never"))
    for i in range(5):
        wal.append(RT_DELETE, encode_delete(np.arange(i + 1)))
    wal.close()
    seg = sorted(os.listdir(d))[-1]
    path = os.path.join(d, seg)
    with open(path, "ab") as f:
        f.write(b"\x07\x07\x07")                     # torn tail
    assert wal_tail_seq(d) == 4                      # reader stops clean
    size_torn = os.path.getsize(path)
    wal = Wal(d, DurabilityConfig(fsync="never"), resume=True)
    assert os.path.getsize(path) == size_torn - 3    # tail truncated
    assert wal.append(RT_COMPACT) == 5               # sequence continues
    wal.close()
    assert wal_tail_seq(d) == 5


def test_wal_midlog_corruption_raises(tmp_path):
    """The same damage before the tail of the last segment is real
    corruption, not a torn tail: reading raises ``WalError``."""
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="never", segment_bytes=128))
    for i in range(20):
        wal.append(RT_DELETE, encode_delete(np.arange(8)))
    wal.close()
    first = sorted(os.listdir(d))[0]                 # NOT the last segment
    path = os.path.join(d, first)
    data = bytearray(open(path, "rb").read())
    data[-1] ^= 0xFF                                 # flip a payload byte
    open(path, "wb").write(bytes(data))
    with pytest.raises(WalError):
        list(iter_records(d))


def test_wal_refuses_existing_history_without_resume(tmp_path):
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="never"))
    wal.append(RT_COMPACT)
    wal.close()
    with pytest.raises(RuntimeError, match="load_engine"):
        Wal(d, DurabilityConfig(fsync="never"))


def test_payload_codecs_roundtrip():
    ids = np.asarray([3, -1, 7, 2**31 - 1], np.int32)
    vecs = np.arange(16, dtype=np.float32).reshape(4, 4)
    rid, rvec = decode_upsert(encode_upsert(ids, vecs))
    np.testing.assert_array_equal(rid, ids)
    np.testing.assert_array_equal(rvec, vecs)
    np.testing.assert_array_equal(decode_delete(encode_delete(ids)), ids)
    dec = {"decision": "grow", "row_extra": 256, "cell_extra": 64}
    assert decode_policy(encode_policy(dec)) == dec


# --- crash recovery at every record boundary ---------------------------------

# each op is sized under the delta compact point (48 of 64), so ops map
# 1:1 onto WAL records and an op prefix IS a record prefix
_OPS = [
    ("upsert", np.arange(600, 630, dtype=np.int32), 1),
    ("delete", np.asarray([3, 5, 600, 604], np.int32), None),
    ("upsert", np.arange(625, 640, dtype=np.int32), 2),
    ("compact", None, None),
    ("upsert", np.arange(640, 670, dtype=np.int32), 3),
    ("delete", np.asarray([10, 11, 650], np.int32), None),
    ("upsert", np.arange(7, 12, dtype=np.int32), 4),   # overwrite base rows
]


def _apply_ops(eng, ops):
    for op, ids, seed in ops:
        if op == "upsert":
            eng.upsert(ids, _rows(seed, len(ids)))
        elif op == "delete":
            eng.delete(ids)
        else:
            eng.compact()


def _tail_records(live):
    """The WAL records past the newest durable snapshot's mark — the
    replay script a recovery of ``live`` would run."""
    import json
    meta = json.load(open(os.path.join(live, "engine.json")))
    return (meta["wal_seq"],
            list(iter_records(os.path.join(live, "wal"),
                              after=meta["wal_seq"])))


def _prefix_dir(src, dst, records, p, mark_payload=b"-1"):
    """A copy of the durable directory as a crash at the boundary after
    tail record ``p`` would leave it: snapshot intact, WAL holding the
    snapshot mark (seq 0) + the first ``p`` tail records."""
    os.makedirs(dst)
    for f in os.listdir(src):
        if f != "wal":
            shutil.copy2(os.path.join(src, f), os.path.join(dst, f))
    wal = Wal(os.path.join(dst, "wal"), DurabilityConfig(fsync="never"))
    wal.append(4, mark_payload)                  # RT_SNAPSHOT mark, seq 0
    for _, rtype, payload in records[:p]:
        wal.append(rtype, payload)
    wal.close()


@pytest.mark.parametrize("index", ("flat", "ivf", "pq", "ivfpq"))
def test_recovery_at_every_record_boundary(index, tmp_path):
    """The acceptance property: a crash after any WAL record recovers to
    search ids identical to an uncrashed engine that ran exactly that
    prefix of operations."""
    q = _queries()
    cfg = _cfg(index)
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), cfg).durable(
        live, DurabilityConfig(fsync="batch"))
    _apply_ops(eng, _OPS)
    eng._wal.sync()
    _, records = _tail_records(live)
    assert len(records) == len(_OPS)         # 1:1 op <-> record mapping
    # the uncrashed oracle: same deterministic build, ops applied one at
    # a time, ids captured at every boundary
    oracle = SearchEngine(_data(), cfg)
    want = [np.asarray(oracle.search(q, K)[1])]
    for op in _OPS:
        _apply_ops(oracle, [op])
        want.append(np.asarray(oracle.search(q, K)[1]))
    for p in range(len(records) + 1):
        crash = str(tmp_path / f"crash{p}")
        _prefix_dir(live, crash, records, p)
        rec = load_engine(crash)
        assert rec._replayed == p
        got = np.asarray(rec.search(q, K)[1])
        np.testing.assert_array_equal(got, want[p], err_msg=f"prefix {p}")


def test_recovered_store_matches_rebuild_oracle(tmp_path):
    """After recovery + compact, the store serves exactly what a
    from-scratch rebuild over the surviving rows (same frozen
    quantizers) serves — recovery does not fork the streaming
    equivalence contract."""
    index = "ivfpq"
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _cfg(index)).durable(
        live, DurabilityConfig(fsync="batch"))
    _apply_ops(eng, _OPS)
    rec = load_engine(live)
    rec.compact()
    alive = {}
    for i, v in enumerate(np.asarray(_data(), np.float32)):
        alive[i] = v
    for op, ids, seed in _OPS:
        if op == "upsert":
            for j, rid in enumerate(ids):
                alive[int(rid)] = _rows(seed, len(ids))[j]
        elif op == "delete":
            for rid in ids:
                alive.pop(int(rid), None)
    surv_ids = np.array(sorted(alive))
    surv = jnp.asarray(np.stack([alive[i] for i in surv_ids]))
    oracle = rebuild_state(rec.frozen, surv, index=index)
    q = _queries()
    d_r, i_r = search_fn(oracle, q, K, nprobe=12, rerank=128,
                         backend="jnp", interpret=True, lut_dtype="f32")
    d_s, i_s = rec.search(q, K)
    np.testing.assert_array_equal(np.sort(np.asarray(i_s), axis=1),
                                  np.sort(surv_ids[np.asarray(i_r)], axis=1))


def test_torn_tail_after_workload_recovers_to_last_record(tmp_path):
    """Garbage appended by a crash mid-append is dropped; recovery lands
    on the last intact record's state."""
    live = str(tmp_path / "live")
    cfg = _cfg("ivf")
    eng = SearchEngine(_data(), cfg).durable(
        live, DurabilityConfig(fsync="batch"))
    _apply_ops(eng, _OPS)
    q = _queries()
    want = np.asarray(eng.search(q, K)[1])
    wal_dir = os.path.join(live, "wal")
    seg = sorted(f for f in os.listdir(wal_dir) if f.endswith(".log"))[-1]
    with open(os.path.join(wal_dir, seg), "ab") as f:
        f.write(b"\x13\x37" * 9)                     # torn half-frame
    rec = load_engine(live)
    np.testing.assert_array_equal(np.asarray(rec.search(q, K)[1]), want)


@pytest.mark.parametrize("point,upto", [
    ("wal_appended", 1),     # crashed right after the first durable record
    ("compact_swap", 4),     # crashed mid-swap: barrier at op 4 replays
    ("vacuum", None),        # crashed entering vacuum (record is durable)
])
def test_injected_crash_at_lifecycle_points(point, upto, tmp_path):
    """``FailureInjector`` killing the engine at a named lifecycle point
    leaves a directory that recovers to the oracle state: everything
    WAL-logged before the kill replays (the log is ahead of the store,
    never behind)."""
    q = _queries()
    cfg = _cfg("ivf", policy=PolicyConfig(tombstone_density=0.2,
                                          tombstone_min_dead=32))
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), cfg).durable(
        live, DurabilityConfig(fsync="batch"))
    injector = FailureInjector(fail_at={point})
    eng.crash_hook = injector.maybe_fail
    ops = _OPS if point != "vacuum" else (
        _OPS + [("delete", np.arange(100, 300, dtype=np.int32), None)])
    with pytest.raises(RuntimeError, match="injected failure"):
        _apply_ops(eng, ops)
    # oracle: uncrashed engine running every op whose record is durable;
    # a compaction barrier / policy record replays to COMPLETION even
    # though the crash interrupted the action itself
    oracle = SearchEngine(_data(), cfg)
    n_durable = len(_tail_records(live)[1])
    applied = 0
    for op in ops:
        if applied >= n_durable:
            break
        _apply_ops(oracle, [op])
        applied += 1
    if upto is not None:
        assert n_durable == upto
    rec = load_engine(live)
    np.testing.assert_array_equal(np.asarray(rec.search(q, K)[1]),
                                  np.asarray(oracle.search(q, K)[1]))


def test_recovered_engine_resumes_the_log(tmp_path):
    """Recovery is not read-only: the recovered engine appends to the
    same WAL, and a second crash + recovery sees both histories."""
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _cfg("flat")).durable(
        live, DurabilityConfig(fsync="batch"))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    rec = load_engine(live)
    rec.upsert(np.arange(620, 640, dtype=np.int32), _rows(2, 20))
    rec.delete(np.asarray([600, 625], np.int32))
    q = _queries()
    want = np.asarray(rec.search(q, K)[1])
    rec2 = load_engine(live)
    # the tail now holds the pre-crash record plus the two the recovered
    # engine appended to the SAME log
    assert rec2._replayed == rec._replayed + 2
    np.testing.assert_array_equal(np.asarray(rec2.search(q, K)[1]), want)


def test_save_marks_and_truncates_the_wal(tmp_path):
    """A durable snapshot obsoletes the log prefix: save() records the
    covered seq, truncates covered segments, and the next recovery
    replays only the tail."""
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _cfg("flat")).durable(
        live, DurabilityConfig(fsync="batch", segment_bytes=4096))
    for s in range(4):
        eng.upsert(np.arange(600 + 20 * s, 620 + 20 * s, dtype=np.int32),
                   _rows(s, 20))
    eng.save(live)                       # durable snapshot: log is prefix
    eng.upsert(np.arange(700, 710, dtype=np.int32), _rows(9, 10))
    q = _queries()
    want = np.asarray(eng.search(q, K)[1])
    rec = load_engine(live)
    # only the post-snapshot tail: the auto-compact barrier the last
    # upsert tripped (delta was 40/48 at the save) plus the upsert itself
    assert rec._replayed == 2
    np.testing.assert_array_equal(np.asarray(rec.search(q, K)[1]), want)


def test_snapshot_steps_increment_and_meta_names_checkpoint(tmp_path):
    """Each save lands under a fresh checkpoint step and the metadata
    names its checkpoint — a stray newer array file without a committed
    metadata (crash mid-save) is ignored at load."""
    import json
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _cfg("flat")).durable(
        live, DurabilityConfig(fsync="batch"))
    eng.upsert(np.arange(600, 610, dtype=np.int32), _rows(1, 10))
    eng.save(live)
    meta = json.load(open(os.path.join(live, "engine.json")))
    named = meta["ckpt"]
    assert named in os.listdir(live)
    q = _queries()
    want = np.asarray(eng.search(q, K)[1])
    # simulate a crash between the array write and the metadata commit:
    # a newer checkpoint file exists but engine.json still names `named`
    stray = os.path.join(live, "ckpt_0000009999.npz")
    shutil.copy2(os.path.join(live, named), stray)
    with open(stray, "ab") as f:
        f.write(b"\x00")                 # would fail to parse if read
    rec = load_engine(live)
    np.testing.assert_array_equal(np.asarray(rec.search(q, K)[1]), want)


def test_durable_twice_raises(tmp_path):
    eng = SearchEngine(_data(), _cfg("flat")).durable(str(tmp_path / "d"))
    with pytest.raises(RuntimeError, match="already durable"):
        eng.durable(str(tmp_path / "d2"))


# --- non-blocking compaction -------------------------------------------------

def _bg_engine(index="ivf", **stream_kw):
    stream_kw.setdefault("background_compact", True)
    return SearchEngine(_data(), _cfg(index, **stream_kw))


def test_background_compaction_atomic_swap():
    """While the fold runs on the worker, searches serve the OLD store;
    after the swap they serve the NEW one — never a mix, and writes that
    landed during the fold survive it."""
    eng = _bg_engine()
    gate = threading.Event()
    eng.crash_hook = lambda p: gate.wait(30) if p == "compact_task" else None
    q = _queries()
    eng.upsert(np.arange(600, 640, dtype=np.int32), _rows(1, 40))
    pre = np.asarray(eng.search(q, K)[1])
    eng.upsert(np.arange(640, 660, dtype=np.int32), _rows(2, 20))
    assert eng.metrics().compact.pending
    for _ in range(4):
        mid = np.asarray(eng.search(q, K)[1])    # old store, mid-fold
        np.testing.assert_array_equal(mid, pre)
    # a write during the fold: lands live now, replayed onto the swap
    eng.delete(np.asarray([600], np.int32))
    during = np.asarray(eng.search(q, K)[1])
    assert 600 not in during
    gate.set()
    eng.finish_compact()
    m = eng.metrics()
    assert m.compact.swaps == 1
    assert not m.compact.pending
    post = np.asarray(eng.search(q, K)[1])
    assert 600 not in post
    # post-swap store == blocking-compaction oracle over the same ops
    oracle = _bg_engine(background_compact=False)
    oracle.upsert(np.arange(600, 640, dtype=np.int32), _rows(1, 40))
    oracle.upsert(np.arange(640, 660, dtype=np.int32), _rows(2, 20))
    oracle.delete(np.asarray([600], np.int32))
    oracle.compact()
    np.testing.assert_array_equal(post, np.asarray(oracle.search(q, K)[1]))


def test_background_compaction_poll_swaps_without_explicit_finish():
    """Once the fold completes, the next search entry installs it — no
    explicit finish_compact needed."""
    eng = _bg_engine()
    eng.upsert(np.arange(600, 640, dtype=np.int32), _rows(1, 40))
    eng.upsert(np.arange(640, 660, dtype=np.int32), _rows(2, 20))
    fut = eng._compact_future
    assert fut is not None
    fut.result()                          # wait for the fold (test only)
    eng.search(_queries(), K)             # poll point
    assert eng._compact_future is None
    assert eng.metrics().compact.swaps == 1


def test_background_overflow_falls_back_to_blocking():
    """A chunk that cannot fit the delta alongside the live rows forces
    the blocking path (never silently dropped rows)."""
    eng = _bg_engine()
    eng.upsert(np.arange(600, 640, dtype=np.int32), _rows(1, 40))
    eng.upsert(np.arange(640, 680, dtype=np.int32), _rows(2, 40))
    m = eng.metrics()
    assert not m.compact.pending
    assert m.compact.compactions >= 1
    ids = np.asarray(eng.search(_queries(), 5)[1])
    assert ids.shape == (16, 5)


@pytest.mark.multidevice
@pytest.mark.parametrize("shards", (1, 2, 8))
def test_background_compaction_atomic_on_shards(shards):
    """The acceptance property on a mesh: searches concurrent with the
    background fold return pre- OR post-compaction ids on every shard
    count — the re-shard happens inside the swap."""
    if jax.device_count() < shards:
        pytest.skip(f"needs {shards} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={shards})")
    mesh = jax.make_mesh((shards,), ("data",))
    eng = _bg_engine()
    eng.shard(mesh)
    gate = threading.Event()
    eng.crash_hook = lambda p: gate.wait(30) if p == "compact_task" else None
    q = _queries()
    eng.upsert(np.arange(600, 640, dtype=np.int32), _rows(1, 40))
    pre = np.asarray(eng.search(q, K)[1])
    eng.upsert(np.arange(640, 660, dtype=np.int32), _rows(2, 20))
    assert eng.metrics().compact.pending
    mid = np.asarray(eng.search(q, K)[1])
    np.testing.assert_array_equal(mid, pre)       # old store, whole fleet
    gate.set()
    eng.finish_compact()
    post = np.asarray(eng.search(q, K)[1])
    # single-device blocking oracle: the sharded swap must be invisible
    oracle = _bg_engine(background_compact=False)
    oracle.upsert(np.arange(600, 640, dtype=np.int32), _rows(1, 40))
    oracle.upsert(np.arange(640, 660, dtype=np.int32), _rows(2, 20))
    oracle.compact()
    np.testing.assert_array_equal(post, np.asarray(oracle.search(q, K)[1]))


# --- maintenance policy ------------------------------------------------------

def test_delete_triggers_vacuum_through_policy():
    """The delete-path fix: enough tombstones now routes into vacuum —
    dead rows are reclaimed, live ids survive, searches never return the
    deleted."""
    eng = SearchEngine(_data(), _cfg(
        "ivf", policy=PolicyConfig(tombstone_density=0.2,
                                   tombstone_min_dead=32)))
    q = _queries()
    keep = np.asarray(eng.search(q, K)[1])
    eng.delete(np.arange(200, 500, dtype=np.int32))
    m = eng.metrics()
    assert m.compact.vacuums == 1
    assert m.stream.tombstones == 0               # reclaimed, not masked
    assert m.stream.rows == N - 300
    got = np.asarray(eng.search(q, K)[1])
    assert not np.any((got >= 200) & (got < 500))


def test_delete_without_policy_never_vacuums():
    """No configured policy -> deletes only tombstone (the pre-existing
    contract, incl. the pinned no-recompile behavior, is untouched)."""
    eng = SearchEngine(_data(), _cfg("ivf"))
    eng.delete(np.arange(0, 400, dtype=np.int32))
    m = eng.metrics()
    assert m.compact.vacuums == 0
    assert m.stream.tombstones == 400


def test_policy_grow_headroom(tmp_path):
    """Capacity pressure: when post-compaction free rows drop under the
    headroom, the policy grows proactively — and the grow replays from
    the WAL as a policy record, not a re-derivation."""
    cfg = _cfg("flat", policy=PolicyConfig(grow_headroom=2.0))
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), cfg).durable(
        live, DurabilityConfig(fsync="batch"))
    cap0 = eng.metrics().stream.row_capacity
    ids = np.arange(600, 600 + 3 * 48, dtype=np.int32)
    eng.upsert(ids, _rows(5, len(ids)))           # forces compactions
    eng.compact()
    m = eng.metrics()
    assert m.compact.policy_grows >= 1
    assert m.stream.row_capacity > cap0
    wal_types = [rt for _, rt, _ in
                 iter_records(os.path.join(live, "wal"))]
    assert RT_POLICY in wal_types
    q = _queries()
    rec = load_engine(live)
    assert rec.metrics().stream.row_capacity == m.stream.row_capacity
    np.testing.assert_array_equal(np.asarray(rec.search(q, K)[1]),
                                  np.asarray(eng.search(q, K)[1]))


def test_drift_advises_then_auto_rebuilds():
    """Shifted data drives the encode error over the baseline ratio:
    default policy surfaces "advise_rebuild" in stats; auto_rebuild=True
    runs the retrain and re-bases the drift reference."""
    mk = lambda auto: SearchEngine(_data(), _cfg(
        "pq", policy=PolicyConfig(drift_ratio=2.0, drift_min_rows=32,
                                  auto_rebuild=auto)))
    shifted = np.asarray(_data(seed=4), np.float32)[:48] * 6 + 30
    adv = mk(False)
    adv.upsert(np.arange(600, 648, dtype=np.int32), shifted)
    adv.compact()
    m = adv.metrics()
    assert m.policy.decisions.get("advise_rebuild", 0) >= 1
    assert m.compact.rebuilds == 0
    assert m.policy.drift_ratio > 2.0
    auto = mk(True)
    auto.upsert(np.arange(600, 648, dtype=np.int32), shifted)
    auto.compact()
    m = auto.metrics()
    assert m.compact.rebuilds == 1
    assert m.policy.observed_rows == 0            # re-based after retrain
    # the retrained engine still serves every live id
    got = np.asarray(auto.search(_queries(), K)[1])
    assert got.min() >= 0


def test_rebuild_replays_deterministically(tmp_path):
    """A WAL-logged rebuild carries its seed: recovery reruns the exact
    same retrain and lands on identical search ids."""
    cfg = _cfg("pq", policy=PolicyConfig(drift_ratio=2.0, drift_min_rows=32,
                                         auto_rebuild=True))
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), cfg).durable(
        live, DurabilityConfig(fsync="batch"))
    shifted = np.asarray(_data(seed=4), np.float32)[:48] * 6 + 30
    eng.upsert(np.arange(600, 648, dtype=np.int32), shifted)
    eng.compact()                                  # drift -> logged rebuild
    assert eng.metrics().compact.rebuilds == 1
    q = _queries()
    rec = load_engine(live)
    assert rec.metrics().compact.rebuilds == 1
    np.testing.assert_array_equal(np.asarray(rec.search(q, K)[1]),
                                  np.asarray(eng.search(q, K)[1]))


def test_metrics_surface():
    """The public counters window: benches and tests read the typed
    metrics() tree, not private fields (stats() is gone)."""
    eng = SearchEngine(_data(), _cfg("ivfpq"))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    m = eng.metrics()
    assert m.engine.streaming and not m.engine.sharded
    assert m.stream.delta_used == 20
    assert m.stream.rows == N
    for name in ("compactions", "swaps", "vacuums", "rebuilds",
                 "policy_grows"):
        assert getattr(m.compact, name) >= 0
    assert m.wal is None                          # not durable
    assert not hasattr(eng, "stats")              # removed in favor of metrics
    ro = SearchEngine(_data(), ServeConfig(index="flat"))
    assert not ro.metrics().engine.streaming
