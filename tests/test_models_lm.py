"""LM correctness: decode==prefill consistency, chunking invariance, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, init_moe_params, moe_block
from repro.models.transformer import (LMConfig, init_cache, layer_runs,
                                      lm_decode_step, lm_embed,
                                      lm_init_params, lm_loss, lm_prefill)

CFG = LMConfig(name="t", n_layers=3, d_model=48, n_heads=4, n_kv_heads=2,
               d_head=12, d_ff=96, vocab=120, tie_embeddings=False,
               seq_chunk=8, q_chunk=8, kv_chunk=8)
GEMMA = LMConfig(name="g", n_layers=7, d_model=32, n_heads=4, n_kv_heads=2,
                 d_head=8, d_ff=64, vocab=64, sliding_window=6,
                 global_every=3, rope_theta_local=10_000.0,
                 seq_chunk=8, q_chunk=8, kv_chunk=8)


def _toks(cfg, b, s, seed=0):
    return jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab)


@pytest.mark.parametrize("cfg", [CFG, GEMMA], ids=["dense", "local_global"])
def test_decode_matches_prefill(cfg):
    params = lm_init_params(jax.random.key(1), cfg)
    toks = _toks(cfg, 2, 17)
    nxt = _toks(cfg, 2, 1, seed=2)[:, 0]
    cache = init_cache(cfg, 2, 24)
    _, cache = lm_prefill(params, cfg, toks, cache)
    ld, _ = lm_decode_step(params, cfg, nxt, jnp.int32(17), cache)
    full = jnp.concatenate([toks, nxt[:, None]], 1)
    lf, _ = lm_prefill(params, cfg, full, init_cache(cfg, 2, 24))
    np.testing.assert_allclose(ld, lf, atol=2e-4)


def test_multi_step_decode(dense_cfg=CFG):
    """Three sequential decode steps == one prefill of the longer seq."""
    cfg = dense_cfg
    params = lm_init_params(jax.random.key(1), cfg)
    toks = _toks(cfg, 1, 9)
    extra = _toks(cfg, 1, 3, seed=5)[0]
    cache = init_cache(cfg, 1, 16)
    _, cache = lm_prefill(params, cfg, toks, cache)
    for i in range(3):
        logits, cache = lm_decode_step(params, cfg, extra[i:i + 1],
                                       jnp.int32(9 + i), cache)
    full = jnp.concatenate([toks[0], extra])[None, :]
    lf, _ = lm_prefill(params, cfg, full, init_cache(cfg, 1, 16))
    np.testing.assert_allclose(logits, lf, atol=2e-4)


def test_loss_near_log_vocab_at_init():
    params = lm_init_params(jax.random.key(1), CFG)
    toks = _toks(CFG, 4, 32)
    loss = lm_loss(params, CFG, toks, toks)
    assert abs(float(loss) - np.log(CFG.vocab)) < 2.0


def test_chunk_size_invariance():
    """seq_chunk / q_chunk / kv_chunk must not change the loss."""
    params = lm_init_params(jax.random.key(1), CFG)
    toks = _toks(CFG, 2, 24)
    l1 = lm_loss(params, CFG, toks, toks)
    cfg2 = dataclasses.replace(CFG, seq_chunk=24, q_chunk=24, kv_chunk=4)
    l2 = lm_loss(params, cfg2, toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_remat_invariance():
    params = lm_init_params(jax.random.key(1), CFG)
    toks = _toks(CFG, 2, 16)
    l1 = lm_loss(params, CFG, toks, toks)
    l2 = lm_loss(params, dataclasses.replace(CFG, remat=False), CFG and toks,
                 toks) if False else lm_loss(
        params, dataclasses.replace(CFG, remat=False), toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: lm_loss(p, CFG, toks, toks))(params)
    g2 = jax.grad(lambda p: lm_loss(
        p, dataclasses.replace(CFG, remat=False), toks, toks))(params)
    np.testing.assert_allclose(g1["embed"], g2["embed"], atol=1e-5)


def test_sliding_window_masks_far_tokens():
    """Single local layer, window w: logits at the last position must not
    depend on tokens older than w (multi-layer stacks widen the receptive
    field to 1 + L*(w-1), so depth must be 1 for a direct mask test)."""
    cfg = dataclasses.replace(GEMMA, global_every=None, n_layers=1)
    params = lm_init_params(jax.random.key(1), cfg)
    toks = _toks(cfg, 1, 16)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab)  # distant change
    l1, _ = lm_prefill(params, cfg, toks, init_cache(cfg, 1, 16))
    l2, _ = lm_prefill(params, cfg, toks2, init_cache(cfg, 1, 16))
    np.testing.assert_allclose(l1, l2, atol=1e-5)       # pos 15, window 6


def test_vocab_padding_masked():
    cfg = dataclasses.replace(CFG, vocab=100)           # pads to 256
    params = lm_init_params(jax.random.key(1), cfg)
    logits, _ = lm_prefill(params, cfg, _toks(cfg, 1, 8),
                           init_cache(cfg, 1, 8))
    assert cfg.vocab_padded == 256
    assert float(jnp.max(logits[:, cfg.vocab:])) < -1e29


def test_lm_embed():
    params = lm_init_params(jax.random.key(1), CFG)
    emb = lm_embed(params, CFG, _toks(CFG, 3, 16))
    assert emb.shape == (3, CFG.d_model)
    assert bool(jnp.all(jnp.isfinite(emb)))


def test_layer_runs_pattern():
    assert layer_runs(CFG) == [("global", 3)]
    assert layer_runs(GEMMA) == [("local", 2), ("global", 1), ("local", 2),
                                 ("global", 1), ("local", 1)]


def test_moe_dense_equals_dispatch_no_drop():
    mc_dense = MoEConfig(n_experts=4, top_k=2, d_ff=16, impl="dense")
    mc_disp = MoEConfig(n_experts=4, top_k=2, d_ff=16, impl="dispatch",
                        capacity_factor=8.0)
    p = init_moe_params(jax.random.key(0), mc_dense, 24, 1, jnp.float32)
    p1 = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.key(1), (2, 10, 24))
    y1, a1 = moe_block(x, p1, mc_dense)
    y2, a2 = moe_block(x, p1, mc_disp)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, some tokens pass through un-expert-ed (residual
    semantics handled by caller); dispatch must stay finite."""
    mc = MoEConfig(n_experts=2, top_k=2, d_ff=8, impl="dispatch",
                   capacity_factor=0.1)
    p = init_moe_params(jax.random.key(0), mc, 12, 1, jnp.float32)
    p1 = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.key(1), (1, 64, 12))
    y, _ = moe_block(x, p1, mc)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.mean(jnp.abs(y))) < float(jnp.mean(jnp.abs(x)))
