"""Parity tests for the fused ADC-scan Pallas kernels (interpret=True
executes the kernel body on CPU) against the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pq_adc import (dequantize_lut, lut_error_bound,
                                  pq_adc_gather_topk_pallas,
                                  pq_adc_gather_topk_ref, pq_adc_scores_ref,
                                  pq_adc_topk_pallas, pq_adc_topk_ref,
                                  quantize_lut)
from repro.search.pq import build_pq, pq_search

pytestmark = pytest.mark.kernels


def _tables_codes(key, nq, n, m, kc):
    tables = jax.random.uniform(jax.random.fold_in(key, 0), (nq, m, kc))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, m), 0, kc)
    return tables, codes


@pytest.mark.parametrize("nq,n,m,kc,bq,bn", [
    (17, 300, 4, 64, 8, 128),        # ragged Q and N, small codebook
    (64, 1000, 8, 256, 32, 256),     # byte-code shape, ragged N
    (128, 512, 16, 128, 128, 512),   # exact-block shape
])
def test_shared_kernel_matches_ref(nq, n, m, kc, bq, bn):
    tables, codes = _tables_codes(jax.random.key(0), nq, n, m, kc)
    d_ref, i_ref = pq_adc_topk_ref(tables, codes, 10)
    d_k, i_k = pq_adc_topk_pallas(tables, codes, 10, block_q=bq, block_n=bn)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref), atol=1e-4)
    # ids can legitimately differ on near-ties; check each returned id's
    # true score is within tolerance of the oracle's at the same rank
    scores = np.asarray(pq_adc_scores_ref(tables, codes))
    picked = np.take_along_axis(scores, np.asarray(i_k), axis=1)
    np.testing.assert_allclose(picked, np.asarray(d_ref), atol=1e-4)


@pytest.mark.parametrize("nq,c,m,kc,bq,bn", [
    (9, 200, 4, 32, 4, 64),
    (33, 513, 8, 128, 8, 128),
])
def test_gather_kernel_matches_ref(nq, c, m, kc, bq, bn):
    key = jax.random.key(1)
    tables = jax.random.uniform(jax.random.fold_in(key, 0), (nq, m, kc))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (nq, c, m), 0, kc)
    base = jax.random.uniform(jax.random.fold_in(key, 2), (nq, c))
    base = base.at[:, -5:].set(jnp.inf)          # masked posting-list pads
    d_ref, _ = pq_adc_gather_topk_ref(tables, codes, base, 12)
    d_k, _ = pq_adc_gather_topk_pallas(tables, codes, base, 12,
                                       block_q=bq, block_n=bn)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref), atol=1e-4)


def test_masked_pads_never_surface():
    """All-but-k candidates masked: the kernel must return exactly the
    unmasked slots, in distance order."""
    nq, c, m, kc = 4, 96, 4, 16
    key = jax.random.key(2)
    tables = jax.random.uniform(jax.random.fold_in(key, 0), (nq, m, kc))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (nq, c, m), 0, kc)
    base = jnp.full((nq, c), jnp.inf)
    keep = jnp.array([3, 17, 40, 77])
    base = base.at[:, keep].set(0.0)
    d_k, i_k = pq_adc_gather_topk_pallas(tables, codes, base, 4,
                                         block_q=4, block_n=32)
    assert np.isfinite(np.asarray(d_k)).all()
    np.testing.assert_array_equal(np.sort(np.asarray(i_k), axis=1),
                                  np.broadcast_to(np.asarray(keep), (nq, 4)))


# --- quantized LUT path (lut_dtype="bf16" | "int8") -------------------------

@pytest.mark.parametrize("lut_dtype,atol", [("bf16", 1e-2), ("int8", 1e-3)])
def test_shared_kernel_quantized_matches_ref(lut_dtype, atol):
    """Kernel and ref score through the same quantized tables, so they must
    agree up to f32 summation order — the quantization error itself cancels."""
    tables, codes = _tables_codes(jax.random.key(7), 33, 500, 8, 64)
    tables = tables * 5.0
    d_ref, _ = pq_adc_topk_ref(tables, codes, 10, lut_dtype=lut_dtype)
    d_k, i_k = pq_adc_topk_pallas(tables, codes, 10, block_q=8, block_n=128,
                                  lut_dtype=lut_dtype)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref), atol=atol)
    scores = np.asarray(pq_adc_scores_ref(tables, codes, lut_dtype))
    picked = np.take_along_axis(scores, np.asarray(i_k), axis=1)
    np.testing.assert_allclose(picked, np.asarray(d_ref), atol=atol)


@pytest.mark.parametrize("lut_dtype,atol", [("bf16", 1e-2), ("int8", 1e-3)])
def test_gather_kernel_quantized_matches_ref(lut_dtype, atol):
    key = jax.random.key(8)
    tables = jax.random.uniform(jax.random.fold_in(key, 0), (9, 8, 64)) * 5.0
    codes = jax.random.randint(jax.random.fold_in(key, 1), (9, 200, 8), 0, 64)
    base = jax.random.uniform(jax.random.fold_in(key, 2), (9, 200))
    base = base.at[:, -5:].set(jnp.inf)
    d_ref, _ = pq_adc_gather_topk_ref(tables, codes, base, 12,
                                      lut_dtype=lut_dtype)
    d_k, _ = pq_adc_gather_topk_pallas(tables, codes, base, 12, block_q=4,
                                       block_n=64, lut_dtype=lut_dtype)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref), atol=atol)


def test_int8_scale_round_trip():
    """quantize -> dequantize must stay within scale/2 per entry, scales are
    strictly positive, and the int8 grid is fully symmetric (|q| <= 127)."""
    tables = jax.random.normal(jax.random.key(9), (12, 8, 64)) * 7.0
    qt, scale = quantize_lut(tables, "int8")
    assert qt.dtype == jnp.int8
    assert float(jnp.min(scale)) > 0.0
    assert int(jnp.max(jnp.abs(qt.astype(jnp.int32)))) <= 127
    rt = dequantize_lut(qt, scale)
    err = jnp.abs(rt - tables)
    assert float(jnp.max(err - scale[:, None, None] / 2)) <= 1e-6
    # degenerate all-zero table: scale must not collapse to 0/NaN
    qt0, scale0 = quantize_lut(jnp.zeros((2, 4, 8)), "int8")
    assert float(jnp.min(scale0)) > 0.0
    assert not np.isnan(np.asarray(dequantize_lut(qt0, scale0))).any()


@pytest.mark.parametrize("lut_dtype", ["bf16", "int8"])
def test_quantized_scores_within_error_bound(lut_dtype):
    """|quantized ADC score - f32 ADC score| <= lut_error_bound per query."""
    tables, codes = _tables_codes(jax.random.key(10), 16, 300, 8, 32)
    tables = (tables - 0.5) * 9.0
    s_f32 = np.asarray(pq_adc_scores_ref(tables, codes))
    s_q = np.asarray(pq_adc_scores_ref(tables, codes, lut_dtype))
    bound = np.asarray(lut_error_bound(tables, lut_dtype))[:, None]
    assert (np.abs(s_q - s_f32) <= bound + 1e-5).all()


@pytest.mark.parametrize("lut_dtype", ["f32", "bf16", "int8"])
def test_pq_search_backends_agree_per_lut_dtype(lut_dtype):
    """jnp and kernel backends are parity oracles at every LUT precision."""
    key = jax.random.key(11)
    x = jax.random.normal(jax.random.fold_in(key, 0), (600, 32))
    q = jax.random.normal(jax.random.fold_in(key, 1), (40, 32))
    idx = build_pq(jax.random.fold_in(key, 2), x, m_subspaces=4,
                   n_centroids=64)
    d_j, _ = pq_search(idx, q, 10, backend="jnp", lut_dtype=lut_dtype)
    d_k, _ = pq_search(idx, q, 10, backend="kernel", lut_dtype=lut_dtype)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_j), atol=1e-3)


def test_pq_search_rejects_unknown_lut_dtype():
    key = jax.random.key(12)
    x = jax.random.normal(key, (200, 16))
    idx = build_pq(key, x, m_subspaces=4, n_centroids=32)
    with pytest.raises(ValueError, match="lut_dtype"):
        pq_search(idx, x[:4], 5, lut_dtype="fp4")


def test_pq_search_kernel_backend_matches_jnp():
    key = jax.random.key(3)
    x = jax.random.normal(jax.random.fold_in(key, 0), (600, 32))
    q = jax.random.normal(jax.random.fold_in(key, 1), (40, 32))
    idx = build_pq(jax.random.fold_in(key, 2), x, m_subspaces=4,
                   n_centroids=64)
    d_j, _ = pq_search(idx, q, 10, backend="jnp")
    d_k, _ = pq_search(idx, q, 10, backend="kernel")
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_j), atol=1e-4)


def test_pq_search_rejects_unknown_backend():
    key = jax.random.key(4)
    x = jax.random.normal(key, (200, 16))
    idx = build_pq(key, x, m_subspaces=4, n_centroids=32)
    with pytest.raises(ValueError, match="backend"):
        pq_search(idx, x[:4], 5, backend="cuda")
