"""Sharded streaming serving: base sharded, delta + tombstones replicated.

``sharded_stream_search_fn`` over ``shard_stream`` must be invisible:
identical ids to the single-device streaming search, with writes landing
on the replicated leaves only (no re-shard between compactions) and
``compact()`` re-laying the base out transparently.

The >1-shard cases need simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — both the
``tier1-stream`` and ``tier1-multidevice`` CI jobs); single-device
sessions run the 1-shard mesh through the whole shard_map path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.search import SearchEngine, ServeConfig, StreamConfig

pytestmark = [pytest.mark.stream, pytest.mark.multidevice]

N, DIM, K = 601, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=24):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, DIM))


def _engine(index, lut="f32", backend="jnp", target_dim=None):
    kw = dict(target_dim=target_dim, rerank=64, index=index,
              mpad=MPADConfig(m=8, iters=16) if target_dim else None,
              fit_sample=512, stream=StreamConfig(delta_capacity=64))
    if index in ("ivf", "ivfpq"):
        kw.update(nlist=12, nprobe=5)
    if index in ("pq", "ivfpq"):
        kw.update(pq_subspaces=8, pq_centroids=64, lut_dtype=lut,
                  pq_backend=backend)
    return SearchEngine(_data(), ServeConfig(**kw))


def _mesh(shards):
    if jax.device_count() < shards:
        pytest.skip(f"needs {shards} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={shards})")
    return jax.make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])


def _write_some(eng, seed=0):
    rng = np.random.RandomState(seed)
    eng.upsert(np.arange(N, N + 20), rng.randn(20, DIM).astype(np.float32))
    eng.delete(np.arange(0, 30, 3))
    eng.upsert(np.array([5, 8]), rng.randn(2, DIM).astype(np.float32))


@pytest.mark.parametrize("shards", (1, 2, 8))
@pytest.mark.parametrize("index", ("flat", "ivf", "pq", "ivfpq"))
def test_sharded_stream_matches_single_device(index, shards):
    eng = _engine(index)
    _write_some(eng)
    q = _queries()
    d1, i1 = eng.search(q, K)                 # single-device streaming
    eng.shard(_mesh(shards))
    d2, i2 = eng.search(q, K)                 # sharded streaming
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


@pytest.mark.parametrize("lut,backend", [("int8", "jnp"),
                                         ("f32", "kernel"),
                                         ("int8", "kernel")])
def test_sharded_stream_ivfpq_quantized_and_kernel(lut, backend):
    """Quantized LUTs and the fused ADC-gather kernel both serve the
    tombstone-masked sharded scan (mask rides the base term)."""
    shards = min(2, jax.device_count())
    eng = _engine("ivfpq", lut=lut, backend=backend)
    _write_some(eng)
    q = _queries()
    d1, i1 = eng.search(q, K)
    eng.shard(_mesh(shards))
    d2, i2 = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_writes_while_sharded_and_compact_reshards():
    """Upserts/deletes land on the replicated leaves (base untouched);
    compact() folds them in and re-lays the sharded base out — results
    stay identical to the unsharded store throughout."""
    shards = min(2, jax.device_count())
    eng = _engine("ivfpq")
    eng.shard(_mesh(shards))
    rng = np.random.RandomState(1)
    base_before = eng._stream_sharded_base
    eng.upsert(np.arange(N + 100, N + 130),
               rng.randn(30, DIM).astype(np.float32))
    eng.delete(np.arange(10, 20))
    assert eng._stream_sharded_base is base_before   # writes don't re-shard
    q = _queries()
    d1, i1 = eng.search(q, K)
    eng.compact()
    assert eng._stream_sharded_base is not base_before
    assert int(eng.store.delta_count) == 0
    d2, i2 = eng.search(q, K)
    eng._stream_sharded_base = None                  # back to single-device
    d3, i3 = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i3))
    # compaction itself must not change what is served
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_sharded_stream_with_projection():
    shards = min(2, jax.device_count())
    eng = _engine("ivfpq", target_dim=8)
    _write_some(eng)
    q = _queries()
    d1, i1 = eng.search(q, K)
    eng.shard(_mesh(shards))
    d2, i2 = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_streaming_shard_refuses_donation():
    eng = _engine("flat")
    with pytest.raises(ValueError, match="donate"):
        eng.shard(_mesh(1), donate=True)
