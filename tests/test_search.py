"""Vector-search substrate tests: knn, metrics, IVF, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import MPADConfig, Reducer
from repro.search import (IVFIndex, SearchEngine, ServeConfig, amk_accuracy,
                          build_ivf, ivf_search, knn_search,
                          knn_search_blocked)
from repro.search.knn import recall_at_k


def _data(seed=0, n=400, d=24):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (10, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 10)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def test_blocked_equals_full():
    x = _data()
    q = _data(seed=9, n=50)
    d1, i1 = knn_search(q, x, 10)
    d2, i2 = knn_search_blocked(q, x, 10, block=128)
    np.testing.assert_array_equal(np.sort(np.asarray(i1), 1),
                                  np.sort(np.asarray(i2), 1))
    np.testing.assert_allclose(d1, d2, atol=1e-4)


def test_identity_reducer_perfect_recall():
    x = _data()
    y = _data(seed=3, n=60)
    acc = amk_accuracy(Reducer("id", lambda v: v), x, y, 10)
    assert float(acc) == 1.0


def test_recall_metric():
    a = jnp.array([[1, 2, 3], [4, 5, 6]])
    b = jnp.array([[3, 2, 9], [7, 8, 0]])
    np.testing.assert_allclose(float(recall_at_k(a, b)), (2 / 3 + 0) / 2,
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_recall_permutation_invariant(seed):
    k = 8
    found = jax.random.permutation(jax.random.key(seed), 20)[:k][None, :]
    perm = jax.random.permutation(jax.random.key(seed + 1), found[0])[None, :]
    truth = jax.random.permutation(jax.random.key(seed + 2), 20)[:k][None, :]
    assert float(recall_at_k(found, truth)) == float(
        recall_at_k(perm, truth))


def test_ivf_full_probe_exact():
    x = _data(seed=5)
    q = _data(seed=6, n=40)
    idx = build_ivf(jax.random.key(0), x, nlist=8)
    _, truth = knn_search(q, x, 10)
    _, found = ivf_search(idx, q, 10, nprobe=8)
    assert float(recall_at_k(found, truth)) == 1.0


def test_ivf_partial_probe_reasonable():
    x = _data(seed=5)
    q = _data(seed=6, n=40)
    idx = build_ivf(jax.random.key(0), x, nlist=8)
    _, truth = knn_search(q, x, 10)
    _, found = ivf_search(idx, q, 10, nprobe=3)
    assert float(recall_at_k(found, truth)) > 0.6


def test_engine_with_mpad_and_rerank():
    x = _data(seed=7, n=500)
    q = _data(seed=8, n=50)
    _, truth = knn_search(q, x, 10)
    eng = SearchEngine(x, ServeConfig(
        target_dim=8, rerank=64,
        mpad=MPADConfig(m=8, iters=24)))
    _, found = eng.search(q, 10)
    assert float(recall_at_k(found, truth)) > 0.8


def test_engine_ivf_path():
    x = _data(seed=7, n=500)
    q = _data(seed=8, n=50)
    _, truth = knn_search(q, x, 10)
    eng = SearchEngine(x, ServeConfig(
        target_dim=8, rerank=64, index="ivf", nlist=16, nprobe=16,
        mpad=MPADConfig(m=8, iters=24)))
    _, found = eng.search(q, 10)
    assert float(recall_at_k(found, truth)) > 0.7


def test_engine_pq_path_via_spec():
    """MPAD-reduce -> PQ-code -> ADC scan -> exact re-rank, built from the
    pipeline-spec string instead of a flat config."""
    from repro.search import build_engine
    x = _data(seed=7, n=500)
    q = _data(seed=8, n=50)
    _, truth = knn_search(q, x, 10)
    eng = build_engine(x, "qpad8>pq4x64", mpad=MPADConfig(m=8, iters=24))
    _, found = eng.search(q, 10)
    assert float(recall_at_k(found, truth)) > 0.7
