"""Mesh-sharded serving: the distributed top-k merge must be invisible.

``sharded_search_fn`` over ``shard_engine(state, mesh)`` returns exactly
the same neighbor ids (and distances, to fp tolerance) as the
single-device ``search_fn`` — for every index kind, both LUT dtypes, both
scoring backends, and 1 / 2 / 8 shards. The corpus size (601) and cell
count (12) are deliberately not divisible by the shard counts, so the
per-shard-equal padding paths (pad rows, pad cells, kernel over-fetch
slack) are all live.

The full matrix needs 8 simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the
``tier1-multidevice`` CI job); in a single-device session the >1-shard
cases skip and the 1-shard mesh still exercises the whole shard_map path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.parallel.context import mesh_context
from repro.parallel.engine import shard_engine
from repro.search import (SearchEngine, ServeConfig, search_fn,
                          sharded_search_fn)

pytestmark = pytest.mark.multidevice

N, DIM, K = 601, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=24):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, DIM))


_ENGINES = {}


def _config(index, **kw):
    """Per-kind config: stage knobs only where the pipeline has the stage
    (dead knobs raise at config time)."""
    base = dict(target_dim=8, rerank=64, index=index,
                mpad=MPADConfig(m=8, iters=16), fit_sample=512)
    if index in ("ivf", "ivfpq"):
        base.update(nlist=12, nprobe=5)
    if index in ("pq", "ivfpq"):
        base.update(pq_subspaces=8, pq_centroids=64)
    base.update(kw)
    return ServeConfig(**base)


def _engine(index):
    """One build per index kind (MPAD fit + index train are the slow part)."""
    if index not in _ENGINES:
        _ENGINES[index] = SearchEngine(_data(), _config(index))
    return _ENGINES[index]


def _mesh(shards):
    if jax.device_count() < shards:
        pytest.skip(f"needs {shards} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={shards})")
    return jax.make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])


def _assert_parity(eng, kw, shards, q=None, k=K):
    q = _queries() if q is None else q
    mesh = _mesh(shards)
    d1, i1 = search_fn(eng.state, q, k, **kw)
    sstate = shard_engine(eng.state, mesh)
    d2, i2 = sharded_search_fn(sstate, q, k, mesh=mesh, axis="data", **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


# --- the acceptance matrix ---------------------------------------------------

@pytest.mark.parametrize("shards", (1, 2, 8))
@pytest.mark.parametrize("lut", ("f32", "int8"))
@pytest.mark.parametrize("index", ("flat", "ivf", "pq", "ivfpq"))
def test_sharded_matches_single_device(index, lut, shards):
    eng = _engine(index)
    coded = index in ("pq", "ivfpq")
    kw = dict(nprobe=5, rerank=64, backend="jnp",
              interpret=True, lut_dtype=lut if coded else "f32")
    _assert_parity(eng, kw, shards)


@pytest.mark.parametrize("lut", ("f32", "int8"))
@pytest.mark.parametrize("index", ("pq", "ivfpq"))
def test_sharded_kernel_backend_parity(index, lut):
    """The fused Pallas scans run inside shard_map too; the shared-codes
    entry exercises the over-fetch slack that keeps shard-pad rows from
    displacing real candidates."""
    shards = min(2, jax.device_count())
    eng = _engine(index)
    kw = dict(nprobe=5, rerank=64, backend="kernel",
              interpret=True, lut_dtype=lut)
    _assert_parity(eng, kw, shards)


# --- engine-level routing ----------------------------------------------------

def test_engine_shard_roundtrip_and_context_mesh():
    """``SearchEngine.shard()`` (mesh from the context) must not change
    what ``search`` returns, and must key its own compile cache."""
    eng = _engine("ivfpq")
    q = _queries()
    d0, i0 = eng.search(q, K)
    mesh = _mesh(min(2, jax.device_count()))
    with mesh_context(mesh):
        eng.shard()
    try:
        assert eng.sharded_state is not None
        d1, i1 = eng.search(q, K)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-5)
        assert eng.compile_count >= 2     # single-device + sharded programs
    finally:
        eng.sharded_state = None          # _ENGINES is shared across tests


def test_shard_engine_requires_mesh():
    eng = _engine("flat")
    with pytest.raises(RuntimeError, match="mesh"):
        shard_engine(eng.state)


def test_sharded_state_padding_is_per_shard_equal():
    shards = min(8, jax.device_count())
    mesh = _mesh(shards)
    sstate = shard_engine(_engine("ivfpq").state, mesh)
    assert sstate.index.kind == "ivfpq"
    ix = sstate.index.payload                        # ShardedIVFPQ
    assert sstate.corpus.shape[0] % shards == 0
    assert ix.lists.shape[0] % shards == 0
    assert ix.codes_cell.shape[:2] == ix.lists.shape
    assert int(sstate.n_real) == N
    # pad cells are empty posting rows
    nlist_real = ix.centroids.shape[0]
    pads = np.asarray(ix.lists)[nlist_real:]
    assert (pads == -1).all()


def test_shard_aware_builders_prepad_cells():
    """``build_ivf/build_ivfpq(shards=)`` emit per-shard-equal cell layouts
    up front; ``shard_engine``'s padding is then a no-op on them, and scan
    results are unchanged vs the unsharded build."""
    from repro.search import build_ivf, build_ivfpq, ivf_search
    from repro.search.ivfpq import ivfpq_search
    x = _data()
    key = jax.random.key(1)
    plain = build_ivf(key, x, nlist=12)
    pre = build_ivf(key, x, nlist=12, shards=8)
    assert plain.lists.shape[0] == 12
    assert pre.lists.shape[0] == 16 and pre.lists.shape[0] % 8 == 0
    assert (np.asarray(pre.lists)[12:] == -1).all()      # pad cells empty
    q = _queries()
    _, i1 = ivf_search(plain, q, K, nprobe=5)
    _, i2 = ivf_search(pre, q, K, nprobe=5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    plain = build_ivfpq(key, x, nlist=12, m_subspaces=8, n_centroids=64)
    pre = build_ivfpq(key, x, nlist=12, m_subspaces=8, n_centroids=64,
                      shards=8)
    assert pre.codes_cell.shape[0] == 16 == pre.bias_cell.shape[0]
    _, i1 = ivfpq_search(plain, q, K, nprobe=5)
    _, i2 = ivfpq_search(pre, q, K, nprobe=5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_shard_donate_releases_dense_buffers():
    """``shard(donate=True)`` frees the dense EngineState (no 2x database
    memory): every dense leaf is deleted or — by identity — lives on in
    the sharded pytree; re-sharding raises; results are unchanged."""
    shards = min(2, jax.device_count())
    x = _data()
    eng = SearchEngine(x, _config("ivfpq"))
    q = _queries()
    d0, i0 = eng.search(q, K)
    old_leaves = jax.tree.leaves(eng.state)
    eng.shard(_mesh(shards), donate=True)
    placed = {id(leaf) for leaf in jax.tree.leaves(eng.sharded_state)}
    for leaf in old_leaves:
        # the caller-supplied corpus array stays caller-owned by design
        assert leaf.is_deleted() or id(leaf) in placed or leaf is x
    assert eng.state is None
    with pytest.raises(RuntimeError, match="donate"):
        eng.shard(_mesh(shards))                 # no dense state to re-shard
    d1, i1 = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-5)
    # the public reducer was re-pointed at the replicated projection
    # copies, so it keeps working after the dense arrays were donated
    red = eng.reducer(q)
    assert red.shape == (q.shape[0], 8)


def test_shard_donate_spares_user_owned_corpus():
    """A caller-supplied f32 corpus array passes into EngineState by
    reference; donation must not delete it out from under the caller."""
    x = jnp.asarray(_data(), jnp.float32)
    eng = SearchEngine(x, ServeConfig(target_dim=None, index="flat"))
    eng.shard(_mesh(1), donate=True)
    assert not x.is_deleted()
    assert float(jnp.sum(x)) == float(jnp.sum(x))    # still usable


def test_balanced_cell_placement_improves_shard_mass():
    """Load-aware placement (greedy bin-pack by posting mass) must beat
    the unbalanced layout on a skewed corpus, without changing results."""
    from repro.search import balance_cells, build_ivfpq
    from repro.search.ivfpq import ivfpq_search
    key = jax.random.key(0)
    nlist, shards, d = 16, 4, DIM
    sizes = [600, 300, 150, 80, 40, 30, 20, 15] + [10] * 8
    centers = jax.random.normal(key, (16, d)) * 6
    x = jnp.concatenate([
        centers[i] + 0.1 * jax.random.normal(jax.random.fold_in(key, i),
                                             (s, d))
        for i, s in enumerate(sizes)])

    def imbalance(lists):
        per = lists.shape[0] // shards
        mass = [(np.asarray(lists[s * per:(s + 1) * per]) >= 0).sum()
                for s in range(shards)]
        return max(mass) - min(mass)

    bal = build_ivfpq(jax.random.key(1), x, nlist, 8, 64, shards=shards)
    raw = build_ivfpq(jax.random.key(1), x, nlist, 8, 64, shards=shards,
                      balance=False)
    assert imbalance(bal.lists) < imbalance(raw.lists)
    q = x[:32] + 0.02 * jax.random.normal(jax.random.key(9), (32, d))
    _, i1 = ivfpq_search(bal, q, K, nprobe=8)
    _, i2 = ivfpq_search(raw, q, K, nprobe=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # the permutation is a permutation: every cell placed exactly once
    counts = np.asarray(jnp.bincount(
        jnp.argmin(((x[:, None, :] - bal.centroids[None]) ** 2).sum(-1),
                   axis=1), length=nlist))
    perm = balance_cells(counts, shards)
    assert sorted(perm.tolist()) == list(range(nlist))


def test_sharded_bucket_padding_never_perturbs_results():
    """Query-bucket pad rows must stay row-independent through the
    all_gather + pmin merge, exactly as on the single-device path."""
    eng = _engine("ivf")
    mesh = _mesh(min(2, jax.device_count()))
    eng.shard(mesh)
    try:
        q = _queries(24)
        d24, i24 = eng.search(q, K)         # bucket 64 (padded)
        d5, i5 = eng.search(q[:5], K)       # bucket 8 (small-batch path)
        np.testing.assert_array_equal(np.asarray(i24)[:5], np.asarray(i5))
        np.testing.assert_allclose(np.asarray(d24)[:5], np.asarray(d5),
                                   atol=1e-5)
    finally:
        eng.sharded_state = None
