"""Data pipeline tests: generators, determinism, sharding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import (deterministic_shard, lm_token_batches,
                                 recsys_ranking_batch, twotower_batch)
from repro.data.synthetic import PAPER_DATASETS


def test_paper_generators_shapes():
    for name, (gen, dim, test_n) in PAPER_DATASETS.items():
        xtr, xte = gen(jax.random.key(0), n_train=200, n_test=50)
        assert xtr.ndim == 2 and xte.shape[0] == 50
        assert bool(jnp.all(jnp.isfinite(xtr))), name
        assert float(jnp.std(xtr)) > 0


def test_generators_seeded():
    gen = PAPER_DATASETS["fasttext"][0]
    a1, _ = gen(jax.random.key(3), n_train=64, n_test=8)
    a2, _ = gen(jax.random.key(3), n_train=64, n_test=8)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_lm_stream_deterministic_and_restartable():
    s1 = list(lm_token_batches(7, 2, 16, 100, n_steps=5))
    s2 = list(lm_token_batches(7, 2, 16, 100, n_steps=5))
    for b1, b2 in zip(s1, s2):
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # restart mid-stream: step k is a pure function of (seed, k, shard)
    k1 = deterministic_shard(7, 3, 0)
    k2 = deterministic_shard(7, 3, 0)
    np.testing.assert_array_equal(jax.random.key_data(k1),
                                  jax.random.key_data(k2))


def test_shards_differ():
    a = deterministic_shard(0, 1, 0)
    b = deterministic_shard(0, 1, 1)
    assert not np.array_equal(jax.random.key_data(a), jax.random.key_data(b))


def test_batch_builders():
    rb = recsys_ranking_batch(jax.random.key(0), 8, 10, 1000)
    assert rb["hist_items"].shape == (8, 10)
    assert set(rb) >= {"target_item", "neg_items", "label"}
    tb = twotower_batch(jax.random.key(0), 8, 100, 50, 4, 16)
    assert tb["neg_items"].shape == (16,)
    assert bool(jnp.all(tb["pos_items"] < 50))
